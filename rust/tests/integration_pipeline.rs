//! Lifecycle and space-bound tests for the overlapped-I/O pipeline
//! (`roomy::storage::pipeline`) and the flat per-task capture budget.
//!
//! The determinism matrix (depths × workers, byte-identical state) lives
//! in `tests/determinism.rs`; this suite covers what that one cannot:
//! teardown (no service thread survives the instance, panics leave no
//! staging files), graceful degradation (depth ≫ data), and the
//! metrics-observable RAM bounds.

mod common;

use common::{dir_digest, roomy_with};
use roomy::storage::PIPE_CHUNK;
use roomy::testutil::files_under;
use std::sync::atomic::Ordering;

/// A panicking collective at depth > 0 must (a) surface as WorkerPanic,
/// (b) leave no write-behind staging files under tmp/pipeline/, and —
/// once the instance is dropped — (c) leave no I/O service thread alive.
#[test]
fn panic_mid_collective_leaves_no_threads_or_staging() {
    let (t, r) = roomy_with("pipe_panic", |c| {
        c.workers = 2;
        c.buckets_per_worker = 2;
        c.num_workers = 4;
        c.io_pipeline_depth = 4;
    });
    let nworkers = r.cluster().nworkers();
    let flags = r.cluster().io_alive_flags();
    assert_eq!(flags.len(), nworkers * 2, "one read + one write lane per node");
    assert!(flags.iter().all(|f| f.load(Ordering::SeqCst)));

    // map_update holds a PrefetchReader *and* a write-behind stream (with
    // a staging file under tmp/pipeline/) per bucket task — panicking in
    // its middle abandons both mid-flight.
    let ra = r.array::<u64>("a", 600_000, 1).unwrap();
    let res = ra.map_update(|i, _v| assert!(i != 444_444, "boom"));
    match res {
        Err(roomy::RoomyError::WorkerPanic { .. }) => {}
        other => panic!("expected WorkerPanic, got {other:?}"),
    }

    // Every staging file is gone right after the failed collective
    // returns (writer Drop cleans up during unwinding, before the pool
    // reports the panic).
    for w in 0..nworkers {
        let staging = r.cluster().disk(w).root().join("tmp/pipeline");
        assert_eq!(files_under(&staging), 0, "staging leak on node {w}");
    }

    // The instance stays usable after the failed collective...
    let count = std::sync::atomic::AtomicU64::new(0);
    ra.map(|_i, _v| {
        count.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(count.into_inner(), 600_000);

    // ...and teardown joins every service lane.
    drop(ra);
    drop(r);
    drop(t);
    assert!(
        flags.iter().all(|f| !f.load(Ordering::SeqCst)),
        "an io service thread survived instance teardown"
    );
}

/// A depth far larger than the data (and than the bucket count) degrades
/// gracefully: tiny structures work, produce bytes identical to the
/// synchronous run, and allocate at most one chunk per stream.
#[test]
fn depth_larger_than_buckets_degrades_gracefully() {
    let run = |depth: usize| {
        let (t, r) = roomy_with(&format!("pipe_deep_{depth}"), |c| {
            c.workers = 2;
            c.buckets_per_worker = 2; // 4 buckets, depth 64 dwarfs them
            c.num_workers = 2;
            c.io_pipeline_depth = depth;
        });
        let l = r.list::<u64>("l").unwrap();
        for v in [3u64, 1, 4, 1, 5, 9, 2, 6] {
            l.add(&v).unwrap();
        }
        l.sync().unwrap();
        l.remove_dupes().unwrap();
        assert_eq!(l.size(), 7);
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.insert(&2, &20).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.fetch(&2).unwrap(), Some(20));
        if depth > 0 {
            let snap = r.cluster().pipeline_snapshot();
            assert!(
                snap.peak_stream_buf <= PIPE_CHUNK as u64,
                "sub-chunk data allocated {} bytes of stream buffers",
                snap.peak_stream_buf
            );
        }
        drop(ht);
        drop(l);
        drop(r);
        dir_digest(t.path())
    };
    let reference = run(0);
    assert_eq!(run(64), reference, "depth 64 diverged from synchronous bytes");
}

/// Pipeline RAM is metered and bounded: a bulk scan + rewrite at depth d
/// keeps every stream's buffers within d × PIPE_CHUNK, visibly uses the
/// pipeline (chunks flow both directions), and ends with clean scratch.
#[test]
fn pipeline_ram_bounded_by_depth_times_chunk() {
    for depth in [1usize, 2, 4] {
        let (t, r) = roomy_with(&format!("pipe_ram_{depth}"), |c| {
            c.workers = 2;
            c.buckets_per_worker = 2;
            c.num_workers = 2;
            c.io_pipeline_depth = depth;
        });
        let ra = r.array::<u64>("a", 600_000, 1).unwrap(); // ~4.8 MB
        ra.map_update(|i, v| *v = i ^ *v).unwrap();
        let sum = ra
            .reduce(|| 0u64, |a, _i, v| a.wrapping_add(*v), |a, b| a.wrapping_add(b))
            .unwrap();
        assert_eq!(
            sum,
            (0..600_000u64).fold(0u64, |a, i| a.wrapping_add(i ^ 1))
        );

        let snap = r.cluster().pipeline_snapshot();
        assert!(snap.streams > 0, "pipeline never engaged at depth {depth}");
        assert!(snap.chunks_ahead > 0, "no read-ahead at depth {depth}");
        assert!(snap.chunks_behind > 0, "no write-behind at depth {depth}");
        assert!(
            snap.peak_stream_buf <= (depth * PIPE_CHUNK) as u64,
            "depth {depth}: peak stream buffers {} exceed depth × chunk = {}",
            snap.peak_stream_buf,
            depth * PIPE_CHUNK
        );
        for w in 0..r.cluster().nworkers() {
            let staging = r.cluster().disk(w).root().join("tmp/pipeline");
            assert_eq!(files_under(&staging), 0, "staging leak on node {w}");
        }
        drop(ra);
        drop(r);
        drop(t);
    }
}

/// The flat per-task capture budget spans destination structures: a map
/// staging into three lists stays within one threshold + one record of
/// capture RAM per task, counts its budget-forced spills, and remains
/// byte-deterministic across worker counts and depths.
#[test]
fn flat_capture_budget_spans_destinations() {
    const THRESHOLD: usize = 256;
    const RECORD: usize = 2 + 8 + 8; // list op (hdr + elt) + capture header

    let run = |nw: usize, depth: usize| {
        let (t, r) = roomy_with(&format!("pipe_flatcap_{nw}_{depth}"), |c| {
            c.num_workers = nw;
            c.workers = 3;
            c.buckets_per_worker = 2;
            c.io_pipeline_depth = depth;
            c.capture_spill_threshold = THRESHOLD;
        });
        let src = r.list::<u64>("src").unwrap();
        for v in 0..3_000u64 {
            src.add(&v).unwrap();
        }
        src.sync().unwrap();
        let dsts: Vec<_> =
            (0..3).map(|i| r.list::<u64>(&format!("dst{i}")).unwrap()).collect();
        let emit = dsts.clone();
        // Each element stages into all three destinations: per-destination
        // volume per task (~6.7 KiB) and task total (~20 KiB) both dwarf
        // the 256-byte flat budget.
        src.map(move |&v| {
            for (i, d) in emit.iter().enumerate() {
                d.add(&(v * 3 + i as u64)).unwrap();
            }
        })
        .unwrap();

        let stats = r.cluster().pool().stats();
        assert!(
            stats.capture_peak_task_ram() as usize <= THRESHOLD + RECORD,
            "flat budget violated: peak {} > {} + record across 3 destinations",
            stats.capture_peak_task_ram(),
            THRESHOLD,
        );
        assert!(stats.capture_budget_spills() > 0, "budget never forced a spill");
        assert!(stats.capture_spilled_bytes() > 0);
        for w in 0..r.cluster().nworkers() {
            let scratch = r.cluster().disk(w).root().join("tmp/capture");
            assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
        }
        for d in &dsts {
            d.sync().unwrap();
            assert_eq!(d.size(), 3_000);
        }
        drop(dsts);
        drop(src);
        drop(r);
        dir_digest(t.path())
    };

    let serial = run(1, 0);
    for (nw, depth) in [(2usize, 0usize), (4, 0), (1, 4), (4, 4)] {
        assert_eq!(
            run(nw, depth),
            serial,
            "on-disk bytes diverged at num_workers={nw} depth={depth}"
        );
    }
}
