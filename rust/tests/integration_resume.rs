//! Kill-and-resume pancake BFS — the acceptance bar for the durable
//! checkpoint subsystem.
//!
//! For every cell of pool workers {1, 4} × io pipeline depths {0, 4}:
//! run pancake n=7 to completion with a checkpoint after every level
//! (the uninterrupted reference), then run it again in a separate root,
//! "kill" it after three levels (in-RAM state abandoned, checkpoint on
//! disk), wreck the checkpoint dir with a half-written staging directory
//! (crash-mid-save), and resume in a **fresh session**. The resumed run
//! must produce the identical level profile and a final checkpoint whose
//! per-file digests are byte-identical to the reference — and every cell
//! must agree with every other cell, so neither the kill point, the
//! worker count nor the pipeline depth leaves a trace in the bytes.

mod common;

use roomy::accel::Accel;
use roomy::apps::pancake::{self, Structure};
use roomy::constructs::bfs::{BfsOutcome, LevelStats, ResumableBfs};
use roomy::testutil::tmpdir;
use roomy::{Roomy, RoomyConfig};

const MATRIX: [(usize, usize); 4] = [(1, 0), (1, 4), (4, 0), (4, 4)];

fn open(root: &std::path::Path, num_workers: usize, depth: usize) -> Roomy {
    let mut cfg = RoomyConfig::for_testing(root);
    cfg.num_workers = num_workers;
    cfg.io_pipeline_depth = depth;
    Roomy::open(cfg).unwrap()
}

/// Like [`open`] but with the exact-backed bloom dedup tier active —
/// checkpoint bytes must not notice the difference.
fn open_bloom(root: &std::path::Path, num_workers: usize, depth: usize) -> Roomy {
    let mut cfg = RoomyConfig::for_testing(root);
    cfg.num_workers = num_workers;
    cfg.io_pipeline_depth = depth;
    cfg.bloom_bits_per_key = 10;
    Roomy::open(cfg).unwrap()
}

/// Run the resumable pancake driver to completion and return the level
/// stats plus the final checkpoint's per-file digest rows.
fn run_to_completion(
    r: &Roomy,
    n: usize,
    structure: Structure,
    tag: &str,
) -> (LevelStats, Vec<(usize, String, u64, u64)>) {
    let mgr = r.checkpoints().unwrap();
    let out = pancake::roomy_bfs_resumable(
        r,
        n,
        structure,
        &Accel::rust(),
        &ResumableBfs::new(&mgr, tag),
    )
    .unwrap();
    let digests = mgr.load_manifest(tag).unwrap().file_digests();
    match out {
        BfsOutcome::Complete(stats) => (stats, digests),
        other => panic!("expected completion, got {other:?}"),
    }
}

/// `#[ignore]`: 8 full pancake n=7 runs make this the most expensive
/// test in the repo, and it pins its own worker/depth matrix regardless
/// of the suite-wide env — so the plain `cargo test` pass would only
/// repeat it without adding coverage. CI runs it in a dedicated release
/// step (`--include-ignored`); locally: `cargo test --release --test
/// integration_resume -- --include-ignored`.
#[test]
#[ignore]
fn pancake_n7_kill_and_resume_matrix_is_byte_identical() {
    let n = 7;
    let expect_levels = pancake::reference_bfs(n);
    let mut pinned: Option<(LevelStats, Vec<(usize, String, u64, u64)>)> = None;

    for &(nw, depth) in &MATRIX {
        // --- uninterrupted reference, checkpointing every level -------
        let t_ref = tmpdir(&format!("resume_ref_w{nw}_d{depth}"));
        let (ref_stats, ref_digests) = {
            let r = open(t_ref.path(), nw, depth);
            run_to_completion(&r, n, Structure::List, "pk")
        };
        assert_eq!(ref_stats.levels, expect_levels, "w{nw} d{depth}");
        assert_eq!(ref_stats.total, pancake::factorial(n));

        // --- killed after 3 levels, crash-mid-save, resumed fresh -----
        let t_kill = tmpdir(&format!("resume_kill_w{nw}_d{depth}"));
        {
            let r = open(t_kill.path(), nw, depth);
            let mgr = r.checkpoints().unwrap();
            let opts = ResumableBfs {
                manager: &mgr,
                tag: "pk".into(),
                stop_after_levels: Some(3),
            };
            let out =
                pancake::roomy_bfs_resumable(&r, n, Structure::List, &Accel::rust(), &opts)
                    .unwrap();
            assert_eq!(out, BfsOutcome::Suspended { next_level: 4 }, "w{nw} d{depth}");
            // crash mid-save: a half-written staging dir appears beside
            // the committed checkpoint; the prior checkpoint must stay
            // restorable and the next save must clean this up
            let staging = mgr.root().join("pk.staging");
            std::fs::create_dir_all(staging.join("node0/rl_pancake_all")).unwrap();
            std::fs::write(staging.join("node0/rl_pancake_all/s0.dat"), b"torn").unwrap();
        } // session dies here (io services joined, state dropped)

        let (res_stats, res_digests) = {
            let r = open(t_kill.path(), nw, depth);
            run_to_completion(&r, n, Structure::List, "pk")
        };

        // within-cell: resumed == uninterrupted, to the byte
        assert_eq!(res_stats, ref_stats, "level profile diverged at w{nw} d{depth}");
        assert_eq!(
            res_digests, ref_digests,
            "final structure digests diverged at w{nw} d{depth}"
        );
        assert!(!res_digests.is_empty(), "final checkpoint holds no files?");

        // cross-cell: no worker count / pipeline depth leaves a trace
        match pinned.take() {
            None => pinned = Some((ref_stats, ref_digests)),
            Some((p_stats, p_digests)) => {
                assert_eq!(ref_stats, p_stats, "profile diverged across cells at w{nw} d{depth}");
                assert_eq!(
                    ref_digests, p_digests,
                    "digests diverged across cells at w{nw} d{depth}"
                );
                pinned = Some((p_stats, p_digests));
            }
        }
    }

    // bloom cell: kill-and-resume with the dedup filter active must land
    // on the same pinned bytes as every bloom-off cell above.
    let (p_stats, p_digests) = pinned.expect("matrix ran");
    let t_bloom = tmpdir("resume_kill_bloom");
    {
        let r = open_bloom(t_bloom.path(), 4, 4);
        let mgr = r.checkpoints().unwrap();
        let opts = ResumableBfs { manager: &mgr, tag: "pk".into(), stop_after_levels: Some(3) };
        let out =
            pancake::roomy_bfs_resumable(&r, n, Structure::List, &Accel::rust(), &opts).unwrap();
        assert_eq!(out, BfsOutcome::Suspended { next_level: 4 }, "bloom cell");
    }
    let (bloom_stats, bloom_digests) = {
        let r = open_bloom(t_bloom.path(), 4, 4);
        run_to_completion(&r, n, Structure::List, "pk")
    };
    assert_eq!(bloom_stats, p_stats, "profile diverged in the bloom cell");
    assert_eq!(bloom_digests, p_digests, "digests diverged in the bloom cell");
}

#[test]
fn pancake_hash_variant_kill_and_resume_matches() {
    let n = 6;
    let t_ref = tmpdir("resume_hash_ref");
    let (ref_stats, ref_digests) = {
        let r = open(t_ref.path(), 4, 4);
        run_to_completion(&r, n, Structure::Hash, "pkh")
    };
    assert_eq!(ref_stats.levels, pancake::reference_bfs(n));

    let t_kill = tmpdir("resume_hash_kill");
    {
        let r = open(t_kill.path(), 4, 4);
        let mgr = r.checkpoints().unwrap();
        let opts =
            ResumableBfs { manager: &mgr, tag: "pkh".into(), stop_after_levels: Some(2) };
        let out =
            pancake::roomy_bfs_resumable(&r, n, Structure::Hash, &Accel::rust(), &opts).unwrap();
        assert_eq!(out, BfsOutcome::Suspended { next_level: 3 });
    }
    let (res_stats, res_digests) = {
        let r = open(t_kill.path(), 4, 4);
        run_to_completion(&r, n, Structure::Hash, "pkh")
    };
    assert_eq!(res_stats, ref_stats);
    assert_eq!(res_digests, ref_digests);
}

#[test]
fn pancake_array_variant_kill_and_resume_matches() {
    // The Array variant checkpoints its seen-bits bit array together with
    // the current level list (the carried ROADMAP item).
    let n = 6;
    let t_ref = tmpdir("resume_arr_ref");
    let (ref_stats, ref_digests) = {
        let r = open(t_ref.path(), 4, 4);
        run_to_completion(&r, n, Structure::Array, "pka")
    };
    assert_eq!(ref_stats.levels, pancake::reference_bfs(n));
    assert_eq!(ref_stats.total, pancake::factorial(n));

    let t_kill = tmpdir("resume_arr_kill");
    {
        let r = open(t_kill.path(), 4, 4);
        let mgr = r.checkpoints().unwrap();
        let opts =
            ResumableBfs { manager: &mgr, tag: "pka".into(), stop_after_levels: Some(2) };
        let out =
            pancake::roomy_bfs_resumable(&r, n, Structure::Array, &Accel::rust(), &opts).unwrap();
        assert_eq!(out, BfsOutcome::Suspended { next_level: 3 });
    }
    let (res_stats, res_digests) = {
        let r = open(t_kill.path(), 4, 4);
        run_to_completion(&r, n, Structure::Array, "pka")
    };
    assert_eq!(res_stats, ref_stats);
    assert_eq!(res_digests, ref_digests);
    assert!(!res_digests.is_empty());
}

/// Kill-and-resume with the bloom dedup tier active: the filter is
/// RAM-only and rebuilt from restored bucket/shard files, so a bloom-on
/// killed-and-resumed run must match the **bloom-off uninterrupted**
/// reference byte-for-byte — for both BFS dedup families.
#[test]
fn bloom_kill_and_resume_matches_bloom_off_reference_n6() {
    let n = 6;
    for (structure, tag) in [(Structure::List, "pkbl"), (Structure::Hash, "pkbh")] {
        // bloom-off uninterrupted reference
        let t_ref = tmpdir(&format!("resume_bloom_ref_{tag}"));
        let (ref_stats, ref_digests) = {
            let r = open(t_ref.path(), 4, 4);
            run_to_completion(&r, n, structure, tag)
        };
        assert_eq!(ref_stats.levels, pancake::reference_bfs(n), "{tag}");

        // bloom-on, killed after two levels, resumed bloom-on fresh
        let t_kill = tmpdir(&format!("resume_bloom_kill_{tag}"));
        {
            let r = open_bloom(t_kill.path(), 4, 4);
            let mgr = r.checkpoints().unwrap();
            let opts =
                ResumableBfs { manager: &mgr, tag: tag.into(), stop_after_levels: Some(2) };
            let out =
                pancake::roomy_bfs_resumable(&r, n, structure, &Accel::rust(), &opts).unwrap();
            assert_eq!(out, BfsOutcome::Suspended { next_level: 3 }, "{tag}");
        }
        let (res_stats, res_digests) = {
            let r = open_bloom(t_kill.path(), 4, 4);
            let snap_before = r.dedup_snapshot();
            let out = run_to_completion(&r, n, structure, tag);
            let snap = r.dedup_snapshot();
            assert!(
                snap.probes > snap_before.probes,
                "{tag}: resumed run never touched the filter: {snap:?}"
            );
            out
        };
        assert_eq!(res_stats, ref_stats, "{tag}: profile diverged under bloom");
        assert_eq!(
            res_digests, ref_digests,
            "{tag}: bloom-on resumed checkpoint bytes differ from bloom-off reference"
        );
    }
}

/// A checkpoint written bloom-off must resume correctly bloom-on (and
/// vice versa): the filter is config state, not checkpoint state.
#[test]
fn bloom_mode_can_change_across_resume_sessions() {
    let n = 6;
    let t_ref = tmpdir("resume_bloomx_ref");
    let (ref_stats, ref_digests) = {
        let r = open(t_ref.path(), 4, 0);
        run_to_completion(&r, n, Structure::Hash, "pkx")
    };

    let t = tmpdir("resume_bloomx");
    {
        // session 1: bloom OFF, killed after one level
        let r = open(t.path(), 4, 0);
        let mgr = r.checkpoints().unwrap();
        let opts = ResumableBfs { manager: &mgr, tag: "pkx".into(), stop_after_levels: Some(1) };
        let out =
            pancake::roomy_bfs_resumable(&r, n, Structure::Hash, &Accel::rust(), &opts).unwrap();
        assert_eq!(out, BfsOutcome::Suspended { next_level: 2 });
    }
    {
        // session 2: bloom ON over the bloom-off checkpoint, killed again
        let r = open_bloom(t.path(), 4, 0);
        let mgr = r.checkpoints().unwrap();
        let opts = ResumableBfs { manager: &mgr, tag: "pkx".into(), stop_after_levels: Some(2) };
        let out =
            pancake::roomy_bfs_resumable(&r, n, Structure::Hash, &Accel::rust(), &opts).unwrap();
        assert_eq!(out, BfsOutcome::Suspended { next_level: 4 });
    }
    // session 3: bloom OFF again, runs to completion
    let (stats, digests) = {
        let r = open(t.path(), 4, 0);
        run_to_completion(&r, n, Structure::Hash, "pkx")
    };
    assert_eq!(stats, ref_stats);
    assert_eq!(digests, ref_digests);
}

#[test]
fn repeated_kills_every_level_still_converge() {
    // the pathological operator: killed after every single level
    let n = 6;
    let t_ref = tmpdir("resume_rep_ref");
    let (ref_stats, ref_digests) = {
        let r = open(t_ref.path(), 4, 0);
        run_to_completion(&r, n, Structure::List, "pk")
    };

    let t = tmpdir("resume_rep");
    let mut rounds = 0u32;
    let (stats, digests) = loop {
        rounds += 1;
        assert!(rounds < 32, "resume failed to make progress");
        let r = open(t.path(), 4, 0);
        let mgr = r.checkpoints().unwrap();
        let opts =
            ResumableBfs { manager: &mgr, tag: "pk".into(), stop_after_levels: Some(1) };
        match pancake::roomy_bfs_resumable(&r, n, Structure::List, &Accel::rust(), &opts)
            .unwrap()
        {
            BfsOutcome::Suspended { .. } => continue,
            BfsOutcome::Complete(stats) => {
                break (stats, mgr.load_manifest("pk").unwrap().file_digests())
            }
        }
    };
    assert_eq!(stats, ref_stats);
    assert_eq!(digests, ref_digests);
}
