//! Robustness / failure-injection: corrupted op logs, truncated bucket
//! files, worker panics, exotic configurations — the failure surface a
//! production adopter hits first.

mod common;

use common::{roomy, roomy_with};
use roomy::{RoomyError, RoomySet};

#[test]
fn corrupt_op_tag_is_clean_error_not_panic() {
    let (_t, r) = roomy("rb_corrupt");
    let ra = r.array::<u32>("a", 16, 0).unwrap();
    let add = ra.register_update(|_i, v: &mut u32, p: &u32| *v += p);
    ra.update(3, &1u32, add).unwrap();
    // Overwrite the staged spill with garbage by forcing a spill first.
    // Instead of poking internals, craft a corrupt staged file through a
    // tiny-buffer config in a second instance:
    let (_t2, r2) = roomy_with("rb_corrupt2", |c| c.op_buffer_bytes = 1);
    let ra2 = r2.array::<u32>("a", 4, 0).unwrap();
    let add2 = ra2.register_update(|_i, v: &mut u32, p: &u32| *v += p);
    ra2.update(0, &1u32, add2).unwrap(); // spilled immediately
    // find the spill file and scribble on it
    let mut scribbled = false;
    for w in 0..r2.cluster().nworkers() {
        let disk = r2.cluster().disk(w);
        for f in disk.list("ra_a").unwrap() {
            if f.to_str().unwrap().contains(".spill") {
                let root = disk.root().join(&f);
                std::fs::write(&root, [0xFFu8; 12]).unwrap();
                scribbled = true;
            }
        }
    }
    assert!(scribbled, "expected a spill file to corrupt");
    match ra2.sync() {
        Err(RoomyError::InvalidArg(msg)) => assert!(msg.contains("corrupt"), "{msg}"),
        other => panic!("expected corrupt-op error, got {other:?}"),
    }
}

#[test]
fn misaligned_bucket_file_is_clean_error() {
    let (_t, r) = roomy("rb_misaligned");
    let ra = r.array::<u64>("a", 64, 0).unwrap();
    // truncate one bucket file to a non-multiple of the record size
    let disk = r.cluster().disk(0);
    let files = disk.list("ra_a").unwrap();
    let target = disk.root().join(&files[0]);
    let data = std::fs::read(&target).unwrap();
    std::fs::write(&target, &data[..data.len() - 3]).unwrap();
    let err = ra.map(|_i, _v| {}).unwrap_err();
    assert!(
        err.to_string().contains("multiple of record size"),
        "unexpected error: {err}"
    );
}

#[test]
fn user_fn_panic_is_worker_panic_error() {
    let (_t, r) = roomy("rb_panic");
    let ra = r.array::<u32>("a", 8, 0).unwrap();
    let boom = ra.register_update(|i, _v: &mut u32, _p: &()| {
        if i == 5 {
            panic!("user function exploded");
        }
    });
    ra.update(5, &(), boom).unwrap();
    match ra.sync() {
        Err(RoomyError::WorkerPanic { .. }) => {}
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
}

#[test]
fn single_bucket_single_worker_everything_still_works() {
    let (_t, r) = roomy_with("rb_tiny", |c| {
        c.workers = 1;
        c.buckets_per_worker = 1;
    });
    let l = r.list::<u64>("l").unwrap();
    for v in 0..100u64 {
        l.add(&(v % 10)).unwrap();
    }
    l.sync().unwrap();
    l.remove_dupes().unwrap();
    assert_eq!(l.size(), 10);
    let ht = r.hash_table::<u64, u64>("h").unwrap();
    ht.insert(&1, &2).unwrap();
    ht.sync().unwrap();
    assert_eq!(ht.fetch(&1).unwrap(), Some(2));
}

#[test]
fn many_tiny_buckets_configuration() {
    let (_t, r) = roomy_with("rb_manybuckets", |c| {
        c.workers = 2;
        c.buckets_per_worker = 64; // 128 buckets for 200 elements
    });
    let ra = r.array::<u32>("a", 200, 7).unwrap();
    ra.map_update(|i, v| *v = i as u32).unwrap();
    let sum = ra.reduce(|| 0u64, |a, _i, v| a + *v as u64, |a, b| a + b).unwrap();
    assert_eq!(sum, (0..200).sum::<u64>());
}

#[test]
fn element_larger_than_op_buffer_still_stages() {
    let (_t, r) = roomy_with("rb_bigelt", |c| c.op_buffer_bytes = 8);
    let l = r.list::<[u8; 64]>("l").unwrap();
    let big = [7u8; 64];
    for _ in 0..10 {
        l.add(&big).unwrap();
    }
    l.sync().unwrap();
    assert_eq!(l.size(), 10);
}

#[test]
fn set_remove_of_absent_and_double_destroy_name_reuse() {
    let (_t, r) = roomy("rb_setedge");
    let s: RoomySet<u64> = r.set("s").unwrap();
    s.remove(&42).unwrap(); // absent: no-op
    s.sync().unwrap();
    assert_eq!(s.size(), 0);
    s.add(&1).unwrap();
    s.sync().unwrap();
    s.destroy().unwrap();
    r.release_name("s");
    let s2: RoomySet<u64> = r.set("s").unwrap();
    assert_eq!(s2.size(), 0, "recreated set starts empty");
}

#[test]
fn interleaved_structures_share_cluster_without_interference() {
    let (_t, r) = roomy("rb_interleave");
    let a = r.array::<u64>("a", 100, 0).unwrap();
    let l = r.list::<u64>("l").unwrap();
    let h = r.hash_table::<u64, u64>("h").unwrap();
    let s = r.set::<u64>("s").unwrap();
    let bump = h.register_update(|_k, cur: Option<&u64>, _p: &()| {
        Some(cur.copied().unwrap_or(0) + 1)
    });
    let setv = a.register_update(|_i, v: &mut u64, p: &u64| *v = *p);
    for i in 0..100u64 {
        a.update(i, &(i * 2), setv).unwrap();
        l.add(&i).unwrap();
        h.update(&(i % 7), &(), bump).unwrap();
        s.add(&(i % 13)).unwrap();
    }
    a.sync().unwrap();
    l.sync().unwrap();
    h.sync().unwrap();
    s.sync().unwrap();
    assert_eq!(a.fetch(50).unwrap(), 100);
    assert_eq!(l.size(), 100);
    assert_eq!(h.size(), 7);
    assert_eq!(s.size(), 13);
}
