//! Shared helpers for the integration suites.

// Each integration crate includes this module and uses a subset of it.
#![allow(dead_code)]

use std::path::Path;

use roomy::{Roomy, RoomyConfig};

/// Open a Roomy instance over a fresh temp root; returns the guard too so
/// the directory outlives the instance.
pub fn roomy(tag: &str) -> (roomy::testutil::TmpDir, Roomy) {
    let t = roomy::testutil::tmpdir(tag);
    let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
    (t, r)
}

/// Like [`roomy`] but with a customized config.
pub fn roomy_with(tag: &str, f: impl FnOnce(&mut RoomyConfig)) -> (roomy::testutil::TmpDir, Roomy) {
    let t = roomy::testutil::tmpdir(tag);
    let mut cfg = RoomyConfig::for_testing(t.path());
    f(&mut cfg);
    let r = Roomy::open(cfg).unwrap();
    (t, r)
}

/// True if AOT artifacts are available (XLA paths testable).
pub fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.tsv").exists()
}

/// FNV-1a over every file under `root`: (sorted relative path, contents).
/// Two instance roots with equal digests hold byte-identical on-disk
/// state — the currency of the determinism suites.
pub fn dir_digest(root: &Path) -> u64 {
    fn collect(base: &Path, dir: &Path, out: &mut Vec<std::path::PathBuf>) {
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        for e in entries.flatten() {
            let p = e.path();
            if p.is_dir() {
                collect(base, &p, out);
            } else {
                out.push(p.strip_prefix(base).unwrap().to_path_buf());
            }
        }
    }
    let mut files = Vec::new();
    collect(root, root, &mut files);
    files.sort();
    let mut h = 0xcbf29ce484222325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for rel in files {
        eat(rel.to_string_lossy().as_bytes());
        eat(&[0]);
        eat(&std::fs::read(root.join(&rel)).unwrap());
        eat(&[0xFF]);
    }
    h
}

