//! Shared helpers for the integration suites.

use roomy::{Roomy, RoomyConfig};

/// Open a Roomy instance over a fresh temp root; returns the guard too so
/// the directory outlives the instance.
pub fn roomy(tag: &str) -> (roomy::testutil::TmpDir, Roomy) {
    let t = roomy::testutil::tmpdir(tag);
    let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
    (t, r)
}

/// Like [`roomy`] but with a customized config.
pub fn roomy_with(tag: &str, f: impl FnOnce(&mut RoomyConfig)) -> (roomy::testutil::TmpDir, Roomy) {
    let t = roomy::testutil::tmpdir(tag);
    let mut cfg = RoomyConfig::for_testing(t.path());
    f(&mut cfg);
    let r = Roomy::open(cfg).unwrap();
    (t, r)
}

/// True if AOT artifacts are available (XLA paths testable).
pub fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.tsv").exists()
}
