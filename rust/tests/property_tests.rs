//! Property-based invariants over the whole stack, via the homegrown
//! deterministic harness (`roomy::testutil::prop`). Each property runs a
//! randomized workload against an in-RAM model.

mod common;

use common::roomy_with;
use roomy::testutil::{prop_check, Rng};
use std::collections::{BTreeMap, BTreeSet, HashMap};

fn rand_cfg(rng: &mut Rng, c: &mut roomy::RoomyConfig) {
    c.workers = rng.range(1, 5);
    c.buckets_per_worker = rng.range(1, 4);
    c.op_buffer_bytes = [64usize, 1024, 64 * 1024][rng.range(0, 3)];
}

#[test]
fn prop_array_sync_equals_serial_application() {
    prop_check("array sync == serial model", 12, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_array", |c| rand_cfg(&mut seed_rng, c));
        let n = rng.range(1, 300) as u64;
        let ra = r.array::<i64>("a", n, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut i64, p: &i64| *v = v.wrapping_add(*p));
        let setv = ra.register_update(|_i, v: &mut i64, p: &i64| *v = *p);
        let mut model = vec![0i64; n as usize];
        // several sync rounds of random ops
        for _round in 0..rng.range(1, 4) {
            for _ in 0..rng.range(0, 500) {
                let i = rng.below(n);
                let p = rng.range_i64(-100, 100);
                if rng.chance(0.5) {
                    ra.update(i, &p, add).unwrap();
                    model[i as usize] = model[i as usize].wrapping_add(p);
                } else {
                    ra.update(i, &p, setv).unwrap();
                    model[i as usize] = p;
                }
            }
            ra.sync().unwrap();
        }
        let collected = std::sync::Mutex::new(vec![0i64; n as usize]);
        ra.map(|i, v| collected.lock().unwrap()[i as usize] = *v).unwrap();
        assert_eq!(*collected.lock().unwrap(), model);
    });
}

#[test]
fn prop_hashtable_equals_hashmap_model() {
    prop_check("hashtable == HashMap model", 12, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_ht", |c| rand_cfg(&mut seed_rng, c));
        let ht = r.hash_table::<u64, i64>("h").unwrap();
        let bump = ht.register_update(|_k, cur: Option<&i64>, p: &i64| {
            Some(cur.copied().unwrap_or(0) + p)
        });
        let mut model: HashMap<u64, i64> = HashMap::new();
        for _round in 0..rng.range(1, 4) {
            for _ in 0..rng.range(0, 400) {
                let k = rng.below(50); // heavy collisions
                match rng.range(0, 3) {
                    0 => {
                        let v = rng.range_i64(-9, 9);
                        ht.insert(&k, &v).unwrap();
                        model.insert(k, v);
                    }
                    1 => {
                        ht.remove(&k).unwrap();
                        model.remove(&k);
                    }
                    _ => {
                        let p = rng.range_i64(1, 5);
                        ht.update(&k, &p, bump).unwrap();
                        *model.entry(k).or_insert(0) += p;
                    }
                }
            }
            ht.sync().unwrap();
        }
        assert_eq!(ht.size(), model.len() as u64);
        let collected = std::sync::Mutex::new(HashMap::new());
        ht.map(|k, v| {
            collected.lock().unwrap().insert(*k, *v);
        })
        .unwrap();
        assert_eq!(*collected.lock().unwrap(), model);
    });
}

#[test]
fn prop_list_equals_multiset_model() {
    prop_check("list == multiset model", 12, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_list", |c| rand_cfg(&mut seed_rng, c));
        let l = r.list::<u64>("l").unwrap();
        let mut model: BTreeMap<u64, u64> = BTreeMap::new();
        for _round in 0..rng.range(1, 4) {
            // Roomy list sync semantics: all adds of the sync apply first,
            // then removes delete every occurrence — model it that way.
            let mut adds: Vec<u64> = Vec::new();
            let mut removes: Vec<u64> = Vec::new();
            for _ in 0..rng.range(0, 300) {
                let v = rng.below(40);
                if rng.chance(0.8) {
                    l.add(&v).unwrap();
                    adds.push(v);
                } else {
                    l.remove(&v).unwrap();
                    removes.push(v);
                }
            }
            l.sync().unwrap();
            for v in adds {
                *model.entry(v).or_insert(0) += 1;
            }
            for v in removes {
                model.remove(&v);
            }
            if rng.chance(0.3) {
                l.remove_dupes().unwrap();
                for c in model.values_mut() {
                    *c = 1;
                }
            }
        }
        let mut got: BTreeMap<u64, u64> = BTreeMap::new();
        for v in l.collect().unwrap() {
            *got.entry(v).or_insert(0) += 1;
        }
        assert_eq!(got, model);
        assert_eq!(l.size(), model.values().sum::<u64>());
    });
}

#[test]
fn prop_setops_match_std_sets() {
    prop_check("set ops == BTreeSet", 10, |rng| {
        // half the cases force the sort-merge removeAll path (budget 1)
        let budget = if rng.chance(0.5) { 1 } else { 1 << 20 };
        let (_t, r) = roomy_with("pt_set", |c| c.ram_budget_bytes = budget);
        let va: Vec<u64> = (0..rng.range(0, 120)).map(|_| rng.below(60)).collect();
        let vb: Vec<u64> = (0..rng.range(0, 120)).map(|_| rng.below(60)).collect();
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in &va {
            a.add(v).unwrap();
        }
        for v in &vb {
            b.add(v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        roomy::constructs::setops::to_set(&a).unwrap();
        roomy::constructs::setops::to_set(&b).unwrap();
        let sa: BTreeSet<u64> = va.into_iter().collect();
        let sb: BTreeSet<u64> = vb.into_iter().collect();
        let c = roomy::constructs::setops::intersection(&r, "c", &a, &b).unwrap();
        let got: BTreeSet<u64> = c.collect().unwrap().into_iter().collect();
        let expect: BTreeSet<u64> = sa.intersection(&sb).copied().collect();
        assert_eq!(got, expect);
    });
}

#[test]
fn prop_bfs_matches_ram_bfs() {
    prop_check("roomy BFS == RAM BFS", 6, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_bfs", |c| rand_cfg(&mut seed_rng, c));
        // random functional graph with out-degree 2 over m nodes
        let m = rng.range(5, 120) as u64;
        let s1 = rng.next_u64() | 1;
        let s2 = rng.next_u64() | 1;
        let gen = move |v: u64| {
            [v.wrapping_mul(s1) % m, v.wrapping_mul(s2).wrapping_add(1) % m]
        };
        // RAM BFS
        let mut seen = vec![false; m as usize];
        seen[0] = true;
        let mut cur = vec![0u64];
        let mut ram_levels = vec![1u64];
        let mut total = 1u64;
        while !cur.is_empty() {
            let mut next = vec![];
            for &v in &cur {
                for nb in gen(v) {
                    if !seen[nb as usize] {
                        seen[nb as usize] = true;
                        next.push(nb);
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            ram_levels.push(next.len() as u64);
            total += next.len() as u64;
            cur = next;
        }
        // Roomy BFS
        let stats = roomy::constructs::bfs::bfs_list(&r, "g", &[0u64], move |&v, out| {
            out.extend(gen(v));
        })
        .unwrap();
        assert_eq!(stats.levels, ram_levels);
        assert_eq!(stats.total, total);
    });
}

#[test]
fn prop_pancake_small_n_random_config() {
    prop_check("pancake BFS any config", 4, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_pancake", |c| rand_cfg(&mut seed_rng, c));
        let n = rng.range(4, 7);
        let s = [
            roomy::apps::pancake::Structure::List,
            roomy::apps::pancake::Structure::Hash,
            roomy::apps::pancake::Structure::Array,
        ][rng.range(0, 3)];
        let stats =
            roomy::apps::pancake::roomy_bfs(&r, n, s, &roomy::accel::Accel::rust()).unwrap();
        assert_eq!(stats.levels, roomy::apps::pancake::reference_bfs(n), "n={n} {s:?}");
    });
}

#[test]
fn prop_prefix_sum_any_shape() {
    prop_check("prefix sum any shape", 8, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_prefix", |c| rand_cfg(&mut seed_rng, c));
        let n = rng.range(1, 400) as u64;
        let vals: Vec<i64> = (0..n).map(|_| rng.range_i64(-1000, 1000)).collect();
        let ra = r.array::<i64>("a", n, 0).unwrap();
        let v2 = vals.clone();
        ra.map_update(move |i, v| *v = v2[i as usize]).unwrap();
        roomy::constructs::prefix::prefix_scan_array(&ra, &roomy::accel::Accel::rust())
            .unwrap();
        let mut acc = 0i64;
        for (i, v) in vals.iter().enumerate() {
            acc = acc.wrapping_add(*v);
            if i % 37 == 0 || i + 1 == vals.len() {
                assert_eq!(ra.fetch(i as u64).unwrap(), acc);
            }
        }
    });
}
