//! Property-based invariants over the whole stack, via the homegrown
//! deterministic harness (`roomy::testutil::prop`). Each property runs a
//! randomized workload against an in-RAM model.

mod common;

use common::roomy_with;
use roomy::testutil::{prop_check, Rng};
use std::collections::{BTreeMap, BTreeSet, HashMap};

fn rand_cfg(rng: &mut Rng, c: &mut roomy::RoomyConfig) {
    c.workers = rng.range(1, 5);
    c.buckets_per_worker = rng.range(1, 4);
    c.op_buffer_bytes = [64usize, 1024, 64 * 1024][rng.range(0, 3)];
}

#[test]
fn prop_array_sync_equals_serial_application() {
    prop_check("array sync == serial model", 12, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_array", |c| rand_cfg(&mut seed_rng, c));
        let n = rng.range(1, 300) as u64;
        let ra = r.array::<i64>("a", n, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut i64, p: &i64| *v = v.wrapping_add(*p));
        let setv = ra.register_update(|_i, v: &mut i64, p: &i64| *v = *p);
        let mut model = vec![0i64; n as usize];
        // several sync rounds of random ops
        for _round in 0..rng.range(1, 4) {
            for _ in 0..rng.range(0, 500) {
                let i = rng.below(n);
                let p = rng.range_i64(-100, 100);
                if rng.chance(0.5) {
                    ra.update(i, &p, add).unwrap();
                    model[i as usize] = model[i as usize].wrapping_add(p);
                } else {
                    ra.update(i, &p, setv).unwrap();
                    model[i as usize] = p;
                }
            }
            ra.sync().unwrap();
        }
        let collected = std::sync::Mutex::new(vec![0i64; n as usize]);
        ra.map(|i, v| collected.lock().unwrap()[i as usize] = *v).unwrap();
        assert_eq!(*collected.lock().unwrap(), model);
    });
}

#[test]
fn prop_hashtable_equals_hashmap_model() {
    prop_check("hashtable == HashMap model", 12, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_ht", |c| rand_cfg(&mut seed_rng, c));
        let ht = r.hash_table::<u64, i64>("h").unwrap();
        let bump = ht.register_update(|_k, cur: Option<&i64>, p: &i64| {
            Some(cur.copied().unwrap_or(0) + p)
        });
        let mut model: HashMap<u64, i64> = HashMap::new();
        for _round in 0..rng.range(1, 4) {
            for _ in 0..rng.range(0, 400) {
                let k = rng.below(50); // heavy collisions
                match rng.range(0, 3) {
                    0 => {
                        let v = rng.range_i64(-9, 9);
                        ht.insert(&k, &v).unwrap();
                        model.insert(k, v);
                    }
                    1 => {
                        ht.remove(&k).unwrap();
                        model.remove(&k);
                    }
                    _ => {
                        let p = rng.range_i64(1, 5);
                        ht.update(&k, &p, bump).unwrap();
                        *model.entry(k).or_insert(0) += p;
                    }
                }
            }
            ht.sync().unwrap();
        }
        assert_eq!(ht.size(), model.len() as u64);
        let collected = std::sync::Mutex::new(HashMap::new());
        ht.map(|k, v| {
            collected.lock().unwrap().insert(*k, *v);
        })
        .unwrap();
        assert_eq!(*collected.lock().unwrap(), model);
    });
}

#[test]
fn prop_list_equals_multiset_model() {
    prop_check("list == multiset model", 12, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_list", |c| rand_cfg(&mut seed_rng, c));
        let l = r.list::<u64>("l").unwrap();
        let mut model: BTreeMap<u64, u64> = BTreeMap::new();
        for _round in 0..rng.range(1, 4) {
            // Roomy list sync semantics: all adds of the sync apply first,
            // then removes delete every occurrence — model it that way.
            let mut adds: Vec<u64> = Vec::new();
            let mut removes: Vec<u64> = Vec::new();
            for _ in 0..rng.range(0, 300) {
                let v = rng.below(40);
                if rng.chance(0.8) {
                    l.add(&v).unwrap();
                    adds.push(v);
                } else {
                    l.remove(&v).unwrap();
                    removes.push(v);
                }
            }
            l.sync().unwrap();
            for v in adds {
                *model.entry(v).or_insert(0) += 1;
            }
            for v in removes {
                model.remove(&v);
            }
            if rng.chance(0.3) {
                l.remove_dupes().unwrap();
                for c in model.values_mut() {
                    *c = 1;
                }
            }
        }
        let mut got: BTreeMap<u64, u64> = BTreeMap::new();
        for v in l.collect().unwrap() {
            *got.entry(v).or_insert(0) += 1;
        }
        assert_eq!(got, model);
        assert_eq!(l.size(), model.values().sum::<u64>());
    });
}

#[test]
fn prop_setops_match_std_sets() {
    prop_check("set ops == BTreeSet", 10, |rng| {
        // half the cases force the sort-merge removeAll path (budget 1)
        let budget = if rng.chance(0.5) { 1 } else { 1 << 20 };
        let (_t, r) = roomy_with("pt_set", |c| c.ram_budget_bytes = budget);
        let va: Vec<u64> = (0..rng.range(0, 120)).map(|_| rng.below(60)).collect();
        let vb: Vec<u64> = (0..rng.range(0, 120)).map(|_| rng.below(60)).collect();
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in &va {
            a.add(v).unwrap();
        }
        for v in &vb {
            b.add(v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        roomy::constructs::setops::to_set(&a).unwrap();
        roomy::constructs::setops::to_set(&b).unwrap();
        let sa: BTreeSet<u64> = va.into_iter().collect();
        let sb: BTreeSet<u64> = vb.into_iter().collect();
        let c = roomy::constructs::setops::intersection(&r, "c", &a, &b).unwrap();
        let got: BTreeSet<u64> = c.collect().unwrap().into_iter().collect();
        let expect: BTreeSet<u64> = sa.intersection(&sb).copied().collect();
        assert_eq!(got, expect);
    });
}

#[test]
fn prop_bfs_matches_ram_bfs() {
    prop_check("roomy BFS == RAM BFS", 6, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_bfs", |c| rand_cfg(&mut seed_rng, c));
        // random functional graph with out-degree 2 over m nodes
        let m = rng.range(5, 120) as u64;
        let s1 = rng.next_u64() | 1;
        let s2 = rng.next_u64() | 1;
        let gen = move |v: u64| {
            [v.wrapping_mul(s1) % m, v.wrapping_mul(s2).wrapping_add(1) % m]
        };
        // RAM BFS
        let mut seen = vec![false; m as usize];
        seen[0] = true;
        let mut cur = vec![0u64];
        let mut ram_levels = vec![1u64];
        let mut total = 1u64;
        while !cur.is_empty() {
            let mut next = vec![];
            for &v in &cur {
                for nb in gen(v) {
                    if !seen[nb as usize] {
                        seen[nb as usize] = true;
                        next.push(nb);
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            ram_levels.push(next.len() as u64);
            total += next.len() as u64;
            cur = next;
        }
        // Roomy BFS
        let stats = roomy::constructs::bfs::bfs_list(&r, "g", &[0u64], move |&v, out| {
            out.extend(gen(v));
        })
        .unwrap();
        assert_eq!(stats.levels, ram_levels);
        assert_eq!(stats.total, total);
    });
}

#[test]
fn prop_pancake_small_n_random_config() {
    prop_check("pancake BFS any config", 4, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_pancake", |c| rand_cfg(&mut seed_rng, c));
        let n = rng.range(4, 7);
        let s = [
            roomy::apps::pancake::Structure::List,
            roomy::apps::pancake::Structure::Hash,
            roomy::apps::pancake::Structure::Array,
        ][rng.range(0, 3)];
        let stats =
            roomy::apps::pancake::roomy_bfs(&r, n, s, &roomy::accel::Accel::rust()).unwrap();
        assert_eq!(stats.levels, roomy::apps::pancake::reference_bfs(n), "n={n} {s:?}");
    });
}

// ---------------------------------------------------------------------
// storage/extsort.rs invariants: sortedness, no element loss or
// duplication across chunk/run boundaries, determinism, dedup = unique.
// ---------------------------------------------------------------------

fn extsort_disk(dir: &std::path::Path) -> std::sync::Arc<roomy::storage::NodeDisk> {
    std::sync::Arc::new(
        roomy::storage::NodeDisk::create(0, dir, roomy::DiskPolicy::unthrottled()).unwrap(),
    )
}

fn write_records(d: &roomy::storage::NodeDisk, rel: &str, recs: &[Vec<u8>], rec_size: usize) {
    let mut w = roomy::storage::RecordWriter::create(d, rel, rec_size).unwrap();
    for r in recs {
        w.push(r).unwrap();
    }
    w.finish().unwrap();
}

fn read_records(d: &roomy::storage::NodeDisk, rel: &str, rec_size: usize) -> Vec<Vec<u8>> {
    let mut out = vec![];
    roomy::storage::chunkfile::for_each_record(d, rel, rec_size, 128, |rec| {
        out.push(rec.to_vec());
        Ok(())
    })
    .unwrap();
    out
}

#[test]
fn prop_extsort_sorted_and_lossless_across_chunk_boundaries() {
    prop_check("extsort sorted + lossless", 15, |rng| {
        let t = roomy::testutil::tmpdir("pt_extsort");
        let d = extsort_disk(t.path());
        // variable record size stresses batch/boundary arithmetic; 8 and
        // 16 take the word-wise integer/multiword sort fast paths, which
        // must agree with the memcmp-ordered model below
        let rec_size = [2usize, 4, 7, 8, 16][rng.range(0, 5)];
        let n = rng.range(0, 600);
        let recs: Vec<Vec<u8>> = (0..n).map(|_| rng.bytes(rec_size)).collect();
        write_records(&d, "in.dat", &recs, rec_size);
        // tiny chunks force many runs; every boundary is exercised
        let chunk = rng.range(rec_size, rec_size * 9);
        let written = roomy::storage::extsort::sort_file(
            &d, "in.dat", "out.dat", rec_size, chunk, false,
        )
        .unwrap();
        assert_eq!(written as usize, n, "no element lost or duplicated");
        assert!(roomy::storage::extsort::is_sorted(&d, "out.dat", rec_size).unwrap());
        // multiset preservation: sorted input == sorted output
        let mut expect = recs.clone();
        expect.sort();
        assert_eq!(read_records(&d, "out.dat", rec_size), expect);
        // determinism/idempotence: sorting the sorted file is the identity
        roomy::storage::extsort::sort_file(&d, "out.dat", "out2.dat", rec_size, chunk, false)
            .unwrap();
        assert_eq!(read_records(&d, "out2.dat", rec_size), expect);
    });
}

#[test]
fn prop_extsort_dedup_is_sorted_unique() {
    prop_check("extsort dedup == sorted unique", 12, |rng| {
        let t = roomy::testutil::tmpdir("pt_extsort_dd");
        let d = extsort_disk(t.path());
        let n = rng.range(0, 500);
        // small value domain for heavy duplication
        let recs: Vec<Vec<u8>> = (0..n)
            .map(|_| (rng.below(40) as u32).to_be_bytes().to_vec())
            .collect();
        write_records(&d, "in.dat", &recs, 4);
        let chunk = rng.range(4, 64);
        let written =
            roomy::storage::extsort::sort_file(&d, "in.dat", "out.dat", 4, chunk, true)
                .unwrap();
        let expect: Vec<Vec<u8>> = recs
            .iter()
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .cloned()
            .collect();
        assert_eq!(written as usize, expect.len());
        assert_eq!(read_records(&d, "out.dat", 4), expect);
    });
}

#[test]
fn prop_merge_diff_removes_every_occurrence() {
    prop_check("merge_diff == multiset minus set", 12, |rng| {
        let t = roomy::testutil::tmpdir("pt_diff");
        let d = extsort_disk(t.path());
        // 8/16 take the word-wise compare/equality kernels; 4 the byte path
        let rec_size = [4usize, 8, 16][rng.range(0, 3)];
        let mk = |rng: &mut Rng, n: usize| -> Vec<Vec<u8>> {
            (0..n)
                .map(|_| {
                    let mut rec = vec![0u8; rec_size];
                    // tiny value domain so diff actually removes records
                    rec[rec_size - 1] = rng.below(50) as u8;
                    rec[0] = rng.below(3) as u8;
                    rec
                })
                .collect()
        };
        let na = rng.range(0, 300);
        let nb = rng.range(0, 100);
        let mut a = mk(rng, na);
        let mut b = mk(rng, nb);
        a.sort();
        b.sort();
        write_records(&d, "a.dat", &a, rec_size);
        write_records(&d, "b.dat", &b, rec_size);
        let n =
            roomy::storage::extsort::merge_diff(&d, "a.dat", "b.dat", "c.dat", rec_size)
                .unwrap();
        let bset: BTreeSet<&Vec<u8>> = b.iter().collect();
        let expect: Vec<Vec<u8>> =
            a.iter().filter(|r| !bset.contains(r)).cloned().collect();
        assert_eq!(n as usize, expect.len());
        assert_eq!(read_records(&d, "c.dat", rec_size), expect);
    });
}

// ---------------------------------------------------------------------
// Raw-speed kernel equivalences: the batched/lane fingerprint kernels
// and the word-wise bitset kernels are drop-in replacements for their
// scalar/byte-wise twins — bit for bit, under every dispatch mode.
// ---------------------------------------------------------------------

#[test]
fn prop_batched_fingerprints_match_scalar_in_every_mode() {
    use roomy::hashfn;
    use roomy::KernelMode;
    prop_check("batched fp == scalar fp, all modes", 12, |rng| {
        let rec_size = rng.range(1, 33);
        let n = rng.range(0, 200);
        let mut batch = Vec::with_capacity(n * rec_size);
        for _ in 0..n {
            batch.extend_from_slice(&rng.bytes(rec_size));
        }
        let scalar: Vec<u64> =
            batch.chunks_exact(rec_size).map(hashfn::fp_bytes).collect();
        for mode in [KernelMode::Scalar, KernelMode::Portable, KernelMode::Auto] {
            let mut got = Vec::new();
            hashfn::fp_bytes_batch_with(mode, &batch, rec_size, &mut got);
            assert_eq!(got, scalar, "fp_bytes_batch diverged in {mode}");
        }
        // the fused routing path agrees with per-record bucket_of_bytes
        // under whatever mode the process is currently dispatching
        let nbuckets = rng.range(1, 64) as u32;
        let mut routes = Vec::new();
        hashfn::route_batch_into(&batch, rec_size, nbuckets, &mut routes);
        let expect: Vec<u32> = batch
            .chunks_exact(rec_size)
            .map(|rec| hashfn::bucket_of_bytes(rec, nbuckets))
            .collect();
        assert_eq!(routes, expect);
        // word batches: k u64 words per record
        let k = rng.range(1, 5);
        let nw = rng.range(0, 80);
        let words: Vec<u64> = (0..nw * k).map(|_| rng.next_u64()).collect();
        let scalar_w: Vec<u64> = words.chunks_exact(k).map(hashfn::fp_words).collect();
        for mode in [KernelMode::Scalar, KernelMode::Portable, KernelMode::Auto] {
            let mut got = Vec::new();
            hashfn::fp_words_batch_with(mode, &words, k, &mut got);
            assert_eq!(got, scalar_w, "fp_words_batch diverged in {mode}");
        }
        // strided arena sweep: key prefix of each slot
        let stride = rec_size + rng.range(0, 9);
        let slots = rng.range(0, 60);
        let arena = rng.bytes(slots * stride);
        let mut got = Vec::new();
        hashfn::fp_bytes_batch_strided_into(&arena, stride, rec_size, &mut got);
        let expect: Vec<u64> = arena
            .chunks_exact(stride)
            .map(|slot| hashfn::fp_bytes(&slot[..rec_size]))
            .collect();
        assert_eq!(got, expect, "strided batch diverged");
    });
}

#[test]
fn prop_wordwise_bitset_kernels_match_bytewise() {
    use roomy::roomy::bitkernels::{self, CombineOp};
    prop_check("word-wise bitset kernels == scalar", 12, |rng| {
        let bits = [1u8, 2, 4, 8][rng.range(0, 4)];
        let per = (8 / bits) as usize;
        let nbytes = rng.range(0, 200);
        let data = rng.bytes(nbytes);
        let nelems = rng.range(0, nbytes * per + 1) as u64;
        let mask = bitkernels::field_mask(bits);
        let get = |i: u64| {
            let i = i as usize;
            (data[i / per] >> ((i % per) as u8 * bits)) & mask
        };
        // count_value + histogram vs scalar extraction
        let hist = bitkernels::histogram(&data, bits, nelems);
        for v in 0..=mask {
            let expect = (0..nelems).filter(|&i| get(i) == v).count() as u64;
            assert_eq!(
                bitkernels::count_value(&data, bits, nelems, v),
                expect,
                "count_value({v}) bits={bits} nelems={nelems}"
            );
            assert_eq!(hist[v as usize], expect);
        }
        // unpacked walk visits every field in order
        let mut walked = Vec::new();
        bitkernels::for_each_unpacked(&data, bits, nelems, |i, v| walked.push((i, v)));
        let expect: Vec<(u64, u8)> = (0..nelems).map(|i| (i, get(i))).collect();
        assert_eq!(walked, expect);
        // combine sweeps vs per-byte boolean algebra
        let other = rng.bytes(nbytes);
        for (op, f) in [
            (CombineOp::Or, (|a, b| a | b) as fn(u8, u8) -> u8),
            (CombineOp::And, |a, b| a & b),
            (CombineOp::AndNot, |a, b| a & !b),
        ] {
            let mut dst = data.clone();
            let expect: Vec<u8> =
                data.iter().zip(&other).map(|(&a, &b)| f(a, b)).collect();
            bitkernels::combine_into(&mut dst, &other, op);
            assert_eq!(dst, expect, "{op:?} sweep diverged");
        }
    });
}

// ---------------------------------------------------------------------
// Hash-bucket partitioning: every element lands in exactly one bucket,
// deterministically, and all routing paths agree.
// ---------------------------------------------------------------------

#[test]
fn prop_hash_partitioning_total_and_deterministic() {
    prop_check("bucket routing total function", 20, |rng| {
        let nbuckets = rng.range(1, 100) as u32;
        for _ in 0..50 {
            let elt = rng.bytes(rng.range(1, 24));
            let b = roomy::hashfn::bucket_of_bytes(&elt, nbuckets);
            // in range...
            assert!(b < nbuckets, "bucket {b} out of range {nbuckets}");
            // ...exactly one bucket: repeated routing never disagrees
            assert_eq!(b, roomy::hashfn::bucket_of_bytes(&elt, nbuckets));
            // ...and the two-step fingerprint path agrees with the fused one
            assert_eq!(
                b,
                roomy::hashfn::bucket_of(roomy::hashfn::fp_bytes(&elt), nbuckets)
            );
        }
    });
}

#[test]
fn prop_partitioning_covers_and_preserves_all_elements() {
    prop_check("partition = disjoint cover", 10, |rng| {
        let nbuckets = rng.range(1, 16) as u32;
        let n = rng.range(1, 400);
        let elts: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        // partition into per-bucket piles
        let mut piles: Vec<Vec<u64>> = vec![Vec::new(); nbuckets as usize];
        for &e in &elts {
            let b = roomy::hashfn::bucket_of_bytes(&e.to_le_bytes(), nbuckets);
            piles[b as usize].push(e);
        }
        // disjoint cover: recomposition is the original multiset
        let total: usize = piles.iter().map(|p| p.len()).sum();
        assert_eq!(total, n, "every element in exactly one bucket");
        let mut recomposed: Vec<u64> = piles.into_iter().flatten().collect();
        recomposed.sort_unstable();
        let mut expect = elts.clone();
        expect.sort_unstable();
        assert_eq!(recomposed, expect);
    });
}

#[test]
fn prop_list_shard_files_partition_the_list() {
    prop_check("list shards partition elements", 6, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_shards", |c| rand_cfg(&mut seed_rng, c));
        let l = r.list::<u64>("l").unwrap();
        let n = rng.range(1, 500) as u64;
        for _ in 0..n {
            l.add(&rng.next_u64()).unwrap();
        }
        l.sync().unwrap();
        // sum of per-shard record counts == list size: nothing dropped,
        // nothing double-routed
        let nb = r.cluster().nbuckets();
        let mut per_shard_total = 0u64;
        for b in 0..nb {
            let disk = r.cluster().disk(r.cluster().owner(b));
            per_shard_total += roomy::storage::chunkfile::record_count(
                disk,
                format!("rl_l/s{b}.dat"),
                8,
            );
        }
        assert_eq!(per_shard_total, n);
        assert_eq!(l.size(), n);
    });
}

#[test]
fn prop_prefix_sum_any_shape() {
    prop_check("prefix sum any shape", 8, |rng| {
        let mut seed_rng = rng.clone();
        let (_t, r) = roomy_with("pt_prefix", |c| rand_cfg(&mut seed_rng, c));
        let n = rng.range(1, 400) as u64;
        let vals: Vec<i64> = (0..n).map(|_| rng.range_i64(-1000, 1000)).collect();
        let ra = r.array::<i64>("a", n, 0).unwrap();
        let v2 = vals.clone();
        ra.map_update(move |i, v| *v = v2[i as usize]).unwrap();
        roomy::constructs::prefix::prefix_scan_array(&ra, &roomy::accel::Accel::rust())
            .unwrap();
        let mut acc = 0i64;
        for (i, v) in vals.iter().enumerate() {
            acc = acc.wrapping_add(*v);
            if i % 37 == 0 || i + 1 == vals.len() {
                assert_eq!(ra.fetch(i as u64).unwrap(), acc);
            }
        }
    });
}
