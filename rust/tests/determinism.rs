//! Parallel == serial, byte for byte — at every pipeline depth and every
//! pool scheduling policy.
//!
//! Every collective runs its buckets on the locality-aware worker pool
//! (`roomy::runtime::pool`: per-node queues, bounded stealing, cross-task
//! prefetch hints) and streams them through the overlapped-I/O pipeline
//! (`roomy::storage::pipeline`); these tests prove the pool's three
//! determinism rules (bucket isolation, merge-by-bucket-index, per-task
//! delayed-op capture) *and* the pipeline's transparency by running
//! identical randomized workloads over the matrix `steal_policy` ∈
//! {off, bounded} × `num_workers` ∈ {1, 2, 4} × `io_pipeline_depth` ∈
//! {0, 4} (plus one greedy = flat-cursor cell) and demanding **identical
//! on-disk bytes** (full recursive digest of the instance root) and
//! identical order-sensitive reduce results.

mod common;

use common::dir_digest;
use roomy::constructs::bfs;
use roomy::testutil::{tmpdir, Rng};
use roomy::{Roomy, RoomyConfig, StealPolicy};

/// The steal-policy × pipeline-depth × worker-count grid every workload
/// must be byte-identical across. (off, depth 0, workers 1) is the
/// serial reference; the final greedy cell pins the pre-locality
/// flat-cursor schedule to the same bytes.
const MATRIX: [(StealPolicy, usize, usize); 13] = [
    (StealPolicy::Off, 0, 1),
    (StealPolicy::Off, 0, 2),
    (StealPolicy::Off, 0, 4),
    (StealPolicy::Off, 4, 1),
    (StealPolicy::Off, 4, 2),
    (StealPolicy::Off, 4, 4),
    (StealPolicy::Bounded, 0, 1),
    (StealPolicy::Bounded, 0, 2),
    (StealPolicy::Bounded, 0, 4),
    (StealPolicy::Bounded, 4, 1),
    (StealPolicy::Bounded, 4, 2),
    (StealPolicy::Bounded, 4, 4),
    (StealPolicy::Greedy, 4, 4),
];

/// Run `workload` once per (steal, depth, workers) cell; the workload
/// returns an order-sensitive value that must also match. Asserts equal
/// digests.
fn assert_deterministic(tag: &str, workload: impl Fn(&Roomy, &mut Rng) -> u64) {
    let mut outcomes = Vec::new();
    for &(steal, depth, nw) in &MATRIX {
        let t = tmpdir(&format!("det_{tag}_s{steal}_d{depth}_w{nw}"));
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 3; // uneven bucket→node split
        cfg.buckets_per_worker = 2;
        cfg.num_workers = nw;
        cfg.io_pipeline_depth = depth;
        cfg.steal_policy = steal;
        cfg.op_buffer_bytes = 256; // force staging spills
        cfg.capture_spill_threshold = 96; // force in-collective capture spills
        let r = Roomy::open(cfg).unwrap();
        let mut rng = Rng::new(0xD15EA5E); // identical input per cell
        let value = workload(&r, &mut rng);
        drop(r); // join io service threads before digesting
        let digest = dir_digest(t.path());
        outcomes.push((steal, depth, nw, value, digest));
    }
    let (_, _, _, v0, d0) = outcomes[0];
    for (steal, depth, nw, v, d) in &outcomes[1..] {
        assert_eq!(
            *v, v0,
            "{tag}: value diverged at steal={steal} depth={depth} num_workers={nw}"
        );
        assert_eq!(
            *d, d0,
            "{tag}: on-disk bytes diverged at steal={steal} depth={depth} num_workers={nw}"
        );
    }
}

/// Order-sensitive fold (neither associative nor commutative): any change
/// in merge order changes the result.
fn order_hash(acc: u64, v: u64) -> u64 {
    acc.wrapping_mul(0x9E3779B97F4A7C15) ^ v
}

#[test]
fn det_array_map_update_sync_reduce() {
    assert_deterministic("array", |r, rng| {
        let n = 997u64;
        let ra = r.array::<u64>("a", n, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v = v.wrapping_add(*p));
        let set = ra.register_update(|i, v: &mut u64, p: &u64| *v = *p ^ i);
        for _round in 0..3 {
            for _ in 0..800 {
                let i = rng.below(n);
                let p = rng.next_u64() >> 32;
                if rng.chance(0.7) {
                    ra.update(i, &p, add).unwrap();
                } else {
                    ra.update(i, &p, set).unwrap();
                }
            }
            ra.sync().unwrap();
        }
        // map that issues delayed ops on another structure from inside the
        // collective (the capture path)
        let rl = r.list::<u64>("spill").unwrap();
        let rl2 = rl.clone();
        ra.map(move |i, v| {
            if v % 3 == 0 {
                rl2.add(&(i ^ v)).unwrap();
            }
        })
        .unwrap();
        rl.sync().unwrap();
        // order-sensitive reduce over both
        let h1 = ra
            .reduce(|| 0u64, |acc, i, v| order_hash(acc, i ^ *v), order_hash)
            .unwrap();
        let h2 = rl.reduce(|| h1, |acc, v| order_hash(acc, *v), order_hash).unwrap();
        h2
    });
}

#[test]
fn det_list_dupelim_and_set_algebra() {
    assert_deterministic("listset", |r, rng| {
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for _ in 0..2_000 {
            a.add(&rng.below(500)).unwrap();
            if rng.chance(0.6) {
                b.add(&rng.below(500)).unwrap();
            }
        }
        a.sync().unwrap();
        b.sync().unwrap();
        // dup elimination (per-shard external sort on the pool)
        a.remove_dupes().unwrap();
        b.remove_dupes().unwrap();
        // union then difference via the paper's constructions
        roomy::constructs::setops::union_into(&a, &b).unwrap();
        roomy::constructs::setops::difference_into(&a, &b).unwrap();
        let c = roomy::constructs::setops::intersection(&r, "c", &a, &b).unwrap();
        let h = a
            .reduce(|| 0u64, |acc, v| order_hash(acc, *v), order_hash)
            .unwrap();
        c.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    });
}

#[test]
fn det_native_set_union_intersect_difference() {
    assert_deterministic("rset", |r, rng| {
        let a = r.set::<u64>("a").unwrap();
        let b = r.set::<u64>("b").unwrap();
        for _ in 0..1_500 {
            let v = rng.below(400);
            if rng.chance(0.8) {
                a.add(&v).unwrap();
            } else {
                a.remove(&v).unwrap();
            }
            if rng.chance(0.5) {
                b.add(&rng.below(400)).unwrap();
            }
        }
        a.sync().unwrap();
        b.sync().unwrap();
        let u = r.set::<u64>("u").unwrap();
        u.union_with(&a).unwrap();
        u.union_with(&b).unwrap();
        let i = r.set::<u64>("i").unwrap();
        i.union_with(&a).unwrap();
        i.intersect_with(&b).unwrap();
        a.difference_with(&b).unwrap();
        let h = u
            .reduce(|| 0u64, |acc, v| order_hash(acc, *v), order_hash)
            .unwrap();
        i.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    });
}

#[test]
fn det_hashtable_upserts() {
    assert_deterministic("ht", |r, rng| {
        let ht = r.hash_table::<u64, u64>("h").unwrap();
        let bump = ht.register_update(|k, cur: Option<&u64>, p: &u64| {
            Some(cur.copied().unwrap_or(*k).wrapping_add(*p))
        });
        for _round in 0..3 {
            for _ in 0..700 {
                let k = rng.below(300);
                match rng.range(0, 4) {
                    0 => ht.insert(&k, &rng.next_u64()).unwrap(),
                    1 => ht.remove(&k).unwrap(),
                    _ => ht.update(&k, &(rng.next_u64() >> 40), bump).unwrap(),
                }
            }
            ht.sync().unwrap();
        }
        ht.reduce(|| 0u64, |acc, k, v| order_hash(acc, k ^ v), order_hash).unwrap()
    });
}

#[test]
fn det_bitarray_updates() {
    assert_deterministic("bits", |r, rng| {
        let ba = r.bit_array("b", 4_096, 2).unwrap();
        let bump = ba.register_update(|_i, cur, p: &u8| cur.wrapping_add(*p) & 3);
        for _round in 0..2 {
            for _ in 0..1_500 {
                ba.update(rng.below(4_096), &((rng.below(3) + 1) as u8), bump).unwrap();
            }
            ba.sync().unwrap();
        }
        (0..4u8).fold(0u64, |acc, v| order_hash(acc, ba.count_value(v)))
    });
}

/// One BFS level expansion through the hash-table driver: the visit
/// function emits next-level states from *inside* `table.sync` — the
/// canonical delayed-op capture scenario.
#[test]
fn det_bfs_level_expansion() {
    assert_deterministic("bfs_level", |r, rng| {
        let table = r.hash_table::<u64, u32>("levels").unwrap();
        let cur = r.list::<u64>("cur").unwrap();
        let next = r.list::<u64>("next").unwrap();
        let frontier: Vec<u64> = (0..64).map(|_| rng.below(1 << 14)).collect();
        for s in &frontier {
            table.insert(s, &0).unwrap();
            cur.add(s).unwrap();
        }
        table.sync().unwrap();
        cur.sync().unwrap();
        cur.remove_dupes().unwrap();

        let next_emit = next.clone();
        let visit = table.register_update(move |k: &u64, cur_v: Option<&u32>, _p: &()| {
            match cur_v {
                Some(&v) => Some(v),
                None => {
                    next_emit.add(k).expect("emit");
                    Some(1)
                }
            }
        });
        let table2 = table.clone();
        cur.map(move |&v| {
            for bit in 0..14u32 {
                table2.update(&(v ^ (1 << bit)), &(), visit).unwrap();
            }
        })
        .unwrap();
        table.sync().unwrap();
        next.sync().unwrap();
        next.remove_dupes().unwrap();
        let h = table
            .reduce(|| 0u64, |acc, k, v| order_hash(acc, k ^ *v as u64), order_hash)
            .unwrap();
        next.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    });
}

/// One **batched** BFS level expansion, staged exactly the way
/// `bfs_list_batched` / `bfs_hash_batched` stage it (per-task frontier
/// batches via `map_batched`, delayed adds on the next level, delayed
/// insert-if-absent updates on the level table). The digest check pins
/// the *byte order* of the batched staging path across worker counts —
/// this was only result-deterministic before the per-task batch
/// accumulators.
#[test]
fn det_bfs_batched_staging() {
    assert_deterministic("bfs_batched", |r, rng| {
        let cur = r.list::<u64>("cur").unwrap();
        for _ in 0..1_200 {
            cur.add(&rng.below(1 << 12)).unwrap();
        }
        cur.sync().unwrap();
        cur.remove_dupes().unwrap();

        let next = r.list::<u64>("next").unwrap();
        let table = r.hash_table::<u64, u32>("levels").unwrap();
        let next_emit = next.clone();
        let visit = table.register_update(move |k: &u64, cur_v: Option<&u32>, _p: &()| {
            match cur_v {
                Some(&v) => Some(v),
                None => {
                    next_emit.add(k).expect("emit");
                    Some(1)
                }
            }
        });
        // odd batch size so shards end in ragged tail batches
        cur.map_batched(37, |batch| {
            for &v in batch {
                for bit in 0..6u32 {
                    let nb = v ^ (1 << bit);
                    next.add(&nb)?;
                    table.update(&nb, &(), visit)?;
                }
            }
            Ok(())
        })
        .unwrap();
        table.sync().unwrap();
        next.sync().unwrap();
        let h = table
            .reduce(|| 0u64, |acc, k, v| order_hash(acc, k ^ *v as u64), order_hash)
            .unwrap();
        next.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    });
}

/// The exact-backed bloom dedup tier is byte-transparent: the same
/// dup-heavy workload over the native set and the hash table produces
/// identical on-disk bytes with the filter off (reference cell) and on —
/// across filter widths, schedules, worker counts, and pipeline depths.
#[test]
fn det_bloom_exact_tier_is_byte_transparent() {
    // (bloom bits, steal, depth, workers); cell 0 = filter off.
    let grid: [(usize, StealPolicy, usize, usize); 6] = [
        (0, StealPolicy::Off, 0, 1),
        (10, StealPolicy::Off, 0, 1),
        (10, StealPolicy::Off, 4, 2),
        (10, StealPolicy::Bounded, 4, 4),
        (10, StealPolicy::Greedy, 4, 4),
        (6, StealPolicy::Bounded, 0, 4),
    ];
    let workload = |r: &Roomy, rng: &mut Rng| -> u64 {
        let s = r.set::<u64>("s").unwrap();
        let ht = r.hash_table::<u64, u64>("h").unwrap();
        let bump = ht.register_update(|k, cur: Option<&u64>, p: &u64| {
            Some(cur.copied().unwrap_or(*k).wrapping_add(*p))
        });
        for _round in 0..3 {
            for _ in 0..600 {
                let v = rng.below(350);
                if rng.chance(0.8) {
                    s.add(&v).unwrap();
                } else {
                    s.remove(&v).unwrap();
                }
                let k = rng.below(250);
                match rng.range(0, 4) {
                    0 => ht.insert(&k, &rng.next_u64()).unwrap(),
                    1 => ht.remove(&k).unwrap(),
                    _ => ht.update(&k, &(rng.next_u64() >> 40), bump).unwrap(),
                }
            }
            s.sync().unwrap();
            ht.sync().unwrap();
        }
        let h = s
            .reduce(|| 0u64, |acc, v| order_hash(acc, *v), order_hash)
            .unwrap();
        ht.reduce(|| h, |acc, k, v| order_hash(acc, k ^ v), order_hash).unwrap()
    };
    let mut outcomes = Vec::new();
    for &(bloom, steal, depth, nw) in &grid {
        let t = tmpdir(&format!("det_bloom_b{bloom}_s{steal}_d{depth}_w{nw}"));
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 3;
        cfg.buckets_per_worker = 2;
        cfg.num_workers = nw;
        cfg.io_pipeline_depth = depth;
        cfg.steal_policy = steal;
        cfg.bloom_bits_per_key = bloom;
        let r = Roomy::open(cfg).unwrap();
        let mut rng = Rng::new(0xD15EA5E);
        let value = workload(&r, &mut rng);
        drop(r);
        outcomes.push((bloom, steal, depth, nw, value, dir_digest(t.path())));
    }
    let (_, _, _, _, v0, d0) = outcomes[0];
    for (bloom, steal, depth, nw, v, d) in &outcomes[1..] {
        assert_eq!(
            *v, v0,
            "value diverged at bloom={bloom} steal={steal} depth={depth} num_workers={nw}"
        );
        assert_eq!(
            *d, d0,
            "on-disk bytes diverged at bloom={bloom} steal={steal} depth={depth} num_workers={nw}"
        );
    }
}

/// The autotune controller is byte-transparent: it moves pipeline depth
/// and hint distance between collectives, never what lands on disk. The
/// same dup-heavy multi-structure workload digests identically across
/// autotune {off, on} × num_workers {1, 4} × pipeline depth {0, 4} —
/// with (off, depth 0, serial) as the reference cell.
#[test]
fn det_autotune_is_byte_transparent() {
    use roomy::AutotuneMode;
    let grid: [(AutotuneMode, usize, usize); 8] = [
        (AutotuneMode::Off, 0, 1),
        (AutotuneMode::Off, 0, 4),
        (AutotuneMode::Off, 4, 1),
        (AutotuneMode::Off, 4, 4),
        (AutotuneMode::On, 0, 1),
        (AutotuneMode::On, 0, 4),
        (AutotuneMode::On, 4, 1),
        (AutotuneMode::On, 4, 4),
    ];
    let workload = |r: &Roomy, rng: &mut Rng| -> u64 {
        let ra = r.array::<u64>("a", 777, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v = v.wrapping_add(*p));
        let s = r.set::<u64>("s").unwrap();
        for _round in 0..4 {
            for _ in 0..500 {
                ra.update(rng.below(777), &(rng.next_u64() >> 32), add).unwrap();
                let v = rng.below(300);
                if rng.chance(0.8) {
                    s.add(&v).unwrap();
                } else {
                    s.remove(&v).unwrap();
                }
            }
            ra.sync().unwrap();
            s.sync().unwrap();
        }
        let h = ra
            .reduce(|| 0u64, |acc, i, v| order_hash(acc, i ^ *v), order_hash)
            .unwrap();
        s.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    };
    let mut outcomes = Vec::new();
    for &(tune, depth, nw) in &grid {
        let t = tmpdir(&format!("det_tune_{tune}_d{depth}_w{nw}"));
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 3;
        cfg.buckets_per_worker = 2;
        cfg.num_workers = nw;
        cfg.io_pipeline_depth = depth;
        cfg.autotune = tune;
        let r = Roomy::open(cfg).unwrap();
        let mut rng = Rng::new(0xD15EA5E);
        let value = workload(&r, &mut rng);
        if tune.enabled() {
            let at = r.cluster().autotune().expect("controller must exist when on");
            assert!(at.rounds() > 0, "controller never adapted");
        } else {
            assert!(r.cluster().autotune().is_none());
        }
        drop(r);
        outcomes.push((tune, depth, nw, value, dir_digest(t.path())));
    }
    let (_, _, _, v0, d0) = outcomes[0];
    for (tune, depth, nw, v, d) in &outcomes[1..] {
        assert_eq!(
            *v, v0,
            "value diverged at autotune={tune} depth={depth} num_workers={nw}"
        );
        assert_eq!(
            *d, d0,
            "on-disk bytes diverged at autotune={tune} depth={depth} num_workers={nw}"
        );
    }
}

/// The raw-speed kernels are byte-transparent: forcing the scalar
/// reference kernels versus letting dispatch pick the widest available
/// implementation (`Auto` → AVX2 or the portable 4-lane path) produces
/// identical on-disk bytes and identical order-sensitive reduces, across
/// num_workers {1, 4} × pipeline depth {0, 4} — with (scalar, depth 0,
/// serial) as the reference cell. The workload routes through every
/// batched-fingerprint consumer: list/set staging, hashtable bucket
/// routing, dup elimination (word-wise extsort runs and merges), and
/// bit-array update/count kernels.
///
/// (Kernel dispatch is process-global — `Roomy::open` pins it from
/// `cfg.kernels` — but every mode is bit-exact by construction, so
/// concurrent tests re-pinning it cannot perturb these digests; that
/// indifference is exactly what this matrix demands.)
#[test]
fn det_kernels_are_byte_transparent() {
    use roomy::KernelMode;
    let grid: [(KernelMode, usize, usize); 8] = [
        (KernelMode::Scalar, 0, 1),
        (KernelMode::Scalar, 0, 4),
        (KernelMode::Scalar, 4, 1),
        (KernelMode::Scalar, 4, 4),
        (KernelMode::Auto, 0, 1),
        (KernelMode::Auto, 0, 4),
        (KernelMode::Auto, 4, 1),
        (KernelMode::Auto, 4, 4),
    ];
    let workload = |r: &Roomy, rng: &mut Rng| -> u64 {
        let l = r.list::<u64>("l").unwrap();
        let s = r.set::<u64>("s").unwrap();
        let ht = r.hash_table::<u64, u64>("h").unwrap();
        let ba = r.bit_array("b", 2_048, 2).unwrap();
        let bump_ht = ht.register_update(|k, cur: Option<&u64>, p: &u64| {
            Some(cur.copied().unwrap_or(*k).wrapping_add(*p))
        });
        let bump_ba = ba.register_update(|_i, cur, p: &u8| cur.wrapping_add(*p) & 3);
        for _round in 0..3 {
            for _ in 0..600 {
                l.add(&rng.below(400)).unwrap();
                let v = rng.below(350);
                if rng.chance(0.8) {
                    s.add(&v).unwrap();
                } else {
                    s.remove(&v).unwrap();
                }
                let k = rng.below(250);
                match rng.range(0, 4) {
                    0 => ht.insert(&k, &rng.next_u64()).unwrap(),
                    1 => ht.remove(&k).unwrap(),
                    _ => ht.update(&k, &(rng.next_u64() >> 40), bump_ht).unwrap(),
                }
                ba.update(rng.below(2_048), &((rng.below(3) + 1) as u8), bump_ba)
                    .unwrap();
            }
            l.sync().unwrap();
            s.sync().unwrap();
            ht.sync().unwrap();
            ba.sync().unwrap();
        }
        l.remove_dupes().unwrap(); // extsort: runs, word-wise merge, dedup
        let h = l
            .reduce(|| 0u64, |acc, v| order_hash(acc, *v), order_hash)
            .unwrap();
        let h = s.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap();
        let h = ht
            .reduce(|| h, |acc, k, v| order_hash(acc, k ^ v), order_hash)
            .unwrap();
        (0..4u8).fold(h, |acc, v| order_hash(acc, ba.count_value(v)))
    };
    let mut outcomes = Vec::new();
    for &(kernels, depth, nw) in &grid {
        let t = tmpdir(&format!("det_kern_{kernels}_d{depth}_w{nw}"));
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 3;
        cfg.buckets_per_worker = 2;
        cfg.num_workers = nw;
        cfg.io_pipeline_depth = depth;
        cfg.kernels = kernels;
        let r = Roomy::open(cfg).unwrap();
        let mut rng = Rng::new(0xD15EA5E);
        let value = workload(&r, &mut rng);
        drop(r);
        outcomes.push((kernels, depth, nw, value, dir_digest(t.path())));
    }
    let (_, _, _, v0, d0) = outcomes[0];
    for (kernels, depth, nw, v, d) in &outcomes[1..] {
        assert_eq!(
            *v, v0,
            "value diverged at kernels={kernels} depth={depth} num_workers={nw}"
        );
        assert_eq!(
            *d, d0,
            "on-disk bytes diverged at kernels={kernels} depth={depth} num_workers={nw}"
        );
    }
}

/// The flight recorder is byte-transparent: the same dup-heavy
/// multi-structure workload digests identically with tracing off and
/// with tracing armed — across num_workers {1, 4} × pipeline depth
/// {0, 4}, with (off, depth 0, serial) as the reference cell. The trace
/// destination lives *outside* the digested instance root; recording
/// only ever captures timestamps and counter deltas, never data. Armed
/// cells additionally flush and re-parse their trace: it must be valid
/// JSON with a non-empty traceEvents array.
///
/// (Arming is process-global and sticky, so the off cells run first —
/// under a suite-wide ROOMY_TRACE they may still record, which is
/// exactly the transparency this test pins.)
#[test]
fn det_trace_is_byte_transparent() {
    let grid: [(bool, usize, usize); 8] = [
        (false, 0, 1),
        (false, 0, 4),
        (false, 4, 1),
        (false, 4, 4),
        (true, 0, 1),
        (true, 0, 4),
        (true, 4, 1),
        (true, 4, 4),
    ];
    let workload = |r: &Roomy, rng: &mut Rng| -> u64 {
        let ra = r.array::<u64>("a", 777, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v = v.wrapping_add(*p));
        let s = r.set::<u64>("s").unwrap();
        let l = r.list::<u64>("l").unwrap();
        for _round in 0..3 {
            for _ in 0..500 {
                ra.update(rng.below(777), &(rng.next_u64() >> 32), add).unwrap();
                let v = rng.below(300);
                if rng.chance(0.8) {
                    s.add(&v).unwrap();
                } else {
                    s.remove(&v).unwrap();
                }
                l.add(&rng.below(200)).unwrap();
            }
            ra.sync().unwrap();
            s.sync().unwrap();
            l.sync().unwrap();
        }
        l.remove_dupes().unwrap(); // external sort → run-gen/merge spans
        let h = ra
            .reduce(|| 0u64, |acc, i, v| order_hash(acc, i ^ *v), order_hash)
            .unwrap();
        let h = s.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap();
        l.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    };
    let mut outcomes = Vec::new();
    for &(trace, depth, nw) in &grid {
        let t = tmpdir(&format!("det_trace_{trace}_d{depth}_w{nw}"));
        // Trace file goes in its own directory, outside the digested root.
        let tdir = tmpdir(&format!("det_tracefile_{trace}_d{depth}_w{nw}"));
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 3;
        cfg.buckets_per_worker = 2;
        cfg.num_workers = nw;
        cfg.io_pipeline_depth = depth;
        // Explicit per-cell destination; the off cells clear any
        // suite-wide ROOMY_TRACE that for_testing picked up.
        cfg.trace_path = if trace { Some(tdir.path().join("trace.json")) } else { None };
        let r = Roomy::open(cfg).unwrap();
        let mut rng = Rng::new(0xD15EA5E);
        let value = workload(&r, &mut rng);
        if trace {
            // Flush to whatever destination is currently armed (a
            // concurrently-opened instance may have re-pointed it; the
            // rings are shared, so any flushed file carries our spans).
            let flushed = r.flush_trace().unwrap().expect("tracing must be armed");
            let text = std::fs::read_to_string(&flushed).unwrap();
            let doc = roomy::obs::json::parse(&text)
                .unwrap_or_else(|e| panic!("flushed trace must parse as JSON: {e}"));
            let events = doc
                .get("traceEvents")
                .and_then(|v| v.as_arr())
                .expect("trace must carry a traceEvents array");
            assert!(!events.is_empty(), "armed trace captured no events");
        }
        drop(r); // join io service threads + teardown flush
        outcomes.push((trace, depth, nw, value, dir_digest(t.path())));
    }
    let (_, _, _, v0, d0) = outcomes[0];
    for (trace, depth, nw, v, d) in &outcomes[1..] {
        assert_eq!(
            *v, v0,
            "value diverged at trace={trace} depth={depth} num_workers={nw}"
        );
        assert_eq!(
            *d, d0,
            "on-disk bytes diverged at trace={trace} depth={depth} num_workers={nw}"
        );
    }
}

/// Latency histograms are byte-transparent: the same multi-structure
/// workload digests identically with histograms off and armed, across
/// num_workers {1, 4} — with (off, serial) as the reference cell. The
/// recorder only ever increments in-memory atomic counters, never
/// anything that lands on disk. (Arming is process-global and sticky, so
/// the off cells run first; another armed test in this binary could make
/// them record too, which is exactly the transparency pinned here.)
#[test]
fn det_hist_is_byte_transparent() {
    let grid: [(bool, usize); 4] = [(false, 1), (false, 4), (true, 1), (true, 4)];
    let workload = |r: &Roomy, rng: &mut Rng| -> u64 {
        let ra = r.array::<u64>("a", 777, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v = v.wrapping_add(*p));
        let s = r.set::<u64>("s").unwrap();
        for _round in 0..3 {
            for _ in 0..500 {
                ra.update(rng.below(777), &(rng.next_u64() >> 32), add).unwrap();
                let v = rng.below(300);
                if rng.chance(0.8) {
                    s.add(&v).unwrap();
                } else {
                    s.remove(&v).unwrap();
                }
            }
            ra.sync().unwrap();
            s.sync().unwrap();
        }
        let h = ra
            .reduce(|| 0u64, |acc, i, v| order_hash(acc, i ^ *v), order_hash)
            .unwrap();
        s.reduce(|| h, |acc, v| order_hash(acc, *v), order_hash).unwrap()
    };
    let mut outcomes = Vec::new();
    for &(hist_on, nw) in &grid {
        let t = tmpdir(&format!("det_hist_{hist_on}_w{nw}"));
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 3;
        cfg.buckets_per_worker = 2;
        cfg.num_workers = nw;
        cfg.io_pipeline_depth = 4;
        cfg.hist = hist_on;
        let r = Roomy::open(cfg).unwrap();
        let mut rng = Rng::new(0xD15EA5E);
        let value = workload(&r, &mut rng);
        if hist_on {
            use roomy::obs::hist::{global, Domain};
            assert!(
                global().merged(Domain::Task).count() > 0,
                "armed histograms recorded no pool tasks"
            );
            assert!(global().merged(Domain::Collective).count() > 0);
        }
        drop(r); // join io service threads before digesting
        outcomes.push((hist_on, nw, value, dir_digest(t.path())));
    }
    let (_, _, v0, d0) = outcomes[0];
    for (hist_on, nw, v, d) in &outcomes[1..] {
        assert_eq!(*v, v0, "value diverged at hist={hist_on} num_workers={nw}");
        assert_eq!(*d, d0, "on-disk bytes diverged at hist={hist_on} num_workers={nw}");
    }
}

/// Full **batched** BFS drivers agree (level profile and totals) across
/// worker counts and pipeline depths — both the list and the hash-table
/// variant (the BFS frontier scans are the issue's canonical
/// read-ahead consumer).
#[test]
fn det_full_bfs_levels() {
    fn gen(batch: &[u64], out: &mut Vec<u64>) -> roomy::Result<()> {
        for &v in batch {
            for b in 0..7u32 {
                out.push(v ^ (1 << b));
            }
        }
        Ok(())
    }
    let grid: [(StealPolicy, usize, usize); 6] = [
        (StealPolicy::Off, 0, 1),
        (StealPolicy::Off, 4, 4),
        (StealPolicy::Bounded, 0, 4),
        (StealPolicy::Bounded, 1, 2),
        (StealPolicy::Bounded, 4, 4),
        (StealPolicy::Greedy, 4, 1),
    ];
    for driver in ["hash", "list"] {
        let mut profiles = Vec::new();
        for &(steal, depth, nw) in &grid {
            let t = tmpdir(&format!("det_bfs_{driver}_s{steal}_d{depth}_w{nw}"));
            let mut cfg = RoomyConfig::for_testing(t.path());
            cfg.num_workers = nw;
            cfg.io_pipeline_depth = depth;
            cfg.steal_policy = steal;
            cfg.capture_spill_threshold = 128; // exercise capture spills
            let r = Roomy::open(cfg).unwrap();
            let stats = match driver {
                "hash" => bfs::bfs_hash_batched(&r, "cube", &[0u64], gen).unwrap(),
                _ => bfs::bfs_list_batched(&r, "cube", &[0u64], gen).unwrap(),
            };
            profiles.push((steal, depth, nw, stats));
        }
        for (steal, depth, nw, s) in &profiles[1..] {
            assert_eq!(
                s, &profiles[0].3,
                "{driver} BFS level profile diverged at steal={steal} depth={depth} num_workers={nw}"
            );
        }
    }
}
