//! End-to-end run analysis: the committed synthetic fixture, a freshly
//! recorded trace from a live instance, and the regression diff gate.
//!
//! The fixture (`tests/fixtures/synthetic_trace.json`) is a hand-built
//! Chrome trace in the flusher's exact event shape — two `rl.sync
//! [frontier]` instances with skewed per-node tasks and a stolen task, a
//! `rl.dupelim [frontier]`, reader/writer stalls inside and outside the
//! collective windows, plus the metadata and instant events a real flush
//! carries (which the analyzer must skip). Every expected number below is
//! computed by hand from that file, so the attribution rules are pinned
//! against a document that never changes underneath them.

use roomy::obs::analyze::{diff, flatten_metrics, render_diff, render_table, Analysis};
use roomy::obs::json::{parse, Value};
use roomy::testutil::tmpdir;
use roomy::{Roomy, RoomyConfig};

fn fixture() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/synthetic_trace.json");
    std::fs::read_to_string(path).expect("committed fixture must exist")
}

#[test]
fn committed_fixture_attributes_critical_path_skew_and_stalls() {
    let v = parse(&fixture()).expect("fixture must parse");
    let a = Analysis::from_value(&v).unwrap();
    assert_eq!(a.source, "trace");
    assert!(!a.truncated());

    // Totals: 3 collective instances, 8 tasks (1 stolen), every stall
    // counted whether or not a window claims it.
    assert_eq!(a.totals.collectives, 3);
    assert!((a.totals.wall_us - 4500.0).abs() < 1e-9);
    assert_eq!(a.totals.tasks, 8);
    assert_eq!(a.totals.stolen, 1);
    assert!((a.totals.task_us - 3110.0).abs() < 1e-9);
    assert_eq!(a.totals.reader_stalls, 2);
    assert!((a.totals.reader_stall_us - 250.0).abs() < 1e-9);
    assert_eq!(a.totals.writer_stalls, 1);
    assert!((a.totals.writer_stall_us - 100.0).abs() < 1e-9);

    // Heaviest group: both rl.sync instances fold into one row.
    let sync = &a.groups[0];
    assert_eq!(sync.name, "rl.sync [frontier]");
    assert_eq!(sync.calls, 2);
    assert!((sync.wall_us - 3000.0).abs() < 1e-9);
    // First instance: worker0 ran 300+200+250 (one stolen), worker1 ran
    // 1200 → critical path 1200. Second instance: 150 vs 160 → 160.
    assert!((sync.critical_us - 1360.0).abs() < 1e-9);
    assert_eq!(sync.tasks, 6);
    assert_eq!(sync.stolen, 1);
    assert!((sync.reader_stall_us - 200.0).abs() < 1e-9, "in-window stall attributes");
    assert_eq!(sync.writer_stall_us, 0.0, "other groups' stalls stay out");
    assert!(sync.stretch() > 2.0, "wall 3000 vs critical 1360");

    // Per-node skew: node0 durs {300,200,150} → p95 300; node1 durs
    // {1200,250,160} → p95 1200 (exact offline percentiles).
    let n0 = sync.per_node.iter().find(|n| n.node == 0).unwrap();
    let n1 = sync.per_node.iter().find(|n| n.node == 1).unwrap();
    assert_eq!((n0.tasks, n1.tasks), (3, 3));
    assert!((n0.p95_us - 300.0).abs() < 1e-9);
    assert!((n1.p95_us - 1200.0).abs() < 1e-9);
    assert!((n1.max_us - 1200.0).abs() < 1e-9);
    assert!(sync.p95_skew() >= 1.0);

    let dupe = &a.groups[1];
    assert_eq!(dupe.name, "rl.dupelim [frontier]");
    assert_eq!(dupe.calls, 1);
    assert!((dupe.critical_us - 450.0).abs() < 1e-9);
    assert!((dupe.writer_stall_us - 100.0).abs() < 1e-9);

    // Table and JSON agree with the struct view.
    let table = render_table(&a, 10);
    assert!(table.contains("rl.sync [frontier]"), "{table}");
    assert!(table.contains("per-node task p95"), "{table}");
    assert!(!table.contains("WARNING"), "untruncated fixture must not warn:\n{table}");
    let j = parse(&a.to_json()).expect("analysis JSON must reparse");
    assert_eq!(j.get("analysis").and_then(Value::as_f64), Some(1.0));
    let rows = j.get("collectives").and_then(Value::as_arr).unwrap();
    assert_eq!(rows.len(), 2);
}

#[test]
fn truncated_fixture_warns() {
    let t = fixture().replace("\"droppedEvents\":0", "\"droppedEvents\":7");
    assert_ne!(t, fixture());
    let a = Analysis::from_value(&parse(&t).unwrap()).unwrap();
    assert!(a.truncated());
    assert_eq!(a.dropped_events, 7);
    assert!(render_table(&a, 5).contains("WARNING"));
}

/// A live instance with tracing and histograms armed: the flushed trace
/// must analyze to at least one collective with attributed tasks and a
/// positive critical path, and the `report_json` snapshot must analyze
/// through the same entry point.
#[test]
fn fresh_trace_from_live_run_attributes_collectives() {
    let root = tmpdir("analyze_live");
    let tdir = tmpdir("analyze_live_trace");
    let mut cfg = RoomyConfig::for_testing(root.path());
    cfg.workers = 3;
    cfg.buckets_per_worker = 2;
    cfg.num_workers = 2;
    cfg.trace_path = Some(tdir.path().join("trace.json"));
    cfg.hist = true;
    let r = Roomy::open(cfg).unwrap();

    let l = r.list::<u64>("l").unwrap();
    for i in 0..2_000u64 {
        l.add(&(i % 400)).unwrap();
    }
    l.sync().unwrap();
    l.remove_dupes().unwrap();
    l.map(|_v| {}).unwrap();

    // The armed histograms saw the pool tasks those collectives ran.
    use roomy::obs::hist::{global, Domain};
    assert!(global().merged(Domain::Task).count() > 0, "armed hist recorded no tasks");
    assert!(global().merged(Domain::Collective).count() > 0);

    let flushed = r.flush_trace().unwrap().expect("tracing must be armed");
    let text = std::fs::read_to_string(&flushed).unwrap();
    let a = Analysis::from_value(&parse(&text).unwrap()).unwrap();
    assert_eq!(a.source, "trace");
    assert!(a.totals.collectives >= 2, "sync + dupelim + map must record");
    let g = a
        .groups
        .iter()
        .find(|g| g.tasks > 0)
        .expect("at least one collective must have attributed tasks");
    assert!(g.critical_us > 0.0, "attributed tasks imply a critical path");
    assert!(!g.per_node.is_empty());

    // The metrics report analyzes through the same front door.
    let rep = r.report_json();
    let ra = Analysis::from_value(&parse(&rep).unwrap()).unwrap();
    assert_eq!(ra.source, "report");
    assert!(ra.totals.collectives > 0);

    // And the two documents diff against themselves cleanly.
    let (rows, regressed) = diff(&parse(&text).unwrap(), &parse(&text).unwrap(), 25.0).unwrap();
    assert!(!rows.is_empty());
    assert!(!regressed, "a run diffed against itself must never regress");
}

#[test]
fn diff_gate_fires_on_injected_regression_only() {
    let v = parse(&fixture()).unwrap();
    let m = flatten_metrics(&v).unwrap();
    assert!(m.contains_key("total/wall_ms"));
    assert!(m.contains_key("collective/rl.sync [frontier]/wall_ms"));

    // Identical runs: zero deltas, no gate.
    let (rows, regressed) = diff(&v, &v, 10.0).unwrap();
    assert!(!regressed);
    assert!(rows.iter().all(|r| r.delta_pct == 0.0));

    // Inject a 10x slowdown into the heavy collective instance.
    let slow = fixture().replace("\"dur\":2000,", "\"dur\":20000,");
    assert_ne!(slow, fixture());
    let vb = parse(&slow).unwrap();
    let (rows, regressed) = diff(&v, &vb, 25.0).unwrap();
    assert!(regressed, "10x wall growth past 25% must gate");
    assert!(rows.iter().any(|r| r.regressed && r.key.contains("rl.sync")));
    assert!(render_diff(&rows, 25.0, regressed).contains("REGRESSION"));

    // The same pair in the improving direction never fires.
    let (_, regressed) = diff(&vb, &v, 25.0).unwrap();
    assert!(!regressed, "getting faster is never a regression");
}
