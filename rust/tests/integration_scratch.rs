//! Invariants of the process-wide scratch buffer pool
//! (`roomy::storage::scratch`): bounded idle RAM, measurable reuse, and
//! leak-free unwinding when a collective panics mid-stream.
//!
//! These live in their own integration binary because the pool and its
//! [`roomy::metrics::AllocStats`] gauges are process-global — the loan
//! gauge (`outstanding`) is only meaningfully zero when no other test in
//! the same process is mid-collective. Within this binary the tests
//! additionally serialize on a lock so their snapshots never interleave.

mod common;

use common::roomy_with;
use roomy::storage::scratch;
use std::sync::Mutex;

/// Serializes the tests in this binary: each one reads the global pool
/// gauges and must not observe another test's checked-out buffers.
static POOL_GAUGES: Mutex<()> = Mutex::new(());

/// Under a parallel scan + rewrite (4 pool workers × pipeline depth 4 —
/// the widest hot path), the pool's idle RAM stays under the fixed cap,
/// buffers are measurably reused, and every loan is returned once the
/// collectives finish.
#[test]
fn pool_ram_bounded_and_loans_returned() {
    let _g = POOL_GAUGES.lock().unwrap();
    scratch::reset_alloc_stats();

    let (_t, r) = roomy_with("scratch_bound", |c| {
        c.workers = 2;
        c.buckets_per_worker = 2;
        c.num_workers = 4;
        c.io_pipeline_depth = 4;
    });
    let ra = r.array::<u64>("a", 600_000, 1).unwrap(); // ~4.8 MB
    for _round in 0..3 {
        ra.map_update(|i, v| *v = i ^ *v).unwrap();
    }
    let ht = r.hash_table::<u64, u64>("h").unwrap();
    for k in 0..5_000u64 {
        ht.insert(&k, &(k * 3)).unwrap();
    }
    ht.sync().unwrap();
    drop(ht);
    drop(ra);
    drop(r); // join io service threads: they hold circulating chunks

    let snap = scratch::alloc_snapshot();
    assert!(
        snap.peak_pooled_bytes <= scratch::pool_cap_bytes(),
        "idle pool RAM {} exceeds the cap {}",
        snap.peak_pooled_bytes,
        scratch::pool_cap_bytes(),
    );
    assert!(snap.pool_hits > 0, "hot loops never reused a pooled buffer: {snap:?}");
    assert_eq!(snap.outstanding, 0, "leaked scratch loans: {snap:?}");
    assert_eq!(snap.outstanding_bytes, 0, "leaked scratch bytes: {snap:?}");
}

/// A panic inside a mapped collective unwinds through borrowed scratch
/// buffers (scan chunks, record scratch, pipeline stream buffers) — every
/// loan must still come back to the pool, exactly like the staging-file
/// guarantee in `integration_pipeline.rs`.
#[test]
fn panicking_map_returns_every_loan() {
    let _g = POOL_GAUGES.lock().unwrap();
    scratch::reset_alloc_stats();

    let (_t, r) = roomy_with("scratch_panic", |c| {
        c.workers = 2;
        c.buckets_per_worker = 2;
        c.num_workers = 4;
        c.io_pipeline_depth = 4;
    });
    let ra = r.array::<u64>("a", 600_000, 1).unwrap();
    let res = ra.map_update(|i, _v| assert!(i != 444_444, "boom"));
    assert!(
        matches!(res, Err(roomy::RoomyError::WorkerPanic { .. })),
        "expected WorkerPanic, got {res:?}"
    );

    // The instance survives a failed collective; run a clean pass to show
    // the pool still serves buffers normally after the unwind.
    let count = std::sync::atomic::AtomicU64::new(0);
    ra.map(|_i, _v| {
        count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(count.into_inner(), 600_000);

    drop(ra);
    drop(r);
    let snap = scratch::alloc_snapshot();
    assert_eq!(snap.outstanding, 0, "panic leaked scratch loans: {snap:?}");
    assert_eq!(snap.outstanding_bytes, 0, "panic leaked scratch bytes: {snap:?}");
    assert!(
        snap.peak_pooled_bytes <= scratch::pool_cap_bytes(),
        "idle pool RAM {} exceeds the cap {}",
        snap.peak_pooled_bytes,
        scratch::pool_cap_bytes(),
    );
}
