//! Integration: breadth-first search drivers + the pancake application —
//! the paper's flagship workload — across all three data-structure
//! variants, both accel backends, and stressed configurations.

mod common;

use common::{artifacts_present, roomy, roomy_with};
use roomy::accel::Accel;
use roomy::apps::pancake::{
    factorial, pancake_number, reference_bfs, roomy_bfs, Structure,
};
use std::sync::Arc;

fn accel_xla() -> Option<Accel> {
    if artifacts_present() {
        roomy::runtime::Engine::load("artifacts").ok().map(|e| Accel::xla(Arc::new(e)))
    } else {
        None
    }
}

#[test]
fn pancake_n6_all_variants_match_reference() {
    let expect = reference_bfs(6);
    for s in [Structure::List, Structure::Hash, Structure::Array] {
        let (_t, r) = roomy(&format!("ib_n6_{s:?}"));
        let stats = roomy_bfs(&r, 6, s, &Accel::rust()).unwrap();
        assert_eq!(stats.levels, expect, "{s:?}");
        assert_eq!(stats.total, factorial(6));
        assert_eq!(stats.depth(), pancake_number(6).unwrap());
    }
}

#[test]
fn pancake_n7_list_via_xla_expansion() {
    let Some(xla) = accel_xla() else { return };
    let (_t, r) = roomy("ib_n7_xla");
    let stats = roomy_bfs(&r, 7, Structure::List, &xla).unwrap();
    assert_eq!(stats.levels, reference_bfs(7));
    assert_eq!(stats.depth(), pancake_number(7).unwrap()); // f(7) = 8
}

#[test]
fn pancake_n7_hash_xla_equals_rust() {
    let Some(xla) = accel_xla() else { return };
    let (_t1, r1) = roomy("ib_n7h_xla");
    let (_t2, r2) = roomy("ib_n7h_rust");
    let a = roomy_bfs(&r1, 7, Structure::Hash, &xla).unwrap();
    let b = roomy_bfs(&r2, 7, Structure::Hash, &Accel::rust()).unwrap();
    assert_eq!(a.levels, b.levels);
    assert_eq!(a.total, b.total);
}

#[test]
fn pancake_n8_list_spill_heavy() {
    // 40320 states with tiny buffers: staging spills constantly
    let (_t, r) = roomy_with("ib_n8_spill", |c| {
        c.op_buffer_bytes = 512;
        c.workers = 4;
        c.buckets_per_worker = 2;
    });
    let stats = roomy_bfs(&r, 8, Structure::List, &Accel::rust()).unwrap();
    assert_eq!(stats.levels, reference_bfs(8));
    assert_eq!(stats.total, factorial(8));
    assert_eq!(stats.depth(), 9); // f(8) = 9
}

#[test]
fn pancake_single_worker_degenerate_cluster() {
    let (_t, r) = roomy_with("ib_w1", |c| {
        c.workers = 1;
        c.buckets_per_worker = 1;
    });
    let stats = roomy_bfs(&r, 6, Structure::List, &Accel::rust()).unwrap();
    assert_eq!(stats.levels, reference_bfs(6));
}

#[test]
fn generic_bfs_grid_graph() {
    // 2-D grid: BFS levels are anti-diagonals
    let (_t, r) = roomy("ib_grid");
    let w = 12u64;
    let stats = roomy::constructs::bfs::bfs_list(&r, "grid", &[0u64], |&v, out| {
        let (x, y) = (v % w, v / w);
        if x + 1 < w {
            out.push(v + 1);
        }
        if y + 1 < w {
            out.push(v + w);
        }
    })
    .unwrap();
    assert_eq!(stats.total, w * w);
    assert_eq!(stats.depth(), 2 * (w - 1));
    // level k size = number of (x,y) with x+y == k
    for (k, &c) in stats.levels.iter().enumerate() {
        let k = k as u64;
        let expect = if k < w { k + 1 } else { 2 * w - 1 - k };
        assert_eq!(c, expect, "level {k}");
    }
}

#[test]
fn bfs_list_and_hash_agree_on_random_graph() {
    // deterministic pseudo-random sparse digraph over 0..500
    let gen = |v: u64, out: &mut Vec<u64>| {
        let m = 500u64;
        let a = (v.wrapping_mul(2654435761) % m) as u64;
        let b = (v.wrapping_mul(0x9E3779B97F4A7C15) % m) as u64;
        out.push(a);
        out.push(b);
    };
    let (_t1, r1) = roomy("ib_rand_list");
    let s1 = roomy::constructs::bfs::bfs_list(&r1, "g", &[0u64], |&v, out| gen(v, out)).unwrap();
    let (_t2, r2) = roomy("ib_rand_hash");
    let s2 = roomy::constructs::bfs::bfs_hash_batched(&r2, "g", &[0u64], |batch, out| {
        for &v in batch {
            gen(v, out);
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(s1.levels, s2.levels);
    assert_eq!(s1.total, s2.total);
}

#[test]
fn level_counts_sum_to_total() {
    let (_t, r) = roomy("ib_sum");
    let stats = roomy_bfs(&r, 7, Structure::Hash, &Accel::rust()).unwrap();
    assert_eq!(stats.levels.iter().sum::<u64>(), stats.total);
    assert_eq!(stats.total, factorial(7));
}
