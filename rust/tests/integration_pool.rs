//! End-to-end exercise of the worker-pool execution engine: the paper's
//! flagship pancake-sort BFS at n = 7 on a 4-wide pool, and a concurrency
//! stress test hammering one Roomy instance (and therefore one pool and
//! one PJRT-style shared engine path) from many client threads at once.

mod common;

use common::{dir_digest, roomy_with};
use roomy::testutil::files_under;
use roomy::accel::Accel;
use roomy::apps::pancake::{self, Structure};
use std::sync::atomic::{AtomicU64, Ordering};

/// Pancake BFS for n = 7 must reproduce the known level profile (it sums
/// to 7! = 5040 and its depth is the pancake number f(7) = 8) with the
/// pool at full width.
#[test]
fn pancake_n7_level_profile_under_pool() {
    let (_t, r) = roomy_with("pool_pancake7", |c| {
        c.num_workers = 4;
        c.buckets_per_worker = 2;
    });
    let stats = pancake::roomy_bfs(&r, 7, Structure::Hash, &Accel::rust()).unwrap();
    let expect = pancake::reference_bfs(7);
    assert_eq!(stats.levels, expect, "level profile");
    assert_eq!(stats.total, pancake::factorial(7));
    assert_eq!(stats.depth(), pancake::pancake_number(7).unwrap());
    // the pool actually ran bucket tasks
    assert!(r.cluster().pool().stats().total_tasks() > 0);
    // per-worker counters add up and the report mentions the pool
    let per: u64 = r
        .cluster()
        .pool()
        .stats()
        .per_worker()
        .iter()
        .map(|(t, _)| t)
        .sum();
    assert_eq!(per, r.cluster().pool().stats().total_tasks());
    assert!(r.report().contains("pool (4 workers"), "{}", r.report());
}

/// The list variant agrees with the hash variant at n = 6 under the pool
/// (cross-driver agreement exercises sort-based and bucket-based dedup).
#[test]
fn pancake_variants_agree_under_pool() {
    for structure in [Structure::List, Structure::Array] {
        let (_t, r) = roomy_with("pool_pancake6", |c| c.num_workers = 4);
        let stats = pancake::roomy_bfs(&r, 6, structure, &Accel::rust()).unwrap();
        assert_eq!(stats.levels, pancake::reference_bfs(6), "{structure:?}");
    }
}

/// Many client threads hammer one instance concurrently: delayed ops are
/// issued from all of them, several threads call collectives (sync / map /
/// reduce) at the same time, and the final state must account for every
/// single op.
#[test]
fn concurrent_clients_one_pool_stress() {
    let (_t, r) = roomy_with("pool_stress", |c| {
        c.num_workers = 4;
        c.workers = 2;
        c.buckets_per_worker = 2;
        c.op_buffer_bytes = 512; // force spill churn under contention
    });
    let n = 512u64;
    let ra = r.array::<u64>("shared", n, 0).unwrap();
    let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v = v.wrapping_add(*p));
    let rl = r.list::<u64>("events").unwrap();

    let issued_sum = AtomicU64::new(0);
    let issued_adds = AtomicU64::new(0);
    let nthreads = 8usize;
    let per_thread = 2_000u64;

    std::thread::scope(|s| {
        for tid in 0..nthreads {
            let (ra, rl) = (ra.clone(), rl.clone());
            let (issued_sum, issued_adds) = (&issued_sum, &issued_adds);
            s.spawn(move || {
                let mut rng = roomy::testutil::Rng::new(tid as u64 + 1);
                for k in 0..per_thread {
                    let i = rng.below(n);
                    let p = rng.below(1_000) + 1;
                    ra.update(i, &p, add).unwrap();
                    issued_sum.fetch_add(p, Ordering::Relaxed);
                    rl.add(&(tid as u64 * per_thread + k)).unwrap();
                    issued_adds.fetch_add(1, Ordering::Relaxed);
                    // a few threads run collectives mid-stream
                    if k % 701 == 0 && tid % 3 == 0 {
                        ra.sync().unwrap();
                    }
                    if k % 907 == 0 && tid % 3 == 1 {
                        rl.sync().unwrap();
                        let _ = rl.size();
                    }
                    if k % 1301 == 0 && tid % 3 == 2 {
                        // read-only collective racing the writers
                        let _ = ra
                            .reduce(|| 0u64, |a, _i, v| a.wrapping_add(*v), |a, b| {
                                a.wrapping_add(b)
                            })
                            .unwrap();
                    }
                }
            });
        }
    });

    // Drain everything that is still staged.
    ra.sync().unwrap();
    rl.sync().unwrap();

    let total = ra
        .reduce(|| 0u64, |a, _i, v| a.wrapping_add(*v), |a, b| a.wrapping_add(b))
        .unwrap();
    assert_eq!(total, issued_sum.load(Ordering::Relaxed), "no update lost or doubled");
    assert_eq!(rl.size(), issued_adds.load(Ordering::Relaxed), "no add lost");
    // every event id exactly once
    rl.remove_dupes().unwrap();
    assert_eq!(rl.size(), (nthreads as u64) * per_thread);
}

/// The strict space bound inside collectives: a capture-heavy map (each
/// element issues several delayed adds, ~10× the capture threshold per
/// task in total) must keep per-task capture RAM within threshold + one
/// record, spill the rest to scratch files, clean the scratch up, and
/// still produce on-disk bytes identical to the serial (1-worker) run.
#[test]
fn capture_heavy_map_is_space_bounded_and_deterministic() {
    const THRESHOLD: usize = 256;
    // list<u64> op record = 2-byte header + 8-byte element, + 8-byte
    // capture log header
    const RECORD: usize = 2 + 8 + 8;

    let run = |nw: usize| {
        let (t, r) = roomy_with(&format!("pool_capture_bound_{nw}"), |c| {
            c.num_workers = nw;
            c.workers = 3;
            c.buckets_per_worker = 2;
            c.capture_spill_threshold = THRESHOLD;
        });
        let src = r.list::<u64>("src").unwrap();
        let n = 3_000u64;
        for v in 0..n {
            src.add(&v).unwrap();
        }
        src.sync().unwrap();
        let dst = r.list::<u64>("dst").unwrap();
        let dst2 = dst.clone();
        // ~500 elements per task × 4 adds × 10 bytes ≈ 20 KiB per task:
        // two orders of magnitude over the 256-byte threshold.
        src.map(move |&v| {
            for k in 0..4u64 {
                dst2.add(&(v * 4 + k)).unwrap();
            }
        })
        .unwrap();

        let stats = r.cluster().pool().stats();
        assert!(
            stats.capture_peak_task_ram() as usize <= THRESHOLD + RECORD,
            "peak per-task capture RAM {} exceeds threshold {} + record",
            stats.capture_peak_task_ram(),
            THRESHOLD,
        );
        assert!(stats.capture_spilled_bytes() > 0, "spill path never ran");
        assert!(stats.capture_scratch_files() > 0);
        // scratch is gone after the barrier
        for w in 0..r.cluster().nworkers() {
            let scratch = r.cluster().disk(w).root().join("tmp/capture");
            assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
        }

        dst.sync().unwrap();
        assert_eq!(dst.size(), n * 4);
        drop(r);
        dir_digest(t.path())
    };

    let serial = run(1);
    for nw in [2usize, 4] {
        assert_eq!(run(nw), serial, "on-disk bytes diverged at num_workers={nw}");
    }
}

/// A map that panics mid-collective must leave zero capture scratch files
/// behind — including those of tasks that had already spilled.
#[test]
fn panicking_map_leaves_no_capture_scratch() {
    let (_t, r) = roomy_with("pool_capture_panic_leak", |c| {
        c.num_workers = 4;
        c.capture_spill_threshold = 64; // every task spills quickly
    });
    let src = r.list::<u64>("src").unwrap();
    for v in 0..2_000u64 {
        src.add(&v).unwrap();
    }
    src.sync().unwrap();
    let dst = r.list::<u64>("dst").unwrap();
    let dst2 = dst.clone();
    let res = src.map(move |&v| {
        for k in 0..4u64 {
            dst2.add(&(v ^ k)).unwrap();
        }
        // all shards stage plenty before any task trips the panic
        assert!(v != 1_777, "boom");
    });
    match res {
        Err(roomy::RoomyError::WorkerPanic { .. }) => {}
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
    // scratch files were really created (the leak check is not vacuous)...
    assert!(r.cluster().pool().stats().capture_spilled_bytes() > 0);
    // ...and none survive the failed collective.
    for w in 0..r.cluster().nworkers() {
        let scratch = r.cluster().disk(w).root().join("tmp/capture");
        assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
    }
    // nothing captured in the failed collective was replayed
    assert_eq!(dst.pending_bytes(), 0);
    // the structure stays usable afterwards
    dst.add(&1).unwrap();
    dst.sync().unwrap();
    assert_eq!(dst.size(), 1);
}

/// Collectives from multiple threads at once on the same structure.
#[test]
fn concurrent_collectives_do_not_interleave_state() {
    let (_t, r) = roomy_with("pool_concurrent_maps", |c| c.num_workers = 4);
    let ra = r.array::<u64>("a", 1_000, 1).unwrap();
    std::thread::scope(|s| {
        for _ in 0..6 {
            let ra = ra.clone();
            s.spawn(move || {
                for _ in 0..5 {
                    let sum = ra
                        .reduce(|| 0u64, |a, _i, v| a + v, |a, b| a + b)
                        .unwrap();
                    assert_eq!(sum, 1_000);
                    let count = std::sync::atomic::AtomicU64::new(0);
                    ra.map(|_i, _v| {
                        count.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap();
                    assert_eq!(count.into_inner(), 1_000);
                }
            });
        }
    });
}

/// The issue's pinned locality scenario: a deliberately skewed bucket
/// load (every element hashes into node 0's buckets, so node 0's two
/// shard tasks carry all the work while the other six are empty). Under
/// `bounded` stealing the idle workers must drain node 0's queue
/// (steals > 0), `PoolStats` must report per-node queue depths and the
/// locality split, and the on-disk result must stay byte-identical to
/// the serial run.
#[test]
fn skewed_load_steals_and_matches_serial_digest() {
    use roomy::{hashfn, StealPolicy};

    let (workers, bpw) = (4usize, 2usize);
    let nb = (workers * bpw) as u32;
    // deterministically collect values routed to node 0's buckets
    let mut vals = Vec::new();
    let mut v = 0u64;
    while vals.len() < 6_000 {
        if hashfn::bucket_of_bytes(&v.to_le_bytes(), nb) as usize % workers == 0 {
            vals.push(v);
        }
        v += 1;
    }

    let run = |nw: usize, steal: StealPolicy| {
        let (t, r) = roomy_with(&format!("pool_skew_{nw}_{steal}"), |c| {
            c.workers = workers;
            c.buckets_per_worker = bpw;
            c.num_workers = nw;
            c.steal_policy = steal;
        });
        let l = r.list::<u64>("skew").unwrap();
        for x in &vals {
            l.add(x).unwrap();
        }
        l.sync().unwrap();
        // a scan-heavy collective over the skewed shards, with a little
        // CPU per element so node 0's tasks are visibly long
        let acc = AtomicU64::new(0);
        l.map(|&x| {
            acc.fetch_add(x.wrapping_mul(0x9E3779B97F4A7C15), Ordering::Relaxed);
        })
        .unwrap();
        let _ = l.reduce(|| 0u64, |a, &x| a ^ x, |a, b| a.wrapping_add(b)).unwrap();

        if nw > 1 && steal == StealPolicy::Bounded {
            let st = r.cluster().pool().stats();
            assert!(st.steals() > 0, "skewed load must trigger steals");
            assert!(st.locality_hits() > 0, "home drains must dominate");
            let rate = st.locality_rate();
            assert!(rate > 0.0 && rate < 1.0, "mixed schedule expected, got {rate}");
            // queue depth is balanced by construction (count skew lives
            // in task *weight*): 8 buckets over 4 nodes = 2 each
            assert_eq!(st.per_node_queue_depth(), vec![2, 2, 2, 2]);
            assert!(
                r.report().contains("locality:"),
                "report must surface the locality counters:\n{}",
                r.report()
            );
        }
        drop(r);
        dir_digest(t.path())
    };

    let serial = run(1, StealPolicy::Off);
    assert_eq!(
        run(4, StealPolicy::Bounded),
        serial,
        "stealing must not change on-disk bytes"
    );
    assert_eq!(
        run(4, StealPolicy::Off),
        serial,
        "strict locality must not change on-disk bytes"
    );
}

// ----------------------------------------------------------------------
// Process-wide scratch pool invariants (bounded idle RAM, measurable
// reuse, leak-free unwinding). The pool and its AllocStats gauges are
// process-global; `scratch::metric_scope()` gates these tests against
// each other and quiesces/zeroes the counters, so they can share this
// binary instead of needing their own (formerly tests/integration_scratch.rs).
// ----------------------------------------------------------------------

/// Under a parallel scan + rewrite (4 pool workers × pipeline depth 4 —
/// the widest hot path), the pool's idle RAM stays under the fixed cap,
/// buffers are measurably reused, and every loan is returned once the
/// collectives finish.
#[test]
fn pool_ram_bounded_and_loans_returned() {
    let scope = roomy::storage::scratch::metric_scope();

    let (_t, r) = roomy_with("scratch_bound", |c| {
        c.workers = 2;
        c.buckets_per_worker = 2;
        c.num_workers = 4;
        c.io_pipeline_depth = 4;
    });
    let ra = r.array::<u64>("a", 600_000, 1).unwrap(); // ~4.8 MB
    for _round in 0..3 {
        ra.map_update(|i, v| *v = i ^ *v).unwrap();
    }
    let ht = r.hash_table::<u64, u64>("h").unwrap();
    for k in 0..5_000u64 {
        ht.insert(&k, &(k * 3)).unwrap();
    }
    ht.sync().unwrap();
    drop(ht);
    drop(ra);
    drop(r); // join io service threads: they hold circulating chunks

    let snap = scope.settled();
    assert!(
        snap.peak_pooled_bytes <= roomy::storage::scratch::pool_cap_bytes(),
        "idle pool RAM {} exceeds the cap {}",
        snap.peak_pooled_bytes,
        roomy::storage::scratch::pool_cap_bytes(),
    );
    assert!(snap.pool_hits > 0, "hot loops never reused a pooled buffer: {snap:?}");
    assert_eq!(snap.outstanding, 0, "leaked scratch loans: {snap:?}");
    assert_eq!(snap.outstanding_bytes, 0, "leaked scratch bytes: {snap:?}");
}

/// A panic inside a mapped collective unwinds through borrowed scratch
/// buffers (scan chunks, record scratch, pipeline stream buffers) — every
/// loan must still come back to the pool, exactly like the staging-file
/// guarantee in `integration_pipeline.rs`.
#[test]
fn panicking_map_returns_every_loan() {
    let scope = roomy::storage::scratch::metric_scope();

    let (_t, r) = roomy_with("scratch_panic", |c| {
        c.workers = 2;
        c.buckets_per_worker = 2;
        c.num_workers = 4;
        c.io_pipeline_depth = 4;
    });
    let ra = r.array::<u64>("a", 600_000, 1).unwrap();
    let res = ra.map_update(|i, _v| assert!(i != 444_444, "boom"));
    assert!(
        matches!(res, Err(roomy::RoomyError::WorkerPanic { .. })),
        "expected WorkerPanic, got {res:?}"
    );

    // The instance survives a failed collective; run a clean pass to show
    // the pool still serves buffers normally after the unwind.
    let count = AtomicU64::new(0);
    ra.map(|_i, _v| {
        count.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(count.into_inner(), 600_000);

    drop(ra);
    drop(r);
    let snap = scope.settled();
    assert_eq!(snap.outstanding, 0, "panic leaked scratch loans: {snap:?}");
    assert_eq!(snap.outstanding_bytes, 0, "panic leaked scratch bytes: {snap:?}");
    assert!(
        snap.peak_pooled_bytes <= roomy::storage::scratch::pool_cap_bytes(),
        "idle pool RAM {} exceeds the cap {}",
        snap.peak_pooled_bytes,
        roomy::storage::scratch::pool_cap_bytes(),
    );
}
