//! The approximate-membership dedup tier (`roomy::storage::bloom`) is
//! **exact-backed by default**: a bloom "definitely new" answer may skip
//! exact work (scans, sort-merges, full bucket rewrites), but anything
//! "maybe seen" falls through to the seed's exact paths — so with the
//! filter on, every structure's on-disk bytes are identical to the
//! filter-off run at every worker count and pipeline depth. Opt-in
//! approximate mode trades a small, measured false-positive rate for
//! skipping the exact merge; its FP budget is pinned here too.

mod common;

use std::collections::BTreeSet;

use common::dir_digest;
use roomy::constructs::bfs;
use roomy::testutil::{tmpdir, Rng};
use roomy::{Roomy, RoomyConfig};

/// (bloom bits-per-key, num_workers, io_pipeline_depth) grid: cell 0 is
/// the filter-off serial reference every other cell must match.
const CELLS: [(usize, usize, usize); 8] = [
    (0, 1, 0),
    (0, 4, 4),
    (10, 1, 0),
    (10, 1, 4),
    (10, 4, 0),
    (10, 4, 4),
    (6, 4, 4),
    (14, 1, 0),
];

fn open_cell(root: &std::path::Path, bloom: usize, nw: usize, depth: usize) -> Roomy {
    let mut cfg = RoomyConfig::for_testing(root);
    cfg.workers = 3; // uneven bucket→node split
    cfg.buckets_per_worker = 2;
    cfg.num_workers = nw;
    cfg.io_pipeline_depth = depth;
    cfg.bloom_bits_per_key = bloom;
    cfg.bloom_approximate = false;
    Roomy::open(cfg).unwrap()
}

/// A dup-heavy mixed workload over every structure the filter fronts:
/// set add/remove churn, hash-table upserts, list dedup + set algebra.
/// Returns an order-sensitive value so result order is pinned too.
fn dedup_workload(r: &Roomy, rng: &mut Rng) -> u64 {
    let s = r.set::<u64>("s").unwrap();
    let ht = r.hash_table::<u64, u64>("h").unwrap();
    let l = r.list::<u64>("l").unwrap();
    let bump = ht.register_update(|k, cur: Option<&u64>, p: &u64| {
        Some(cur.copied().unwrap_or(*k).wrapping_add(*p))
    });
    for _round in 0..3 {
        for _ in 0..600 {
            let v = rng.below(400);
            if rng.chance(0.8) {
                s.add(&v).unwrap();
            } else {
                s.remove(&v).unwrap();
            }
            let k = rng.below(300);
            match rng.range(0, 4) {
                0 => ht.insert(&k, &rng.next_u64()).unwrap(),
                1 => ht.remove(&k).unwrap(),
                _ => ht.update(&k, &(rng.next_u64() >> 40), bump).unwrap(),
            }
            l.add(&rng.below(500)).unwrap();
        }
        s.sync().unwrap();
        ht.sync().unwrap();
        l.sync().unwrap();
    }
    l.remove_dupes().unwrap();
    // Queries that ride the filter front.
    let mut probe_hash = 0u64;
    for q in 0..800u64 {
        if s.contains(&q).unwrap() {
            probe_hash = probe_hash.wrapping_mul(0x9E3779B97F4A7C15) ^ q;
        }
        if let Some(v) = ht.fetch(&q).unwrap() {
            probe_hash = probe_hash.wrapping_mul(0x9E3779B97F4A7C15) ^ v;
        }
    }
    let h1 = s
        .reduce(|| probe_hash, |acc, v| acc.wrapping_mul(0x9E3779B97F4A7C15) ^ v, |a, b| {
            a.wrapping_mul(0x9E3779B97F4A7C15) ^ b
        })
        .unwrap();
    ht.reduce(
        || h1,
        |acc, k, v| acc.wrapping_mul(0x9E3779B97F4A7C15) ^ (k ^ v),
        |a, b| a.wrapping_mul(0x9E3779B97F4A7C15) ^ b,
    )
    .unwrap()
}

/// Tentpole acceptance: with the exact-backed filter on, on-disk bytes
/// (full recursive digest of the instance root) and results are identical
/// to the filter-off run — across filter widths, worker counts, and
/// pipeline depths.
#[test]
fn digests_identical_bloom_on_off_across_workers_and_depths() {
    let mut outcomes = Vec::new();
    for &(bloom, nw, depth) in &CELLS {
        let t = tmpdir(&format!("dedup_dig_b{bloom}_w{nw}_d{depth}"));
        let r = open_cell(t.path(), bloom, nw, depth);
        let mut rng = Rng::new(0xB10_0F11);
        let value = dedup_workload(&r, &mut rng);
        let snap = r.dedup_snapshot();
        if bloom > 0 {
            assert!(snap.probes > 0, "filter configured but never probed: {snap:?}");
        } else {
            assert_eq!(snap.probes, 0, "filter off must not probe");
        }
        drop(r); // join io service threads before digesting
        let digest = dir_digest(t.path());
        outcomes.push((bloom, nw, depth, value, digest));
    }
    let (_, _, _, v0, d0) = outcomes[0];
    for (bloom, nw, depth, v, d) in &outcomes[1..] {
        assert_eq!(*v, v0, "value diverged at bloom={bloom} workers={nw} depth={depth}");
        assert_eq!(
            *d, d0,
            "on-disk bytes diverged at bloom={bloom} workers={nw} depth={depth}"
        );
    }
}

/// Exact-backed mode never drops a genuinely-new record: the final set
/// contents equal an in-RAM model of the same operation stream, for every
/// random seed tried.
#[test]
fn bloom_exact_never_drops_new_records() {
    for seed in [1u64, 2, 3, 4, 5] {
        let t = tmpdir(&format!("dedup_nofn_{seed}"));
        let r = open_cell(t.path(), 10, 4, 4);
        let s = r.set::<u64>("s").unwrap();
        let mut model = BTreeSet::new();
        let mut rng = Rng::new(seed);
        for _round in 0..4 {
            for _ in 0..500 {
                let v = rng.below(3_000);
                if rng.chance(0.85) {
                    s.add(&v).unwrap();
                    model.insert(v);
                } else {
                    s.remove(&v).unwrap();
                    model.remove(&v);
                }
            }
            s.sync().unwrap();
        }
        let got: BTreeSet<u64> = s.collect().unwrap().into_iter().collect();
        assert_eq!(got, model, "seed {seed}: exact-backed filter dropped/kept wrong records");
        assert_eq!(s.size(), model.len() as u64);
        // Membership queries stay exact through the filter front.
        for v in 0..200u64 {
            assert_eq!(s.contains(&v).unwrap(), model.contains(&v), "seed {seed} elt {v}");
        }
    }
}

/// The filter actually avoids exact work on dup-free traffic (the metric
/// the E6 bench table reports): fresh keys through set + hash table must
/// record exact-merge shortcuts with nonzero bytes avoided.
#[test]
fn bloom_records_exact_work_avoided() {
    let t = tmpdir("dedup_avoided");
    let r = open_cell(t.path(), 10, 4, 0);
    let ht = r.hash_table::<u64, u64>("h").unwrap();
    for wave in 0..3u64 {
        for k in (wave * 500)..(wave * 500 + 500) {
            ht.insert(&k, &k).unwrap();
        }
        ht.sync().unwrap();
    }
    let s = r.set::<u64>("s").unwrap();
    for v in 0..500u64 {
        s.add(&v).unwrap();
    }
    s.sync().unwrap();
    for v in 5_000..5_500u64 {
        assert!(!s.contains(&v).unwrap());
    }
    let snap = r.dedup_snapshot();
    assert!(snap.shortcuts > 0, "no exact work avoided: {snap:?}");
    assert!(snap.bytes_avoided > 0, "no bytes avoided: {snap:?}");
    assert!(snap.filter_ram_bytes > 0, "filter RAM unmetered: {snap:?}");
    assert!(snap.inserts > 0, "filter never fed: {snap:?}");
}

/// Approximate mode: distinct records wrongly dropped as duplicates stay
/// within the configured bits-per-key false-positive budget, and the drop
/// count is surfaced in `DedupStats`.
#[test]
fn approximate_fp_rate_within_budget() {
    let t = tmpdir("dedup_fp");
    let mut cfg = RoomyConfig::for_testing(t.path());
    cfg.bloom_bits_per_key = 10;
    cfg.bloom_approximate = true;
    let r = Roomy::open(cfg).unwrap();
    let s = r.set::<u64>("s").unwrap();
    // Phase 1: fill the filter with 20k distinct keys.
    for v in 0..20_000u64 {
        s.add(&v).unwrap();
    }
    s.sync().unwrap();
    assert_eq!(s.size(), 20_000, "phase 1 adds probe an empty filter — nothing may drop");
    // Phase 2: 20k more distinct keys; any drop is a filter false
    // positive. 10 bits/key targets ~1% FP; 5% is a generous ceiling.
    for v in 20_000..40_000u64 {
        s.add(&v).unwrap();
    }
    s.sync().unwrap();
    let snap = r.dedup_snapshot();
    let dropped = 40_000 - s.size();
    assert_eq!(snap.approx_dropped, dropped, "drop accounting disagrees with set size");
    assert!(
        dropped <= 1_000,
        "false-positive rate {:.2}% exceeds budget (dropped {dropped} of 20000)",
        dropped as f64 / 200.0
    );
    // Dropping the exact merge is the point: shortcut work must register.
    assert!(snap.shortcuts > 0 || snap.approx_dropped > 0, "{snap:?}");
}

/// Full BFS drivers (list and hash families) produce identical level
/// profiles and totals with the exact-backed filter on or off.
#[test]
fn bfs_profiles_identical_bloom_on_off() {
    fn gen(batch: &[u64], out: &mut Vec<u64>) -> roomy::Result<()> {
        for &v in batch {
            for b in 0..7u32 {
                out.push(v ^ (1 << b));
            }
        }
        Ok(())
    }
    for driver in ["hash", "list"] {
        let mut profiles = Vec::new();
        for &(bloom, nw, depth) in &[(0usize, 1usize, 0usize), (10, 1, 0), (10, 4, 4)] {
            let t = tmpdir(&format!("dedup_bfs_{driver}_b{bloom}_w{nw}_d{depth}"));
            let r = open_cell(t.path(), bloom, nw, depth);
            let stats = match driver {
                "hash" => bfs::bfs_hash_batched(&r, "cube", &[0u64], gen).unwrap(),
                _ => bfs::bfs_list_batched(&r, "cube", &[0u64], gen).unwrap(),
            };
            if bloom > 0 {
                let snap = r.dedup_snapshot();
                assert!(snap.probes > 0, "{driver}: BFS never touched the filter: {snap:?}");
            }
            profiles.push((bloom, nw, depth, stats));
        }
        for (bloom, nw, depth, s) in &profiles[1..] {
            assert_eq!(
                s, &profiles[0].3,
                "{driver} BFS diverged at bloom={bloom} workers={nw} depth={depth}"
            );
        }
    }
}
