//! Integration: the `roomy` CLI binary end-to-end (subcommand parsing,
//! validation paths, exit codes).

use std::process::Command;

fn roomy_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_roomy"))
}

fn tmp_root(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("roomy-cli-{tag}-{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn help_prints_usage() {
    let out = roomy_bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pancake"), "{text}");
    assert!(text.contains("rubik"), "{text}");
}

#[test]
fn unknown_subcommand_exits_2() {
    let out = roomy_bin().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn pancake_small_validates_and_exits_0() {
    let root = tmp_root("pk");
    let out = roomy_bin()
        .args(["pancake", "--n", "6", "--structure", "hash", "--workers", "2",
               "--accel", "rust", "--root", &root])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stdout: {text}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("validation vs known f(6)=7: OK"), "{text}");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn pancake_rejects_bad_args() {
    for args in [
        vec!["pancake", "--n", "99"],
        vec!["pancake", "--structure", "btree"],
        vec!["pancake", "--accel", "gpu"],
    ] {
        let out = roomy_bin().args(&args).output().unwrap();
        assert_eq!(out.status.code(), Some(1), "args {args:?} should fail");
    }
}

#[test]
fn pancake_checkpoint_dir_then_resume() {
    let root = tmp_root("pkck");
    let ckpt = tmp_root("pkck-ckpt");
    let base = [
        "pancake", "--n", "5", "--structure", "list", "--workers", "2",
        "--accel", "rust",
    ];
    let out = roomy_bin()
        .args(base)
        .args(["--root", &root, "--checkpoint-dir", &ckpt])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stdout: {text}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("checkpointing every level"), "{text}");
    assert!(text.contains("checkpoints:"), "{text}");

    // rerun with --resume: the finished checkpoint answers immediately
    // and still validates
    let root2 = tmp_root("pkck2");
    let out = roomy_bin()
        .args(base)
        .args(["--root", &root2, "--checkpoint-dir", &ckpt, "--resume"])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stdout: {text}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("resuming checkpoint"), "{text}");
    assert!(text.contains("validation vs known f(5)=5: OK"), "{text}");

    // --resume against an empty checkpoint dir is a hard error
    let empty = tmp_root("pkck-empty");
    let out = roomy_bin()
        .args(base)
        .args(["--root", &root2, "--checkpoint-dir", &empty, "--resume"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_dir_all(&root2).ok();
    std::fs::remove_dir_all(&ckpt).ok();
    std::fs::remove_dir_all(&empty).ok();
}

#[test]
fn demo_runs_clean() {
    let root = tmp_root("demo");
    let out = roomy_bin()
        .args(["demo", "--workers", "2", "--root", &root])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("sum of squares 1..10 = 385"), "{text}");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn kernels_reports_artifacts_when_present() {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        return;
    }
    let out = roomy_bin().arg("kernels").output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("hash_partition xla==rust over 8192 words: OK"), "{text}");
    assert!(text.contains("prefix_scan   xla==rust over 8192 i64:   OK"), "{text}");
}
