//! Durable checkpoint/restart (`storage::checkpoint`): atomic snapshots
//! of structure sets, digest-validated restore, crash-window recovery.
//!
//! Covers the subsystem end to end: full-fidelity roundtrips of all five
//! structures, corruption detection (a flipped byte in any bucket file or
//! manifest field is a typed `RoomyError::Checkpoint` at restore),
//! interrupted saves (staging present → previous checkpoint restores
//! cleanly; commit window → `.prev` fallback), hardlink-vs-copy
//! accounting, and survival across cluster bring-up over the same root.

mod common;

use std::path::Path;

use common::{roomy, roomy_with};
use roomy::storage::checkpoint::Checkpointable;
use roomy::testutil::Rng;
use roomy::{Roomy, RoomyConfig, RoomyError};

/// Recursively collect plain files under `dir` (absolute paths).
fn files_in(dir: &Path) -> Vec<std::path::PathBuf> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else { return out };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            out.extend(files_in(&p));
        } else {
            out.push(p);
        }
    }
    out.sort();
    out
}

#[test]
fn roundtrip_all_five_structures() {
    let (t, r) = roomy("ckpt_rt");
    let list = r.list::<u64>("lst").unwrap();
    for v in 0..500u64 {
        list.add(&(v % 300)).unwrap();
    }
    list.sync().unwrap();
    list.remove_dupes().unwrap();

    let arr = r.array::<u32>("arr", 257, 7).unwrap();
    let set_fn = arr.register_update(|i, v: &mut u32, p: &u32| *v = *p + i as u32);
    for i in 0..257 {
        arr.update(i, &1000u32, set_fn).unwrap();
    }
    arr.sync().unwrap();

    let bits = r.bit_array("bits", 1000, 2).unwrap();
    let mark = bits.register_update(|i, _cur, _p: &()| (i % 4) as u8);
    for i in 0..1000 {
        bits.update(i, &(), mark).unwrap();
    }
    bits.sync().unwrap();

    let ht = r.hash_table::<u64, u64>("ht").unwrap();
    for k in 0..400u64 {
        ht.insert(&k, &(k * k)).unwrap();
    }
    ht.sync().unwrap();

    let set = r.set::<u64>("set").unwrap();
    for v in 0..300u64 {
        set.add(&(v % 200)).unwrap();
    }
    set.sync().unwrap();

    let mgr = r.checkpoints().unwrap();
    mgr.save(
        "snap",
        &[&list as &dyn Checkpointable, &arr, &bits, &ht, &set],
        &[("note", "all five structures")],
    )
    .unwrap();
    drop((list, arr, bits, ht, set));
    drop(r);

    // Fresh session over the same root: restore and verify every value.
    let r2 = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
    let mgr2 = r2.checkpoints().unwrap();
    let res = mgr2.restore("snap").unwrap();
    assert_eq!(res.app("note"), Some("all five structures"));

    let list = r2.restored_list::<u64>(&res, "lst").unwrap();
    assert_eq!(list.size(), 300);
    assert!(list.is_sorted());
    let mut got = list.collect().unwrap();
    got.sort();
    assert_eq!(got, (0..300u64).collect::<Vec<_>>());

    let arr = r2.restored_array::<u32>(&res, "arr").unwrap();
    assert_eq!(arr.len(), 257);
    for i in [0u64, 100, 256] {
        assert_eq!(arr.fetch(i).unwrap(), 1000 + i as u32);
    }

    let bits = r2.restored_bit_array(&res, "bits").unwrap();
    assert_eq!(bits.len(), 1000);
    assert_eq!(bits.bits(), 2);
    assert_eq!(bits.count_value(0), 250);
    assert_eq!(bits.count_value(3), 250);
    assert_eq!(bits.fetch(5).unwrap(), 1);

    let ht = r2.restored_hash_table::<u64, u64>(&res, "ht").unwrap();
    assert_eq!(ht.size(), 400);
    assert_eq!(ht.fetch(&17).unwrap(), Some(289));

    let set = r2.restored_set::<u64>(&res, "set").unwrap();
    assert_eq!(set.size(), 200);
    assert!(set.contains(&199).unwrap());
    assert!(!set.contains(&200).unwrap());
}

#[test]
fn restored_structures_keep_working() {
    let (t, r) = roomy("ckpt_alive");
    let list = r.list::<u64>("l").unwrap();
    for v in 0..100u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[]).unwrap();
    drop(list);
    drop(r);

    let r2 = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
    let mgr2 = r2.checkpoints().unwrap();
    let res = mgr2.restore("s").unwrap();
    let list = r2.restored_list::<u64>(&res, "l").unwrap();
    // keep mutating after restore: appends, dedup, map/reduce
    for v in 100..150u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    assert_eq!(list.size(), 150);
    list.remove_dupes().unwrap();
    assert_eq!(list.size(), 150);
    let sum = list.reduce(|| 0u64, |a, v| a + v, |a, b| a + b).unwrap();
    assert_eq!(sum, (0..150u64).sum::<u64>());
    // ...and mutations after restore must never reach back into the
    // committed checkpoint (lists are copied, never hardlinked): a second
    // restore re-validates every digest against the original bytes.
    r2.release_name("l");
    drop(list);
    let res2 = mgr2.restore("s").unwrap();
    assert_eq!(
        res2.manifest().file_digests(),
        res.manifest().file_digests(),
        "checkpoint bytes changed after post-restore mutations"
    );
    let list = r2.restored_list::<u64>(&res2, "l").unwrap();
    assert_eq!(list.size(), 100, "second restore returns the original state");
}

#[test]
fn pending_ops_refused() {
    let (_t, r) = roomy("ckpt_pending");
    let list = r.list::<u64>("l").unwrap();
    list.add(&1).unwrap(); // staged, not synced
    let mgr = r.checkpoints().unwrap();
    let err = mgr.save("s", &[&list as &dyn Checkpointable], &[]).unwrap_err();
    match err {
        RoomyError::Checkpoint(msg) => assert!(msg.contains("pending"), "{msg}"),
        other => panic!("expected Checkpoint error, got {other:?}"),
    }
    // after sync it goes through
    list.sync().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[]).unwrap();
}

#[test]
fn prop_flipped_byte_in_any_bucket_file_caught_at_restore() {
    let (t, r) = roomy("ckpt_fuzz");
    let list = r.list::<u64>("fuzzlist").unwrap();
    for v in 0..2_000u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    let ht = r.hash_table::<u64, u32>("fuzzht").unwrap();
    for k in 0..1_000u64 {
        ht.insert(&k, &(k as u32)).unwrap();
    }
    ht.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("fz", &[&list as &dyn Checkpointable, &ht], &[]).unwrap();

    let ckpt_dir = mgr.root().join("fz");
    let victims: Vec<_> = files_in(&ckpt_dir)
        .into_iter()
        .filter(|p| p.file_name().is_some_and(|f| f != std::ffi::OsStr::new("MANIFEST")))
        .collect();
    assert!(!victims.is_empty(), "checkpoint holds no bucket files?");

    let mut rng = Rng::new(0xC0FFEE);
    for round in 0..20 {
        // flip one random byte in one random snapshotted bucket file
        let victim = &victims[rng.range(0, victims.len())];
        let mut bytes = std::fs::read(victim).unwrap();
        if bytes.is_empty() {
            continue;
        }
        let pos = rng.range(0, bytes.len());
        let orig = bytes[pos];
        bytes[pos] ^= 1u8 << rng.range(0, 8);
        std::fs::write(victim, &bytes).unwrap();

        let err = mgr.restore("fz");
        match err {
            Err(RoomyError::Checkpoint(msg)) => {
                assert!(msg.contains("digest mismatch"), "round {round}: {msg}")
            }
            other => panic!("round {round}: corruption undetected: {other:?}"),
        }

        // undo the flip; the checkpoint must validate again
        bytes[pos] = orig;
        std::fs::write(victim, &bytes).unwrap();
    }
    drop((list, ht));
    drop(r);
    let r2 = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
    let mgr2 = r2.checkpoints().unwrap();
    mgr2.restore("fz").unwrap();
}

#[test]
fn prop_flipped_byte_in_manifest_caught() {
    let (_t, r) = roomy("ckpt_fuzz_manifest");
    let list = r.list::<u64>("l").unwrap();
    for v in 0..500u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("m", &[&list as &dyn Checkpointable], &[("lev", "3")]).unwrap();

    let mpath = mgr.root().join("m").join("MANIFEST");
    let orig = std::fs::read(&mpath).unwrap();
    let pristine = mgr.load_manifest("m").unwrap();
    let mut rng = Rng::new(0xBADC0DE);
    for round in 0..30 {
        let mut bytes = orig.clone();
        // exclude the final trailing newline: it sits outside every
        // digested field (flipping it to another whitespace is a no-op)
        let pos = rng.range(0, bytes.len() - 1);
        bytes[pos] ^= 1u8 << rng.range(0, 8);
        if bytes == orig {
            continue;
        }
        std::fs::write(&mpath, &bytes).unwrap();
        match mgr.restore("m") {
            // real corruption: the typed error
            Err(RoomyError::Checkpoint(_)) => {}
            // value-preserving flip (e.g. hex case in the digest line):
            // legal only if it decodes to the identical manifest
            Ok(res) => assert_eq!(
                res.manifest(),
                &pristine,
                "round {round} (flip at {pos}): decoded to different content"
            ),
            other => panic!("round {round} (flip at {pos}): undetected: {other:?}"),
        }
    }
    std::fs::write(&mpath, &orig).unwrap();
    mgr.load_manifest("m").unwrap();
}

#[test]
fn interrupted_save_previous_checkpoint_restores_cleanly() {
    let (_t, r) = roomy("ckpt_staging");
    let list = r.list::<u64>("l").unwrap();
    for v in 0..100u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[("gen", "1")]).unwrap();

    // simulate a crash mid-save: a half-written staging dir appears
    let staging = mgr.root().join("s.staging");
    std::fs::create_dir_all(staging.join("node0/rl_l")).unwrap();
    std::fs::write(staging.join("node0/rl_l/s0.dat"), b"torn half-written").unwrap();
    // no MANIFEST in staging — it is never eligible for restore

    let res = mgr.restore("s").unwrap();
    assert_eq!(res.app("gen"), Some("1"), "previous checkpoint must restore");
    let restored = r
        .restored_list::<u64>(&res, "l")
        .map(|l| l.size());
    // name still claimed by the live handle in this session
    assert!(restored.is_err());
    r.release_name("l");
    drop(list);
    let list = r.restored_list::<u64>(&res, "l").unwrap();
    assert_eq!(list.size(), 100);

    // the next save clears the stale staging dir
    mgr.save("s", &[&list as &dyn Checkpointable], &[("gen", "2")]).unwrap();
    assert!(!staging.exists(), "stale staging must be cleaned by the next save");
    assert_eq!(mgr.load_manifest("s").unwrap().app("gen"), Some("2"));
}

#[test]
fn crash_in_commit_window_falls_back_to_prev() {
    let (_t, r) = roomy("ckpt_prev");
    let list = r.list::<u64>("l").unwrap();
    for v in 0..64u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[("gen", "1")]).unwrap();

    // simulate the commit window: live renamed to .prev, new live not yet
    // in place (crash between steps 2 and 3)
    std::fs::rename(mgr.root().join("s"), mgr.root().join("s.prev")).unwrap();
    assert!(mgr.exists("s"), "prev survivor must count as restorable");
    let res = mgr.restore("s").unwrap();
    assert_eq!(res.app("gen"), Some("1"));

    // the next save commits a fresh live dir and drops the survivor
    r.release_name("l");
    drop(list);
    let list = r.restored_list::<u64>(&res, "l").unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[("gen", "2")]).unwrap();
    assert!(mgr.root().join("s").is_dir());
    assert!(!mgr.root().join("s.prev").exists());
    assert_eq!(mgr.load_manifest("s").unwrap().app("gen"), Some("2"));
}

#[test]
fn checkpoints_survive_cluster_bringup_and_geometry_is_enforced() {
    let (t, r) = roomy("ckpt_survive");
    let list = r.list::<u64>("l").unwrap();
    list.add(&42).unwrap();
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[]).unwrap();
    drop(list);
    drop(r);

    // same root, same geometry: bring-up must not purge checkpoints
    let r2 = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
    let mgr2 = r2.checkpoints().unwrap();
    assert!(mgr2.exists("s"), "checkpoint lost across bring-up");
    mgr2.restore("s").unwrap();
    drop(r2);

    // different geometry: typed refusal
    let mut cfg = RoomyConfig::for_testing(t.path());
    cfg.workers = 2;
    cfg.buckets_per_worker = 1;
    let r3 = Roomy::open(cfg).unwrap();
    let mgr3 = r3.checkpoints().unwrap();
    match mgr3.restore("s") {
        Err(RoomyError::Checkpoint(msg)) => assert!(msg.contains("cluster"), "{msg}"),
        other => panic!("geometry mismatch undetected: {other:?}"),
    }
}

#[test]
fn hardlink_and_copy_paths_both_exercised_and_stats_counted() {
    let (_t, r) = roomy("ckpt_stats");
    let list = r.list::<u64>("l").unwrap(); // appendable → copied
    for v in 0..1_000u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();
    let ht = r.hash_table::<u64, u32>("h").unwrap(); // rename-only → linked
    for k in 0..1_000u64 {
        ht.insert(&k, &1).unwrap();
    }
    ht.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    let report = mgr
        .save("s", &[&list as &dyn Checkpointable, &ht], &[])
        .unwrap();
    assert!(report.files > 0 && report.bytes > 0);
    assert!(report.copied > 0, "list shards must be copied");
    // default checkpoint root shares the node filesystem → links succeed
    assert!(report.linked > 0, "hash-table buckets should hardlink");
    let snap = mgr.stats().snapshot();
    assert_eq!(snap.saves, 1);
    assert_eq!(snap.files_copied + snap.files_linked, report.files);

    // restore counts too
    r.release_name("l");
    r.release_name("h");
    drop((list, ht));
    let res = mgr.restore("s").unwrap();
    let snap = mgr.stats().snapshot();
    assert_eq!(snap.restores, 1);
    assert!(snap.restore_ns > 0);
    let list = r.restored_list::<u64>(&res, "l").unwrap();
    assert_eq!(list.size(), 1_000);
}

#[test]
fn type_mismatches_rejected_at_reopen() {
    let (_t, r) = roomy("ckpt_types");
    let list = r.list::<u64>("l").unwrap();
    list.add(&1).unwrap();
    list.sync().unwrap();
    let ht = r.hash_table::<u64, u32>("h").unwrap();
    ht.insert(&1, &2).unwrap();
    ht.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable, &ht], &[]).unwrap();
    r.release_name("l");
    r.release_name("h");
    drop((list, ht));

    let res = mgr.restore("s").unwrap();
    // wrong element width
    assert!(r.restored_list::<u32>(&res, "l").is_err());
    // wrong kind
    assert!(r.restored_set::<u64>(&res, "l").is_err());
    // wrong key/value split
    assert!(r.restored_hash_table::<u32, u64>(&res, "h").is_err());
    // unknown name
    assert!(r.restored_list::<u64>(&res, "nope").is_err());
    // correct types go through
    let _l = r.restored_list::<u64>(&res, "l").unwrap();
    let _h = r.restored_hash_table::<u64, u32>(&res, "h").unwrap();
}

#[test]
fn checkpoint_dir_override_is_honored() {
    let t = roomy::testutil::tmpdir("ckpt_override");
    let elsewhere = t.path().join("my-checkpoints");
    let (_t2, r) = roomy_with("ckpt_override_inst", |cfg| {
        cfg.checkpoint_dir = Some(elsewhere.clone());
    });
    let list = r.list::<u64>("l").unwrap();
    list.add(&1).unwrap();
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    assert_eq!(mgr.root(), elsewhere.as_path());
    mgr.save("s", &[&list as &dyn Checkpointable], &[]).unwrap();
    assert!(elsewhere.join("s").join("MANIFEST").is_file());
}

#[test]
fn remove_deletes_all_variants() {
    let (_t, r) = roomy("ckpt_remove");
    let list = r.list::<u64>("l").unwrap();
    list.add(&1).unwrap();
    list.sync().unwrap();
    let mgr = r.checkpoints().unwrap();
    mgr.save("s", &[&list as &dyn Checkpointable], &[]).unwrap();
    std::fs::create_dir_all(mgr.root().join("s.staging")).unwrap();
    std::fs::create_dir_all(mgr.root().join("s.prev")).unwrap();
    mgr.remove("s").unwrap();
    assert!(!mgr.exists("s"));
    assert!(!mgr.root().join("s.staging").exists());
    assert!(!mgr.root().join("s.prev").exists());
}

/// Differential checkpoints, cheap half: re-saving unchanged structures
/// must reuse the prior manifest's digests for every hardlinkable file
/// (a metadata stat instead of a full re-read — observable as fewer read
/// bytes), while list shards (copied) and genuinely changed buckets are
/// always re-digested. A restore after a reuse-heavy save must still
/// validate and reproduce the data exactly.
#[test]
fn unchanged_files_reuse_prior_digests() {
    let (t, r) = roomy("ckpt_reuse");
    let arr = r.array::<u64>("arr", 500, 0).unwrap();
    let setv = arr.register_update(|i, v: &mut u64, p: &u64| *v = *p ^ i);
    for i in 0..500 {
        arr.update(i, &0xABCDu64, setv).unwrap();
    }
    arr.sync().unwrap();
    let list = r.list::<u64>("lst").unwrap();
    for v in 0..400u64 {
        list.add(&v).unwrap();
    }
    list.sync().unwrap();

    let mgr = r.checkpoints().unwrap();
    let io0 = r.io_snapshot();
    let rep1 = mgr.save("ck", &[&arr as &dyn Checkpointable, &list], &[]).unwrap();
    let read1 = r.io_snapshot().delta(&io0).bytes_read;
    assert_eq!(rep1.reused, 0, "first save has no prior manifest to reuse");
    assert!(rep1.linked > 0, "array buckets must hardlink");

    // Save again with nothing changed: every hardlinked file reuses its
    // digest; only the list shards are re-read.
    let io1 = r.io_snapshot();
    let rep2 = mgr.save("ck", &[&arr as &dyn Checkpointable, &list], &[]).unwrap();
    let read2 = r.io_snapshot().delta(&io1).bytes_read;
    assert_eq!(rep2.reused, rep2.linked, "all unchanged hardlinks must reuse");
    assert!(rep2.reused > 0);
    assert!(
        read2 < read1,
        "digest reuse must cut save read I/O ({read2} !< {read1})"
    );
    let stats = mgr.stats().snapshot();
    assert_eq!(stats.files_reused, rep2.reused);
    assert!(stats.bytes_reused > 0);
    // both manifests describe identical payloads
    let m1 = mgr.load_manifest("ck").unwrap();
    assert_eq!(m1.file_digests().len() as u64, rep2.files);

    // Mutate the array: its buckets get new inodes, so the next save
    // re-digests them (no stale digests), while nothing else regresses.
    arr.map_update(|_i, v| *v = v.wrapping_add(1)).unwrap();
    let rep3 = mgr.save("ck", &[&arr as &dyn Checkpointable, &list], &[]).unwrap();
    assert_eq!(rep3.reused, 0, "rewritten buckets must not reuse digests");

    // The reuse-written checkpoint restores and validates end to end.
    drop((arr, list));
    drop(r);
    let mut cfg = RoomyConfig::for_testing(t.path());
    cfg.workers = 4;
    cfg.buckets_per_worker = 2;
    let r2 = Roomy::open(cfg).unwrap();
    let mgr2 = r2.checkpoints().unwrap();
    let restored = mgr2.restore("ck").unwrap();
    let arr2 = r2.restored_array::<u64>(&restored, "arr").unwrap();
    let check = arr2
        .reduce(|| 0u64, |acc, i, v| acc ^ (v.wrapping_mul(i + 1)), |a, b| a ^ b)
        .unwrap();
    let expect = (0..500u64).fold(0u64, |acc, i| {
        acc ^ ((0xABCDu64 ^ i).wrapping_add(1).wrapping_mul(i + 1))
    });
    assert_eq!(check, expect, "restored array content diverged");
    let lst2 = r2.restored_list::<u64>(&restored, "lst").unwrap();
    assert_eq!(lst2.size(), 400);
}
