//! Integration: the paper §3 constructs composed end-to-end, including the
//! accel (XLA) paths when artifacts are present.

mod common;

use common::{artifacts_present, roomy, roomy_with};
use roomy::accel::Accel;
use roomy::constructs::{chainred, mapreduce, pairred, prefix};
use std::sync::Arc;

fn accel_xla() -> Option<Accel> {
    if artifacts_present() {
        roomy::runtime::Engine::load("artifacts").ok().map(|e| Accel::xla(Arc::new(e)))
    } else {
        None
    }
}

#[test]
fn chain_then_prefix_compose() {
    let (_t, r) = roomy("ic_compose");
    let n = 300u64;
    let ra = r.array::<i64>("a", n, 0).unwrap();
    ra.map_update(|_i, v| *v = 1).unwrap();
    // chain reduce: a = [1, 2, 2, 2, ...]
    chainred::chain_reduce(&ra, |a, b| a + b).unwrap();
    // prefix sum over that: 1, 3, 5, 7, ...
    prefix::parallel_prefix(&ra, |a, b| a.wrapping_add(*b)).unwrap();
    assert_eq!(ra.fetch(0).unwrap(), 1);
    for i in 1..n {
        assert_eq!(ra.fetch(i).unwrap(), (2 * i + 1) as i64, "i={i}");
    }
}

#[test]
fn prefix_log_rounds_vs_accel_single_pass() {
    // the E7 ablation shape: both implementations, same bits
    let (_t, r1) = roomy("ic_logrounds");
    let (_t2, r2) = roomy("ic_scanpass");
    let n = 5000u64;
    let vals: Vec<i64> = (0..n).map(|i| ((i * 37) % 101) as i64 - 50).collect();

    let ra1 = r1.array::<i64>("a", n, 0).unwrap();
    let v1 = vals.clone();
    ra1.map_update(move |i, v| *v = v1[i as usize]).unwrap();
    prefix::parallel_prefix(&ra1, |a, b| a.wrapping_add(*b)).unwrap();

    let ra2 = r2.array::<i64>("a", n, 0).unwrap();
    let v2 = vals.clone();
    ra2.map_update(move |i, v| *v = v2[i as usize]).unwrap();
    prefix::prefix_scan_array(&ra2, &Accel::rust()).unwrap();

    for i in (0..n).step_by(379) {
        assert_eq!(ra1.fetch(i).unwrap(), ra2.fetch(i).unwrap(), "i={i}");
    }
    assert_eq!(ra1.fetch(n - 1).unwrap(), ra2.fetch(n - 1).unwrap());
}

#[test]
fn prefix_accel_xla_path() {
    let Some(xla) = accel_xla() else { return };
    let (_t, r) = roomy("ic_prefix_xla");
    let n = 9000u64; // spans multiple SCAN_BATCHes and buckets
    let ra = r.array::<i64>("a", n, 0).unwrap();
    ra.map_update(|i, v| *v = (i as i64 % 7) - 3).unwrap();
    prefix::prefix_scan_array(&ra, &xla).unwrap();
    let mut acc = 0i64;
    for i in 0..n {
        acc += (i as i64 % 7) - 3;
        if i % 1234 == 0 || i == n - 1 {
            assert_eq!(ra.fetch(i).unwrap(), acc, "i={i}");
        }
    }
}

#[test]
fn sum_of_squares_all_backends_agree() {
    let (_t, r) = roomy("ic_sumsq");
    let l = r.list::<i64>("l").unwrap();
    for v in 0..20_000i64 {
        l.add(&(v % 2003 - 1000)).unwrap();
    }
    l.sync().unwrap();
    let plain = mapreduce::sum_of_squares(&l).unwrap();
    let rust_batched = mapreduce::sum_of_squares_accel(&l, &Accel::rust()).unwrap();
    assert_eq!(plain, rust_batched);
    if let Some(xla) = accel_xla() {
        let xla_batched = mapreduce::sum_of_squares_accel(&l, &xla).unwrap();
        assert_eq!(plain, xla_batched);
    }
}

#[test]
fn pair_reduction_distance_matrix_into_hashtable() {
    // realistic pair-reduction use: all-pairs |a_i - a_j| below threshold
    let (_t, r) = roomy("ic_pairs");
    let n = 20u64;
    let ra = r.array::<i64>("pts", n, 0).unwrap();
    ra.map_update(|i, v| *v = (i as i64 * i as i64) % 31).unwrap();
    let close = r.list::<(u64, u64)>("close").unwrap();
    let close2 = close.clone();
    pairred::pair_reduction(&ra, move |j, inner, i, outer| {
        if i != j && (inner - outer).abs() <= 2 {
            close2.add(&(i, j)).unwrap();
        }
    })
    .unwrap();
    close.sync().unwrap();
    // symmetric relation: (i,j) present iff (j,i) present
    let pairs: std::collections::HashSet<(u64, u64)> =
        close.collect().unwrap().into_iter().collect();
    for &(i, j) in &pairs {
        assert!(pairs.contains(&(j, i)), "asymmetric pair ({i},{j})");
    }
    assert!(!pairs.is_empty());
}

#[test]
fn map_example_then_reduce_over_hashtable() {
    let (_t, r) = roomy_with("ic_mapred", |c| c.workers = 2);
    let ra = r.array::<u32>("a", 500, 0).unwrap();
    ra.map_update(|i, v| *v = i as u32).unwrap();
    let rht = r.hash_table::<u64, u32>("h").unwrap();
    mapreduce::array_to_hashtable(&ra, &rht).unwrap();
    assert_eq!(rht.size(), 500);
    let sum = rht
        .reduce(|| 0u64, |a, _k, v| a + *v as u64, |a, b| a + b)
        .unwrap();
    assert_eq!(sum, (0..500).sum::<u64>());
}

#[test]
fn k_largest_across_shards() {
    let (_t, r) = roomy("ic_klargest");
    let l = r.list::<u64>("l").unwrap();
    for v in 0..5000u64 {
        l.add(&(v * 2654435761 % 100_000)).unwrap();
    }
    l.sync().unwrap();
    let top = mapreduce::k_largest(&l, 5).unwrap();
    let mut all = l.collect().unwrap();
    all.sort_unstable_by(|a, b| b.cmp(a));
    assert_eq!(top, all[..5].to_vec());
}
