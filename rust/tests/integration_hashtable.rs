//! Integration: RoomyHashTable under realistic workloads — word-count,
//! mixed op streams, predicate maintenance, spill-heavy configs.

mod common;

use common::{roomy, roomy_with};

#[test]
fn word_count_style_aggregation() {
    let (_t, r) = roomy("ih_wordcount");
    let ht = r.hash_table::<u64, u64>("wc").unwrap();
    let bump = ht.register_update(|_k, cur: Option<&u64>, inc: &u64| {
        Some(cur.copied().unwrap_or(0) + inc)
    });
    // zipf-ish synthetic stream: key k appears roughly 1000/k times
    let mut expected = std::collections::HashMap::new();
    for k in 1..=50u64 {
        let reps = 1000 / k;
        for _ in 0..reps {
            ht.update(&k, &1u64, bump).unwrap();
        }
        expected.insert(k, reps);
    }
    ht.sync().unwrap();
    assert_eq!(ht.size(), 50);
    for (k, v) in expected {
        assert_eq!(ht.fetch(&k).unwrap(), Some(v), "key {k}");
    }
}

#[test]
fn mixed_inserts_removes_updates_interleaved() {
    let (_t, r) = roomy("ih_mixed");
    let ht = r.hash_table::<u32, u32>("m").unwrap();
    let double_or_init =
        ht.register_update(|_k, cur: Option<&u32>, _p: &()| Some(cur.copied().unwrap_or(1) * 2));
    // FIFO per key: insert 5 -> update(x2) -> remove -> update (re-init 1 -> x2)
    ht.insert(&9, &5).unwrap();
    ht.update(&9, &(), double_or_init).unwrap();
    ht.remove(&9).unwrap();
    ht.update(&9, &(), double_or_init).unwrap();
    ht.sync().unwrap();
    assert_eq!(ht.fetch(&9).unwrap(), Some(2));
    assert_eq!(ht.size(), 1);
}

#[test]
fn spill_heavy_config_many_keys() {
    let (_t, r) = roomy_with("ih_spill", |c| {
        c.op_buffer_bytes = 128;
        c.workers = 3;
        c.buckets_per_worker = 2;
    });
    let ht = r.hash_table::<u64, u64>("big").unwrap();
    let n = 20_000u64;
    for k in 0..n {
        ht.insert(&k, &(k ^ 0xABCD)).unwrap();
    }
    ht.sync().unwrap();
    assert_eq!(ht.size(), n);
    // reduce validates every pair
    let bad = ht
        .reduce(
            || 0u64,
            |acc, k, v| acc + u64::from(*v != (k ^ 0xABCD)),
            |a, b| a + b,
        )
        .unwrap();
    assert_eq!(bad, 0);
}

#[test]
fn access_emits_to_list_join_pattern() {
    // relational-join-ish: probe table with a stream of keys; hits emit
    let (_t, r) = roomy("ih_join");
    let ht = r.hash_table::<u64, u64>("dim").unwrap();
    for k in (0..100u64).step_by(2) {
        ht.insert(&k, &(k * 10)).unwrap();
    }
    ht.sync().unwrap();
    let hits = r.list::<(u64, u64)>("hits").unwrap();
    let hits2 = hits.clone();
    let probe = ht.register_access(move |k: &u64, v: &u64, _p: &()| {
        hits2.add(&(*k, *v)).unwrap();
    });
    for k in 0..100u64 {
        ht.access(&k, &(), probe).unwrap(); // half miss
    }
    ht.sync().unwrap();
    hits.sync().unwrap();
    assert_eq!(hits.size(), 50);
}

#[test]
fn level_table_pattern_insert_if_absent() {
    // the BFS hash-variant invariant: first writer wins
    let (_t, r) = roomy("ih_levels");
    let ht = r.hash_table::<u64, u32>("lv").unwrap();
    let visit = ht.register_update(|_k, cur: Option<&u32>, lvl: &u32| {
        Some(cur.copied().unwrap_or(*lvl))
    });
    for k in 0..100u64 {
        ht.update(&k, &1u32, visit).unwrap();
    }
    ht.sync().unwrap();
    for k in 0..100u64 {
        ht.update(&k, &2u32, visit).unwrap(); // must not overwrite
    }
    ht.sync().unwrap();
    let later = ht.register_predicate(|_k, v| *v == 2).unwrap();
    assert_eq!(ht.predicate_count(later), 0);
    assert_eq!(ht.size(), 100);
}

#[test]
fn reduce_finds_extremes() {
    let (_t, r) = roomy("ih_reduce");
    let ht = r.hash_table::<u32, i64>("x").unwrap();
    for k in 0..1000u32 {
        ht.insert(&k, &((k as i64 - 500) * 3)).unwrap();
    }
    ht.sync().unwrap();
    let (mn, mx) = ht
        .reduce(
            || (i64::MAX, i64::MIN),
            |(mn, mx), _k, v| (mn.min(*v), mx.max(*v)),
            |a, b| (a.0.min(b.0), a.1.max(b.1)),
        )
        .unwrap();
    assert_eq!(mn, -1500);
    assert_eq!(mx, 499 * 3 - 1500 + 1500 - 1500 + 1500); // (999-500)*3
    assert_eq!(mx, 1497);
}

#[test]
fn tuple_keys_and_unit_values() {
    // a set-like table keyed by pairs
    let (_t, r) = roomy("ih_tuple");
    let ht = r.hash_table::<(u32, u32), ()>("edges").unwrap();
    for i in 0..50u32 {
        ht.insert(&(i, i + 1), &()).unwrap();
    }
    ht.sync().unwrap();
    assert_eq!(ht.size(), 50);
    assert!(ht.fetch(&(3, 4)).unwrap().is_some());
    assert!(ht.fetch(&(4, 3)).unwrap().is_none());
}
