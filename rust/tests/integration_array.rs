//! Integration: RoomyArray + RoomyBitArray across realistic configurations
//! (many workers, tiny op buffers forcing spills, throttled disks).

mod common;

use common::{roomy, roomy_with};
use std::sync::atomic::{AtomicU64, Ordering};

#[test]
fn histogram_via_delayed_updates() {
    // Classic Roomy idiom: scatter increments into a large array.
    let (_t, r) = roomy("ia_hist");
    let n = 1024u64;
    let ra = r.array::<u64>("hist", n, 0).unwrap();
    let inc = ra.register_update(|_i, v: &mut u64, amount: &u64| *v += amount);
    // 10k updates, heavy collisions
    for i in 0..10_000u64 {
        ra.update(i % n, &1u64, inc).unwrap();
    }
    ra.sync().unwrap();
    let total = ra.reduce(|| 0u64, |a, _i, v| a + v, |a, b| a + b).unwrap();
    assert_eq!(total, 10_000);
    // the first (10_000 mod 1024) cells got one extra hit
    assert_eq!(ra.fetch(0).unwrap(), 10);
    assert_eq!(ra.fetch(1023).unwrap(), 9);
}

#[test]
fn tiny_op_buffers_force_disk_spill_and_stay_correct() {
    let (_t, r) = roomy_with("ia_spill", |c| {
        c.op_buffer_bytes = 64; // absurdly small: every few ops spill
        c.workers = 3;
        c.buckets_per_worker = 3;
    });
    let n = 500u64;
    let ra = r.array::<u32>("a", n, 0).unwrap();
    let set = ra.register_update(|i, v: &mut u32, p: &u32| *v = i as u32 + p);
    for i in 0..n {
        ra.update(i, &7u32, set).unwrap();
    }
    ra.sync().unwrap();
    for i in (0..n).step_by(97) {
        assert_eq!(ra.fetch(i).unwrap(), i as u32 + 7);
    }
}

#[test]
fn access_issuing_ops_on_second_structure() {
    // paper's cross-structure idiom: access fn pushes into a list
    let (_t, r) = roomy("ia_cross");
    let ra = r.array::<u64>("a", 100, 5).unwrap();
    let out = r.list::<u64>("out").unwrap();
    let out2 = out.clone();
    let probe = ra.register_access(move |i, v: &u64, threshold: &u64| {
        if *v >= *threshold {
            out2.add(&i).unwrap();
        }
    });
    ra.map_update(|i, v| *v = i % 10).unwrap();
    for i in 0..100 {
        ra.access(i, &8u64, probe).unwrap();
    }
    ra.sync().unwrap();
    out.sync().unwrap();
    assert_eq!(out.size(), 20); // values 8 and 9 in each decade
}

#[test]
fn multi_sync_rounds_accumulate() {
    let (_t, r) = roomy("ia_rounds");
    let ra = r.array::<i64>("a", 64, 0).unwrap();
    let add = ra.register_update(|_i, v: &mut i64, p: &i64| *v += p);
    for round in 1..=5i64 {
        for i in 0..64u64 {
            ra.update(i, &round, add).unwrap();
        }
        ra.sync().unwrap();
    }
    assert_eq!(ra.fetch(0).unwrap(), 15);
    assert_eq!(ra.fetch(63).unwrap(), 15);
}

#[test]
fn throttled_disk_still_correct() {
    let (_t, r) = roomy_with("ia_throttle", |c| {
        // mild throttle so the test stays fast but the path is exercised
        c.disk = roomy::DiskPolicy {
            read_bps: Some(200 * 1000 * 1000),
            write_bps: Some(200 * 1000 * 1000),
            seek_us: 50,
        };
        c.workers = 2;
        c.buckets_per_worker = 2;
    });
    let ra = r.array::<u32>("a", 100, 1).unwrap();
    ra.map_update(|i, v| *v = i as u32).unwrap();
    let sum = ra.reduce(|| 0u64, |a, _i, v| a + *v as u64, |a, b| a + b).unwrap();
    assert_eq!(sum, (0..100).sum::<u64>());
    let io = r.io_snapshot();
    assert!(io.throttle_ns > 0, "throttle must have engaged");
}

#[test]
fn bitarray_two_bit_level_marks() {
    // the BFS level-marking pattern with 2-bit values
    let (_t, r) = roomy("ia_2bit");
    let ba = r.bit_array("levels", 10_000, 2).unwrap();
    let mark = ba.register_update(|_i, cur, p: &u8| if cur == 0 { *p } else { cur });
    for i in 0..10_000u64 {
        ba.update(i, &((i % 3 + 1) as u8), mark).unwrap();
    }
    ba.sync().unwrap();
    // second wave must not overwrite
    for i in 0..10_000u64 {
        ba.update(i, &3u8, mark).unwrap();
    }
    ba.sync().unwrap();
    assert_eq!(ba.count_value(0), 0);
    let c1 = ba.count_value(1);
    let c2 = ba.count_value(2);
    let c3 = ba.count_value(3);
    assert_eq!(c1 + c2 + c3, 10_000);
    assert_eq!(c1, 3334);
    assert_eq!(c2, 3333);
    assert_eq!(c3, 3333);
}

#[test]
fn map_concurrency_sees_all_workers() {
    let (_t, r) = roomy_with("ia_conc", |c| {
        c.workers = 4;
        c.buckets_per_worker = 2;
    });
    let ra = r.array::<u8>("a", 4096, 0).unwrap();
    let count = AtomicU64::new(0);
    ra.map(|_i, _v| {
        count.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(count.into_inner(), 4096);
    // every node's disk saw reads
    for io in r.cluster().per_node_io() {
        assert!(io.bytes_read > 0, "all disks stream in parallel");
    }
}

#[test]
fn predicate_counts_across_rounds() {
    let (_t, r) = roomy("ia_preds");
    let ra = r.array::<u32>("a", 200, 0).unwrap();
    let set = ra.register_update(|_i, v: &mut u32, p: &u32| *v = *p);
    let even = ra.register_predicate(|_i, v| v % 2 == 0).unwrap();
    let big = ra.register_predicate(|_i, v| *v > 100).unwrap();
    assert_eq!(ra.predicate_count(even), 200); // all zero
    assert_eq!(ra.predicate_count(big), 0);
    for i in 0..200u64 {
        ra.update(i, &(i as u32 + 1), set).unwrap();
    }
    ra.sync().unwrap();
    assert_eq!(ra.predicate_count(even), 100);
    assert_eq!(ra.predicate_count(big), 100); // 101..=200
}

#[test]
fn staged_ram_stays_bounded_by_budget() {
    // Space-limited discipline: staging RAM never exceeds
    // nbuckets * op_buffer_bytes (plus one in-flight record per bucket).
    let (_t, r) = roomy_with("ia_budget", |c| {
        c.op_buffer_bytes = 1024;
        c.workers = 2;
        c.buckets_per_worker = 2;
    });
    let ra = r.array::<u64>("a", 10_000, 0).unwrap();
    let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v += p);
    for i in 0..50_000u64 {
        ra.update(i % 10_000, &1u64, add).unwrap();
    }
    // 50k ops * 18B ≈ 900 KB total staged, but RAM must stay ~4 * 1KB
    assert!(ra.pending_bytes() > 100_000, "most ops staged");
    ra.sync().unwrap();
    let total = ra.reduce(|| 0u64, |a, _i, v| a + v, |a, b| a + b).unwrap();
    assert_eq!(total, 50_000);
}
