//! Integration: the PJRT runtime + accel layer against the real AOT
//! artifacts (skipped cleanly when `make artifacts` has not run).
//!
//! These are the cross-layer numeric contracts: every XLA entry point must
//! agree bit-for-bit with the Rust twin across batch boundaries, padding,
//! and concurrent callers.

mod common;

use common::artifacts_present;
use roomy::accel::Accel;
use roomy::apps::pancake;
use roomy::hashfn;
use roomy::runtime::{Engine, TensorBuf, BFS_BATCH, HASH_BATCH};
use roomy::testutil::Rng;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    if artifacts_present() {
        Engine::load("artifacts").ok().map(Arc::new)
    } else {
        None
    }
}

#[test]
fn manifest_covers_expected_entry_points() {
    let Some(e) = engine() else { return };
    for name in [
        "hash_partition_k1",
        "hash_partition_k2",
        "prefix_scan",
        "reduce_sumsq",
        "bfs_expand_n6",
        "bfs_expand_n8",
        "bfs_expand_n10",
        "bfs_expand_n12",
    ] {
        assert!(e.has(name), "missing artifact {name}");
    }
}

#[test]
fn raw_engine_hash_partition_bit_exact() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1);
    let words: Vec<u64> = (0..HASH_BATCH).map(|_| rng.next_u64()).collect();
    let out = e
        .run(
            "hash_partition_k1",
            vec![
                TensorBuf::u64_2d(words.clone(), HASH_BATCH, 1),
                TensorBuf::u64_1d(vec![101]),
            ],
        )
        .unwrap();
    let fp = out[0].clone().into_u64().unwrap();
    let bk = out[1].clone().into_u64().unwrap();
    for i in 0..HASH_BATCH {
        let expect = hashfn::fp_words(&[words[i]]);
        assert_eq!(fp[i], expect);
        assert_eq!(bk[i] as u32, hashfn::bucket_of(expect, 101));
    }
}

#[test]
fn raw_engine_bfs_expand_bit_exact() {
    let Some(e) = engine() else { return };
    let n = 9usize;
    let mut rng = Rng::new(2);
    let codes: Vec<u64> =
        (0..BFS_BATCH).map(|_| pancake::pack_perm(&rng.permutation(n))).collect();
    let out = e
        .run(
            "bfs_expand_n9",
            vec![TensorBuf::u64_1d(codes.clone()), TensorBuf::u64_1d(vec![32])],
        )
        .unwrap();
    let packed = out[0].clone().into_u64().unwrap();
    let fp = out[1].clone().into_u64().unwrap();
    let bucket = out[2].clone().into_u64().unwrap();
    for (b, &code) in codes.iter().enumerate() {
        for (j, k) in (2..=n as u32).enumerate() {
            let idx = b * (n - 1) + j;
            let expect = pancake::flip_packed(code, k);
            assert_eq!(packed[idx], expect, "b={b} k={k}");
            let efp = hashfn::fp_words(&[expect]);
            assert_eq!(fp[idx], efp);
            assert_eq!(bucket[idx] as u32, hashfn::bucket_of(efp, 32));
        }
    }
}

#[test]
fn accel_full_surface_xla_vs_rust() {
    let Some(e) = engine() else { return };
    let xla = Accel::xla(e);
    let rust = Accel::rust();
    let mut rng = Rng::new(3);

    // hash partition, awkward sizes
    for count in [1usize, 17, HASH_BATCH, HASH_BATCH + 1, 3 * HASH_BATCH - 5] {
        let words: Vec<u64> = (0..count).map(|_| rng.next_u64()).collect();
        assert_eq!(
            xla.hash_partition(&words, 1, 13).unwrap(),
            rust.hash_partition(&words, 1, 13).unwrap(),
            "count={count}"
        );
    }

    // scan with negative values across batch boundaries
    let x: Vec<i64> = (0..10_000).map(|_| rng.range_i64(-1_000_000, 1_000_000)).collect();
    assert_eq!(xla.prefix_scan(&x).unwrap(), rust.prefix_scan(&x).unwrap());

    // reduce with wrapping squares
    let big: Vec<i64> = (0..5000).map(|_| rng.next_u64() as i64).collect();
    assert_eq!(xla.reduce_sumsq(&big).unwrap(), rust.reduce_sumsq(&big).unwrap());

    // expansion for every AOT'd n
    for n in 6..=12usize {
        let frontier: Vec<u64> =
            (0..97).map(|_| pancake::pack_perm(&rng.permutation(n))).collect();
        let a = xla.bfs_expand(&frontier, n, 16).unwrap();
        let b = rust.bfs_expand(&frontier, n, 16).unwrap();
        assert_eq!(a.packed, b.packed, "n={n}");
        assert_eq!(a.fp, b.fp, "n={n}");
        assert_eq!(a.bucket, b.bucket, "n={n}");
    }
}

#[test]
fn engine_concurrent_mixed_kernels() {
    let Some(e) = engine() else { return };
    let accel = Accel::xla(e);
    std::thread::scope(|s| {
        for t in 0..6u64 {
            let accel = accel.clone();
            s.spawn(move || {
                let mut rng = Rng::new(100 + t);
                match t % 3 {
                    0 => {
                        let words: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
                        let (fp, _) = accel.hash_partition(&words, 1, 7).unwrap();
                        assert_eq!(fp[0], hashfn::fp_words(&[words[0]]));
                    }
                    1 => {
                        let x: Vec<i64> = (0..3000).map(|_| rng.range_i64(-5, 5)).collect();
                        let (scan, total) = accel.prefix_scan(&x).unwrap();
                        assert_eq!(*scan.last().unwrap(), total);
                    }
                    _ => {
                        let f: Vec<u64> =
                            (0..50).map(|_| pancake::pack_perm(&rng.permutation(8))).collect();
                        let exp = accel.bfs_expand(&f, 8, 9).unwrap();
                        assert_eq!(exp.packed.len(), 50 * 7);
                    }
                }
            });
        }
    });
}

#[test]
fn unknown_artifact_is_clean_error() {
    let Some(e) = engine() else { return };
    match e.run("definitely_not_real", vec![]) {
        Err(roomy::RoomyError::MissingArtifact { name }) => {
            assert_eq!(name, "definitely_not_real")
        }
        other => panic!("expected MissingArtifact, got {other:?}"),
    }
}
