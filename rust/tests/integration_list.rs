//! Integration: RoomyList — multiset semantics, set algebra at scale,
//! sort-path vs hash-path equivalence, spill-heavy staging.

mod common;

use common::{roomy, roomy_with};
use std::collections::BTreeMap;

fn multiset(l: &roomy::RoomyList<u64>) -> BTreeMap<u64, u64> {
    let mut m = BTreeMap::new();
    for v in l.collect().unwrap() {
        *m.entry(v).or_insert(0) += 1;
    }
    m
}

#[test]
fn multiset_semantics_preserved() {
    let (_t, r) = roomy("il_multi");
    let l = r.list::<u64>("l").unwrap();
    for _ in 0..3 {
        l.add(&7).unwrap();
    }
    for _ in 0..2 {
        l.add(&8).unwrap();
    }
    l.sync().unwrap();
    assert_eq!(l.size(), 5);
    let m = multiset(&l);
    assert_eq!(m[&7], 3);
    assert_eq!(m[&8], 2);
}

#[test]
fn dedup_then_readd_recounts() {
    let (_t, r) = roomy("il_readd");
    let l = r.list::<u64>("l").unwrap();
    for v in [1u64, 1, 2, 2, 3] {
        l.add(&v).unwrap();
    }
    l.sync().unwrap();
    l.remove_dupes().unwrap();
    assert_eq!(l.size(), 3);
    assert!(l.is_sorted());
    l.add(&1).unwrap();
    l.sync().unwrap();
    assert!(!l.is_sorted(), "append invalidates sortedness");
    assert_eq!(l.size(), 4);
    l.remove_dupes().unwrap();
    assert_eq!(l.size(), 3);
}

#[test]
fn set_algebra_at_scale_hash_vs_sort_paths_agree() {
    // Same workload under the hash-set path and the forced sort-merge
    // path must produce identical results.
    let run = |tag: &str, budget: usize| -> Vec<u64> {
        let (_t, r) = roomy_with(tag, |c| c.ram_budget_bytes = budget);
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..5000u64 {
            a.add(&(v % 3000)).unwrap(); // dups beyond 2000
        }
        for v in (0..3000u64).step_by(3) {
            b.add(&v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.remove_all(&b).unwrap();
        let mut v = a.collect().unwrap();
        v.sort();
        v
    };
    let fast = run("il_scale_hash", 64 * 1024 * 1024);
    let slow = run("il_scale_sort", 1);
    assert_eq!(fast, slow);
    // sanity: no multiples of 3 below 3000 remain
    assert!(fast.iter().all(|v| v % 3 != 0));
}

#[test]
fn paper_intersection_workflow_end_to_end() {
    let (_t, r) = roomy("il_paperflow");
    // The full §3 set-ops fragment: build two multisets, make them sets,
    // union / difference / intersection.
    let a = r.list::<u64>("A").unwrap();
    let b = r.list::<u64>("B").unwrap();
    for v in 0..2000u64 {
        a.add(&(v % 1200)).unwrap();
        b.add(&(v % 800 + 600)).unwrap();
    }
    a.sync().unwrap();
    b.sync().unwrap();
    roomy::constructs::setops::to_set(&a).unwrap(); // A = 0..1200
    roomy::constructs::setops::to_set(&b).unwrap(); // B = 600..1400
    let c = roomy::constructs::setops::intersection(&r, "C", &a, &b).unwrap();
    assert_eq!(c.size(), 600); // 600..1200
    let vals = c.collect().unwrap();
    assert!(vals.iter().all(|&v| (600..1200).contains(&v)));
}

#[test]
fn remove_then_add_next_sync_independent() {
    let (_t, r) = roomy("il_order");
    let l = r.list::<u64>("l").unwrap();
    l.add(&5).unwrap();
    l.sync().unwrap();
    l.remove(&5).unwrap();
    l.sync().unwrap();
    assert_eq!(l.size(), 0);
    // removed elements can be re-added later
    l.add(&5).unwrap();
    l.sync().unwrap();
    assert_eq!(l.size(), 1);
}

#[test]
fn spilled_staging_survives_large_burst() {
    let (_t, r) = roomy_with("il_burst", |c| {
        c.op_buffer_bytes = 256;
        c.workers = 4;
        c.buckets_per_worker = 2;
    });
    let l = r.list::<(u64, u64)>("pairs").unwrap();
    let n = 30_000u64;
    for v in 0..n {
        l.add(&(v, v * 2)).unwrap();
    }
    assert!(l.pending_bytes() >= n * 16, "staged bytes tracked");
    l.sync().unwrap();
    assert_eq!(l.size(), n);
    let sum = l
        .reduce(|| 0u64, |a, (x, y)| a + x + y, |a, b| a + b)
        .unwrap();
    assert_eq!(sum, (0..n).map(|v| 3 * v).sum::<u64>());
}

#[test]
fn add_all_self_view_is_rejected_by_types_not_needed_here() {
    // add_all with an independent list of the same instance
    let (_t, r) = roomy("il_addall");
    let a = r.list::<u64>("a").unwrap();
    let b = r.list::<u64>("b").unwrap();
    for v in 0..10u64 {
        a.add(&v).unwrap();
    }
    a.sync().unwrap();
    b.add_all(&a).unwrap();
    b.add_all(&a).unwrap();
    assert_eq!(b.size(), 20);
    b.remove_dupes().unwrap();
    assert_eq!(b.size(), 10);
}

#[test]
fn shard_distribution_roughly_uniform() {
    // hash sharding spreads bytes across all node disks
    let (_t, r) = roomy_with("il_shard", |c| {
        c.workers = 4;
        c.buckets_per_worker = 4;
    });
    let l = r.list::<u64>("l").unwrap();
    for v in 0..40_000u64 {
        l.add(&v).unwrap();
    }
    l.sync().unwrap();
    let per_node = r.cluster().per_node_io();
    let writes: Vec<u64> = per_node.iter().map(|io| io.bytes_written).collect();
    let total: u64 = writes.iter().sum();
    for (i, w) in writes.iter().enumerate() {
        let share = *w as f64 / total as f64;
        assert!(
            (0.15..=0.35).contains(&share),
            "node {i} got {share:.2} of bytes (writes {writes:?})"
        );
    }
}
