//! Simulated compute cluster: `workers` nodes, each with its own local
//! disk directory; a leader (the calling thread) drives collective
//! operations.
//!
//! Roomy is bulk-synchronous: every collective (sync, map, reduce, sort,
//! shuffle) is "leader fans a job out, jobs stream their local shards,
//! barrier". Two fan-out shapes exist:
//!
//! - [`Cluster::run`] — one job per **node**, one scoped thread each
//!   (the paper's cluster topology; used where node-level concurrency is
//!   the contract, e.g. teardown);
//! - [`Cluster::run_buckets`] — one task per **bucket**, dispatched
//!   through the shared [`WorkerPool`] of
//!   [`RoomyConfig::num_workers`](crate::RoomyConfig::num_workers)
//!   threads. This is the hot path every structure collective uses:
//!   bucket tasks are independent, results come back in bucket order, and
//!   delayed ops issued inside tasks are captured/replayed
//!   deterministically (see [`crate::runtime::pool`]).
//!
//! Bucket tasks are dispatched **locality-aware**: the shared
//! [`Topology`] tags every task with its owning node, the pool keeps one
//! work queue per node with worker slots bound to home nodes, and idle
//! workers steal across nodes only as
//! [`RoomyConfig::steal_policy`](crate::RoomyConfig::steal_policy)
//! allows. [`Cluster::run_buckets_hinted`] additionally supplies the
//! per-bucket file a task will scan, which the pool turns into cross-task
//! prefetch hints on the owning node's read-ahead lane.

pub mod topology;

pub use topology::Topology;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{AutotuneMode, RoomyConfig};
use crate::error::{Result, RoomyError};
use crate::metrics::{CheckpointStats, IoSnapshot, PhaseTimes, PipelineSnapshot};
use crate::obs::{hist, trace};
use crate::runtime::autotune::Autotune;
use crate::runtime::pool::WorkerPool;
use crate::storage::NodeDisk;

/// The ephemeral scratch subtrees the cluster owns under each node's
/// `tmp/` — exactly these are purged at bring-up. Anything else (durable
/// checkpoints, user files beside the node dirs, even unrecognized
/// entries under `tmp/` itself) is never touched.
const OWNED_SCRATCH: [&str; 4] = ["tmp/capture", "tmp/sort", "tmp/pipeline", "tmp/restore"];

/// A simulated cluster: `workers` nodes, each owning one [`NodeDisk`],
/// plus the collective execution pool shared by every structure on it.
#[derive(Debug)]
pub struct Cluster {
    disks: Vec<Arc<NodeDisk>>,
    topology: Topology,
    phases: PhaseTimes,
    pool: WorkerPool,
    /// Self-tuner ([`crate::runtime::autotune`]), present only when
    /// [`RoomyConfig::autotune`] is enabled (`On` reads coarse counters,
    /// `Spans` reads histogram p95s). Runs one adaptation
    /// round at the top of every bucket collective; absent (the default)
    /// the hot path is untouched.
    autotune: Option<Autotune>,
    /// Where durable checkpoints live ([`crate::storage::checkpoint`]):
    /// a sibling of the node directories (or a user-chosen directory),
    /// deliberately outside every purged scratch subtree.
    checkpoint_root: PathBuf,
    /// Save/restore counters shared by every
    /// [`crate::storage::checkpoint::CheckpointManager`] on this cluster,
    /// so `Roomy::report()`/`report_json()` see checkpoint activity no
    /// matter which manager instance performed it.
    checkpoint_stats: Arc<CheckpointStats>,
}

impl Cluster {
    /// Bring up the cluster: create one disk directory per node under
    /// `cfg.root` (each with an I/O service when
    /// `cfg.io_pipeline_depth > 0`). The collective pool's op capture
    /// spills to per-task scratch directories under each node's
    /// `tmp/capture/` (allocated lazily on first spill, removed after
    /// replay), so in-collective op issue stays inside one **flat**
    /// `cfg.capture_spill_threshold`-byte budget of RAM per task —
    /// O(threshold), not O(ops) and not O(destination structures),
    /// however many ops a collective issues.
    pub fn new(cfg: &RoomyConfig) -> Result<Self> {
        cfg.validate()?;
        let mut disks = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let dir = cfg.root.join(format!("node{w}"));
            let disk = NodeDisk::create_with_depth(w, dir, cfg.disk, cfg.io_pipeline_depth)?;
            // The scratch subtrees this cluster owns (capture logs, sort
            // runs, pipeline staging) are strictly ephemeral. A crashed
            // process can leave them behind (Drop never ran), and scratch
            // names restart per process — purge so a rerun over the same
            // root can neither replay a dead run's ops nor trip over its
            // staging files. The purge is scoped to exactly those
            // subtrees: durable state (checkpoints/, structure dirs,
            // anything a user parked beside or under tmp/) must survive
            // a restart — that survival is what makes checkpoint/resume
            // possible at all.
            for sub in OWNED_SCRATCH {
                disk.remove_dir(sub)?;
            }
            disks.push(Arc::new(disk));
        }
        let mut pool = WorkerPool::new(cfg.num_workers);
        pool.set_capture_spill(disks.clone(), cfg.capture_spill_threshold);
        pool.set_steal_policy(cfg.steal_policy);
        let checkpoint_root = cfg
            .checkpoint_dir
            .clone()
            .unwrap_or_else(|| cfg.root.join("checkpoints"));
        let autotune = match cfg.autotune {
            AutotuneMode::Off => None,
            AutotuneMode::On => Some(Autotune::new(cfg.workers)),
            // Spans mode reads the process-global histogram bank (armed
            // by `Roomy::open` before the cluster comes up).
            AutotuneMode::Spans => {
                Some(Autotune::with_spans(cfg.workers, hist::global()))
            }
        };
        Ok(Cluster {
            disks,
            topology: Topology::new(cfg.workers, cfg.buckets_per_worker),
            phases: PhaseTimes::new(),
            pool,
            autotune,
            checkpoint_root,
            checkpoint_stats: Arc::new(CheckpointStats::new()),
        })
    }

    /// Directory durable checkpoints are written under. Never purged at
    /// bring-up; defaults to `<root>/checkpoints`, beside the node dirs.
    pub fn checkpoint_root(&self) -> &Path {
        &self.checkpoint_root
    }

    /// Cluster-wide checkpoint save/restore counters (shared by every
    /// manager created on this cluster).
    pub fn checkpoint_stats(&self) -> &Arc<CheckpointStats> {
        &self.checkpoint_stats
    }

    /// The collective execution pool (per-worker counters, width).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The self-tuning controller, when autotune is `On`.
    pub fn autotune(&self) -> Option<&Autotune> {
        self.autotune.as_ref()
    }

    /// The bucket→node ownership arithmetic of this cluster, shared with
    /// the pool's per-node work queues, the checkpoint geometry checks
    /// and the structures' hash routing.
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Number of nodes.
    pub fn nworkers(&self) -> usize {
        self.disks.len()
    }

    /// Total bucket count every structure on this cluster is split into.
    pub fn nbuckets(&self) -> u32 {
        self.topology.nbuckets()
    }

    /// The node that owns bucket `b` (round-robin: balances buckets and,
    /// with a good hash, bytes across disks).
    pub fn owner(&self, bucket: u32) -> usize {
        self.topology.owner(bucket)
    }

    /// Buckets owned by `node`, ascending.
    pub fn buckets_of(&self, node: usize) -> impl Iterator<Item = u32> + '_ {
        self.topology.buckets_of(node)
    }

    /// Disk of node `w`.
    pub fn disk(&self, w: usize) -> &Arc<NodeDisk> {
        &self.disks[w]
    }

    /// All node disks.
    pub fn disks(&self) -> &[Arc<NodeDisk>] {
        &self.disks
    }

    /// Phase-time accumulator (sync breakdowns for the benches).
    pub fn phases(&self) -> &PhaseTimes {
        &self.phases
    }

    /// Run `job(node, disk)` on every node in parallel and collect results
    /// in node order. The closure runs on a scoped worker thread — this is
    /// the leader-fan-out / barrier collective of the paper.
    ///
    /// Wall time is charged to phase `phase`.
    pub fn run<R, F>(&self, phase: &str, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize, &Arc<NodeDisk>) -> Result<R> + Sync,
    {
        let mut sp = self.open_collective(phase);
        let io0 = sp.as_ref().map(|_| self.io_snapshot());
        // Collective wall-time histogram: disarmed, the only cost is the
        // one relaxed load inside `enabled()`.
        let h0 = hist::enabled().then(std::time::Instant::now);
        let out = self.phases.time(phase, || {
            let results: Vec<std::thread::Result<Result<R>>> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .disks
                        .iter()
                        .enumerate()
                        .map(|(w, disk)| {
                            let job = &job;
                            scope.spawn(move || job(w, disk))
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect()
                });
            let mut out = Vec::with_capacity(results.len());
            for (w, r) in results.into_iter().enumerate() {
                match r {
                    Ok(Ok(v)) => out.push(v),
                    Ok(Err(e)) => return Err(e),
                    Err(_) => {
                        return Err(RoomyError::WorkerPanic {
                            worker: w,
                            phase: phase.to_string(),
                        })
                    }
                }
            }
            Ok(out)
        });
        if let Some(t0) = h0 {
            hist::record_collective(t0.elapsed());
        }
        self.close_collective(&mut sp, io0);
        out
    }

    /// Open a flight-recorder span for one collective (`None` when
    /// tracing is off — the only cost is one relaxed load). The span is
    /// tagged with the calling structure's instance label, if any.
    fn open_collective(&self, phase: &str) -> Option<trace::Span> {
        if !trace::enabled() {
            return None;
        }
        let name = match trace::current_label() {
            Some(l) => format!("{phase} [{l}]"),
            None => phase.to_string(),
        };
        Some(trace::span(trace::Kind::Collective, &name, None))
    }

    /// Attach the collective's I/O delta (bytes in/out) before the span
    /// closes. Snapshot reads happen only while tracing — they are reads
    /// of relaxed counters either way, but off means *zero* extra work.
    fn close_collective(&self, sp: &mut Option<trace::Span>, io0: Option<IoSnapshot>) {
        if let (Some(sp), Some(io0)) = (sp.as_mut(), io0) {
            let d = self.io_snapshot().delta(&io0);
            sp.set_args(d.bytes_read, d.bytes_written);
        }
    }

    /// Run `job(bucket, disk-of-owner)` for **every bucket**, dispatched
    /// through the worker pool's per-node queues; results are returned in
    /// ascending bucket order regardless of the schedule. This is the
    /// per-bucket collective engine all structure sync/map/reduce paths
    /// use: bucket tasks touch only their own bucket's files, so any
    /// `num_workers` / steal policy produces byte-identical on-disk state
    /// (see [`crate::runtime::pool`]).
    ///
    /// Wall time is charged to phase `phase`.
    pub fn run_buckets<R, F>(&self, phase: &str, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(u32, &Arc<NodeDisk>) -> Result<R> + Sync,
    {
        self.run_buckets_hinted(phase, |_b| None, job)
    }

    /// [`Cluster::run_buckets`] plus a **cross-task prefetch hint**:
    /// `hint(b)` names the file (relative to bucket `b`'s owner disk)
    /// the task will scan. When a worker dequeues a bucket, the pool
    /// posts the hint for the *next* queued bucket on the same node into
    /// that node's read-ahead lane ([`NodeDisk::hint_prefetch`]), so the
    /// next task's scan finds its first chunk already staged. Hints are
    /// best-effort and bounded by the pipeline depth; they never change
    /// what a task reads, only when the bytes move.
    pub fn run_buckets_hinted<R, F, H>(&self, phase: &str, hint: H, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(u32, &Arc<NodeDisk>) -> Result<R> + Sync,
        H: Fn(u32) -> Option<String> + Sync,
    {
        let nb = self.nbuckets() as usize;
        let topo = self.topology;
        // Self-tuning happens strictly between collectives: streams
        // started inside keep the depth they began with.
        if let Some(at) = &self.autotune {
            at.adapt(&self.disks, &self.pool);
        }
        let mut sp = self.open_collective(phase);
        let io0 = sp.as_ref().map(|_| self.io_snapshot());
        let h0 = hist::enabled().then(std::time::Instant::now);
        let out = self.phases.time(phase, || {
            self.pool.run_tagged(
                phase,
                nb,
                topo,
                |t| {
                    let b = t as u32;
                    if let Some(rel) = hint(b) {
                        self.disk(topo.owner(b)).hint_prefetch(rel);
                    }
                },
                |t| {
                    let b = t as u32;
                    job(b, self.disk(topo.owner(b)))
                },
            )
        });
        if let Some(t0) = h0 {
            hist::record_collective(t0.elapsed());
        }
        self.close_collective(&mut sp, io0);
        out
    }

    /// Aggregate I/O across all node disks.
    pub fn io_snapshot(&self) -> IoSnapshot {
        self.disks
            .iter()
            .map(|d| d.stats().snapshot())
            .fold(IoSnapshot::default(), |a, b| a + b)
    }

    /// Per-node I/O snapshots.
    pub fn per_node_io(&self) -> Vec<IoSnapshot> {
        self.disks.iter().map(|d| d.stats().snapshot()).collect()
    }

    /// Aggregate read-ahead / write-behind counters across all nodes
    /// (peak stream buffer RAM is a max, the rest sum).
    pub fn pipeline_snapshot(&self) -> PipelineSnapshot {
        self.disks
            .iter()
            .map(|d| d.pipe_stats().snapshot())
            .fold(PipelineSnapshot::default(), |a, b| a + b)
    }

    /// Liveness flags of every node's I/O service lane threads (empty at
    /// depth 0). The lifecycle tests hold these across teardown to prove
    /// no service thread survives the instance.
    pub fn io_alive_flags(&self) -> Vec<Arc<std::sync::atomic::AtomicBool>> {
        self.disks
            .iter()
            .filter_map(|d| d.io_service().map(|s| s.alive_flags()))
            .flatten()
            .collect()
    }

    /// Reset all I/O counters, phase times and pool counters (bench
    /// harness support).
    pub fn reset_metrics(&self) {
        for d in &self.disks {
            d.stats().reset();
            d.pipe_stats().reset();
        }
        self.phases.reset();
        self.pool.stats().reset();
    }

    /// Remove a structure directory on every node.
    pub fn remove_structure_dirs(&self, rel: impl AsRef<Path> + Sync) -> Result<()> {
        self.run("teardown", |_w, disk| disk.remove_dir(rel.as_ref()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::tmpdir;

    fn cluster(workers: usize, bpw: usize, root: &Path) -> Cluster {
        let mut cfg = RoomyConfig::for_testing(root);
        cfg.workers = workers;
        cfg.buckets_per_worker = bpw;
        Cluster::new(&cfg).unwrap()
    }

    #[test]
    fn creates_node_dirs() {
        let t = tmpdir("cluster_dirs");
        let c = cluster(3, 2, t.path());
        assert_eq!(c.nworkers(), 3);
        assert_eq!(c.nbuckets(), 6);
        for w in 0..3 {
            assert!(t.path().join(format!("node{w}")).is_dir());
        }
    }

    #[test]
    fn stale_tmp_scratch_purged_on_bringup() {
        let t = tmpdir("cluster_stale_scratch");
        drop(cluster(2, 1, t.path()));
        // simulate a crashed process leaving every flavor of tmp scratch
        // behind: capture logs, sort runs, pipeline staging
        let stale = [
            t.path().join("node0/tmp/capture/r0t0/d0.capture"),
            t.path().join("node0/tmp/sort/rl_a_s0.dat.run3"),
            t.path().join("node1/tmp/pipeline/n1-17.pstage"),
        ];
        for p in &stale {
            std::fs::create_dir_all(p.parent().unwrap()).unwrap();
            std::fs::write(p, b"dead run").unwrap();
        }
        let _c = cluster(2, 1, t.path());
        for p in &stale {
            assert!(!p.exists(), "stale scratch {p:?} must not survive bring-up");
        }
    }

    #[test]
    fn purge_is_scoped_to_owned_scratch_only() {
        let t = tmpdir("cluster_purge_scope");
        drop(cluster(2, 1, t.path()));
        // durable / foreign state that a rerun must NOT delete:
        let keep = [
            // checkpoints live beside the node dirs
            t.path().join("checkpoints/bfs/MANIFEST"),
            t.path().join("checkpoints/bfs/node0/rl_all/s0.dat"),
            // structure payload on a node disk
            t.path().join("node0/rl_all/s0.dat"),
            // unrelated sibling dir next to the node roots
            t.path().join("not-a-node/data.bin"),
            // even unrecognized entries under tmp/ are not ours to delete
            t.path().join("node1/tmp/user-parked.file"),
        ];
        // owned scratch that MUST be purged:
        let purge = [
            t.path().join("node0/tmp/capture/r9t9/d0.capture"),
            t.path().join("node1/tmp/sort/rl_x_s0.dat.run1"),
            t.path().join("node1/tmp/pipeline/n1-3.pstage"),
            t.path().join("node0/tmp/restore/rl_all/s0.dat"),
        ];
        for p in keep.iter().chain(&purge) {
            std::fs::create_dir_all(p.parent().unwrap()).unwrap();
            std::fs::write(p, b"x").unwrap();
        }
        let _c = cluster(2, 1, t.path());
        for p in &keep {
            assert!(p.exists(), "bring-up must not delete durable state {p:?}");
        }
        for p in &purge {
            assert!(!p.exists(), "owned scratch {p:?} must be purged");
        }
    }

    #[test]
    fn checkpoint_root_defaults_beside_node_dirs() {
        let t = tmpdir("cluster_ckpt_root");
        let c = cluster(2, 1, t.path());
        assert_eq!(c.checkpoint_root(), t.path().join("checkpoints"));
        // a configured override wins
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.checkpoint_dir = Some(t.path().join("elsewhere"));
        let c2 = Cluster::new(&cfg).unwrap();
        assert_eq!(c2.checkpoint_root(), t.path().join("elsewhere"));
    }

    #[test]
    fn run_returns_results_in_node_order() {
        let t = tmpdir("cluster_run");
        let c = cluster(4, 1, t.path());
        let out = c.run("ids", |w, _| Ok(w * 10)).unwrap();
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn run_parallelism_is_real() {
        // All workers must be in-flight simultaneously: have each wait for
        // a shared barrier that only opens when all arrive.
        let t = tmpdir("cluster_par");
        let c = cluster(4, 1, t.path());
        let barrier = std::sync::Barrier::new(4);
        c.run("barrier", |_w, _| {
            barrier.wait();
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn run_propagates_errors() {
        let t = tmpdir("cluster_err");
        let c = cluster(2, 1, t.path());
        let r: Result<Vec<()>> = c.run("boom", |w, _| {
            if w == 1 {
                Err(RoomyError::InvalidArg("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn run_surfaces_panics_as_errors() {
        let t = tmpdir("cluster_panic");
        let c = cluster(2, 1, t.path());
        let r: Result<Vec<()>> = c.run("panic", |w, _| {
            if w == 0 {
                panic!("worker exploded");
            }
            Ok(())
        });
        match r {
            Err(RoomyError::WorkerPanic { worker, .. }) => assert_eq!(worker, 0),
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
    }

    #[test]
    fn bucket_ownership_partitions_all_buckets() {
        let t = tmpdir("cluster_owner");
        let c = cluster(3, 4, t.path());
        let mut seen = vec![false; c.nbuckets() as usize];
        for w in 0..c.nworkers() {
            for b in c.buckets_of(w) {
                assert_eq!(c.owner(b), w);
                assert!(!seen[b as usize], "bucket {b} owned twice");
                seen[b as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every bucket must be owned");
    }

    #[test]
    fn run_buckets_covers_every_bucket_once() {
        let t = tmpdir("cluster_rb");
        let c = cluster(2, 3, t.path());
        let buckets = c.run_buckets("collect", |b, _| Ok(b)).unwrap();
        // pool dispatch returns results in ascending bucket order
        assert_eq!(buckets, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn run_buckets_hands_each_bucket_its_owner_disk() {
        let t = tmpdir("cluster_rb_owner");
        let c = cluster(3, 2, t.path());
        let nodes = c.run_buckets("owners", |b, disk| Ok((b, disk.node()))).unwrap();
        for (b, node) in nodes {
            assert_eq!(node, c.owner(b), "bucket {b} ran against the wrong disk");
        }
    }

    #[test]
    fn run_buckets_counts_pool_tasks() {
        let t = tmpdir("cluster_rb_stats");
        let c = cluster(2, 2, t.path());
        c.pool().stats().reset();
        c.run_buckets("count", |_b, _| Ok(())).unwrap();
        assert_eq!(c.pool().stats().total_tasks(), 4);
    }

    /// Autotune `On` builds the controller and runs one adapt round per
    /// bucket collective; the default `Off` holds no controller.
    #[test]
    fn autotune_rounds_follow_collectives() {
        let t = tmpdir("cluster_autotune");
        let off = cluster(2, 2, t.path());
        assert!(off.autotune().is_none(), "default must carry no controller");
        drop(off);

        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 2;
        cfg.autotune = crate::config::AutotuneMode::On;
        let c = Cluster::new(&cfg).unwrap();
        let at = c.autotune().expect("On must build the controller");
        assert_eq!(at.rounds(), 0);
        c.run_buckets("a", |_b, _| Ok(())).unwrap();
        c.run_buckets("b", |_b, _| Ok(())).unwrap();
        assert_eq!(at.rounds(), 2);
    }

    #[test]
    fn io_snapshot_aggregates_nodes() {
        let t = tmpdir("cluster_io");
        let c = cluster(2, 1, t.path());
        c.run("write", |w, disk| {
            disk.write_all(format!("f{w}.dat"), &[0u8; 100])
        })
        .unwrap();
        let s = c.io_snapshot();
        assert_eq!(s.bytes_written, 200);
        c.reset_metrics();
        assert_eq!(c.io_snapshot().bytes_written, 0);
    }

    #[test]
    fn phase_times_recorded() {
        let t = tmpdir("cluster_phase");
        let c = cluster(2, 1, t.path());
        c.run("phase_x", |_, _| Ok(())).unwrap();
        assert!(c.phases().get("phase_x").is_some());
    }
}
