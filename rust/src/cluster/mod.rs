//! Simulated compute cluster: one node per worker thread, each with its own
//! local disk directory; a leader (the calling thread) drives collective
//! operations.
//!
//! Roomy is bulk-synchronous: every collective (sync, map, reduce, sort,
//! shuffle) is "leader fans a job out to all nodes, nodes stream their
//! local shards, barrier". [`Cluster::run`] implements exactly that with
//! scoped threads, preserving the paper's topology — node-local data,
//! explicit cross-node shuffle files — while staying laptop-runnable
//! (DESIGN.md, Substitutions).

use std::path::Path;
use std::sync::Arc;

use crate::config::RoomyConfig;
use crate::error::{Result, RoomyError};
use crate::metrics::{IoSnapshot, PhaseTimes};
use crate::storage::NodeDisk;

/// A simulated cluster: `workers` nodes, each owning one [`NodeDisk`].
#[derive(Debug)]
pub struct Cluster {
    disks: Vec<Arc<NodeDisk>>,
    buckets_per_worker: usize,
    phases: PhaseTimes,
}

impl Cluster {
    /// Bring up the cluster: create one disk directory per node under
    /// `cfg.root`.
    pub fn new(cfg: &RoomyConfig) -> Result<Self> {
        cfg.validate()?;
        let mut disks = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let dir = cfg.root.join(format!("node{w}"));
            disks.push(Arc::new(NodeDisk::create(w, dir, cfg.disk)?));
        }
        Ok(Cluster {
            disks,
            buckets_per_worker: cfg.buckets_per_worker,
            phases: PhaseTimes::new(),
        })
    }

    /// Number of nodes.
    pub fn nworkers(&self) -> usize {
        self.disks.len()
    }

    /// Total bucket count every structure on this cluster is split into.
    pub fn nbuckets(&self) -> u32 {
        (self.disks.len() * self.buckets_per_worker) as u32
    }

    /// The node that owns bucket `b` (round-robin: balances buckets and,
    /// with a good hash, bytes across disks).
    pub fn owner(&self, bucket: u32) -> usize {
        (bucket as usize) % self.disks.len()
    }

    /// Buckets owned by `node`, ascending.
    pub fn buckets_of(&self, node: usize) -> impl Iterator<Item = u32> + '_ {
        let w = self.nworkers();
        (0..self.nbuckets()).filter(move |b| (*b as usize) % w == node)
    }

    /// Disk of node `w`.
    pub fn disk(&self, w: usize) -> &Arc<NodeDisk> {
        &self.disks[w]
    }

    /// All node disks.
    pub fn disks(&self) -> &[Arc<NodeDisk>] {
        &self.disks
    }

    /// Phase-time accumulator (sync breakdowns for the benches).
    pub fn phases(&self) -> &PhaseTimes {
        &self.phases
    }

    /// Run `job(node, disk)` on every node in parallel and collect results
    /// in node order. The closure runs on a scoped worker thread — this is
    /// the leader-fan-out / barrier collective of the paper.
    ///
    /// Wall time is charged to phase `phase`.
    pub fn run<R, F>(&self, phase: &str, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize, &NodeDisk) -> Result<R> + Sync,
    {
        self.phases.time(phase, || {
            let results: Vec<std::thread::Result<Result<R>>> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .disks
                        .iter()
                        .enumerate()
                        .map(|(w, disk)| {
                            let job = &job;
                            scope.spawn(move || job(w, disk))
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect()
                });
            let mut out = Vec::with_capacity(results.len());
            for (w, r) in results.into_iter().enumerate() {
                match r {
                    Ok(Ok(v)) => out.push(v),
                    Ok(Err(e)) => return Err(e),
                    Err(_) => {
                        return Err(RoomyError::WorkerPanic {
                            worker: w,
                            phase: phase.to_string(),
                        })
                    }
                }
            }
            Ok(out)
        })
    }

    /// Like [`Cluster::run`] but the job iterates the node's owned buckets
    /// itself; provided for the common per-bucket collective shape.
    pub fn run_buckets<R, F>(&self, phase: &str, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(u32, &NodeDisk) -> Result<R> + Sync,
    {
        let nested: Vec<Vec<R>> = self.run(phase, |w, disk| {
            let mut acc = Vec::new();
            for b in self.buckets_of(w) {
                acc.push(job(b, disk)?);
            }
            Ok(acc)
        })?;
        Ok(nested.into_iter().flatten().collect())
    }

    /// Aggregate I/O across all node disks.
    pub fn io_snapshot(&self) -> IoSnapshot {
        self.disks
            .iter()
            .map(|d| d.stats().snapshot())
            .fold(IoSnapshot::default(), |a, b| a + b)
    }

    /// Per-node I/O snapshots.
    pub fn per_node_io(&self) -> Vec<IoSnapshot> {
        self.disks.iter().map(|d| d.stats().snapshot()).collect()
    }

    /// Reset all I/O counters and phase times (bench harness support).
    pub fn reset_metrics(&self) {
        for d in &self.disks {
            d.stats().reset();
        }
        self.phases.reset();
    }

    /// Remove a structure directory on every node.
    pub fn remove_structure_dirs(&self, rel: impl AsRef<Path> + Sync) -> Result<()> {
        self.run("teardown", |_w, disk| disk.remove_dir(rel.as_ref()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::tmpdir;

    fn cluster(workers: usize, bpw: usize, root: &Path) -> Cluster {
        let mut cfg = RoomyConfig::for_testing(root);
        cfg.workers = workers;
        cfg.buckets_per_worker = bpw;
        Cluster::new(&cfg).unwrap()
    }

    #[test]
    fn creates_node_dirs() {
        let t = tmpdir("cluster_dirs");
        let c = cluster(3, 2, t.path());
        assert_eq!(c.nworkers(), 3);
        assert_eq!(c.nbuckets(), 6);
        for w in 0..3 {
            assert!(t.path().join(format!("node{w}")).is_dir());
        }
    }

    #[test]
    fn run_returns_results_in_node_order() {
        let t = tmpdir("cluster_run");
        let c = cluster(4, 1, t.path());
        let out = c.run("ids", |w, _| Ok(w * 10)).unwrap();
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn run_parallelism_is_real() {
        // All workers must be in-flight simultaneously: have each wait for
        // a shared barrier that only opens when all arrive.
        let t = tmpdir("cluster_par");
        let c = cluster(4, 1, t.path());
        let barrier = std::sync::Barrier::new(4);
        c.run("barrier", |_w, _| {
            barrier.wait();
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn run_propagates_errors() {
        let t = tmpdir("cluster_err");
        let c = cluster(2, 1, t.path());
        let r: Result<Vec<()>> = c.run("boom", |w, _| {
            if w == 1 {
                Err(RoomyError::InvalidArg("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn run_surfaces_panics_as_errors() {
        let t = tmpdir("cluster_panic");
        let c = cluster(2, 1, t.path());
        let r: Result<Vec<()>> = c.run("panic", |w, _| {
            if w == 0 {
                panic!("worker exploded");
            }
            Ok(())
        });
        match r {
            Err(RoomyError::WorkerPanic { worker, .. }) => assert_eq!(worker, 0),
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
    }

    #[test]
    fn bucket_ownership_partitions_all_buckets() {
        let t = tmpdir("cluster_owner");
        let c = cluster(3, 4, t.path());
        let mut seen = vec![false; c.nbuckets() as usize];
        for w in 0..c.nworkers() {
            for b in c.buckets_of(w) {
                assert_eq!(c.owner(b), w);
                assert!(!seen[b as usize], "bucket {b} owned twice");
                seen[b as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every bucket must be owned");
    }

    #[test]
    fn run_buckets_covers_every_bucket_once() {
        let t = tmpdir("cluster_rb");
        let c = cluster(2, 3, t.path());
        let mut buckets = c.run_buckets("collect", |b, _| Ok(b)).unwrap();
        buckets.sort();
        assert_eq!(buckets, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn io_snapshot_aggregates_nodes() {
        let t = tmpdir("cluster_io");
        let c = cluster(2, 1, t.path());
        c.run("write", |w, disk| {
            disk.write_all(format!("f{w}.dat"), &[0u8; 100])
        })
        .unwrap();
        let s = c.io_snapshot();
        assert_eq!(s.bytes_written, 200);
        c.reset_metrics();
        assert_eq!(c.io_snapshot().bytes_written, 0);
    }

    #[test]
    fn phase_times_recorded() {
        let t = tmpdir("cluster_phase");
        let c = cluster(2, 1, t.path());
        c.run("phase_x", |_, _| Ok(())).unwrap();
        assert!(c.phases().get("phase_x").is_some());
    }
}
