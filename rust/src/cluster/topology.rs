//! Bucket → node ownership arithmetic, in one place.
//!
//! Every layer of the system needs the same three facts about the data
//! layout: how many nodes there are, how many buckets each structure is
//! split into, and which node owns a given bucket. Before this type the
//! modulo arithmetic was repeated in [`Cluster`](super::Cluster)'s
//! `owner`/`buckets_of`, in the checkpoint manifest's geometry check, and
//! (via the bucket count) in every structure's hash routing. [`Topology`]
//! is the single owner of that arithmetic; the per-node work queues in
//! [`crate::runtime::pool`] consume it too, so the scheduler and the
//! storage layout can never disagree about which node a bucket belongs to.
//!
//! Ownership is round-robin (`bucket % nodes`): with a good routing hash
//! it balances both bucket count and bytes across disks, and it makes
//! `buckets_of` a strided range rather than a lookup table.

use crate::hashfn;

/// The data layout of one cluster: `nodes` disks, each owning
/// `buckets_per_node` buckets of every structure. Cheap to copy; value
/// equality is layout equality.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    nodes: usize,
    buckets_per_node: usize,
}

impl Topology {
    /// Layout of `nodes` nodes × `buckets_per_node` buckets each.
    pub fn new(nodes: usize, buckets_per_node: usize) -> Topology {
        assert!(nodes > 0 && buckets_per_node > 0, "degenerate topology");
        Topology { nodes, buckets_per_node }
    }

    /// The degenerate one-bucket-per-rank layout a bare
    /// [`WorkerPool`](crate::runtime::pool::WorkerPool) runs under: task
    /// `t` homes on slot `t % nodes`. Clamps to at least one node.
    pub fn flat(nodes: usize) -> Topology {
        Topology::new(nodes.max(1), 1)
    }

    /// Number of nodes (disks).
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Total bucket count of every structure on this layout.
    pub fn nbuckets(&self) -> u32 {
        (self.nodes * self.buckets_per_node) as u32
    }

    /// The node that owns bucket `b` (round-robin).
    pub fn owner(&self, bucket: u32) -> usize {
        (bucket as usize) % self.nodes
    }

    /// Buckets owned by `node`, ascending (empty for out-of-range nodes).
    pub fn buckets_of(&self, node: usize) -> impl Iterator<Item = u32> + '_ {
        let start = node as u32;
        let end = if node < self.nodes { self.nbuckets() } else { start };
        (start..end).step_by(self.nodes)
    }

    /// The pool worker slot that homes `node` when `nworkers` slots are
    /// live (round-robin over the slots; every node has exactly one home
    /// worker, so strict-locality scheduling still drains every queue).
    pub fn home_worker(&self, node: usize, nworkers: usize) -> usize {
        node % nworkers.max(1)
    }

    /// Hash-route an element's bytes to its bucket (the shared
    /// fingerprint + fast-range formula of [`crate::hashfn`]).
    pub fn route(&self, elt_bytes: &[u8]) -> u32 {
        hashfn::bucket_of_bytes(elt_bytes, self.nbuckets())
    }

    /// Bulk form of [`route`](Self::route): one batched fingerprint sweep
    /// over a chunk of `rec_size`-byte records, appending one bucket per
    /// record to `out`. Bit-exact with a per-record `route` loop (the
    /// kernel contract in [`crate::hashfn`]).
    pub fn route_batch_into(&self, batch: &[u8], rec_size: usize, out: &mut Vec<u32>) {
        hashfn::route_batch_into(batch, rec_size, self.nbuckets(), out);
    }

    /// Whether a recorded geometry (checkpoint manifest, peer structure)
    /// matches this layout.
    pub fn matches(&self, nodes: usize, nbuckets: u32) -> bool {
        self.nodes == nodes && self.nbuckets() == nbuckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ownership_partitions_all_buckets() {
        let t = Topology::new(3, 4);
        assert_eq!(t.nbuckets(), 12);
        let mut seen = vec![false; 12];
        for n in 0..t.nodes() {
            for b in t.buckets_of(n) {
                assert_eq!(t.owner(b), n);
                assert!(!seen[b as usize], "bucket {b} owned twice");
                seen[b as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn every_node_has_exactly_one_home_worker() {
        for (nodes, workers) in [(4usize, 2usize), (2, 4), (3, 3), (5, 1)] {
            let t = Topology::new(nodes, 2);
            for w in 0..workers {
                let mine: Vec<usize> =
                    (0..nodes).filter(|&n| t.home_worker(n, workers) == w).collect();
                for n in &mine {
                    assert_eq!(n % workers, w);
                }
            }
            // partition: each node maps to exactly one worker < workers
            for n in 0..nodes {
                assert!(t.home_worker(n, workers) < workers);
            }
        }
    }

    #[test]
    fn route_matches_hashfn() {
        let t = Topology::new(3, 2);
        for v in 0u64..200 {
            assert_eq!(
                t.route(&v.to_le_bytes()),
                crate::hashfn::bucket_of_bytes(&v.to_le_bytes(), 6)
            );
        }
    }

    #[test]
    fn route_batch_matches_scalar_route() {
        let t = Topology::new(3, 2);
        let mut bytes = Vec::new();
        for v in 0u64..200 {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let mut batch = Vec::new();
        t.route_batch_into(&bytes, 8, &mut batch);
        let scalar: Vec<u32> = bytes.chunks_exact(8).map(|r| t.route(r)).collect();
        assert_eq!(batch, scalar);
    }

    #[test]
    fn geometry_matching() {
        let t = Topology::new(3, 2);
        assert!(t.matches(3, 6));
        assert!(!t.matches(2, 6));
        assert!(!t.matches(3, 12));
    }

    #[test]
    fn flat_is_one_bucket_per_rank() {
        let t = Topology::flat(4);
        assert_eq!(t.nodes(), 4);
        for task in 0..16u32 {
            assert_eq!(t.owner(task), task as usize % 4);
        }
        assert_eq!(Topology::flat(0).nodes(), 1);
    }
}
