//! User-function registries.
//!
//! Roomy serializes delayed operations to disk, so the *function* part of
//! an operation must be named compactly — the C library uses function
//! pointers registered with the structure; we use small integer ids
//! mapping into per-structure registries of type-erased closures. Typed
//! wrappers on the structures recover the ergonomic API.
//!
//! All closures run on worker threads during `sync`/`map` collectives and
//! may issue *delayed* operations on other structures (that is how the
//! paper's BFS works); they must therefore be `Send + Sync`.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::RwLock;

use crate::error::{Result, RoomyError};

/// Id of a registered update function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpdateId(pub(crate) u8);

/// Id of a registered access function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessId(pub(crate) u8);

/// Id of a registered predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PredId(pub(crate) u8);

/// Type-erased update: `(index, element bytes [in/out], passed bytes)`.
pub type UpdateFn = Box<dyn Fn(u64, &mut [u8], &[u8]) + Send + Sync>;
/// Type-erased access: `(index, element bytes, passed bytes)`.
pub type AccessFn = Box<dyn Fn(u64, &[u8], &[u8]) + Send + Sync>;
/// Type-erased predicate over `(index, element bytes)`.
pub type PredFn = Box<dyn Fn(u64, &[u8]) -> bool + Send + Sync>;

struct Registered<F> {
    f: F,
    passed_len: usize,
}

/// Registry of update/access/predicate functions for one structure,
/// plus the incrementally-maintained predicate counters (paper Table 1:
/// `predicateCount` "does not require a separate scan").
#[derive(Default)]
pub struct FuncRegistry {
    updates: RwLock<Vec<Registered<UpdateFn>>>,
    accesses: RwLock<Vec<Registered<AccessFn>>>,
    preds: RwLock<Vec<PredFn>>,
    pred_counts: RwLock<Vec<AtomicI64>>,
    structure: String,
}

impl FuncRegistry {
    pub fn new(structure: &str) -> Self {
        FuncRegistry {
            structure: structure.to_string(),
            ..Default::default()
        }
    }

    pub fn register_update(&self, passed_len: usize, f: UpdateFn) -> UpdateId {
        let mut g = self.updates.write().unwrap();
        assert!(g.len() < 256, "at most 256 update functions per structure");
        g.push(Registered { f, passed_len });
        UpdateId((g.len() - 1) as u8)
    }

    pub fn register_access(&self, passed_len: usize, f: AccessFn) -> AccessId {
        let mut g = self.accesses.write().unwrap();
        assert!(g.len() < 256, "at most 256 access functions per structure");
        g.push(Registered { f, passed_len });
        AccessId((g.len() - 1) as u8)
    }

    pub fn register_pred(&self, f: PredFn) -> PredId {
        let mut preds = self.preds.write().unwrap();
        let mut counts = self.pred_counts.write().unwrap();
        assert!(preds.len() < 256, "at most 256 predicates per structure");
        preds.push(f);
        counts.push(AtomicI64::new(0));
        PredId((preds.len() - 1) as u8)
    }

    pub fn update_passed_len(&self, id: u8) -> Result<usize> {
        self.updates
            .read()
            .unwrap()
            .get(id as usize)
            .map(|r| r.passed_len)
            .ok_or_else(|| RoomyError::UnknownFunc { structure: self.structure.clone(), id })
    }

    pub fn access_passed_len(&self, id: u8) -> Result<usize> {
        self.accesses
            .read()
            .unwrap()
            .get(id as usize)
            .map(|r| r.passed_len)
            .ok_or_else(|| RoomyError::UnknownFunc { structure: self.structure.clone(), id })
    }

    /// Apply update `id` to `elt` in place.
    pub fn apply_update(&self, id: u8, idx: u64, elt: &mut [u8], passed: &[u8]) -> Result<()> {
        let g = self.updates.read().unwrap();
        let r = g.get(id as usize).ok_or_else(|| RoomyError::UnknownFunc {
            structure: self.structure.clone(),
            id,
        })?;
        (r.f)(idx, elt, passed);
        Ok(())
    }

    /// Invoke access `id`.
    pub fn apply_access(&self, id: u8, idx: u64, elt: &[u8], passed: &[u8]) -> Result<()> {
        let g = self.accesses.read().unwrap();
        let r = g.get(id as usize).ok_or_else(|| RoomyError::UnknownFunc {
            structure: self.structure.clone(),
            id,
        })?;
        (r.f)(idx, elt, passed);
        Ok(())
    }

    /// Number of registered predicates.
    pub fn npreds(&self) -> usize {
        self.preds.read().unwrap().len()
    }

    /// Evaluate every predicate on `(idx, elt)`, adding `sign` per hit.
    /// Called for each element mutation (and initial fill) so counts stay
    /// current without a scan.
    pub fn charge_preds(&self, idx: u64, elt: &[u8], sign: i64) {
        let preds = self.preds.read().unwrap();
        if preds.is_empty() {
            return;
        }
        let counts = self.pred_counts.read().unwrap();
        for (p, c) in preds.iter().zip(counts.iter()) {
            if p(idx, elt) {
                c.fetch_add(sign, Ordering::Relaxed);
            }
        }
    }

    /// Charge only predicate `id` (used by its initializing scan).
    pub fn charge_pred_single(&self, id: PredId, idx: u64, elt: &[u8]) {
        let preds = self.preds.read().unwrap();
        let counts = self.pred_counts.read().unwrap();
        if let (Some(p), Some(c)) = (preds.get(id.0 as usize), counts.get(id.0 as usize)) {
            if p(idx, elt) {
                c.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Current count for predicate `id`.
    pub fn pred_count(&self, id: PredId) -> u64 {
        let counts = self.pred_counts.read().unwrap();
        counts
            .get(id.0 as usize)
            .map(|c| c.load(Ordering::Relaxed).max(0) as u64)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_apply_update() {
        let reg = FuncRegistry::new("t");
        let id = reg.register_update(
            4,
            Box::new(|_i, elt, passed| {
                let cur = u32::from_le_bytes(elt.try_into().unwrap());
                let p = u32::from_le_bytes(passed.try_into().unwrap());
                elt.copy_from_slice(&(cur + p).to_le_bytes());
            }),
        );
        assert_eq!(reg.update_passed_len(id.0).unwrap(), 4);
        let mut elt = 10u32.to_le_bytes().to_vec();
        reg.apply_update(id.0, 0, &mut elt, &5u32.to_le_bytes()).unwrap();
        assert_eq!(u32::from_le_bytes(elt.try_into().unwrap()), 15);
    }

    #[test]
    fn unknown_ids_error() {
        let reg = FuncRegistry::new("t");
        assert!(reg.update_passed_len(0).is_err());
        assert!(reg.apply_access(3, 0, &[], &[]).is_err());
        let mut e = [0u8];
        assert!(reg.apply_update(1, 0, &mut e, &[]).is_err());
    }

    #[test]
    fn access_sees_bytes() {
        let reg = FuncRegistry::new("t");
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        let id = reg.register_access(
            0,
            Box::new(move |i, elt, _| {
                seen2.lock().unwrap().push((i, elt.to_vec()));
            }),
        );
        reg.apply_access(id.0, 9, &[1, 2], &[]).unwrap();
        assert_eq!(seen.lock().unwrap().as_slice(), &[(9, vec![1, 2])]);
    }

    #[test]
    fn predicate_counts_track_signs() {
        let reg = FuncRegistry::new("t");
        let even = reg.register_pred(Box::new(|_i, elt| elt[0] % 2 == 0));
        let any = reg.register_pred(Box::new(|_i, _elt| true));
        reg.charge_preds(0, &[2], 1);
        reg.charge_preds(1, &[3], 1);
        reg.charge_preds(2, &[4], 1);
        assert_eq!(reg.pred_count(even), 2);
        assert_eq!(reg.pred_count(any), 3);
        // mutation: 4 -> 5 (old out, new in)
        reg.charge_preds(2, &[4], -1);
        reg.charge_preds(2, &[5], 1);
        assert_eq!(reg.pred_count(even), 1);
        assert_eq!(reg.pred_count(any), 3);
    }

    #[test]
    fn pred_count_clamps_at_zero() {
        let reg = FuncRegistry::new("t");
        let p = reg.register_pred(Box::new(|_, _| true));
        reg.charge_preds(0, &[0], -1);
        assert_eq!(reg.pred_count(p), 0);
    }
}
