//! `RoomyBitArray`: a disk-resident array of sub-byte elements.
//!
//! The paper (§2) notes RoomyArray elements "can be as small as one bit" —
//! this is what makes array-based breadth-first search over an `n!`-sized
//! implicit state space affordable (1–2 bits per state instead of a full
//! packed permutation). Elements are `bits` ∈ {1, 2, 4, 8} wide, packed
//! into byte-aligned bucket files; values are `u8` in `0..2^bits`.
//!
//! Delayed `update`/`access` mirror [`super::RoomyArray`]; a per-value
//! histogram is maintained at every mutation so `count_value` (the
//! bit-array analogue of `predicateCount`) is O(1).

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use super::bitkernels;
use super::element::Element;
use super::funcs::{AccessId, UpdateId};
use super::ops::{OpKind, StagedOps};
use super::Ctx;
use crate::error::{Result, RoomyError};
use crate::storage::checkpoint::{Checkpointable, StructKind, StructMeta};
use crate::storage::scratch;
use crate::storage::{read_all_pipelined, write_all_pipelined};

/// Type-erased bit-array update: `(index, current, passed) -> new`.
type BitUpdateFn = Box<dyn Fn(u64, u8, &[u8]) -> u8 + Send + Sync>;
/// Type-erased bit-array access: `(index, value, passed)`.
type BitAccessFn = Box<dyn Fn(u64, u8, &[u8]) + Send + Sync>;

/// A distributed disk-backed array of sub-byte elements. Cheap to clone.
#[derive(Clone)]
pub struct RoomyBitArray {
    inner: Arc<BitInner>,
}

struct BitInner {
    ctx: Ctx,
    name: String,
    dir: String,
    len: u64,
    bits: u8,
    /// Elements per bucket; multiple of `8 / bits` so buckets are
    /// byte-aligned on disk.
    bsize: u64,
    updates: std::sync::RwLock<Vec<(usize, BitUpdateFn)>>,
    accesses: std::sync::RwLock<Vec<(usize, BitAccessFn)>>,
    staged: Arc<StagedOps>,
    /// Serializes `sync` (bucket rewrite) against concurrent client
    /// threads.
    write_lock: std::sync::Mutex<()>,
    /// Histogram: counts[v] = number of elements equal to v.
    counts: Vec<AtomicI64>,
}

impl RoomyBitArray {
    pub(crate) fn create(ctx: Ctx, name: &str, len: u64, bits: u8) -> Result<Self> {
        if !matches!(bits, 1 | 2 | 4 | 8) {
            return Err(RoomyError::InvalidArg(format!(
                "bit width must be 1, 2, 4 or 8 (got {bits})"
            )));
        }
        if len == 0 {
            return Err(RoomyError::InvalidArg("RoomyBitArray length must be > 0".into()));
        }
        let dir = format!("rba_{name}");
        // A freshly created structure must be fully zero-filled: clear
        // any same-named leftovers from a killed run before materializing
        // the buckets.
        ctx.cluster.remove_structure_dirs(&dir)?;
        let cluster = ctx.cluster.clone();
        let per_byte = (8 / bits) as u64;
        let nb = cluster.nbuckets() as u64;
        // Round bucket size up to a whole number of bytes.
        let bsize = len.div_ceil(nb).div_ceil(per_byte) * per_byte;
        let nvals = 1usize << bits;
        let mut counts = Vec::with_capacity(nvals);
        counts.push(AtomicI64::new(len as i64)); // zero-filled
        for _ in 1..nvals {
            counts.push(AtomicI64::new(0));
        }
        let inner = BitInner {
            staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
            updates: std::sync::RwLock::new(Vec::new()),
            accesses: std::sync::RwLock::new(Vec::new()),
            write_lock: std::sync::Mutex::new(()),
            ctx,
            name: name.to_string(),
            dir,
            len,
            bits,
            bsize,
            counts,
        };
        // Materialize zero-filled bucket files.
        inner.for_owned_buckets("rba.create", |this, b, disk| {
            let nbytes = this.bucket_bytes(b);
            if nbytes == 0 {
                return Ok(());
            }
            disk.write_all(this.bucket_file(b), &vec![0u8; nbytes])
        })?;
        Ok(RoomyBitArray { inner: Arc::new(inner) })
    }

    /// Re-open a restored bit array over bucket files already on disk
    /// ([`crate::storage::checkpoint`]); `counts` is the checkpointed
    /// per-value histogram. Registered functions do not survive a
    /// checkpoint — re-register before staging delayed ops.
    pub(crate) fn open_restored(
        ctx: Ctx,
        name: &str,
        len: u64,
        bits: u8,
        counts: &[u64],
    ) -> Result<Self> {
        if !matches!(bits, 1 | 2 | 4 | 8) {
            return Err(RoomyError::InvalidArg(format!(
                "bit width must be 1, 2, 4 or 8 (got {bits})"
            )));
        }
        if len == 0 {
            return Err(RoomyError::InvalidArg("RoomyBitArray length must be > 0".into()));
        }
        let nvals = 1usize << bits;
        if counts.len() != nvals {
            return Err(RoomyError::Checkpoint(format!(
                "bit array {name:?}: histogram has {} entries, want {nvals}",
                counts.len()
            )));
        }
        let dir = format!("rba_{name}");
        let cluster = ctx.cluster.clone();
        let per_byte = (8 / bits) as u64;
        let nb = cluster.nbuckets() as u64;
        let bsize = len.div_ceil(nb).div_ceil(per_byte) * per_byte;
        Ok(RoomyBitArray {
            inner: Arc::new(BitInner {
                staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
                updates: std::sync::RwLock::new(Vec::new()),
                accesses: std::sync::RwLock::new(Vec::new()),
                write_lock: std::sync::Mutex::new(()),
                ctx,
                name: name.to_string(),
                dir,
                len,
                bits,
                bsize,
                counts: counts.iter().map(|&c| AtomicI64::new(c as i64)).collect(),
            }),
        })
    }

    /// Number of elements.
    pub fn len(&self) -> u64 {
        self.inner.len
    }

    /// True if empty (never; creation requires > 0).
    pub fn is_empty(&self) -> bool {
        self.inner.len == 0
    }

    /// Bits per element.
    pub fn bits(&self) -> u8 {
        self.inner.bits
    }

    /// Structure name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Total staged (not yet synced) delayed-op bytes.
    pub fn pending_bytes(&self) -> u64 {
        self.inner.staged.staged_bytes()
    }

    /// Count of elements currently equal to `v` (O(1); maintained at every
    /// mutation — the paper's `predicateCount` contract).
    pub fn count_value(&self, v: u8) -> u64 {
        self.inner
            .counts
            .get(v as usize)
            .map(|c| c.load(Ordering::Relaxed).max(0) as u64)
            .unwrap_or(0)
    }

    /// Register an update `f(index, current, passed) -> new` (result is
    /// masked to the element width).
    pub fn register_update<P: Element>(
        &self,
        f: impl Fn(u64, u8, &P) -> u8 + Send + Sync + 'static,
    ) -> UpdateId {
        let mut g = self.inner.updates.write().unwrap();
        assert!(g.len() < 256);
        g.push((P::SIZE, Box::new(move |i, cur, p| f(i, cur, &P::read_from(p)))));
        UpdateId((g.len() - 1) as u8)
    }

    /// Register an access `f(index, value, passed)`.
    pub fn register_access<P: Element>(
        &self,
        f: impl Fn(u64, u8, &P) + Send + Sync + 'static,
    ) -> AccessId {
        let mut g = self.inner.accesses.write().unwrap();
        assert!(g.len() < 256);
        g.push((P::SIZE, Box::new(move |i, cur, p| f(i, cur, &P::read_from(p)))));
        AccessId((g.len() - 1) as u8)
    }

    /// Delayed update of element `i`.
    pub fn update<P: Element>(&self, i: u64, passed: &P, id: UpdateId) -> Result<()> {
        let expect = self.inner.update_passed_len(id.0)?;
        self.stage_op(OpKind::Update, id.0, expect, i, passed)
    }

    /// Delayed access of element `i`.
    pub fn access<P: Element>(&self, i: u64, passed: &P, id: AccessId) -> Result<()> {
        let expect = self.inner.access_passed_len(id.0)?;
        self.stage_op(OpKind::Access, id.0, expect, i, passed)
    }

    fn stage_op<P: Element>(
        &self,
        kind: OpKind,
        fn_id: u8,
        expect_len: usize,
        i: u64,
        passed: &P,
    ) -> Result<()> {
        let inner = &self.inner;
        if i >= inner.len {
            return Err(RoomyError::InvalidArg(format!(
                "index {i} out of bounds for RoomyBitArray({}) of length {}",
                inner.name, inner.len
            )));
        }
        if P::SIZE != expect_len {
            return Err(RoomyError::InvalidArg(format!(
                "passed value is {} bytes but function was registered with {expect_len}",
                P::SIZE
            )));
        }
        super::ops::with_op_buf(|rec| {
            rec.push(kind as u8);
            rec.push(fn_id);
            rec.extend_from_slice(&i.to_le_bytes());
            let off = rec.len();
            rec.resize(off + P::SIZE, 0);
            passed.write_to(&mut rec[off..]);
            inner.staged.stage((i / inner.bsize) as u32, rec)
        })
    }

    /// Apply all outstanding delayed operations (FIFO per bucket).
    pub fn sync(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        if inner.staged.is_empty() {
            return Ok(());
        }
        inner.for_owned_buckets("rba.sync", |this, b, disk| {
            let mut ops = this.staged.take(
                b,
                &this.ctx.cluster,
                &this.dir,
                this.ctx.cfg.op_buffer_bytes,
            );
            if ops.is_empty() {
                return ops.clear();
            }
            let file = this.bucket_file(b);
            // Whole-bucket load/store rides the pipeline lanes too: the
            // op-log drain below prefetches while the bucket streams in.
            let mut data = read_all_pipelined(disk, &file)?;
            let mut dirty = false;

            // Op-log replay streams through the read-ahead lane; the
            // drain removes the log's spill file when it drops.
            let mut reader = ops.into_drain()?;
            let mut header = [0u8; 2];
            let mut idx_buf = [0u8; 8];
            let mut passed = scratch::record_buf();
            while reader.read_exact_or_eof(&mut header)? {
                let kind = OpKind::from_u8(header[0]).ok_or_else(|| {
                    RoomyError::InvalidArg(format!("corrupt op tag {}", header[0]))
                })?;
                let fn_id = header[1];
                if !reader.read_exact_or_eof(&mut idx_buf)? {
                    return Err(RoomyError::InvalidArg("truncated op record".into()));
                }
                let idx = u64::from_le_bytes(idx_buf);
                let plen = match kind {
                    OpKind::Update => this.update_passed_len(fn_id)?,
                    OpKind::Access => this.access_passed_len(fn_id)?,
                    other => {
                        return Err(RoomyError::InvalidArg(format!(
                            "unexpected op kind {other:?} in bit-array log"
                        )))
                    }
                };
                passed.resize(plen, 0);
                if plen > 0 && !reader.read_exact_or_eof(&mut passed)? {
                    return Err(RoomyError::InvalidArg("truncated op record".into()));
                }
                let local = idx - b as u64 * this.bsize;
                let cur = this.get_packed(&data, local);
                match kind {
                    OpKind::Update => {
                        let new = {
                            let g = this.updates.read().unwrap();
                            let (_, f) = g.get(fn_id as usize).ok_or_else(|| {
                                RoomyError::UnknownFunc {
                                    structure: format!("RoomyBitArray({})", this.name),
                                    id: fn_id,
                                }
                            })?;
                            f(idx, cur, &passed) & this.mask()
                        };
                        if new != cur {
                            this.set_packed(&mut data, local, new);
                            this.counts[cur as usize].fetch_sub(1, Ordering::Relaxed);
                            this.counts[new as usize].fetch_add(1, Ordering::Relaxed);
                            dirty = true;
                        }
                    }
                    OpKind::Access => {
                        let g = this.accesses.read().unwrap();
                        let (_, f) = g.get(fn_id as usize).ok_or_else(|| {
                            RoomyError::UnknownFunc {
                                structure: format!("RoomyBitArray({})", this.name),
                                id: fn_id,
                            }
                        })?;
                        f(idx, cur, &passed);
                    }
                    _ => unreachable!(),
                }
            }
            drop(reader);
            if dirty {
                write_all_pipelined(disk, &file, &data)?;
            }
            Ok(())
        })
    }

    /// Apply `f(index, value)` to every element (streaming, parallel).
    pub fn map(&self, f: impl Fn(u64, u8) + Sync) -> Result<()> {
        let inner = &self.inner;
        inner.for_owned_buckets("rba.map", |this, b, disk| {
            let nbytes = this.bucket_bytes(b);
            if nbytes == 0 {
                return Ok(());
            }
            let data = read_all_pipelined(disk, this.bucket_file(b))?;
            let base = b as u64 * this.bsize;
            let count = this.bucket_len(b);
            // Word-wise unpack: one u64 load per 64/bits elements instead
            // of a byte load + shift per element.
            bitkernels::for_each_unpacked(&data, this.bits, count, |local, v| {
                f(base + local, v)
            });
            Ok(())
        })
    }

    /// Recompute the per-value histogram from the on-disk buckets with
    /// the word-wise counting kernel ([`bitkernels::histogram`]),
    /// refresh the O(1) counters, and return it. Useful after a restore
    /// or as an integrity cross-check of the incrementally maintained
    /// counts; streams every bucket once.
    pub fn recount(&self) -> Result<Vec<u64>> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        let nvals = 1usize << inner.bits;
        let totals: Vec<AtomicI64> = (0..nvals).map(|_| AtomicI64::new(0)).collect();
        inner.for_owned_buckets("rba.recount", |this, b, disk| {
            let nbytes = this.bucket_bytes(b);
            if nbytes == 0 {
                return Ok(());
            }
            let data = read_all_pipelined(disk, this.bucket_file(b))?;
            let h = bitkernels::histogram(&data, this.bits, this.bucket_len(b));
            for (v, c) in h.iter().enumerate() {
                totals[v].fetch_add(*c as i64, Ordering::Relaxed);
            }
            Ok(())
        })?;
        let out: Vec<u64> =
            totals.iter().map(|c| c.load(Ordering::Relaxed).max(0) as u64).collect();
        for (v, c) in out.iter().enumerate() {
            inner.counts[v].store(*c as i64, Ordering::Relaxed);
        }
        Ok(out)
    }

    /// Combine `src` into this array element-wise with one wide word
    /// sweep per bucket ([`bitkernels::combine_into`]): `Or` unions,
    /// `And` intersects, `AndNot` subtracts (for 1-bit arrays these are
    /// exactly set union / intersection / difference of the set bits).
    /// Both arrays must share geometry (length, width, cluster) and be
    /// fully synced; the histogram is updated from per-bucket deltas
    /// computed with the word-wise counting kernel.
    pub fn combine_from(
        &self,
        src: &RoomyBitArray,
        op: bitkernels::CombineOp,
    ) -> Result<()> {
        let inner = &self.inner;
        let s = &src.inner;
        if inner.len != s.len || inner.bits != s.bits {
            return Err(RoomyError::InvalidArg(format!(
                "combine_from over mismatched geometry: {}×{}b vs {}×{}b",
                inner.len, inner.bits, s.len, s.bits
            )));
        }
        if !inner.staged.is_empty() || !s.staged.is_empty() {
            return Err(RoomyError::InvalidArg(
                "combine_from requires both bit arrays synced (delayed ops pending)".into(),
            ));
        }
        let _write = inner.write_lock.lock().unwrap();
        inner.for_owned_buckets("rba.combine", |this, b, disk| {
            let nbytes = this.bucket_bytes(b);
            if nbytes == 0 {
                return Ok(());
            }
            let mut data = read_all_pipelined(disk, this.bucket_file(b))?;
            let other = read_all_pipelined(disk, s.bucket_file(b))?;
            let count = this.bucket_len(b);
            let before = bitkernels::histogram(&data, this.bits, count);
            bitkernels::combine_into(&mut data, &other, op);
            let after = bitkernels::histogram(&data, this.bits, count);
            for (v, (a, bef)) in after.iter().zip(before.iter()).enumerate() {
                let d = *a as i64 - *bef as i64;
                if d != 0 {
                    this.counts[v].fetch_add(d, Ordering::Relaxed);
                }
            }
            write_all_pipelined(disk, this.bucket_file(b), &data)
        })
    }

    /// Random-access read of one element (**debug/testing**; seeks).
    pub fn fetch(&self, i: u64) -> Result<u8> {
        let inner = &self.inner;
        if i >= inner.len {
            return Err(RoomyError::InvalidArg(format!("index {i} out of bounds")));
        }
        let b = (i / inner.bsize) as u32;
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        let local = i - b as u64 * inner.bsize;
        let per_byte = (8 / inner.bits) as u64;
        let mut r = disk.open_file(inner.bucket_file(b))?;
        r.seek_to(local / per_byte)?;
        let mut byte = [0u8];
        r.read_exact(&mut byte)?;
        let shift = ((local % per_byte) as u8) * inner.bits;
        Ok((byte[0] >> shift) & inner.mask())
    }

    /// Delete all on-disk state.
    pub fn destroy(self) -> Result<()> {
        let dir = self.inner.dir.clone();
        self.inner.ctx.cluster.remove_structure_dirs(dir)
    }
}

impl Checkpointable for RoomyBitArray {
    fn ckpt_meta(&self) -> StructMeta {
        let nvals = 1usize << self.inner.bits;
        StructMeta {
            kind: StructKind::BitArray,
            name: self.inner.name.clone(),
            dir: self.inner.dir.clone(),
            rec_size: 0,
            key_size: 0,
            len: self.inner.len,
            size: 0,
            bits: self.inner.bits,
            sorted: false,
            // bucket files are only ever replaced whole (tmp + rename)
            appendable: false,
            counts: (0..nvals).map(|v| self.count_value(v as u8)).collect(),
        }
    }

    fn ckpt_pending(&self) -> u64 {
        RoomyBitArray::pending_bytes(self)
    }
}

impl BitInner {
    fn mask(&self) -> u8 {
        if self.bits == 8 {
            0xFF
        } else {
            (1u8 << self.bits) - 1
        }
    }

    fn bucket_file(&self, b: u32) -> String {
        format!("{}/b{b}.dat", self.dir)
    }

    /// Elements held by bucket `b`.
    fn bucket_len(&self, b: u32) -> u64 {
        let start = b as u64 * self.bsize;
        if start >= self.len {
            0
        } else {
            self.bsize.min(self.len - start)
        }
    }

    /// Bytes of bucket `b`'s file.
    fn bucket_bytes(&self, b: u32) -> usize {
        let per_byte = (8 / self.bits) as u64;
        (self.bucket_len(b).div_ceil(per_byte)) as usize
    }

    fn get_packed(&self, data: &[u8], local: u64) -> u8 {
        let per_byte = (8 / self.bits) as u64;
        let byte = data[(local / per_byte) as usize];
        let shift = ((local % per_byte) as u8) * self.bits;
        (byte >> shift) & self.mask()
    }

    fn set_packed(&self, data: &mut [u8], local: u64, v: u8) {
        let per_byte = (8 / self.bits) as u64;
        let pos = (local / per_byte) as usize;
        let shift = ((local % per_byte) as u8) * self.bits;
        data[pos] = (data[pos] & !(self.mask() << shift)) | ((v & self.mask()) << shift);
    }

    fn update_passed_len(&self, id: u8) -> Result<usize> {
        self.updates.read().unwrap().get(id as usize).map(|(l, _)| *l).ok_or_else(|| {
            RoomyError::UnknownFunc { structure: format!("RoomyBitArray({})", self.name), id }
        })
    }

    fn access_passed_len(&self, id: u8) -> Result<usize> {
        self.accesses.read().unwrap().get(id as usize).map(|(l, _)| *l).ok_or_else(|| {
            RoomyError::UnknownFunc { structure: format!("RoomyBitArray({})", self.name), id }
        })
    }

    /// Run `f(self, bucket, disk)` for every bucket on the worker pool,
    /// hinting each bucket's file for cross-task prefetch.
    fn for_owned_buckets(
        &self,
        phase: &str,
        f: impl Fn(&Self, u32, &std::sync::Arc<crate::storage::NodeDisk>) -> Result<()> + Sync,
    ) -> Result<()> {
        let _lbl = crate::obs::trace::struct_label(&self.name);
        self.ctx.cluster.run_buckets_hinted(
            phase,
            |b| Some(self.bucket_file(b)),
            |b, disk| f(self, b, disk),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::{prop_check, tmpdir};

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    #[test]
    fn rejects_bad_widths() {
        let t = tmpdir("rba_bad");
        let r = mk(t.path());
        assert!(r.bit_array("x3", 10, 3).is_err());
        assert!(r.bit_array("x0", 10, 0).is_err());
        assert!(r.bit_array("z", 0, 1).is_err());
    }

    #[test]
    fn zero_filled_and_counts() {
        let t = tmpdir("rba_zero");
        let r = mk(t.path());
        let ba = r.bit_array("b", 1000, 2).unwrap();
        assert_eq!(ba.count_value(0), 1000);
        assert_eq!(ba.count_value(1), 0);
        assert_eq!(ba.fetch(999).unwrap(), 0);
    }

    #[test]
    fn set_bits_via_update() {
        let t = tmpdir("rba_set");
        let r = mk(t.path());
        let ba = r.bit_array("b", 100, 1).unwrap();
        let set = ba.register_update(|_i, _cur, _p: &()| 1);
        for i in (0..100).step_by(3) {
            ba.update(i, &(), set).unwrap();
        }
        ba.sync().unwrap();
        assert_eq!(ba.count_value(1), 34);
        assert_eq!(ba.count_value(0), 66);
        assert_eq!(ba.fetch(3).unwrap(), 1);
        assert_eq!(ba.fetch(4).unwrap(), 0);
    }

    #[test]
    fn update_sees_current_value_fifo() {
        let t = tmpdir("rba_fifo");
        let r = mk(t.path());
        let ba = r.bit_array("b", 16, 4).unwrap();
        let inc = ba.register_update(|_i, cur, _p: &()| cur + 1);
        for _ in 0..5 {
            ba.update(7, &(), inc).unwrap();
        }
        ba.sync().unwrap();
        assert_eq!(ba.fetch(7).unwrap(), 5);
        assert_eq!(ba.count_value(5), 1);
    }

    #[test]
    fn result_masked_to_width() {
        let t = tmpdir("rba_mask");
        let r = mk(t.path());
        let ba = r.bit_array("b", 8, 2).unwrap();
        let big = ba.register_update(|_i, _cur, _p: &()| 0xFF);
        ba.update(0, &(), big).unwrap();
        ba.sync().unwrap();
        assert_eq!(ba.fetch(0).unwrap(), 3, "0xFF masked to 2 bits");
    }

    #[test]
    fn access_emits_to_other_structure() {
        // The BFS idiom: update sets a bit, the update fn pushes newly-set
        // indices into a list on another structure.
        let t = tmpdir("rba_emit");
        let r = mk(t.path());
        let ba = r.bit_array("seen", 64, 1).unwrap();
        let next = r.list::<u64>("next").unwrap();
        let next2 = next.clone();
        let visit = ba.register_update(move |i, cur, _p: &()| {
            if cur == 0 {
                next2.add(&i).unwrap();
            }
            1
        });
        ba.update(5, &(), visit).unwrap();
        ba.update(5, &(), visit).unwrap(); // dup in same sync: no second emit
        ba.update(9, &(), visit).unwrap();
        ba.sync().unwrap();
        next.sync().unwrap();
        let mut v = next.collect().unwrap();
        v.sort();
        assert_eq!(v, vec![5, 9]);
    }

    #[test]
    fn map_streams_everything() {
        let t = tmpdir("rba_map");
        let r = mk(t.path());
        let ba = r.bit_array("b", 300, 2).unwrap();
        let set = ba.register_update(|i, _cur, _p: &()| (i % 4) as u8);
        for i in 0..300 {
            ba.update(i, &(), set).unwrap();
        }
        ba.sync().unwrap();
        let bad = std::sync::atomic::AtomicU64::new(0);
        ba.map(|i, v| {
            if v != (i % 4) as u8 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        })
        .unwrap();
        assert_eq!(bad.into_inner(), 0);
        for v in 0..4u8 {
            assert_eq!(ba.count_value(v), 75, "value {v}");
        }
    }

    #[test]
    fn recount_matches_incremental_histogram() {
        let t = tmpdir("rba_recount");
        let r = mk(t.path());
        let ba = r.bit_array("b", 777, 2).unwrap();
        let set = ba.register_update(|i, _cur, _p: &()| ((i * 7) % 4) as u8);
        for i in 0..777 {
            ba.update(i, &(), set).unwrap();
        }
        ba.sync().unwrap();
        let h = ba.recount().unwrap();
        assert_eq!(h.len(), 4);
        for v in 0..4u8 {
            assert_eq!(h[v as usize], ba.count_value(v), "value {v}");
            let expect = (0..777u64).filter(|i| ((i * 7) % 4) as u8 == v).count() as u64;
            assert_eq!(h[v as usize], expect, "value {v}");
        }
    }

    #[test]
    fn combine_from_is_element_wise() {
        use crate::roomy::bitkernels::CombineOp;
        let t = tmpdir("rba_combine");
        let r = mk(t.path());
        let n = 500u64;
        let a_bits: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
        let b_bits: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
        for (op, expect_fn) in [
            (CombineOp::Or, (|a, b| a | b) as fn(bool, bool) -> bool),
            (CombineOp::And, |a, b| a & b),
            (CombineOp::AndNot, |a, b| a & !b),
        ] {
            let name = format!("dst_{op:?}");
            let dst = r.bit_array(&name, n, 1).unwrap();
            let src = r.bit_array(&format!("src_{op:?}"), n, 1).unwrap();
            let av = a_bits.clone();
            let seta = dst.register_update(move |i, _cur, _p: &()| av[i as usize] as u8);
            let bv = b_bits.clone();
            let setb = src.register_update(move |i, _cur, _p: &()| bv[i as usize] as u8);
            for i in 0..n {
                dst.update(i, &(), seta).unwrap();
                src.update(i, &(), setb).unwrap();
            }
            dst.sync().unwrap();
            src.sync().unwrap();
            dst.combine_from(&src, op).unwrap();
            let expect: Vec<bool> =
                (0..n as usize).map(|i| expect_fn(a_bits[i], b_bits[i])).collect();
            let ones = expect.iter().filter(|&&x| x).count() as u64;
            assert_eq!(dst.count_value(1), ones, "{op:?} histogram");
            assert_eq!(dst.count_value(0), n - ones, "{op:?} histogram");
            assert_eq!(dst.recount().unwrap(), vec![n - ones, ones], "{op:?} recount");
            let bad = std::sync::atomic::AtomicU64::new(0);
            dst.map(|i, v| {
                if (v != 0) != expect[i as usize] {
                    bad.fetch_add(1, Ordering::Relaxed);
                }
            })
            .unwrap();
            assert_eq!(bad.into_inner(), 0, "{op:?} element values");
        }
    }

    #[test]
    fn combine_from_rejects_mismatch_and_pending() {
        use crate::roomy::bitkernels::CombineOp;
        let t = tmpdir("rba_combine_bad");
        let r = mk(t.path());
        let a = r.bit_array("a", 64, 1).unwrap();
        let b = r.bit_array("b", 32, 1).unwrap();
        assert!(a.combine_from(&b, CombineOp::Or).is_err(), "length mismatch");
        let c = r.bit_array("c", 64, 2).unwrap();
        assert!(a.combine_from(&c, CombineOp::Or).is_err(), "width mismatch");
        let d = r.bit_array("d", 64, 1).unwrap();
        let set = d.register_update(|_i, _cur, _p: &()| 1);
        d.update(3, &(), set).unwrap();
        assert!(a.combine_from(&d, CombineOp::Or).is_err(), "pending src ops");
        d.sync().unwrap();
        a.combine_from(&d, CombineOp::Or).unwrap();
        assert_eq!(a.count_value(1), 1);
        assert_eq!(a.fetch(3).unwrap(), 1);
    }

    #[test]
    fn prop_packed_roundtrip() {
        prop_check("bit pack/unpack", 30, |rng| {
            let bits = [1u8, 2, 4, 8][rng.range(0, 4)];
            let t = tmpdir("rba_prop");
            let r = mk(t.path());
            let n = rng.range(1, 200) as u64;
            let name = format!("p{}", rng.next_u64());
            let ba = r.bit_array(&name, n, bits).unwrap();
            let mask = if bits == 8 { 0xFF } else { (1u8 << bits) - 1 };
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as u8) & mask).collect();
            let vals2 = vals.clone();
            let set = ba.register_update(move |i, _cur, _p: &()| vals2[i as usize]);
            for i in 0..n {
                ba.update(i, &(), set).unwrap();
            }
            ba.sync().unwrap();
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(ba.fetch(i as u64).unwrap(), v, "bits={bits} i={i}");
            }
            // histogram consistency
            for v in 0..(1u16 << bits) {
                let expect = vals.iter().filter(|&&x| x == v as u8).count() as u64;
                assert_eq!(ba.count_value(v as u8), expect);
            }
        });
    }
}
