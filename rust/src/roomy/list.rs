//! `RoomyList<T>`: a disk-resident, unordered multiset of fixed-size
//! elements.
//!
//! Paper §2/Table 1: `add`/`remove` are delayed; `addAll`, `removeAll`,
//! `removeDupes`, `size`, `map`, `reduce` are immediate. Elements are
//! hash-sharded across buckets by the shared fingerprint, so duplicates of
//! an element always land in the same shard — `removeDupes` and
//! `removeAll` are shard-local external sorts / merges. This is exactly
//! why the paper warns that RoomyList computations "are often dominated by
//! the time to sort the list" (experiment E4 reproduces that asymmetry).
//!
//! Sync semantics: staged `add`s are appended first, then staged `remove`s
//! delete **all occurrences** of each removed element (including ones
//! added in the same sync).

use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::Arc;

use super::element::Element;
use super::funcs::{FuncRegistry, PredId};
use super::ops::{OpKind, StagedOps};
use super::Ctx;
use crate::error::{Result, RoomyError};
use crate::storage::bloom::{DedupFilter, ShardBloom};
use crate::storage::checkpoint::{Checkpointable, StructKind, StructMeta};
use crate::storage::chunkfile::record_count;
use crate::storage::extsort;
use crate::storage::scratch::{self, Arena};
use crate::storage::{NodeDisk, PrefetchReader, WriteBehindWriter};

const SCAN_BATCH: usize = 8192;

/// A distributed disk-backed unordered list. Cheap to clone (shared state).
pub struct RoomyList<T: Element> {
    inner: Arc<ListInner<T>>,
}

impl<T: Element> Clone for RoomyList<T> {
    fn clone(&self) -> Self {
        RoomyList { inner: Arc::clone(&self.inner) }
    }
}

struct ListInner<T: Element> {
    ctx: Ctx,
    name: String,
    dir: String,
    funcs: FuncRegistry,
    staged: Arc<StagedOps>,
    /// Guards shard files against torn concurrent access: rewriting
    /// collectives (`sync`, `add_all`, `remove_all`, `remove_dupes`) take
    /// the write side; streaming reads (`map`, `reduce`, predicate scans)
    /// take the read side. Lists need this — unlike the tmp+rename
    /// structures — because `sync` *appends in place*, so a concurrent
    /// reader could otherwise see a partial record at EOF.
    write_lock: std::sync::RwLock<()>,
    size: AtomicI64,
    /// Whether every shard file is currently sorted (set by
    /// `remove_dupes`, cleared by appends) — lets repeated dedups and
    /// `remove_all` skip re-sorting.
    sorted: AtomicBool,
    /// Per-shard approximate-membership filters
    /// ([`crate::storage::bloom`]); `None` when `bloom_bits_per_key` is
    /// 0. Fed by every append path (`sync_shard` adds, `add_all`),
    /// probed by `remove_all` against the *other* list's filter. RAM
    /// only — never checkpointed, rebuilt on restore.
    bloom: Option<DedupFilter>,
    _t: PhantomData<fn() -> T>,
}

impl<T: Element> RoomyList<T> {
    pub(crate) fn create(ctx: Ctx, name: &str) -> Result<Self> {
        // A freshly created structure must be empty: clear any same-named
        // shard files a killed run left behind — same-root reruns are the
        // normal case now that checkpoints make state durable.
        ctx.cluster.remove_structure_dirs(format!("rl_{name}"))?;
        Self::build(ctx, name)
    }

    fn build(ctx: Ctx, name: &str) -> Result<Self> {
        let dir = format!("rl_{name}");
        let cluster = ctx.cluster.clone();
        let bloom = ctx.dedup_filter();
        let inner = ListInner {
            staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
            funcs: FuncRegistry::new(&format!("RoomyList({name})")),
            write_lock: std::sync::RwLock::new(()),
            ctx,
            name: name.to_string(),
            dir,
            size: AtomicI64::new(0),
            sorted: AtomicBool::new(false),
            bloom,
            _t: PhantomData,
        };
        Ok(RoomyList { inner: Arc::new(inner) })
    }

    /// Re-open a restored list over shard files already on disk
    /// ([`crate::storage::checkpoint`]), reconstituting the in-RAM size
    /// counter and sorted flag from the checkpoint manifest. Registered
    /// predicates do not survive a checkpoint — re-register if needed.
    /// The bloom filters (when enabled) are RAM-only and never
    /// checkpointed; they are rebuilt here from the restored shard
    /// files, so on-disk state stays byte-identical filter on or off.
    pub(crate) fn open_restored(ctx: Ctx, name: &str, size: u64, sorted: bool) -> Result<Self> {
        let list = Self::build(ctx, name)?;
        list.inner.size.store(size as i64, Ordering::Relaxed);
        list.inner.sorted.store(sorted, Ordering::Relaxed);
        list.inner.rebuild_bloom()?;
        Ok(list)
    }

    /// Number of elements, duplicates included (immediate).
    pub fn size(&self) -> u64 {
        self.inner.size.load(Ordering::Relaxed).max(0) as u64
    }

    /// True if the list has no synced elements.
    pub fn is_empty(&self) -> bool {
        self.size() == 0
    }

    /// Structure name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Total staged (not yet synced) delayed-op bytes.
    pub fn pending_bytes(&self) -> u64 {
        self.inner.staged.staged_bytes()
    }

    // ------------------------------------------------------------------
    // Delayed operations
    // ------------------------------------------------------------------

    /// Delayed add of one element.
    pub fn add(&self, elt: &T) -> Result<()> {
        self.stage_elt(OpKind::Add, elt)
    }

    /// Delayed remove of **all occurrences** of `elt`.
    pub fn remove(&self, elt: &T) -> Result<()> {
        self.stage_elt(OpKind::Remove, elt)
    }

    /// Delayed add of a whole slice of elements: encodes them into one
    /// contiguous chunk and routes it through the batched fingerprint
    /// kernels ([`crate::hashfn`]) — one lane sweep instead of one hash
    /// call per element. Staged bytes (and so every later `sync`) are
    /// identical to an [`add`](Self::add) loop.
    pub fn add_batch(&self, elts: &[T]) -> Result<()> {
        let mut chunk = scratch::record_buf();
        chunk.clear();
        chunk.resize(elts.len() * T::SIZE, 0);
        for (e, slot) in elts.iter().zip(chunk.chunks_exact_mut(T::SIZE)) {
            e.write_to(slot);
        }
        super::ops::stage_elt_batch(
            &self.inner.staged,
            &self.inner.ctx.cluster.topology(),
            OpKind::Add,
            &chunk,
            T::SIZE,
        )
    }

    /// Encode `[kind, 0, elt]` into the thread-local buffer (no per-op
    /// allocation) and stage it to the element's shard.
    fn stage_elt(&self, kind: OpKind, elt: &T) -> Result<()> {
        super::ops::with_op_buf(|rec| {
            rec.push(kind as u8);
            rec.push(0);
            let off = rec.len();
            rec.resize(off + T::SIZE, 0);
            elt.write_to(&mut rec[off..]);
            let shard = self.inner.shard_of(&rec[off..off + T::SIZE]);
            self.inner.staged.stage(shard, rec)
        })
    }

    /// Apply staged adds, then staged removes (paper Table 1 `sync`).
    pub fn sync(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.write().unwrap();
        if inner.staged.is_empty() {
            return Ok(());
        }
        let deltas: Vec<(i64, bool)> = inner
            .ctx
            .cluster
            .run_buckets("rl.sync", |b, disk| inner.sync_shard(b, disk))?;
        let total: i64 = deltas.iter().map(|(d, _)| d).sum();
        let appended_any = deltas.iter().any(|(_, a)| *a);
        inner.size.fetch_add(total, Ordering::Relaxed);
        if appended_any {
            inner.sorted.store(false, Ordering::Relaxed);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Immediate operations (paper Table 1)
    // ------------------------------------------------------------------

    /// Append every element of `other` to `self` (immediate `addAll`).
    /// Both lists must have the same element type (enforced by the type
    /// system) and belong to clusters with the same shard count.
    pub fn add_all(&self, other: &RoomyList<T>) -> Result<()> {
        let inner = &self.inner;
        if inner.ctx.cluster.nbuckets() != other.inner.ctx.cluster.nbuckets() {
            return Err(RoomyError::Incompatible(
                "addAll requires identical shard counts".into(),
            ));
        }
        let _write = inner.write_lock.write().unwrap();
        let added: Vec<i64> = inner.ctx.cluster.run_buckets_hinted(
            "rl.add_all",
            |b| Some(other.inner.shard_file(b)),
            |b, disk| {
            let src = other.inner.shard_file(b);
            if !disk.exists(&src) {
                return Ok(0i64);
            }
            // Same fingerprint ⇒ same shard id in both lists; the shard
            // lives on the same node, so this is a local stream-append
            // (read-ahead on the source, write-behind on the target).
            let mut n = 0i64;
            let mut r = PrefetchReader::open(disk, &src, T::SIZE)?;
            let mut w_ = WriteBehindWriter::append(disk, inner.shard_file(b), T::SIZE)?;
            let mut buf = scratch::record_buf();
            loop {
                let got = r.read_batch(&mut buf, SCAN_BATCH)?;
                if got == 0 {
                    break;
                }
                if let Some(bl) = &inner.bloom {
                    bl.insert_batch(b as usize, &buf, T::SIZE);
                }
                w_.push_batch(&buf)?;
                n += got as i64;
            }
            w_.finish()?;
            Ok(n)
            },
        )?;
        inner.size.fetch_add(added.iter().sum::<i64>(), Ordering::Relaxed);
        inner.sorted.store(false, Ordering::Relaxed);
        Ok(())
    }

    /// Remove from `self` every element that occurs in `other`
    /// (immediate `removeAll`; all occurrences are removed).
    pub fn remove_all(&self, other: &RoomyList<T>) -> Result<()> {
        let inner = &self.inner;
        if inner.ctx.cluster.nbuckets() != other.inner.ctx.cluster.nbuckets() {
            return Err(RoomyError::Incompatible(
                "removeAll requires identical shard counts".into(),
            ));
        }
        let _write = inner.write_lock.write().unwrap();
        let ram_budget = inner.ctx.cfg.ram_budget_bytes;
        let sort_chunk = inner.ctx.cfg.sort_chunk_bytes;
        // hint the *other* list's shard: it is streamed first (into the
        // filter set or the sort), before our own shard is touched
        let removed: Vec<i64> = inner.ctx.cluster.run_buckets_hinted(
            "rl.remove_all",
            |b| Some(other.inner.shard_file(b)),
            |b, disk| {
            let mine = inner.shard_file(b);
            let theirs = other.inner.shard_file(b);
            if !disk.exists(&mine) || !disk.exists(&theirs) {
                return Ok(0i64);
            }
            let their_bytes = disk.len(&theirs) as usize;
            let npreds = inner.funcs.npreds();
            // Bloom front: probe `other`'s per-shard filter with our own
            // records before touching `theirs` at all.
            if let Some(ob) = other.inner.bloom.as_ref() {
                if ob.approximate() {
                    // Approximate mode: treat "maybe in other" as "in
                    // other" — rewrite `mine` keeping only records the
                    // filter proves absent from `other`, never reading
                    // `theirs`. False positives (genuinely-new records
                    // dropped) are bounded by the bits-per-key budget
                    // and metered.
                    let dropped =
                        inner.filter_shard(b, disk, |rec| !ob.probe(b as usize, rec))?;
                    inner.ctx.dedup.add_shortcut(their_bytes as u64);
                    inner.ctx.dedup.add_approx_dropped(dropped as u64);
                    return Ok(dropped);
                }
                if their_bytes <= ram_budget {
                    // Exact-backed shortcut: if every record of ours is
                    // *definitely* not in `other`, nothing would be
                    // removed — skip streaming `theirs` and skip the
                    // rewrite (which would reproduce `mine` byte for
                    // byte). Only valid on the hash-set path: the
                    // sort-merge path below rewrites `mine` in sorted
                    // order even when it removes nothing, so skipping
                    // it would change bytes vs the filter-off run.
                    let mut any_maybe = false;
                    inner.scan_shard(b, disk, |rec| {
                        if !any_maybe && ob.probe(b as usize, rec) {
                            any_maybe = true;
                        }
                        Ok(())
                    })?;
                    if !any_maybe {
                        inner.ctx.dedup.add_shortcut(their_bytes as u64);
                        return Ok(0);
                    }
                }
                inner.ctx.dedup.add_fallback();
            }
            if their_bytes <= ram_budget {
                // In-RAM filter set: batch-decode `other`'s shard into a
                // flat arena (read-ahead; adopts the task's prefetch
                // hint), sort it once, binary-search during the
                // stream-rewrite of ours — no per-record `Vec`s.
                let mut del = Arena::new(T::SIZE);
                let mut r = PrefetchReader::open(disk, &theirs, T::SIZE)?;
                let mut buf = scratch::record_buf();
                loop {
                    let got = r.read_batch(&mut buf, SCAN_BATCH)?;
                    if got == 0 {
                        break;
                    }
                    T::decode_chunk_into(&buf, &mut del);
                }
                drop(r);
                del.sort_records();
                inner.filter_shard(b, disk, |rec| !del.contains_sorted(rec))
            } else {
                // Space-limited path: sort both shards, sorted-merge
                // difference (the paper's regime for huge lists).
                let a_sorted = format!("{mine}.diff.a");
                let b_sorted = format!("{mine}.diff.b");
                extsort::sort_file(disk, &mine, &a_sorted, T::SIZE, sort_chunk, false)?;
                extsort::sort_file(disk, &theirs, &b_sorted, T::SIZE, sort_chunk, false)?;
                let before = record_count(disk, &a_sorted, T::SIZE);
                let out = format!("{mine}.diff.out");
                if npreds > 0 {
                    inner.charge_shard(b, disk, -1)?;
                }
                let after = extsort::merge_diff(disk, &a_sorted, &b_sorted, &out, T::SIZE)?;
                disk.rename(&out, &mine)?;
                disk.remove(&a_sorted)?;
                disk.remove(&b_sorted)?;
                if npreds > 0 {
                    inner.charge_shard(b, disk, 1)?;
                }
                Ok(before as i64 - after as i64)
            }
            },
        )?;
        inner.size.fetch_add(-removed.iter().sum::<i64>(), Ordering::Relaxed);
        Ok(())
    }

    /// Remove duplicate elements (immediate `removeDupes`): per-shard
    /// external sort + unique. After this call the list is a set.
    pub fn remove_dupes(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.write().unwrap();
        let sort_chunk = inner.ctx.cfg.sort_chunk_bytes;
        let npreds = inner.funcs.npreds();
        let removed: Vec<i64> = inner.ctx.cluster.run_buckets_hinted(
            "rl.remove_dupes",
            |b| Some(inner.shard_file(b)),
            |b, disk| {
            let file = inner.shard_file(b);
            if !disk.exists(&file) {
                return Ok(0i64);
            }
            let before = record_count(disk, &file, T::SIZE);
            if npreds > 0 {
                inner.charge_shard(b, disk, -1)?;
            }
            let after = extsort::sort_file(disk, &file, &file, T::SIZE, sort_chunk, true)?;
            if npreds > 0 {
                inner.charge_shard(b, disk, 1)?;
            }
            Ok(before as i64 - after as i64)
            },
        )?;
        inner.size.fetch_add(-removed.iter().sum::<i64>(), Ordering::Relaxed);
        inner.sorted.store(true, Ordering::Relaxed);
        Ok(())
    }

    /// Whether all shards are currently sorted (post-`remove_dupes`).
    pub fn is_sorted(&self) -> bool {
        self.inner.sorted.load(Ordering::Relaxed)
    }

    /// Apply `f` to every element (streaming, parallel). `f` may issue
    /// delayed ops on other structures — the paper's BFS `genNext` idiom.
    pub fn map(&self, f: impl Fn(&T) + Sync) -> Result<()> {
        self.inner.for_owned_shards("rl.map", |this, b, disk| {
            this.scan_shard(b, disk, |rec| {
                f(&T::read_from(rec));
                Ok(())
            })
        })
    }

    /// Apply `f` to batches of at most `batch` elements (streaming,
    /// parallel). Batches are accumulated **per shard task**, never
    /// across shards: the batch composition — and therefore the byte
    /// order of any delayed ops `f` issues — depends only on the on-disk
    /// shard contents, not on `num_workers` or the pool schedule. The
    /// batched BFS drivers rely on this for byte-determinism; a shard's
    /// final batch may be short.
    pub fn map_batched(
        &self,
        batch: usize,
        f: impl Fn(&[T]) -> Result<()> + Sync,
    ) -> Result<()> {
        let batch = batch.max(1);
        self.inner.for_owned_shards("rl.map_batched", |this, b, disk| {
            let mut acc: Vec<T> = Vec::with_capacity(batch);
            this.scan_shard(b, disk, |rec| {
                acc.push(T::read_from(rec));
                if acc.len() >= batch {
                    f(&acc)?;
                    acc.clear();
                }
                Ok(())
            })?;
            if !acc.is_empty() {
                f(&acc)?;
            }
            Ok(())
        })
    }

    /// Reduce over all elements (the paper's sum-of-squares example);
    /// `fold`/`merge` must be assoc+comm in effect. Shards reduce
    /// concurrently on the pool; partials merge in shard order, so the
    /// result is independent of `num_workers`.
    pub fn reduce<R: Send>(
        &self,
        identity: impl Fn() -> R + Sync,
        fold: impl Fn(R, &T) -> R + Sync,
        merge: impl Fn(R, R) -> R,
    ) -> Result<R> {
        let inner = &self.inner;
        let _read = inner.write_lock.read().unwrap();
        let partials: Vec<R> = inner.ctx.cluster.run_buckets_hinted(
            "rl.reduce",
            |b| Some(inner.shard_file(b)),
            |b, disk| {
                let mut local = Some(identity());
                inner.scan_shard(b, disk, |rec| {
                    let cur = local.take().expect("reduce accumulator");
                    local = Some(fold(cur, &T::read_from(rec)));
                    Ok(())
                })?;
                Ok(local.take().expect("reduce accumulator"))
            },
        )?;
        let mut it = partials.into_iter();
        let first = it.next().expect("at least one shard");
        Ok(it.fold(first, merge))
    }

    /// Register a predicate; the count is initialized with one scan and
    /// maintained on every synced add/remove afterwards.
    pub fn register_predicate(
        &self,
        f: impl Fn(&T) -> bool + Send + Sync + 'static,
    ) -> Result<PredId> {
        let id = self
            .inner
            .funcs
            .register_pred(Box::new(move |_idx, rec| f(&T::read_from(rec))));
        let inner = &self.inner;
        inner.for_owned_shards("rl.pred_scan", |this, b, disk| {
            this.scan_shard(b, disk, |rec| {
                this.funcs.charge_pred_single(id, 0, rec);
                Ok(())
            })
        })?;
        Ok(id)
    }

    /// Current count for predicate `id` (immediate).
    ///
    /// Note: `remove_dupes`/`remove_all` rewrite shards wholesale; they
    /// adjust predicate counts by re-scanning only the affected shards.
    pub fn predicate_count(&self, id: PredId) -> u64 {
        self.inner.funcs.pred_count(id)
    }

    /// Collect every element into a `Vec` (testing/debug; the whole point
    /// of Roomy is that this usually does not fit in RAM). Each shard
    /// task accumulates into its own buffer and the pool merges them by
    /// shard index — no shared lock on the hot path, and the result
    /// order is shard order regardless of `num_workers` (the PR 2
    /// batched-BFS pattern).
    pub fn collect(&self) -> Result<Vec<T>> {
        let inner = &self.inner;
        let _read = inner.write_lock.read().unwrap();
        let per_shard: Vec<Vec<T>> = inner.ctx.cluster.run_buckets_hinted(
            "rl.collect",
            |b| Some(inner.shard_file(b)),
            |b, disk| {
                let mut acc = Vec::new();
                inner.scan_shard(b, disk, |rec| {
                    acc.push(T::read_from(rec));
                    Ok(())
                })?;
                Ok(acc)
            },
        )?;
        Ok(per_shard.into_iter().flatten().collect())
    }

    /// Delete all on-disk state.
    pub fn destroy(self) -> Result<()> {
        let dir = self.inner.dir.clone();
        self.inner.ctx.cluster.remove_structure_dirs(dir)
    }
}

impl<T: Element> Checkpointable for RoomyList<T> {
    fn ckpt_meta(&self) -> StructMeta {
        StructMeta {
            kind: StructKind::List,
            name: self.inner.name.clone(),
            dir: self.inner.dir.clone(),
            rec_size: T::SIZE,
            key_size: 0,
            len: 0,
            size: self.size(),
            bits: 0,
            sorted: self.is_sorted(),
            // `sync`/`add_all` append shard files in place, so a
            // snapshot must copy them — a hardlink would let the next
            // appends reach back into the committed checkpoint
            appendable: true,
            counts: Vec::new(),
        }
    }

    fn ckpt_pending(&self) -> u64 {
        RoomyList::pending_bytes(self)
    }
}

impl<T: Element> ListInner<T> {
    fn shard_of(&self, elt_bytes: &[u8]) -> u32 {
        self.ctx.cluster.topology().route(elt_bytes)
    }

    /// Re-derive every shard's bloom filter from its on-disk records
    /// (checkpoint restore: filters are RAM-only and never serialized).
    fn rebuild_bloom(&self) -> Result<()> {
        let Some(bloom) = &self.bloom else { return Ok(()) };
        let bits = bloom.bits_per_key();
        self.ctx.cluster.run_buckets("rl.bloom_rebuild", |b, disk| {
            bloom.with_shard(b as usize, |s| {
                *s = ShardBloom::new(bits);
                self.scan_shard(b, disk, |rec| {
                    s.insert(rec);
                    Ok(())
                })
            })
        })?;
        Ok(())
    }

    fn shard_file(&self, b: u32) -> String {
        format!("{}/s{b}.dat", self.dir)
    }

    /// Scan-type collectives announce the shard file each task will
    /// stream, so the pool's per-node schedulers can prefetch the next
    /// shard's first chunk while the current one computes.
    fn for_owned_shards(
        &self,
        phase: &str,
        f: impl Fn(&Self, u32, &Arc<NodeDisk>) -> Result<()> + Sync,
    ) -> Result<()> {
        let _read = self.write_lock.read().unwrap();
        let _lbl = crate::obs::trace::struct_label(&self.name);
        self.ctx.cluster.run_buckets_hinted(
            phase,
            |b| Some(self.shard_file(b)),
            |b, disk| f(self, b, disk),
        )?;
        Ok(())
    }

    fn scan_shard(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        mut f: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let file = self.shard_file(b);
        if !disk.exists(&file) {
            return Ok(());
        }
        let mut r = PrefetchReader::open(disk, &file, T::SIZE)?;
        let mut buf = scratch::record_buf();
        loop {
            let n = r.read_batch(&mut buf, SCAN_BATCH)?;
            if n == 0 {
                return Ok(());
            }
            for rec in buf.chunks_exact(T::SIZE) {
                f(rec)?;
            }
        }
    }

    /// Charge every predicate `sign` for each record in shard `b` (used
    /// around wholesale rewrites like dedup/sort-merge difference).
    fn charge_shard(&self, b: u32, disk: &Arc<NodeDisk>, sign: i64) -> Result<()> {
        self.scan_shard(b, disk, |rec| {
            self.funcs.charge_preds(0, rec, sign);
            Ok(())
        })
    }

    /// Stream-rewrite shard `b`, keeping records where `keep` is true.
    /// Returns the number of records dropped. Charges predicates.
    /// Read-ahead and write-behind overlap here, so a pipelined filter
    /// keeps both disk directions busy at once.
    fn filter_shard(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        keep: impl Fn(&[u8]) -> bool,
    ) -> Result<i64> {
        let file = self.shard_file(b);
        if !disk.exists(&file) {
            return Ok(0);
        }
        let npreds = self.funcs.npreds();
        let tmp = format!("{file}.filter.tmp");
        let mut dropped = 0i64;
        {
            let mut r = PrefetchReader::open(disk, &file, T::SIZE)?;
            let mut w = WriteBehindWriter::create(disk, &tmp, T::SIZE)?;
            let mut buf = scratch::record_buf();
            loop {
                let n = r.read_batch(&mut buf, SCAN_BATCH)?;
                if n == 0 {
                    break;
                }
                for rec in buf.chunks_exact(T::SIZE) {
                    if keep(rec) {
                        w.push(rec)?;
                    } else {
                        dropped += 1;
                        if npreds > 0 {
                            self.funcs.charge_preds(0, rec, -1);
                        }
                    }
                }
            }
            w.finish()?;
        }
        disk.rename(&tmp, &file)?;
        Ok(dropped)
    }

    /// Apply staged ops for shard `b`: adds appended, removes filtered.
    /// Returns (size delta, appended-any).
    fn sync_shard(&self, b: u32, disk: &Arc<NodeDisk>) -> Result<(i64, bool)> {
        let mut ops =
            self.staged.take(b, &self.ctx.cluster, &self.dir, self.ctx.cfg.op_buffer_bytes);
        if ops.is_empty() {
            return ops.clear().map(|_| (0, false));
        }
        let npreds = self.funcs.npreds();
        let mut removes = Arena::new(T::SIZE);
        let mut added = 0i64;
        {
            // Pass 1: append adds, collect removes (into a flat arena —
            // sorted once below, binary-searched during the rewrite).
            // The op log streams back through the read-ahead lane
            // (into_drain), appended elements flush through the
            // write-behind lane; the drain deletes the log's spill file
            // when it drops, error or not.
            let mut reader = ops.into_drain()?;
            let mut header = [0u8; 2];
            let mut elt = scratch::record_buf();
            elt.resize(T::SIZE, 0);
            let mut writer: Option<WriteBehindWriter> = None;
            while reader.read_exact_or_eof(&mut header)? {
                let kind = OpKind::from_u8(header[0]).ok_or_else(|| {
                    RoomyError::InvalidArg(format!("corrupt op tag {}", header[0]))
                })?;
                if !reader.read_exact_or_eof(&mut elt)? {
                    return Err(RoomyError::InvalidArg("truncated op record".into()));
                }
                match kind {
                    OpKind::Add => {
                        if writer.is_none() {
                            writer = Some(WriteBehindWriter::append(
                                disk,
                                self.shard_file(b),
                                T::SIZE,
                            )?);
                        }
                        writer.as_mut().unwrap().push(&elt)?;
                        if let Some(bl) = &self.bloom {
                            bl.insert(b as usize, &elt);
                        }
                        added += 1;
                        if npreds > 0 {
                            self.funcs.charge_preds(0, &elt, 1);
                        }
                    }
                    OpKind::Remove => {
                        removes.push_record(&elt);
                    }
                    other => {
                        return Err(RoomyError::InvalidArg(format!(
                            "unexpected op kind {other:?} in list log"
                        )))
                    }
                }
            }
            if let Some(w) = writer {
                w.finish()?;
            }
        }
        // Pass 2: apply removes (all occurrences).
        let mut removed = 0i64;
        if !removes.is_empty() {
            removes.sort_records();
            removed = self.filter_shard(b, disk, |rec| !removes.contains_sorted(rec))?;
        }
        Ok((added - removed, added > 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    fn sorted_collect(l: &RoomyList<u64>) -> Vec<u64> {
        let mut v = l.collect().unwrap();
        v.sort();
        v
    }

    #[test]
    fn add_sync_size() {
        let t = tmpdir("rl_basic");
        let r = mk(t.path());
        let l = r.list::<u64>("l").unwrap();
        l.add(&1).unwrap();
        l.add(&2).unwrap();
        l.add(&2).unwrap();
        assert_eq!(l.size(), 0, "add is delayed");
        l.sync().unwrap();
        assert_eq!(l.size(), 3);
        assert_eq!(sorted_collect(&l), vec![1, 2, 2]);
    }

    #[test]
    fn add_batch_matches_scalar_adds() {
        let t = tmpdir("rl_add_batch");
        let r = mk(t.path());
        let vals: Vec<u64> = (0..500).map(|i| i * 17 + 3).collect();
        let a = r.list::<u64>("a").unwrap();
        a.add_batch(&vals).unwrap();
        a.sync().unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in &vals {
            b.add(v).unwrap();
        }
        b.sync().unwrap();
        assert_eq!(a.size(), b.size());
        assert_eq!(sorted_collect(&a), sorted_collect(&b));
    }

    #[test]
    fn remove_all_occurrences_in_same_sync() {
        let t = tmpdir("rl_remove");
        let r = mk(t.path());
        let l = r.list::<u64>("l").unwrap();
        l.add(&5).unwrap();
        l.sync().unwrap();
        l.add(&5).unwrap(); // second occurrence, same sync as remove
        l.add(&6).unwrap();
        l.remove(&5).unwrap();
        l.sync().unwrap();
        assert_eq!(sorted_collect(&l), vec![6]);
        assert_eq!(l.size(), 1);
    }

    #[test]
    fn remove_dupes_makes_set() {
        let t = tmpdir("rl_dupes");
        let r = mk(t.path());
        let l = r.list::<u64>("l").unwrap();
        for v in [3u64, 1, 3, 2, 1, 3, 99] {
            l.add(&v).unwrap();
        }
        l.sync().unwrap();
        assert!(!l.is_sorted());
        l.remove_dupes().unwrap();
        assert!(l.is_sorted());
        assert_eq!(l.size(), 4);
        assert_eq!(sorted_collect(&l), vec![1, 2, 3, 99]);
        // idempotent
        l.remove_dupes().unwrap();
        assert_eq!(l.size(), 4);
    }

    #[test]
    fn add_all_appends_everything() {
        let t = tmpdir("rl_addall");
        let r = mk(t.path());
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..100u64 {
            a.add(&v).unwrap();
            b.add(&(v + 50)).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.add_all(&b).unwrap();
        assert_eq!(a.size(), 200);
        let mut expect: Vec<u64> = (0..100).chain(50..150).collect();
        expect.sort();
        assert_eq!(sorted_collect(&a), expect);
        // b unchanged
        assert_eq!(b.size(), 100);
    }

    #[test]
    fn remove_all_hashset_path() {
        let t = tmpdir("rl_removeall");
        let r = mk(t.path());
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..100u64 {
            a.add(&v).unwrap();
        }
        a.add(&8).unwrap(); // duplicate of an even: both occurrences must go
        for v in (0..100u64).step_by(2) {
            b.add(&v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.remove_all(&b).unwrap();
        let expect: Vec<u64> = (0..100).filter(|v| v % 2 == 1).collect();
        assert_eq!(sorted_collect(&a), expect);
        assert_eq!(a.size(), 50);
    }

    #[test]
    fn remove_all_sort_merge_path() {
        let t = tmpdir("rl_removeall_sort");
        let mut cfg = crate::RoomyConfig::for_testing(t.path());
        cfg.ram_budget_bytes = 1; // force the sort-merge path
        let r = Roomy::open(cfg).unwrap();
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..100u64 {
            a.add(&v).unwrap();
        }
        a.add(&8).unwrap();
        for v in (0..100u64).step_by(2) {
            b.add(&v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.remove_all(&b).unwrap();
        let expect: Vec<u64> = (0..100).filter(|v| v % 2 == 1).collect();
        assert_eq!(sorted_collect(&a), expect);
        assert_eq!(a.size(), 50);
    }

    #[test]
    fn map_batched_sees_every_element_once_in_shard_batches() {
        let t = tmpdir("rl_map_batched");
        let r = mk(t.path());
        let l = r.list::<u64>("l").unwrap();
        let n = 1000u64;
        for v in 0..n {
            l.add(&v).unwrap();
        }
        l.sync().unwrap();
        let seen = std::sync::Mutex::new(Vec::new());
        let batches = std::sync::atomic::AtomicU64::new(0);
        l.map_batched(37, |batch| {
            assert!(!batch.is_empty() && batch.len() <= 37);
            batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            seen.lock().unwrap().extend_from_slice(batch);
            Ok(())
        })
        .unwrap();
        let mut seen = seen.into_inner().unwrap();
        seen.sort();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
        // enough elements that batching actually kicked in
        assert!(batches.into_inner() >= (n / 37), "batches too coarse");
    }

    #[test]
    fn map_and_reduce_sum_of_squares() {
        // the paper's reduce example
        let t = tmpdir("rl_reduce");
        let r = mk(t.path());
        let l = r.list::<i64>("l").unwrap();
        for v in -10i64..=10 {
            l.add(&v).unwrap();
        }
        l.sync().unwrap();
        let sumsq = l
            .reduce(|| 0i64, |acc, v| acc + v * v, |a, b| a + b)
            .unwrap();
        assert_eq!(sumsq, (-10i64..=10).map(|v| v * v).sum::<i64>());
    }

    #[test]
    fn predicate_counts_maintained() {
        let t = tmpdir("rl_pred");
        let r = mk(t.path());
        let l = r.list::<u64>("l").unwrap();
        l.add(&4).unwrap();
        l.sync().unwrap();
        let even = l.register_predicate(|v| v % 2 == 0).unwrap();
        assert_eq!(l.predicate_count(even), 1);
        l.add(&5).unwrap();
        l.add(&6).unwrap();
        l.sync().unwrap();
        assert_eq!(l.predicate_count(even), 2);
        l.remove(&4).unwrap();
        l.sync().unwrap();
        assert_eq!(l.predicate_count(even), 1);
    }

    #[test]
    fn large_list_spills_and_survives() {
        let t = tmpdir("rl_large");
        let mut cfg = crate::RoomyConfig::for_testing(t.path());
        cfg.op_buffer_bytes = 256; // force staging spills
        let r = Roomy::open(cfg).unwrap();
        let l = r.list::<u64>("l").unwrap();
        let n = 20_000u64;
        for v in 0..n {
            l.add(&(v % 1000)).unwrap();
        }
        l.sync().unwrap();
        assert_eq!(l.size(), n);
        l.remove_dupes().unwrap();
        assert_eq!(l.size(), 1000);
    }

    fn mk_bloom(root: &std::path::Path, approx: bool) -> Roomy {
        let mut cfg = crate::RoomyConfig::for_testing(root);
        cfg.bloom_bits_per_key = 10;
        cfg.bloom_approximate = approx;
        Roomy::open(cfg).unwrap()
    }

    #[test]
    fn bloom_exact_remove_all_matches_plain() {
        let t = tmpdir("rl_bloom_exact");
        let r = mk_bloom(t.path(), false);
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..500u64 {
            a.add(&v).unwrap();
        }
        for v in (0..500u64).step_by(2) {
            b.add(&v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.remove_all(&b).unwrap();
        let expect: Vec<u64> = (0..500).filter(|v| v % 2 == 1).collect();
        assert_eq!(sorted_collect(&a), expect);
        let snap = r.dedup_snapshot();
        assert!(snap.probes > 0, "filter was never probed");
        assert_eq!(snap.approx_dropped, 0, "exact mode must never approx-drop");
    }

    #[test]
    fn bloom_shortcut_skips_exact_pass_on_disjoint_lists() {
        let t = tmpdir("rl_bloom_skip");
        let r = mk_bloom(t.path(), false);
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..500u64 {
            a.add(&v).unwrap();
            b.add(&(v + 10_000)).unwrap(); // fully disjoint
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.remove_all(&b).unwrap();
        assert_eq!(a.size(), 500, "disjoint remove_all must remove nothing");
        let snap = r.dedup_snapshot();
        assert!(snap.shortcuts > 0, "no shard skipped its exact pass: {snap:?}");
        assert!(snap.bytes_avoided > 0);
    }

    #[test]
    fn bloom_approximate_remove_all_never_reads_theirs() {
        let t = tmpdir("rl_bloom_approx");
        let r = mk_bloom(t.path(), true);
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        for v in 0..500u64 {
            a.add(&v).unwrap();
        }
        for v in (0..500u64).step_by(2) {
            b.add(&v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();
        a.remove_all(&b).unwrap();
        // Every even is in b's filter (no false negatives), so at most
        // the odds survive; false positives may drop a few odds too.
        let got = sorted_collect(&a);
        assert!(got.iter().all(|v| v % 2 == 1), "an even survived: {got:?}");
        assert!(got.len() >= 200, "implausibly many false positives: {}", got.len());
        let snap = r.dedup_snapshot();
        assert!(snap.shortcuts > 0, "approx mode always skips the exact pass");
    }

    #[test]
    fn destroy_removes_dirs() {
        let t = tmpdir("rl_destroy");
        let r = mk(t.path());
        let l = r.list::<u64>("l").unwrap();
        l.add(&1).unwrap();
        l.sync().unwrap();
        l.destroy().unwrap();
        for w in 0..r.cluster().nworkers() {
            assert!(!r.cluster().disk(w).exists("rl_l"));
        }
    }
}
