//! Fixed-size element encoding.
//!
//! Roomy data structures store *fixed-size byte records* (paper §2: every
//! structure is created with an `eltSize`). The [`Element`] trait maps Rust
//! values onto those records.
//!
//! Integer impls use **big-endian** encodings so that the byte-wise
//! (memcmp) order used by the external sort coincides with numeric order —
//! the sort only needs an order consistent with equality, but numeric
//! order makes sorted files human-auditable and enables range debugging.

/// A value storable in a Roomy structure: fixed size, plain bytes.
pub trait Element: Clone + Send + Sync + 'static {
    /// Encoded size in bytes. Must be > 0.
    const SIZE: usize;

    /// Serialize into `out` (exactly `SIZE` bytes).
    fn write_to(&self, out: &mut [u8]);

    /// Deserialize from `buf` (exactly `SIZE` bytes).
    fn read_from(buf: &[u8]) -> Self;

    /// Convenience: encode to an owned vector.
    fn to_bytes(&self) -> Vec<u8> {
        let mut v = vec![0u8; Self::SIZE];
        self.write_to(&mut v);
        v
    }
}

macro_rules! impl_element_int {
    ($($t:ty),*) => {$(
        impl Element for $t {
            const SIZE: usize = std::mem::size_of::<$t>();
            #[inline]
            fn write_to(&self, out: &mut [u8]) {
                out.copy_from_slice(&self.to_be_bytes());
            }
            #[inline]
            fn read_from(buf: &[u8]) -> Self {
                <$t>::from_be_bytes(buf.try_into().expect("element size"))
            }
        }
    )*};
}

impl_element_int!(u8, u16, u32, u64, u128);

// Signed integers: flip the sign bit so memcmp order == numeric order.
macro_rules! impl_element_signed {
    ($($t:ty => $u:ty),*) => {$(
        impl Element for $t {
            const SIZE: usize = std::mem::size_of::<$t>();
            #[inline]
            fn write_to(&self, out: &mut [u8]) {
                let biased = (*self as $u) ^ (1 << (<$t>::BITS - 1));
                out.copy_from_slice(&biased.to_be_bytes());
            }
            #[inline]
            fn read_from(buf: &[u8]) -> Self {
                let biased = <$u>::from_be_bytes(buf.try_into().expect("element size"));
                (biased ^ (1 << (<$t>::BITS - 1))) as $t
            }
        }
    )*};
}

impl_element_signed!(i8 => u8, i16 => u16, i32 => u32, i64 => u64);

impl<const K: usize> Element for [u8; K] {
    const SIZE: usize = K;
    #[inline]
    fn write_to(&self, out: &mut [u8]) {
        out.copy_from_slice(self);
    }
    #[inline]
    fn read_from(buf: &[u8]) -> Self {
        buf.try_into().expect("element size")
    }
}

impl<A: Element, B: Element> Element for (A, B) {
    const SIZE: usize = A::SIZE + B::SIZE;
    #[inline]
    fn write_to(&self, out: &mut [u8]) {
        self.0.write_to(&mut out[..A::SIZE]);
        self.1.write_to(&mut out[A::SIZE..]);
    }
    #[inline]
    fn read_from(buf: &[u8]) -> Self {
        (A::read_from(&buf[..A::SIZE]), B::read_from(&buf[A::SIZE..]))
    }
}

/// The unit element — occasionally useful as a set-style hash-table value.
/// Encoded as a single zero byte (zero-size records are not representable).
impl Element for () {
    const SIZE: usize = 1;
    #[inline]
    fn write_to(&self, out: &mut [u8]) {
        out[0] = 0;
    }
    #[inline]
    fn read_from(_buf: &[u8]) -> Self {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop_check;

    fn roundtrip<T: Element + PartialEq + std::fmt::Debug>(v: T) {
        let b = v.to_bytes();
        assert_eq!(b.len(), T::SIZE);
        assert_eq!(T::read_from(&b), v);
    }

    #[test]
    fn unsigned_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(0xDEADu16);
        roundtrip(0xDEADBEEFu32);
        roundtrip(u64::MAX);
        roundtrip(u128::MAX - 7);
    }

    #[test]
    fn signed_roundtrip() {
        for v in [i64::MIN, -1, 0, 1, i64::MAX] {
            roundtrip(v);
        }
        for v in [i32::MIN, -42, 0, 7, i32::MAX] {
            roundtrip(v);
        }
    }

    #[test]
    fn array_and_tuple_roundtrip() {
        roundtrip([1u8, 2, 3, 4, 5]);
        roundtrip((0xAAu32, 0xBBu64));
        roundtrip(((1u8, 2u16), 3u32));
        roundtrip(());
        assert_eq!(<(u32, u64)>::SIZE, 12);
    }

    #[test]
    fn unsigned_byte_order_is_numeric() {
        prop_check("u64 memcmp == numeric", 50, |rng| {
            let a = rng.next_u64();
            let b = rng.next_u64();
            assert_eq!(a.to_bytes().cmp(&b.to_bytes()), a.cmp(&b));
        });
    }

    #[test]
    fn signed_byte_order_is_numeric() {
        prop_check("i64 memcmp == numeric", 50, |rng| {
            let a = rng.next_u64() as i64;
            let b = rng.next_u64() as i64;
            assert_eq!(a.to_bytes().cmp(&b.to_bytes()), a.cmp(&b));
        });
        // explicit boundary cases
        let order = [i64::MIN, -2, -1, 0, 1, 2, i64::MAX];
        for w in order.windows(2) {
            assert!(w[0].to_bytes() < w[1].to_bytes(), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn tuple_orders_lexicographically() {
        let a = (1u32, 9u32).to_bytes();
        let b = (2u32, 0u32).to_bytes();
        assert!(a < b);
    }
}
