//! Fixed-size element encoding.
//!
//! Roomy data structures store *fixed-size byte records* (paper §2: every
//! structure is created with an `eltSize`). The [`Element`] trait maps Rust
//! values onto those records.
//!
//! Integer impls use **big-endian** encodings so that the byte-wise
//! (memcmp) order used by the external sort coincides with numeric order —
//! the sort only needs an order consistent with equality, but numeric
//! order makes sorted files human-auditable and enables range debugging.
//!
//! The **batch codec** methods ([`Element::decode_chunk_into`] /
//! [`Element::encode_from`]) move whole chunks between disk form and a
//! flat [`Arena`], so hot loops iterate borrowed `&[u8]` slices instead
//! of materializing a `Vec` per record. The defaults are correct for
//! every fixed-size encoding (records on disk are already the arena
//! layout — the decode is a bulk copy); impls with a faster path may
//! override. The bytes produced are identical to record-at-a-time
//! `write_to`, so fingerprint routing via
//! [`crate::hashfn::fp_bytes`] and every determinism pin are
//! unaffected.

use crate::storage::scratch::Arena;

/// A value storable in a Roomy structure: fixed size, plain bytes.
pub trait Element: Clone + Send + Sync + 'static {
    /// Encoded size in bytes. Must be > 0.
    const SIZE: usize;

    /// Serialize into `out` (exactly `SIZE` bytes).
    fn write_to(&self, out: &mut [u8]);

    /// Deserialize from `buf` (exactly `SIZE` bytes).
    fn read_from(buf: &[u8]) -> Self;

    /// Convenience: encode to an owned vector.
    fn to_bytes(&self) -> Vec<u8> {
        let mut v = vec![0u8; Self::SIZE];
        self.write_to(&mut v);
        v
    }

    /// Re-encode into a reusable buffer (the pooled replacement for
    /// [`Element::to_bytes`] in hot loops): clears `out` and leaves
    /// exactly `SIZE` bytes in it.
    #[inline]
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.resize(Self::SIZE, 0);
        self.write_to(out);
    }

    /// Batch-decode a whole chunk of encoded records (`chunk.len()`
    /// must be a multiple of `SIZE`) into `arena`, whose record size
    /// must match. Records land end to end; iterate them as borrowed
    /// slices via [`Arena::iter`]. Fixed-size records are already the
    /// arena layout, so the default is one bulk copy.
    #[inline]
    fn decode_chunk_into(chunk: &[u8], arena: &mut Arena) {
        debug_assert_eq!(arena.rec_size(), Self::SIZE, "arena record size mismatch");
        arena.extend_raw(chunk);
    }

    /// Batch-encode `items` by appending `items.len() × SIZE` bytes to
    /// `out`. One resize, then in-place `write_to` per record — no
    /// intermediate allocations.
    #[inline]
    fn encode_from(items: &[Self], out: &mut Vec<u8>) {
        let start = out.len();
        out.resize(start + items.len() * Self::SIZE, 0);
        for (i, it) in items.iter().enumerate() {
            let off = start + i * Self::SIZE;
            it.write_to(&mut out[off..off + Self::SIZE]);
        }
    }
}

macro_rules! impl_element_int {
    ($($t:ty),*) => {$(
        impl Element for $t {
            const SIZE: usize = std::mem::size_of::<$t>();
            #[inline]
            fn write_to(&self, out: &mut [u8]) {
                out.copy_from_slice(&self.to_be_bytes());
            }
            #[inline]
            fn read_from(buf: &[u8]) -> Self {
                <$t>::from_be_bytes(buf.try_into().expect("element size"))
            }
        }
    )*};
}

impl_element_int!(u8, u16, u32, u64, u128);

// Signed integers: flip the sign bit so memcmp order == numeric order.
macro_rules! impl_element_signed {
    ($($t:ty => $u:ty),*) => {$(
        impl Element for $t {
            const SIZE: usize = std::mem::size_of::<$t>();
            #[inline]
            fn write_to(&self, out: &mut [u8]) {
                let biased = (*self as $u) ^ (1 << (<$t>::BITS - 1));
                out.copy_from_slice(&biased.to_be_bytes());
            }
            #[inline]
            fn read_from(buf: &[u8]) -> Self {
                let biased = <$u>::from_be_bytes(buf.try_into().expect("element size"));
                (biased ^ (1 << (<$t>::BITS - 1))) as $t
            }
        }
    )*};
}

impl_element_signed!(i8 => u8, i16 => u16, i32 => u32, i64 => u64);

impl<const K: usize> Element for [u8; K] {
    const SIZE: usize = K;
    #[inline]
    fn write_to(&self, out: &mut [u8]) {
        out.copy_from_slice(self);
    }
    #[inline]
    fn read_from(buf: &[u8]) -> Self {
        buf.try_into().expect("element size")
    }
}

impl<A: Element, B: Element> Element for (A, B) {
    const SIZE: usize = A::SIZE + B::SIZE;
    #[inline]
    fn write_to(&self, out: &mut [u8]) {
        self.0.write_to(&mut out[..A::SIZE]);
        self.1.write_to(&mut out[A::SIZE..]);
    }
    #[inline]
    fn read_from(buf: &[u8]) -> Self {
        (A::read_from(&buf[..A::SIZE]), B::read_from(&buf[A::SIZE..]))
    }
}

/// The unit element — occasionally useful as a set-style hash-table value.
/// Encoded as a single zero byte (zero-size records are not representable).
impl Element for () {
    const SIZE: usize = 1;
    #[inline]
    fn write_to(&self, out: &mut [u8]) {
        out[0] = 0;
    }
    #[inline]
    fn read_from(_buf: &[u8]) -> Self {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop_check;

    fn roundtrip<T: Element + PartialEq + std::fmt::Debug>(v: T) {
        let b = v.to_bytes();
        assert_eq!(b.len(), T::SIZE);
        assert_eq!(T::read_from(&b), v);
    }

    #[test]
    fn unsigned_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(0xDEADu16);
        roundtrip(0xDEADBEEFu32);
        roundtrip(u64::MAX);
        roundtrip(u128::MAX - 7);
    }

    #[test]
    fn signed_roundtrip() {
        for v in [i64::MIN, -1, 0, 1, i64::MAX] {
            roundtrip(v);
        }
        for v in [i32::MIN, -42, 0, 7, i32::MAX] {
            roundtrip(v);
        }
    }

    #[test]
    fn array_and_tuple_roundtrip() {
        roundtrip([1u8, 2, 3, 4, 5]);
        roundtrip((0xAAu32, 0xBBu64));
        roundtrip(((1u8, 2u16), 3u32));
        roundtrip(());
        assert_eq!(<(u32, u64)>::SIZE, 12);
    }

    #[test]
    fn unsigned_byte_order_is_numeric() {
        prop_check("u64 memcmp == numeric", 50, |rng| {
            let a = rng.next_u64();
            let b = rng.next_u64();
            assert_eq!(a.to_bytes().cmp(&b.to_bytes()), a.cmp(&b));
        });
    }

    #[test]
    fn signed_byte_order_is_numeric() {
        prop_check("i64 memcmp == numeric", 50, |rng| {
            let a = rng.next_u64() as i64;
            let b = rng.next_u64() as i64;
            assert_eq!(a.to_bytes().cmp(&b.to_bytes()), a.cmp(&b));
        });
        // explicit boundary cases
        let order = [i64::MIN, -2, -1, 0, 1, 2, i64::MAX];
        for w in order.windows(2) {
            assert!(w[0].to_bytes() < w[1].to_bytes(), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn tuple_orders_lexicographically() {
        let a = (1u32, 9u32).to_bytes();
        let b = (2u32, 0u32).to_bytes();
        assert!(a < b);
    }

    #[test]
    fn batch_codec_matches_record_at_a_time() {
        let items: Vec<u64> = vec![3, 1, u64::MAX, 0, 42];
        let mut batch = Vec::new();
        Element::encode_from(&items, &mut batch);
        let mut one_by_one = Vec::new();
        for it in &items {
            one_by_one.extend_from_slice(&it.to_bytes());
        }
        assert_eq!(batch, one_by_one);

        let mut arena = Arena::new(u64::SIZE);
        u64::decode_chunk_into(&batch, &mut arena);
        assert_eq!(arena.len(), items.len());
        let back: Vec<u64> = arena.iter().map(u64::read_from).collect();
        assert_eq!(back, items);
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut buf = Vec::new();
        7u32.encode_into(&mut buf);
        assert_eq!(buf, 7u32.to_bytes());
        let cap = buf.capacity();
        9u32.encode_into(&mut buf);
        assert_eq!(buf, 9u32.to_bytes());
        assert_eq!(buf.capacity(), cap);
    }
}
