//! Delayed-operation records and per-bucket staging.
//!
//! Every *random-access* operation in Roomy is delayed (paper §2): encoded
//! as a compact record, staged into the buffer of the bucket that owns the
//! target datum, and applied in batch when the structure is synced. The
//! staging buffers spill to the owning node's disk, so an unbounded number
//! of delayed ops uses bounded RAM.

use std::sync::{Arc, Mutex, MutexGuard, Weak};

use crate::cluster::{Cluster, Topology};
use crate::error::Result;
use crate::storage::SpillBuffer;

/// Operation tags. The per-structure sync loops interpret these; mixing
/// kinds in one FIFO stream preserves issue order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum OpKind {
    /// Array/bit-array element update via registered function.
    Update = 0,
    /// Array/bit-array element access via registered function.
    Access = 1,
    /// Hash-table insert of (key, value).
    HtInsert = 2,
    /// Hash-table remove by key.
    HtRemove = 3,
    /// Hash-table access via registered function.
    HtAccess = 4,
    /// Hash-table update via registered function.
    HtUpdate = 5,
    /// List add element.
    Add = 6,
    /// List remove-all-occurrences of element.
    Remove = 7,
}

impl OpKind {
    pub fn from_u8(v: u8) -> Option<OpKind> {
        use OpKind::*;
        Some(match v {
            0 => Update,
            1 => Access,
            2 => HtInsert,
            3 => HtRemove,
            4 => HtAccess,
            5 => HtUpdate,
            6 => Add,
            7 => Remove,
            _ => return None,
        })
    }
}

thread_local! {
    /// Reusable encode buffer: delayed-op issue is the hottest user-facing
    /// path (millions of calls per sync), so record encoding must not
    /// allocate (§Perf P2). This is *per-worker* scratch under the pool
    /// execution model: [`crate::runtime::pool`] workers are distinct
    /// scoped threads, so each owns a private instance for the duration of
    /// a collective — no sharing, no contention.
    static ENCODE_BUF: std::cell::RefCell<Vec<u8>> =
        std::cell::RefCell::new(Vec::with_capacity(256));
}

/// Run `f` with a cleared thread-local scratch buffer for op encoding.
pub fn with_op_buf<R>(f: impl FnOnce(&mut Vec<u8>) -> R) -> R {
    ENCODE_BUF.with(|b| {
        let mut b = b.borrow_mut();
        b.clear();
        f(&mut b)
    })
}

/// Encode an indexed (array-style) op: `[kind, fn_id, idx u64 LE, passed]`.
pub fn encode_indexed(out: &mut Vec<u8>, kind: OpKind, fn_id: u8, idx: u64, passed: &[u8]) {
    out.clear();
    out.push(kind as u8);
    out.push(fn_id);
    out.extend_from_slice(&idx.to_le_bytes());
    out.extend_from_slice(passed);
}

/// Encode a keyed (hash-table-style) op: `[kind, fn_id, key, payload]`.
/// `fn_id` is 0 for insert/remove.
pub fn encode_keyed(out: &mut Vec<u8>, kind: OpKind, fn_id: u8, key: &[u8], payload: &[u8]) {
    out.clear();
    out.push(kind as u8);
    out.push(fn_id);
    out.extend_from_slice(key);
    out.extend_from_slice(payload);
}

/// Encode a bare element op (list add/remove): `[kind, 0, elt]`.
pub fn encode_elt(out: &mut Vec<u8>, kind: OpKind, elt: &[u8]) {
    out.clear();
    out.push(kind as u8);
    out.push(0);
    out.extend_from_slice(elt);
}

thread_local! {
    /// Reusable bucket-route scratch for the batched staging path.
    static ROUTE_BUF: std::cell::RefCell<Vec<u32>> =
        std::cell::RefCell::new(Vec::with_capacity(1024));
}

/// Bulk delayed-op issue: route a whole chunk of fixed-size elements in
/// **one batched fingerprint sweep** ([`Topology::route_batch_into`]) and
/// stage one `[kind, 0, elt]` record per element into its bucket. Staging
/// order within the chunk is element order, so the staged bytes — and
/// therefore every downstream sync — are identical to a per-element
/// `encode_elt` + `stage` loop; only the hash work is batched.
pub fn stage_elt_batch(
    staged: &StagedOps,
    topo: &Topology,
    kind: OpKind,
    batch: &[u8],
    rec_size: usize,
) -> Result<()> {
    ROUTE_BUF.with(|r| {
        let mut routes = r.borrow_mut();
        routes.clear();
        topo.route_batch_into(batch, rec_size, &mut routes);
        with_op_buf(|buf| {
            for (elt, &b) in batch.chunks_exact(rec_size).zip(routes.iter()) {
                encode_elt(buf, kind, elt);
                staged.stage(b, buf)?;
            }
            Ok(())
        })
    })
}

/// Per-bucket spillable staging for one structure.
///
/// Issue path: `stage(bucket, record)` locks only that bucket's buffer —
/// unless the calling thread is inside a [`crate::runtime::pool`] task,
/// in which case the record is diverted into that task's capture log
/// (spill-backed under a flat per-task budget, so in-collective issue is
/// space-bounded too) and replayed (via [`StagedOps::stage_direct`])
/// after the collective's barrier in deterministic (task, destination,
/// issue) order — each destination's buffers see exactly the serial byte
/// order.
///
/// Sync path: `take(bucket)` swaps the buffer for a fresh one under the
/// lock and returns the full old buffer — ops staged during the same sync
/// (e.g. by access functions) are replayed post-barrier into the fresh
/// buffer and processed by the *next* sync, never lost.
pub struct StagedOps {
    states: Vec<Mutex<SlotState>>,
    /// Self-reference handed to the pool's capture log, which must hold
    /// the staging alive until replay.
    weak_self: Weak<StagedOps>,
}

struct SlotState {
    buf: SpillBuffer,
    gen: u64,
}

impl StagedOps {
    /// One staging slot per bucket; slot `b` spills to the disk of the node
    /// owning bucket `b`, under `<struct_dir>/stage<b>.<gen>.spill`.
    pub fn new(cluster: &Cluster, struct_dir: &str, threshold: usize) -> Arc<Self> {
        let nb = cluster.nbuckets();
        let mut states = Vec::with_capacity(nb as usize);
        for b in 0..nb {
            let disk = Arc::clone(cluster.disk(cluster.owner(b)));
            let rel = format!("{struct_dir}/stage{b}.0.spill");
            states.push(Mutex::new(SlotState {
                buf: SpillBuffer::new(disk, rel, threshold),
                gen: 0,
            }));
        }
        Arc::new_cyclic(|weak_self| StagedOps { states, weak_self: weak_self.clone() })
    }

    /// Number of staging slots (== bucket count).
    pub fn nbuckets(&self) -> usize {
        self.states.len()
    }

    /// Append `record` to bucket `b`'s staging buffer — or, inside a pool
    /// task, to the task's capture log for deterministic post-barrier
    /// replay.
    pub fn stage(&self, b: u32, record: &[u8]) -> Result<()> {
        if crate::runtime::pool::capture_active() {
            if let Some(me) = self.weak_self.upgrade() {
                if crate::runtime::pool::try_capture(&me, b, record)? {
                    return Ok(());
                }
            }
        }
        self.stage_direct(b, record)
    }

    /// Append `record` to bucket `b`'s staging buffer unconditionally
    /// (bypasses capture; used by the pool's replay).
    pub(crate) fn stage_direct(&self, b: u32, record: &[u8]) -> Result<()> {
        let mut g = self.lock_slot(b);
        g.buf.push(record)
    }

    /// True if no bucket has staged bytes.
    pub fn is_empty(&self) -> bool {
        self.states.iter().all(|s| s.lock().unwrap().buf.is_empty())
    }

    /// Total staged bytes across buckets.
    pub fn staged_bytes(&self) -> u64 {
        self.states.iter().map(|s| s.lock().unwrap().buf.len_bytes()).sum()
    }

    /// Peak RAM currently held by staging buffers (space-budget tests).
    pub fn ram_bytes(&self) -> usize {
        self.states.iter().map(|s| s.lock().unwrap().buf.ram_bytes()).sum()
    }

    /// Swap out bucket `b`'s staged ops for processing. The returned
    /// buffer is owned by the caller, who should [`SpillBuffer::clear`] it
    /// after applying (dropping without clear leaks the spill file until
    /// structure teardown).
    pub fn take(&self, b: u32, cluster: &Cluster, struct_dir: &str, threshold: usize) -> SpillBuffer {
        let mut g = self.lock_slot(b);
        let gen = g.gen + 1;
        let disk = Arc::clone(cluster.disk(cluster.owner(b)));
        let rel = format!("{struct_dir}/stage{b}.{gen}.spill");
        let fresh = SpillBuffer::new(disk, rel, threshold);
        g.gen = gen;
        std::mem::replace(&mut g.buf, fresh)
    }

    fn lock_slot(&self, b: u32) -> MutexGuard<'_, SlotState> {
        self.states[b as usize]
            .lock()
            .expect("op staging mutex poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoomyConfig;
    use crate::testutil::tmpdir;

    #[test]
    fn opkind_roundtrip() {
        for v in 0u8..8 {
            let k = OpKind::from_u8(v).unwrap();
            assert_eq!(k as u8, v);
        }
        assert!(OpKind::from_u8(8).is_none());
    }

    #[test]
    fn encode_indexed_layout() {
        let mut v = Vec::new();
        encode_indexed(&mut v, OpKind::Update, 3, 0x0102030405060708, &[0xAA]);
        assert_eq!(v[0], 0);
        assert_eq!(v[1], 3);
        assert_eq!(u64::from_le_bytes(v[2..10].try_into().unwrap()), 0x0102030405060708);
        assert_eq!(v[10], 0xAA);
    }

    #[test]
    fn encode_keyed_and_elt_layouts() {
        let mut v = Vec::new();
        encode_keyed(&mut v, OpKind::HtInsert, 0, &[1, 2], &[3, 4, 5]);
        assert_eq!(v, vec![2, 0, 1, 2, 3, 4, 5]);
        encode_elt(&mut v, OpKind::Add, &[9, 9]);
        assert_eq!(v, vec![6, 0, 9, 9]);
    }

    fn mkcluster(root: &std::path::Path) -> Cluster {
        let mut cfg = RoomyConfig::for_testing(root);
        cfg.workers = 2;
        cfg.buckets_per_worker = 2;
        Cluster::new(&cfg).unwrap()
    }

    #[test]
    fn stage_and_take_roundtrip() {
        let t = tmpdir("staged_rt");
        let c = mkcluster(t.path());
        let s = StagedOps::new(&c, "x", 16);
        s.stage(1, &[1, 2, 3]).unwrap();
        s.stage(1, &[4, 5, 6]).unwrap();
        assert!(!s.is_empty());
        assert_eq!(s.staged_bytes(), 6);

        let taken = s.take(1, &c, "x", 16);
        assert!(s.is_empty(), "fresh buffer must be empty");
        let mut r = taken.reader().unwrap();
        let mut rec = [0u8; 3];
        assert!(r.read_exact_or_eof(&mut rec).unwrap());
        assert_eq!(rec, [1, 2, 3]);
        assert!(r.read_exact_or_eof(&mut rec).unwrap());
        assert_eq!(rec, [4, 5, 6]);
        assert!(!r.read_exact_or_eof(&mut rec).unwrap());
    }

    #[test]
    fn staging_after_take_lands_in_fresh_buffer() {
        let t = tmpdir("staged_gen");
        let c = mkcluster(t.path());
        let s = StagedOps::new(&c, "x", 8);
        s.stage(0, &[1; 4]).unwrap();
        let mut old = s.take(0, &c, "x", 8);
        s.stage(0, &[2; 4]).unwrap(); // concurrent-issue simulation
        assert_eq!(old.len_bytes(), 4);
        assert_eq!(s.staged_bytes(), 4);
        old.clear().unwrap();
        // the fresh buffer still holds the new op
        let fresh = s.take(0, &c, "x", 8);
        let mut r = fresh.reader().unwrap();
        let mut rec = [0u8; 4];
        assert!(r.read_exact_or_eof(&mut rec).unwrap());
        assert_eq!(rec, [2; 4]);
    }

    #[test]
    fn stage_elt_batch_matches_scalar_loop() {
        let t = tmpdir("staged_batch");
        let c = mkcluster(t.path());
        let topo = c.topology();
        let batch: Vec<u8> = (0..40u64).flat_map(|v| v.to_le_bytes()).collect();

        let bulk = StagedOps::new(&c, "bulk", 1 << 20);
        stage_elt_batch(&bulk, &topo, OpKind::Add, &batch, 8).unwrap();

        let scalar = StagedOps::new(&c, "scalar", 1 << 20);
        with_op_buf(|buf| {
            for elt in batch.chunks_exact(8) {
                encode_elt(buf, OpKind::Add, elt);
                scalar.stage(topo.route(elt), buf).unwrap();
            }
        });

        for b in 0..topo.nbuckets() {
            let mut take_bytes = |s: &StagedOps, dir: &str| {
                let taken = s.take(b, &c, dir, 1 << 20);
                let mut r = taken.reader().unwrap();
                let mut out = Vec::new();
                let mut rec = [0u8; 10]; // [kind, 0, 8-byte elt]
                while r.read_exact_or_eof(&mut rec).unwrap() {
                    out.extend_from_slice(&rec);
                }
                out
            };
            assert_eq!(
                take_bytes(&bulk, "bulk"),
                take_bytes(&scalar, "scalar"),
                "bucket {b} staged bytes diverge"
            );
        }
    }

    #[test]
    fn spill_goes_to_owner_disk() {
        let t = tmpdir("staged_owner");
        let c = mkcluster(t.path());
        let s = StagedOps::new(&c, "str", 4);
        // bucket 1 owned by node 1; push enough to spill
        s.stage(1, &[7; 16]).unwrap();
        assert!(
            c.disk(1).exists("str/stage1.0.spill"),
            "spill file must live on the owning node's disk"
        );
        assert!(!c.disk(0).exists("str/stage1.0.spill"));
    }
}
