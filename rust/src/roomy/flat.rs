//! `FlatTable`: an open-addressing hash table over fixed-size byte
//! records, used as the in-RAM representation of a hash-table bucket
//! during `sync` (§Perf P3).
//!
//! Compared with `HashMap<Vec<u8>, Vec<u8>>` it removes the two heap
//! allocations per record (BFS over n=9 loads ~3.6 M records per level)
//! and hashes with the crate fingerprint instead of SipHash. Records live
//! contiguously in an arena (`key ++ value`), so bucket write-back is a
//! straight scan.

use crate::hashfn;

const EMPTY: u32 = u32::MAX;
const TOMB: u32 = u32::MAX - 1;

/// Open-addressing (linear probing) table of `key ++ value` byte records.
pub struct FlatTable {
    ksize: usize,
    vsize: usize,
    /// Slot array: arena record index, EMPTY or TOMB. Power-of-two sized.
    slots: Vec<u32>,
    /// Contiguous `key ++ value` records (including dead ones).
    arena: Vec<u8>,
    /// Liveness per arena record (false after remove).
    alive: Vec<bool>,
    /// Live record count.
    len: usize,
    /// Live + tombstoned slots (controls rehash trigger).
    occupied: usize,
}

impl FlatTable {
    /// New table for `ksize`-byte keys and `vsize`-byte values, with
    /// capacity for about `expect` records without rehashing.
    pub fn new(ksize: usize, vsize: usize, expect: usize) -> FlatTable {
        let cap = (expect.max(8) * 4 / 3).next_power_of_two();
        FlatTable {
            ksize,
            vsize,
            slots: vec![EMPTY; cap],
            arena: Vec::with_capacity(expect * (ksize + vsize)),
            alive: Vec::with_capacity(expect),
            len: 0,
            occupied: 0,
        }
    }

    fn rec_size(&self) -> usize {
        self.ksize + self.vsize
    }

    /// Live record count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no live records.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn key_of(&self, rec_idx: u32) -> &[u8] {
        let off = rec_idx as usize * self.rec_size();
        &self.arena[off..off + self.ksize]
    }

    /// Probe for `key`: returns (slot index, Some(record index) if found).
    fn probe(&self, key: &[u8]) -> (usize, Option<u32>) {
        debug_assert_eq!(key.len(), self.ksize);
        let mask = self.slots.len() - 1;
        let mut i = (hashfn::fp_bytes(key) as usize) & mask;
        let mut first_tomb: Option<usize> = None;
        loop {
            match self.slots[i] {
                EMPTY => return (first_tomb.unwrap_or(i), None),
                TOMB => {
                    first_tomb.get_or_insert(i);
                }
                rec => {
                    if self.key_of(rec) == key {
                        return (i, Some(rec));
                    }
                }
            }
            i = (i + 1) & mask;
        }
    }

    /// Value bytes for `key`, if present.
    pub fn get(&self, key: &[u8]) -> Option<&[u8]> {
        let (_, found) = self.probe(key);
        found.map(|rec| {
            let off = rec as usize * self.rec_size() + self.ksize;
            &self.arena[off..off + self.vsize]
        })
    }

    /// Insert or overwrite; returns true if the key already existed.
    pub fn put(&mut self, key: &[u8], val: &[u8]) -> bool {
        debug_assert_eq!(val.len(), self.vsize);
        self.maybe_grow();
        let (slot, found) = self.probe(key);
        match found {
            Some(rec) => {
                let off = rec as usize * self.rec_size() + self.ksize;
                self.arena[off..off + self.vsize].copy_from_slice(val);
                true
            }
            None => {
                let rec = self.alive.len() as u32;
                self.arena.extend_from_slice(key);
                self.arena.extend_from_slice(val);
                self.alive.push(true);
                if self.slots[slot] == EMPTY {
                    self.occupied += 1;
                }
                self.slots[slot] = rec;
                self.len += 1;
                false
            }
        }
    }

    /// Remove `key`; returns true if it was present.
    pub fn remove(&mut self, key: &[u8]) -> bool {
        let (slot, found) = self.probe(key);
        match found {
            Some(rec) => {
                self.slots[slot] = TOMB;
                self.alive[rec as usize] = false;
                self.len -= 1;
                true
            }
            None => false,
        }
    }

    /// Visit every live `key ++ value` record.
    pub fn for_each(&self, mut f: impl FnMut(&[u8])) {
        let rs = self.rec_size();
        for (i, alive) in self.alive.iter().enumerate() {
            if *alive {
                f(&self.arena[i * rs..(i + 1) * rs]);
            }
        }
    }

    fn maybe_grow(&mut self) {
        if (self.occupied + 1) * 4 < self.slots.len() * 3 {
            return;
        }
        // Rehash live records into a table sized for 2x the live count;
        // also compacts the arena (drops dead records and tombstones).
        let rs = self.rec_size();
        let new_cap = ((self.len.max(8) * 4 / 3).next_power_of_two()) * 2;
        let mut slots = vec![EMPTY; new_cap];
        let mut arena = Vec::with_capacity(self.len * rs);
        let mut alive = Vec::with_capacity(self.len);
        let mask = new_cap - 1;
        // One strided batched fingerprint sweep over the whole arena
        // (dead records are hashed and skipped — cheaper than a scalar
        // fp_bytes call per live record, and bit-exact with one).
        let mut fps = Vec::new();
        hashfn::fp_bytes_batch_strided_into(&self.arena, rs, self.ksize, &mut fps);
        for (i, a) in self.alive.iter().enumerate() {
            if !*a {
                continue;
            }
            let rec = &self.arena[i * rs..(i + 1) * rs];
            let idx = alive.len() as u32;
            let mut s = (fps[i] as usize) & mask;
            while slots[s] != EMPTY {
                s = (s + 1) & mask;
            }
            slots[s] = idx;
            arena.extend_from_slice(rec);
            alive.push(true);
        }
        self.slots = slots;
        self.arena = arena;
        self.alive = alive;
        self.occupied = self.len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop_check;
    use std::collections::HashMap;

    fn k(x: u64) -> [u8; 8] {
        x.to_be_bytes()
    }

    #[test]
    fn put_get_remove_basics() {
        let mut t = FlatTable::new(8, 4, 4);
        assert!(t.is_empty());
        assert!(!t.put(&k(1), &[1, 0, 0, 0]));
        assert!(!t.put(&k(2), &[2, 0, 0, 0]));
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(&k(1)), Some(&[1u8, 0, 0, 0][..]));
        assert!(t.put(&k(1), &[9, 0, 0, 0]), "overwrite reports existing");
        assert_eq!(t.get(&k(1)), Some(&[9u8, 0, 0, 0][..]));
        assert_eq!(t.len(), 2);
        assert!(t.remove(&k(1)));
        assert!(!t.remove(&k(1)));
        assert_eq!(t.get(&k(1)), None);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn reinsert_after_remove_uses_tombstone() {
        let mut t = FlatTable::new(8, 1, 4);
        t.put(&k(5), &[1]);
        t.remove(&k(5));
        t.put(&k(5), &[2]);
        assert_eq!(t.get(&k(5)), Some(&[2u8][..]));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut t = FlatTable::new(8, 8, 4);
        for i in 0..10_000u64 {
            t.put(&k(i), &(i * 3).to_be_bytes());
        }
        assert_eq!(t.len(), 10_000);
        for i in (0..10_000u64).step_by(977) {
            assert_eq!(t.get(&k(i)), Some(&(i * 3).to_be_bytes()[..]));
        }
    }

    #[test]
    fn for_each_visits_live_only() {
        let mut t = FlatTable::new(8, 1, 8);
        for i in 0..20u64 {
            t.put(&k(i), &[i as u8]);
        }
        for i in (0..20u64).step_by(2) {
            t.remove(&k(i));
        }
        let mut seen = vec![];
        t.for_each(|rec| seen.push(u64::from_be_bytes(rec[..8].try_into().unwrap())));
        seen.sort();
        assert_eq!(seen, (0..20u64).filter(|i| i % 2 == 1).collect::<Vec<_>>());
    }

    #[test]
    fn prop_matches_hashmap_model() {
        prop_check("FlatTable == HashMap", 20, |rng| {
            let mut t = FlatTable::new(8, 8, 8);
            let mut model: HashMap<u64, u64> = HashMap::new();
            for _ in 0..rng.range(0, 2000) {
                let key = rng.below(200);
                match rng.range(0, 3) {
                    0 | 1 => {
                        let v = rng.next_u64();
                        t.put(&k(key), &v.to_be_bytes());
                        model.insert(key, v);
                    }
                    _ => {
                        assert_eq!(t.remove(&k(key)), model.remove(&key).is_some());
                    }
                }
            }
            assert_eq!(t.len(), model.len());
            for (key, v) in &model {
                assert_eq!(t.get(&k(*key)), Some(&v.to_be_bytes()[..]));
            }
            let mut count = 0;
            t.for_each(|_| count += 1);
            assert_eq!(count, model.len());
        });
    }
}
