//! Word-wise kernels over packed sub-byte element buffers — the
//! raw-speed inner loops behind [`super::bitarray::RoomyBitArray`].
//!
//! A bit-array bucket is a byte buffer of `bits` ∈ {1, 2, 4, 8}-wide
//! fields, lowest element at the least-significant bits of byte 0. A
//! little-endian `u64` load therefore presents `64 / bits` consecutive
//! elements in register, in index order, so counting and combining can
//! run one word at a time with `count_ones` and SWAR field folds instead
//! of a shift/mask per element:
//!
//! - **count**: XOR against a broadcast of the probe value zeroes the
//!   matching fields; OR-folding each field onto its own LSB and masking
//!   leaves one bit per *non*-matching field, so a single `count_ones`
//!   yields the match count for the whole word.
//! - **combine**: union / intersection / subtraction of two buffers are
//!   wide `OR` / `AND` / `ANDNOT` sweeps — fields never straddle words,
//!   so bitwise word ops are exactly the per-element ops.
//!
//! Every kernel is bit-exact with the obvious per-element loop (pinned
//! by the property tests below and `tests/property_tests.rs`); callers
//! choose them purely for speed. Tails that don't fill a word fall back
//! to the scalar path, so no alignment or padding preconditions leak to
//! callers.

/// `0b…0001` repeated at every `bits`-wide field boundary (the LSB mask,
/// and the broadcast multiplier).
#[inline]
fn rep(bits: u8) -> u64 {
    u64::MAX / ((1u64 << bits) - 1)
}

/// The element mask for a field width.
#[inline]
pub fn field_mask(bits: u8) -> u8 {
    if bits == 8 {
        0xFF
    } else {
        (1u8 << bits) - 1
    }
}

/// Matching fields in one word: fold each field's bits onto its LSB and
/// popcount the non-matches.
#[inline]
fn count_word_eq(w: u64, v: u8, bits: u8) -> u64 {
    let mut x = w ^ (v as u64).wrapping_mul(rep(bits));
    let mut s = 1u8;
    while s < bits {
        x |= x >> s;
        s <<= 1;
    }
    (64 / bits as u64) - (x & rep(bits)).count_ones() as u64
}

/// Count elements equal to `v` among the first `nelems` fields of
/// `data`. Word-wise over whole `u64`s, scalar over the ragged tail;
/// identical to testing every element with a shift/mask.
pub fn count_value(data: &[u8], bits: u8, nelems: u64, v: u8) -> u64 {
    assert!(matches!(bits, 1 | 2 | 4 | 8), "bad field width {bits}");
    let mask = field_mask(bits);
    assert!(v <= mask, "value {v} does not fit {bits} bits");
    let epw = 64 / bits as u64; // elements per word
    let nwords = (nelems / epw) as usize;
    let mut count = 0u64;
    for chunk in data[..nwords * 8].chunks_exact(8) {
        let w = u64::from_le_bytes(chunk.try_into().expect("8-byte chunk"));
        count += count_word_eq(w, v, bits);
    }
    let per_byte = (8 / bits) as u64;
    for i in nwords as u64 * epw..nelems {
        let byte = data[(i / per_byte) as usize];
        let shift = ((i % per_byte) as u8) * bits;
        if (byte >> shift) & mask == v {
            count += 1;
        }
    }
    count
}

/// Per-value histogram of the first `nelems` fields: `out[v]` = elements
/// equal to `v`. One SWAR sweep per value for sub-byte widths (≤ 16
/// passes), one table-indexed scalar pass for byte-wide fields (256
/// sweeps would thrash the cache for no win).
pub fn histogram(data: &[u8], bits: u8, nelems: u64) -> Vec<u64> {
    assert!(matches!(bits, 1 | 2 | 4 | 8), "bad field width {bits}");
    if bits == 8 {
        let mut h = vec![0u64; 256];
        for &b in &data[..nelems as usize] {
            h[b as usize] += 1;
        }
        return h;
    }
    (0..1u16 << bits).map(|v| count_value(data, bits, nelems, v as u8)).collect()
}

/// Set bits across the whole buffer (fields ignored — a raw popcount).
pub fn popcount_bytes(data: &[u8]) -> u64 {
    let n = data.len() / 8 * 8;
    let mut c = 0u64;
    for chunk in data[..n].chunks_exact(8) {
        c += u64::from_le_bytes(chunk.try_into().expect("8-byte chunk")).count_ones() as u64;
    }
    c + data[n..].iter().map(|b| b.count_ones() as u64).sum::<u64>()
}

/// How two packed buffers combine in [`combine_into`]. Fields align
/// across equal-geometry buffers, so each op is the per-element bitwise
/// op applied to every element at once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombineOp {
    /// `dst |= src` — set union for 1-bit fields.
    Or,
    /// `dst &= src` — set intersection for 1-bit fields.
    And,
    /// `dst &= !src` — set subtraction for 1-bit fields.
    AndNot,
}

/// Combine `src` into `dst` with a wide word sweep (`u64` at a time,
/// byte tail scalar). Buffers must be the same length.
pub fn combine_into(dst: &mut [u8], src: &[u8], op: CombineOp) {
    assert_eq!(dst.len(), src.len(), "combine over mismatched buffers");
    let n = dst.len() / 8 * 8;
    for (dc, sc) in dst[..n].chunks_exact_mut(8).zip(src[..n].chunks_exact(8)) {
        let d = u64::from_le_bytes((&*dc).try_into().expect("8-byte chunk"));
        let s = u64::from_le_bytes(sc.try_into().expect("8-byte chunk"));
        let w = match op {
            CombineOp::Or => d | s,
            CombineOp::And => d & s,
            CombineOp::AndNot => d & !s,
        };
        dc.copy_from_slice(&w.to_le_bytes());
    }
    for (d, s) in dst[n..].iter_mut().zip(src[n..].iter()) {
        match op {
            CombineOp::Or => *d |= *s,
            CombineOp::And => *d &= *s,
            CombineOp::AndNot => *d &= !*s,
        }
    }
}

/// Visit the first `count` fields of `data` in index order, unpacking a
/// whole word of elements per load instead of a byte load + shift per
/// element (the streaming-read kernel behind `RoomyBitArray::map`).
pub fn for_each_unpacked(data: &[u8], bits: u8, count: u64, mut f: impl FnMut(u64, u8)) {
    assert!(matches!(bits, 1 | 2 | 4 | 8), "bad field width {bits}");
    let mask = field_mask(bits);
    let epw = 64 / bits as u64;
    let nwords = (count / epw) as usize;
    let mut idx = 0u64;
    for chunk in data[..nwords * 8].chunks_exact(8) {
        let mut w = u64::from_le_bytes(chunk.try_into().expect("8-byte chunk"));
        for _ in 0..epw {
            f(idx, (w as u8) & mask);
            w >>= bits;
            idx += 1;
        }
    }
    let per_byte = (8 / bits) as u64;
    while idx < count {
        let byte = data[(idx / per_byte) as usize];
        let shift = ((idx % per_byte) as u8) * bits;
        f(idx, (byte >> shift) & mask);
        idx += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop_check;

    /// Scalar reference: extract element `i` of a packed buffer.
    fn get(data: &[u8], bits: u8, i: u64) -> u8 {
        let per_byte = (8 / bits) as u64;
        (data[(i / per_byte) as usize] >> (((i % per_byte) as u8) * bits)) & field_mask(bits)
    }

    fn packed(rng: &mut crate::testutil::Rng, nbytes: usize) -> Vec<u8> {
        (0..nbytes).map(|_| rng.next_u64() as u8).collect()
    }

    #[test]
    fn prop_count_and_histogram_match_scalar() {
        prop_check("word-wise count == scalar count", 30, |rng| {
            let bits = [1u8, 2, 4, 8][rng.range(0, 4)];
            let per_byte = (8 / bits) as u64;
            let nbytes = rng.range(0, 64);
            let data = packed(rng, nbytes);
            let max_elems = nbytes as u64 * per_byte;
            let nelems = rng.range(0, max_elems as usize + 1) as u64;
            let h = histogram(&data, bits, nelems);
            assert_eq!(h.len(), 1 << bits);
            for v in 0..(1u16 << bits) {
                let expect =
                    (0..nelems).filter(|&i| get(&data, bits, i) == v as u8).count() as u64;
                assert_eq!(
                    count_value(&data, bits, nelems, v as u8),
                    expect,
                    "bits={bits} n={nelems} v={v}"
                );
                assert_eq!(h[v as usize], expect);
            }
            assert_eq!(h.iter().sum::<u64>(), nelems, "histogram covers every element");
        });
    }

    #[test]
    fn prop_combine_matches_per_element() {
        prop_check("word-wise combine == per-element", 30, |rng| {
            let nbytes = rng.range(0, 100);
            let a = packed(rng, nbytes);
            let b = packed(rng, nbytes);
            for op in [CombineOp::Or, CombineOp::And, CombineOp::AndNot] {
                let mut dst = a.clone();
                combine_into(&mut dst, &b, op);
                for i in 0..nbytes {
                    let expect = match op {
                        CombineOp::Or => a[i] | b[i],
                        CombineOp::And => a[i] & b[i],
                        CombineOp::AndNot => a[i] & !b[i],
                    };
                    assert_eq!(dst[i], expect, "{op:?} byte {i}");
                }
            }
        });
    }

    #[test]
    fn prop_unpack_walk_matches_scalar() {
        prop_check("word unpack walk == scalar gets", 30, |rng| {
            let bits = [1u8, 2, 4, 8][rng.range(0, 4)];
            let per_byte = (8 / bits) as u64;
            let nbytes = rng.range(0, 48);
            let data = packed(rng, nbytes);
            let count = rng.range(0, (nbytes as u64 * per_byte) as usize + 1) as u64;
            let mut seen = vec![];
            for_each_unpacked(&data, bits, count, |i, v| seen.push((i, v)));
            assert_eq!(seen.len() as u64, count);
            for (k, (i, v)) in seen.iter().enumerate() {
                assert_eq!(*i, k as u64, "visit order is index order");
                assert_eq!(*v, get(&data, bits, *i));
            }
        });
    }

    #[test]
    fn popcount_matches_scalar() {
        prop_check("popcount_bytes == per-byte count_ones", 20, |rng| {
            let data = packed(rng, rng.range(0, 80));
            let expect: u64 = data.iter().map(|b| b.count_ones() as u64).sum();
            assert_eq!(popcount_bytes(&data), expect);
        });
    }

    #[test]
    fn count_rejects_out_of_width_values() {
        let r = std::panic::catch_unwind(|| count_value(&[0u8; 8], 2, 4, 7));
        assert!(r.is_err(), "value wider than the field must panic");
    }
}
