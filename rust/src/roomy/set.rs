//! `RoomySet<T>`: a native disk-resident set — the paper's stated future
//! work ("Future work is planned to add a native RoomySet data structure"
//! and "Set intersection may become a Roomy primitive in the future",
//! §3).
//!
//! Unlike [`super::RoomyList`], a `RoomySet` maintains the set invariant
//! *incrementally*: shards are kept **sorted** on disk and staged adds are
//! sorted in RAM and merged in one streaming pass at `sync` — no full
//! re-sort of existing data, which is exactly the cost the paper's
//! list-based set emulation pays on every `removeDupes`. Set algebra
//! (union / difference / intersection) then becomes a shard-aligned
//! sorted-merge primitive.
//!
//! Complexity per sync: O(existing + staged·log staged) bytes streamed,
//! vs O(existing·log existing) for the list emulation's external sort.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use super::element::Element;
use super::ops::{OpKind, StagedOps};
use super::Ctx;
use crate::error::{Result, RoomyError};
use crate::storage::bloom::{DedupFilter, ShardBloom};
use crate::storage::checkpoint::{Checkpointable, StructKind, StructMeta};
use crate::storage::chunkfile::record_count;
use crate::storage::scratch::{self, Arena};
use crate::storage::{NodeDisk, PrefetchReader, WriteBehindWriter, PIPE_CHUNK};

const SCAN_BATCH: usize = 8192;

/// A distributed disk-backed set with incrementally-maintained sorted
/// shards. Cheap to clone (shared state).
pub struct RoomySet<T: Element> {
    inner: Arc<SetInner<T>>,
}

impl<T: Element> Clone for RoomySet<T> {
    fn clone(&self) -> Self {
        RoomySet { inner: Arc::clone(&self.inner) }
    }
}

struct SetInner<T: Element> {
    ctx: Ctx,
    name: String,
    dir: String,
    staged: Arc<StagedOps>,
    /// Serializes shard-rewriting collectives (`sync`, `merge_with`)
    /// against concurrent client threads.
    write_lock: std::sync::Mutex<()>,
    size: AtomicI64,
    /// Optional approximate-membership tier ([`crate::storage::bloom`]).
    /// Fed by every append path (sync merges and union merges); fronts
    /// `contains` in exact-backed mode and drops maybe-seen adds before
    /// the merge in approximate mode. Shards here stay sorted and are
    /// replaced whole at sync, so there is no append-bypass — the list
    /// and hashtable carry that shortcut. RAM-only: rebuilt from shard
    /// files after a checkpoint restore, never serialized.
    bloom: Option<DedupFilter>,
    _t: PhantomData<fn() -> T>,
}

impl<T: Element> RoomySet<T> {
    pub(crate) fn create(ctx: Ctx, name: &str) -> Result<Self> {
        // A freshly created structure must be empty: clear any same-named
        // shard files a killed run left behind (same-root reruns are the
        // normal case now that checkpoints make state durable).
        ctx.cluster.remove_structure_dirs(format!("rs_{name}"))?;
        Self::build(ctx, name)
    }

    fn build(ctx: Ctx, name: &str) -> Result<Self> {
        let dir = format!("rs_{name}");
        let cluster = ctx.cluster.clone();
        let bloom = ctx.dedup_filter();
        Ok(RoomySet {
            inner: Arc::new(SetInner {
                staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
                write_lock: std::sync::Mutex::new(()),
                ctx,
                name: name.to_string(),
                dir,
                size: AtomicI64::new(0),
                bloom,
                _t: PhantomData,
            }),
        })
    }

    /// Re-open a restored set over shard files already on disk
    /// ([`crate::storage::checkpoint`]), reconstituting the in-RAM size
    /// counter and re-deriving the (RAM-only) dedup filters from the
    /// restored shards.
    pub(crate) fn open_restored(ctx: Ctx, name: &str, size: u64) -> Result<Self> {
        let set = Self::build(ctx, name)?;
        set.inner.size.store(size as i64, Ordering::Relaxed);
        set.inner.rebuild_bloom()?;
        Ok(set)
    }

    /// Number of elements (immediate).
    pub fn size(&self) -> u64 {
        self.inner.size.load(Ordering::Relaxed).max(0) as u64
    }

    /// Total staged (not yet synced) delayed-op bytes.
    pub fn pending_bytes(&self) -> u64 {
        self.inner.staged.staged_bytes()
    }

    /// True if the set has no synced elements.
    pub fn is_empty(&self) -> bool {
        self.size() == 0
    }

    /// Structure name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Delayed add (idempotent at sync: duplicates are discarded).
    pub fn add(&self, elt: &T) -> Result<()> {
        self.stage(OpKind::Add, elt)
    }

    /// Delayed remove.
    pub fn remove(&self, elt: &T) -> Result<()> {
        self.stage(OpKind::Remove, elt)
    }

    /// Delayed add of a whole slice of elements, routed through the
    /// batched fingerprint kernels ([`crate::hashfn`]) — one lane sweep
    /// instead of one hash call per element. Staged bytes are identical
    /// to an [`add`](Self::add) loop.
    pub fn add_batch(&self, elts: &[T]) -> Result<()> {
        let mut chunk = scratch::record_buf();
        chunk.clear();
        chunk.resize(elts.len() * T::SIZE, 0);
        for (e, slot) in elts.iter().zip(chunk.chunks_exact_mut(T::SIZE)) {
            e.write_to(slot);
        }
        super::ops::stage_elt_batch(
            &self.inner.staged,
            &self.inner.ctx.cluster.topology(),
            OpKind::Add,
            &chunk,
            T::SIZE,
        )
    }

    fn stage(&self, kind: OpKind, elt: &T) -> Result<()> {
        super::ops::with_op_buf(|rec| {
            rec.push(kind as u8);
            rec.push(0);
            let off = rec.len();
            rec.resize(off + T::SIZE, 0);
            elt.write_to(&mut rec[off..]);
            let shard = self.inner.ctx.cluster.topology().route(&rec[off..off + T::SIZE]);
            self.inner.staged.stage(shard, rec)
        })
    }

    /// Apply staged ops: per shard, the staged adds/removes are sorted in
    /// RAM and merged with the (sorted) shard file in one streaming pass.
    /// Remove wins over add for the same element in the same sync.
    pub fn sync(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        if inner.staged.is_empty() {
            return Ok(());
        }
        let deltas: Vec<i64> = inner.ctx.cluster.run_buckets_hinted(
            "rset.sync",
            |b| Some(inner.shard_file(b)),
            |b, disk| inner.sync_shard(b, disk),
        )?;
        inner.size.fetch_add(deltas.iter().sum::<i64>(), Ordering::Relaxed);
        Ok(())
    }

    /// Membership probe (immediate, **debug/testing**: random access).
    ///
    /// With the dedup tier enabled, a "definitely new" filter answer
    /// settles the probe without touching disk; only "maybe seen" falls
    /// through to the exact shard scan, so the answer is always exact.
    pub fn contains(&self, elt: &T) -> Result<bool> {
        let inner = &self.inner;
        let eb = elt.to_bytes();
        let b = inner.ctx.cluster.topology().route(&eb);
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        if let Some(bl) = &inner.bloom {
            if !bl.probe(b as usize, &eb) {
                let avoided = record_count(disk, &inner.shard_file(b), T::SIZE) * T::SIZE as u64;
                inner.ctx.dedup.add_shortcut(avoided);
                return Ok(false);
            }
            inner.ctx.dedup.add_fallback();
        }
        let mut found = false;
        inner.scan_shard(b, disk, |rec| {
            if rec == &eb[..] {
                found = true;
            }
            Ok(())
        })?;
        Ok(found)
    }

    /// Apply `f` to every element (streaming, parallel; sorted order
    /// within each shard).
    pub fn map(&self, f: impl Fn(&T) + Sync) -> Result<()> {
        self.inner.for_owned_shards("rset.map", |this, b, disk| {
            this.scan_shard(b, disk, |rec| {
                f(&T::read_from(rec));
                Ok(())
            })
        })
    }

    /// Reduce over all elements (assoc + comm). Shards reduce concurrently
    /// on the pool; partials merge in shard order, independent of
    /// `num_workers`.
    pub fn reduce<R: Send>(
        &self,
        identity: impl Fn() -> R + Sync,
        fold: impl Fn(R, &T) -> R + Sync,
        merge: impl Fn(R, R) -> R,
    ) -> Result<R> {
        let inner = &self.inner;
        let partials: Vec<R> = inner.ctx.cluster.run_buckets_hinted(
            "rset.reduce",
            |b| Some(inner.shard_file(b)),
            |b, disk| {
                let mut local = Some(identity());
                inner.scan_shard(b, disk, |rec| {
                    let cur = local.take().expect("reduce accumulator");
                    local = Some(fold(cur, &T::read_from(rec)));
                    Ok(())
                })?;
                Ok(local.take().expect("reduce accumulator"))
            },
        )?;
        let mut it = partials.into_iter();
        let first = it.next().expect("at least one shard");
        Ok(it.fold(first, merge))
    }

    /// Native set-algebra primitive: `self = self ∘ other` where `op` is
    /// union / difference / intersection. One shard-aligned sorted merge —
    /// the primitive the paper says intersection "may become".
    pub fn merge_with(&self, other: &RoomySet<T>, op: SetOp) -> Result<()> {
        let inner = &self.inner;
        if inner.ctx.cluster.nbuckets() != other.inner.ctx.cluster.nbuckets() {
            return Err(RoomyError::Incompatible(
                "set algebra requires identical shard counts".into(),
            ));
        }
        let _write = inner.write_lock.lock().unwrap();
        // no prefetch hint: the merge halves its chunk size per side
        // (PIPE_CHUNK / 2), which a full-chunk warm cannot serve
        let deltas: Vec<i64> = inner.ctx.cluster.run_buckets("rset.merge", |b, disk| {
            inner.merge_shard(b, disk, &other.inner.shard_file(b), op)
        })?;
        inner.size.fetch_add(deltas.iter().sum::<i64>(), Ordering::Relaxed);
        Ok(())
    }

    /// `self = self ∪ other`.
    pub fn union_with(&self, other: &RoomySet<T>) -> Result<()> {
        self.merge_with(other, SetOp::Union)
    }

    /// `self = self − other`.
    pub fn difference_with(&self, other: &RoomySet<T>) -> Result<()> {
        self.merge_with(other, SetOp::Difference)
    }

    /// `self = self ∩ other`.
    pub fn intersect_with(&self, other: &RoomySet<T>) -> Result<()> {
        self.merge_with(other, SetOp::Intersection)
    }

    /// Collect every element (testing/debug). Each shard accumulates
    /// into its own buffer on the pool; partials concatenate in shard
    /// order, so the result is deterministic and lock-free.
    pub fn collect(&self) -> Result<Vec<T>> {
        let inner = &self.inner;
        let per_shard: Vec<Vec<T>> = inner.ctx.cluster.run_buckets_hinted(
            "rset.collect",
            |b| Some(inner.shard_file(b)),
            |b, disk| {
                let mut acc = Vec::new();
                inner.scan_shard(b, disk, |rec| {
                    acc.push(T::read_from(rec));
                    Ok(())
                })?;
                Ok(acc)
            },
        )?;
        Ok(per_shard.into_iter().flatten().collect())
    }

    /// Delete all on-disk state.
    pub fn destroy(self) -> Result<()> {
        let dir = self.inner.dir.clone();
        self.inner.ctx.cluster.remove_structure_dirs(dir)
    }
}

/// Shard-merge operator for [`RoomySet::merge_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetOp {
    Union,
    Difference,
    Intersection,
}

impl<T: Element> Checkpointable for RoomySet<T> {
    fn ckpt_meta(&self) -> StructMeta {
        StructMeta {
            kind: StructKind::Set,
            name: self.inner.name.clone(),
            dir: self.inner.dir.clone(),
            rec_size: T::SIZE,
            key_size: 0,
            len: 0,
            size: self.size(),
            bits: 0,
            // shards are maintained sorted by construction
            sorted: true,
            // shard files are only ever replaced whole (merge + rename)
            appendable: false,
            counts: Vec::new(),
        }
    }

    fn ckpt_pending(&self) -> u64 {
        RoomySet::pending_bytes(self)
    }
}

impl<T: Element> SetInner<T> {
    fn shard_file(&self, b: u32) -> String {
        format!("{}/s{b}.dat", self.dir)
    }

    /// Run `f(self, shard, disk)` for every shard on the worker pool,
    /// hinting each shard's file for cross-task prefetch.
    fn for_owned_shards(
        &self,
        phase: &str,
        f: impl Fn(&Self, u32, &Arc<NodeDisk>) -> Result<()> + Sync,
    ) -> Result<()> {
        let _lbl = crate::obs::trace::struct_label(&self.name);
        self.ctx.cluster.run_buckets_hinted(
            phase,
            |b| Some(self.shard_file(b)),
            |b, disk| f(self, b, disk),
        )?;
        Ok(())
    }

    fn scan_shard(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        mut f: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let file = self.shard_file(b);
        if !disk.exists(&file) {
            return Ok(());
        }
        let mut r = PrefetchReader::open(disk, &file, T::SIZE)?;
        let mut buf = scratch::record_buf();
        loop {
            let n = r.read_batch(&mut buf, SCAN_BATCH)?;
            if n == 0 {
                return Ok(());
            }
            for rec in buf.chunks_exact(T::SIZE) {
                f(rec)?;
            }
        }
    }

    /// Re-derive every shard's dedup filter from the authoritative shard
    /// files (checkpoint restore: filters are RAM-only, never serialized).
    fn rebuild_bloom(&self) -> Result<()> {
        let Some(bloom) = &self.bloom else { return Ok(()) };
        let bits = bloom.bits_per_key();
        self.ctx.cluster.run_buckets("rset.bloom_rebuild", |b, disk| {
            bloom.with_shard(b as usize, |s| {
                *s = ShardBloom::new(bits);
                self.scan_shard(b, disk, |rec| {
                    s.insert(rec);
                    Ok(())
                })
            })
        })?;
        Ok(())
    }

    /// One streaming merge of (sorted shard) with (sorted staged deltas).
    fn sync_shard(&self, b: u32, disk: &Arc<NodeDisk>) -> Result<i64> {
        let mut ops =
            self.staged.take(b, &self.ctx.cluster, &self.dir, self.ctx.cfg.op_buffer_bytes);
        if ops.is_empty() {
            return ops.clear().map(|_| 0);
        }
        // Collect staged ops into a flat arena: each record is the
        // element's bytes followed by one verdict byte (0 = remove,
        // 1 = add). Sorting bytewise orders by element first and puts
        // removes ahead of adds within a run, so the prefix-dedup keeps
        // the winning verdict ("remove dominates") with zero per-op
        // allocation. (Staged volume is bounded by op_buffer_bytes per
        // shard in RAM; spilled segments stream back through the reader.)
        let vrec = T::SIZE + 1;
        let mut verdicts = Arena::new(vrec);
        {
            // Op-log replay streams through the read-ahead lane; the
            // drain removes the log's spill file when it drops.
            let mut reader = ops.into_drain()?;
            let mut header = [0u8; 2];
            let mut rec = scratch::record_buf();
            rec.resize(vrec, 0);
            while reader.read_exact_or_eof(&mut header)? {
                let kind = OpKind::from_u8(header[0]).ok_or_else(|| {
                    RoomyError::InvalidArg(format!("corrupt op tag {}", header[0]))
                })?;
                if !reader.read_exact_or_eof(&mut rec[..T::SIZE])? {
                    return Err(RoomyError::InvalidArg("truncated op record".into()));
                }
                rec[T::SIZE] = (kind == OpKind::Add) as u8;
                verdicts.push_record(&rec);
            }
        }
        // Sort; one verdict per element, remove dominating.
        verdicts.sort_records();
        verdicts.dedup_by_prefix(T::SIZE);

        // Approximate mode: treat "maybe seen" adds as duplicates and
        // drop them before the merge; if nothing survives, the shard
        // merge (a full sorted rewrite) is skipped outright. Exact-backed
        // mode never prunes here — the sorted rewrite must see every
        // verdict to keep bytes identical to the filter-off run.
        if let Some(bl) = &self.bloom {
            if bl.approximate() {
                let before = verdicts.len();
                verdicts.retain(|v| v[T::SIZE] == 0 || !bl.probe(b as usize, &v[..T::SIZE]));
                let dropped = before - verdicts.len();
                if dropped > 0 {
                    self.ctx.dedup.add_approx_dropped(dropped as u64);
                }
                if verdicts.is_empty() {
                    let avoided =
                        record_count(disk, &self.shard_file(b), T::SIZE) * T::SIZE as u64;
                    self.ctx.dedup.add_shortcut(avoided);
                    return Ok(0);
                }
                self.ctx.dedup.add_fallback();
            }
        }

        // Streaming merge with the sorted shard file.
        let file = self.shard_file(b);
        let tmp = format!("{file}.sync.tmp");
        let mut delta = 0i64;
        {
            let mut w = WriteBehindWriter::create(disk, &tmp, T::SIZE)?;
            let mut vi = 0usize;
            let emit_pending = |w: &mut WriteBehindWriter,
                                    vi: &mut usize,
                                    upto: Option<&[u8]>,
                                    delta: &mut i64|
             -> Result<()> {
                while *vi < verdicts.len()
                    && upto.is_none_or(|rec| &verdicts.get(*vi)[..T::SIZE] < &rec[..])
                {
                    let v = verdicts.get(*vi);
                    if v[T::SIZE] == 1 {
                        w.push(&v[..T::SIZE])?;
                        // genuinely-new element entering the shard: feed
                        // the dedup filter (append-path soundness rule)
                        if let Some(bl) = &self.bloom {
                            bl.insert(b as usize, &v[..T::SIZE]);
                        }
                        *delta += 1;
                    }
                    *vi += 1;
                }
                Ok(())
            };
            if disk.exists(&file) {
                let mut r = PrefetchReader::open(disk, &file, T::SIZE)?;
                let mut rec = scratch::record_buf();
                rec.resize(T::SIZE, 0);
                while r.read_one(&mut rec)? {
                    emit_pending(&mut w, &mut vi, Some(&rec), &mut delta)?;
                    if vi < verdicts.len() && verdicts.get(vi)[..T::SIZE] == rec[..] {
                        // existing element with a verdict: keep on add,
                        // drop on remove; either way consume the verdict.
                        if verdicts.get(vi)[T::SIZE] == 1 {
                            w.push(&rec)?;
                        } else {
                            delta -= 1;
                        }
                        vi += 1;
                    } else {
                        w.push(&rec)?;
                    }
                }
            }
            emit_pending(&mut w, &mut vi, None, &mut delta)?;
            w.finish()?;
        }
        disk.rename(&tmp, &file)?;
        Ok(delta)
    }

    /// Sorted-merge `self ∘ other` for one shard. Returns the size delta.
    /// Both inputs read ahead (half a chunk each) and the merged output
    /// flushes behind on a pipelined disk.
    fn merge_shard(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        other_file: &str,
        op: SetOp,
    ) -> Result<i64> {
        let mine = self.shard_file(b);
        let before = record_count(disk, &mine, T::SIZE) as i64;
        let tmp = format!("{mine}.merge.tmp");
        let mut written = 0i64;
        {
            let mut w = WriteBehindWriter::create(disk, &tmp, T::SIZE)?;
            let mut a_rec = scratch::record_buf();
            a_rec.resize(T::SIZE, 0);
            let mut b_rec = scratch::record_buf();
            b_rec.resize(T::SIZE, 0);
            let mut ra = if disk.exists(&mine) {
                Some(PrefetchReader::open_with_chunk(disk, &mine, T::SIZE, PIPE_CHUNK / 2)?)
            } else {
                None
            };
            let mut rb = if disk.exists(other_file) {
                Some(PrefetchReader::open_with_chunk(disk, other_file, T::SIZE, PIPE_CHUNK / 2)?)
            } else {
                None
            };
            let mut have_a = match ra.as_mut() {
                Some(r) => r.read_one(&mut a_rec)?,
                None => false,
            };
            let mut have_b = match rb.as_mut() {
                Some(r) => r.read_one(&mut b_rec)?,
                None => false,
            };
            loop {
                match (have_a, have_b) {
                    (false, false) => break,
                    (true, false) => {
                        if matches!(op, SetOp::Union | SetOp::Difference) {
                            w.push(&a_rec)?;
                            written += 1;
                        }
                        have_a = ra.as_mut().unwrap().read_one(&mut a_rec)?;
                    }
                    (false, true) => {
                        if matches!(op, SetOp::Union) {
                            w.push(&b_rec)?;
                            // record from `other` entering this set: feed
                            // the dedup filter (append-path soundness)
                            if let Some(bl) = &self.bloom {
                                bl.insert(b as usize, &b_rec);
                            }
                            written += 1;
                        }
                        have_b = rb.as_mut().unwrap().read_one(&mut b_rec)?;
                    }
                    (true, true) => match a_rec.cmp(&b_rec) {
                        std::cmp::Ordering::Less => {
                            if matches!(op, SetOp::Union | SetOp::Difference) {
                                w.push(&a_rec)?;
                                written += 1;
                            }
                            have_a = ra.as_mut().unwrap().read_one(&mut a_rec)?;
                        }
                        std::cmp::Ordering::Greater => {
                            if matches!(op, SetOp::Union) {
                                w.push(&b_rec)?;
                                if let Some(bl) = &self.bloom {
                                    bl.insert(b as usize, &b_rec);
                                }
                                written += 1;
                            }
                            have_b = rb.as_mut().unwrap().read_one(&mut b_rec)?;
                        }
                        std::cmp::Ordering::Equal => {
                            if matches!(op, SetOp::Union | SetOp::Intersection) {
                                w.push(&a_rec)?;
                                written += 1;
                            }
                            have_a = ra.as_mut().unwrap().read_one(&mut a_rec)?;
                            have_b = rb.as_mut().unwrap().read_one(&mut b_rec)?;
                        }
                    },
                }
            }
            w.finish()?;
        }
        disk.rename(&tmp, &mine)?;
        Ok(written - before)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::{prop_check, tmpdir};
    use std::collections::BTreeSet;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    fn as_btree(s: &RoomySet<u64>) -> BTreeSet<u64> {
        s.collect().unwrap().into_iter().collect()
    }

    #[test]
    fn add_is_idempotent() {
        let t = tmpdir("rset_idem");
        let r = mk(t.path());
        let s = r.set::<u64>("s").unwrap();
        for _ in 0..5 {
            s.add(&7).unwrap();
        }
        s.add(&8).unwrap();
        s.sync().unwrap();
        assert_eq!(s.size(), 2);
        // adding again across syncs stays idempotent
        s.add(&7).unwrap();
        s.sync().unwrap();
        assert_eq!(s.size(), 2);
        assert!(s.contains(&7).unwrap());
        assert!(!s.contains(&9).unwrap());
    }

    #[test]
    fn add_batch_matches_scalar_adds() {
        let t = tmpdir("rset_add_batch");
        let r = mk(t.path());
        let vals: Vec<u64> = (0..300).map(|i| i % 97).collect();
        let a = r.set::<u64>("a").unwrap();
        a.add_batch(&vals).unwrap();
        a.sync().unwrap();
        let b = r.set::<u64>("b").unwrap();
        for v in &vals {
            b.add(v).unwrap();
        }
        b.sync().unwrap();
        assert_eq!(a.size(), b.size());
        assert_eq!(as_btree(&a), as_btree(&b));
    }

    #[test]
    fn remove_wins_within_one_sync() {
        let t = tmpdir("rset_rm");
        let r = mk(t.path());
        let s = r.set::<u64>("s").unwrap();
        s.add(&1).unwrap();
        s.remove(&1).unwrap();
        s.add(&1).unwrap(); // still removed: remove dominates in one sync
        s.sync().unwrap();
        assert_eq!(s.size(), 0);
        s.add(&1).unwrap();
        s.sync().unwrap();
        assert_eq!(s.size(), 1);
    }

    #[test]
    fn shards_stay_sorted() {
        let t = tmpdir("rset_sorted");
        let r = mk(t.path());
        let s = r.set::<u64>("s").unwrap();
        for v in [9u64, 3, 7, 1, 3, 100, 55] {
            s.add(&v).unwrap();
        }
        s.sync().unwrap();
        for v in [2u64, 8, 4] {
            s.add(&v).unwrap();
        }
        s.remove(&7).unwrap();
        s.sync().unwrap();
        // verify order within each shard by scanning
        let prev = std::sync::Mutex::new(None::<u64>);
        // collect per shard via map ordering is per-shard; just check set
        assert_eq!(as_btree(&s), BTreeSet::from([1, 2, 3, 4, 8, 9, 55, 100]));
        drop(prev);
    }

    #[test]
    fn native_algebra_matches_std() {
        let t = tmpdir("rset_algebra");
        let r = mk(t.path());
        let a = r.set::<u64>("a").unwrap();
        let b = r.set::<u64>("b").unwrap();
        for v in 0..100u64 {
            a.add(&v).unwrap();
        }
        for v in 50..150u64 {
            b.add(&v).unwrap();
        }
        a.sync().unwrap();
        b.sync().unwrap();

        let u = r.set::<u64>("u").unwrap();
        u.union_with(&a).unwrap();
        u.union_with(&b).unwrap();
        assert_eq!(u.size(), 150);

        let i = r.set::<u64>("i").unwrap();
        i.union_with(&a).unwrap();
        i.intersect_with(&b).unwrap();
        assert_eq!(as_btree(&i), (50..100).collect());

        let d = r.set::<u64>("d").unwrap();
        d.union_with(&a).unwrap();
        d.difference_with(&b).unwrap();
        assert_eq!(as_btree(&d), (0..50).collect());
    }

    #[test]
    fn prop_set_matches_btreeset_model() {
        prop_check("RoomySet == BTreeSet", 10, |rng| {
            let t = tmpdir("rset_prop");
            let r = mk(t.path());
            let s = r.set::<u64>("s").unwrap();
            let mut model: BTreeSet<u64> = BTreeSet::new();
            for _round in 0..rng.range(1, 4) {
                let mut adds = vec![];
                let mut removes = vec![];
                for _ in 0..rng.range(0, 200) {
                    let v = rng.below(50);
                    if rng.chance(0.7) {
                        s.add(&v).unwrap();
                        adds.push(v);
                    } else {
                        s.remove(&v).unwrap();
                        removes.push(v);
                    }
                }
                s.sync().unwrap();
                // model: removes dominate adds within one sync
                for v in adds {
                    if !removes.contains(&v) {
                        model.insert(v);
                    }
                }
                for v in removes {
                    model.remove(&v);
                }
            }
            assert_eq!(as_btree(&s), model);
            assert_eq!(s.size(), model.len() as u64);
        });
    }

    #[test]
    fn prop_algebra_matches_std_ops() {
        prop_check("RoomySet algebra == std", 8, |rng| {
            let t = tmpdir("rset_palg");
            let r = mk(t.path());
            let va: BTreeSet<u64> =
                (0..rng.range(0, 100)).map(|_| rng.below(60)).collect();
            let vb: BTreeSet<u64> =
                (0..rng.range(0, 100)).map(|_| rng.below(60)).collect();
            let a = r.set::<u64>("a").unwrap();
            let b = r.set::<u64>("b").unwrap();
            for v in &va {
                a.add(v).unwrap();
            }
            for v in &vb {
                b.add(v).unwrap();
            }
            a.sync().unwrap();
            b.sync().unwrap();
            match rng.range(0, 3) {
                0 => {
                    a.union_with(&b).unwrap();
                    assert_eq!(as_btree(&a), va.union(&vb).copied().collect());
                }
                1 => {
                    a.difference_with(&b).unwrap();
                    assert_eq!(as_btree(&a), va.difference(&vb).copied().collect());
                }
                _ => {
                    a.intersect_with(&b).unwrap();
                    assert_eq!(as_btree(&a), va.intersection(&vb).copied().collect());
                }
            }
            assert_eq!(a.size() as usize, a.collect().unwrap().len());
        });
    }

    #[test]
    fn spill_heavy_sync() {
        let t = tmpdir("rset_spill");
        let mut cfg = crate::RoomyConfig::for_testing(t.path());
        cfg.op_buffer_bytes = 128;
        let r = Roomy::open(cfg).unwrap();
        let s = r.set::<u64>("s").unwrap();
        for v in 0..20_000u64 {
            s.add(&(v % 5000)).unwrap();
        }
        s.sync().unwrap();
        assert_eq!(s.size(), 5000);
    }

    fn mk_bloom(root: &std::path::Path, approx: bool) -> Roomy {
        let mut cfg = crate::RoomyConfig::for_testing(root);
        cfg.bloom_bits_per_key = 10;
        cfg.bloom_approximate = approx;
        Roomy::open(cfg).unwrap()
    }

    #[test]
    fn bloom_exact_mode_matches_plain_semantics() {
        let t0 = tmpdir("rset_bl_off");
        let t1 = tmpdir("rset_bl_on");
        let run = |r: &Roomy| -> (BTreeSet<u64>, u64) {
            let s = r.set::<u64>("s").unwrap();
            for v in [9u64, 3, 7, 1, 3, 100, 55] {
                s.add(&v).unwrap();
            }
            s.sync().unwrap();
            for v in [2u64, 8, 4, 7] {
                s.add(&v).unwrap();
            }
            s.remove(&9).unwrap();
            s.sync().unwrap();
            (as_btree(&s), s.size())
        };
        let plain = run(&mk(t0.path()));
        let bloomed = run(&mk_bloom(t1.path(), false));
        assert_eq!(plain, bloomed);
        assert_eq!(plain.0, BTreeSet::from([1, 2, 3, 4, 7, 8, 55, 100]));
    }

    #[test]
    fn bloom_fronts_contains_without_scanning() {
        let t = tmpdir("rset_bl_contains");
        let r = mk_bloom(t.path(), false);
        let s = r.set::<u64>("s").unwrap();
        for v in 0..100u64 {
            s.add(&v).unwrap();
        }
        s.sync().unwrap();
        for v in 0..100u64 {
            assert!(s.contains(&v).unwrap(), "fed element must be found");
        }
        for v in 1000..1100u64 {
            assert!(!s.contains(&v).unwrap(), "absent element must stay absent");
        }
        let snap = r.dedup_snapshot();
        assert!(snap.probes >= 200, "every contains goes through the filter");
        assert!(snap.shortcuts > 0, "definitely-new probes skip the shard scan");
    }

    #[test]
    fn bloom_approximate_drops_duplicate_adds_before_merge() {
        let t = tmpdir("rset_bl_approx");
        let r = mk_bloom(t.path(), true);
        let s = r.set::<u64>("s").unwrap();
        for v in 0..500u64 {
            s.add(&v).unwrap();
        }
        s.sync().unwrap();
        assert_eq!(s.size(), 500);
        // Re-adding the same elements: every add probes maybe-seen (no
        // false negatives over the fed set), so the whole second sync
        // short-circuits without a merge.
        for v in 0..500u64 {
            s.add(&v).unwrap();
        }
        s.sync().unwrap();
        assert_eq!(s.size(), 500);
        let snap = r.dedup_snapshot();
        assert_eq!(snap.approx_dropped, 500);
        assert!(snap.shortcuts > 0, "all-duplicate shards skip the merge");
        // Genuinely-new elements still land (modulo the small measured
        // FP budget — deterministic for a fixed key set).
        for v in 500..550u64 {
            s.add(&v).unwrap();
        }
        s.sync().unwrap();
        assert!(s.size() >= 540 && s.size() <= 550, "size {}", s.size());
    }

    #[test]
    fn destroy_removes_dirs() {
        let t = tmpdir("rset_destroy");
        let r = mk(t.path());
        let s = r.set::<u64>("s").unwrap();
        s.add(&1).unwrap();
        s.sync().unwrap();
        s.destroy().unwrap();
        for w in 0..r.cluster().nworkers() {
            assert!(!r.cluster().disk(w).exists("rs_s"));
        }
    }
}
