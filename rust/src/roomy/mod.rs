//! The Roomy API: distributed disk-backed data structures (paper §2).
//!
//! A [`Roomy`] instance owns a simulated [`Cluster`](crate::cluster::Cluster)
//! and hands out data structures partitioned across the cluster's disks:
//!
//! - [`RoomyArray`]: fixed-size indexed array of fixed-size elements
//! - [`RoomyBitArray`]: array of 1/2/4/8-bit elements
//! - [`RoomyHashTable`]: key → value map
//! - [`RoomyList`]: unordered multiset with sort-based set algebra
//! - [`RoomySet`]: native set with incrementally-sorted shards (the
//!   paper's stated future work)
//!
//! Operations are **immediate** when they stream (map, reduce, size,
//! add_all, remove_all, remove_dupes, predicate_count) and **delayed**
//! when they random-access (access, update, insert, remove, add) — delayed
//! ops take effect at the structure's `sync()`. See paper Table 1.

pub mod array;
pub mod bitarray;
pub mod bitkernels;
pub mod element;
pub mod flat;
pub mod funcs;
pub mod hashtable;
pub mod list;
pub mod ops;
pub mod set;

pub use array::RoomyArray;
pub use bitarray::RoomyBitArray;
pub use element::Element;
pub use funcs::{AccessId, PredId, UpdateId};
pub use hashtable::RoomyHashTable;
pub use list::RoomyList;
pub use set::{RoomySet, SetOp};

use std::collections::HashSet;
use std::sync::{Arc, Mutex, OnceLock};

use crate::cluster::Cluster;
use crate::config::RoomyConfig;
use crate::error::{Result, RoomyError};
use crate::metrics::DedupStats;
use crate::runtime::Engine;
use crate::storage::bloom::DedupFilter;
use crate::storage::checkpoint::{CheckpointManager, Restored, StructKind};

/// Shared context threaded through every structure: configuration, the
/// cluster, the lazily-initialized XLA engine, and the instance-wide
/// dedup-tier counters.
pub(crate) struct CtxInner {
    pub cfg: RoomyConfig,
    pub cluster: Arc<Cluster>,
    pub engine: OnceLock<Option<Arc<Engine>>>,
    pub dedup: Arc<DedupStats>,
}

pub(crate) type Ctx = Arc<CtxInner>;

impl Drop for CtxInner {
    fn drop(&mut self) {
        // Last handle to the instance: flush any armed flight-recorder
        // trace so short-lived programs get a file without calling
        // `flush_trace()` explicitly. Errors are swallowed — teardown
        // must never fail because a trace destination vanished.
        if crate::obs::trace::enabled() {
            let _ = crate::obs::trace::flush();
        }
    }
}

impl CtxInner {
    /// A fresh per-bucket bloom filter bank for one structure, or `None`
    /// when the tier is disabled (`bloom_bits_per_key == 0`). Structures
    /// that participate in dup-elim (list, set, hashtable) call this at
    /// create/restore time; every filter bank shares the instance's
    /// [`DedupStats`].
    pub fn dedup_filter(&self) -> Option<DedupFilter> {
        if self.cfg.bloom_bits_per_key == 0 {
            return None;
        }
        Some(DedupFilter::new(
            self.cfg.nbuckets(),
            self.cfg.bloom_bits_per_key,
            self.cfg.bloom_approximate,
            Arc::clone(&self.dedup),
        ))
    }
}

/// Handle to a Roomy instance. Cheap to clone.
#[derive(Clone)]
pub struct Roomy {
    ctx: Ctx,
    names: Arc<Mutex<HashSet<String>>>,
}

impl Roomy {
    /// Bring up a Roomy instance: validates `cfg`, creates the per-node
    /// disk directories.
    pub fn open(cfg: RoomyConfig) -> Result<Roomy> {
        if let Some(p) = &cfg.trace_path {
            crate::obs::trace::arm(p);
        }
        // Latency histograms: armed explicitly, or implied by the
        // spans-mode tuner (which reads them every round). Must happen
        // before the cluster comes up so its Autotune sees a live bank.
        if cfg.hist || cfg.autotune == crate::config::AutotuneMode::Spans {
            crate::obs::hist::arm();
        }
        // Pin the process-wide kernel dispatch (batched fingerprints,
        // word kernels) to the configured mode. Every mode is bit-exact;
        // this only selects which lane code runs.
        crate::hashfn::set_kernel_mode(cfg.kernels);
        let cluster = Arc::new(Cluster::new(&cfg)?);
        Ok(Roomy {
            ctx: Arc::new(CtxInner {
                cfg,
                cluster,
                engine: OnceLock::new(),
                dedup: Arc::new(DedupStats::new()),
            }),
            names: Arc::new(Mutex::new(HashSet::new())),
        })
    }

    /// The underlying simulated cluster (metrics, per-node disks).
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.ctx.cluster
    }

    /// The instance configuration.
    pub fn config(&self) -> &RoomyConfig {
        &self.ctx.cfg
    }

    /// The XLA acceleration engine, if enabled and available. Lazily
    /// initialized on first use; `AccelMode::Rust` always yields `None`.
    pub fn engine(&self) -> Option<Arc<Engine>> {
        self.ctx
            .engine
            .get_or_init(|| Engine::from_config(&self.ctx.cfg))
            .clone()
    }

    pub(crate) fn ctx(&self) -> Ctx {
        Arc::clone(&self.ctx)
    }

    fn claim_name(&self, name: &str) -> Result<()> {
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(RoomyError::InvalidArg(format!(
                "structure name {name:?} must be non-empty [A-Za-z0-9_-]"
            )));
        }
        let mut g = self.names.lock().unwrap();
        if !g.insert(name.to_string()) {
            return Err(RoomyError::InvalidArg(format!(
                "structure name {name:?} already in use"
            )));
        }
        Ok(())
    }

    /// Create a [`RoomyArray`] of `len` elements, all set to `default`.
    pub fn array<T: Element>(&self, name: &str, len: u64, default: T) -> Result<RoomyArray<T>> {
        self.claim_name(name)?;
        RoomyArray::create(self.ctx(), name, len, default)
    }

    /// Create a [`RoomyBitArray`] of `len` elements of `bits` ∈ {1,2,4,8}
    /// bits each, zero-filled.
    pub fn bit_array(&self, name: &str, len: u64, bits: u8) -> Result<RoomyBitArray> {
        self.claim_name(name)?;
        RoomyBitArray::create(self.ctx(), name, len, bits)
    }

    /// Create an empty [`RoomyHashTable`].
    pub fn hash_table<K: Element, V: Element>(&self, name: &str) -> Result<RoomyHashTable<K, V>> {
        self.claim_name(name)?;
        RoomyHashTable::create(self.ctx(), name)
    }

    /// Create an empty [`RoomyList`].
    pub fn list<T: Element>(&self, name: &str) -> Result<RoomyList<T>> {
        self.claim_name(name)?;
        RoomyList::create(self.ctx(), name)
    }

    /// Create an empty [`RoomySet`] (the paper's future-work native set:
    /// incrementally-sorted shards, merge-based algebra primitives).
    pub fn set<T: Element>(&self, name: &str) -> Result<RoomySet<T>> {
        self.claim_name(name)?;
        RoomySet::create(self.ctx(), name)
    }

    /// Release a structure name for reuse (used with `destroy` in
    /// long-lived programs like the BFS level rotation).
    pub fn release_name(&self, name: &str) {
        self.names.lock().unwrap().remove(name);
    }

    // ------------------------------------------------------------------
    // Durable checkpoints ([`crate::storage::checkpoint`])
    // ------------------------------------------------------------------

    /// A checkpoint manager over this instance's cluster, rooted at
    /// [`Cluster::checkpoint_root`] (default `<root>/checkpoints/`,
    /// configurable via `RoomyConfig::checkpoint_dir`).
    pub fn checkpoints(&self) -> Result<CheckpointManager> {
        CheckpointManager::new(&self.ctx.cluster)
    }

    /// Re-open a checkpointed [`RoomyList`] whose files
    /// [`CheckpointManager::restore`] put back on the node disks. The
    /// element type is checked against the manifest record size.
    pub fn restored_list<T: Element>(&self, res: &Restored, name: &str) -> Result<RoomyList<T>> {
        let meta = res.require(StructKind::List, name)?;
        if meta.rec_size != T::SIZE {
            return Err(RoomyError::Checkpoint(format!(
                "list {name:?} holds {}-byte elements, requested type is {} bytes",
                meta.rec_size,
                T::SIZE
            )));
        }
        self.claim_name(name)?;
        RoomyList::open_restored(self.ctx(), name, meta.size, meta.sorted)
    }

    /// Re-open a checkpointed [`RoomyArray`] (see [`Roomy::restored_list`]).
    pub fn restored_array<T: Element>(&self, res: &Restored, name: &str) -> Result<RoomyArray<T>> {
        let meta = res.require(StructKind::Array, name)?;
        if meta.rec_size != T::SIZE {
            return Err(RoomyError::Checkpoint(format!(
                "array {name:?} holds {}-byte elements, requested type is {} bytes",
                meta.rec_size,
                T::SIZE
            )));
        }
        self.claim_name(name)?;
        RoomyArray::open_restored(self.ctx(), name, meta.len)
    }

    /// Re-open a checkpointed [`RoomyBitArray`] (see [`Roomy::restored_list`]).
    pub fn restored_bit_array(&self, res: &Restored, name: &str) -> Result<RoomyBitArray> {
        let meta = res.require(StructKind::BitArray, name)?;
        self.claim_name(name)?;
        RoomyBitArray::open_restored(self.ctx(), name, meta.len, meta.bits, &meta.counts)
    }

    /// Re-open a checkpointed [`RoomyHashTable`] (see [`Roomy::restored_list`]).
    pub fn restored_hash_table<K: Element, V: Element>(
        &self,
        res: &Restored,
        name: &str,
    ) -> Result<RoomyHashTable<K, V>> {
        let meta = res.require(StructKind::HashTable, name)?;
        if meta.rec_size != K::SIZE + V::SIZE || meta.key_size != K::SIZE {
            return Err(RoomyError::Checkpoint(format!(
                "hash table {name:?} holds {}-byte keys / {}-byte records, requested types are {} / {}",
                meta.key_size,
                meta.rec_size,
                K::SIZE,
                K::SIZE + V::SIZE
            )));
        }
        self.claim_name(name)?;
        RoomyHashTable::open_restored(self.ctx(), name, meta.size)
    }

    /// Re-open a checkpointed [`RoomySet`] (see [`Roomy::restored_list`]).
    pub fn restored_set<T: Element>(&self, res: &Restored, name: &str) -> Result<RoomySet<T>> {
        let meta = res.require(StructKind::Set, name)?;
        if meta.rec_size != T::SIZE {
            return Err(RoomyError::Checkpoint(format!(
                "set {name:?} holds {}-byte elements, requested type is {} bytes",
                meta.rec_size,
                T::SIZE
            )));
        }
        self.claim_name(name)?;
        RoomySet::open_restored(self.ctx(), name, meta.size)
    }

    /// Aggregate I/O across all node disks.
    pub fn io_snapshot(&self) -> crate::metrics::IoSnapshot {
        self.ctx.cluster.io_snapshot()
    }

    /// Point-in-time counters of the approximate-membership dedup tier
    /// ([`crate::storage::bloom`]); all zeros when `bloom_bits_per_key`
    /// is 0.
    pub fn dedup_snapshot(&self) -> crate::metrics::DedupSnapshot {
        self.ctx.dedup.snapshot()
    }

    /// Multi-line human-readable metrics report.
    pub fn report(&self) -> String {
        let io = self.io_snapshot();
        let mut s = String::new();
        s.push_str(&format!(
            "io: read {} ({} ops), wrote {} ({} ops), {} seeks\n",
            crate::metrics::fmt_bytes(io.bytes_read),
            io.reads,
            crate::metrics::fmt_bytes(io.bytes_written),
            io.writes,
            io.seeks,
        ));
        let pipe = self.ctx.cluster.pipeline_snapshot();
        s.push_str(&format!(
            "pipeline (depth {}): {} streams, read-ahead {} ({} chunks), write-behind {} ({} chunks), peak stream buf {}, stalls r {:.1} ms / w {:.1} ms\n",
            self.ctx.cfg.io_pipeline_depth,
            pipe.streams,
            crate::metrics::fmt_bytes(pipe.bytes_ahead),
            pipe.chunks_ahead,
            crate::metrics::fmt_bytes(pipe.bytes_behind),
            pipe.chunks_behind,
            crate::metrics::fmt_bytes(pipe.peak_stream_buf),
            pipe.reader_wait_ns as f64 / 1e6,
            pipe.writer_wait_ns as f64 / 1e6,
        ));
        s.push_str(&format!(
            "prefetch hints: {} posted, {} hits ({:.0}%), {} wasted\n",
            pipe.hints_posted,
            pipe.hint_hits,
            pipe.hint_hit_rate() * 100.0,
            pipe.hint_wastes,
        ));
        if self.ctx.cfg.bloom_bits_per_key > 0 {
            s.push_str(&format!(
                "{} ({} bits/key, {} mode)\n",
                self.dedup_snapshot().report(),
                self.ctx.cfg.bloom_bits_per_key,
                if self.ctx.cfg.bloom_approximate { "approximate" } else { "exact-backed" },
            ));
        }
        s.push_str(&crate::storage::scratch::alloc_snapshot().report());
        s.push('\n');
        if crate::obs::hist::enabled() {
            use crate::metrics::fmt_dur_ns;
            let bank = crate::obs::hist::global();
            for d in crate::obs::hist::DOMAINS {
                let m = bank.merged(d);
                if m.count() == 0 {
                    continue;
                }
                s.push_str(&format!(
                    "hist {}: {} samples, p50 {} / p95 {} / p99 {}, mean {}\n",
                    d.key(),
                    m.count(),
                    fmt_dur_ns(m.p50()),
                    fmt_dur_ns(m.p95()),
                    fmt_dur_ns(m.p99()),
                    fmt_dur_ns(m.mean_ns()),
                ));
            }
        }
        match self.ctx.cluster.autotune() {
            Some(at) => {
                s.push_str(&at.report(self.ctx.cluster.disks()));
                s.push('\n');
            }
            None => s.push_str("autotune: off\n"),
        }
        s.push_str("phases:\n");
        s.push_str(&self.ctx.cluster.phases().report());
        s.push_str(&format!(
            "pool ({} workers, steal={}):\n",
            self.ctx.cluster.pool().num_workers(),
            self.ctx.cluster.pool().steal_policy(),
        ));
        s.push_str(&self.ctx.cluster.pool().stats().report());
        s
    }

    /// Flush the flight recorder to the armed trace destination now
    /// (normally it flushes on teardown). Returns the path written, or
    /// `Ok(None)` when tracing was never armed.
    pub fn flush_trace(&self) -> std::io::Result<Option<std::path::PathBuf>> {
        crate::obs::trace::flush()
    }

    /// Machine-readable metrics report: every counter surface
    /// ([`report`](Roomy::report) shows the same data for humans) as one
    /// JSON document.
    ///
    /// The document carries `"schema": 1`. Versioning rule: adding keys
    /// is allowed without a bump; removing or renaming a key, or changing
    /// a value's type or unit, bumps the schema number.
    pub fn report_json(&self) -> String {
        use crate::obs::json::{array, Obj};
        let cfg = &self.ctx.cfg;
        let io = self.io_snapshot();
        let pipe = self.ctx.cluster.pipeline_snapshot();
        let dd = self.dedup_snapshot();
        let al = crate::storage::scratch::alloc_snapshot();
        let ck = self.ctx.cluster.checkpoint_stats().snapshot();
        let pool = self.ctx.cluster.pool();
        let ps = pool.stats();

        let mut root = Obj::new();
        root.u64("schema", 1);

        let mut c = Obj::new();
        c.u64("nodes", cfg.workers as u64);
        c.u64("buckets_per_worker", cfg.buckets_per_worker as u64);
        c.u64("num_workers", cfg.num_workers as u64);
        c.u64("io_pipeline_depth", cfg.io_pipeline_depth as u64);
        c.str("steal_policy", &format!("{}", cfg.steal_policy));
        c.u64("bloom_bits_per_key", cfg.bloom_bits_per_key as u64);
        c.bool("bloom_approximate", cfg.bloom_approximate);
        c.str("autotune", &format!("{:?}", cfg.autotune));
        c.str("kernels", cfg.kernels.as_str());
        c.str("kernel_impl", crate::hashfn::kernel_impl());
        c.bool("hist", cfg.hist);
        match &cfg.trace_path {
            Some(p) => {
                c.str("trace_path", &p.display().to_string());
            }
            None => {
                c.raw("trace_path", "null");
            }
        }
        root.raw("config", &c.build());

        let mut o = Obj::new();
        o.u64("bytes_read", io.bytes_read);
        o.u64("bytes_written", io.bytes_written);
        o.u64("reads", io.reads);
        o.u64("writes", io.writes);
        o.u64("seeks", io.seeks);
        o.f64("throttle_ms", io.throttle_ns as f64 / 1e6);
        root.raw("io", &o.build());

        let mut o = Obj::new();
        o.u64("depth", cfg.io_pipeline_depth as u64);
        o.u64("streams", pipe.streams);
        o.u64("chunks_ahead", pipe.chunks_ahead);
        o.u64("bytes_ahead", pipe.bytes_ahead);
        o.u64("chunks_behind", pipe.chunks_behind);
        o.u64("bytes_behind", pipe.bytes_behind);
        o.u64("peak_stream_buf", pipe.peak_stream_buf);
        o.f64("reader_wait_ms", pipe.reader_wait_ns as f64 / 1e6);
        o.f64("writer_wait_ms", pipe.writer_wait_ns as f64 / 1e6);
        o.u64("hints_posted", pipe.hints_posted);
        o.u64("hint_hits", pipe.hint_hits);
        o.u64("hint_wastes", pipe.hint_wastes);
        o.f64("hint_hit_rate", pipe.hint_hit_rate());
        root.raw("pipeline", &o.build());

        let mut o = Obj::new();
        o.bool("enabled", cfg.bloom_bits_per_key > 0);
        o.u64("probes", dd.probes);
        o.u64("definite_new", dd.definite_new);
        o.u64("maybe_seen", dd.maybe_seen);
        o.u64("inserts", dd.inserts);
        o.u64("shortcuts", dd.shortcuts);
        o.u64("exact_fallbacks", dd.exact_fallbacks);
        o.u64("bytes_avoided", dd.bytes_avoided);
        o.u64("approx_dropped", dd.approx_dropped);
        o.u64("filter_ram_bytes", dd.filter_ram_bytes);
        root.raw("dedup", &o.build());

        let mut o = Obj::new();
        o.u64("pool_hits", al.pool_hits);
        o.u64("pool_misses", al.pool_misses);
        o.f64("reuse_rate", al.reuse_rate());
        o.u64("returns", al.returns);
        o.u64("discards", al.discards);
        o.u64("outstanding", al.outstanding);
        o.u64("outstanding_bytes", al.outstanding_bytes);
        o.u64("peak_outstanding_bytes", al.peak_outstanding_bytes);
        o.u64("pooled_bytes", al.pooled_bytes);
        o.u64("peak_pooled_bytes", al.peak_pooled_bytes);
        o.u64("arena_bytes", al.arena_bytes);
        root.raw("scratch", &o.build());

        let mut o = Obj::new();
        o.u64("saves", ck.saves);
        o.u64("restores", ck.restores);
        o.u64("files_linked", ck.files_linked);
        o.u64("files_copied", ck.files_copied);
        o.u64("bytes_linked", ck.bytes_linked);
        o.u64("bytes_copied", ck.bytes_copied);
        o.u64("files_reused", ck.files_reused);
        o.u64("bytes_reused", ck.bytes_reused);
        o.f64("save_ms", ck.save_ns as f64 / 1e6);
        o.f64("restore_ms", ck.restore_ns as f64 / 1e6);
        root.raw("checkpoint", &o.build());

        let mut o = Obj::new();
        o.u64("workers", pool.num_workers() as u64);
        o.str("steal_policy", &format!("{}", pool.steal_policy()));
        o.u64("locality_hits", ps.locality_hits());
        o.u64("steals", ps.steals());
        o.f64("locality_rate", ps.locality_rate());
        o.u64("capture_bytes", ps.capture_bytes());
        o.u64("capture_spilled_bytes", ps.capture_spilled_bytes());
        o.u64("capture_scratch_files", ps.capture_scratch_files());
        o.u64("capture_peak_task_ram", ps.capture_peak_task_ram());
        o.u64("capture_budget_spills", ps.capture_budget_spills());
        let depths: Vec<String> =
            ps.per_node_queue_depth().iter().map(|d| d.to_string()).collect();
        o.raw("queue_depth_peaks", &array(&depths));
        let rows: Vec<String> = ps
            .per_worker()
            .into_iter()
            .enumerate()
            .map(|(w, (tasks, busy))| {
                let mut r = Obj::new();
                r.u64("worker", w as u64);
                r.u64("tasks", tasks);
                r.f64("busy_ms", busy.as_secs_f64() * 1e3);
                r.build()
            })
            .collect();
        o.raw("per_worker", &array(&rows));
        root.raw("pool", &o.build());

        let mut o = Obj::new();
        match self.ctx.cluster.autotune() {
            Some(at) => {
                o.bool("enabled", true);
                o.str("mode", at.mode());
                o.u64("rounds", at.rounds());
                o.u64("depth_raises", at.depth_raises());
                o.u64("depth_decays", at.depth_decays());
                o.u64("hint_ahead", at.hint_ahead() as u64);
                o.u64("width", at.width() as u64);
                o.u64("width_shrinks", at.width_shrinks());
                o.u64("width_grows", at.width_grows());
                o.u64("steal_boosts", at.steal_boosts());
                let eff: Vec<String> = self
                    .ctx
                    .cluster
                    .disks()
                    .iter()
                    .map(|d| d.effective_depth().to_string())
                    .collect();
                o.raw("effective_depths", &array(&eff));
            }
            None => {
                o.bool("enabled", false);
            }
        }
        root.raw("autotune", &o.build());

        let phases: Vec<String> = self
            .ctx
            .cluster
            .phases()
            .rows()
            .into_iter()
            .map(|(name, d, hits)| {
                let mut r = Obj::new();
                r.str("name", &name);
                r.f64("total_ms", d.as_secs_f64() * 1e3);
                r.u64("calls", hits);
                r.build()
            })
            .collect();
        root.raw("phases", &array(&phases));

        let mut o = Obj::new();
        o.bool("enabled", crate::obs::trace::enabled());
        // Ring-overwrite total: nonzero means any flushed trace is a
        // truncated window, and `obs::analyze` will say so.
        o.u64("dropped_events", crate::obs::trace::dropped_events());
        match crate::obs::trace::armed_path() {
            Some(p) => {
                o.str("path", &p.display().to_string());
            }
            None => {
                o.raw("path", "null");
            }
        }
        root.raw("trace", &o.build());

        // Latency histograms ([`crate::obs::hist`]): per-domain merged
        // percentiles plus per-node task rows (the skew surface the
        // spans-mode tuner reads). All zeros / absent domains when the
        // bank was never armed.
        let mut o = Obj::new();
        o.bool("enabled", crate::obs::hist::enabled());
        if crate::obs::hist::enabled() {
            let bank = crate::obs::hist::global();
            for d in crate::obs::hist::DOMAINS {
                let m = bank.merged(d);
                let mut h = Obj::new();
                h.u64("count", m.count());
                h.f64("p50_us", m.p50() as f64 / 1e3);
                h.f64("p95_us", m.p95() as f64 / 1e3);
                h.f64("p99_us", m.p99() as f64 / 1e3);
                h.f64("mean_us", m.mean_ns() as f64 / 1e3);
                o.raw(d.key(), &h.build());
            }
            let rows: Vec<String> = bank
                .per_node(crate::obs::hist::Domain::Task, cfg.workers)
                .into_iter()
                .enumerate()
                .filter(|(_, m)| m.count() > 0)
                .map(|(n, m)| {
                    let mut r = Obj::new();
                    r.u64("node", n as u64);
                    r.u64("count", m.count());
                    r.f64("p95_us", m.p95() as f64 / 1e3);
                    r.f64("mean_us", m.mean_ns() as f64 / 1e3);
                    r.build()
                })
                .collect();
            o.raw("task_per_node", &array(&rows));
        }
        root.raw("hist", &o.build());

        root.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::tmpdir;

    #[test]
    fn open_and_create_structures() {
        let t = tmpdir("roomy_open");
        let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
        let _a = r.array::<u32>("arr", 10, 0).unwrap();
        let _l = r.list::<u64>("lst").unwrap();
        let _h = r.hash_table::<u64, u32>("ht").unwrap();
        let _b = r.bit_array("bits", 100, 2).unwrap();
    }

    #[test]
    fn duplicate_names_rejected_until_released() {
        let t = tmpdir("roomy_names");
        let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
        let _a = r.array::<u32>("x", 10, 0).unwrap();
        assert!(r.array::<u32>("x", 10, 0).is_err());
        r.release_name("x");
        assert!(r.list::<u32>("x").is_ok());
    }

    #[test]
    fn bad_names_rejected() {
        let t = tmpdir("roomy_badname");
        let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
        assert!(r.array::<u32>("", 10, 0).is_err());
        assert!(r.array::<u32>("a/b", 10, 0).is_err());
        assert!(r.array::<u32>("a b", 10, 0).is_err());
    }

    #[test]
    fn report_json_round_trips() {
        let t = tmpdir("roomy_report_json");
        let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
        let a = r.array::<u32>("arr", 100, 1).unwrap();
        a.map(|_, _| {}).unwrap();
        let doc = r.report_json();
        let v = crate::obs::json::parse(&doc).expect("report_json must parse");
        assert_eq!(v.get("schema").and_then(|s| s.as_f64()), Some(1.0), "{doc}");
        let io = v.get("io").expect("io section");
        assert!(io.get("bytes_read").and_then(|b| b.as_f64()).is_some());
        let pool = v.get("pool").expect("pool section");
        let rows = pool.get("per_worker").and_then(|w| w.as_arr()).expect("per_worker");
        assert_eq!(rows.len(), r.config().num_workers);
        assert!(v.get("phases").and_then(|p| p.as_arr()).is_some());
        let at = v.get("autotune").expect("autotune section");
        assert!(at.get("enabled").is_some());
        let tr = v.get("trace").expect("trace section");
        assert!(tr.get("dropped_events").and_then(|d| d.as_u64()).is_some());
        let h = v.get("hist").expect("hist section");
        assert!(h.get("enabled").and_then(|e| e.as_bool()).is_some());
    }

    /// With the bank armed, the report surfaces task/collective
    /// percentiles and per-node task rows.
    #[test]
    fn report_json_surfaces_hist_percentiles() {
        let t = tmpdir("roomy_report_hist");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.hist = true;
        let r = Roomy::open(cfg).unwrap();
        let a = r.array::<u32>("arr", 200, 1).unwrap();
        a.map(|_, _| {}).unwrap();
        a.map(|_, _| {}).unwrap();
        let v = crate::obs::json::parse(&r.report_json()).unwrap();
        let h = v.get("hist").expect("hist section");
        assert_eq!(h.get("enabled").and_then(|e| e.as_bool()), Some(true));
        let task = h.get("task").expect("task domain");
        assert!(task.get("count").and_then(|c| c.as_u64()).unwrap() > 0);
        assert!(task.get("p95_us").and_then(|p| p.as_f64()).unwrap() > 0.0);
        let coll = h.get("collective").expect("collective domain");
        assert!(coll.get("count").and_then(|c| c.as_u64()).unwrap() >= 2);
        let rows = h.get("task_per_node").and_then(|r| r.as_arr()).unwrap();
        assert!(!rows.is_empty(), "per-node task rows must be present");
        let rep = r.report();
        assert!(rep.contains("hist task:"), "{rep}");
        assert!(rep.contains("p95"), "{rep}");
    }

    #[test]
    fn report_mentions_io() {
        let t = tmpdir("roomy_report");
        let r = Roomy::open(RoomyConfig::for_testing(t.path())).unwrap();
        let _a = r.array::<u32>("arr", 100, 1).unwrap();
        let rep = r.report();
        assert!(rep.contains("io:"), "{rep}");
        assert!(rep.contains("scratch pool:"), "{rep}");
        assert!(rep.contains("autotune: off"), "{rep}");
    }
}
