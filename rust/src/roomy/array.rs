//! `RoomyArray<T>`: a fixed-size, disk-resident, bucket-partitioned array.
//!
//! Paper §2: arrays (and hash tables) avoid the external sorts that
//! dominate `RoomyList` workloads by *bucketing* — indices map statically
//! to buckets sized to fit in RAM, delayed `access`/`update` operations
//! are staged per bucket, and `sync` streams each bucket through memory
//! exactly once to apply its batch.
//!
//! Semantics (matching the paper's chain-reduction example):
//! - delayed ops are applied at `sync`, never before;
//! - `passed` values are captured at issue time (scatter-gather), so an
//!   update reading pre-sync state via `map` is deterministic;
//! - within one bucket, staged ops apply in issue (FIFO) order.

use std::marker::PhantomData;
use std::sync::Arc;

use super::element::Element;
use super::funcs::{AccessId, FuncRegistry, PredId, UpdateId};
use super::ops::{OpKind, StagedOps};
use super::Ctx;
use crate::error::{Result, RoomyError};
use crate::storage::checkpoint::{Checkpointable, StructKind, StructMeta};
use crate::storage::chunkfile::RecordWriter;
use crate::storage::scratch;
use crate::storage::{
    read_all_pipelined, write_all_pipelined, NodeDisk, PrefetchReader, WriteBehindWriter,
};

/// Records streamed per batch during map/reduce scans.
const SCAN_BATCH: usize = 8192;

/// A distributed disk-backed array of `len` fixed-size elements.
///
/// Cheap to clone (all clones share state); safe to use from user
/// functions running on worker threads.
pub struct RoomyArray<T: Element> {
    inner: Arc<ArrayInner<T>>,
}

impl<T: Element> Clone for RoomyArray<T> {
    fn clone(&self) -> Self {
        RoomyArray { inner: Arc::clone(&self.inner) }
    }
}

struct ArrayInner<T: Element> {
    ctx: Ctx,
    name: String,
    dir: String,
    len: u64,
    /// Elements per bucket (last bucket may be short).
    bsize: u64,
    funcs: FuncRegistry,
    staged: Arc<StagedOps>,
    /// Serializes collectives that rewrite bucket files (`sync`,
    /// `map_update`): concurrent client threads would otherwise race
    /// take-read-modify-write on the same bucket and lose updates.
    write_lock: std::sync::Mutex<()>,
    _t: PhantomData<fn() -> T>,
}

impl<T: Element> RoomyArray<T> {
    /// Create the array, filling every element with `default`.
    pub(crate) fn create(ctx: Ctx, name: &str, len: u64, default: T) -> Result<Self> {
        if len == 0 {
            return Err(RoomyError::InvalidArg("RoomyArray length must be > 0".into()));
        }
        let dir = format!("ra_{name}");
        // A freshly created structure must be fully default-filled: clear
        // any same-named leftovers (e.g. rewrite tmp files) from a killed
        // run before materializing the buckets.
        ctx.cluster.remove_structure_dirs(&dir)?;
        let cluster = ctx.cluster.clone();
        let nb = cluster.nbuckets() as u64;
        let bsize = len.div_ceil(nb).max(1);
        let inner = ArrayInner {
            staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
            funcs: FuncRegistry::new(&format!("RoomyArray({name})")),
            write_lock: std::sync::Mutex::new(()),
            ctx,
            name: name.to_string(),
            dir: dir.clone(),
            len,
            bsize,
            _t: PhantomData,
        };
        // Materialize bucket files filled with the default element.
        let default_bytes = default.to_bytes();
        inner.for_owned_buckets("ra.create", |this, b, disk| {
            let recs = this.bucket_len(b);
            if recs == 0 {
                return Ok(());
            }
            let mut w = RecordWriter::create(disk, this.bucket_file(b), T::SIZE)?;
            // Write in chunks to keep the staging allocation bounded.
            let chunk_recs = SCAN_BATCH.min(recs as usize);
            let chunk: Vec<u8> = default_bytes
                .iter()
                .copied()
                .cycle()
                .take(chunk_recs * T::SIZE)
                .collect();
            let mut left = recs;
            while left > 0 {
                let n = (left as usize).min(chunk_recs);
                w.push_batch(&chunk[..n * T::SIZE])?;
                left -= n as u64;
            }
            w.finish()
        })?;
        Ok(RoomyArray { inner: Arc::new(inner) })
    }

    /// Re-open a restored array over bucket files already on disk
    /// ([`crate::storage::checkpoint`]): the layout mirrors `create`, but
    /// no bucket is materialized. Registered functions do not survive a
    /// checkpoint — re-register before staging delayed ops.
    pub(crate) fn open_restored(ctx: Ctx, name: &str, len: u64) -> Result<Self> {
        if len == 0 {
            return Err(RoomyError::InvalidArg("RoomyArray length must be > 0".into()));
        }
        let dir = format!("ra_{name}");
        let cluster = ctx.cluster.clone();
        let nb = cluster.nbuckets() as u64;
        let bsize = len.div_ceil(nb).max(1);
        Ok(RoomyArray {
            inner: Arc::new(ArrayInner {
                staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
                funcs: FuncRegistry::new(&format!("RoomyArray({name})")),
                write_lock: std::sync::Mutex::new(()),
                ctx,
                name: name.to_string(),
                dir,
                len,
                bsize,
                _t: PhantomData,
            }),
        })
    }

    /// Number of elements (immediate; paper Table 1 `size`).
    pub fn len(&self) -> u64 {
        self.inner.len
    }

    /// True if the array has no elements (never: creation requires > 0).
    pub fn is_empty(&self) -> bool {
        self.inner.len == 0
    }

    /// Structure name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Total staged (not yet synced) delayed-op bytes.
    pub fn pending_bytes(&self) -> u64 {
        self.inner.staged.staged_bytes()
    }

    // ------------------------------------------------------------------
    // Function registration (typed wrappers over the byte registry)
    // ------------------------------------------------------------------

    /// Register an update function `f(index, element, passed)`; the
    /// element is mutated in place when the op is applied at sync.
    pub fn register_update<P: Element>(
        &self,
        f: impl Fn(u64, &mut T, &P) + Send + Sync + 'static,
    ) -> UpdateId {
        self.inner.funcs.register_update(
            P::SIZE,
            Box::new(move |idx, elt, passed| {
                let mut t = T::read_from(elt);
                let p = P::read_from(passed);
                f(idx, &mut t, &p);
                t.write_to(elt);
            }),
        )
    }

    /// Register an access function `f(index, element, passed)`. Access
    /// functions run on worker threads during sync and may issue delayed
    /// ops on *other* structures (the paper's pair-reduction / BFS idiom).
    pub fn register_access<P: Element>(
        &self,
        f: impl Fn(u64, &T, &P) + Send + Sync + 'static,
    ) -> AccessId {
        self.inner.funcs.register_access(
            P::SIZE,
            Box::new(move |idx, elt, passed| {
                f(idx, &T::read_from(elt), &P::read_from(passed));
            }),
        )
    }

    /// Register a predicate and initialize its count with one streaming
    /// scan; afterwards the count is maintained incrementally on every
    /// mutation (paper Table 1: `predicateCount` needs no extra scan).
    pub fn register_predicate(
        &self,
        f: impl Fn(u64, &T) -> bool + Send + Sync + 'static,
    ) -> Result<PredId> {
        let id = self
            .inner
            .funcs
            .register_pred(Box::new(move |idx, elt| f(idx, &T::read_from(elt))));
        // Initializing scan.
        let inner = &self.inner;
        inner.for_owned_buckets("ra.pred_scan", |this, b, disk| {
            this.scan_bucket(b, disk, |idx, elt| {
                this.funcs.charge_pred_single(id, idx, elt);
                Ok(())
            })
        })?;
        Ok(id)
    }

    /// Current count of elements satisfying predicate `id` (immediate).
    pub fn predicate_count(&self, id: PredId) -> u64 {
        self.inner.funcs.pred_count(id)
    }

    // ------------------------------------------------------------------
    // Delayed operations
    // ------------------------------------------------------------------

    /// Delayed update of element `i` with `passed` via function `id`.
    pub fn update<P: Element>(&self, i: u64, passed: &P, id: UpdateId) -> Result<()> {
        self.stage_op(OpKind::Update, id.0, self.inner.funcs.update_passed_len(id.0)?, i, passed)
    }

    /// Delayed access of element `i` with `passed` via function `id`.
    pub fn access<P: Element>(&self, i: u64, passed: &P, id: AccessId) -> Result<()> {
        self.stage_op(OpKind::Access, id.0, self.inner.funcs.access_passed_len(id.0)?, i, passed)
    }

    fn stage_op<P: Element>(
        &self,
        kind: OpKind,
        fn_id: u8,
        expect_len: usize,
        i: u64,
        passed: &P,
    ) -> Result<()> {
        let inner = &self.inner;
        if i >= inner.len {
            return Err(RoomyError::InvalidArg(format!(
                "index {i} out of bounds for RoomyArray({}) of length {}",
                inner.name, inner.len
            )));
        }
        if P::SIZE != expect_len {
            return Err(RoomyError::InvalidArg(format!(
                "passed value is {} bytes but function was registered with {} bytes",
                P::SIZE,
                expect_len
            )));
        }
        super::ops::with_op_buf(|rec| {
            rec.push(kind as u8);
            rec.push(fn_id);
            rec.extend_from_slice(&i.to_le_bytes());
            let off = rec.len();
            rec.resize(off + P::SIZE, 0);
            passed.write_to(&mut rec[off..]);
            inner.staged.stage(inner.bucket_of(i), rec)
        })
    }

    /// Apply all outstanding delayed operations (paper Table 1 `sync`).
    ///
    /// Each bucket is loaded into RAM once, its op log is streamed in FIFO
    /// order, and the bucket is written back if any update dirtied it.
    /// Ops issued *during* this sync (by access functions) are processed
    /// by the next sync.
    pub fn sync(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        if inner.staged.is_empty() {
            return Ok(());
        }
        inner.for_owned_buckets("ra.sync", |this, b, disk| {
            let mut ops = this.staged.take(b, &this.ctx.cluster, &this.dir, this.ctx.cfg.op_buffer_bytes);
            if ops.is_empty() {
                return ops.clear();
            }
            let file = this.bucket_file(b);
            // Whole-bucket load/store rides the pipeline lanes too: the
            // op-log drain below prefetches while the bucket streams in.
            let mut data = read_all_pipelined(disk, &file)?;
            let base = b as u64 * this.bsize;
            let npreds = this.funcs.npreds();
            let mut dirty = false;

            // Op-log replay streams through the read-ahead lane; the
            // drain removes the log's spill file when it drops.
            let mut reader = ops.into_drain()?;
            let mut header = [0u8; 2];
            let mut idx_buf = [0u8; 8];
            let mut passed = scratch::record_buf();
            let mut old = scratch::record_buf();
            old.resize(T::SIZE, 0);
            while reader.read_exact_or_eof(&mut header)? {
                let kind = OpKind::from_u8(header[0]).ok_or_else(|| {
                    RoomyError::InvalidArg(format!("corrupt op tag {}", header[0]))
                })?;
                let fn_id = header[1];
                if !reader.read_exact_or_eof(&mut idx_buf)? {
                    return Err(RoomyError::InvalidArg("truncated op record".into()));
                }
                let idx = u64::from_le_bytes(idx_buf);
                let plen = match kind {
                    OpKind::Update => this.funcs.update_passed_len(fn_id)?,
                    OpKind::Access => this.funcs.access_passed_len(fn_id)?,
                    _ => {
                        return Err(RoomyError::InvalidArg(format!(
                            "unexpected op kind {kind:?} in array log"
                        )))
                    }
                };
                passed.resize(plen, 0);
                if plen > 0 && !reader.read_exact_or_eof(&mut passed)? {
                    return Err(RoomyError::InvalidArg("truncated op record".into()));
                }
                let off = ((idx - base) as usize) * T::SIZE;
                let elt = &mut data[off..off + T::SIZE];
                match kind {
                    OpKind::Update => {
                        if npreds > 0 {
                            old.copy_from_slice(elt);
                        }
                        this.funcs.apply_update(fn_id, idx, elt, &passed)?;
                        if npreds > 0 && old[..] != elt[..] {
                            this.funcs.charge_preds(idx, &old, -1);
                            this.funcs.charge_preds(idx, elt, 1);
                        }
                        dirty = true;
                    }
                    OpKind::Access => {
                        this.funcs.apply_access(fn_id, idx, elt, &passed)?;
                    }
                    _ => unreachable!(),
                }
            }
            drop(reader);
            if dirty {
                write_all_pipelined(disk, &file, &data)?;
            }
            Ok(())
        })
    }

    // ------------------------------------------------------------------
    // Immediate operations
    // ------------------------------------------------------------------

    /// Apply `f(index, element)` to every element, streaming all disks in
    /// parallel (immediate; paper Table 1 `map`).
    pub fn map(&self, f: impl Fn(u64, &T) + Sync) -> Result<()> {
        self.inner.for_owned_buckets("ra.map", |this, b, disk| {
            this.scan_bucket(b, disk, |idx, elt| {
                f(idx, &T::read_from(elt));
                Ok(())
            })
        })
    }

    /// Map that may mutate elements in place (streaming rewrite).
    pub fn map_update(&self, f: impl Fn(u64, &mut T) + Sync) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        inner.for_owned_buckets("ra.map_update", |this, b, disk| {
            let recs = this.bucket_len(b);
            if recs == 0 {
                return Ok(());
            }
            let file = this.bucket_file(b);
            let npreds = this.funcs.npreds();
            let tmp = format!("{}.mu.tmp", file);
            {
                // read-ahead the scan, write-behind the rewrite
                let mut r = PrefetchReader::open(disk, &file, T::SIZE)?;
                let mut w = WriteBehindWriter::create(disk, &tmp, T::SIZE)?;
                let mut buf = scratch::record_buf();
                let base = b as u64 * this.bsize;
                let mut idx = base;
                loop {
                    let n = r.read_batch(&mut buf, SCAN_BATCH)?;
                    if n == 0 {
                        break;
                    }
                    for elt in buf.chunks_exact_mut(T::SIZE) {
                        let mut t = T::read_from(elt);
                        f(idx, &mut t);
                        if npreds > 0 {
                            this.funcs.charge_preds(idx, elt, -1);
                        }
                        t.write_to(elt);
                        if npreds > 0 {
                            this.funcs.charge_preds(idx, elt, 1);
                        }
                        idx += 1;
                    }
                    w.push_batch(&buf)?;
                }
                w.finish()?;
            }
            disk.rename(&tmp, &file)
        })
    }

    /// Reduce: `fold` combines a per-bucket partial with one element;
    /// `merge` combines partials. Buckets reduce concurrently on the pool
    /// and partials merge in ascending bucket order, so for a fixed input
    /// the result is identical for every `num_workers` (the paper still
    /// requires assoc+comm in effect, since bucket layout is an
    /// implementation detail).
    pub fn reduce<R: Send>(
        &self,
        identity: impl Fn() -> R + Sync,
        fold: impl Fn(R, u64, &T) -> R + Sync,
        merge: impl Fn(R, R) -> R,
    ) -> Result<R> {
        let inner = &self.inner;
        let partials: Vec<R> = inner.ctx.cluster.run_buckets_hinted(
            "ra.reduce",
            |b| Some(inner.bucket_file(b)),
            |b, disk| {
                let mut local = Some(identity());
                inner.scan_bucket(b, disk, |idx, elt| {
                    let cur = local.take().expect("reduce accumulator");
                    local = Some(fold(cur, idx, &T::read_from(elt)));
                    Ok(())
                })?;
                Ok(local.take().expect("reduce accumulator"))
            },
        )?;
        let mut it = partials.into_iter();
        let first = it.next().expect("at least one bucket");
        Ok(it.fold(first, merge))
    }

    /// Random-access read of one element. **Debug/testing convenience** —
    /// this is exactly the latency-bound pattern Roomy exists to avoid;
    /// it is charged a seek per call.
    pub fn fetch(&self, i: u64) -> Result<T> {
        let inner = &self.inner;
        if i >= inner.len {
            return Err(RoomyError::InvalidArg(format!("index {i} out of bounds")));
        }
        let b = inner.bucket_of(i);
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        let mut r = disk.open_file(inner.bucket_file(b))?;
        let local = i - b as u64 * inner.bsize;
        r.seek_to(local * T::SIZE as u64)?;
        let mut buf = vec![0u8; T::SIZE];
        r.read_exact(&mut buf)?;
        Ok(T::read_from(&buf))
    }

    /// Delete all on-disk state for this array.
    pub fn destroy(self) -> Result<()> {
        let dir = self.inner.dir.clone();
        self.inner.ctx.cluster.remove_structure_dirs(dir)
    }
}

/// Raw bucket access for the accelerated constructs (crate-internal).
///
/// These bypass predicate accounting; callers (e.g.
/// [`crate::constructs::prefix::prefix_scan_array`]) must not be mixed
/// with registered predicates.
impl RoomyArray<i64> {
    /// Number of non-empty buckets.
    pub(crate) fn bucket_count(&self) -> u32 {
        self.inner.len.div_ceil(self.inner.bsize) as u32
    }

    /// The cluster this array lives on (pool dispatch for the
    /// accelerated constructs).
    pub(crate) fn cluster(&self) -> &Arc<crate::cluster::Cluster> {
        &self.inner.ctx.cluster
    }

    /// Relative path of bucket `b`'s file (prefetch hints from the
    /// accelerated constructs).
    pub(crate) fn bucket_rel(&self, b: u32) -> String {
        self.inner.bucket_file(b)
    }

    /// Read bucket `b` and decode its elements.
    pub(crate) fn read_bucket_i64(&self, b: u32) -> Result<Vec<i64>> {
        let inner = &self.inner;
        if inner.bucket_len(b) == 0 {
            return Ok(Vec::new());
        }
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        let data = read_all_pipelined(disk, inner.bucket_file(b))?;
        Ok(data.chunks_exact(8).map(i64::read_from).collect())
    }

    /// Overwrite bucket `b` with `vals` (must match the bucket length).
    pub(crate) fn write_bucket_i64(&self, b: u32, vals: &[i64]) -> Result<()> {
        let inner = &self.inner;
        debug_assert_eq!(vals.len() as u64, inner.bucket_len(b));
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        let mut bytes = vec![0u8; vals.len() * 8];
        for (v, chunk) in vals.iter().zip(bytes.chunks_exact_mut(8)) {
            v.write_to(chunk);
        }
        write_all_pipelined(disk, inner.bucket_file(b), &bytes)
    }
}

impl<T: Element> Checkpointable for RoomyArray<T> {
    fn ckpt_meta(&self) -> StructMeta {
        StructMeta {
            kind: StructKind::Array,
            name: self.inner.name.clone(),
            dir: self.inner.dir.clone(),
            rec_size: T::SIZE,
            key_size: 0,
            len: self.inner.len,
            size: 0,
            bits: 0,
            sorted: false,
            // bucket files are only ever replaced whole (tmp + rename),
            // so snapshots may hardlink them
            appendable: false,
            counts: Vec::new(),
        }
    }

    fn ckpt_pending(&self) -> u64 {
        RoomyArray::pending_bytes(self)
    }
}

impl<T: Element> ArrayInner<T> {
    fn bucket_of(&self, i: u64) -> u32 {
        (i / self.bsize) as u32
    }

    fn bucket_file(&self, b: u32) -> String {
        format!("{}/b{b}.dat", self.dir)
    }

    /// Elements held by bucket `b`.
    fn bucket_len(&self, b: u32) -> u64 {
        let start = b as u64 * self.bsize;
        if start >= self.len {
            0
        } else {
            self.bsize.min(self.len - start)
        }
    }

    /// Run `f(self, bucket, disk)` for every bucket on the worker pool,
    /// hinting each bucket's file for cross-task prefetch (sync, map and
    /// rewrite all start by streaming it).
    fn for_owned_buckets(
        &self,
        phase: &str,
        f: impl Fn(&Self, u32, &Arc<NodeDisk>) -> Result<()> + Sync,
    ) -> Result<()> {
        let _lbl = crate::obs::trace::struct_label(&self.name);
        self.ctx.cluster.run_buckets_hinted(
            phase,
            |b| Some(self.bucket_file(b)),
            |b, disk| f(self, b, disk),
        )?;
        Ok(())
    }

    /// Stream bucket `b`, invoking `f(index, element bytes)`.
    fn scan_bucket(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        mut f: impl FnMut(u64, &[u8]) -> Result<()>,
    ) -> Result<()> {
        if self.bucket_len(b) == 0 {
            return Ok(());
        }
        let mut r = PrefetchReader::open(disk, self.bucket_file(b), T::SIZE)?;
        let mut buf = scratch::record_buf();
        let mut idx = b as u64 * self.bsize;
        loop {
            let n = r.read_batch(&mut buf, SCAN_BATCH)?;
            if n == 0 {
                return Ok(());
            }
            for elt in buf.chunks_exact(T::SIZE) {
                f(idx, elt)?;
                idx += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    #[test]
    fn create_fill_and_fetch() {
        let t = tmpdir("ra_create");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 100, 7).unwrap();
        assert_eq!(ra.len(), 100);
        assert_eq!(ra.fetch(0).unwrap(), 7);
        assert_eq!(ra.fetch(99).unwrap(), 7);
        assert!(ra.fetch(100).is_err());
    }

    #[test]
    fn delayed_update_applies_only_at_sync() {
        let t = tmpdir("ra_delay");
        let r = mk(t.path());
        let ra = r.array::<u64>("a", 16, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v += *p);
        ra.update(3, &10u64, add).unwrap();
        ra.update(3, &5u64, add).unwrap();
        assert_eq!(ra.fetch(3).unwrap(), 0, "update must be delayed");
        ra.sync().unwrap();
        assert_eq!(ra.fetch(3).unwrap(), 15, "FIFO batch applied");
        // idempotent sync
        ra.sync().unwrap();
        assert_eq!(ra.fetch(3).unwrap(), 15);
    }

    #[test]
    fn updates_hit_every_bucket() {
        let t = tmpdir("ra_buckets");
        let r = mk(t.path());
        let n = 1000u64;
        let ra = r.array::<u64>("a", n, 0).unwrap();
        let set = ra.register_update(|i, v: &mut u64, p: &u64| *v = i + *p);
        for i in 0..n {
            ra.update(i, &1000u64, set).unwrap();
        }
        ra.sync().unwrap();
        let sum = ra
            .reduce(|| 0u64, |acc, _i, v| acc + v, |a, b| a + b)
            .unwrap();
        assert_eq!(sum, (0..n).map(|i| i + 1000).sum::<u64>());
    }

    #[test]
    fn access_runs_at_sync_with_element_value() {
        let t = tmpdir("ra_access");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 10, 42).unwrap();
        let hits = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let h = hits.clone();
        let acc = ra.register_access(move |i, v: &u32, p: &u32| {
            h.lock().unwrap().push((i, *v, *p));
        });
        ra.access(7, &9u32, acc).unwrap();
        assert!(hits.lock().unwrap().is_empty());
        ra.sync().unwrap();
        assert_eq!(hits.lock().unwrap().as_slice(), &[(7, 42, 9)]);
    }

    #[test]
    fn map_update_and_reduce() {
        let t = tmpdir("ra_mapred");
        let r = mk(t.path());
        let ra = r.array::<u64>("a", 257, 1).unwrap();
        ra.map_update(|i, v| *v = i).unwrap();
        let max = ra
            .reduce(|| 0u64, |acc, _i, v| acc.max(*v), |a, b| a.max(b))
            .unwrap();
        assert_eq!(max, 256);
    }

    #[test]
    fn map_sees_indices_in_every_bucket() {
        let t = tmpdir("ra_map");
        let r = mk(t.path());
        let ra = r.array::<u8>("a", 100, 0).unwrap();
        let seen = std::sync::Mutex::new(vec![false; 100]);
        ra.map(|i, _v| {
            seen.lock().unwrap()[i as usize] = true;
        })
        .unwrap();
        assert!(seen.lock().unwrap().iter().all(|&x| x));
    }

    #[test]
    fn predicate_count_initial_scan_and_maintenance() {
        let t = tmpdir("ra_pred");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 50, 0).unwrap();
        let set = ra.register_update(|_i, v: &mut u32, p: &u32| *v = *p);
        ra.update(4, &9u32, set).unwrap();
        ra.sync().unwrap();
        // register after some data exists: initializing scan must count it
        let nonzero = ra.register_predicate(|_i, v| *v != 0).unwrap();
        assert_eq!(ra.predicate_count(nonzero), 1);
        // maintained incrementally afterwards
        ra.update(5, &1u32, set).unwrap();
        ra.update(4, &0u32, set).unwrap();
        ra.sync().unwrap();
        assert_eq!(ra.predicate_count(nonzero), 1);
        ra.map_update(|_i, v| *v = 3).unwrap();
        assert_eq!(ra.predicate_count(nonzero), 50);
    }

    #[test]
    fn out_of_bounds_and_wrong_passed_size() {
        let t = tmpdir("ra_oob");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 10, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u32, p: &u32| *v += *p);
        assert!(ra.update(10, &1u32, add).is_err());
        assert!(ra.update(0, &1u64, add).is_err(), "passed size mismatch");
    }

    #[test]
    fn chain_reduction_semantics_pre_sync_values() {
        // The paper's chain-reduction determinism: passed values captured
        // from pre-sync state via map, applied at sync.
        let t = tmpdir("ra_chain");
        let r = mk(t.path());
        let n = 64u64;
        let ra = r.array::<u64>("a", n, 0).unwrap();
        ra.map_update(|i, v| *v = i + 1).unwrap(); // a[i] = i+1
        let ra2 = ra.clone();
        let do_update = ra.register_update(|_i, v: &mut u64, prev: &u64| *v += *prev);
        ra.map(move |i, v| {
            if i + 1 < n {
                ra2.update(i + 1, v, do_update).unwrap();
            }
        })
        .unwrap();
        ra.sync().unwrap();
        // a[i] = old a[i] + old a[i-1] = (i+1) + i
        for i in 1..n {
            assert_eq!(ra.fetch(i).unwrap(), 2 * i + 1);
        }
        assert_eq!(ra.fetch(0).unwrap(), 1);
    }

    #[test]
    fn destroy_removes_files() {
        let t = tmpdir("ra_destroy");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 10, 0).unwrap();
        ra.sync().unwrap();
        ra.destroy().unwrap();
        for w in 0..r.cluster().nworkers() {
            assert!(!r.cluster().disk(w).exists("ra_a"));
        }
    }

    #[test]
    fn pending_bytes_reflects_staging() {
        let t = tmpdir("ra_pending");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 10, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u32, p: &u32| *v += *p);
        assert_eq!(ra.pending_bytes(), 0);
        ra.update(1, &1, add).unwrap();
        assert!(ra.pending_bytes() > 0);
        ra.sync().unwrap();
        assert_eq!(ra.pending_bytes(), 0);
    }
}
