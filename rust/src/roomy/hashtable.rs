//! `RoomyHashTable<K, V>`: a disk-resident, hash-bucketed key→value map.
//!
//! Paper §2/Table 1: `insert`, `remove`, `access`, `update` are delayed;
//! `sync`, `size`, `map`, `reduce`, `predicateCount` are immediate. Keys
//! route to buckets by the shared fingerprint ([`crate::hashfn`]) — the
//! same routing the XLA hash-partition kernel computes on-device — so a
//! bucket's records and its staged ops always live on the same node, and
//! `sync` streams each bucket through RAM exactly once.
//!
//! Update semantics: the registered function sees `Option<V>` (present or
//! absent) and returns `Option<V>` (store or remove/leave-absent). This is
//! the insert-if-absent idiom the paper's BFS variants rely on.

use std::marker::PhantomData;
use std::sync::Arc;

use super::element::Element;
use super::flat::FlatTable;
use super::funcs::{AccessId, FuncRegistry, PredId, UpdateId};
use super::ops::{OpKind, StagedOps};
use super::Ctx;
use crate::error::{Result, RoomyError};
use crate::storage::checkpoint::{Checkpointable, StructKind, StructMeta};
use crate::storage::{NodeDisk, PrefetchReader, WriteBehindWriter};

const SCAN_BATCH: usize = 4096;

/// Type-erased hash-table update: `(key, current value or None, passed)`
/// → new value or None.
type HtUpdateFn = Box<dyn Fn(&[u8], Option<&[u8]>, &[u8]) -> Option<Vec<u8>> + Send + Sync>;

/// A distributed disk-backed hash table. Cheap to clone (shared state).
pub struct RoomyHashTable<K: Element, V: Element> {
    inner: Arc<HtInner<K, V>>,
}

impl<K: Element, V: Element> Clone for RoomyHashTable<K, V> {
    fn clone(&self) -> Self {
        RoomyHashTable { inner: Arc::clone(&self.inner) }
    }
}

struct HtInner<K: Element, V: Element> {
    ctx: Ctx,
    name: String,
    dir: String,
    funcs: FuncRegistry,
    /// Hash-table updates have a richer signature than array updates
    /// (`Option<V>` in/out), so they get their own registry.
    ht_updates: std::sync::RwLock<Vec<(usize, HtUpdateFn)>>,
    staged: Arc<StagedOps>,
    /// Serializes `sync` (bucket rewrite) against concurrent client
    /// threads.
    write_lock: std::sync::Mutex<()>,
    size: std::sync::atomic::AtomicI64,
    _t: PhantomData<fn() -> (K, V)>,
}

impl<K: Element, V: Element> RoomyHashTable<K, V> {
    pub(crate) fn create(ctx: Ctx, name: &str) -> Result<Self> {
        // A freshly created structure must be empty: clear any same-named
        // bucket files a killed run left behind (same-root reruns are the
        // normal case now that checkpoints make state durable).
        ctx.cluster.remove_structure_dirs(format!("rht_{name}"))?;
        Self::build(ctx, name)
    }

    fn build(ctx: Ctx, name: &str) -> Result<Self> {
        let dir = format!("rht_{name}");
        let cluster = ctx.cluster.clone();
        let inner = HtInner {
            staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
            funcs: FuncRegistry::new(&format!("RoomyHashTable({name})")),
            ht_updates: std::sync::RwLock::new(Vec::new()),
            write_lock: std::sync::Mutex::new(()),
            ctx,
            name: name.to_string(),
            dir,
            size: std::sync::atomic::AtomicI64::new(0),
            _t: PhantomData,
        };
        Ok(RoomyHashTable { inner: Arc::new(inner) })
    }

    /// Re-open a restored table over bucket files already on disk
    /// ([`crate::storage::checkpoint`]), reconstituting the in-RAM size
    /// counter. Registered functions do not survive a checkpoint —
    /// re-register before staging delayed ops.
    pub(crate) fn open_restored(ctx: Ctx, name: &str, size: u64) -> Result<Self> {
        let ht = Self::build(ctx, name)?;
        ht.inner.size.store(size as i64, std::sync::atomic::Ordering::Relaxed);
        Ok(ht)
    }

    /// Number of (key, value) pairs (immediate; maintained at sync).
    pub fn size(&self) -> u64 {
        self.inner.size.load(std::sync::atomic::Ordering::Relaxed).max(0) as u64
    }

    /// Structure name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Total staged (not yet synced) delayed-op bytes.
    pub fn pending_bytes(&self) -> u64 {
        self.inner.staged.staged_bytes()
    }

    // ------------------------------------------------------------------
    // Registration
    // ------------------------------------------------------------------

    /// Register an access function `f(key, value, passed)`; runs at sync
    /// for keys that are present (absent keys are silently skipped, as in
    /// Roomy).
    pub fn register_access<P: Element>(
        &self,
        f: impl Fn(&K, &V, &P) + Send + Sync + 'static,
    ) -> AccessId {
        self.inner.funcs.register_access(
            P::SIZE,
            Box::new(move |_idx, kv, passed| {
                // kv = key bytes ++ value bytes
                let k = K::read_from(&kv[..K::SIZE]);
                let v = V::read_from(&kv[K::SIZE..]);
                f(&k, &v, &P::read_from(passed));
            }),
        )
    }

    /// Register an update function
    /// `f(key, current, passed) -> Option<new value>`:
    /// - current is `None` if the key is absent;
    /// - returning `None` removes the key (or leaves it absent).
    pub fn register_update<P: Element>(
        &self,
        f: impl Fn(&K, Option<&V>, &P) -> Option<V> + Send + Sync + 'static,
    ) -> UpdateId {
        let mut g = self.inner.ht_updates.write().unwrap();
        assert!(g.len() < 256, "at most 256 update functions per structure");
        g.push((
            P::SIZE,
            Box::new(move |k, cur, passed| {
                let key = K::read_from(k);
                let cur_v = cur.map(V::read_from);
                f(&key, cur_v.as_ref(), &P::read_from(passed)).map(|v| v.to_bytes())
            }),
        ));
        UpdateId((g.len() - 1) as u8)
    }

    /// Register a predicate over `(key, value)`; counts maintained on
    /// every mutation, initialized by one scan.
    pub fn register_predicate(
        &self,
        f: impl Fn(&K, &V) -> bool + Send + Sync + 'static,
    ) -> Result<PredId> {
        let id = self.inner.funcs.register_pred(Box::new(move |_idx, kv| {
            f(&K::read_from(&kv[..K::SIZE]), &V::read_from(&kv[K::SIZE..]))
        }));
        let inner = &self.inner;
        inner.for_owned_buckets("rht.pred_scan", |this, b, disk| {
            this.scan_bucket(b, disk, |kv| {
                this.funcs.charge_pred_single(id, 0, kv);
                Ok(())
            })
        })?;
        Ok(id)
    }

    /// Current count for predicate `id` (immediate).
    pub fn predicate_count(&self, id: PredId) -> u64 {
        self.inner.funcs.pred_count(id)
    }

    // ------------------------------------------------------------------
    // Delayed operations
    // ------------------------------------------------------------------

    /// Delayed insert of `(key, value)` (overwrites at sync).
    pub fn insert(&self, key: &K, value: &V) -> Result<()> {
        self.stage_keyed(OpKind::HtInsert, 0, key, |rec| {
            let off = rec.len();
            rec.resize(off + V::SIZE, 0);
            value.write_to(&mut rec[off..]);
        })
    }

    /// Delayed remove of `key`.
    pub fn remove(&self, key: &K) -> Result<()> {
        self.stage_keyed(OpKind::HtRemove, 0, key, |_rec| {})
    }

    /// Encode `[kind, fn_id, key, payload]` into the thread-local buffer
    /// (no per-op allocation) and stage it to the key's bucket.
    fn stage_keyed(
        &self,
        kind: OpKind,
        fn_id: u8,
        key: &K,
        payload: impl FnOnce(&mut Vec<u8>),
    ) -> Result<()> {
        super::ops::with_op_buf(|rec| {
            rec.push(kind as u8);
            rec.push(fn_id);
            let koff = rec.len();
            rec.resize(koff + K::SIZE, 0);
            key.write_to(&mut rec[koff..]);
            let bucket = self.inner.bucket_of_key(&rec[koff..koff + K::SIZE]);
            payload(rec);
            self.inner.staged.stage(bucket, rec)
        })
    }

    /// Delayed access of `key` with `passed` via function `id`.
    pub fn access<P: Element>(&self, key: &K, passed: &P, id: AccessId) -> Result<()> {
        let expect = self.inner.funcs.access_passed_len(id.0)?;
        if P::SIZE != expect {
            return Err(RoomyError::InvalidArg(format!(
                "passed value is {} bytes but function was registered with {expect}",
                P::SIZE
            )));
        }
        self.stage_keyed(OpKind::HtAccess, id.0, key, |rec| {
            let off = rec.len();
            rec.resize(off + P::SIZE, 0);
            passed.write_to(&mut rec[off..]);
        })
    }

    /// Delayed update of `key` with `passed` via function `id`.
    pub fn update<P: Element>(&self, key: &K, passed: &P, id: UpdateId) -> Result<()> {
        let expect = self.inner.ht_update_passed_len(id.0)?;
        if P::SIZE != expect {
            return Err(RoomyError::InvalidArg(format!(
                "passed value is {} bytes but function was registered with {expect}",
                P::SIZE
            )));
        }
        self.stage_keyed(OpKind::HtUpdate, id.0, key, |rec| {
            let off = rec.len();
            rec.resize(off + P::SIZE, 0);
            passed.write_to(&mut rec[off..]);
        })
    }

    /// Apply all outstanding delayed operations (FIFO per bucket).
    pub fn sync(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        if inner.staged.is_empty() {
            return Ok(());
        }
        let deltas: Vec<i64> = inner.ctx.cluster.run_buckets_hinted(
            "rht.sync",
            |b| Some(inner.bucket_file(b)),
            |b, disk| inner.sync_bucket(b, disk),
        )?;
        inner
            .size
            .fetch_add(deltas.iter().sum::<i64>(), std::sync::atomic::Ordering::Relaxed);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Immediate operations
    // ------------------------------------------------------------------

    /// Apply `f(key, value)` to every pair (streaming, parallel).
    pub fn map(&self, f: impl Fn(&K, &V) + Sync) -> Result<()> {
        self.inner.for_owned_buckets("rht.map", |this, b, disk| {
            this.scan_bucket(b, disk, |kv| {
                f(&K::read_from(&kv[..K::SIZE]), &V::read_from(&kv[K::SIZE..]));
                Ok(())
            })
        })
    }

    /// Reduce over all pairs; `fold`/`merge` must be assoc+comm in effect.
    /// Buckets reduce concurrently on the pool; partials merge in bucket
    /// order, so the result is independent of `num_workers`.
    pub fn reduce<R: Send>(
        &self,
        identity: impl Fn() -> R + Sync,
        fold: impl Fn(R, &K, &V) -> R + Sync,
        merge: impl Fn(R, R) -> R,
    ) -> Result<R> {
        let inner = &self.inner;
        let partials: Vec<R> = inner.ctx.cluster.run_buckets_hinted(
            "rht.reduce",
            |b| Some(inner.bucket_file(b)),
            |b, disk| {
                let mut local = Some(identity());
                inner.scan_bucket(b, disk, |kv| {
                    let cur = local.take().expect("reduce accumulator");
                    local = Some(fold(
                        cur,
                        &K::read_from(&kv[..K::SIZE]),
                        &V::read_from(&kv[K::SIZE..]),
                    ));
                    Ok(())
                })?;
                Ok(local.take().expect("reduce accumulator"))
            },
        )?;
        let mut it = partials.into_iter();
        let first = it.next().expect("at least one bucket");
        Ok(it.fold(first, merge))
    }

    /// Random-access lookup. **Debug/testing convenience** (the
    /// latency-bound pattern Roomy exists to avoid): scans the key's bucket.
    pub fn fetch(&self, key: &K) -> Result<Option<V>> {
        let inner = &self.inner;
        let kb = key.to_bytes();
        let b = inner.bucket_of_key(&kb);
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        let mut found = None;
        inner.scan_bucket(b, disk, |kv| {
            if kv[..K::SIZE] == kb[..] {
                found = Some(V::read_from(&kv[K::SIZE..]));
            }
            Ok(())
        })?;
        Ok(found)
    }

    /// Delete all on-disk state.
    pub fn destroy(self) -> Result<()> {
        let dir = self.inner.dir.clone();
        self.inner.ctx.cluster.remove_structure_dirs(dir)
    }
}

impl<K: Element, V: Element> Checkpointable for RoomyHashTable<K, V> {
    fn ckpt_meta(&self) -> StructMeta {
        StructMeta {
            kind: StructKind::HashTable,
            name: self.inner.name.clone(),
            dir: self.inner.dir.clone(),
            rec_size: K::SIZE + V::SIZE,
            key_size: K::SIZE,
            len: 0,
            size: self.size(),
            bits: 0,
            sorted: false,
            // bucket files are only ever replaced whole (tmp + rename)
            appendable: false,
            counts: Vec::new(),
        }
    }

    fn ckpt_pending(&self) -> u64 {
        RoomyHashTable::pending_bytes(self)
    }
}

impl<K: Element, V: Element> HtInner<K, V> {
    fn rec_size() -> usize {
        K::SIZE + V::SIZE
    }

    fn bucket_of_key(&self, key_bytes: &[u8]) -> u32 {
        self.ctx.cluster.topology().route(key_bytes)
    }

    fn bucket_file(&self, b: u32) -> String {
        format!("{}/b{b}.dat", self.dir)
    }

    fn ht_update_passed_len(&self, id: u8) -> Result<usize> {
        self.ht_updates
            .read()
            .unwrap()
            .get(id as usize)
            .map(|(plen, _)| *plen)
            .ok_or_else(|| RoomyError::UnknownFunc {
                structure: format!("RoomyHashTable({})", self.name),
                id,
            })
    }

    /// Run `f(self, bucket, disk)` for every bucket on the worker pool,
    /// hinting each bucket's file for cross-task prefetch.
    fn for_owned_buckets(
        &self,
        phase: &str,
        f: impl Fn(&Self, u32, &Arc<NodeDisk>) -> Result<()> + Sync,
    ) -> Result<()> {
        self.ctx.cluster.run_buckets_hinted(
            phase,
            |b| Some(self.bucket_file(b)),
            |b, disk| f(self, b, disk),
        )?;
        Ok(())
    }

    /// Stream bucket `b`'s (key ++ value) records (read-ahead on a
    /// pipelined disk).
    fn scan_bucket(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        mut f: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let file = self.bucket_file(b);
        if !disk.exists(&file) {
            return Ok(());
        }
        let rec = Self::rec_size();
        let mut r = PrefetchReader::open(disk, &file, rec)?;
        let mut buf = Vec::new();
        loop {
            let n = r.read_batch(&mut buf, SCAN_BATCH)?;
            if n == 0 {
                return Ok(());
            }
            for kv in buf.chunks_exact(rec) {
                f(kv)?;
            }
        }
    }

    /// Charge all predicates for a (key, value) pair.
    fn charge_kv(&self, kvbuf: &mut [u8], key: &[u8], val: &[u8], sign: i64) {
        kvbuf[..K::SIZE].copy_from_slice(key);
        kvbuf[K::SIZE..].copy_from_slice(val);
        self.funcs.charge_preds(0, kvbuf, sign);
    }

    /// Load bucket `b` into a RAM map, apply its op log FIFO, write back.
    /// Returns the size delta.
    fn sync_bucket(&self, b: u32, disk: &Arc<NodeDisk>) -> Result<i64> {
        let mut ops =
            self.staged.take(b, &self.ctx.cluster, &self.dir, self.ctx.cfg.op_buffer_bytes);
        if ops.is_empty() {
            return ops.clear().map(|_| 0);
        }
        // Bucket → RAM (the unit Roomy sizes to fit in memory). FlatTable
        // keeps records in one arena: no per-record allocations (§Perf P3).
        let expect = crate::storage::chunkfile::record_count(
            disk,
            self.bucket_file(b),
            Self::rec_size(),
        ) as usize;
        let mut table = FlatTable::new(K::SIZE, V::SIZE, expect);
        self.scan_bucket(b, disk, |kv| {
            table.put(&kv[..K::SIZE], &kv[K::SIZE..]);
            Ok(())
        })?;
        let npreds = self.funcs.npreds();
        let mut delta = 0i64;
        let mut kvbuf = vec![0u8; Self::rec_size()];

        // Op-log replay streams through the read-ahead lane; the drain
        // removes the log's spill file when it drops.
        let mut reader = ops.into_drain()?;
        let mut header = [0u8; 2];
        let mut key = vec![0u8; K::SIZE];
        let mut payload = Vec::new();
        while reader.read_exact_or_eof(&mut header)? {
            let kind = OpKind::from_u8(header[0]).ok_or_else(|| {
                RoomyError::InvalidArg(format!("corrupt op tag {}", header[0]))
            })?;
            let fn_id = header[1];
            if !reader.read_exact_or_eof(&mut key)? {
                return Err(RoomyError::InvalidArg("truncated op record".into()));
            }
            let plen = match kind {
                OpKind::HtInsert => V::SIZE,
                OpKind::HtRemove => 0,
                OpKind::HtAccess => self.funcs.access_passed_len(fn_id)?,
                OpKind::HtUpdate => self.ht_update_passed_len(fn_id)?,
                other => {
                    return Err(RoomyError::InvalidArg(format!(
                        "unexpected op kind {other:?} in hash-table log"
                    )))
                }
            };
            payload.resize(plen, 0);
            if plen > 0 && !reader.read_exact_or_eof(&mut payload)? {
                return Err(RoomyError::InvalidArg("truncated op record".into()));
            }
            // Pre-read the old value only when predicates need it.
            let mut old_val: Option<Vec<u8>> = None;
            if npreds > 0 && matches!(kind, OpKind::HtInsert | OpKind::HtRemove | OpKind::HtUpdate)
            {
                old_val = table.get(&key).map(|v| v.to_vec());
            }
            match kind {
                OpKind::HtInsert => {
                    let existed = table.put(&key, &payload);
                    if !existed {
                        delta += 1;
                    }
                    if npreds > 0 {
                        if let Some(old) = &old_val {
                            self.charge_kv(&mut kvbuf, &key, old, -1);
                        }
                        self.charge_kv(&mut kvbuf, &key, &payload, 1);
                    }
                }
                OpKind::HtRemove => {
                    if table.remove(&key) {
                        delta -= 1;
                        if npreds > 0 {
                            if let Some(old) = &old_val {
                                self.charge_kv(&mut kvbuf, &key, old, -1);
                            }
                        }
                    }
                }
                OpKind::HtAccess => {
                    if let Some(val) = table.get(&key) {
                        kvbuf[..K::SIZE].copy_from_slice(&key);
                        kvbuf[K::SIZE..].copy_from_slice(val);
                        self.funcs.apply_access(fn_id, 0, &kvbuf, &payload)?;
                    }
                }
                OpKind::HtUpdate => {
                    let new = {
                        let g = self.ht_updates.read().unwrap();
                        let (_, f) = g.get(fn_id as usize).ok_or_else(|| {
                            RoomyError::UnknownFunc {
                                structure: format!("RoomyHashTable({})", self.name),
                                id: fn_id,
                            }
                        })?;
                        f(&key, table.get(&key), &payload)
                    };
                    match new {
                        Some(v) => {
                            let existed = table.put(&key, &v);
                            if !existed {
                                delta += 1;
                            }
                            if npreds > 0 {
                                if let Some(old) = &old_val {
                                    self.charge_kv(&mut kvbuf, &key, old, -1);
                                }
                                self.charge_kv(&mut kvbuf, &key, &v, 1);
                            }
                        }
                        None => {
                            if table.remove(&key) {
                                delta -= 1;
                                if npreds > 0 {
                                    if let Some(old) = &old_val {
                                        self.charge_kv(&mut kvbuf, &key, old, -1);
                                    }
                                }
                            }
                        }
                    }
                }
                _ => unreachable!(),
            }
        }
        drop(reader);

        // Write the bucket back (streaming rewrite straight from the
        // arena, flushed through the write-behind lane).
        let tmp = format!("{}.sync.tmp", self.bucket_file(b));
        {
            let mut w = WriteBehindWriter::create(disk, &tmp, Self::rec_size())?;
            let mut err = None;
            table.for_each(|rec| {
                if err.is_none() {
                    if let Err(e) = w.push(rec) {
                        err = Some(e);
                    }
                }
            });
            if let Some(e) = err {
                return Err(e);
            }
            w.finish()?;
        }
        disk.rename(&tmp, self.bucket_file(b))?;
        Ok(delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    #[test]
    fn insert_sync_fetch() {
        let t = tmpdir("ht_basic");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.insert(&2, &20).unwrap();
        assert_eq!(ht.size(), 0, "insert is delayed");
        ht.sync().unwrap();
        assert_eq!(ht.size(), 2);
        assert_eq!(ht.fetch(&1).unwrap(), Some(10));
        assert_eq!(ht.fetch(&2).unwrap(), Some(20));
        assert_eq!(ht.fetch(&3).unwrap(), None);
    }

    #[test]
    fn insert_overwrites_and_remove_removes() {
        let t = tmpdir("ht_overwrite");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.insert(&1, &11).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.size(), 1);
        assert_eq!(ht.fetch(&1).unwrap(), Some(11));
        ht.remove(&1).unwrap();
        ht.remove(&99).unwrap(); // removing absent key is a no-op
        ht.sync().unwrap();
        assert_eq!(ht.size(), 0);
        assert_eq!(ht.fetch(&1).unwrap(), None);
    }

    #[test]
    fn many_keys_across_buckets() {
        let t = tmpdir("ht_many");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u64>("h").unwrap();
        let n = 5000u64;
        for k in 0..n {
            ht.insert(&k, &(k * k)).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.size(), n);
        let sum = ht
            .reduce(|| 0u64, |acc, _k, v| acc.wrapping_add(*v), |a, b| a.wrapping_add(b))
            .unwrap();
        assert_eq!(sum, (0..n).map(|k| k * k).sum::<u64>());
    }

    #[test]
    fn update_insert_if_absent_idiom() {
        let t = tmpdir("ht_upsert");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        // count occurrences: absent -> 1, present -> +1
        let bump = ht.register_update(|_k, cur: Option<&u32>, _p: &()| {
            Some(cur.copied().unwrap_or(0) + 1)
        });
        for k in [1u64, 2, 1, 1, 3, 2] {
            ht.update(&k, &(), bump).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.fetch(&1).unwrap(), Some(3));
        assert_eq!(ht.fetch(&2).unwrap(), Some(2));
        assert_eq!(ht.fetch(&3).unwrap(), Some(1));
        assert_eq!(ht.size(), 3);
    }

    #[test]
    fn update_returning_none_removes() {
        let t = tmpdir("ht_updremove");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&5, &50).unwrap();
        ht.sync().unwrap();
        let del = ht.register_update(|_k, _cur: Option<&u32>, _p: &()| None);
        ht.update(&5, &(), del).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.size(), 0);
        assert_eq!(ht.fetch(&5).unwrap(), None);
    }

    #[test]
    fn access_skips_absent_keys() {
        let t = tmpdir("ht_access");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&7, &70).unwrap();
        ht.sync().unwrap();
        let hits = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let h = hits.clone();
        let acc = ht.register_access(move |k: &u64, v: &u32, p: &u8| {
            h.lock().unwrap().push((*k, *v, *p));
        });
        ht.access(&7, &1u8, acc).unwrap();
        ht.access(&8, &2u8, acc).unwrap(); // absent
        ht.sync().unwrap();
        assert_eq!(hits.lock().unwrap().as_slice(), &[(7, 70, 1)]);
    }

    #[test]
    fn fifo_order_within_sync() {
        let t = tmpdir("ht_fifo");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &1).unwrap();
        ht.remove(&1).unwrap();
        ht.insert(&1, &2).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.fetch(&1).unwrap(), Some(2));
        assert_eq!(ht.size(), 1);
    }

    #[test]
    fn predicate_counts() {
        let t = tmpdir("ht_pred");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.sync().unwrap();
        let big = ht.register_predicate(|_k, v| *v >= 10).unwrap();
        assert_eq!(ht.predicate_count(big), 1);
        ht.insert(&2, &5).unwrap();
        ht.insert(&3, &100).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.predicate_count(big), 2);
        ht.remove(&3).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.predicate_count(big), 1);
    }

    #[test]
    fn map_visits_all() {
        let t = tmpdir("ht_map");
        let r = mk(t.path());
        let ht = r.hash_table::<u32, u32>("h").unwrap();
        for k in 0..100u32 {
            ht.insert(&k, &(k + 1)).unwrap();
        }
        ht.sync().unwrap();
        let count = std::sync::atomic::AtomicU64::new(0);
        ht.map(|k, v| {
            assert_eq!(*v, *k + 1);
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.into_inner(), 100);
    }

    #[test]
    fn destroy_removes_dirs() {
        let t = tmpdir("ht_destroy");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &1).unwrap();
        ht.sync().unwrap();
        ht.destroy().unwrap();
        for w in 0..r.cluster().nworkers() {
            assert!(!r.cluster().disk(w).exists("rht_h"));
        }
    }
}
