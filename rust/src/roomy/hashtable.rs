//! `RoomyHashTable<K, V>`: a disk-resident, hash-bucketed key→value map.
//!
//! Paper §2/Table 1: `insert`, `remove`, `access`, `update` are delayed;
//! `sync`, `size`, `map`, `reduce`, `predicateCount` are immediate. Keys
//! route to buckets by the shared fingerprint ([`crate::hashfn`]) — the
//! same routing the XLA hash-partition kernel computes on-device — so a
//! bucket's records and its staged ops always live on the same node, and
//! `sync` streams each bucket through RAM exactly once.
//!
//! Update semantics: the registered function sees `Option<V>` (present or
//! absent) and returns `Option<V>` (store or remove/leave-absent). This is
//! the insert-if-absent idiom the paper's BFS variants rely on.

use std::marker::PhantomData;
use std::sync::Arc;

use super::element::Element;
use super::flat::FlatTable;
use super::funcs::{AccessId, FuncRegistry, PredId, UpdateId};
use super::ops::{OpKind, StagedOps};
use super::Ctx;
use crate::error::{Result, RoomyError};
use crate::storage::bloom::{DedupFilter, ShardBloom};
use crate::storage::checkpoint::{Checkpointable, StructKind, StructMeta};
use crate::storage::chunkfile::record_count;
use crate::storage::scratch;
use crate::storage::{NodeDisk, PrefetchReader, WriteBehindWriter};

const SCAN_BATCH: usize = 4096;

/// Type-erased hash-table update: `(key, current value or None, passed,
/// out)` → whether a new value was written into `out`. Writing into a
/// caller-owned buffer keeps the per-op hot path allocation-free.
type HtUpdateFn = Box<dyn Fn(&[u8], Option<&[u8]>, &[u8], &mut Vec<u8>) -> bool + Send + Sync>;

/// A distributed disk-backed hash table. Cheap to clone (shared state).
pub struct RoomyHashTable<K: Element, V: Element> {
    inner: Arc<HtInner<K, V>>,
}

impl<K: Element, V: Element> Clone for RoomyHashTable<K, V> {
    fn clone(&self) -> Self {
        RoomyHashTable { inner: Arc::clone(&self.inner) }
    }
}

struct HtInner<K: Element, V: Element> {
    ctx: Ctx,
    name: String,
    dir: String,
    funcs: FuncRegistry,
    /// Hash-table updates have a richer signature than array updates
    /// (`Option<V>` in/out), so they get their own registry.
    ht_updates: std::sync::RwLock<Vec<(usize, HtUpdateFn)>>,
    staged: Arc<StagedOps>,
    /// Serializes `sync` (bucket rewrite) against concurrent client
    /// threads.
    write_lock: std::sync::Mutex<()>,
    size: std::sync::atomic::AtomicI64,
    /// Optional approximate-membership tier over **keys**
    /// ([`crate::storage::bloom`]). When a whole bucket op log probes
    /// definitely-new, `sync_bucket` skips the full-bucket load and
    /// rewrite and appends the new records in place (byte-identical to
    /// the rewrite); `fetch` answers definitely-absent without a scan.
    /// RAM-only: rebuilt from bucket files after a checkpoint restore.
    bloom: Option<DedupFilter>,
    _t: PhantomData<fn() -> (K, V)>,
}

impl<K: Element, V: Element> RoomyHashTable<K, V> {
    pub(crate) fn create(ctx: Ctx, name: &str) -> Result<Self> {
        // A freshly created structure must be empty: clear any same-named
        // bucket files a killed run left behind (same-root reruns are the
        // normal case now that checkpoints make state durable).
        ctx.cluster.remove_structure_dirs(format!("rht_{name}"))?;
        Self::build(ctx, name)
    }

    fn build(ctx: Ctx, name: &str) -> Result<Self> {
        let dir = format!("rht_{name}");
        let cluster = ctx.cluster.clone();
        let bloom = ctx.dedup_filter();
        let inner = HtInner {
            staged: StagedOps::new(&cluster, &dir, ctx.cfg.op_buffer_bytes),
            funcs: FuncRegistry::new(&format!("RoomyHashTable({name})")),
            ht_updates: std::sync::RwLock::new(Vec::new()),
            write_lock: std::sync::Mutex::new(()),
            ctx,
            name: name.to_string(),
            dir,
            size: std::sync::atomic::AtomicI64::new(0),
            bloom,
            _t: PhantomData,
        };
        Ok(RoomyHashTable { inner: Arc::new(inner) })
    }

    /// Re-open a restored table over bucket files already on disk
    /// ([`crate::storage::checkpoint`]), reconstituting the in-RAM size
    /// counter and re-deriving the (RAM-only) dedup filters from the
    /// restored buckets. Registered functions do not survive a
    /// checkpoint — re-register before staging delayed ops.
    pub(crate) fn open_restored(ctx: Ctx, name: &str, size: u64) -> Result<Self> {
        let ht = Self::build(ctx, name)?;
        ht.inner.size.store(size as i64, std::sync::atomic::Ordering::Relaxed);
        ht.inner.rebuild_bloom()?;
        Ok(ht)
    }

    /// Number of (key, value) pairs (immediate; maintained at sync).
    pub fn size(&self) -> u64 {
        self.inner.size.load(std::sync::atomic::Ordering::Relaxed).max(0) as u64
    }

    /// Structure name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Total staged (not yet synced) delayed-op bytes.
    pub fn pending_bytes(&self) -> u64 {
        self.inner.staged.staged_bytes()
    }

    // ------------------------------------------------------------------
    // Registration
    // ------------------------------------------------------------------

    /// Register an access function `f(key, value, passed)`; runs at sync
    /// for keys that are present (absent keys are silently skipped, as in
    /// Roomy).
    pub fn register_access<P: Element>(
        &self,
        f: impl Fn(&K, &V, &P) + Send + Sync + 'static,
    ) -> AccessId {
        self.inner.funcs.register_access(
            P::SIZE,
            Box::new(move |_idx, kv, passed| {
                // kv = key bytes ++ value bytes
                let k = K::read_from(&kv[..K::SIZE]);
                let v = V::read_from(&kv[K::SIZE..]);
                f(&k, &v, &P::read_from(passed));
            }),
        )
    }

    /// Register an update function
    /// `f(key, current, passed) -> Option<new value>`:
    /// - current is `None` if the key is absent;
    /// - returning `None` removes the key (or leaves it absent).
    pub fn register_update<P: Element>(
        &self,
        f: impl Fn(&K, Option<&V>, &P) -> Option<V> + Send + Sync + 'static,
    ) -> UpdateId {
        let mut g = self.inner.ht_updates.write().unwrap();
        assert!(g.len() < 256, "at most 256 update functions per structure");
        g.push((
            P::SIZE,
            Box::new(move |k, cur, passed, out: &mut Vec<u8>| {
                let key = K::read_from(k);
                let cur_v = cur.map(V::read_from);
                match f(&key, cur_v.as_ref(), &P::read_from(passed)) {
                    Some(v) => {
                        v.encode_into(out);
                        true
                    }
                    None => false,
                }
            }),
        ));
        UpdateId((g.len() - 1) as u8)
    }

    /// Register a predicate over `(key, value)`; counts maintained on
    /// every mutation, initialized by one scan.
    pub fn register_predicate(
        &self,
        f: impl Fn(&K, &V) -> bool + Send + Sync + 'static,
    ) -> Result<PredId> {
        let id = self.inner.funcs.register_pred(Box::new(move |_idx, kv| {
            f(&K::read_from(&kv[..K::SIZE]), &V::read_from(&kv[K::SIZE..]))
        }));
        let inner = &self.inner;
        inner.for_owned_buckets("rht.pred_scan", |this, b, disk| {
            this.scan_bucket(b, disk, |kv| {
                this.funcs.charge_pred_single(id, 0, kv);
                Ok(())
            })
        })?;
        Ok(id)
    }

    /// Current count for predicate `id` (immediate).
    pub fn predicate_count(&self, id: PredId) -> u64 {
        self.inner.funcs.pred_count(id)
    }

    // ------------------------------------------------------------------
    // Delayed operations
    // ------------------------------------------------------------------

    /// Delayed insert of `(key, value)` (overwrites at sync).
    pub fn insert(&self, key: &K, value: &V) -> Result<()> {
        self.stage_keyed(OpKind::HtInsert, 0, key, |rec| {
            let off = rec.len();
            rec.resize(off + V::SIZE, 0);
            value.write_to(&mut rec[off..]);
        })
    }

    /// Delayed remove of `key`.
    pub fn remove(&self, key: &K) -> Result<()> {
        self.stage_keyed(OpKind::HtRemove, 0, key, |_rec| {})
    }

    /// Encode `[kind, fn_id, key, payload]` into the thread-local buffer
    /// (no per-op allocation) and stage it to the key's bucket.
    fn stage_keyed(
        &self,
        kind: OpKind,
        fn_id: u8,
        key: &K,
        payload: impl FnOnce(&mut Vec<u8>),
    ) -> Result<()> {
        super::ops::with_op_buf(|rec| {
            rec.push(kind as u8);
            rec.push(fn_id);
            let koff = rec.len();
            rec.resize(koff + K::SIZE, 0);
            key.write_to(&mut rec[koff..]);
            let bucket = self.inner.bucket_of_key(&rec[koff..koff + K::SIZE]);
            payload(rec);
            self.inner.staged.stage(bucket, rec)
        })
    }

    /// Delayed access of `key` with `passed` via function `id`.
    pub fn access<P: Element>(&self, key: &K, passed: &P, id: AccessId) -> Result<()> {
        let expect = self.inner.funcs.access_passed_len(id.0)?;
        if P::SIZE != expect {
            return Err(RoomyError::InvalidArg(format!(
                "passed value is {} bytes but function was registered with {expect}",
                P::SIZE
            )));
        }
        self.stage_keyed(OpKind::HtAccess, id.0, key, |rec| {
            let off = rec.len();
            rec.resize(off + P::SIZE, 0);
            passed.write_to(&mut rec[off..]);
        })
    }

    /// Delayed update of `key` with `passed` via function `id`.
    pub fn update<P: Element>(&self, key: &K, passed: &P, id: UpdateId) -> Result<()> {
        let expect = self.inner.ht_update_passed_len(id.0)?;
        if P::SIZE != expect {
            return Err(RoomyError::InvalidArg(format!(
                "passed value is {} bytes but function was registered with {expect}",
                P::SIZE
            )));
        }
        self.stage_keyed(OpKind::HtUpdate, id.0, key, |rec| {
            let off = rec.len();
            rec.resize(off + P::SIZE, 0);
            passed.write_to(&mut rec[off..]);
        })
    }

    /// Apply all outstanding delayed operations (FIFO per bucket).
    pub fn sync(&self) -> Result<()> {
        let inner = &self.inner;
        let _write = inner.write_lock.lock().unwrap();
        if inner.staged.is_empty() {
            return Ok(());
        }
        let deltas: Vec<i64> = inner.ctx.cluster.run_buckets_hinted(
            "rht.sync",
            |b| Some(inner.bucket_file(b)),
            |b, disk| inner.sync_bucket(b, disk),
        )?;
        inner
            .size
            .fetch_add(deltas.iter().sum::<i64>(), std::sync::atomic::Ordering::Relaxed);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Immediate operations
    // ------------------------------------------------------------------

    /// Apply `f(key, value)` to every pair (streaming, parallel).
    pub fn map(&self, f: impl Fn(&K, &V) + Sync) -> Result<()> {
        self.inner.for_owned_buckets("rht.map", |this, b, disk| {
            this.scan_bucket(b, disk, |kv| {
                f(&K::read_from(&kv[..K::SIZE]), &V::read_from(&kv[K::SIZE..]));
                Ok(())
            })
        })
    }

    /// Reduce over all pairs; `fold`/`merge` must be assoc+comm in effect.
    /// Buckets reduce concurrently on the pool; partials merge in bucket
    /// order, so the result is independent of `num_workers`.
    pub fn reduce<R: Send>(
        &self,
        identity: impl Fn() -> R + Sync,
        fold: impl Fn(R, &K, &V) -> R + Sync,
        merge: impl Fn(R, R) -> R,
    ) -> Result<R> {
        let inner = &self.inner;
        let partials: Vec<R> = inner.ctx.cluster.run_buckets_hinted(
            "rht.reduce",
            |b| Some(inner.bucket_file(b)),
            |b, disk| {
                let mut local = Some(identity());
                inner.scan_bucket(b, disk, |kv| {
                    let cur = local.take().expect("reduce accumulator");
                    local = Some(fold(
                        cur,
                        &K::read_from(&kv[..K::SIZE]),
                        &V::read_from(&kv[K::SIZE..]),
                    ));
                    Ok(())
                })?;
                Ok(local.take().expect("reduce accumulator"))
            },
        )?;
        let mut it = partials.into_iter();
        let first = it.next().expect("at least one bucket");
        Ok(it.fold(first, merge))
    }

    /// Random-access lookup. **Debug/testing convenience** (the
    /// latency-bound pattern Roomy exists to avoid): scans the key's bucket.
    pub fn fetch(&self, key: &K) -> Result<Option<V>> {
        let inner = &self.inner;
        let mut kb = scratch::record_buf();
        key.encode_into(&mut kb);
        let b = inner.bucket_of_key(&kb);
        let disk = inner.ctx.cluster.disk(inner.ctx.cluster.owner(b));
        if let Some(bl) = &inner.bloom {
            if !bl.probe(b as usize, &kb) {
                let avoided = record_count(disk, inner.bucket_file(b), K::SIZE + V::SIZE)
                    * (K::SIZE + V::SIZE) as u64;
                inner.ctx.dedup.add_shortcut(avoided);
                return Ok(None);
            }
            inner.ctx.dedup.add_fallback();
        }
        let mut found = None;
        inner.scan_bucket(b, disk, |kv| {
            if kv[..K::SIZE] == kb[..] {
                found = Some(V::read_from(&kv[K::SIZE..]));
            }
            Ok(())
        })?;
        Ok(found)
    }

    /// Delete all on-disk state.
    pub fn destroy(self) -> Result<()> {
        let dir = self.inner.dir.clone();
        self.inner.ctx.cluster.remove_structure_dirs(dir)
    }
}

impl<K: Element, V: Element> Checkpointable for RoomyHashTable<K, V> {
    fn ckpt_meta(&self) -> StructMeta {
        StructMeta {
            kind: StructKind::HashTable,
            name: self.inner.name.clone(),
            dir: self.inner.dir.clone(),
            rec_size: K::SIZE + V::SIZE,
            key_size: K::SIZE,
            len: 0,
            size: self.size(),
            bits: 0,
            sorted: false,
            // Checkpoints treat bucket files as replaced-whole even when
            // the bloom fast path appends in place: `sync_bucket` only
            // appends to a bucket whose inode is private (nlink == 1), so
            // a file hardlinked into (or restored from) a checkpoint is
            // always rewritten via tmp + rename first.
            appendable: false,
            counts: Vec::new(),
        }
    }

    fn ckpt_pending(&self) -> u64 {
        RoomyHashTable::pending_bytes(self)
    }
}

impl<K: Element, V: Element> HtInner<K, V> {
    fn rec_size() -> usize {
        K::SIZE + V::SIZE
    }

    fn bucket_of_key(&self, key_bytes: &[u8]) -> u32 {
        self.ctx.cluster.topology().route(key_bytes)
    }

    fn bucket_file(&self, b: u32) -> String {
        format!("{}/b{b}.dat", self.dir)
    }

    fn ht_update_passed_len(&self, id: u8) -> Result<usize> {
        self.ht_updates
            .read()
            .unwrap()
            .get(id as usize)
            .map(|(plen, _)| *plen)
            .ok_or_else(|| RoomyError::UnknownFunc {
                structure: format!("RoomyHashTable({})", self.name),
                id,
            })
    }

    /// Run `f(self, bucket, disk)` for every bucket on the worker pool,
    /// hinting each bucket's file for cross-task prefetch.
    fn for_owned_buckets(
        &self,
        phase: &str,
        f: impl Fn(&Self, u32, &Arc<NodeDisk>) -> Result<()> + Sync,
    ) -> Result<()> {
        let _lbl = crate::obs::trace::struct_label(&self.name);
        self.ctx.cluster.run_buckets_hinted(
            phase,
            |b| Some(self.bucket_file(b)),
            |b, disk| f(self, b, disk),
        )?;
        Ok(())
    }

    /// Stream bucket `b`'s (key ++ value) records (read-ahead on a
    /// pipelined disk).
    fn scan_bucket(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        mut f: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let file = self.bucket_file(b);
        if !disk.exists(&file) {
            return Ok(());
        }
        let rec = Self::rec_size();
        let mut r = PrefetchReader::open(disk, &file, rec)?;
        let mut buf = scratch::record_buf();
        loop {
            let n = r.read_batch(&mut buf, SCAN_BATCH)?;
            if n == 0 {
                return Ok(());
            }
            for kv in buf.chunks_exact(rec) {
                f(kv)?;
            }
        }
    }

    /// Charge all predicates for a (key, value) pair.
    fn charge_kv(&self, kvbuf: &mut [u8], key: &[u8], val: &[u8], sign: i64) {
        kvbuf[..K::SIZE].copy_from_slice(key);
        kvbuf[K::SIZE..].copy_from_slice(val);
        self.funcs.charge_preds(0, kvbuf, sign);
    }

    /// Apply bucket `b`'s op log FIFO and write the result back. Returns
    /// the size delta.
    ///
    /// Without a dedup filter (or when one is inconclusive) this is the
    /// classic Roomy sync: load the bucket into a RAM [`FlatTable`],
    /// replay the log, rewrite the bucket whole (tmp + rename). With a
    /// filter, decoded ops are first buffered — never applied, since
    /// update/access closures must run exactly once — while every key
    /// probes definitely-new. If the whole log qualifies, the buffered ops
    /// replay into an empty table whose records are **appended** to the
    /// bucket file, skipping the full read + rewrite. The bytes are
    /// identical to the rewrite: the arena preserves insertion order, so
    /// the full path would emit exactly (old records in file order ++ new
    /// records in first-put order). One maybe-seen key, an oversized log,
    /// or a bucket inode shared with a checkpoint falls back to the exact
    /// path, replaying the buffered prefix first.
    fn sync_bucket(&self, b: u32, disk: &Arc<NodeDisk>) -> Result<i64> {
        let mut ops =
            self.staged.take(b, &self.ctx.cluster, &self.dir, self.ctx.cfg.op_buffer_bytes);
        if ops.is_empty() {
            return ops.clear().map(|_| 0);
        }
        let file = self.bucket_file(b);
        let expect = record_count(disk, &file, Self::rec_size()) as usize;
        let npreds = self.funcs.npreds();
        let mut delta = 0i64;
        let mut kvbuf = scratch::record_buf();
        kvbuf.resize(Self::rec_size(), 0);

        // Op-log replay streams through the read-ahead lane; the drain
        // removes the log's spill file when it drops.
        let mut reader = ops.into_drain()?;
        let mut header = [0u8; 2];
        let mut key = scratch::record_buf();
        key.resize(K::SIZE, 0);
        let mut payload = scratch::record_buf();

        let mut probing = self.bloom.is_some() && self.bucket_is_private(disk, &file);
        // Probe-window backlog: decoded-but-unapplied ops live in one
        // flat pooled buffer ([key ++ payload] spans laid end to end)
        // plus a small index — no per-op heap pair. The window is
        // bounded by `budget` bytes.
        let mut opbuf = scratch::chunk_buf(0);
        let mut bindex: Vec<(OpKind, u8, usize)> = Vec::new(); // (kind, fn_id, payload len)
        let mut buffered_bytes = 0usize;
        let budget = self.ctx.cfg.op_buffer_bytes.max(4096);
        let mut table: Option<FlatTable> = None;

        while reader.read_exact_or_eof(&mut header)? {
            let kind = OpKind::from_u8(header[0]).ok_or_else(|| {
                RoomyError::InvalidArg(format!("corrupt op tag {}", header[0]))
            })?;
            let fn_id = header[1];
            if !reader.read_exact_or_eof(&mut key)? {
                return Err(RoomyError::InvalidArg("truncated op record".into()));
            }
            let plen = match kind {
                OpKind::HtInsert => V::SIZE,
                OpKind::HtRemove => 0,
                OpKind::HtAccess => self.funcs.access_passed_len(fn_id)?,
                OpKind::HtUpdate => self.ht_update_passed_len(fn_id)?,
                other => {
                    return Err(RoomyError::InvalidArg(format!(
                        "unexpected op kind {other:?} in hash-table log"
                    )))
                }
            };
            payload.resize(plen, 0);
            if plen > 0 && !reader.read_exact_or_eof(&mut payload)? {
                return Err(RoomyError::InvalidArg("truncated op record".into()));
            }
            if probing {
                let bl = self.bloom.as_ref().expect("probing implies a filter");
                let maybe_seen = bl.probe(b as usize, &key);
                buffered_bytes += 2 + K::SIZE + plen;
                bindex.push((kind, fn_id, plen));
                opbuf.extend_from_slice(&key);
                opbuf.extend_from_slice(&payload);
                if maybe_seen || buffered_bytes > budget {
                    // Inconclusive (or the backlog outgrew the op buffer):
                    // close the window; the next op loads the bucket and
                    // replays the backlog first.
                    probing = false;
                }
                continue;
            }
            if table.is_none() {
                table = Some(self.load_and_replay(
                    b,
                    disk,
                    expect,
                    &opbuf,
                    &mut bindex,
                    npreds,
                    &mut kvbuf,
                    &mut delta,
                )?);
            }
            let t = table.as_mut().expect("table just loaded");
            self.apply_op(t, b, kind, fn_id, &key, &payload, npreds, &mut kvbuf, &mut delta)?;
        }
        drop(reader);

        // The probe window survived the whole log: every key is
        // definitely new, so replay into an empty table and append.
        let fast = probing && table.is_none();
        let table = match table {
            Some(t) => t,
            None if fast => {
                let mut t = FlatTable::new(K::SIZE, V::SIZE, bindex.len());
                let mut cur = 0usize;
                for (kind, fn_id, plen) in bindex.drain(..) {
                    let k = &opbuf[cur..cur + K::SIZE];
                    let p = &opbuf[cur + K::SIZE..cur + K::SIZE + plen];
                    cur += K::SIZE + plen;
                    self.apply_op(&mut t, b, kind, fn_id, k, p, npreds, &mut kvbuf, &mut delta)?;
                }
                // Avoided streaming every existing record in and back out.
                self.ctx.dedup.add_shortcut((expect * Self::rec_size() * 2) as u64);
                t
            }
            // The window closed on the final op: load and replay the
            // backlog even though the streaming loop never got there.
            None => self.load_and_replay(
                b,
                disk,
                expect,
                &opbuf,
                &mut bindex,
                npreds,
                &mut kvbuf,
                &mut delta,
            )?,
        };

        if fast {
            let mut w = WriteBehindWriter::append(disk, &file, Self::rec_size())?;
            let mut err = None;
            table.for_each(|rec| {
                if err.is_none() {
                    if let Err(e) = w.push(rec) {
                        err = Some(e);
                    }
                }
            });
            if let Some(e) = err {
                return Err(e);
            }
            w.finish()?;
        } else {
            // Write the bucket back (streaming rewrite straight from the
            // arena, flushed through the write-behind lane).
            let tmp = format!("{file}.sync.tmp");
            {
                let mut w = WriteBehindWriter::create(disk, &tmp, Self::rec_size())?;
                let mut err = None;
                table.for_each(|rec| {
                    if err.is_none() {
                        if let Err(e) = w.push(rec) {
                            err = Some(e);
                        }
                    }
                });
                if let Some(e) = err {
                    return Err(e);
                }
                w.finish()?;
            }
            disk.rename(&tmp, &file)?;
        }
        Ok(delta)
    }

    /// Load bucket `b` into a RAM [`FlatTable`] (the unit Roomy sizes to
    /// fit in memory; one arena, no per-record allocations — §Perf P3) and
    /// replay any ops buffered during the probe window, FIFO.
    #[allow(clippy::too_many_arguments)]
    fn load_and_replay(
        &self,
        b: u32,
        disk: &Arc<NodeDisk>,
        expect: usize,
        opbuf: &[u8],
        bindex: &mut Vec<(OpKind, u8, usize)>,
        npreds: usize,
        kvbuf: &mut [u8],
        delta: &mut i64,
    ) -> Result<FlatTable> {
        if self.bloom.is_some() {
            self.ctx.dedup.add_fallback();
        }
        let mut table = FlatTable::new(K::SIZE, V::SIZE, expect);
        self.scan_bucket(b, disk, |kv| {
            table.put(&kv[..K::SIZE], &kv[K::SIZE..]);
            Ok(())
        })?;
        let mut cur = 0usize;
        for (kind, fn_id, plen) in bindex.drain(..) {
            let k = &opbuf[cur..cur + K::SIZE];
            let p = &opbuf[cur + K::SIZE..cur + K::SIZE + plen];
            cur += K::SIZE + plen;
            self.apply_op(&mut table, b, kind, fn_id, k, p, npreds, kvbuf, delta)?;
        }
        Ok(table)
    }

    /// Apply one decoded delayed op to `table`, charging predicates and
    /// feeding the dedup filter with every key that lands in the table.
    #[allow(clippy::too_many_arguments)]
    fn apply_op(
        &self,
        table: &mut FlatTable,
        b: u32,
        kind: OpKind,
        fn_id: u8,
        key: &[u8],
        payload: &[u8],
        npreds: usize,
        kvbuf: &mut [u8],
        delta: &mut i64,
    ) -> Result<()> {
        // Pre-read the old value only when predicates need it (pooled
        // copy — the table arena may move under the op below).
        let mut old_val: Option<scratch::ScratchBuf> = None;
        if npreds > 0 && matches!(kind, OpKind::HtInsert | OpKind::HtRemove | OpKind::HtUpdate) {
            old_val = table.get(key).map(|v| {
                let mut o = scratch::record_buf();
                o.extend_from_slice(v);
                o
            });
        }
        match kind {
            OpKind::HtInsert => {
                let existed = table.put(key, payload);
                if !existed {
                    *delta += 1;
                }
                if let Some(bl) = &self.bloom {
                    bl.insert(b as usize, key);
                }
                if npreds > 0 {
                    if let Some(old) = &old_val {
                        self.charge_kv(kvbuf, key, old, -1);
                    }
                    self.charge_kv(kvbuf, key, payload, 1);
                }
            }
            OpKind::HtRemove => {
                if table.remove(key) {
                    *delta -= 1;
                    if npreds > 0 {
                        if let Some(old) = &old_val {
                            self.charge_kv(kvbuf, key, old, -1);
                        }
                    }
                }
            }
            OpKind::HtAccess => {
                if let Some(val) = table.get(key) {
                    kvbuf[..K::SIZE].copy_from_slice(key);
                    kvbuf[K::SIZE..].copy_from_slice(val);
                    self.funcs.apply_access(fn_id, 0, kvbuf, payload)?;
                }
            }
            OpKind::HtUpdate => {
                let mut newbuf = scratch::record_buf();
                let present = {
                    let g = self.ht_updates.read().unwrap();
                    let (_, f) = g.get(fn_id as usize).ok_or_else(|| {
                        RoomyError::UnknownFunc {
                            structure: format!("RoomyHashTable({})", self.name),
                            id: fn_id,
                        }
                    })?;
                    f(key, table.get(key), payload, &mut newbuf)
                };
                if present {
                    let existed = table.put(key, &newbuf);
                    if !existed {
                        *delta += 1;
                    }
                    if let Some(bl) = &self.bloom {
                        bl.insert(b as usize, key);
                    }
                    if npreds > 0 {
                        if let Some(old) = &old_val {
                            self.charge_kv(kvbuf, key, old, -1);
                        }
                        self.charge_kv(kvbuf, key, &newbuf, 1);
                    }
                } else if table.remove(key) {
                    *delta -= 1;
                    if npreds > 0 {
                        if let Some(old) = &old_val {
                            self.charge_kv(kvbuf, key, old, -1);
                        }
                    }
                }
            }
            other => {
                return Err(RoomyError::InvalidArg(format!(
                    "unexpected op kind {other:?} in hash-table log"
                )))
            }
        }
        Ok(())
    }

    /// True when bucket file `file` may be appended to in place: its
    /// inode must not be shared (hardlinked) with a checkpoint. A missing
    /// file is private — append creates it.
    #[cfg(unix)]
    fn bucket_is_private(&self, disk: &Arc<NodeDisk>, file: &str) -> bool {
        use std::os::unix::fs::MetadataExt;
        match std::fs::metadata(disk.root().join(file)) {
            Ok(m) => m.nlink() == 1,
            Err(_) => true,
        }
    }

    #[cfg(not(unix))]
    fn bucket_is_private(&self, _disk: &Arc<NodeDisk>, _file: &str) -> bool {
        false
    }

    /// Re-derive the per-bucket dedup filters from the on-disk bucket
    /// files (after a checkpoint restore — filters are never serialized).
    fn rebuild_bloom(&self) -> Result<()> {
        let Some(bloom) = &self.bloom else { return Ok(()) };
        let bits = bloom.bits_per_key();
        self.ctx.cluster.run_buckets("rht.bloom_rebuild", |b, disk| {
            bloom.with_shard(b as usize, |s| {
                *s = ShardBloom::new(bits);
                self.scan_bucket(b, disk, |kv| {
                    s.insert(&kv[..K::SIZE]);
                    Ok(())
                })
            })
        })?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    #[test]
    fn insert_sync_fetch() {
        let t = tmpdir("ht_basic");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.insert(&2, &20).unwrap();
        assert_eq!(ht.size(), 0, "insert is delayed");
        ht.sync().unwrap();
        assert_eq!(ht.size(), 2);
        assert_eq!(ht.fetch(&1).unwrap(), Some(10));
        assert_eq!(ht.fetch(&2).unwrap(), Some(20));
        assert_eq!(ht.fetch(&3).unwrap(), None);
    }

    #[test]
    fn insert_overwrites_and_remove_removes() {
        let t = tmpdir("ht_overwrite");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.insert(&1, &11).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.size(), 1);
        assert_eq!(ht.fetch(&1).unwrap(), Some(11));
        ht.remove(&1).unwrap();
        ht.remove(&99).unwrap(); // removing absent key is a no-op
        ht.sync().unwrap();
        assert_eq!(ht.size(), 0);
        assert_eq!(ht.fetch(&1).unwrap(), None);
    }

    #[test]
    fn many_keys_across_buckets() {
        let t = tmpdir("ht_many");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u64>("h").unwrap();
        let n = 5000u64;
        for k in 0..n {
            ht.insert(&k, &(k * k)).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.size(), n);
        let sum = ht
            .reduce(|| 0u64, |acc, _k, v| acc.wrapping_add(*v), |a, b| a.wrapping_add(b))
            .unwrap();
        assert_eq!(sum, (0..n).map(|k| k * k).sum::<u64>());
    }

    #[test]
    fn update_insert_if_absent_idiom() {
        let t = tmpdir("ht_upsert");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        // count occurrences: absent -> 1, present -> +1
        let bump = ht.register_update(|_k, cur: Option<&u32>, _p: &()| {
            Some(cur.copied().unwrap_or(0) + 1)
        });
        for k in [1u64, 2, 1, 1, 3, 2] {
            ht.update(&k, &(), bump).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.fetch(&1).unwrap(), Some(3));
        assert_eq!(ht.fetch(&2).unwrap(), Some(2));
        assert_eq!(ht.fetch(&3).unwrap(), Some(1));
        assert_eq!(ht.size(), 3);
    }

    #[test]
    fn update_returning_none_removes() {
        let t = tmpdir("ht_updremove");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&5, &50).unwrap();
        ht.sync().unwrap();
        let del = ht.register_update(|_k, _cur: Option<&u32>, _p: &()| None);
        ht.update(&5, &(), del).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.size(), 0);
        assert_eq!(ht.fetch(&5).unwrap(), None);
    }

    #[test]
    fn access_skips_absent_keys() {
        let t = tmpdir("ht_access");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&7, &70).unwrap();
        ht.sync().unwrap();
        let hits = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let h = hits.clone();
        let acc = ht.register_access(move |k: &u64, v: &u32, p: &u8| {
            h.lock().unwrap().push((*k, *v, *p));
        });
        ht.access(&7, &1u8, acc).unwrap();
        ht.access(&8, &2u8, acc).unwrap(); // absent
        ht.sync().unwrap();
        assert_eq!(hits.lock().unwrap().as_slice(), &[(7, 70, 1)]);
    }

    #[test]
    fn fifo_order_within_sync() {
        let t = tmpdir("ht_fifo");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &1).unwrap();
        ht.remove(&1).unwrap();
        ht.insert(&1, &2).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.fetch(&1).unwrap(), Some(2));
        assert_eq!(ht.size(), 1);
    }

    #[test]
    fn predicate_counts() {
        let t = tmpdir("ht_pred");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &10).unwrap();
        ht.sync().unwrap();
        let big = ht.register_predicate(|_k, v| *v >= 10).unwrap();
        assert_eq!(ht.predicate_count(big), 1);
        ht.insert(&2, &5).unwrap();
        ht.insert(&3, &100).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.predicate_count(big), 2);
        ht.remove(&3).unwrap();
        ht.sync().unwrap();
        assert_eq!(ht.predicate_count(big), 1);
    }

    #[test]
    fn map_visits_all() {
        let t = tmpdir("ht_map");
        let r = mk(t.path());
        let ht = r.hash_table::<u32, u32>("h").unwrap();
        for k in 0..100u32 {
            ht.insert(&k, &(k + 1)).unwrap();
        }
        ht.sync().unwrap();
        let count = std::sync::atomic::AtomicU64::new(0);
        ht.map(|k, v| {
            assert_eq!(*v, *k + 1);
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.into_inner(), 100);
    }

    fn mk_bloom(root: &std::path::Path) -> Roomy {
        let mut cfg = crate::RoomyConfig::for_testing(root);
        cfg.bloom_bits_per_key = 10;
        cfg.bloom_approximate = false;
        Roomy::open(cfg).unwrap()
    }

    /// Collect (worker-qualified name, bytes) for every bucket file under
    /// `dir` on every worker root, sorted for cross-run comparison.
    fn ht_bucket_bytes(r: &Roomy, dir: &str) -> Vec<(String, Vec<u8>)> {
        let mut out = Vec::new();
        for w in 0..r.cluster().nworkers() {
            let root = r.cluster().disk(w).root().join(dir);
            if !root.exists() {
                continue;
            }
            let mut names: Vec<String> = std::fs::read_dir(&root)
                .unwrap()
                .map(|e| e.unwrap().file_name().into_string().unwrap())
                .collect();
            names.sort();
            for n in names {
                out.push((format!("w{w}/{n}"), std::fs::read(root.join(&n)).unwrap()));
            }
        }
        out
    }

    #[test]
    fn bloom_fast_path_bytes_match_plain_rewrite() {
        let tp = tmpdir("ht_bloom_plain");
        let tb = tmpdir("ht_bloom_fast");
        let plain = mk(tp.path());
        let bloomed = mk_bloom(tb.path());
        for r in [&plain, &bloomed] {
            let ht = r.hash_table::<u64, u64>("h").unwrap();
            // Three waves of all-new keys: with the filter on, every wave
            // takes the append fast path instead of the full rewrite.
            for wave in 0..3u64 {
                for k in (wave * 400)..(wave * 400 + 400) {
                    ht.insert(&k, &(k * 7)).unwrap();
                }
                ht.sync().unwrap();
            }
            assert_eq!(ht.size(), 1200);
        }
        assert_eq!(
            ht_bucket_bytes(&plain, "rht_h"),
            ht_bucket_bytes(&bloomed, "rht_h"),
            "append fast path must be byte-identical to the rewrite"
        );
        let snap = bloomed.dedup_snapshot();
        assert!(snap.shortcuts > 0, "fast path never taken: {snap:?}");
    }

    #[test]
    fn bloom_dup_keys_fall_back_to_exact() {
        let t = tmpdir("ht_bloom_dup");
        let r = mk_bloom(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        for k in 0..300u64 {
            ht.insert(&k, &1).unwrap();
        }
        ht.sync().unwrap();
        // Overwrite the same keys: every bucket log probes maybe-seen and
        // takes the exact rewrite.
        for k in 0..300u64 {
            ht.insert(&k, &2).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.size(), 300);
        assert_eq!(ht.fetch(&123).unwrap(), Some(2));
        assert!(r.dedup_snapshot().exact_fallbacks > 0);
    }

    #[test]
    fn bloom_update_fast_path_insert_if_absent() {
        let t = tmpdir("ht_bloom_upd");
        let r = mk_bloom(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        let bump = ht.register_update(|_k, cur: Option<&u32>, _p: &()| {
            Some(cur.copied().unwrap_or(0) + 1)
        });
        for k in 0..200u64 {
            ht.update(&k, &(), bump).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.size(), 200);
        assert_eq!(ht.fetch(&7).unwrap(), Some(1));
        let snap = r.dedup_snapshot();
        assert!(snap.shortcuts > 0, "update-only new-key log should fast-path: {snap:?}");
        // A second round over the same keys must fall back and bump to 2.
        for k in 0..200u64 {
            ht.update(&k, &(), bump).unwrap();
        }
        ht.sync().unwrap();
        assert_eq!(ht.fetch(&7).unwrap(), Some(2));
        assert_eq!(ht.size(), 200);
    }

    #[test]
    fn bloom_fetch_answers_absent_without_scan() {
        let t = tmpdir("ht_bloom_fetch");
        let r = mk_bloom(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        for k in 0..100u64 {
            ht.insert(&k, &(k as u32)).unwrap();
        }
        ht.sync().unwrap();
        for k in 0..100u64 {
            assert_eq!(ht.fetch(&k).unwrap(), Some(k as u32));
        }
        for k in 10_000..10_100u64 {
            assert_eq!(ht.fetch(&k).unwrap(), None);
        }
        let snap = r.dedup_snapshot();
        assert!(snap.probes >= 200);
        assert!(snap.shortcuts > 0, "absent fetches should shortcut: {snap:?}");
    }

    #[test]
    fn destroy_removes_dirs() {
        let t = tmpdir("ht_destroy");
        let r = mk(t.path());
        let ht = r.hash_table::<u64, u32>("h").unwrap();
        ht.insert(&1, &1).unwrap();
        ht.sync().unwrap();
        ht.destroy().unwrap();
        for w in 0..r.cluster().nworkers() {
            assert!(!r.cluster().disk(w).exists("rht_h"));
        }
    }
}
