//! Roomy launcher: the Layer-3 coordinator CLI.
//!
//! Subcommands (run `roomy help`):
//! - `pancake  --n <N> [--structure list|array|hash] [--workers W] ...`
//!   — the paper's flagship workload: disk-based BFS over the pancake
//!   graph, validated against known pancake numbers.
//! - `demo` — a quick tour of the four data structures and constructs.
//! - `kernels` — report which AOT artifacts are loadable and their
//!   Rust-vs-XLA agreement on a smoke batch.

use std::path::PathBuf;
use std::time::Instant;

use roomy::accel::Accel;
use roomy::apps::pancake;
use roomy::constructs::bfs::{BfsOutcome, ResumableBfs};
use roomy::constructs::{mapreduce, setops};
use roomy::metrics::{fmt_bytes, fmt_rate};
use roomy::{AccelMode, DiskPolicy, Roomy, RoomyConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("pancake") => cmd_pancake(&args[1..]).map(|_| 0),
        Some("rubik") => cmd_rubik(&args[1..]).map(|_| 0),
        Some("demo") => cmd_demo(&args[1..]).map(|_| 0),
        Some("kernels") => cmd_kernels(&args[1..]).map(|_| 0),
        Some("analyze") | Some("--analyze") => cmd_analyze(&args[1..]).map(|_| 0),
        Some("analyze-diff") | Some("--analyze-diff") => cmd_analyze_diff(&args[1..]),
        Some("help") | None => {
            print_help();
            Ok(0)
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    }
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn print_help() {
    println!(
        "roomy — a system for space-limited computations (Kunkle 2010 reproduction)

USAGE:
  roomy pancake --n <N> [--structure list|array|hash] [--workers W]
                [--num-workers T]      # collective pool threads
                [--capture-spill B]    # flat in-collective op-capture RAM
                                       # budget per task before spilling
                                       # (bytes; env ROOMY_CAPTURE_SPILL)
                [--io-depth D]         # chunk buffers per bucket stream:
                                       # 0 = synchronous I/O, D >= 1 reads
                                       # ahead / writes behind through the
                                       # per-node io service (env
                                       # ROOMY_IO_DEPTH)
                [--steal P]            # idle pool-worker policy over the
                                       # per-node work queues: off =
                                       # strict locality, bounded =
                                       # home-first + LIFO steals
                                       # (default), greedy = flat cursor
                                       # (env ROOMY_STEAL); on-disk bytes
                                       # identical at every setting
                [--bloom BITS]         # per-key bits for the per-node
                                       # bloom dedup tier over exact
                                       # sort-merge; 0 = off (default;
                                       # env ROOMY_BLOOM). Exact-backed:
                                       # on-disk bytes identical to off
                [--bloom-approx]       # approximate mode: drop maybe-seen
                                       # adds without the exact merge
                                       # (bounded false-positive budget;
                                       # env ROOMY_BLOOM_APPROX)
                [--autotune M]         # off (default) pins every knob to
                                       # its configured value; on adapts
                                       # effective io depth + hint-ahead
                                       # from stall/queue counters between
                                       # collectives; spans adapts them
                                       # from histogram p95s instead, plus
                                       # skew-adaptive pool width / steal
                                       # boost (implies --hist; env
                                       # ROOMY_AUTOTUNE); on-disk bytes
                                       # identical in every mode
                [--kernels K]          # fingerprint/bitset kernel dispatch:
                                       # auto (default) runtime-detects
                                       # AVX2 else portable lanes; portable
                                       # forces the 4-lane path; scalar
                                       # forces the per-record reference
                                       # loops (env ROOMY_KERNELS); every
                                       # mode is bit-exact with every other
                [--buckets-per-worker B] [--root DIR] [--accel rust|xla|auto]
                [--throttle]           # simulate 2010-era disks
                [--checkpoint-dir DIR] # durable checkpoint after every BFS
                                       # level (atomic snapshot + manifest);
                                       # a rerun with the same dir resumes
                                       # from the last completed level
                [--resume]             # require an existing checkpoint and
                                       # continue it (error if none found)
                [--trace PATH]         # arm the flight recorder and flush
                                       # a Chrome-trace-event JSON there on
                                       # exit (load in Perfetto; env
                                       # ROOMY_TRACE); on-disk bytes are
                                       # identical with tracing on or off
                [--report-json PATH]   # write the machine-readable metrics
                                       # report (Roomy::report_json) there
                                       # before exit
                [--hist]               # arm the latency histograms: log2
                                       # buckets of task / stall /
                                       # collective durations, p50/p95/p99
                                       # in the report (env ROOMY_HIST);
                                       # on-disk bytes identical either way
  roomy rubik   [--workers W] [--root DIR]        # 2x2x2 cube God's number
  roomy demo    [--workers W] [--root DIR] [--trace PATH] [--report-json PATH]
  roomy kernels [--artifacts DIR]
  roomy analyze <run.json> [--top N] [--out PATH]
                # offline run analysis over a flushed Chrome trace
                # (--trace output) or a metrics report (--report-json
                # output): per-collective critical path, per-node task
                # p95 skew, reader/writer stall attribution, steal
                # counts, top-N slow collectives. --out also writes the
                # analysis as machine-readable JSON.
  roomy analyze-diff <a.json> <b.json> [--threshold-pct P]
                # side-by-side comparison of two runs (traces, reports,
                # analysis JSON, or BENCH_*.json baselines, in any
                # combination). Time-like metrics that grew more than P%
                # (default 25) are regressions: exit code 3 when any
                # fire, 0 otherwise — wire it into CI as a perf gate.
  roomy help"
    );
}

/// Tiny flag parser: `--key value` and boolean `--key` pairs.
struct Flags {
    pairs: Vec<(String, String)>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags, String> {
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let k = args[i]
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {:?}", args[i]))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                pairs.push((k.to_string(), args[i + 1].clone()));
                i += 2;
            } else {
                pairs.push((k.to_string(), String::new()));
                i += 1;
            }
        }
        Ok(Flags { pairs })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("bad value for --{key}: {v:?}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

fn config_from_flags(f: &Flags) -> Result<RoomyConfig, String> {
    let defaults = RoomyConfig::default();
    let mut cfg = RoomyConfig {
        workers: f.get_parse("workers", 4usize)?,
        buckets_per_worker: f.get_parse("buckets-per-worker", 4usize)?,
        num_workers: f.get_parse("num-workers", defaults.num_workers)?,
        capture_spill_threshold: f
            .get_parse("capture-spill", defaults.capture_spill_threshold)?,
        io_pipeline_depth: f.get_parse("io-depth", defaults.io_pipeline_depth)?,
        steal_policy: f.get_parse("steal", defaults.steal_policy)?,
        bloom_bits_per_key: f.get_parse("bloom", defaults.bloom_bits_per_key)?,
        bloom_approximate: f.has("bloom-approx") || defaults.bloom_approximate,
        autotune: f.get_parse("autotune", defaults.autotune)?,
        kernels: f.get_parse("kernels", defaults.kernels)?,
        hist: f.has("hist") || defaults.hist,
        ..defaults
    };
    cfg.root = f
        .get("root")
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join(format!("roomy-run-{}", std::process::id())));
    cfg.artifacts_dir = f.get("artifacts").map(PathBuf::from).unwrap_or_else(|| "artifacts".into());
    cfg.checkpoint_dir = f.get("checkpoint-dir").map(PathBuf::from);
    if let Some(p) = f.get("trace") {
        // `..defaults` already picked up ROOMY_TRACE; the flag wins.
        cfg.trace_path = Some(PathBuf::from(p));
    }
    cfg.accel = match f.get("accel").unwrap_or("auto") {
        "rust" => AccelMode::Rust,
        "xla" => AccelMode::Xla,
        "auto" => AccelMode::Auto,
        other => return Err(format!("bad --accel {other:?} (rust|xla|auto)")),
    };
    if f.has("throttle") {
        cfg.disk = DiskPolicy::paper_2010();
    }
    Ok(cfg)
}

/// End-of-run observability outputs shared by the subcommands: honor
/// `--report-json PATH` and flush the flight recorder (if armed) so the
/// trace lands even when the instance outlives `main`'s scope briefly.
fn finish_run(f: &Flags, r: &Roomy) -> Result<(), String> {
    if let Some(p) = f.get("report-json") {
        std::fs::write(p, r.report_json())
            .map_err(|e| format!("cannot write --report-json {p:?}: {e}"))?;
        println!("metrics report written to {p}");
    }
    match r.flush_trace() {
        Ok(Some(path)) => println!("trace written to {}", path.display()),
        Ok(None) => {}
        Err(e) => return Err(format!("trace flush failed: {e}")),
    }
    Ok(())
}

fn cmd_pancake(args: &[String]) -> Result<(), String> {
    let f = Flags::parse(args)?;
    let n: usize = f.get_parse("n", 8usize)?;
    if !(2..=12).contains(&n) {
        return Err("--n must be in 2..=12".into());
    }
    let structure = match f.get("structure").unwrap_or("list") {
        "list" => pancake::Structure::List,
        "array" => pancake::Structure::Array,
        "hash" => pancake::Structure::Hash,
        other => return Err(format!("bad --structure {other:?} (list|array|hash)")),
    };
    let cfg = config_from_flags(&f)?;
    println!(
        "pancake n={n} structure={structure:?} workers={} buckets={} root={:?}",
        cfg.workers,
        cfg.nbuckets(),
        cfg.root
    );
    let r = Roomy::open(cfg).map_err(|e| e.to_string())?;
    let accel = Accel::from_roomy(&r);
    println!("accel backend: {}", if accel.is_xla() { "XLA (AOT artifacts)" } else { "Rust" });

    let use_checkpoints = f.has("checkpoint-dir") || f.has("resume");
    let t0 = Instant::now();
    let stats = if use_checkpoints {
        let mgr = r.checkpoints().map_err(|e| e.to_string())?;
        let tag = format!(
            "pancake{n}-{}",
            match structure {
                pancake::Structure::List => "list",
                pancake::Structure::Array => "array",
                pancake::Structure::Hash => "hash",
            }
        );
        if mgr.exists(&tag) {
            println!("resuming checkpoint {tag:?} under {:?}", mgr.root());
        } else if f.has("resume") {
            return Err(format!(
                "--resume: no checkpoint named {tag:?} under {:?} (run once with --checkpoint-dir first)",
                mgr.root()
            ));
        } else {
            println!("checkpointing every level as {tag:?} under {:?}", mgr.root());
        }
        let out = pancake::roomy_bfs_resumable(
            &r,
            n,
            structure,
            &accel,
            &ResumableBfs::new(&mgr, tag),
        )
        .map_err(|e| e.to_string())?;
        println!("{}", mgr.stats().snapshot().report());
        match out {
            BfsOutcome::Complete(stats) => stats,
            BfsOutcome::Suspended { next_level } => {
                return Err(format!("BFS suspended before level {next_level}"))
            }
        }
    } else {
        pancake::roomy_bfs(&r, n, structure, &accel).map_err(|e| e.to_string())?
    };
    let dt = t0.elapsed().as_secs_f64();

    println!("\nlevel  states");
    for (i, c) in stats.levels.iter().enumerate() {
        println!("{i:>5}  {c}");
    }
    println!("total states: {} (n! = {})", stats.total, pancake::factorial(n));
    println!("pancake number f({n}) = {}", stats.depth());
    if let Some(known) = pancake::pancake_number(n) {
        let ok = stats.depth() == known && stats.total == pancake::factorial(n);
        println!("validation vs known f({n})={known}: {}", if ok { "OK" } else { "MISMATCH" });
        if !ok {
            return Err("validation failed".into());
        }
    }
    let io = r.io_snapshot();
    println!(
        "\nwall {dt:.2}s | disk: read {} written {} | aggregate {}",
        fmt_bytes(io.bytes_read),
        fmt_bytes(io.bytes_written),
        fmt_rate(io.bytes_read + io.bytes_written, dt),
    );
    print!("{}", r.report());
    finish_run(&f, &r)?;
    Ok(())
}

fn cmd_rubik(args: &[String]) -> Result<(), String> {
    let f = Flags::parse(args)?;
    let cfg = config_from_flags(&f)?;
    let r = Roomy::open(cfg).map_err(|e| e.to_string())?;
    println!(
        "2x2x2 pocket cube: {} states, 9 HTM generators",
        roomy::apps::rubik::STATE_COUNT
    );
    let t0 = Instant::now();
    let stats =
        roomy::apps::rubik::roomy_bfs(&r, &Accel::from_roomy(&r)).map_err(|e| e.to_string())?;
    let dt = t0.elapsed().as_secs_f64();
    println!("\nlevel  states");
    for (i, c) in stats.levels.iter().enumerate() {
        println!("{i:>5}  {c}");
    }
    let ok = stats.total == roomy::apps::rubik::STATE_COUNT
        && stats.depth() == roomy::apps::rubik::GODS_NUMBER;
    println!(
        "\ntotal {} | God's number {} (known {}) | {}",
        stats.total,
        stats.depth(),
        roomy::apps::rubik::GODS_NUMBER,
        if ok { "validation OK" } else { "MISMATCH" }
    );
    let io = r.io_snapshot();
    println!(
        "wall {dt:.1}s | disk read {} written {}",
        fmt_bytes(io.bytes_read),
        fmt_bytes(io.bytes_written)
    );
    if ok { Ok(()) } else { Err("validation failed".into()) }
}

fn cmd_demo(args: &[String]) -> Result<(), String> {
    let f = Flags::parse(args)?;
    let cfg = config_from_flags(&f)?;
    let r = Roomy::open(cfg).map_err(|e| e.to_string())?;
    let run = || -> roomy::Result<()> {
        println!("== RoomyArray: delayed updates + chain reduction ==");
        let ra = r.array::<i64>("demo_arr", 10, 0)?;
        ra.map_update(|i, v| *v = i as i64 + 1)?;
        roomy::constructs::chainred::chain_reduce(&ra, |a, b| a + b)?;
        let vals: Vec<i64> = (0..10).map(|i| ra.fetch(i).unwrap()).collect();
        println!("after chain reduce: {vals:?}");

        println!("\n== RoomyList: sets ==");
        let a = r.list::<u64>("demo_a")?;
        let b = r.list::<u64>("demo_b")?;
        for v in [1u64, 2, 3, 4, 4] {
            a.add(&v)?;
        }
        for v in [3u64, 4, 5] {
            b.add(&v)?;
        }
        a.sync()?;
        b.sync()?;
        setops::to_set(&a)?;
        setops::to_set(&b)?;
        let c = setops::intersection(&r, "demo_c", &a, &b)?;
        let mut got = c.collect()?;
        got.sort();
        println!("A ∩ B = {got:?}");

        println!("\n== RoomyHashTable: word-count style update ==");
        let ht = r.hash_table::<u64, u32>("demo_ht")?;
        let bump =
            ht.register_update(|_k, cur: Option<&u32>, _p: &()| Some(cur.copied().unwrap_or(0) + 1));
        for k in [10u64, 20, 10, 10, 30] {
            ht.update(&k, &(), bump)?;
        }
        ht.sync()?;
        println!("count(10) = {:?}, size = {}", ht.fetch(&10)?, ht.size());

        println!("\n== RoomySet: incrementally-sorted shards + merge algebra ==");
        let s1 = r.set::<u64>("demo_s1")?;
        let s2 = r.set::<u64>("demo_s2")?;
        for v in [2u64, 4, 6, 8] {
            s1.add(&v)?;
        }
        for v in [4u64, 8, 16] {
            s2.add(&v)?;
        }
        s1.sync()?;
        s2.sync()?;
        s1.intersect_with(&s2)?;
        let mut got = s1.collect()?;
        got.sort();
        println!("S1 ∩ S2 = {got:?} (size {})", s1.size());

        println!("\n== RoomyBitArray: 2-bit visited colors ==");
        let ba = r.bit_array("demo_bits", 64, 2)?;
        let mark = ba.register_update(|_i, cur, _p: &()| if cur == 0 { 1 } else { cur });
        for i in [0u64, 7, 7, 63] {
            ba.update(i, &(), mark)?;
        }
        ba.sync()?;
        println!("marked cells = {}, cell(7) = {}", ba.count_value(1), ba.fetch(7)?);

        println!("\n== reduce: paper's sum of squares ==");
        let l = r.list::<i64>("demo_sq")?;
        for v in 1..=10i64 {
            l.add(&v)?;
        }
        l.sync()?;
        println!("sum of squares 1..10 = {}", mapreduce::sum_of_squares(&l)?);
        Ok(())
    };
    run().map_err(|e| e.to_string())?;
    print!("\n{}", r.report());
    finish_run(&f, &r)?;
    Ok(())
}

/// Split `args` into leading positional operands (everything before the
/// first `--flag`) and the remaining flag tail.
fn split_positional(args: &[String]) -> (Vec<String>, &[String]) {
    let n = args.iter().take_while(|a| !a.starts_with("--")).count();
    (args[..n].to_vec(), &args[n..])
}

fn load_json(path: &str) -> Result<roomy::obs::json::Value, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path:?}: {e}"))?;
    roomy::obs::json::parse(&text).map_err(|e| format!("{path:?} is not valid JSON: {e}"))
}

fn cmd_analyze(args: &[String]) -> Result<(), String> {
    use roomy::obs::analyze::{render_table, Analysis};
    let (paths, rest) = split_positional(args);
    let f = Flags::parse(rest)?;
    let [path] = paths.as_slice() else {
        return Err("usage: roomy analyze <trace.json|report.json> [--top N] [--out PATH]".into());
    };
    let a = Analysis::from_value(&load_json(path)?)?;
    if a.truncated() {
        eprintln!(
            "warning: {path} is a truncated trace ({} events overwritten before the flush); \
             attribution is a lower bound",
            a.dropped_events
        );
    }
    let top = f.get_parse("top", 10usize)?;
    print!("{}", render_table(&a, top));
    if let Some(out) = f.get("out") {
        std::fs::write(out, a.to_json())
            .map_err(|e| format!("cannot write --out {out:?}: {e}"))?;
        println!("\nanalysis JSON written to {out}");
    }
    Ok(())
}

/// Returns the process exit code: 0 when no time-like metric regressed
/// past the threshold, 3 when at least one did.
fn cmd_analyze_diff(args: &[String]) -> Result<i32, String> {
    use roomy::obs::analyze::{diff, render_diff};
    let (paths, rest) = split_positional(args);
    let f = Flags::parse(rest)?;
    let [a, b] = paths.as_slice() else {
        return Err(
            "usage: roomy analyze-diff <a.json> <b.json> [--threshold-pct P] (a = baseline, b = candidate)"
                .into(),
        );
    };
    let threshold = f.get_parse("threshold-pct", 25.0f64)?;
    if threshold < 0.0 {
        return Err("--threshold-pct must be >= 0".into());
    }
    let (rows, regressed) = diff(&load_json(a)?, &load_json(b)?, threshold)?;
    if rows.is_empty() {
        return Err(format!("no common metrics between {a:?} and {b:?}"));
    }
    print!("{}", render_diff(&rows, threshold, regressed));
    Ok(if regressed { 3 } else { 0 })
}

fn cmd_kernels(args: &[String]) -> Result<(), String> {
    let f = Flags::parse(args)?;
    let dir = f.get("artifacts").unwrap_or("artifacts");
    let engine = roomy::runtime::Engine::load(dir)
        .map_err(|e| format!("cannot load artifacts from {dir:?}: {e} (run `make artifacts`)"))?;
    let mut names: Vec<_> = engine.names().iter().map(|s| s.to_string()).collect();
    names.sort();
    println!("artifacts in {dir:?}:");
    for n in &names {
        println!("  {n}");
    }
    // Rust-vs-XLA agreement smoke.
    let xla = Accel::xla(std::sync::Arc::new(engine));
    let rust = Accel::rust();
    let words: Vec<u64> = (0..8192u64).map(|i| i.wrapping_mul(0x2545F4914F6CDD1D)).collect();
    let a = xla.hash_partition(&words, 1, 64).map_err(|e| e.to_string())?;
    let b = rust.hash_partition(&words, 1, 64).map_err(|e| e.to_string())?;
    println!(
        "hash_partition xla==rust over 8192 words: {}",
        if a == b { "OK" } else { "MISMATCH" }
    );
    let x: Vec<i64> = (0..8192).map(|i| (i % 101) - 50).collect();
    let sa = xla.prefix_scan(&x).map_err(|e| e.to_string())?;
    let sb = rust.prefix_scan(&x).map_err(|e| e.to_string())?;
    println!("prefix_scan   xla==rust over 8192 i64:   {}", if sa == sb { "OK" } else { "MISMATCH" });
    Ok(())
}
