//! 64-bit fingerprint + fast-range bucket routing.
//!
//! **Bit-exact twin** of the L1 Pallas kernel
//! (`python/compile/kernels/hashpart.py`) and the numpy oracle
//! (`python/compile/kernels/ref.py`). Roomy routes every delayed operation
//! and list element by this fingerprint, and the XLA-accelerated paths
//! compute it on-device — the two implementations are pinned to shared
//! test vectors below; change them only in lockstep. The batch entry
//! points ([`fp_words_batch`], [`fp_bytes_batch`], [`route_batch_into`],
//! [`fp_bytes_batch_strided_into`]) are part of the same contract: every
//! kernel mode (scalar / portable lanes / AVX2) must produce fingerprints
//! bit-identical to a per-record [`fp_words`] loop, so the on-disk layout
//! never depends on which kernel ran.
//!
//! Dispatch: records are independent (the splitmix recurrence is
//! per-record), so batching is plain lane parallelism — 4 records per
//! iteration. `ROOMY_KERNELS` (see [`KernelMode`]) picks the
//! implementation: `auto` (default) runtime-detects AVX2 and otherwise
//! uses the portable unrolled lanes; `portable` forces the fallback;
//! `scalar` forces the per-record reference loop.

pub use crate::config::KernelMode;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};

const GOLDEN: u64 = 0x9E3779B97F4A7C15;
const MIX1: u64 = 0xBF58476D1CE4E5B9;
const MIX2: u64 = 0x94D049BB133111EB;

/// One per-word avalanche step of the splitmix recurrence.
#[inline(always)]
fn mix_word(h: u64, w: u64) -> u64 {
    let h = (h ^ w).wrapping_mul(MIX1);
    h ^ (h >> 29)
}

/// The splitmix finalizer.
#[inline(always)]
fn finish(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(MIX1);
    h ^= h >> 27;
    h = h.wrapping_mul(MIX2);
    h ^ (h >> 31)
}

/// splitmix-style avalanche fingerprint of a K-word element.
#[inline]
pub fn fp_words(words: &[u64]) -> u64 {
    let mut h = GOLDEN ^ words.len() as u64;
    for &w in words {
        h = mix_word(h, w);
    }
    finish(h)
}

/// Fold a byte string into 8-byte LE words, zero-padding the tail.
/// `out` must hold exactly `bytes.len().div_ceil(8)` words.
#[inline]
fn fold_le_words(bytes: &[u8], out: &mut [u64]) {
    debug_assert_eq!(out.len(), bytes.len().div_ceil(8));
    for (w, chunk) in out.iter_mut().zip(bytes.chunks(8)) {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        *w = u64::from_le_bytes(b);
    }
}

/// Fingerprint of an arbitrary byte string: fold into 8-byte LE words,
/// zero-padding the tail. Equality of byte strings implies equality of the
/// word sequence (length is mixed in), so this is a sound routing hash for
/// fixed-size Roomy elements.
#[inline]
pub fn fp_bytes(bytes: &[u8]) -> u64 {
    let mut words = [0u64; 8];
    let nwords = bytes.len().div_ceil(8);
    if nwords <= words.len() {
        fold_le_words(bytes, &mut words[..nwords]);
        fp_words(&words[..nwords])
    } else {
        // Rare large-element path: heap-allocate the word vector.
        let mut v = vec![0u64; nwords];
        fold_le_words(bytes, &mut v);
        fp_words(&v)
    }
}

/// Fast-range bucket id: `((fp >> 32) * nbuckets) >> 32`.
///
/// Avoids the modulo bias/latency and — critically — matches the formula
/// used in the XLA kernels (no u128 on-device).
#[inline]
pub fn bucket_of(fp: u64, nbuckets: u32) -> u32 {
    (((fp >> 32) * nbuckets as u64) >> 32) as u32
}

/// Convenience: bucket of a byte-string element.
#[inline]
pub fn bucket_of_bytes(bytes: &[u8], nbuckets: u32) -> u32 {
    bucket_of(fp_bytes(bytes), nbuckets)
}

// ---------------------------------------------------------------------------
// Kernel mode dispatch
// ---------------------------------------------------------------------------

const MODE_UNSET: u8 = 0xFF;

/// Process-global kernel mode. Every mode is bit-exact, so concurrent
/// flips (tests, `Roomy::open` applying its config) can never change
/// results — only which lane code computes them.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Cached AVX2 runtime detection: 0 unknown, 1 present, 2 absent.
#[cfg(target_arch = "x86_64")]
static AVX2_DETECT: AtomicU8 = AtomicU8::new(0);

/// The active kernel mode, lazily initialized from `ROOMY_KERNELS`.
pub fn kernel_mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_UNSET => {
            let m = std::env::var("ROOMY_KERNELS")
                .ok()
                .as_deref()
                .and_then(KernelMode::parse)
                .unwrap_or(KernelMode::Auto);
            MODE.store(m as u8, Ordering::Relaxed);
            m
        }
        v => KernelMode::from_u8(v),
    }
}

/// Override the kernel mode (applied by `Roomy::open` from its config;
/// also the hook the determinism matrix uses to pit kernels against each
/// other in one process).
pub fn set_kernel_mode(m: KernelMode) {
    MODE.store(m as u8, Ordering::Relaxed);
}

/// Which lane implementation actually runs a batch call.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Lanes {
    Scalar,
    Portable,
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    match AVX2_DETECT.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            AVX2_DETECT.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

fn resolve(mode: KernelMode) -> Lanes {
    match mode {
        KernelMode::Scalar => Lanes::Scalar,
        KernelMode::Portable => Lanes::Portable,
        KernelMode::Auto => {
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                return Lanes::Avx2;
            }
            Lanes::Portable
        }
    }
}

/// Name of the implementation the current mode resolves to — for
/// reports/benches: `"avx2"`, `"portable"` or `"scalar"`.
pub fn kernel_impl() -> &'static str {
    match resolve(kernel_mode()) {
        Lanes::Scalar => "scalar",
        Lanes::Portable => "portable",
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => "avx2",
    }
}

// ---------------------------------------------------------------------------
// Batch entry points
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread word scratch for byte-record batches (no per-call alloc).
    static WORD_SCRATCH: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// Per-thread fingerprint scratch for fused route batches.
    static FP_SCRATCH: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// Fingerprint every `k`-word record of `words` (its length must be a
/// whole number of records), appending one fingerprint per record to
/// `out`. Bit-exact with a per-record [`fp_words`] loop in every mode.
pub fn fp_words_batch_into(words: &[u64], k: usize, out: &mut Vec<u64>) {
    fp_words_batch_with(kernel_mode(), words, k, out)
}

/// [`fp_words_batch_into`] returning a fresh vector.
pub fn fp_words_batch(words: &[u64], k: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(if k == 0 { 0 } else { words.len() / k });
    fp_words_batch_into(words, k, &mut out);
    out
}

/// Mode-explicit batch fingerprint (benches and tests pit the
/// implementations against each other through this).
pub fn fp_words_batch_with(mode: KernelMode, words: &[u64], k: usize, out: &mut Vec<u64>) {
    assert!(k > 0, "record width k must be nonzero");
    assert_eq!(words.len() % k, 0, "words are not a whole number of records");
    out.reserve(words.len() / k);
    match resolve(mode) {
        Lanes::Scalar => {
            for rec in words.chunks_exact(k) {
                out.push(fp_words(rec));
            }
        }
        Lanes::Portable => batch_portable(words, k, out),
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { avx2::batch(words, k, out) },
    }
}

/// Portable lane kernel: four independent splitmix recurrences per
/// iteration (the lanes are whole records, so this is bit-exact by
/// construction); remainder records go through the scalar loop.
fn batch_portable(words: &[u64], k: usize, out: &mut Vec<u64>) {
    let seed = GOLDEN ^ k as u64;
    let quads = (words.len() / k) / 4;
    for q in 0..quads {
        let base = q * 4 * k;
        let (mut h0, mut h1, mut h2, mut h3) = (seed, seed, seed, seed);
        for w in 0..k {
            h0 = mix_word(h0, words[base + w]);
            h1 = mix_word(h1, words[base + k + w]);
            h2 = mix_word(h2, words[base + 2 * k + w]);
            h3 = mix_word(h3, words[base + 3 * k + w]);
        }
        out.extend_from_slice(&[finish(h0), finish(h1), finish(h2), finish(h3)]);
    }
    for rec in words[quads * 4 * k..].chunks_exact(k) {
        out.push(fp_words(rec));
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 lane kernel: 4 records per `__m256i`, same recurrence as the
    //! scalar twin. AVX2 has no 64x64 multiply, so it is composed from
    //! three 32-bit products (the carry-free schoolbook low half).
    use super::{fp_words, GOLDEN, MIX1, MIX2};
    use std::arch::x86_64::*;

    /// Low 64 bits of a 64x64 multiply per lane:
    /// `lo(a)·lo(b) + ((hi(a)·lo(b) + lo(a)·hi(b)) << 32)`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mul64(a: __m256i, b: __m256i) -> __m256i {
        let lo = _mm256_mul_epu32(a, b);
        let cross = _mm256_add_epi64(
            _mm256_mul_epu32(_mm256_srli_epi64(a, 32), b),
            _mm256_mul_epu32(a, _mm256_srli_epi64(b, 32)),
        );
        _mm256_add_epi64(lo, _mm256_slli_epi64(cross, 32))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn xorshr(h: __m256i, s: i32) -> __m256i {
        _mm256_xor_si256(h, _mm256_srl_epi64(h, _mm_cvtsi32_si128(s)))
    }

    /// # Safety
    /// Caller must have verified AVX2 via runtime detection.
    #[target_feature(enable = "avx2")]
    pub unsafe fn batch(words: &[u64], k: usize, out: &mut Vec<u64>) {
        let seed = GOLDEN ^ k as u64;
        let quads = (words.len() / k) / 4;
        let mix1 = _mm256_set1_epi64x(MIX1 as i64);
        let mix2 = _mm256_set1_epi64x(MIX2 as i64);
        let mut lanes = [0u64; 4];
        for q in 0..quads {
            let base = q * 4 * k;
            let mut h = _mm256_set1_epi64x(seed as i64);
            for w in 0..k {
                let v = _mm256_set_epi64x(
                    words[base + 3 * k + w] as i64,
                    words[base + 2 * k + w] as i64,
                    words[base + k + w] as i64,
                    words[base + w] as i64,
                );
                h = mul64(_mm256_xor_si256(h, v), mix1);
                h = xorshr(h, 29);
            }
            h = xorshr(h, 30);
            h = mul64(h, mix1);
            h = xorshr(h, 27);
            h = mul64(h, mix2);
            h = xorshr(h, 31);
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, h);
            out.extend_from_slice(&lanes);
        }
        for rec in words[quads * 4 * k..].chunks_exact(k) {
            out.push(fp_words(rec));
        }
    }
}

/// Fingerprint every `rec_size`-byte record of `batch` — exactly
/// [`fp_bytes`] per record (LE word fold, zero-padded tail) but one call
/// per chunk instead of per record.
pub fn fp_bytes_batch_into(batch: &[u8], rec_size: usize, out: &mut Vec<u64>) {
    fp_bytes_batch_with(kernel_mode(), batch, rec_size, out)
}

/// [`fp_bytes_batch_into`] returning a fresh vector.
pub fn fp_bytes_batch(batch: &[u8], rec_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(if rec_size == 0 { 0 } else { batch.len() / rec_size });
    fp_bytes_batch_into(batch, rec_size, &mut out);
    out
}

/// Mode-explicit byte-record batch fingerprint.
pub fn fp_bytes_batch_with(mode: KernelMode, batch: &[u8], rec_size: usize, out: &mut Vec<u64>) {
    assert!(rec_size > 0, "record size must be nonzero");
    assert_eq!(batch.len() % rec_size, 0, "batch is not a whole number of records");
    let nw = rec_size.div_ceil(8);
    WORD_SCRATCH.with(|s| {
        let mut scratch = s.borrow_mut();
        scratch.clear();
        scratch.resize(batch.len() / rec_size * nw, 0);
        if rec_size % 8 == 0 {
            // Whole-word records: one straight LE sweep over the chunk.
            for (w, c) in scratch.iter_mut().zip(batch.chunks_exact(8)) {
                *w = u64::from_le_bytes(c.try_into().expect("8-byte chunk"));
            }
        } else {
            for (rec, ws) in batch.chunks_exact(rec_size).zip(scratch.chunks_mut(nw)) {
                fold_le_words(rec, ws);
            }
        }
        fp_words_batch_with(mode, &scratch, nw, out);
    })
}

/// Fingerprint the first `key_len` bytes of every `stride`-byte record in
/// `arena` (hash-table rehash: arena records are `key ++ value`). Exactly
/// `fp_bytes(&rec[..key_len])` per record.
pub fn fp_bytes_batch_strided_into(
    arena: &[u8],
    stride: usize,
    key_len: usize,
    out: &mut Vec<u64>,
) {
    assert!(key_len > 0 && key_len <= stride, "bad key span {key_len}/{stride}");
    assert_eq!(arena.len() % stride, 0, "arena is not a whole number of records");
    let nw = key_len.div_ceil(8);
    WORD_SCRATCH.with(|s| {
        let mut scratch = s.borrow_mut();
        scratch.clear();
        scratch.resize(arena.len() / stride * nw, 0);
        for (rec, ws) in arena.chunks_exact(stride).zip(scratch.chunks_mut(nw)) {
            fold_le_words(&rec[..key_len], ws);
        }
        fp_words_batch_into(&scratch, nw, out);
    })
}

/// Fused fingerprint + fast-range bucket of every `rec_size`-byte record:
/// one batched hash sweep, then [`bucket_of`] per fingerprint. This is the
/// bulk form of [`bucket_of_bytes`] and the routing entry the structures'
/// batch paths use.
pub fn route_batch_into(batch: &[u8], rec_size: usize, nbuckets: u32, out: &mut Vec<u32>) {
    FP_SCRATCH.with(|s| {
        let mut fps = s.borrow_mut();
        fps.clear();
        fp_bytes_batch_into(batch, rec_size, &mut fps);
        out.reserve(fps.len());
        out.extend(fps.iter().map(|&fp| bucket_of(fp, nbuckets)));
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_MODES: &[KernelMode] =
        &[KernelMode::Scalar, KernelMode::Portable, KernelMode::Auto];

    /// Cross-language pin vectors, generated from the numpy oracle
    /// (`python/tests/test_hashpart.py` keeps the same values). These
    /// define the on-disk routing contract between the Rust and XLA paths.
    const PIN_K1: &[(u64, u64)] = &[
        (0x0000000000000000, 0x06CA4302F7957093),
        (0x0000000000000001, 0xFDC71BA11F1623D2),
        (0xFFFFFFFFFFFFFFFF, 0xF02738DF33C41F59),
        (0x0123456789ABCDEF, 0x5EE5D896C5F71E42),
        (0x9E3779B97F4A7C15, 0x5A2C67DDBAFC107E),
    ];

    #[test]
    fn pin_vectors_k1() {
        for &(w, expect) in PIN_K1 {
            assert_eq!(fp_words(&[w]), expect, "word {w:#x}");
        }
    }

    #[test]
    fn pin_vectors_k1_batch_form() {
        // The same oracle rows pushed through every batch kernel in one
        // call — the batch layer is part of the cross-language contract.
        let words: Vec<u64> = PIN_K1.iter().map(|&(w, _)| w).collect();
        let expect: Vec<u64> = PIN_K1.iter().map(|&(_, fp)| fp).collect();
        for &mode in ALL_MODES {
            let mut out = Vec::new();
            fp_words_batch_with(mode, &words, 1, &mut out);
            assert_eq!(out, expect, "mode {mode:?}");
        }
    }

    #[test]
    fn pin_vector_k2() {
        assert_eq!(
            fp_words(&[0x0123456789ABCDEF, 0xFEDCBA9876543210]),
            0x71B4AA2CD4369C1A
        );
    }

    #[test]
    fn pin_vector_k2_batch_form() {
        // Five copies of the k=2 oracle record so every lane of the 4-wide
        // kernels (and the remainder path) sees it.
        let rec = [0x0123456789ABCDEFu64, 0xFEDCBA9876543210];
        let words: Vec<u64> = rec.iter().copied().cycle().take(10).collect();
        for &mode in ALL_MODES {
            let mut out = Vec::new();
            fp_words_batch_with(mode, &words, 2, &mut out);
            assert_eq!(out, vec![0x71B4AA2CD4369C1A; 5], "mode {mode:?}");
        }
    }

    #[test]
    fn pin_buckets_nb7() {
        // (word, fp, bucket) rows from the oracle.
        let rows: &[(u64, u64, u32)] = &[
            (1, 18286615190786417618, 6),
            (2, 7775381647587981615, 2),
            (3, 17688293697997199404, 6),
            (4, 5293305913000472489, 2),
            (5, 15733362921970038256, 5),
        ];
        for &(w, fp, b) in rows {
            assert_eq!(fp_words(&[w]), fp);
            assert_eq!(bucket_of(fp, 7), b);
        }
        // Batch form: the fused route sweep lands in the same buckets.
        let mut bytes = Vec::new();
        for &(w, _, _) in rows {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let mut buckets = Vec::new();
        route_batch_into(&bytes, 8, 7, &mut buckets);
        let expect: Vec<u32> = rows.iter().map(|&(_, _, b)| b).collect();
        assert_eq!(buckets, expect);
    }

    #[test]
    fn length_is_mixed_in() {
        // A trailing zero word must change the fingerprint.
        assert_ne!(fp_words(&[42]), fp_words(&[42, 0]));
    }

    #[test]
    fn bytes_fold_matches_words() {
        let w: u64 = 0x0123456789ABCDEF;
        assert_eq!(fp_bytes(&w.to_le_bytes()), fp_words(&[w]));
        // 12 bytes -> two words, second zero-padded.
        let mut b = vec![];
        b.extend_from_slice(&w.to_le_bytes());
        b.extend_from_slice(&0xAABBCCDDu32.to_le_bytes());
        assert_eq!(fp_bytes(&b), fp_words(&[w, 0xAABBCCDD]));
    }

    #[test]
    fn bytes_large_element_path() {
        let bytes = vec![7u8; 100]; // > 64 bytes: heap path
        let words: Vec<u64> = bytes
            .chunks(8)
            .map(|c| {
                let mut w = [0u8; 8];
                w[..c.len()].copy_from_slice(c);
                u64::from_le_bytes(w)
            })
            .collect();
        assert_eq!(fp_bytes(&bytes), fp_words(&words));
    }

    /// Deterministic pseudo-random word (no RNG dep in unit tests).
    fn tword(i: u64) -> u64 {
        finish(GOLDEN.wrapping_mul(i).wrapping_add(0xD1B54A32D192ED03))
    }

    #[test]
    fn words_batch_matches_scalar_every_mode() {
        for &mode in ALL_MODES {
            for k in [1usize, 2, 3, 7, 9] {
                for n in [0usize, 1, 3, 4, 5, 8, 17] {
                    let words: Vec<u64> = (0..(n * k) as u64).map(tword).collect();
                    let expect: Vec<u64> =
                        words.chunks_exact(k).map(fp_words).collect();
                    let mut out = Vec::new();
                    fp_words_batch_with(mode, &words, k, &mut out);
                    assert_eq!(out, expect, "mode {mode:?} k={k} n={n}");
                }
            }
        }
    }

    #[test]
    fn bytes_batch_matches_scalar_every_mode() {
        for &mode in ALL_MODES {
            for rec_size in [1usize, 3, 8, 12, 16, 24, 100] {
                for n in [0usize, 1, 4, 5, 13] {
                    let batch: Vec<u8> =
                        (0..n * rec_size).map(|i| tword(i as u64) as u8).collect();
                    let expect: Vec<u64> =
                        batch.chunks_exact(rec_size).map(fp_bytes).collect();
                    let mut out = Vec::new();
                    fp_bytes_batch_with(mode, &batch, rec_size, &mut out);
                    assert_eq!(out, expect, "mode {mode:?} rec={rec_size} n={n}");
                }
            }
        }
    }

    #[test]
    fn strided_batch_hashes_key_prefix() {
        let (stride, key_len, n) = (12usize, 5usize, 9usize);
        let arena: Vec<u8> = (0..n * stride).map(|i| tword(i as u64) as u8).collect();
        let expect: Vec<u64> =
            arena.chunks_exact(stride).map(|r| fp_bytes(&r[..key_len])).collect();
        let mut out = Vec::new();
        fp_bytes_batch_strided_into(&arena, stride, key_len, &mut out);
        assert_eq!(out, expect);
    }

    #[test]
    fn kernel_mode_dispatch_names() {
        let prev = kernel_mode();
        set_kernel_mode(KernelMode::Scalar);
        assert_eq!(kernel_impl(), "scalar");
        set_kernel_mode(KernelMode::Portable);
        assert_eq!(kernel_impl(), "portable");
        set_kernel_mode(KernelMode::Auto);
        assert!(matches!(kernel_impl(), "avx2" | "portable"));
        set_kernel_mode(prev);
    }

    #[test]
    fn bucket_range() {
        for nb in [1u32, 2, 3, 17, 255, 1024] {
            for w in 0..1000u64 {
                let b = bucket_of(fp_words(&[w]), nb);
                assert!(b < nb, "bucket {b} out of range for nb={nb}");
            }
        }
    }

    #[test]
    fn bucket_distribution_roughly_uniform() {
        let nb = 16u32;
        let mut counts = vec![0usize; nb as usize];
        let n = 100_000u64;
        for w in 0..n {
            counts[bucket_of(fp_words(&[w]), nb) as usize] += 1;
        }
        let expect = n as f64 / nb as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bucket {i} count {c} deviates {dev:.3} from uniform");
        }
    }
}
