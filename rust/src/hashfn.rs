//! 64-bit fingerprint + fast-range bucket routing.
//!
//! **Bit-exact twin** of the L1 Pallas kernel
//! (`python/compile/kernels/hashpart.py`) and the numpy oracle
//! (`python/compile/kernels/ref.py`). Roomy routes every delayed operation
//! and list element by this fingerprint, and the XLA-accelerated paths
//! compute it on-device — the two implementations are pinned to shared
//! test vectors below; change them only in lockstep.

const GOLDEN: u64 = 0x9E3779B97F4A7C15;
const MIX1: u64 = 0xBF58476D1CE4E5B9;
const MIX2: u64 = 0x94D049BB133111EB;

/// splitmix-style avalanche fingerprint of a K-word element.
#[inline]
pub fn fp_words(words: &[u64]) -> u64 {
    let mut h = GOLDEN ^ words.len() as u64;
    for &w in words {
        h = (h ^ w).wrapping_mul(MIX1);
        h ^= h >> 29;
    }
    h ^= h >> 30;
    h = h.wrapping_mul(MIX1);
    h ^= h >> 27;
    h = h.wrapping_mul(MIX2);
    h ^= h >> 31;
    h
}

/// Fingerprint of an arbitrary byte string: fold into 8-byte LE words,
/// zero-padding the tail. Equality of byte strings implies equality of the
/// word sequence (length is mixed in), so this is a sound routing hash for
/// fixed-size Roomy elements.
#[inline]
pub fn fp_bytes(bytes: &[u8]) -> u64 {
    let mut words = [0u64; 8];
    let nwords = bytes.len().div_ceil(8);
    if nwords <= words.len() {
        for (i, chunk) in bytes.chunks(8).enumerate() {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            words[i] = u64::from_le_bytes(w);
        }
        fp_words(&words[..nwords])
    } else {
        // Rare large-element path: heap-allocate the word vector.
        let mut v = vec![0u64; nwords];
        for (i, chunk) in bytes.chunks(8).enumerate() {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            v[i] = u64::from_le_bytes(w);
        }
        fp_words(&v)
    }
}

/// Fast-range bucket id: `((fp >> 32) * nbuckets) >> 32`.
///
/// Avoids the modulo bias/latency and — critically — matches the formula
/// used in the XLA kernels (no u128 on-device).
#[inline]
pub fn bucket_of(fp: u64, nbuckets: u32) -> u32 {
    (((fp >> 32) * nbuckets as u64) >> 32) as u32
}

/// Convenience: bucket of a byte-string element.
#[inline]
pub fn bucket_of_bytes(bytes: &[u8], nbuckets: u32) -> u32 {
    bucket_of(fp_bytes(bytes), nbuckets)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cross-language pin vectors, generated from the numpy oracle
    /// (`python/tests/test_hashpart.py` keeps the same values). These
    /// define the on-disk routing contract between the Rust and XLA paths.
    const PIN_K1: &[(u64, u64)] = &[
        (0x0000000000000000, 0x06CA4302F7957093),
        (0x0000000000000001, 0xFDC71BA11F1623D2),
        (0xFFFFFFFFFFFFFFFF, 0xF02738DF33C41F59),
        (0x0123456789ABCDEF, 0x5EE5D896C5F71E42),
        (0x9E3779B97F4A7C15, 0x5A2C67DDBAFC107E),
    ];

    #[test]
    fn pin_vectors_k1() {
        for &(w, expect) in PIN_K1 {
            assert_eq!(fp_words(&[w]), expect, "word {w:#x}");
        }
    }

    #[test]
    fn pin_vector_k2() {
        assert_eq!(
            fp_words(&[0x0123456789ABCDEF, 0xFEDCBA9876543210]),
            0x71B4AA2CD4369C1A
        );
    }

    #[test]
    fn pin_buckets_nb7() {
        // (word, fp, bucket) rows from the oracle.
        let rows: &[(u64, u64, u32)] = &[
            (1, 18286615190786417618, 6),
            (2, 7775381647587981615, 2),
            (3, 17688293697997199404, 6),
            (4, 5293305913000472489, 2),
            (5, 15733362921970038256, 5),
        ];
        for &(w, fp, b) in rows {
            assert_eq!(fp_words(&[w]), fp);
            assert_eq!(bucket_of(fp, 7), b);
        }
    }

    #[test]
    fn length_is_mixed_in() {
        // A trailing zero word must change the fingerprint.
        assert_ne!(fp_words(&[42]), fp_words(&[42, 0]));
    }

    #[test]
    fn bytes_fold_matches_words() {
        let w: u64 = 0x0123456789ABCDEF;
        assert_eq!(fp_bytes(&w.to_le_bytes()), fp_words(&[w]));
        // 12 bytes -> two words, second zero-padded.
        let mut b = vec![];
        b.extend_from_slice(&w.to_le_bytes());
        b.extend_from_slice(&0xAABBCCDDu32.to_le_bytes());
        assert_eq!(fp_bytes(&b), fp_words(&[w, 0xAABBCCDD]));
    }

    #[test]
    fn bytes_large_element_path() {
        let bytes = vec![7u8; 100]; // > 64 bytes: heap path
        let words: Vec<u64> = bytes
            .chunks(8)
            .map(|c| {
                let mut w = [0u8; 8];
                w[..c.len()].copy_from_slice(c);
                u64::from_le_bytes(w)
            })
            .collect();
        assert_eq!(fp_bytes(&bytes), fp_words(&words));
    }

    #[test]
    fn bucket_range() {
        for nb in [1u32, 2, 3, 17, 255, 1024] {
            for w in 0..1000u64 {
                let b = bucket_of(fp_words(&[w]), nb);
                assert!(b < nb, "bucket {b} out of range for nb={nb}");
            }
        }
    }

    #[test]
    fn bucket_distribution_roughly_uniform() {
        let nb = 16u32;
        let mut counts = vec![0usize; nb as usize];
        let n = 100_000u64;
        for w in 0..n {
            counts[bucket_of(fp_words(&[w]), nb) as usize] += 1;
        }
        let expect = n as f64 / nb as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bucket {i} count {c} deviates {dev:.3} from uniform");
        }
    }
}
