//! Spillable staging buffers for delayed operations.
//!
//! Delayed ops are staged in RAM per destination bucket and spilled to the
//! owning node's disk when they exceed the configured budget — this is the
//! paper's central trick: random-access operations accumulate as a
//! *sequential* byte stream and are applied in batch at `sync`, so the
//! disks only ever see streaming I/O.
//!
//! The buffer stores an opaque byte stream (op records are self-describing,
//! see [`crate::roomy::ops`]); [`SpillReader`] replays the stream in FIFO
//! order (spilled segments first, then the RAM tail) with `read_exact`
//! semantics so variable-size records can span chunk boundaries safely.

use std::path::PathBuf;
use std::sync::Arc;

use super::diskio::NodeDisk;
use crate::error::Result;

/// Byte-stream staging buffer that spills to disk past a RAM threshold.
pub struct SpillBuffer {
    /// `None` in RAM-only mode ([`SpillBuffer::ram_only`]): content grows
    /// unbounded in RAM and never touches a file.
    disk: Option<Arc<NodeDisk>>,
    /// Spill file path (single append-only segment file).
    spill_rel: PathBuf,
    ram: Vec<u8>,
    threshold: usize,
    spilled_bytes: u64,
}

impl SpillBuffer {
    /// New buffer spilling to `spill_rel` on `disk` once RAM content
    /// exceeds `threshold` bytes.
    pub fn new(disk: Arc<NodeDisk>, spill_rel: impl Into<PathBuf>, threshold: usize) -> Self {
        SpillBuffer {
            disk: Some(disk),
            spill_rel: spill_rel.into(),
            ram: Vec::new(),
            threshold: threshold.max(1),
            spilled_bytes: 0,
        }
    }

    /// A buffer with no disk backing: content accumulates in RAM without
    /// bound. Used where no node disk exists to spill to (e.g. a bare
    /// [`crate::runtime::pool::WorkerPool`] outside any cluster); every
    /// production buffer should prefer [`SpillBuffer::new`].
    pub fn ram_only() -> Self {
        SpillBuffer {
            disk: None,
            spill_rel: PathBuf::new(),
            ram: Vec::new(),
            threshold: usize::MAX,
            spilled_bytes: 0,
        }
    }

    /// Append `bytes` (one or more complete records).
    pub fn push(&mut self, bytes: &[u8]) -> Result<()> {
        self.ram.extend_from_slice(bytes);
        if self.ram.len() >= self.threshold {
            self.spill()?;
        }
        Ok(())
    }

    /// Force RAM contents out to the spill file (no-op when RAM-only).
    pub fn spill(&mut self) -> Result<()> {
        let Some(disk) = &self.disk else { return Ok(()) };
        if self.ram.is_empty() {
            return Ok(());
        }
        let mut w = disk.append_file(&self.spill_rel)?;
        w.write_bytes(&self.ram)?;
        w.finish()?;
        self.spilled_bytes += self.ram.len() as u64;
        self.ram.clear();
        Ok(())
    }

    /// Total staged bytes (RAM + spilled).
    pub fn len_bytes(&self) -> u64 {
        self.spilled_bytes + self.ram.len() as u64
    }

    /// Bytes currently resident in RAM (tests assert the space budget).
    pub fn ram_bytes(&self) -> usize {
        self.ram.len()
    }

    /// Bytes spilled to disk so far.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
    }

    pub fn is_empty(&self) -> bool {
        self.len_bytes() == 0
    }

    /// Open a FIFO reader over everything staged. The buffer keeps its
    /// contents; call [`SpillBuffer::clear`] after a successful apply.
    pub fn reader(&self) -> Result<SpillReader<'_>> {
        let file = if self.spilled_bytes > 0 {
            let disk = self.disk.as_ref().expect("spilled bytes imply a disk");
            Some(disk.open_file(&self.spill_rel)?)
        } else {
            None
        };
        Ok(SpillReader { file, ram: &self.ram, ram_pos: 0 })
    }

    /// Consume the buffer into an owned streaming drain: a one-shot FIFO
    /// reader over everything staged that removes the spill file when
    /// dropped (read fully or not). This is the leak-free way to replay a
    /// buffer whose content is no longer needed afterwards — the pool's
    /// capture replay, the structure sync loops and the error paths all
    /// rely on the drop-side cleanup. On a pipelined disk the spilled
    /// segment streams back through the node's read-ahead lane
    /// ([`crate::storage::pipeline::ByteReader`]), overlapping op-log
    /// replay with the apply work it feeds.
    pub fn into_drain(self) -> Result<SpillDrain> {
        let file = if self.spilled_bytes > 0 {
            let disk = self.disk.as_ref().expect("spilled bytes imply a disk");
            Some(super::pipeline::ByteReader::open(disk, &self.spill_rel)?)
        } else {
            None
        };
        Ok(SpillDrain {
            remove_on_drop: self.spilled_bytes > 0,
            disk: self.disk,
            spill_rel: self.spill_rel,
            file,
            ram: self.ram,
            ram_pos: 0,
        })
    }

    /// Discard all staged content (after a successful sync apply).
    pub fn clear(&mut self) -> Result<()> {
        self.ram.clear();
        if self.spilled_bytes > 0 {
            let disk = self.disk.as_ref().expect("spilled bytes imply a disk");
            disk.remove(&self.spill_rel)?;
            self.spilled_bytes = 0;
        }
        Ok(())
    }
}

/// FIFO replay of a [`SpillBuffer`]: spilled segment first, then RAM tail.
pub struct SpillReader<'b> {
    file: Option<super::diskio::MeteredReader<'b>>,
    ram: &'b [u8],
    ram_pos: usize,
}

impl<'b> SpillReader<'b> {
    /// Read exactly `buf.len()` bytes; Ok(false) = clean EOF at a record
    /// boundary (no bytes read). Errors on partial reads.
    pub fn read_exact_or_eof(&mut self, buf: &mut [u8]) -> Result<bool> {
        let mut got = 0;
        if let Some(f) = self.file.as_mut() {
            got = f.read_fully(&mut buf[..])?;
            if got == buf.len() {
                return Ok(true);
            }
            // file exhausted; fall through to RAM
            self.file = None;
        }
        let want = buf.len() - got;
        let avail = self.ram.len() - self.ram_pos;
        if got == 0 && avail == 0 {
            return Ok(false);
        }
        if avail < want {
            return Err(crate::error::RoomyError::InvalidArg(
                "truncated record in spill buffer".into(),
            ));
        }
        buf[got..].copy_from_slice(&self.ram[self.ram_pos..self.ram_pos + want]);
        self.ram_pos += want;
        Ok(true)
    }
}

/// Owned FIFO drain of a [`SpillBuffer`] (see [`SpillBuffer::into_drain`]):
/// spilled segment first (prefetched on pipelined disks), then the RAM
/// tail. Removes the spill file on drop.
pub struct SpillDrain {
    disk: Option<Arc<NodeDisk>>,
    spill_rel: PathBuf,
    file: Option<super::pipeline::ByteReader>,
    ram: Vec<u8>,
    ram_pos: usize,
    remove_on_drop: bool,
}

impl SpillDrain {
    /// Read exactly `buf.len()` bytes; Ok(false) = clean EOF at a record
    /// boundary (no bytes read). Errors on partial reads.
    pub fn read_exact_or_eof(&mut self, buf: &mut [u8]) -> Result<bool> {
        let mut got = 0;
        if let Some(f) = self.file.as_mut() {
            got = f.read_fully(&mut buf[..])?;
            if got == buf.len() {
                return Ok(true);
            }
            // file exhausted; fall through to RAM
            self.file = None;
        }
        let want = buf.len() - got;
        let avail = self.ram.len() - self.ram_pos;
        if got == 0 && avail == 0 {
            return Ok(false);
        }
        if avail < want {
            return Err(crate::error::RoomyError::InvalidArg(
                "truncated record in spill buffer".into(),
            ));
        }
        buf[got..].copy_from_slice(&self.ram[self.ram_pos..self.ram_pos + want]);
        self.ram_pos += want;
        Ok(true)
    }
}

impl Drop for SpillDrain {
    fn drop(&mut self) {
        self.file = None; // close before removing (Windows-friendly habit)
        if self.remove_on_drop {
            if let Some(disk) = &self.disk {
                let _ = disk.remove(&self.spill_rel);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DiskPolicy;
    use crate::testutil::tmpdir;

    fn mkdisk(dir: &std::path::Path) -> Arc<NodeDisk> {
        Arc::new(NodeDisk::create(0, dir, DiskPolicy::unthrottled()).unwrap())
    }

    #[test]
    fn ram_only_roundtrip() {
        let t = tmpdir("spill_ram");
        let d = mkdisk(t.path());
        let mut b = SpillBuffer::new(d, "b.spill", 1 << 20);
        b.push(&[1, 2, 3]).unwrap();
        b.push(&[4, 5]).unwrap();
        assert_eq!(b.len_bytes(), 5);
        assert_eq!(b.spilled_bytes(), 0);
        let mut r = b.reader().unwrap();
        let mut buf = [0u8; 5];
        assert!(r.read_exact_or_eof(&mut buf).unwrap());
        assert_eq!(buf, [1, 2, 3, 4, 5]);
        assert!(!r.read_exact_or_eof(&mut [0u8; 1]).unwrap());
    }

    #[test]
    fn spills_past_threshold_and_replays_in_order() {
        let t = tmpdir("spill_order");
        let d = mkdisk(t.path());
        let mut b = SpillBuffer::new(d, "b.spill", 16);
        for i in 0u8..10 {
            b.push(&[i; 4]).unwrap();
        }
        assert!(b.spilled_bytes() > 0, "should have spilled");
        assert_eq!(b.len_bytes(), 40);
        assert!(b.ram_bytes() < 40, "ram stays bounded");

        let mut r = b.reader().unwrap();
        for i in 0u8..10 {
            let mut rec = [0u8; 4];
            assert!(r.read_exact_or_eof(&mut rec).unwrap());
            assert_eq!(rec, [i; 4], "record {i} out of order");
        }
        let mut rec = [0u8; 4];
        assert!(!r.read_exact_or_eof(&mut rec).unwrap());
    }

    #[test]
    fn record_spanning_spill_boundary() {
        let t = tmpdir("spill_span");
        let d = mkdisk(t.path());
        // Threshold 5: a 4-byte push then a 4-byte push spills at 8 bytes
        // total; reading 3-byte records crosses the file/RAM boundary.
        let mut b = SpillBuffer::new(d, "b.spill", 5);
        b.push(&[1, 2, 3, 4]).unwrap();
        b.push(&[5, 6, 7, 8]).unwrap(); // spill happens here (8 >= 5)
        b.push(&[9]).unwrap(); // stays in RAM
        let mut r = b.reader().unwrap();
        let mut rec = [0u8; 3];
        let mut all = vec![];
        while r.read_exact_or_eof(&mut rec).unwrap() {
            all.extend_from_slice(&rec);
        }
        assert_eq!(all, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn clear_resets_everything() {
        let t = tmpdir("spill_clear");
        let d = mkdisk(t.path());
        let mut b = SpillBuffer::new(d.clone(), "b.spill", 4);
        b.push(&[1; 8]).unwrap();
        assert!(b.spilled_bytes() > 0);
        b.clear().unwrap();
        assert!(b.is_empty());
        assert!(!d.exists("b.spill"));
        // reusable after clear
        b.push(&[2, 2]).unwrap();
        let mut r = b.reader().unwrap();
        let mut rec = [0u8; 2];
        assert!(r.read_exact_or_eof(&mut rec).unwrap());
        assert_eq!(rec, [2, 2]);
    }

    #[test]
    fn drain_replays_in_order_and_removes_spill_file() {
        let t = tmpdir("spill_drain");
        let d = mkdisk(t.path());
        let mut b = SpillBuffer::new(d.clone(), "b.spill", 16);
        for i in 0u8..10 {
            b.push(&[i; 4]).unwrap();
        }
        assert!(b.spilled_bytes() > 0);
        let mut drain = b.into_drain().unwrap();
        for i in 0u8..10 {
            let mut rec = [0u8; 4];
            assert!(drain.read_exact_or_eof(&mut rec).unwrap());
            assert_eq!(rec, [i; 4], "record {i} out of order");
        }
        assert!(!drain.read_exact_or_eof(&mut [0u8; 4]).unwrap());
        assert!(d.exists("b.spill"), "file lives while the drain does");
        drop(drain);
        assert!(!d.exists("b.spill"), "drop must remove the spill file");
    }

    #[test]
    fn abandoned_drain_still_removes_spill_file() {
        let t = tmpdir("spill_drain_abandon");
        let d = mkdisk(t.path());
        let mut b = SpillBuffer::new(d.clone(), "b.spill", 4);
        b.push(&[1; 8]).unwrap();
        let drain = b.into_drain().unwrap();
        drop(drain); // nothing read
        assert!(!d.exists("b.spill"));
    }

    #[test]
    fn ram_only_never_touches_disk() {
        let mut b = SpillBuffer::ram_only();
        for i in 0u8..100 {
            b.push(&[i; 8]).unwrap();
        }
        assert_eq!(b.spilled_bytes(), 0);
        assert_eq!(b.ram_bytes(), 800);
        b.spill().unwrap(); // no-op, not an error
        assert_eq!(b.spilled_bytes(), 0);
        let mut r = b.reader().unwrap();
        let mut rec = [0u8; 8];
        assert!(r.read_exact_or_eof(&mut rec).unwrap());
        assert_eq!(rec, [0; 8]);
        drop(r);
        let mut drain = b.into_drain().unwrap();
        let mut n = 0;
        while drain.read_exact_or_eof(&mut rec).unwrap() {
            n += 1;
        }
        assert_eq!(n, 100);
    }

    #[test]
    fn truncated_record_is_error() {
        let t = tmpdir("spill_trunc");
        let d = mkdisk(t.path());
        let mut b = SpillBuffer::new(d, "b.spill", 1 << 20);
        b.push(&[1, 2, 3]).unwrap();
        let mut r = b.reader().unwrap();
        let mut rec = [0u8; 2];
        assert!(r.read_exact_or_eof(&mut rec).unwrap());
        // one byte left, but we ask for two
        assert!(r.read_exact_or_eof(&mut rec).is_err());
    }
}
