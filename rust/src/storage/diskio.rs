//! Metered, optionally throttled access to one simulated node-local disk.
//!
//! [`NodeDisk`] is the only way the rest of the crate touches files. Every
//! read/write is counted into the node's [`IoStats`] and, when a
//! [`DiskPolicy`] sets bandwidth caps or a seek penalty, slowed down to
//! match — this is what lets the E1/E2 experiments reproduce the paper's
//! "disk is 50x slower than RAM, seeks are fatal" regime deterministically.

use std::fs::{self, File, OpenOptions};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::pipeline::{HintCache, IoService};
use crate::config::DiskPolicy;
use crate::error::{Result, RoomyError};
use crate::metrics::{IoStats, PipelineStats};

/// Identity of an open file's inode: `(device, inode)`. `(0, 0)` means
/// "unknown" and never matches anything — on non-Unix targets every id is
/// unknown, which simply disables the identity-based fast paths (prefetch
/// hint adoption, checkpoint digest reuse), never their correctness.
pub(crate) fn file_id_of(f: &File) -> (u64, u64) {
    match f.metadata() {
        Ok(m) => metadata_id(&m),
        Err(_) => (0, 0),
    }
}

/// Identity of the inode currently behind `path` (see [`file_id_of`]).
pub(crate) fn path_file_id(path: &Path) -> (u64, u64) {
    match fs::metadata(path) {
        Ok(m) => metadata_id(&m),
        Err(_) => (0, 0),
    }
}

#[cfg(unix)]
fn metadata_id(m: &fs::Metadata) -> (u64, u64) {
    use std::os::unix::fs::MetadataExt;
    (m.dev(), m.ino())
}

#[cfg(not(unix))]
fn metadata_id(_m: &fs::Metadata) -> (u64, u64) {
    (0, 0)
}

/// Buffered writer size. Large enough that the OS sees streaming writes.
const WRITE_BUF: usize = 1 << 20;
/// Buffered reader size.
const READ_BUF: usize = 1 << 20;

/// One simulated node-local disk rooted at a directory.
#[derive(Debug)]
pub struct NodeDisk {
    node: usize,
    root: PathBuf,
    policy: DiskPolicy,
    stats: Arc<IoStats>,
    /// Token-bucket state per direction: the instant at which the
    /// simulated device becomes free again. Real I/O time counts against
    /// the budget — a throttled disk delivers ≈ the configured bandwidth,
    /// not (configured ∥ host) in series. (§Perf P1.)
    read_free: Mutex<Option<Instant>>,
    write_free: Mutex<Option<Instant>>,
    /// Overlapped-I/O pipeline: buffer count per stream (0 = synchronous)
    /// and, when depth > 0, this node's I/O service lanes
    /// ([`crate::storage::pipeline`]).
    pipeline_depth: usize,
    /// Runtime-adjustable stream depth ([`crate::runtime::autotune`]):
    /// new streams circulate this many buffers. Clamped to
    /// `1..=pipeline_depth` — the service's existence is fixed at
    /// creation, so a depth-0 disk stays synchronous and an overlapped
    /// disk never exceeds its configured buffer budget.
    effective_depth: std::sync::atomic::AtomicUsize,
    io: Option<IoService>,
    pipe_stats: Arc<PipelineStats>,
    /// Cross-task prefetch hints warmed by the read lane, waiting for the
    /// scan that asked for them ([`crate::storage::pipeline`]). Bounded
    /// by the pipeline depth. Holds no `Arc<NodeDisk>` — a cycle here
    /// would keep the disk (and its service threads) alive forever.
    hints: HintCache,
}

impl NodeDisk {
    /// Create (and mkdir) a node disk rooted at `root`, with no I/O
    /// pipeline (all reads/writes synchronous).
    pub fn create(node: usize, root: impl Into<PathBuf>, policy: DiskPolicy) -> Result<Self> {
        Self::create_with_depth(node, root, policy, 0)
    }

    /// Create a node disk whose streams may overlap I/O with computation:
    /// `depth` chunk buffers per stream circulate through a per-node I/O
    /// service (spawned here when `depth > 0`, joined when the disk
    /// drops). Depth 0 is exactly [`NodeDisk::create`].
    pub fn create_with_depth(
        node: usize,
        root: impl Into<PathBuf>,
        policy: DiskPolicy,
        depth: usize,
    ) -> Result<Self> {
        let root = root.into();
        fs::create_dir_all(&root).map_err(|e| RoomyError::io(&root, e))?;
        let io = if depth > 0 { Some(IoService::spawn(node)?) } else { None };
        Ok(NodeDisk {
            node,
            root,
            policy,
            stats: Arc::new(IoStats::new()),
            read_free: Mutex::new(None),
            write_free: Mutex::new(None),
            pipeline_depth: depth,
            effective_depth: std::sync::atomic::AtomicUsize::new(depth),
            io,
            pipe_stats: Arc::new(PipelineStats::new()),
            hints: HintCache::new(depth),
        })
    }

    /// The prefetch-hint cache (crate-internal; sized by the pipeline
    /// depth).
    pub(crate) fn hints(&self) -> &HintCache {
        &self.hints
    }

    /// Post a cross-task prefetch hint: warm the first chunk of `rel`
    /// through this node's read-ahead lane so an upcoming scan of the
    /// same file finds its bytes already staged
    /// ([`crate::storage::pipeline`]). Best-effort and infallible: with
    /// no I/O service, a missing file, a duplicate hint, or a full cache
    /// (bounded by the pipeline depth) the hint is simply dropped. Hints
    /// never change what a scan reads — adoption is guarded by the
    /// file's (device, inode, length) identity — only when the bytes
    /// move.
    pub fn hint_prefetch(self: &Arc<Self>, rel: impl AsRef<Path>) {
        super::pipeline::post_hint(self, rel.as_ref());
    }

    /// Chunk buffers per pipelined stream as configured at creation
    /// (0 = synchronous I/O).
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Chunk buffers a *new* stream will circulate right now: the
    /// configured depth unless [`NodeDisk::set_effective_depth`]
    /// lowered/restored it between collectives. Equal to
    /// `pipeline_depth()` unless autotune is active.
    pub fn effective_depth(&self) -> usize {
        self.effective_depth.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Adjust the depth new streams use, clamped to
    /// `1..=pipeline_depth()`. A no-op on a synchronous (depth-0) disk —
    /// the service's existence cannot change after creation. Safe to
    /// call between collectives: depth only moves *when* bytes
    /// transfer, never what lands on disk (`tests/determinism.rs` pins
    /// bytes across depths).
    pub fn set_effective_depth(&self, depth: usize) {
        if self.pipeline_depth == 0 {
            return;
        }
        let clamped = depth.clamp(1, self.pipeline_depth);
        let prev = self
            .effective_depth
            .swap(clamped, std::sync::atomic::Ordering::Relaxed);
        if prev != clamped {
            crate::obs::trace::instant(
                crate::obs::trace::Kind::AutotuneDepth,
                "autotune.depth",
                Some(self.node),
                clamped as u64,
                0,
            );
        }
    }

    /// This node's I/O service lanes, if the pipeline is enabled.
    pub fn io_service(&self) -> Option<&IoService> {
        self.io.as_ref()
    }

    /// Read-ahead / write-behind counters for this disk.
    pub fn pipe_stats(&self) -> &Arc<PipelineStats> {
        &self.pipe_stats
    }

    /// Node index within the cluster.
    pub fn node(&self) -> usize {
        self.node
    }

    /// Root directory of this disk.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// I/O counters for this disk.
    pub fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    /// The performance model in force.
    pub fn policy(&self) -> DiskPolicy {
        self.policy
    }

    fn abs(&self, rel: impl AsRef<Path>) -> PathBuf {
        self.root.join(rel)
    }

    /// Charge one seek (file open / reposition) against the policy.
    fn charge_seek(&self) {
        self.stats.add_seek();
        if self.policy.seek_us > 0 {
            let d = Duration::from_micros(self.policy.seek_us);
            std::thread::sleep(d);
            self.stats.add_throttle(d);
        }
    }

    /// Token-bucket bandwidth charge: advance the device-free instant by
    /// `bytes / bps` from max(now, previous free) and sleep until then.
    /// Real I/O latency overlaps the budget instead of adding to it, and
    /// sub-millisecond debts are deferred (accumulated in the deadline)
    /// rather than slept — OS sleep granularity would otherwise inflate
    /// each small charge by ~0.1 ms and cap throughput below the model
    /// (§Perf P1).
    fn charge_bw(&self, bytes: u64, bps: u64, free: &Mutex<Option<Instant>>) {
        const MIN_SLEEP: Duration = Duration::from_millis(2);
        let dur = Duration::from_secs_f64(bytes as f64 / bps as f64);
        let deadline = {
            let mut g = free.lock().unwrap();
            let now = Instant::now();
            let start = g.map_or(now, |t| t.max(now));
            let deadline = start + dur;
            *g = Some(deadline);
            deadline
        };
        let now = Instant::now();
        if deadline > now {
            let wait = deadline - now;
            if wait >= MIN_SLEEP {
                std::thread::sleep(wait);
                self.stats.add_throttle(wait);
            }
        }
    }

    fn charge_read(&self, bytes: u64) {
        self.stats.add_read(bytes);
        if let Some(bps) = self.policy.read_bps {
            self.charge_bw(bytes, bps, &self.read_free);
        }
    }

    fn charge_write(&self, bytes: u64) {
        self.stats.add_write(bytes);
        if let Some(bps) = self.policy.write_bps {
            self.charge_bw(bytes, bps, &self.write_free);
        }
    }

    /// Open `rel` for writing, truncating. Parent dirs are created.
    pub fn create_file(&self, rel: impl AsRef<Path>) -> Result<MeteredWriter<'_>> {
        let path = self.abs(&rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).map_err(|e| RoomyError::io(dir, e))?;
        }
        let f = File::create(&path).map_err(|e| RoomyError::io(&path, e))?;
        self.charge_seek();
        Ok(MeteredWriter { disk: self, w: BufWriter::with_capacity(WRITE_BUF, f), path })
    }

    /// Open `rel` for appending (created if missing).
    pub fn append_file(&self, rel: impl AsRef<Path>) -> Result<MeteredWriter<'_>> {
        let path = self.abs(&rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).map_err(|e| RoomyError::io(dir, e))?;
        }
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| RoomyError::io(&path, e))?;
        self.charge_seek();
        Ok(MeteredWriter { disk: self, w: BufWriter::with_capacity(WRITE_BUF, f), path })
    }

    /// Open `rel` for streaming reads. Missing files are an error; use
    /// [`NodeDisk::exists`] to probe.
    pub fn open_file(&self, rel: impl AsRef<Path>) -> Result<MeteredReader<'_>> {
        let path = self.abs(&rel);
        let f = File::open(&path).map_err(|e| RoomyError::io(&path, e))?;
        self.charge_seek();
        Ok(MeteredReader { disk: self, r: BufReader::with_capacity(READ_BUF, f), path })
    }

    /// Like [`NodeDisk::open_file`] but the returned reader co-owns the
    /// disk, so it can outlive the borrow that created it (streaming-drain
    /// readers that move across ownership boundaries, e.g.
    /// [`crate::storage::buffer::SpillDrain`]).
    pub fn open_file_shared(self: &Arc<Self>, rel: impl AsRef<Path>) -> Result<SharedMeteredReader> {
        let path = self.abs(&rel);
        let f = File::open(&path).map_err(|e| RoomyError::io(&path, e))?;
        self.charge_seek();
        Ok(SharedMeteredReader {
            disk: Arc::clone(self),
            r: BufReader::with_capacity(READ_BUF, f),
            path,
        })
    }

    /// Like [`NodeDisk::create_file`] but the returned writer co-owns the
    /// disk, so it can move to the pipeline's write lane
    /// ([`crate::storage::pipeline`]).
    pub fn create_file_shared(self: &Arc<Self>, rel: impl AsRef<Path>) -> Result<SharedMeteredWriter> {
        let path = self.abs(&rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).map_err(|e| RoomyError::io(dir, e))?;
        }
        let f = File::create(&path).map_err(|e| RoomyError::io(&path, e))?;
        self.charge_seek();
        Ok(SharedMeteredWriter {
            disk: Arc::clone(self),
            w: BufWriter::with_capacity(WRITE_BUF, f),
            path,
        })
    }

    /// Like [`NodeDisk::append_file`] but the returned writer co-owns the
    /// disk (see [`NodeDisk::create_file_shared`]).
    pub fn append_file_shared(self: &Arc<Self>, rel: impl AsRef<Path>) -> Result<SharedMeteredWriter> {
        let path = self.abs(&rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).map_err(|e| RoomyError::io(dir, e))?;
        }
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| RoomyError::io(&path, e))?;
        self.charge_seek();
        Ok(SharedMeteredWriter {
            disk: Arc::clone(self),
            w: BufWriter::with_capacity(WRITE_BUF, f),
            path,
        })
    }

    /// Length of `rel` in bytes, or 0 if it does not exist.
    pub fn len(&self, rel: impl AsRef<Path>) -> u64 {
        fs::metadata(self.abs(rel)).map(|m| m.len()).unwrap_or(0)
    }

    /// Whether `rel` exists.
    pub fn exists(&self, rel: impl AsRef<Path>) -> bool {
        self.abs(rel).exists()
    }

    /// Delete `rel` if present.
    pub fn remove(&self, rel: impl AsRef<Path>) -> Result<()> {
        let path = self.abs(&rel);
        match fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(RoomyError::io(&path, e)),
        }
    }

    /// Rename within this disk.
    pub fn rename(&self, from: impl AsRef<Path>, to: impl AsRef<Path>) -> Result<()> {
        let (a, b) = (self.abs(&from), self.abs(&to));
        if let Some(dir) = b.parent() {
            fs::create_dir_all(dir).map_err(|e| RoomyError::io(dir, e))?;
        }
        fs::rename(&a, &b).map_err(|e| RoomyError::io(&a, e))
    }

    /// Remove a whole subdirectory tree (structure teardown).
    pub fn remove_dir(&self, rel: impl AsRef<Path>) -> Result<()> {
        let path = self.abs(&rel);
        match fs::remove_dir_all(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(RoomyError::io(&path, e)),
        }
    }

    /// Relative paths of files directly under `rel` (sorted).
    pub fn list(&self, rel: impl AsRef<Path>) -> Result<Vec<PathBuf>> {
        let dir = self.abs(&rel);
        let mut out = vec![];
        let iter = match fs::read_dir(&dir) {
            Ok(it) => it,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(RoomyError::io(&dir, e)),
        };
        for entry in iter {
            let entry = entry.map_err(|e| RoomyError::io(&dir, e))?;
            if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
                out.push(rel.as_ref().join(entry.file_name()));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Read the entire file into RAM (bucket loads — the unit Roomy sizes
    /// to fit in memory).
    pub fn read_all(&self, rel: impl AsRef<Path>) -> Result<Vec<u8>> {
        let mut r = self.open_file(&rel)?;
        let mut buf = Vec::with_capacity(self.len(&rel) as usize);
        r.read_to_end(&mut buf)?;
        Ok(buf)
    }

    /// Write `data` to `rel` atomically-enough (tmp + rename).
    pub fn write_all(&self, rel: impl AsRef<Path>, data: &[u8]) -> Result<()> {
        let tmp = rel.as_ref().with_extension("tmp");
        {
            let mut w = self.create_file(&tmp)?;
            w.write_bytes(data)?;
            w.finish()?;
        }
        self.rename(&tmp, rel)
    }
}

impl Drop for NodeDisk {
    /// Shut the I/O service down with the disk: queued jobs drain, both
    /// lane threads are joined, so no service thread outlives its node.
    /// Hints still warming drain with the queue; whatever sits in the
    /// hint cache afterwards was never consumed and is counted as waste.
    fn drop(&mut self) {
        if let Some(io) = &self.io {
            io.shutdown();
        }
        let unconsumed = self.hints.clear();
        if unconsumed > 0 {
            self.pipe_stats.add_hint_wastes(unconsumed);
        }
    }
}

/// Metered buffered writer; count/throttle happens at `write_bytes`.
pub struct MeteredWriter<'d> {
    disk: &'d NodeDisk,
    w: BufWriter<File>,
    path: PathBuf,
}

impl<'d> MeteredWriter<'d> {
    /// Write a full byte slice, metering it against the disk policy.
    pub fn write_bytes(&mut self, data: &[u8]) -> Result<()> {
        self.w.write_all(data).map_err(|e| RoomyError::io(&self.path, e))?;
        self.disk.charge_write(data.len() as u64);
        Ok(())
    }

    /// Flush buffers to the OS. Must be called before drop for durability;
    /// dropping without `finish` is fine for scratch files.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush().map_err(|e| RoomyError::io(&self.path, e))
    }

    /// Path being written (diagnostics).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Metered buffered reader.
pub struct MeteredReader<'d> {
    disk: &'d NodeDisk,
    r: BufReader<File>,
    path: PathBuf,
}

impl<'d> MeteredReader<'d> {
    /// Read up to `buf.len()` bytes; returns bytes read (0 = EOF).
    pub fn read_some(&mut self, buf: &mut [u8]) -> Result<usize> {
        let n = self.r.read(buf).map_err(|e| RoomyError::io(&self.path, e))?;
        if n > 0 {
            self.disk.charge_read(n as u64);
        }
        Ok(n)
    }

    /// Read exactly `buf.len()` bytes or fail.
    pub fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        self.r.read_exact(buf).map_err(|e| RoomyError::io(&self.path, e))?;
        self.disk.charge_read(buf.len() as u64);
        Ok(())
    }

    /// Fill `buf` as far as possible (loops over short reads); returns
    /// bytes read, which is < `buf.len()` only at EOF.
    pub fn read_fully(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut total = 0;
        while total < buf.len() {
            let n = self.r.read(&mut buf[total..]).map_err(|e| RoomyError::io(&self.path, e))?;
            if n == 0 {
                break;
            }
            total += n;
        }
        if total > 0 {
            self.disk.charge_read(total as u64);
        }
        Ok(total)
    }

    /// Read to end of file.
    pub fn read_to_end(&mut self, out: &mut Vec<u8>) -> Result<usize> {
        let n = self.r.read_to_end(out).map_err(|e| RoomyError::io(&self.path, e))?;
        if n > 0 {
            self.disk.charge_read(n as u64);
        }
        Ok(n)
    }

    /// Reposition (charged as a seek — random access is what Roomy avoids).
    pub fn seek_to(&mut self, offset: u64) -> Result<()> {
        self.r
            .seek(SeekFrom::Start(offset))
            .map_err(|e| RoomyError::io(&self.path, e))?;
        self.disk.charge_seek();
        Ok(())
    }

    /// Path being read (diagnostics).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Metered buffered reader that co-owns its [`NodeDisk`] (see
/// [`NodeDisk::open_file_shared`]). Only the streaming entry point is
/// provided — owned readers exist for FIFO drains, not random access.
pub struct SharedMeteredReader {
    disk: Arc<NodeDisk>,
    r: BufReader<File>,
    path: PathBuf,
}

impl SharedMeteredReader {
    /// Fill `buf` as far as possible (loops over short reads); returns
    /// bytes read, which is < `buf.len()` only at EOF.
    pub fn read_fully(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut total = 0;
        while total < buf.len() {
            let n = self.r.read(&mut buf[total..]).map_err(|e| RoomyError::io(&self.path, e))?;
            if n == 0 {
                break;
            }
            total += n;
        }
        if total > 0 {
            self.disk.charge_read(total as u64);
        }
        Ok(total)
    }

    /// Path being read (diagnostics).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// `(device, inode)` of the open file — pins the exact inode the
    /// bytes come from (prefetch-hint staleness checks).
    pub(crate) fn file_id(&self) -> (u64, u64) {
        file_id_of(self.r.get_ref())
    }

    /// Split off the disk handle, keeping the open file + position. The
    /// prefetch-hint cache lives *inside* the `NodeDisk` and must not own
    /// an `Arc` back to it, so it stores this instead.
    pub(crate) fn detach(self) -> DetachedReader {
        DetachedReader { r: self.r, path: self.path }
    }

    /// Rejoin a [`DetachedReader`] with its disk (hint adoption).
    pub(crate) fn reattach(disk: Arc<NodeDisk>, d: DetachedReader) -> SharedMeteredReader {
        SharedMeteredReader { disk, r: d.r, path: d.path }
    }
}

/// An open, positioned, metered-on-reattach file handle without its disk
/// — see [`SharedMeteredReader::detach`].
pub(crate) struct DetachedReader {
    r: BufReader<File>,
    path: PathBuf,
}

impl std::fmt::Debug for DetachedReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DetachedReader").field("path", &self.path).finish()
    }
}

/// Metered buffered writer that co-owns its [`NodeDisk`] (see
/// [`NodeDisk::create_file_shared`]) — the write-behind lane's owned
/// counterpart of [`MeteredWriter`].
pub struct SharedMeteredWriter {
    disk: Arc<NodeDisk>,
    w: BufWriter<File>,
    path: PathBuf,
}

impl SharedMeteredWriter {
    /// Write a full byte slice, metering it against the disk policy.
    pub fn write_bytes(&mut self, data: &[u8]) -> Result<()> {
        self.w.write_all(data).map_err(|e| RoomyError::io(&self.path, e))?;
        self.disk.charge_write(data.len() as u64);
        Ok(())
    }

    /// Flush buffers to the OS. Must be called before drop for durability;
    /// dropping without `finish` is fine for scratch files.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush().map_err(|e| RoomyError::io(&self.path, e))
    }

    /// Path being written (diagnostics).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::tmpdir;

    fn disk(dir: &Path) -> NodeDisk {
        NodeDisk::create(0, dir, DiskPolicy::unthrottled()).unwrap()
    }

    #[test]
    fn roundtrip_and_metering() {
        let t = tmpdir("diskio_rt");
        let d = disk(t.path());
        let mut w = d.create_file("a/b.dat").unwrap();
        w.write_bytes(b"hello ").unwrap();
        w.write_bytes(b"world").unwrap();
        w.finish().unwrap();

        let data = d.read_all("a/b.dat").unwrap();
        assert_eq!(&data, b"hello world");

        let s = d.stats().snapshot();
        assert_eq!(s.bytes_written, 11);
        assert_eq!(s.bytes_read, 11);
        assert!(s.seeks >= 2); // create + open
    }

    #[test]
    fn append_accumulates() {
        let t = tmpdir("diskio_app");
        let d = disk(t.path());
        for _ in 0..3 {
            let mut w = d.append_file("log.dat").unwrap();
            w.write_bytes(b"x").unwrap();
            w.finish().unwrap();
        }
        assert_eq!(d.len("log.dat"), 3);
    }

    #[test]
    fn missing_len_is_zero_and_remove_is_idempotent() {
        let t = tmpdir("diskio_missing");
        let d = disk(t.path());
        assert_eq!(d.len("nope.dat"), 0);
        assert!(!d.exists("nope.dat"));
        d.remove("nope.dat").unwrap();
        d.remove_dir("nodir").unwrap();
    }

    #[test]
    fn list_sorted() {
        let t = tmpdir("diskio_list");
        let d = disk(t.path());
        for name in ["s/c.dat", "s/a.dat", "s/b.dat"] {
            d.write_all(name, b"1").unwrap();
        }
        let files = d.list("s").unwrap();
        let names: Vec<_> =
            files.iter().map(|p| p.file_name().unwrap().to_str().unwrap()).collect();
        assert_eq!(names, vec!["a.dat", "b.dat", "c.dat"]);
        assert_eq!(d.list("absent").unwrap().len(), 0);
    }

    #[test]
    fn write_all_atomic_replaces() {
        let t = tmpdir("diskio_atomic");
        let d = disk(t.path());
        d.write_all("x.dat", b"old").unwrap();
        d.write_all("x.dat", b"newer").unwrap();
        assert_eq!(d.read_all("x.dat").unwrap(), b"newer");
        assert!(!d.exists("x.tmp"));
    }

    #[test]
    fn throttle_enforces_bandwidth() {
        let t = tmpdir("diskio_throttle");
        // 1 MB/s write cap; writing 100 KB must take >= ~90ms.
        let policy = DiskPolicy {
            read_bps: None,
            write_bps: Some(1_000_000),
            seek_us: 0,
        };
        let d = NodeDisk::create(0, t.path(), policy).unwrap();
        let data = vec![0u8; 100_000];
        let t0 = std::time::Instant::now();
        let mut w = d.create_file("slow.dat").unwrap();
        w.write_bytes(&data).unwrap();
        w.finish().unwrap();
        assert!(t0.elapsed().as_millis() >= 90, "throttle not applied");
        assert!(d.stats().snapshot().throttle_ns > 0);
    }

    #[test]
    fn seek_penalty_charged_on_open() {
        let t = tmpdir("diskio_seek");
        let policy = DiskPolicy { read_bps: None, write_bps: None, seek_us: 2_000 };
        let d = NodeDisk::create(0, t.path(), policy).unwrap();
        d.write_all("f.dat", b"abc").unwrap();
        let before = d.stats().snapshot().seeks;
        let _r = d.open_file("f.dat").unwrap();
        assert_eq!(d.stats().snapshot().seeks, before + 1);
    }

    #[test]
    fn shared_reader_outlives_borrow_and_meters() {
        let t = tmpdir("diskio_shared");
        let d = Arc::new(disk(t.path()));
        d.write_all("f.dat", &[3u8; 6]).unwrap();
        let mut r = {
            // the reader must survive this scope: it co-owns the disk
            let handle = Arc::clone(&d);
            handle.open_file_shared("f.dat").unwrap()
        };
        let mut buf = [0u8; 8];
        assert_eq!(r.read_fully(&mut buf).unwrap(), 6);
        assert_eq!(&buf[..6], &[3u8; 6]);
        assert_eq!(d.stats().snapshot().bytes_read, 6);
    }

    #[test]
    fn shared_writer_meters_and_persists() {
        let t = tmpdir("diskio_shared_w");
        let d = Arc::new(disk(t.path()));
        let mut w = d.create_file_shared("w/f.dat").unwrap();
        w.write_bytes(&[9u8; 12]).unwrap();
        w.finish().unwrap();
        assert_eq!(d.read_all("w/f.dat").unwrap(), vec![9u8; 12]);
        assert_eq!(d.stats().snapshot().bytes_written, 12);
        let mut a = d.append_file_shared("w/f.dat").unwrap();
        a.write_bytes(&[7u8; 4]).unwrap();
        a.finish().unwrap();
        assert_eq!(d.len("w/f.dat"), 16);
    }

    #[test]
    fn depth_zero_disk_has_no_service() {
        let t = tmpdir("diskio_depth0");
        let d = disk(t.path());
        assert_eq!(d.pipeline_depth(), 0);
        assert!(d.io_service().is_none());
    }

    #[test]
    fn effective_depth_clamps_and_ignores_sync_disks() {
        let t = tmpdir("diskio_effdepth");
        let sync = NodeDisk::create(0, t.path().join("n0"), DiskPolicy::unthrottled()).unwrap();
        sync.set_effective_depth(8);
        assert_eq!(sync.effective_depth(), 0, "sync disk depth is immutable");

        let piped =
            NodeDisk::create_with_depth(1, t.path().join("n1"), DiskPolicy::unthrottled(), 4)
                .unwrap();
        assert_eq!(piped.effective_depth(), 4);
        piped.set_effective_depth(2);
        assert_eq!(piped.effective_depth(), 2);
        piped.set_effective_depth(0); // clamps up to 1, never disables
        assert_eq!(piped.effective_depth(), 1);
        piped.set_effective_depth(99); // clamps down to the created depth
        assert_eq!(piped.effective_depth(), 4);
        assert_eq!(piped.pipeline_depth(), 4, "configured depth unchanged");
    }

    #[test]
    fn read_fully_handles_eof() {
        let t = tmpdir("diskio_fully");
        let d = disk(t.path());
        d.write_all("f.dat", &[7u8; 10]).unwrap();
        let mut r = d.open_file("f.dat").unwrap();
        let mut buf = [0u8; 64];
        let n = r.read_fully(&mut buf).unwrap();
        assert_eq!(n, 10);
        assert_eq!(&buf[..10], &[7u8; 10]);
    }

    #[test]
    fn seek_to_repositions() {
        let t = tmpdir("diskio_seekto");
        let d = disk(t.path());
        d.write_all("f.dat", b"0123456789").unwrap();
        let mut r = d.open_file("f.dat").unwrap();
        r.seek_to(5).unwrap();
        let mut buf = [0u8; 5];
        r.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"56789");
    }
}
