//! Fixed-size-record chunk files: the on-disk representation of Roomy
//! bucket payloads, shard files and shuffled op logs.
//!
//! Records are raw fixed-size byte strings; all typing lives above in
//! [`crate::roomy::element`]. Readers stream in large batches — Roomy
//! never random-accesses records inside a file.

use std::path::Path;

use super::diskio::{MeteredReader, MeteredWriter, NodeDisk};
use crate::error::{Result, RoomyError};

/// Streaming writer of fixed-size records.
pub struct RecordWriter<'d> {
    w: MeteredWriter<'d>,
    rec_size: usize,
    written: u64,
}

impl<'d> RecordWriter<'d> {
    /// Create/truncate `rel` on `disk` for records of `rec_size` bytes.
    pub fn create(disk: &'d NodeDisk, rel: impl AsRef<Path>, rec_size: usize) -> Result<Self> {
        assert!(rec_size > 0);
        Ok(RecordWriter { w: disk.create_file(rel)?, rec_size, written: 0 })
    }

    /// Open `rel` for appending records of `rec_size` bytes.
    pub fn append(disk: &'d NodeDisk, rel: impl AsRef<Path>, rec_size: usize) -> Result<Self> {
        assert!(rec_size > 0);
        Ok(RecordWriter { w: disk.append_file(rel)?, rec_size, written: 0 })
    }

    /// Write one record (must be exactly `rec_size` bytes).
    pub fn push(&mut self, rec: &[u8]) -> Result<()> {
        debug_assert_eq!(rec.len(), self.rec_size);
        self.w.write_bytes(rec)?;
        self.written += 1;
        Ok(())
    }

    /// Write a batch of concatenated records.
    pub fn push_batch(&mut self, recs: &[u8]) -> Result<()> {
        debug_assert_eq!(recs.len() % self.rec_size, 0);
        self.w.write_bytes(recs)?;
        self.written += (recs.len() / self.rec_size) as u64;
        Ok(())
    }

    /// Records written through this writer.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Flush and close.
    pub fn finish(self) -> Result<()> {
        self.w.finish()
    }
}

/// Streaming reader of fixed-size records.
pub struct RecordReader<'d> {
    r: MeteredReader<'d>,
    rec_size: usize,
}

impl<'d> RecordReader<'d> {
    /// Open `rel`; errors if the file length is not a record multiple.
    pub fn open(disk: &'d NodeDisk, rel: impl AsRef<Path>, rec_size: usize) -> Result<Self> {
        assert!(rec_size > 0);
        let len = disk.len(&rel);
        if !len.is_multiple_of(rec_size as u64) {
            return Err(RoomyError::InvalidArg(format!(
                "file {:?} length {len} is not a multiple of record size {rec_size}",
                rel.as_ref()
            )));
        }
        Ok(RecordReader { r: disk.open_file(rel)?, rec_size })
    }

    /// Record size in bytes.
    pub fn rec_size(&self) -> usize {
        self.rec_size
    }

    /// Read up to `max` records into `out` (cleared first). Returns the
    /// number of records read; 0 = EOF.
    pub fn read_batch(&mut self, out: &mut Vec<u8>, max: usize) -> Result<usize> {
        out.clear();
        out.resize(max * self.rec_size, 0);
        let n = self.r.read_fully(out)?;
        if n % self.rec_size != 0 {
            return Err(RoomyError::InvalidArg(format!(
                "truncated record ({n} bytes) in {:?}",
                self.r.path()
            )));
        }
        out.truncate(n);
        Ok(n / self.rec_size)
    }

    /// Read one record into `rec`; Ok(false) = EOF.
    pub fn read_one(&mut self, rec: &mut [u8]) -> Result<bool> {
        debug_assert_eq!(rec.len(), self.rec_size);
        let n = self.r.read_fully(rec)?;
        match n {
            0 => Ok(false),
            n if n == self.rec_size => Ok(true),
            n => Err(RoomyError::InvalidArg(format!(
                "truncated record ({n} bytes) in {:?}",
                self.r.path()
            ))),
        }
    }
}

/// Visit every record of `rel` in streaming batches of `batch` records.
pub fn for_each_record(
    disk: &NodeDisk,
    rel: impl AsRef<Path>,
    rec_size: usize,
    batch: usize,
    mut f: impl FnMut(&[u8]) -> Result<()>,
) -> Result<()> {
    if !disk.exists(&rel) {
        return Ok(());
    }
    let mut r = RecordReader::open(disk, rel, rec_size)?;
    let mut buf = Vec::new();
    loop {
        let n = r.read_batch(&mut buf, batch)?;
        if n == 0 {
            return Ok(());
        }
        for rec in buf.chunks_exact(rec_size) {
            f(rec)?;
        }
    }
}

/// Number of records in `rel` (0 for missing files).
pub fn record_count(disk: &NodeDisk, rel: impl AsRef<Path>, rec_size: usize) -> u64 {
    disk.len(rel) / rec_size as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DiskPolicy;
    use crate::testutil::tmpdir;

    fn disk(dir: &Path) -> NodeDisk {
        NodeDisk::create(0, dir, DiskPolicy::unthrottled()).unwrap()
    }

    #[test]
    fn write_read_roundtrip() {
        let t = tmpdir("chunk_rt");
        let d = disk(t.path());
        let mut w = RecordWriter::create(&d, "r.dat", 4).unwrap();
        for i in 0u32..100 {
            w.push(&i.to_le_bytes()).unwrap();
        }
        assert_eq!(w.written(), 100);
        w.finish().unwrap();

        let mut r = RecordReader::open(&d, "r.dat", 4).unwrap();
        let mut buf = Vec::new();
        let n = r.read_batch(&mut buf, 64).unwrap();
        assert_eq!(n, 64);
        assert_eq!(&buf[..4], &0u32.to_le_bytes());
        let n2 = r.read_batch(&mut buf, 64).unwrap();
        assert_eq!(n2, 36);
        assert_eq!(r.read_batch(&mut buf, 64).unwrap(), 0);
    }

    #[test]
    fn read_one_and_eof() {
        let t = tmpdir("chunk_one");
        let d = disk(t.path());
        let mut w = RecordWriter::create(&d, "r.dat", 8).unwrap();
        w.push(&7u64.to_le_bytes()).unwrap();
        w.finish().unwrap();
        let mut r = RecordReader::open(&d, "r.dat", 8).unwrap();
        let mut rec = [0u8; 8];
        assert!(r.read_one(&mut rec).unwrap());
        assert_eq!(u64::from_le_bytes(rec), 7);
        assert!(!r.read_one(&mut rec).unwrap());
    }

    #[test]
    fn rejects_misaligned_file() {
        let t = tmpdir("chunk_misaligned");
        let d = disk(t.path());
        d.write_all("bad.dat", &[1, 2, 3]).unwrap();
        assert!(RecordReader::open(&d, "bad.dat", 2).is_err());
    }

    #[test]
    fn for_each_streams_all() {
        let t = tmpdir("chunk_foreach");
        let d = disk(t.path());
        let mut w = RecordWriter::create(&d, "r.dat", 4).unwrap();
        for i in 0u32..1000 {
            w.push(&i.to_le_bytes()).unwrap();
        }
        w.finish().unwrap();
        let mut sum = 0u64;
        for_each_record(&d, "r.dat", 4, 128, |rec| {
            sum += u32::from_le_bytes(rec.try_into().unwrap()) as u64;
            Ok(())
        })
        .unwrap();
        assert_eq!(sum, 999 * 1000 / 2);
    }

    #[test]
    fn for_each_missing_is_empty() {
        let t = tmpdir("chunk_missing");
        let d = disk(t.path());
        let mut calls = 0;
        for_each_record(&d, "none.dat", 4, 16, |_| {
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 0);
        assert_eq!(record_count(&d, "none.dat", 4), 0);
    }

    #[test]
    fn push_batch_counts_records() {
        let t = tmpdir("chunk_batch");
        let d = disk(t.path());
        let mut w = RecordWriter::create(&d, "r.dat", 2).unwrap();
        w.push_batch(&[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(w.written(), 3);
        w.finish().unwrap();
        assert_eq!(record_count(&d, "r.dat", 2), 3);
    }
}
