//! Pooled scratch buffers and flat decode arenas for the record hot
//! path.
//!
//! The paper's premise is that disk bandwidth, not CPU, bounds a Roomy
//! computation — but a scan loop that allocates a fresh `Vec` per batch
//! (or per record) is allocator-bound on warm-cache runs. This module
//! gives every hot loop a process-wide pool of reusable byte buffers:
//!
//! - [`ScratchBuf`] — a scoped guard around a pooled `Vec<u8>`. Deref
//!   to `Vec<u8>`, so it drops into any `&mut Vec<u8>` call site.
//!   Checked back into the pool on drop — **including during panic
//!   unwind**, so a worker that dies mid-scan leaks nothing (the
//!   `outstanding` gauge in [`AllocStats`] returns to zero; tests
//!   assert this).
//! - [`take_chunk_vec`] / [`put_chunk_vec`] — a raw take/put pair for
//!   the I/O pipeline's chunk buffers, whose custody crosses threads
//!   through channels (a scoped guard cannot follow them). These count
//!   pool hits/misses and idle RAM but not loans.
//! - [`Arena`] — a flat byte arena the [`crate::Element`] batch codecs
//!   decode whole chunks into, so syncs and dup-elim merges iterate
//!   borrowed `&[u8]` slices instead of materializing per-record
//!   `Vec`s.
//!
//! The pool is deliberately small and bounded: at most [`POOL_WIDTH`]
//! idle buffers per class, each clamped to its class's byte ceiling, so
//! idle pooled RAM never exceeds [`pool_cap_bytes`] (tests assert the
//! high-water mark stays under it). Buffers that grew past the ceiling
//! while on loan are freed at check-in rather than parked.
//!
//! Pooling is invisible to on-disk bytes: a pooled buffer is cleared on
//! checkout and every consumer writes before reading, so determinism
//! pins are untouched.

use std::ops::{Deref, DerefMut};
use std::sync::{Mutex, OnceLock};

use crate::metrics::{AllocSnapshot, AllocStats};

/// Maximum idle buffers retained per class. Sized to the widest test
/// pool (4 workers): one buffer per concurrently scanning task.
pub const POOL_WIDTH: usize = 4;

/// Capacity ceiling for pooled chunk-class buffers — one pipeline
/// chunk. Larger check-ins are freed, not parked.
pub const CHUNK_CLASS_MAX: usize = super::pipeline::PIPE_CHUNK;

/// Capacity ceiling for pooled record-class buffers (scan batches,
/// record staging, sort-merge heads).
pub const RECORD_CLASS_MAX: usize = 128 * 1024;

/// Upper bound on idle RAM the pool may retain across both classes.
pub fn pool_cap_bytes() -> u64 {
    (POOL_WIDTH * (CHUNK_CLASS_MAX + RECORD_CLASS_MAX)) as u64
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Class {
    Chunk,
    Record,
}

impl Class {
    fn ceiling(self) -> usize {
        match self {
            Class::Chunk => CHUNK_CLASS_MAX,
            Class::Record => RECORD_CLASS_MAX,
        }
    }
}

/// Process-wide scratch buffer pool: two bounded free lists (chunk and
/// record class) plus the [`AllocStats`] they feed.
pub struct ScratchPool {
    chunks: Mutex<Vec<Vec<u8>>>,
    records: Mutex<Vec<Vec<u8>>>,
    stats: AllocStats,
}

impl ScratchPool {
    fn new() -> Self {
        ScratchPool {
            chunks: Mutex::new(Vec::new()),
            records: Mutex::new(Vec::new()),
            stats: AllocStats::new(),
        }
    }

    fn list(&self, class: Class) -> &Mutex<Vec<Vec<u8>>> {
        match class {
            Class::Chunk => &self.chunks,
            Class::Record => &self.records,
        }
    }

    /// Pop a pooled buffer (cleared, capacity intact) or allocate a
    /// fresh one with exactly `want` bytes reserved. A pooled buffer is
    /// only handed out when its capacity is at most 2 × `want` (for
    /// `want > 0`): k-way merges open streams with chunks scaled down
    /// by k precisely to bound their total RAM, and serving them
    /// full-size pooled buffers would undo that bound. Returns the vec
    /// and whether the pool served it.
    fn take_vec(&self, class: Class, want: usize) -> (Vec<u8>, bool) {
        let popped = {
            let mut list = self.list(class).lock().unwrap();
            let fits = list
                .last()
                .is_some_and(|b| want == 0 || b.capacity() <= want.saturating_mul(2));
            let v = if fits { list.pop() } else { None };
            let total: usize = list.iter().map(|b| b.capacity()).sum();
            self.stats.note_pooled(self.pooled_total(total));
            v
        };
        match popped {
            Some(mut v) => {
                v.clear();
                if v.capacity() < want {
                    v.reserve_exact(want - v.capacity());
                }
                (v, true)
            }
            None => (Vec::with_capacity(want), false),
        }
    }

    /// Park a buffer for reuse. Freed instead if the class list is full
    /// or the buffer outgrew its class ceiling. Returns whether it was
    /// kept.
    fn put_vec(&self, class: Class, mut v: Vec<u8>) -> bool {
        if v.capacity() == 0 || v.capacity() > class.ceiling() {
            return false;
        }
        v.clear();
        let mut list = self.list(class).lock().unwrap();
        let kept = if list.len() < POOL_WIDTH {
            list.push(v);
            true
        } else {
            false
        };
        let total: usize = list.iter().map(|b| b.capacity()).sum();
        self.stats.note_pooled(self.pooled_total(total));
        kept
    }

    /// Total idle bytes across both classes, given one class's total
    /// computed under its own lock (the other class is read afresh —
    /// momentary raciness only moves the gauge, never custody).
    fn pooled_total(&self, this_class_total: usize) -> u64 {
        // Called with exactly one class lock held; summing the other
        // class takes its lock briefly. Lock order is irrelevant: the
        // two locks are never both required by any single operation
        // except this read, which tries the other side non-blockingly.
        let other: usize = [&self.chunks, &self.records]
            .iter()
            .filter_map(|m| m.try_lock().ok())
            .map(|l| l.iter().map(|b| b.capacity()).sum::<usize>())
            .sum();
        (this_class_total + other) as u64
    }

    pub fn stats(&self) -> &AllocStats {
        &self.stats
    }
}

/// The process-wide pool instance.
pub fn global() -> &'static ScratchPool {
    static POOL: OnceLock<ScratchPool> = OnceLock::new();
    POOL.get_or_init(ScratchPool::new)
}

/// Snapshot of the global pool's [`AllocStats`].
pub fn alloc_snapshot() -> AllocSnapshot {
    global().stats().snapshot()
}

/// Reset the global pool's counters (gauges survive — see
/// [`AllocStats::reset`]).
pub fn reset_alloc_stats() {
    global().stats().reset();
}

/// Exclusive, quiesced view of the process-global pool gauges for
/// tests: takes a process-wide gate (scoped tests serialize against
/// each other), waits until every outstanding scratch loan has been
/// returned, then zeroes the counters. Assertions inside the scope see
/// only their own activity; [`MetricScope::settled`] re-quiesces before
/// the closing snapshot so loans held briefly by unrelated threads
/// cannot flake a balance check. Lets gauge tests share a test binary
/// instead of needing their own process.
pub fn metric_scope() -> MetricScope {
    static GATE: Mutex<()> = Mutex::new(());
    let gate = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    wait_loans_returned();
    reset_alloc_stats();
    MetricScope { _gate: gate }
}

/// See [`metric_scope`]. Dropping the guard releases the gate; counters
/// are left as the scope's activity set them (the next scope resets).
pub struct MetricScope {
    _gate: std::sync::MutexGuard<'static, ()>,
}

impl MetricScope {
    /// Snapshot taken at an instant when every outstanding loan
    /// (process-wide) was returned. A leaked guard keeps the gauge
    /// pinned above zero forever, so this panics after the timeout —
    /// returning at all *is* the no-leak assertion; tests on other
    /// threads merely delay it.
    pub fn settled(&self) -> AllocSnapshot {
        wait_loans_returned()
    }
}

/// Poll until one snapshot shows zero outstanding loans (loans are
/// scoped guards, so any healthy workload returns them promptly) and
/// return that snapshot. A generous timeout turns a genuine leak into a
/// clear failure instead of a hang.
fn wait_loans_returned() -> AllocSnapshot {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let snap = alloc_snapshot();
        if snap.outstanding == 0 && snap.outstanding_bytes == 0 {
            return snap;
        }
        if std::time::Instant::now() >= deadline {
            panic!(
                "metric_scope: {} scratch loans ({} bytes) still outstanding after 60s — \
                 a buffer guard leaked or a concurrent workload is wedged",
                snap.outstanding, snap.outstanding_bytes
            );
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

/// Scoped checkout of a chunk-class buffer with at least `want` bytes
/// reserved.
pub fn chunk_buf(want: usize) -> ScratchBuf {
    ScratchBuf::checkout(Class::Chunk, want)
}

/// Scoped checkout of a record-class buffer (scan batches, record
/// staging). Capacity is whatever the pool had parked; callers resize
/// as needed.
pub fn record_buf() -> ScratchBuf {
    ScratchBuf::checkout(Class::Record, 0)
}

/// Raw checkout of a chunk buffer for custody that crosses threads
/// (pipeline chunk circulation). Pair with [`put_chunk_vec`]; counts
/// hits/misses but not loans.
pub fn take_chunk_vec(want: usize) -> Vec<u8> {
    let pool = global();
    let (v, hit) = pool.take_vec(Class::Chunk, want);
    pool.stats.on_checkout(v.capacity() as u64, hit, false);
    v
}

/// Raw check-in of a chunk buffer taken with [`take_chunk_vec`] (or of
/// a stream buffer whose circulation has ended). Zero-capacity vecs are
/// ignored — they carry no allocation worth counting.
pub fn put_chunk_vec(v: Vec<u8>) {
    if v.capacity() == 0 {
        return;
    }
    let pool = global();
    let cap = v.capacity() as u64;
    let kept = pool.put_vec(Class::Chunk, v);
    pool.stats.on_checkin(cap, kept, false);
}

/// A pooled `Vec<u8>` on loan from the global [`ScratchPool`]. Derefs
/// to `Vec<u8>`; checked back in on drop (panic-safe).
pub struct ScratchBuf {
    buf: Vec<u8>,
    charged: usize,
    class: Class,
}

impl ScratchBuf {
    fn checkout(class: Class, want: usize) -> ScratchBuf {
        let pool = global();
        let (buf, hit) = pool.take_vec(class, want);
        let charged = buf.capacity();
        pool.stats.on_checkout(charged as u64, hit, true);
        ScratchBuf { buf, charged, class }
    }
}

impl Deref for ScratchBuf {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl DerefMut for ScratchBuf {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for ScratchBuf {
    fn drop(&mut self) {
        let pool = global();
        let v = std::mem::take(&mut self.buf);
        let cap = v.capacity();
        if cap > self.charged {
            // Grew while on loan: charge the growth so the release
            // below balances the gauge.
            pool.stats.on_grow((cap - self.charged) as u64);
        }
        // The gauge holds max(cap, charged): `charged` from checkout,
        // topped up to `cap` just above if the buffer grew. (cap <
        // charged happens when a caller moved the allocation out with
        // mem::take — release what was charged.)
        let release = cap.max(self.charged) as u64;
        let kept = pool.put_vec(self.class, v);
        pool.stats.on_checkin(release, kept, true);
    }
}

/// A flat byte arena for batch record decode: one backing buffer,
/// records laid end to end, iterated as borrowed `&[u8]` slices. The
/// backing store is itself a pooled scratch buffer, so arenas recycle
/// like everything else.
pub struct Arena {
    buf: ScratchBuf,
    rec: usize,
}

impl Arena {
    /// A fresh arena for fixed-size records of `rec` bytes.
    pub fn new(rec: usize) -> Arena {
        assert!(rec > 0, "arena record size must be non-zero");
        Arena { buf: chunk_buf(0), rec }
    }

    /// Record size this arena was built for.
    pub fn rec_size(&self) -> usize {
        self.rec
    }

    /// Forget all decoded records, keeping capacity.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Append raw record bytes (`bytes.len()` must be a whole number of
    /// records). Charges [`AllocStats::add_arena_bytes`].
    pub fn extend_raw(&mut self, bytes: &[u8]) {
        debug_assert_eq!(bytes.len() % self.rec, 0, "arena fed a partial record");
        self.buf.extend_from_slice(bytes);
        global().stats().add_arena_bytes(bytes.len() as u64);
    }

    /// Append one record's bytes.
    pub fn push_record(&mut self, bytes: &[u8]) {
        debug_assert_eq!(bytes.len(), self.rec, "arena fed a wrong-size record");
        self.buf.extend_from_slice(bytes);
        global().stats().add_arena_bytes(bytes.len() as u64);
    }

    /// Number of whole records currently held.
    pub fn len(&self) -> usize {
        self.buf.len() / self.rec
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Borrow record `i`.
    pub fn get(&self, i: usize) -> &[u8] {
        &self.buf[i * self.rec..(i + 1) * self.rec]
    }

    /// Iterate all records as borrowed slices.
    pub fn iter(&self) -> std::slice::ChunksExact<'_, u8> {
        self.buf.chunks_exact(self.rec)
    }

    /// The whole arena as one contiguous byte slice.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Sort the records lexicographically in place (fixed-size records
    /// compare bytewise, which is how every sorted structure orders
    /// them). Stable, allocation-free beyond a permutation vector.
    pub fn sort_records(&mut self) {
        let n = self.len();
        if n <= 1 {
            return;
        }
        let rec = self.rec;
        let mut order: Vec<usize> = (0..n).collect();
        {
            let bytes: &[u8] = &self.buf;
            order.sort_by(|&a, &b| bytes[a * rec..(a + 1) * rec].cmp(&bytes[b * rec..(b + 1) * rec]));
        }
        let mut sorted = chunk_buf(self.buf.len());
        for &i in &order {
            sorted.extend_from_slice(&self.buf[i * rec..(i + 1) * rec]);
        }
        std::mem::swap(&mut *self.buf, &mut *sorted);
    }

    /// Keep only records for which `keep` returns true, compacting in
    /// place (order preserved, no allocation).
    pub fn retain(&mut self, mut keep: impl FnMut(&[u8]) -> bool) {
        let rec = self.rec;
        let len = self.buf.len();
        let (mut read, mut write) = (0usize, 0usize);
        while read < len {
            if keep(&self.buf[read..read + rec]) {
                if write != read {
                    self.buf.copy_within(read..read + rec, write);
                }
                write += rec;
            }
            read += rec;
        }
        self.buf.truncate(write);
    }

    /// Collapse runs of records whose leading `prefix` bytes are equal,
    /// keeping the first record of each run (arena must be sorted).
    /// With a verdict byte stored after the key, the record that sorts
    /// first in its run carries the winning verdict.
    pub fn dedup_by_prefix(&mut self, prefix: usize) {
        assert!(prefix <= self.rec, "dedup prefix exceeds record size");
        let rec = self.rec;
        let len = self.buf.len();
        let (mut read, mut write) = (0usize, 0usize);
        while read < len {
            let dup = write > 0
                && self.buf[write - rec..write - rec + prefix]
                    == self.buf[read..read + prefix];
            if !dup {
                if write != read {
                    self.buf.copy_within(read..read + rec, write);
                }
                write += rec;
            }
            read += rec;
        }
        self.buf.truncate(write);
    }

    /// Binary-search for a record equal to `needle` (arena must be
    /// sorted). Returns whether it is present.
    pub fn contains_sorted(&self, needle: &[u8]) -> bool {
        debug_assert_eq!(needle.len(), self.rec);
        let n = self.len();
        let (mut lo, mut hi) = (0usize, n);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            match self.get(mid).cmp(needle) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => return true,
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_checkout_reuses_and_balances_gauges() {
        let before = alloc_snapshot();
        {
            let mut b = record_buf();
            b.extend_from_slice(&[1, 2, 3]);
        }
        // The freed buffer must be served back on the next checkout.
        let b2 = record_buf();
        assert!(b2.capacity() >= 3);
        drop(b2);
        let after = alloc_snapshot();
        assert_eq!(after.outstanding, before.outstanding);
        assert_eq!(after.outstanding_bytes, before.outstanding_bytes);
        assert!(after.pool_hits > before.pool_hits);
    }

    #[test]
    fn pool_never_retains_more_than_cap() {
        // Check in far more buffers than the pool width; idle RAM must
        // stay bounded.
        for _ in 0..4 * POOL_WIDTH {
            let mut b = chunk_buf(CHUNK_CLASS_MAX);
            b.push(0);
        }
        let snap = alloc_snapshot();
        assert!(
            snap.peak_pooled_bytes <= pool_cap_bytes(),
            "pooled {} > cap {}",
            snap.peak_pooled_bytes,
            pool_cap_bytes()
        );
    }

    #[test]
    fn oversized_buffers_are_freed_not_parked() {
        let mut b = record_buf();
        b.resize(RECORD_CLASS_MAX * 2, 0);
        drop(b);
        let snap = alloc_snapshot();
        assert!(snap.pooled_bytes <= pool_cap_bytes());
    }

    #[test]
    fn raw_take_put_round_trips() {
        let v = take_chunk_vec(1024);
        assert!(v.capacity() >= 1024);
        put_chunk_vec(v);
        let v2 = take_chunk_vec(512);
        assert!(v2.capacity() >= 512);
        put_chunk_vec(v2);
    }

    #[test]
    fn guard_drop_runs_during_unwind() {
        let before = alloc_snapshot();
        let r = std::panic::catch_unwind(|| {
            let mut b = record_buf();
            b.push(7);
            panic!("boom");
        });
        assert!(r.is_err());
        let after = alloc_snapshot();
        assert_eq!(after.outstanding, before.outstanding);
    }

    #[test]
    fn arena_roundtrip_sort_and_search() {
        let mut a = Arena::new(4);
        a.extend_raw(&[9, 9, 9, 9, 1, 1, 1, 1, 5, 5, 5, 5]);
        assert_eq!(a.len(), 3);
        a.sort_records();
        assert_eq!(a.get(0), &[1, 1, 1, 1]);
        assert_eq!(a.get(1), &[5, 5, 5, 5]);
        assert_eq!(a.get(2), &[9, 9, 9, 9]);
        assert!(a.contains_sorted(&[5, 5, 5, 5]));
        assert!(!a.contains_sorted(&[0, 0, 0, 0]));
        let collected: Vec<&[u8]> = a.iter().collect();
        assert_eq!(collected.len(), 3);
        a.clear();
        assert!(a.is_empty());
    }

    #[test]
    fn arena_retain_and_prefix_dedup() {
        // records = 2-byte key + 1 verdict byte; remove (0) sorts first
        let mut a = Arena::new(3);
        for rec in [[2u8, 0, 1], [1, 0, 1], [2, 0, 0], [3, 0, 1], [2, 0, 1]] {
            a.push_record(&rec);
        }
        a.sort_records();
        a.dedup_by_prefix(2);
        assert_eq!(a.len(), 3);
        assert_eq!(a.get(0), &[1, 0, 1]); // key 1: add
        assert_eq!(a.get(1), &[2, 0, 0]); // key 2: remove dominates
        assert_eq!(a.get(2), &[3, 0, 1]); // key 3: add
        a.retain(|rec| rec[2] == 1);
        assert_eq!(a.len(), 2);
        assert_eq!(a.get(0), &[1, 0, 1]);
        assert_eq!(a.get(1), &[3, 0, 1]);
    }
}
