//! Durable checkpoint/restart: atomic snapshots of named sets of Roomy
//! structures, restorable into a fresh session.
//!
//! Roomy's flagship computations run for days with all state on disk, yet
//! a crash used to lose everything. This module makes the on-disk state
//! *durable*: a [`CheckpointManager`] snapshots any set of structures
//! (anything implementing [`Checkpointable`]) into a **versioned,
//! digest-validated checkpoint directory** and restores them — bytes,
//! size counters, sorted flags, bit-array histograms — into a fresh
//! [`Roomy`](crate::Roomy) session via the typed
//! `Roomy::restored_*` constructors.
//!
//! ## On-disk layout
//!
//! ```text
//! <checkpoint root>/<name>/MANIFEST            versioned manifest (self-digested)
//! <checkpoint root>/<name>/node<K>/<dir>/<f>   snapshotted bucket/shard files
//! <checkpoint root>/<name>.staging/...         in-progress save (never read)
//! <checkpoint root>/<name>.prev/...            previous checkpoint during commit
//! ```
//!
//! The checkpoint root defaults to `<root>/checkpoints/`, a **sibling** of
//! the per-node disk directories — outside every `node<K>/tmp/` scratch
//! subtree the cluster purges at bring-up, so checkpoints survive crashed
//! runs and restarts ([`Cluster::checkpoint_root`]).
//!
//! ## Atomicity (staging → rename, as in fold's CHECKPOINT_DESIGN)
//!
//! `save` writes everything — snapshot files first, manifest last — under
//! `<name>.staging/`, then commits:
//!
//! 1. remove any stale `<name>.prev`;
//! 2. rename the live `<name>` (if any) to `<name>.prev`;
//! 3. rename `<name>.staging` to `<name>`;
//! 4. remove `<name>.prev`.
//!
//! A crash at any point leaves either the old or the new checkpoint fully
//! intact, never a torn one: during staging the live directory is
//! untouched; between steps 2 and 3 the old checkpoint survives as
//! `.prev`, which [`CheckpointManager::restore`] falls back to when the
//! live directory is missing; after step 3 the new checkpoint is
//! complete. Stale `.staging`/`.prev` directories are cleaned up by the
//! next save.
//!
//! ## Validation
//!
//! The manifest records, per snapshotted file, its length and an FNV-1a
//! digest, plus a digest of the manifest text itself. `restore` re-reads
//! every file and refuses (typed [`RoomyError::Checkpoint`]) if a single
//! byte differs — a flipped bit in a bucket file or a manifest field is
//! caught before any state reaches the session.
//!
//! ## Hardlink where possible
//!
//! Structures whose files are only ever replaced whole (tmp + rename) —
//! arrays, bit arrays, hash tables, native sets — are snapshotted by
//! `hard_link` when the checkpoint root shares their filesystem, falling
//! back to a streaming copy otherwise. `RoomyList` shards are *appended
//! to in place* by `sync`/`add_all`, so they are always copied
//! ([`StructMeta::appendable`]) — a hardlinked list shard would let the
//! next level's appends reach back into the committed checkpoint.
//! [`crate::metrics::CheckpointStats`] counts both paths.
//!
//! ## Quiescence
//!
//! `save` snapshots on-disk bytes plus in-RAM counters; it must run
//! between collectives (no concurrent `sync`/`map` on the snapshotted
//! structures) and refuses structures with pending delayed ops. The
//! resumable BFS drivers ([`crate::constructs::bfs`]) call it at level
//! boundaries, where both hold by construction.

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use super::diskio::{path_file_id, NodeDisk};
use super::pipeline::ByteReader;
use crate::cluster::Cluster;
use crate::error::{Result, RoomyError};
use crate::metrics::CheckpointStats;
use crate::obs::trace;

/// Manifest format version; bumped on incompatible layout changes.
pub const MANIFEST_VERSION: u32 = 1;

/// Manifest file name inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "MANIFEST";

/// Streaming chunk for digest/copy passes.
const COPY_CHUNK: usize = 256 * 1024;

fn ckpt_err(msg: impl Into<String>) -> RoomyError {
    RoomyError::Checkpoint(msg.into())
}

// ---------------------------------------------------------------------
// FNV-1a digests
// ---------------------------------------------------------------------

/// Streaming FNV-1a 64 — the crate-local digest (no external deps).
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf29ce484222325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.0 = h;
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut f = Fnv64::new();
    f.update(bytes);
    f.finish()
}

// ---------------------------------------------------------------------
// Structure metadata
// ---------------------------------------------------------------------

/// Which Roomy structure a checkpointed entry reconstructs into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StructKind {
    Array,
    BitArray,
    HashTable,
    List,
    Set,
}

impl StructKind {
    fn as_str(&self) -> &'static str {
        match self {
            StructKind::Array => "array",
            StructKind::BitArray => "bitarray",
            StructKind::HashTable => "hashtable",
            StructKind::List => "list",
            StructKind::Set => "set",
        }
    }

    fn parse(s: &str) -> Result<StructKind> {
        Ok(match s {
            "array" => StructKind::Array,
            "bitarray" => StructKind::BitArray,
            "hashtable" => StructKind::HashTable,
            "list" => StructKind::List,
            "set" => StructKind::Set,
            other => return Err(ckpt_err(format!("unknown structure kind {other:?}"))),
        })
    }
}

/// Persistent identity + reconstruction metadata for one structure: the
/// part of a structure's state that lives in RAM (size counters, sorted
/// flag, histogram) plus enough layout information (kind, record size,
/// directory) to validate a typed re-open.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StructMeta {
    pub kind: StructKind,
    /// Structure name (claimed again on restore).
    pub name: String,
    /// On-disk directory under each node root (e.g. `rl_pancake_all`).
    pub dir: String,
    /// Record size in bytes (key + value for hash tables; 0 for bit
    /// arrays, whose buckets are packed).
    pub rec_size: usize,
    /// Key size in bytes (hash tables only, else 0).
    pub key_size: usize,
    /// Element count for arrays / bit arrays (fixed at creation).
    pub len: u64,
    /// Element count for lists / tables / sets (the in-RAM counter).
    pub size: u64,
    /// Bits per element (bit arrays only, else 0).
    pub bits: u8,
    /// Whether every shard is currently sorted (lists only).
    pub sorted: bool,
    /// True if the structure mutates its files by appending in place
    /// (lists): snapshot/restore must copy these files, never hardlink.
    pub appendable: bool,
    /// Per-value histogram (bit arrays only; `counts[v]` = elements = v).
    pub counts: Vec<u64>,
}

/// A structure the [`CheckpointManager`] can snapshot. Implemented by all
/// five Roomy structures.
pub trait Checkpointable {
    /// Identity + reconstruction metadata at snapshot time.
    fn ckpt_meta(&self) -> StructMeta;

    /// Staged-but-unsynced delayed-op bytes. Must be 0 at snapshot time:
    /// staged ops live partly in RAM, so a snapshot taken with pending
    /// ops could not be restored faithfully.
    fn ckpt_pending(&self) -> u64;
}

// ---------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------

/// One snapshotted file: which node it belongs to, its path relative to
/// that node's root, and the validation pair (length, digest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestFile {
    pub node: usize,
    pub rel: String,
    pub len: u64,
    pub digest: u64,
}

/// Parsed checkpoint manifest: cluster geometry, per-structure metadata,
/// per-file validation entries, and free-form application state (the
/// resumable BFS drivers store their level counter and profile here).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub version: u32,
    pub workers: usize,
    pub nbuckets: u32,
    pub structs: Vec<StructMeta>,
    pub files: Vec<ManifestFile>,
    pub app: Vec<(String, String)>,
}

impl Manifest {
    /// Metadata for structure `name`, if present.
    pub fn meta(&self, name: &str) -> Option<&StructMeta> {
        self.structs.iter().find(|m| m.name == name)
    }

    /// Application-state value for `key`, if present.
    pub fn app(&self, key: &str) -> Option<&str> {
        self.app.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Sorted `(node, rel, len, digest)` rows — the byte-identity
    /// currency the resume tests compare across runs.
    pub fn file_digests(&self) -> Vec<(usize, String, u64, u64)> {
        let mut rows: Vec<_> = self
            .files
            .iter()
            .map(|f| (f.node, f.rel.clone(), f.len, f.digest))
            .collect();
        rows.sort();
        rows
    }

    /// Serialize to the on-disk text format, self-digest line last.
    fn encode(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("roomy-checkpoint v{}\n", self.version));
        s.push_str(&format!("cluster {} {}\n", self.workers, self.nbuckets));
        for m in &self.structs {
            let counts = if m.counts.is_empty() {
                "-".to_string()
            } else {
                m.counts.iter().map(u64::to_string).collect::<Vec<_>>().join(",")
            };
            s.push_str(&format!(
                "struct {} {} {} {} {} {} {} {} {} {} {counts}\n",
                m.kind.as_str(),
                m.name,
                m.dir,
                m.rec_size,
                m.key_size,
                m.len,
                m.size,
                m.bits,
                m.sorted as u8,
                m.appendable as u8,
            ));
        }
        for f in &self.files {
            s.push_str(&format!("file {} {} {:016x} {}\n", f.node, f.len, f.digest, f.rel));
        }
        for (k, v) in &self.app {
            s.push_str(&format!("app {k} {v}\n"));
        }
        s.push_str(&format!("digest {:016x}\n", fnv64(s.as_bytes())));
        s
    }

    /// Parse and validate the self-digest; any corruption — a flipped
    /// byte in any field — fails the digest check. The digest is checked
    /// over **raw bytes** before any UTF-8 interpretation, so corruption
    /// that produces invalid UTF-8 (a set high bit) is still the typed
    /// checkpoint error, never an I/O decode failure.
    fn decode(raw: &[u8]) -> Result<Manifest> {
        const NEEDLE: &[u8] = b"digest ";
        let at = raw
            .windows(NEEDLE.len())
            .rposition(|w| w == NEEDLE)
            .ok_or_else(|| ckpt_err("manifest missing its digest line"))?;
        let (body, tail) = raw.split_at(at);
        let want = std::str::from_utf8(tail)
            .ok()
            .and_then(|t| t.trim().strip_prefix("digest "))
            .and_then(|h| u64::from_str_radix(h.trim(), 16).ok())
            .ok_or_else(|| ckpt_err("manifest digest line corrupted"))?;
        if fnv64(body) != want {
            return Err(ckpt_err("manifest digest mismatch: manifest corrupted"));
        }
        // The digest matched, so the body is the bytes we wrote — which
        // were valid UTF-8; this conversion is a belt-and-braces check.
        let body = std::str::from_utf8(body)
            .map_err(|_| ckpt_err("manifest digest matched but body is not UTF-8"))?;

        let mut lines = body.lines();
        let head = lines.next().ok_or_else(|| ckpt_err("empty manifest"))?;
        let version: u32 = head
            .strip_prefix("roomy-checkpoint v")
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| ckpt_err(format!("bad manifest header {head:?}")))?;
        if version != MANIFEST_VERSION {
            return Err(ckpt_err(format!(
                "manifest version {version} unsupported (this build reads v{MANIFEST_VERSION})"
            )));
        }
        let mut m = Manifest {
            version,
            workers: 0,
            nbuckets: 0,
            structs: Vec::new(),
            files: Vec::new(),
            app: Vec::new(),
        };
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let bad = || ckpt_err(format!("bad manifest line {line:?}"));
            let mut parts = line.splitn(2, ' ');
            let tag = parts.next().ok_or_else(bad)?;
            let rest = parts.next().ok_or_else(bad)?;
            match tag {
                "cluster" => {
                    let mut it = rest.split(' ');
                    m.workers = it.next().and_then(|v| v.parse().ok()).ok_or_else(bad)?;
                    m.nbuckets = it.next().and_then(|v| v.parse().ok()).ok_or_else(bad)?;
                }
                "struct" => {
                    let f: Vec<&str> = rest.split(' ').collect();
                    if f.len() != 11 {
                        return Err(bad());
                    }
                    let counts = if f[10] == "-" {
                        Vec::new()
                    } else {
                        f[10]
                            .split(',')
                            .map(|v| v.parse::<u64>().map_err(|_| bad()))
                            .collect::<Result<Vec<u64>>>()?
                    };
                    m.structs.push(StructMeta {
                        kind: StructKind::parse(f[0])?,
                        name: f[1].to_string(),
                        dir: f[2].to_string(),
                        rec_size: f[3].parse().map_err(|_| bad())?,
                        key_size: f[4].parse().map_err(|_| bad())?,
                        len: f[5].parse().map_err(|_| bad())?,
                        size: f[6].parse().map_err(|_| bad())?,
                        bits: f[7].parse().map_err(|_| bad())?,
                        sorted: f[8] == "1",
                        appendable: f[9] == "1",
                        counts,
                    });
                }
                "file" => {
                    let f: Vec<&str> = rest.splitn(4, ' ').collect();
                    if f.len() != 4 {
                        return Err(bad());
                    }
                    m.files.push(ManifestFile {
                        node: f[0].parse().map_err(|_| bad())?,
                        len: f[1].parse().map_err(|_| bad())?,
                        digest: u64::from_str_radix(f[2], 16).map_err(|_| bad())?,
                        rel: f[3].to_string(),
                    });
                }
                "app" => {
                    let mut it = rest.splitn(2, ' ');
                    let k = it.next().ok_or_else(bad)?.to_string();
                    let v = it.next().unwrap_or("").to_string();
                    m.app.push((k, v));
                }
                _ => return Err(bad()),
            }
        }
        Ok(m)
    }
}

/// What one `save` did (per-call view of the cumulative
/// [`CheckpointStats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SaveReport {
    pub files: u64,
    pub bytes: u64,
    pub linked: u64,
    pub copied: u64,
    /// Hardlinked files whose digest was reused from the prior manifest
    /// (unchanged (inode, length) — no re-read; always ≤ `linked`).
    pub reused: u64,
    pub wall_secs: f64,
}

/// A validated, restored checkpoint: its files are back in the node
/// directories; hand this to the typed `Roomy::restored_*` constructors
/// to re-open the structures.
#[derive(Debug)]
pub struct Restored {
    manifest: Manifest,
}

impl Restored {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Application-state value for `key`.
    pub fn app(&self, key: &str) -> Option<&str> {
        self.manifest.app(key)
    }

    /// Metadata for `name`, required to be of `kind`.
    pub fn require(&self, kind: StructKind, name: &str) -> Result<&StructMeta> {
        let m = self
            .manifest
            .meta(name)
            .ok_or_else(|| ckpt_err(format!("checkpoint holds no structure named {name:?}")))?;
        if m.kind != kind {
            return Err(ckpt_err(format!(
                "structure {name:?} was checkpointed as {:?}, not {kind:?}",
                m.kind
            )));
        }
        Ok(m)
    }
}

// ---------------------------------------------------------------------
// Manager
// ---------------------------------------------------------------------

/// Atomic snapshots of named sets of structures over one cluster.
pub struct CheckpointManager {
    cluster: Arc<Cluster>,
    root: PathBuf,
    stats: Arc<CheckpointStats>,
}

impl CheckpointManager {
    /// Manager rooted at the cluster's checkpoint root (created here).
    pub fn new(cluster: &Arc<Cluster>) -> Result<CheckpointManager> {
        let root = cluster.checkpoint_root().to_path_buf();
        fs::create_dir_all(&root).map_err(|e| RoomyError::io(&root, e))?;
        Ok(CheckpointManager {
            cluster: Arc::clone(cluster),
            root,
            // Counters live on the cluster so every manager over it (and
            // `Roomy::report()`/`report_json()`) sees one shared ledger.
            stats: Arc::clone(cluster.checkpoint_stats()),
        })
    }

    /// The checkpoint root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Cumulative save/restore counters (shared cluster-wide).
    pub fn stats(&self) -> &Arc<CheckpointStats> {
        &self.stats
    }

    fn live_dir(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    fn staging_dir(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.staging"))
    }

    fn prev_dir(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.prev"))
    }

    fn validate_name(name: &str) -> Result<()> {
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(ckpt_err(format!(
                "checkpoint name {name:?} must be non-empty [A-Za-z0-9_-]"
            )));
        }
        Ok(())
    }

    /// The directory a restore would read: the live checkpoint, or the
    /// `.prev` survivor of an interrupted commit.
    fn pick_dir(&self, name: &str) -> Option<PathBuf> {
        let live = self.live_dir(name);
        if live.join(MANIFEST_FILE).is_file() {
            return Some(live);
        }
        let prev = self.prev_dir(name);
        if prev.join(MANIFEST_FILE).is_file() {
            return Some(prev);
        }
        None
    }

    /// Whether a restorable checkpoint `name` exists (live or `.prev`).
    pub fn exists(&self, name: &str) -> bool {
        self.pick_dir(name).is_some()
    }

    /// Delete checkpoint `name` (live, previous and staging).
    pub fn remove(&self, name: &str) -> Result<()> {
        Self::validate_name(name)?;
        for d in [self.live_dir(name), self.prev_dir(name), self.staging_dir(name)] {
            if d.exists() {
                fs::remove_dir_all(&d).map_err(|e| RoomyError::io(&d, e))?;
            }
        }
        Ok(())
    }

    /// Load (and self-digest-validate) the manifest of checkpoint `name`
    /// without touching any session state.
    pub fn load_manifest(&self, name: &str) -> Result<Manifest> {
        Self::validate_name(name)?;
        let dir = self
            .pick_dir(name)
            .ok_or_else(|| ckpt_err(format!("no checkpoint named {name:?}")))?;
        let path = dir.join(MANIFEST_FILE);
        let raw = fs::read(&path).map_err(|e| RoomyError::io(&path, e))?;
        Manifest::decode(&raw)
    }

    /// Atomically snapshot `structs` (plus free-form `app` state) as
    /// checkpoint `name`, replacing any previous checkpoint of that name.
    /// Must be called between collectives; structures with pending
    /// delayed ops are refused.
    pub fn save(
        &self,
        name: &str,
        structs: &[&dyn Checkpointable],
        app: &[(&str, &str)],
    ) -> Result<SaveReport> {
        let t0 = Instant::now();
        let mut sp = trace::span(trace::Kind::CkptSave, "ckpt.save", None);
        let stats0 = sp.armed().then(|| self.stats.snapshot());
        Self::validate_name(name)?;
        for (k, v) in app {
            // '\r' is rejected too: the line-oriented decode would strip
            // it from a trailing "\r\n", silently altering the value.
            if k.is_empty()
                || k.contains(|c: char| c.is_whitespace())
                || v.contains('\n')
                || v.contains('\r')
            {
                return Err(ckpt_err(format!(
                    "app state key {k:?} must be non-empty without whitespace; values must be single-line"
                )));
            }
        }
        let metas: Vec<StructMeta> = structs.iter().map(|s| s.ckpt_meta()).collect();
        for (s, m) in structs.iter().zip(&metas) {
            if s.ckpt_pending() > 0 {
                return Err(ckpt_err(format!(
                    "structure {:?} has pending delayed ops; sync before checkpointing",
                    m.name
                )));
            }
        }
        for (i, m) in metas.iter().enumerate() {
            if metas[..i].iter().any(|o| o.name == m.name || o.dir == m.dir) {
                return Err(ckpt_err(format!("structure {:?} snapshotted twice", m.name)));
            }
        }

        // Differential fast path (cheap half): files that would be
        // hardlinked are only ever replaced whole (tmp + rename), so a
        // live file whose (device, inode) still equals the prior
        // snapshot's copy — which was hardlinked *from* it — is
        // byte-identical to what the prior manifest digested. For those,
        // reuse the recorded digest: a metadata stat instead of a full
        // re-read, making per-level checkpoint I/O proportional to what
        // *changed* rather than to cumulative state. Append-in-place
        // structures (lists) are excluded — they are always copied and
        // re-digested. A missing or corrupt prior manifest simply
        // disables the fast path.
        let prior: Option<(PathBuf, Manifest)> = self.pick_dir(name).and_then(|dir| {
            let raw = fs::read(dir.join(MANIFEST_FILE)).ok()?;
            Some((dir, Manifest::decode(&raw).ok()?))
        });
        // Index the prior files once: (node, rel) → (len, digest), so the
        // per-file lookup below is O(1) instead of a manifest scan.
        let prior_idx: Option<(&Path, HashMap<(usize, &str), (u64, u64)>)> =
            prior.as_ref().map(|(dir, m)| {
                let mut idx = HashMap::with_capacity(m.files.len());
                for f in &m.files {
                    idx.insert((f.node, f.rel.as_str()), (f.len, f.digest));
                }
                (dir.as_path(), idx)
            });
        let prior_ref = prior_idx.as_ref();

        // Stage everything under <name>.staging (cleared first: a crashed
        // earlier save may have left one behind).
        let staging = self.staging_dir(name);
        if staging.exists() {
            fs::remove_dir_all(&staging).map_err(|e| RoomyError::io(&staging, e))?;
        }
        fs::create_dir_all(&staging).map_err(|e| RoomyError::io(&staging, e))?;

        // One job per node: each digests/links/copies its own files, so
        // checkpoint wall time stays flat as nodes are added — the same
        // per-node fan-out every other collective uses.
        let metas_ref = &metas;
        let staging_ref = &staging;
        let stats = &self.stats;
        let per_node: Vec<(Vec<ManifestFile>, SaveReport)> =
            self.cluster.run("checkpoint.save", |w, disk| {
                let mut files = Vec::new();
                let mut rep = SaveReport::default();
                for m in metas_ref {
                    for rel in disk.list(&m.dir)? {
                        let fname = rel.file_name().and_then(|f| f.to_str()).unwrap_or("");
                        // Spill/tmp files are transient scratch (empty
                        // staged buffers, interrupted rewrites) — never
                        // part of the durable state.
                        if fname.ends_with(".spill") || fname.ends_with(".tmp") {
                            continue;
                        }
                        let rel_str = rel.to_string_lossy().into_owned();
                        let dest = staging_ref.join(format!("node{w}")).join(&rel);
                        if let Some(parent) = dest.parent() {
                            fs::create_dir_all(parent).map_err(|e| RoomyError::io(parent, e))?;
                        }
                        let len = disk.len(&rel);
                        let digest = if m.appendable {
                            // Append-in-place files: one streaming pass
                            // that digests and copies.
                            stats.add_copy(len);
                            rep.copied += 1;
                            digest_from_disk(disk, &rel, Some(&dest))?
                        } else if fs::hard_link(disk.root().join(&rel), &dest).is_ok() {
                            // Replace-by-rename files: share the inode.
                            // If the prior snapshot's copy still shares
                            // the live inode (and the recorded length
                            // matches), its digest is this file's digest
                            // — no re-read. Otherwise read once.
                            stats.add_link(len);
                            rep.linked += 1;
                            let reused = prior_ref.and_then(|(pdir, idx)| {
                                let &(plen, pdigest) =
                                    idx.get(&(w, rel_str.as_str()))?;
                                if plen != len {
                                    return None;
                                }
                                let live = path_file_id(&disk.root().join(&rel));
                                let snap =
                                    path_file_id(&pdir.join(format!("node{w}")).join(&rel));
                                (live != (0, 0) && live == snap).then_some(pdigest)
                            });
                            match reused {
                                Some(d) => {
                                    stats.add_digest_reuse(len);
                                    rep.reused += 1;
                                    d
                                }
                                None => digest_from_disk(disk, &rel, None)?,
                            }
                        } else {
                            stats.add_copy(len);
                            rep.copied += 1;
                            digest_from_disk(disk, &rel, Some(&dest))?
                        };
                        rep.files += 1;
                        rep.bytes += len;
                        files.push(ManifestFile { node: w, rel: rel_str, len, digest });
                    }
                }
                Ok((files, rep))
            })?;
        let mut report = SaveReport::default();
        let mut files = Vec::new();
        for (f, rep) in per_node {
            files.extend(f);
            report.files += rep.files;
            report.bytes += rep.bytes;
            report.linked += rep.linked;
            report.copied += rep.copied;
            report.reused += rep.reused;
        }

        let topo = self.cluster.topology();
        let manifest = Manifest {
            version: MANIFEST_VERSION,
            workers: topo.nodes(),
            nbuckets: topo.nbuckets(),
            structs: metas,
            files,
            app: app.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        };
        let mpath = staging.join(MANIFEST_FILE);
        fs::write(&mpath, manifest.encode()).map_err(|e| RoomyError::io(&mpath, e))?;

        // Commit: old checkpoint steps aside as .prev, staging becomes
        // live, .prev is dropped. Every intermediate state keeps one
        // complete checkpoint restorable: a stale .prev is only removed
        // while the live dir still exists (crash → live survives), the
        // live → .prev window is covered by the .prev fallback in
        // `pick_dir`, and once staging is renamed the new checkpoint is
        // whole.
        let live = self.live_dir(name);
        let prev = self.prev_dir(name);
        if live.exists() {
            if prev.exists() {
                fs::remove_dir_all(&prev).map_err(|e| RoomyError::io(&prev, e))?;
            }
            fs::rename(&live, &prev).map_err(|e| RoomyError::io(&live, e))?;
        }
        fs::rename(&staging, &live).map_err(|e| RoomyError::io(&staging, e))?;
        if prev.exists() {
            fs::remove_dir_all(&prev).map_err(|e| RoomyError::io(&prev, e))?;
        }

        report.wall_secs = t0.elapsed().as_secs_f64();
        self.stats.add_save(t0.elapsed());
        if let Some(s0) = stats0 {
            let s1 = self.stats.snapshot();
            sp.set_args(
                s1.files_total() - s0.files_total(),
                s1.bytes_total() - s0.bytes_total(),
            );
        }
        Ok(report)
    }

    /// Validate checkpoint `name` (every file digest, the manifest
    /// self-digest, cluster geometry) and copy its files back into the
    /// node directories, replacing any same-named structure state. The
    /// returned [`Restored`] feeds the typed `Roomy::restored_*`
    /// constructors.
    pub fn restore(&self, name: &str) -> Result<Restored> {
        let t0 = Instant::now();
        let mut sp = trace::span(trace::Kind::CkptRestore, "ckpt.restore", None);
        let stats0 = sp.armed().then(|| self.stats.snapshot());
        let manifest = self.load_manifest(name)?;
        let dir = self.pick_dir(name).expect("load_manifest verified existence");
        // Geometry check through the shared ownership arithmetic: a
        // manifest written under a different Topology would route buckets
        // to the wrong nodes after restore.
        if !self.cluster.topology().matches(manifest.workers, manifest.nbuckets) {
            return Err(ckpt_err(format!(
                "checkpoint {name:?} was written by a {}-node / {}-bucket cluster; this cluster is {} / {}",
                manifest.workers,
                manifest.nbuckets,
                self.cluster.nworkers(),
                self.cluster.nbuckets()
            )));
        }

        // Clear stale restore staging left by an interrupted restore.
        for d in self.cluster.disks() {
            d.remove_dir("tmp/restore")?;
        }

        // Pass 1 (one job per node): validate every snapshot file before
        // touching session state — a single flipped byte aborts the
        // restore. Copy-installed files stream exactly once: digested
        // while staged under the node's tmp/restore/, renamed into place
        // only in pass 2; hardlink-installed files are digest-read only.
        let manifest_ref = &manifest;
        let dir_ref = dir.as_path();
        let validated = self.cluster.run("checkpoint.validate", |w, disk| {
            for f in manifest_ref.files.iter().filter(|f| f.node == w) {
                let src = dir_ref.join(format!("node{w}")).join(&f.rel);
                let (len, digest) = if installs_by_copy(manifest_ref, f) {
                    digest_and_copy_to_disk(&src, disk, restore_staging(f))?
                } else {
                    digest_plain_file(&src)?
                };
                if len != f.len || digest != f.digest {
                    return Err(ckpt_err(format!(
                        "digest mismatch in {:?} (node {}): checkpoint is corrupted",
                        f.rel, f.node
                    )));
                }
            }
            Ok(())
        });
        if let Err(e) = validated {
            for d in self.cluster.disks() {
                let _ = d.remove_dir("tmp/restore");
            }
            return Err(e);
        }

        // Pass 2: install. Same-named structure dirs from a dead run are
        // removed wholesale first (they may hold post-checkpoint state),
        // then every node installs its own files in parallel.
        for m in &manifest.structs {
            self.cluster.remove_structure_dirs(m.dir.clone())?;
        }
        let stats = &self.stats;
        self.cluster.run("checkpoint.install", |w, disk| {
            for f in manifest_ref.files.iter().filter(|f| f.node == w) {
                if installs_by_copy(manifest_ref, f) {
                    disk.rename(restore_staging(f), &f.rel)?;
                    stats.add_copy(f.len);
                } else {
                    let src = dir_ref.join(format!("node{w}")).join(&f.rel);
                    let dest_abs = disk.root().join(&f.rel);
                    if let Some(parent) = dest_abs.parent() {
                        fs::create_dir_all(parent).map_err(|e| RoomyError::io(parent, e))?;
                    }
                    if fs::hard_link(&src, &dest_abs).is_ok() {
                        stats.add_link(f.len);
                    } else {
                        // cross-filesystem fallback: stream-copy, and
                        // re-check the digest for free
                        let (len, digest) = digest_and_copy_to_disk(&src, disk, &f.rel)?;
                        if len != f.len || digest != f.digest {
                            return Err(ckpt_err(format!(
                                "checkpoint file {:?} changed between validation and install",
                                f.rel
                            )));
                        }
                        stats.add_copy(f.len);
                    }
                }
            }
            Ok(())
        })?;
        // every staged file was renamed away; drop the empty staging tree
        for d in self.cluster.disks() {
            d.remove_dir("tmp/restore")?;
        }
        self.stats.add_restore(t0.elapsed());
        if let Some(s0) = stats0 {
            let s1 = self.stats.snapshot();
            sp.set_args(
                s1.files_total() - s0.files_total(),
                s1.bytes_total() - s0.bytes_total(),
            );
        }
        Ok(Restored { manifest })
    }
}

/// True for manifest files installed by streaming copy (append-in-place
/// structures); false for replace-by-rename files, which hardlink.
fn installs_by_copy(manifest: &Manifest, f: &ManifestFile) -> bool {
    manifest
        .structs
        .iter()
        .find(|m| Path::new(&f.rel).starts_with(&m.dir))
        .is_some_and(|m| m.appendable)
}

/// Per-node staging path a copy-installed file is validated into before
/// pass 2 renames it into place.
fn restore_staging(f: &ManifestFile) -> String {
    format!("tmp/restore/{}", f.rel)
}

/// Stream `rel` off `disk` (metered; read-ahead on a pipelined disk),
/// returning its FNV-1a digest and optionally copying it to `dest`.
fn digest_from_disk(
    disk: &Arc<NodeDisk>,
    rel: impl AsRef<Path>,
    dest: Option<&Path>,
) -> Result<u64> {
    let mut r = ByteReader::open(disk, &rel)?;
    let mut out = match dest {
        Some(p) => Some(std::io::BufWriter::new(
            fs::File::create(p).map_err(|e| RoomyError::io(p, e))?,
        )),
        None => None,
    };
    let mut fnv = Fnv64::new();
    let mut buf = vec![0u8; COPY_CHUNK];
    loop {
        let n = r.read_fully(&mut buf)?;
        fnv.update(&buf[..n]);
        if let Some(w) = out.as_mut() {
            w.write_all(&buf[..n])
                .map_err(|e| RoomyError::io(dest.unwrap(), e))?;
        }
        if n < buf.len() {
            break;
        }
    }
    if let Some(mut w) = out {
        w.flush().map_err(|e| RoomyError::io(dest.unwrap(), e))?;
    }
    Ok(fnv.finish())
}

/// Length + FNV-1a digest of a plain (non-NodeDisk) file.
fn digest_plain_file(path: &Path) -> Result<(u64, u64)> {
    let f = fs::File::open(path).map_err(|e| RoomyError::io(path, e))?;
    let mut r = std::io::BufReader::with_capacity(COPY_CHUNK, f);
    let mut fnv = Fnv64::new();
    let mut buf = vec![0u8; COPY_CHUNK];
    let mut len = 0u64;
    loop {
        let n = r.read(&mut buf).map_err(|e| RoomyError::io(path, e))?;
        if n == 0 {
            break;
        }
        len += n as u64;
        fnv.update(&buf[..n]);
    }
    Ok((len, fnv.finish()))
}

/// Stream a checkpoint file onto `disk` at `rel` through the metered
/// writer, computing its length + FNV-1a digest in the same pass (the
/// single-read validate-and-stage path of restore).
fn digest_and_copy_to_disk(
    src: &Path,
    disk: &Arc<NodeDisk>,
    rel: impl AsRef<Path>,
) -> Result<(u64, u64)> {
    let f = fs::File::open(src).map_err(|e| RoomyError::io(src, e))?;
    let mut r = std::io::BufReader::with_capacity(COPY_CHUNK, f);
    let mut w = disk.create_file(&rel)?;
    let mut fnv = Fnv64::new();
    let mut len = 0u64;
    let mut buf = vec![0u8; COPY_CHUNK];
    loop {
        let n = r.read(&mut buf).map_err(|e| RoomyError::io(src, e))?;
        if n == 0 {
            break;
        }
        len += n as u64;
        fnv.update(&buf[..n]);
        w.write_bytes(&buf[..n])?;
    }
    w.finish()?;
    Ok((len, fnv.finish()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_fixture() -> Manifest {
        Manifest {
            version: MANIFEST_VERSION,
            workers: 3,
            nbuckets: 6,
            structs: vec![
                StructMeta {
                    kind: StructKind::List,
                    name: "all".into(),
                    dir: "rl_all".into(),
                    rec_size: 8,
                    key_size: 0,
                    len: 0,
                    size: 5040,
                    bits: 0,
                    sorted: true,
                    appendable: true,
                    counts: vec![],
                },
                StructMeta {
                    kind: StructKind::BitArray,
                    name: "seen".into(),
                    dir: "rba_seen".into(),
                    rec_size: 0,
                    key_size: 0,
                    len: 128,
                    size: 0,
                    bits: 2,
                    sorted: false,
                    appendable: false,
                    counts: vec![100, 20, 8, 0],
                },
            ],
            files: vec![
                ManifestFile { node: 0, rel: "rl_all/s0.dat".into(), len: 64, digest: 0xDEAD },
                ManifestFile { node: 2, rel: "rba_seen/b5.dat".into(), len: 16, digest: 0xBEEF },
            ],
            app: vec![
                ("lev".into(), "3".into()),
                ("levels".into(), "1,6,15,20".into()),
            ],
        }
    }

    #[test]
    fn manifest_roundtrip() {
        let m = manifest_fixture();
        let text = m.encode();
        let back = Manifest::decode(text.as_bytes()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.app("lev"), Some("3"));
        assert_eq!(back.meta("seen").unwrap().counts, vec![100, 20, 8, 0]);
        assert!(back.meta("nope").is_none());
    }

    #[test]
    fn manifest_flipped_byte_rejected_everywhere() {
        let text = manifest_fixture().encode();
        let bytes = text.as_bytes();
        // flip every bit of every byte (incl. the high bit — invalid
        // UTF-8 — and inside the digest line itself); every flip must
        // either fail with the typed error or decode to the *identical*
        // manifest (value-preserving flips exist: hex case toggles in
        // the digest line parse to the same value). The final trailing
        // newline is excluded: it sits outside every digested field.
        for pos in 0..bytes.len() - 1 {
            for bit in 0..8 {
                let mut corrupt = bytes.to_vec();
                corrupt[pos] ^= 1u8 << bit;
                match Manifest::decode(&corrupt) {
                    Err(RoomyError::Checkpoint(_)) => {}
                    Ok(m) => assert_eq!(
                        m,
                        manifest_fixture(),
                        "flip at {pos} bit {bit} decoded to different content"
                    ),
                    Err(other) => {
                        panic!("flip at {pos} bit {bit}: wrong error type {other:?}")
                    }
                }
            }
        }
    }

    #[test]
    fn manifest_truncation_rejected() {
        let text = manifest_fixture().encode();
        let bytes = text.as_bytes();
        for cut in [1usize, bytes.len() / 2, bytes.len() - 2] {
            assert!(Manifest::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn fnv_is_stable_and_order_sensitive() {
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_ne!(fnv64(b"ab"), fnv64(b"ba"));
        let mut f = Fnv64::new();
        f.update(b"a");
        f.update(b"b");
        assert_eq!(f.finish(), fnv64(b"ab"));
    }

    #[test]
    fn name_validation() {
        assert!(CheckpointManager::validate_name("bfs_pancake-7").is_ok());
        assert!(CheckpointManager::validate_name("").is_err());
        assert!(CheckpointManager::validate_name("a/b").is_err());
        assert!(CheckpointManager::validate_name("a.staging").is_err());
        assert!(CheckpointManager::validate_name("a b").is_err());
    }

    #[test]
    fn unknown_version_rejected() {
        let text = manifest_fixture().encode();
        let bumped = text.replace("roomy-checkpoint v1", "roomy-checkpoint v9");
        // fix the digest so only the version check can fire
        let at = bumped.rfind("digest ").unwrap();
        let body = &bumped[..at];
        let fixed = format!("{body}digest {:016x}\n", fnv64(body.as_bytes()));
        let err = Manifest::decode(fixed.as_bytes()).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }
}
