//! External sort for fixed-size records: in-RAM run generation + k-way
//! streaming merge, with optional duplicate elimination and sorted-merge
//! set algebra (difference) — the machinery behind `RoomyList`'s
//! `removeDupes`/`removeAll` (paper §2: "computations using RoomyLists are
//! often dominated by the time to sort the list").
//!
//! Records compare as raw byte strings (memcmp). Roomy only needs a total
//! order consistent with equality; element encodings choose their byte
//! layout accordingly. For records that are a whole number of `u64`
//! words, the hot compare/equality loops here take a word-wise fast path
//! (big-endian word loads are order-identical to memcmp) instead of
//! byte-at-a-time slice comparison — part of the raw-speed kernel pass,
//! pinned bit-exact by `word_cmp_matches_memcmp` below and the kernel
//! property suite.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::chunkfile::{RecordReader, RecordWriter};
use super::diskio::NodeDisk;
use super::pipeline::{PrefetchReader, WriteBehindWriter, PIPE_CHUNK};
use super::scratch;
use crate::error::Result;
use crate::obs::trace;

/// Scratch prefix for a sort targeting `output`: a flattened name under
/// `tmp/sort/` so crashed runs leave their half-written runs where
/// [`crate::cluster::Cluster::new`] purges them. Keyed on the *output*
/// path, which is unique per concurrent sort (two collectives may sort
/// the same input into different outputs, never into the same one).
/// Compare two equal-length records, word-wise when they are a whole
/// number of `u64` words. Big-endian word loads preserve memcmp order,
/// so this is exactly `a.cmp(b)` — just without the per-byte tail logic
/// for the fixed sizes Roomy's element codecs overwhelmingly produce.
#[inline]
pub(crate) fn cmp_records(a: &[u8], b: &[u8]) -> std::cmp::Ordering {
    debug_assert_eq!(a.len(), b.len());
    if a.len() % 8 != 0 {
        return a.cmp(b);
    }
    for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
        let wa = u64::from_be_bytes(ca.try_into().expect("8-byte chunk"));
        let wb = u64::from_be_bytes(cb.try_into().expect("8-byte chunk"));
        if wa != wb {
            return wa.cmp(&wb);
        }
    }
    std::cmp::Ordering::Equal
}

/// Word-wise equality for equal-length records: whole `u64` loads with a
/// fused-OR difference accumulator, byte tail folded into a final word.
/// Exactly `a == b`.
#[inline]
pub(crate) fn records_equal(a: &[u8], b: &[u8]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut diff = 0u64;
    let (wa, ta) = (a.chunks_exact(8), &a[a.len() - a.len() % 8..]);
    let (wb, tb) = (b.chunks_exact(8), &b[b.len() - b.len() % 8..]);
    for (ca, cb) in wa.zip(wb) {
        diff |= u64::from_le_bytes(ca.try_into().expect("8-byte chunk"))
            ^ u64::from_le_bytes(cb.try_into().expect("8-byte chunk"));
    }
    for (&x, &y) in ta.iter().zip(tb.iter()) {
        diff |= (x ^ y) as u64;
    }
    diff == 0
}

fn run_prefix(output: &Path) -> PathBuf {
    let flat: String = output
        .to_string_lossy()
        .chars()
        .map(|c| if c == '/' || c == '\\' { '_' } else { c })
        .collect();
    PathBuf::from("tmp/sort").join(format!("{flat}.sort"))
}

/// Generate sorted runs from `input`: chunks of ~`chunk_bytes` are sorted
/// in RAM and written to `tmp_prefix.runK`. Returns the run paths.
/// Run generation streams through the node's I/O pipeline when enabled
/// (the next chunk is read ahead while the current one sorts, the sorted
/// run flushes behind).
pub fn make_runs(
    disk: &Arc<NodeDisk>,
    input: impl AsRef<Path>,
    tmp_prefix: impl AsRef<Path>,
    rec_size: usize,
    chunk_bytes: usize,
) -> Result<Vec<PathBuf>> {
    let mut runs = Vec::new();
    if !disk.exists(&input) {
        return Ok(runs);
    }
    let mut sp = trace::span(trace::Kind::SortRuns, "sort.runs", Some(disk.node()));
    // Cap the run size to the file's actual record count: read_batch
    // zero-fills its buffer up front, so an uncapped 64 MB chunk would
    // memset 64 MB per (possibly tiny) shard.
    let total_recs = super::chunkfile::record_count(disk, &input, rec_size).max(1) as usize;
    let recs_per_chunk = (chunk_bytes / rec_size).clamp(1, total_recs);
    let mut reader = PrefetchReader::open(disk, &input, rec_size)?;
    let mut buf = scratch::record_buf();
    loop {
        let n = reader.read_batch(&mut buf, recs_per_chunk)?;
        if n == 0 {
            break;
        }
        let run_rel = tmp_prefix.as_ref().with_extension(format!("run{}", runs.len()));
        let mut w = WriteBehindWriter::create(disk, &run_rel, rec_size)?;
        if rec_size == 8 {
            // Word-wise fast path: a BE u64 load is order-identical to
            // memcmp, so sort the decoded integers instead of paying a
            // memcmp per comparison (the dominant element width).
            let mut keys: Vec<u64> = buf
                .chunks_exact(8)
                .map(|c| u64::from_be_bytes(c.try_into().expect("8-byte record")))
                .collect();
            keys.sort_unstable();
            for key in keys {
                w.push(&key.to_be_bytes())?;
            }
        } else {
            // Sort record *views* then write in order (avoids moving
            // payloads twice for large records). Word-wise compare for
            // whole-word records, memcmp otherwise (same order either
            // way — see `cmp_records`).
            let mut views: Vec<&[u8]> = buf.chunks_exact(rec_size).collect();
            if rec_size % 8 == 0 {
                views.sort_unstable_by(|a, b| cmp_records(a, b));
            } else {
                views.sort_unstable();
            }
            for v in views {
                w.push(v)?;
            }
        }
        w.finish()?;
        runs.push(run_rel);
    }
    sp.set_args(runs.len() as u64, 0);
    Ok(runs)
}

/// K-way merge sorted `runs` into `output`. `dedup` drops records equal to
/// the previously written one. Returns records written. Run files are
/// deleted afterwards. On a pipelined disk every run is read ahead (with
/// per-run chunks scaled down by the fan-in, so a merge's total pipeline
/// RAM stays O(depth × [`PIPE_CHUNK`])) and the output flushes behind.
pub fn merge_runs(
    disk: &Arc<NodeDisk>,
    runs: &[PathBuf],
    output: impl AsRef<Path>,
    rec_size: usize,
    dedup: bool,
) -> Result<u64> {
    let mut sp = trace::span(trace::Kind::SortMerge, "sort.merge", Some(disk.node()));
    let mut writer = WriteBehindWriter::create(disk, &output, rec_size)?;
    let mut heap: BinaryHeap<Reverse<(Vec<u8>, usize)>> = BinaryHeap::new();
    let mut readers = Vec::with_capacity(runs.len());
    let run_chunk = PIPE_CHUNK / runs.len().max(1);
    for (i, run) in runs.iter().enumerate() {
        let mut r = PrefetchReader::open_with_chunk(disk, run, rec_size, run_chunk)?;
        let mut rec = vec![0u8; rec_size];
        if r.read_one(&mut rec)? {
            heap.push(Reverse((rec, i)));
        }
        readers.push(r);
    }
    // Dedup compares against a single reused buffer — no per-unique
    // clone. The heap's k record buffers circulate pop → refill → push,
    // so the merge allocates nothing per record in steady state.
    let mut last = scratch::record_buf();
    last.resize(rec_size, 0);
    let mut have_last = false;
    let mut written = 0u64;
    while let Some(Reverse((rec, i))) = heap.pop() {
        let emit = !(dedup && have_last && records_equal(&last, &rec));
        if emit {
            writer.push(&rec)?;
            written += 1;
            if dedup {
                last.copy_from_slice(&rec);
                have_last = true;
            }
        }
        let mut next = rec; // reuse allocation
        if readers[i].read_one(&mut next)? {
            heap.push(Reverse((next, i)));
        }
    }
    writer.finish()?;
    for run in runs {
        disk.remove(run)?;
    }
    sp.set_args(written, runs.len() as u64);
    Ok(written)
}

/// Sort `input` into `output` (safe for `input == output`), optionally
/// deduplicating. Returns records written. Run files live under
/// `tmp/sort/` (purged at cluster bring-up if a crash strands them).
pub fn sort_file(
    disk: &Arc<NodeDisk>,
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    rec_size: usize,
    chunk_bytes: usize,
    dedup: bool,
) -> Result<u64> {
    let tmp_prefix = run_prefix(output.as_ref());
    let runs = make_runs(disk, &input, &tmp_prefix, rec_size, chunk_bytes)?;
    if runs.is_empty() {
        // Empty/missing input: produce an empty output file.
        RecordWriter::create(disk, &output, rec_size)?.finish()?;
        return Ok(0);
    }
    let tmp_out = tmp_prefix.with_extension("merged");
    let n = merge_runs(disk, &runs, &tmp_out, rec_size, dedup)?;
    disk.rename(&tmp_out, &output)?;
    Ok(n)
}

/// Hash-partition an unsorted record file into per-bucket run files:
/// each chunk is fingerprinted with the batched routing kernel
/// ([`crate::hashfn::route_batch_into`]) and its records scattered to
/// `output_for(bucket)`. Record order within a bucket is input order, so
/// the output files are a deterministic function of the input bytes and
/// `nbuckets` regardless of kernel mode. Returns records per bucket.
/// This is the shuffle primitive behind re-bucketing a structure onto a
/// different bucket count (every output is created, empty buckets
/// included, so downstream merges see a complete file set).
pub fn partition_file(
    disk: &Arc<NodeDisk>,
    input: impl AsRef<Path>,
    output_for: impl Fn(u32) -> PathBuf,
    rec_size: usize,
    nbuckets: u32,
    chunk_bytes: usize,
) -> Result<Vec<u64>> {
    let mut sp = trace::span(trace::Kind::SortRuns, "sort.partition", Some(disk.node()));
    let mut counts = vec![0u64; nbuckets as usize];
    let mut writers = Vec::with_capacity(nbuckets as usize);
    for b in 0..nbuckets {
        writers.push(RecordWriter::create(disk, output_for(b), rec_size)?);
    }
    if disk.exists(&input) {
        let mut reader = PrefetchReader::open(disk, &input, rec_size)?;
        let recs_per_chunk = (chunk_bytes / rec_size).max(1);
        let mut buf = scratch::record_buf();
        let mut routes: Vec<u32> = Vec::new();
        loop {
            let n = reader.read_batch(&mut buf, recs_per_chunk)?;
            if n == 0 {
                break;
            }
            routes.clear();
            crate::hashfn::route_batch_into(&buf, rec_size, nbuckets, &mut routes);
            for (rec, &b) in buf.chunks_exact(rec_size).zip(routes.iter()) {
                writers[b as usize].push(rec)?;
                counts[b as usize] += 1;
            }
        }
    }
    for w in writers {
        w.finish()?;
    }
    sp.set_args(counts.iter().sum(), nbuckets as u64);
    Ok(counts)
}

/// Streaming sorted-merge difference: records of sorted `a` that do not
/// appear in sorted `b` (every occurrence of a matching record is
/// removed — RoomyList `removeAll` semantics). Returns records written.
/// Both inputs read ahead (half a chunk each) and the output flushes
/// behind on a pipelined disk.
pub fn merge_diff(
    disk: &Arc<NodeDisk>,
    a: impl AsRef<Path>,
    b: impl AsRef<Path>,
    output: impl AsRef<Path>,
    rec_size: usize,
) -> Result<u64> {
    let mut out = WriteBehindWriter::create(disk, &output, rec_size)?;
    let mut ra = PrefetchReader::open_with_chunk(disk, &a, rec_size, PIPE_CHUNK / 2)?;
    let mut rec_a = vec![0u8; rec_size];
    let mut have_a = ra.read_one(&mut rec_a)?;

    let mut rec_b = vec![0u8; rec_size];
    let mut have_b;
    let mut rb = if disk.exists(&b) {
        let mut r = PrefetchReader::open_with_chunk(disk, &b, rec_size, PIPE_CHUNK / 2)?;
        have_b = r.read_one(&mut rec_b)?;
        Some(r)
    } else {
        have_b = false;
        None
    };

    let mut written = 0u64;
    while have_a {
        if have_b {
            match cmp_records(&rec_a, &rec_b) {
                std::cmp::Ordering::Less => {
                    out.push(&rec_a)?;
                    written += 1;
                    have_a = ra.read_one(&mut rec_a)?;
                }
                std::cmp::Ordering::Equal => {
                    // drop this occurrence of a (and keep b for more dups)
                    have_a = ra.read_one(&mut rec_a)?;
                }
                std::cmp::Ordering::Greater => {
                    have_b = rb.as_mut().unwrap().read_one(&mut rec_b)?;
                }
            }
        } else {
            out.push(&rec_a)?;
            written += 1;
            have_a = ra.read_one(&mut rec_a)?;
        }
    }
    out.finish()?;
    Ok(written)
}

/// Check that `rel` is sorted (ascending memcmp); test/debug helper.
pub fn is_sorted(disk: &NodeDisk, rel: impl AsRef<Path>, rec_size: usize) -> Result<bool> {
    if !disk.exists(&rel) {
        return Ok(true);
    }
    let mut r = RecordReader::open(disk, &rel, rec_size)?;
    let mut prev = vec![0u8; rec_size];
    let mut cur = vec![0u8; rec_size];
    if !r.read_one(&mut prev)? {
        return Ok(true);
    }
    while r.read_one(&mut cur)? {
        if cur < prev {
            return Ok(false);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DiskPolicy;
    use crate::testutil::{prop_check, tmpdir};

    fn disk(dir: &Path) -> Arc<NodeDisk> {
        Arc::new(NodeDisk::create(0, dir, DiskPolicy::unthrottled()).unwrap())
    }

    fn write_u32s(d: &NodeDisk, rel: &str, vals: &[u32]) {
        let mut w = RecordWriter::create(d, rel, 4).unwrap();
        for v in vals {
            w.push(&v.to_be_bytes()).unwrap(); // BE: memcmp == numeric
        }
        w.finish().unwrap();
    }

    fn read_u32s(d: &NodeDisk, rel: &str) -> Vec<u32> {
        let mut out = vec![];
        super::super::chunkfile::for_each_record(d, rel, 4, 256, |rec| {
            out.push(u32::from_be_bytes(rec.try_into().unwrap()));
            Ok(())
        })
        .unwrap();
        out
    }

    #[test]
    fn sorts_single_run() {
        let t = tmpdir("extsort_single");
        let d = disk(t.path());
        write_u32s(&d, "in.dat", &[5, 3, 9, 1, 7]);
        let n = sort_file(&d, "in.dat", "out.dat", 4, 1 << 20, false).unwrap();
        assert_eq!(n, 5);
        assert_eq!(read_u32s(&d, "out.dat"), vec![1, 3, 5, 7, 9]);
        assert!(is_sorted(&d, "out.dat", 4).unwrap());
    }

    #[test]
    fn sorts_many_runs_with_tiny_chunks() {
        let t = tmpdir("extsort_runs");
        let d = disk(t.path());
        let vals: Vec<u32> = (0..1000).map(|i| (i * 7919) % 1000).collect();
        write_u32s(&d, "in.dat", &vals);
        // chunk_bytes=32 -> 8 records per run -> 125 runs
        let n = sort_file(&d, "in.dat", "out.dat", 4, 32, false).unwrap();
        assert_eq!(n, 1000);
        let got = read_u32s(&d, "out.dat");
        let mut expect = vals.clone();
        expect.sort();
        assert_eq!(got, expect);
        // runs (under tmp/sort) cleaned up
        assert_eq!(crate::testutil::files_under(&t.path().join("tmp/sort")), 0);
    }

    #[test]
    fn pipelined_sort_matches_sync_sort() {
        let vals: Vec<u32> = (0..5_000).map(|i| (i * 2654435761u64 % 5_000) as u32).collect();
        let t0 = tmpdir("extsort_pipe_ref");
        let d0 = disk(t0.path());
        write_u32s(&d0, "in.dat", &vals);
        sort_file(&d0, "in.dat", "out.dat", 4, 512, true).unwrap();
        let reference = d0.read_all("out.dat").unwrap();

        for depth in [1usize, 4] {
            let t = tmpdir(&format!("extsort_pipe_{depth}"));
            let d = Arc::new(
                NodeDisk::create_with_depth(0, t.path(), DiskPolicy::unthrottled(), depth)
                    .unwrap(),
            );
            write_u32s(&d, "in.dat", &vals);
            sort_file(&d, "in.dat", "out.dat", 4, 512, true).unwrap();
            assert_eq!(d.read_all("out.dat").unwrap(), reference, "depth {depth}");
            assert_eq!(crate::testutil::files_under(&t.path().join("tmp")), 0);
        }
    }

    #[test]
    fn dedup_removes_duplicates() {
        let t = tmpdir("extsort_dedup");
        let d = disk(t.path());
        write_u32s(&d, "in.dat", &[4, 2, 4, 4, 1, 2, 8]);
        let n = sort_file(&d, "in.dat", "out.dat", 4, 8, true).unwrap();
        assert_eq!(n, 4);
        assert_eq!(read_u32s(&d, "out.dat"), vec![1, 2, 4, 8]);
    }

    #[test]
    fn sort_in_place_same_path() {
        let t = tmpdir("extsort_inplace");
        let d = disk(t.path());
        write_u32s(&d, "f.dat", &[3, 1, 2]);
        sort_file(&d, "f.dat", "f.dat", 4, 1 << 20, false).unwrap();
        assert_eq!(read_u32s(&d, "f.dat"), vec![1, 2, 3]);
    }

    #[test]
    fn empty_input_gives_empty_output() {
        let t = tmpdir("extsort_empty");
        let d = disk(t.path());
        let n = sort_file(&d, "missing.dat", "out.dat", 4, 1024, true).unwrap();
        assert_eq!(n, 0);
        assert!(d.exists("out.dat"));
        assert_eq!(d.len("out.dat"), 0);
    }

    #[test]
    fn diff_removes_all_occurrences() {
        let t = tmpdir("extsort_diff");
        let d = disk(t.path());
        write_u32s(&d, "a.dat", &[1, 2, 2, 3, 5, 5, 9]);
        write_u32s(&d, "b.dat", &[2, 5]);
        let n = merge_diff(&d, "a.dat", "b.dat", "c.dat", 4).unwrap();
        assert_eq!(n, 3);
        assert_eq!(read_u32s(&d, "c.dat"), vec![1, 3, 9]);
    }

    #[test]
    fn diff_with_missing_b_copies_a() {
        let t = tmpdir("extsort_diffb");
        let d = disk(t.path());
        write_u32s(&d, "a.dat", &[1, 2]);
        let n = merge_diff(&d, "a.dat", "nope.dat", "c.dat", 4).unwrap();
        assert_eq!(n, 2);
        assert_eq!(read_u32s(&d, "c.dat"), vec![1, 2]);
    }

    #[test]
    fn word_cmp_matches_memcmp() {
        prop_check("cmp_records/records_equal == memcmp", 20, |rng| {
            for size in [8usize, 16, 24, 5, 12] {
                let a: Vec<u8> = (0..size).map(|_| rng.below(4) as u8).collect();
                let b: Vec<u8> = (0..size).map(|_| rng.below(4) as u8).collect();
                assert_eq!(cmp_records(&a, &b), a.cmp(&b), "size {size}");
                assert_eq!(records_equal(&a, &b), a == b, "size {size}");
                assert_eq!(cmp_records(&a, &a), std::cmp::Ordering::Equal);
                assert!(records_equal(&b, &b));
            }
        });
    }

    fn write_u64s(d: &NodeDisk, rel: &str, vals: &[u64]) {
        let mut w = RecordWriter::create(d, rel, 8).unwrap();
        for v in vals {
            w.push(&v.to_be_bytes()).unwrap();
        }
        w.finish().unwrap();
    }

    fn read_u64s(d: &NodeDisk, rel: &str) -> Vec<u64> {
        let mut out = vec![];
        super::super::chunkfile::for_each_record(d, rel, 8, 256, |rec| {
            out.push(u64::from_be_bytes(rec.try_into().unwrap()));
            Ok(())
        })
        .unwrap();
        out
    }

    #[test]
    fn word_width_records_take_fast_paths() {
        // 8-byte records exercise the integer-key run sort, the
        // word-wise dedup equality, and the word-wise diff compare.
        let t = tmpdir("extsort_u64");
        let d = disk(t.path());
        let vals: Vec<u64> = (0..2000).map(|i| (i * 0x9E3779B97F4A7C15u64) >> 13).collect();
        let mut with_dups = vals.clone();
        with_dups.extend(vals.iter().step_by(3));
        write_u64s(&d, "in.dat", &with_dups);
        let n = sort_file(&d, "in.dat", "out.dat", 8, 256, true).unwrap();
        let mut expect: Vec<u64> =
            std::collections::BTreeSet::from_iter(with_dups.iter().copied())
                .into_iter()
                .collect();
        assert_eq!(n, expect.len() as u64);
        assert_eq!(read_u64s(&d, "out.dat"), expect);

        let mut bvals: Vec<u64> = vals.iter().copied().step_by(2).collect();
        bvals.sort_unstable();
        write_u64s(&d, "b.dat", &bvals);
        let n = merge_diff(&d, "out.dat", "b.dat", "c.dat", 8).unwrap();
        expect.retain(|v| !bvals.contains(v));
        assert_eq!(n, expect.len() as u64);
        assert_eq!(read_u64s(&d, "c.dat"), expect);
    }

    #[test]
    fn multiword_records_sort_like_memcmp() {
        // 16-byte records exercise the word-wise view comparator.
        let t = tmpdir("extsort_w16");
        let d = disk(t.path());
        let mut recs: Vec<[u8; 16]> = vec![];
        let mut w = RecordWriter::create(&d, "in.dat", 16).unwrap();
        for i in 0..500u64 {
            let mut r = [0u8; 16];
            r[..8].copy_from_slice(&((i * 31) % 17).to_be_bytes());
            r[8..].copy_from_slice(&(i ^ 0xABCD).to_be_bytes());
            w.push(&r).unwrap();
            recs.push(r);
        }
        w.finish().unwrap();
        sort_file(&d, "in.dat", "out.dat", 16, 128, false).unwrap();
        recs.sort();
        let mut got = vec![];
        super::super::chunkfile::for_each_record(&d, "out.dat", 16, 64, |rec| {
            got.push(<[u8; 16]>::try_from(rec).unwrap());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, recs);
    }

    #[test]
    fn partition_file_routes_every_record_to_its_bucket() {
        let t = tmpdir("extsort_part");
        let d = disk(t.path());
        let vals: Vec<u64> = (0..1500).map(|i| i * 3 + 1).collect();
        write_u64s(&d, "in.dat", &vals);
        let nb = 7u32;
        let counts =
            partition_file(&d, "in.dat", |b| PathBuf::from(format!("part{b}.dat")), 8, nb, 256)
                .unwrap();
        assert_eq!(counts.iter().sum::<u64>(), vals.len() as u64);
        let mut seen = vec![];
        for b in 0..nb {
            let part = read_u64s(&d, &format!("part{b}.dat"));
            assert_eq!(counts[b as usize], part.len() as u64);
            for v in part {
                assert_eq!(
                    crate::hashfn::bucket_of_bytes(&v.to_be_bytes(), nb),
                    b,
                    "record {v} landed in wrong bucket"
                );
                seen.push(v);
            }
        }
        seen.sort_unstable();
        let mut expect = vals.clone();
        expect.sort_unstable();
        assert_eq!(seen, expect, "partition must be a permutation of the input");
    }

    #[test]
    fn partition_missing_input_creates_empty_buckets() {
        let t = tmpdir("extsort_part_empty");
        let d = disk(t.path());
        let counts =
            partition_file(&d, "nope.dat", |b| PathBuf::from(format!("p{b}.dat")), 8, 3, 256)
                .unwrap();
        assert_eq!(counts, vec![0, 0, 0]);
        for b in 0..3 {
            assert!(d.exists(format!("p{b}.dat")));
            assert_eq!(d.len(format!("p{b}.dat")), 0);
        }
    }

    #[test]
    fn prop_sort_matches_std() {
        prop_check("extsort vs std sort", 10, |rng| {
            let t = tmpdir("extsort_prop");
            let d = disk(t.path());
            let n = rng.range(0, 500);
            let vals: Vec<u32> = (0..n).map(|_| rng.below(100) as u32).collect();
            write_u32s(&d, "in.dat", &vals);
            let chunk = rng.range(8, 256);
            sort_file(&d, "in.dat", "out.dat", 4, chunk, false).unwrap();
            let mut expect = vals.clone();
            expect.sort();
            assert_eq!(read_u32s(&d, "out.dat"), expect);
        });
    }

    #[test]
    fn prop_dedup_matches_btreeset() {
        prop_check("extsort dedup vs BTreeSet", 10, |rng| {
            let t = tmpdir("extsort_propd");
            let d = disk(t.path());
            let n = rng.range(0, 300);
            let vals: Vec<u32> = (0..n).map(|_| rng.below(50) as u32).collect();
            write_u32s(&d, "in.dat", &vals);
            sort_file(&d, "in.dat", "out.dat", 4, 64, true).unwrap();
            let expect: Vec<u32> =
                std::collections::BTreeSet::from_iter(vals.iter().copied())
                    .into_iter()
                    .collect();
            assert_eq!(read_u32s(&d, "out.dat"), expect);
        });
    }
}
