//! Read-ahead / write-behind bucket I/O: overlapping disk transfers with
//! computation inside a pool task.
//!
//! The paper's premise is that disk bandwidth, not CPU, bounds
//! space-limited computations — so a worker that alternates "read a
//! chunk, compute on it, write a chunk" serializes two resources that
//! could run simultaneously. This module adds a **per-node I/O service**
//! (one read-ahead lane and one write-behind lane, each a dedicated OS
//! thread owned by the node's [`NodeDisk`]) plus two streaming wrappers:
//!
//! - [`PrefetchReader`] — API-compatible with
//!   [`RecordReader`](crate::storage::RecordReader). With pipeline depth
//!   `d > 0` it circulates `d` chunk buffers between the consumer and the
//!   node's read lane, so while a task computes on chunk *k* the service
//!   is already filling chunk *k+1*.
//! - [`WriteBehindWriter`] — API-compatible with
//!   [`RecordWriter`](crate::storage::RecordWriter). Completed chunks are
//!   handed to the write lane and flushed while the task keeps producing;
//!   `finish()` drains the lane and surfaces any deferred error.
//!   In overlapped create mode bytes are staged under `tmp/pipeline/` and
//!   renamed to the destination at `finish()`, so an abandoned stream
//!   (task error, worker panic) never leaves a torn destination — its
//!   `Drop` removes the staging file.
//! - [`ByteReader`] — owned byte-stream variant (no record framing) used
//!   by [`crate::storage::buffer::SpillDrain`] so delayed-op log replay
//!   prefetches too.
//!
//! On top of the per-stream pipeline, **cross-task prefetch hints**
//! ([`NodeDisk::hint_prefetch`]) let the pool's per-node schedulers warm
//! the *next* bucket's file while the current bucket computes: the read
//! lane parks (first chunk, open reader) in a per-node [`HintCache`]
//! bounded by the pipeline depth, and the next scan's `ChunkFetcher`
//! adopts it — guarded by (device, inode, length) identity so a replaced
//! or appended file makes the hint a counted waste, never a wrong byte.
//!
//! **Determinism.** The pipeline moves *when* bytes are transferred, never
//! *what* or *in which order within a file*: chunks of one stream are
//! filled/flushed strictly FIFO (the lanes are FIFO queues and each
//! stream's jobs are enqueued in offset order), and depth-0 mode is
//! byte-for-byte today's synchronous path. On-disk state is therefore
//! identical for every `io_pipeline_depth`, which `tests/determinism.rs`
//! pins across depths 0/1/4 × `num_workers` 1/2/4.
//!
//! **Space bound.** A stream owns at most `depth` chunk buffers (the one
//! the consumer holds counts), allocated lazily — a file smaller than one
//! chunk allocates a single buffer no matter the depth, so depths larger
//! than the data degrade gracefully. Peak per-stream buffer RAM is
//! recorded in [`PipelineStats`] (`note_stream_buf`) and asserted
//! `≤ depth × chunk` by the integration tests. Streams per task are O(1)
//! (a scan holds one, a rewrite two, a k-way merge scales its chunk down
//! by k), keeping per-task pipeline RAM O(depth × chunk).
//!
//! **Metering.** All transfers go through the same
//! [`NodeDisk`](crate::storage::NodeDisk) metered calls, so `IoStats`
//! counts them identically; under a throttled
//! [`DiskPolicy`](crate::DiskPolicy) the simulated bandwidth sleeps are
//! taken **on the service lanes**, which is exactly what "overlapped
//! transfers" means for the bandwidth model: simulated disk time runs
//! concurrently with compute (and read time concurrently with write
//! time), instead of serializing with them as in depth-0 mode.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::chunkfile::{RecordReader, RecordWriter};
use super::diskio::{
    path_file_id, DetachedReader, NodeDisk, SharedMeteredReader, SharedMeteredWriter,
};
use super::scratch;
use crate::error::{Result, RoomyError};
use crate::metrics::PipelineStats;
use crate::obs::{hist, trace};

/// Default chunk size a pipelined stream transfers per job. Large enough
/// to amortize the cross-thread handoff, small enough that
/// `depth × PIPE_CHUNK` stays far below a bucket.
pub const PIPE_CHUNK: usize = 256 * 1024;

/// How long drains wait on a lane before declaring it stalled. Generous:
/// a chunk under the paper's throttle model takes milliseconds.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(60);

/// Stall intervals shorter than this are metered in [`PipelineStats`] but
/// not recorded as flight-recorder spans — a sub-50 µs wait is a queue
/// handoff, not a stall worth a timeline row.
const STALL_TRACE_MIN: Duration = Duration::from_micros(50);

/// Unique suffix for write-behind staging files (process-wide).
static STAGING_ID: AtomicU64 = AtomicU64::new(0);

/// One unit of work for a service lane.
pub(crate) type Job = Box<dyn FnOnce() + Send + 'static>;

fn pipeline_err(msg: &str) -> RoomyError {
    RoomyError::Pipeline(msg.to_string())
}

/// Lock a mutex, tolerating poison (a panicked job must not wedge every
/// other stream on the node).
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

// ---------------------------------------------------------------------
// Per-node service: one read lane + one write lane
// ---------------------------------------------------------------------

/// One service lane: a FIFO job queue drained by a dedicated OS thread.
#[derive(Debug)]
struct Lane {
    tx: Mutex<Option<Sender<Job>>>,
    handle: Mutex<Option<JoinHandle<()>>>,
    alive: Arc<AtomicBool>,
}

impl Lane {
    fn spawn(name: String) -> Result<Lane> {
        let (tx, rx) = channel::<Job>();
        let alive = Arc::new(AtomicBool::new(true));
        let alive2 = Arc::clone(&alive);
        let handle = std::thread::Builder::new()
            .name(name.clone())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    // A panicking job must not take the lane down with it;
                    // its stream surfaces the failure through its own
                    // error/guard channels.
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                }
                alive2.store(false, Ordering::SeqCst);
            })
            .map_err(|e| RoomyError::Pipeline(format!("cannot spawn {name}: {e}")))?;
        Ok(Lane {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            alive,
        })
    }

    fn submit(&self, job: Job) -> Result<()> {
        match lock_ignore_poison(&self.tx).as_ref() {
            Some(tx) => tx
                .send(job)
                .map_err(|_| pipeline_err("io service lane is gone")),
            None => Err(pipeline_err("io service shut down")),
        }
    }

    /// Drop the queue (queued jobs still run) and join the thread — unless
    /// called *from* the lane thread itself (possible when the last
    /// `Arc<NodeDisk>` is dropped by a queued job), where joining would
    /// self-deadlock; the thread exits on its own right after.
    fn shutdown(&self) {
        lock_ignore_poison(&self.tx).take();
        let handle = lock_ignore_poison(&self.handle).take();
        if let Some(h) = handle {
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

/// The per-node I/O service: a read-ahead lane and a write-behind lane.
/// Owned by the node's [`NodeDisk`]; shut down (queues drained, threads
/// joined) when the disk is dropped.
#[derive(Debug)]
pub struct IoService {
    read_lane: Lane,
    write_lane: Lane,
}

impl IoService {
    pub(crate) fn spawn(node: usize) -> Result<IoService> {
        Ok(IoService {
            read_lane: Lane::spawn(format!("roomy-ior-{node}"))?,
            write_lane: Lane::spawn(format!("roomy-iow-{node}"))?,
        })
    }

    pub(crate) fn submit_read(&self, job: Job) -> Result<()> {
        self.read_lane.submit(job)
    }

    pub(crate) fn submit_write(&self, job: Job) -> Result<()> {
        self.write_lane.submit(job)
    }

    /// Liveness flags of both lane threads (cleared as each thread
    /// exits). The lifecycle tests hold these across instance teardown to
    /// prove no service thread survives it.
    pub fn alive_flags(&self) -> Vec<Arc<AtomicBool>> {
        vec![
            Arc::clone(&self.read_lane.alive),
            Arc::clone(&self.write_lane.alive),
        ]
    }

    pub(crate) fn shutdown(&self) {
        self.read_lane.shutdown();
        self.write_lane.shutdown();
    }
}

// ---------------------------------------------------------------------
// Cross-task prefetch hints
// ---------------------------------------------------------------------
//
// The pool's per-node queues know which bucket runs *next* on a node
// while the current bucket still computes ([`crate::runtime::pool`]).
// `post_hint` turns that knowledge into read-lane work: open the named
// file, read its first chunk, and park (chunk, open reader) in the
// node's bounded `HintCache`. When the next task's scan opens the same
// file, `ChunkFetcher::open` adopts the warmed chunk as its first
// in-flight buffer and continues on the already-positioned reader — the
// scan skips one open and one chunk-read of dead time.
//
// Correctness: adoption is guarded by the file's (device, inode)
// identity plus a length check (a short warmed chunk is only valid if
// the file still ends where it did), so a file replaced by rename or
// appended to since the hint was posted is detected and the hint
// discarded — a hint can change *when* bytes move, never *which* bytes a
// scan observes. Metering: the warm is charged exactly like the
// first-chunk read it replaces (one open + one chunk through the metered
// reader), so an adopted hint leaves byte/seek totals identical to an
// unhinted run; only a *wasted* hint adds I/O, which
// [`PipelineStats`] counts.

/// One slot of the hint cache.
#[derive(Debug)]
struct HintSlot {
    rel: PathBuf,
    state: HintState,
}

#[derive(Debug)]
enum HintState {
    /// Accepted; the read lane has not warmed it yet.
    Pending,
    /// Warmed and ready to adopt.
    Ready {
        /// (device, inode) of the file the bytes were read from.
        file_id: (u64, u64),
        /// Chunk geometry the warm used (must match the consumer's).
        chunk_bytes: usize,
        /// The file's first `chunk.len()` bytes (short only at EOF).
        chunk: Vec<u8>,
        /// The open reader positioned after `chunk` — kept open even at
        /// EOF, because the held fd pins the warmed inode and makes the
        /// `file_id` staleness check sound against inode recycling.
        rest: Option<DetachedReader>,
    },
}

/// Outcome of a [`HintCache::take`].
enum HintTake {
    /// No slot for this path (or it is still warming).
    Miss,
    /// A ready slot existed but no longer serves this consumer (file
    /// identity changed, or wrong chunk geometry) — evicted so it cannot
    /// wedge the bounded cache; the caller counts the waste.
    Stale,
    /// Adopt these.
    Hit { chunk: Vec<u8>, rest: Option<DetachedReader> },
}

/// Bounded store of warmed prefetch hints for one node. Owned by the
/// node's [`NodeDisk`]; capacity is the pipeline depth, so hint buffers
/// obey the same budget as every other stream's chunks.
#[derive(Debug)]
pub(crate) struct HintCache {
    slots: Mutex<Vec<HintSlot>>,
    cap: usize,
}

impl HintCache {
    pub(crate) fn new(cap: usize) -> HintCache {
        HintCache { slots: Mutex::new(Vec::new()), cap }
    }

    /// Reserve a pending slot for `rel`. A full cache evicts its oldest
    /// **ready** slot first — a stale leftover (warmed for a file nobody
    /// re-opened) must not wedge hinting for the rest of the run; a cache
    /// full of still-warming slots drops the new hint instead. Returns
    /// `(accepted, ready_slots_evicted)`; not accepted also covers a
    /// duplicate path.
    fn reserve(&self, rel: &Path) -> (bool, u64) {
        let mut g = lock_ignore_poison(&self.slots);
        if g.iter().any(|s| s.rel == rel) {
            return (false, 0);
        }
        let mut evicted = 0u64;
        while g.len() >= self.cap {
            match g.iter().position(|s| matches!(s.state, HintState::Ready { .. })) {
                Some(i) => {
                    g.remove(i);
                    evicted += 1;
                }
                None => return (false, evicted),
            }
        }
        g.push(HintSlot { rel: rel.to_path_buf(), state: HintState::Pending });
        (true, evicted)
    }

    /// Warm the pending slot for `rel` (called by the read-lane job).
    fn fill(
        &self,
        rel: &Path,
        file_id: (u64, u64),
        chunk_bytes: usize,
        chunk: Vec<u8>,
        rest: Option<DetachedReader>,
    ) {
        let mut g = lock_ignore_poison(&self.slots);
        if let Some(s) = g.iter_mut().find(|s| s.rel == rel) {
            s.state = HintState::Ready { file_id, chunk_bytes, chunk, rest };
        }
    }

    /// Drop the pending slot for `rel` (warm failed).
    fn abandon(&self, rel: &Path) {
        let mut g = lock_ignore_poison(&self.slots);
        g.retain(|s| s.rel != rel);
    }

    /// Consume the slot for `rel` if it is ready, matches the consumer's
    /// chunk geometry, and still describes the live file (`live_id`,
    /// `live_len`).
    fn take(
        &self,
        rel: &Path,
        chunk_bytes: usize,
        live_id: (u64, u64),
        live_len: u64,
    ) -> HintTake {
        let mut g = lock_ignore_poison(&self.slots);
        let Some(i) = g.iter().position(|s| s.rel == rel) else {
            return HintTake::Miss;
        };
        let fresh = match &g[i].state {
            // still warming; leave it
            HintState::Pending => return HintTake::Miss,
            HintState::Ready { file_id, chunk_bytes: cb, chunk, .. } => {
                // wrong chunk geometry counts as stale too: this
                // consumer *is* the file's next reader, so a slot it
                // cannot serve would otherwise sit in the bounded cache
                // until teardown
                *cb == chunk_bytes
                    && live_id != (0, 0)
                    && *file_id == live_id
                    // a short warmed chunk claims "this is the whole
                    // file" — only true while the length is unchanged
                    && (chunk.len() == *cb || chunk.len() as u64 == live_len)
            }
        };
        let slot = g.remove(i);
        match slot.state {
            HintState::Ready { chunk, rest, .. } if fresh => HintTake::Hit { chunk, rest },
            _ => HintTake::Stale,
        }
    }

    /// Cheap membership probe (one lock, ≤ cap comparisons) — lets every
    /// stream open without a hint for its path skip the identity stats
    /// entirely (op-log drains, sort runs, and plain scans all come
    /// through `ChunkFetcher::open` while the cache is non-empty).
    fn contains(&self, rel: &Path) -> bool {
        lock_ignore_poison(&self.slots).iter().any(|s| s.rel == rel)
    }

    /// Whether `rel`'s hint is warmed and waiting (test synchronization).
    #[cfg(test)]
    pub(crate) fn is_ready(&self, rel: &Path) -> bool {
        lock_ignore_poison(&self.slots)
            .iter()
            .any(|s| s.rel == rel && matches!(s.state, HintState::Ready { .. }))
    }

    /// Drop every slot, returning how many there were (teardown waste
    /// accounting).
    pub(crate) fn clear(&self) -> u64 {
        let mut g = lock_ignore_poison(&self.slots);
        let n = g.len() as u64;
        g.clear();
        n
    }
}

/// Post a prefetch hint for `rel` on `disk` (see
/// [`NodeDisk::hint_prefetch`] — the public entry point). Best-effort:
/// every failure path just drops the hint.
pub(crate) fn post_hint(disk: &Arc<NodeDisk>, rel: &Path) {
    let Some(service) = disk.io_service() else { return };
    // One stat up front keeps never-created bucket files (empty shards)
    // from becoming lane traffic and waste noise.
    if !disk.exists(rel) {
        return;
    }
    let (accepted, evicted) = disk.hints().reserve(rel);
    if evicted > 0 {
        disk.pipe_stats().add_hint_wastes(evicted);
    }
    if !accepted {
        return; // duplicate, or a cache full of in-flight warms
    }
    disk.pipe_stats().add_hint_posted();
    let disk2 = Arc::clone(disk);
    let rel2 = rel.to_path_buf();
    let job: Job = Box::new(move || {
        let warmed = (|| -> Result<((u64, u64), Vec<u8>, Option<DetachedReader>)> {
            let mut r = disk2.open_file_shared(&rel2)?;
            let id = r.file_id();
            let mut chunk = scratch::take_chunk_vec(PIPE_CHUNK);
            chunk.resize(PIPE_CHUNK, 0);
            let n = r.read_fully(&mut chunk)?;
            chunk.truncate(n);
            // a short warm (whole file < one chunk) keeps only what it
            // holds — the adopting stream's buffer accounting sees the
            // real footprint
            chunk.shrink_to_fit();
            if n > 0 {
                disk2.pipe_stats().add_read_ahead(n as u64);
            }
            // The open reader is kept even at EOF: the held fd PINS the
            // warmed inode, which is what makes the (dev, ino) identity
            // check at take time sound — a closed handle would let the
            // filesystem recycle the inode into a replacement file and
            // fake a match. (Post-EOF fills through it just read 0.)
            let rest = Some(r.detach());
            Ok((id, chunk, rest))
        })();
        match warmed {
            Ok((id, chunk, rest)) => {
                disk2.hints().fill(&rel2, id, PIPE_CHUNK, chunk, rest)
            }
            Err(_) => {
                disk2.hints().abandon(&rel2);
                disk2.pipe_stats().add_hint_wastes(1);
            }
        }
    });
    if service.submit_read(job).is_err() {
        disk.hints().abandon(rel);
        disk.pipe_stats().add_hint_wastes(1);
    }
}

/// Try to adopt a warmed hint for `rel`: validate it against the live
/// file's identity and hand back (first chunk, reattached reader).
/// `None` = no usable hint; the caller opens normally.
fn take_hint(
    disk: &Arc<NodeDisk>,
    rel: &Path,
    chunk_bytes: usize,
) -> Option<(Vec<u8>, Option<SharedMeteredReader>)> {
    if disk.io_service().is_none() || !disk.hints().contains(rel) {
        return None;
    }
    let live_id = path_file_id(&disk.root().join(rel));
    let live_len = disk.len(rel);
    match disk.hints().take(rel, chunk_bytes, live_id, live_len) {
        HintTake::Hit { chunk, rest } => {
            disk.pipe_stats().add_hint_hit();
            trace::instant(trace::Kind::HintHit, "pipe.hint_hit", Some(disk.node()), 0, 0);
            Some((chunk, rest.map(|d| SharedMeteredReader::reattach(Arc::clone(disk), d))))
        }
        HintTake::Stale => {
            disk.pipe_stats().add_hint_wastes(1);
            None
        }
        HintTake::Miss => None,
    }
}

// ---------------------------------------------------------------------
// Read side: chunk fetcher + record/byte wrappers
// ---------------------------------------------------------------------

/// State shared between a reading stream's consumer and its queued fill
/// jobs. `reader` becomes `None` at EOF or on error, turning any
/// still-queued fill into a no-op.
struct ReadShared {
    reader: Mutex<Option<SharedMeteredReader>>,
    cancelled: AtomicBool,
    /// Total buffer bytes this stream has allocated (for the peak metric).
    alloc: AtomicUsize,
}

/// Owned, overlapped chunk stream: up to `depth` chunk buffers circulate
/// between this consumer and the node's read lane.
struct ChunkFetcher {
    disk: Arc<NodeDisk>,
    shared: Arc<ReadShared>,
    data_rx: Receiver<Result<Vec<u8>>>,
    data_tx: Sender<Result<Vec<u8>>>,
    chunk_bytes: usize,
    cur: Vec<u8>,
    pos: usize,
    /// The current chunk was short: nothing follows it.
    last: bool,
    eof: bool,
    failed: bool,
    /// Set when a warmed prefetch hint was adopted as the first in-flight
    /// chunk: the first refill receives it without donating a fill, so
    /// the circulating buffer count stays at `depth`.
    skip_submit_once: bool,
}

impl ChunkFetcher {
    fn open(disk: &Arc<NodeDisk>, rel: impl AsRef<Path>, chunk_bytes: usize) -> Result<Self> {
        let chunk_bytes = chunk_bytes.max(1);
        // Adopt a warmed cross-task prefetch hint when one matches this
        // exact file + chunk geometry; otherwise open fresh.
        let adopted = take_hint(disk, rel.as_ref(), chunk_bytes);
        let (reader, warm) = match adopted {
            Some((chunk, rest)) => (rest, Some(chunk)),
            None => (Some(disk.open_file_shared(&rel)?), None),
        };
        let (data_tx, data_rx) = channel();
        let f = ChunkFetcher {
            disk: Arc::clone(disk),
            shared: Arc::new(ReadShared {
                reader: Mutex::new(reader),
                cancelled: AtomicBool::new(false),
                alloc: AtomicUsize::new(0),
            }),
            data_rx,
            data_tx,
            chunk_bytes,
            cur: Vec::new(),
            pos: 0,
            last: false,
            eof: false,
            failed: false,
            skip_submit_once: false,
        };
        f.disk.pipe_stats().add_stream();
        let mut f = f;
        if let Some(chunk) = warm {
            // The warmed chunk becomes in-flight chunk 0: it is sent
            // before any fill job can run, so stream FIFO order holds
            // (fills continue on the already-positioned reader).
            let cap = chunk.capacity();
            f.shared.alloc.store(cap, Ordering::Relaxed);
            f.disk.pipe_stats().note_stream_buf(cap as u64);
            f.skip_submit_once = true;
            let _ = f.data_tx.send(Ok(chunk));
        }
        // Prime the read-ahead: depth - 1 buffers go to the lane, the
        // depth-th is `cur` (donated on the first refill) — or, with an
        // adopted hint, the warmed chunk (whose receipt skips one
        // donation instead). Buffers come from the scratch pool;
        // pre-sized checkouts are charged to the stream's allocation
        // accounting here (the fill job's grow-metering only sees
        // capacity it adds itself).
        for _ in 1..f.disk.effective_depth().max(1) {
            let buf = scratch::take_chunk_vec(f.chunk_bytes);
            let cap = buf.capacity();
            if cap > 0 {
                let tot = f.shared.alloc.fetch_add(cap, Ordering::Relaxed) + cap;
                f.disk.pipe_stats().note_stream_buf(tot as u64);
            }
            f.submit_fill(buf)?;
        }
        Ok(f)
    }

    fn submit_fill(&self, buf: Vec<u8>) -> Result<()> {
        let shared = Arc::clone(&self.shared);
        let tx = self.data_tx.clone();
        let stats = Arc::clone(self.disk.pipe_stats());
        let chunk_bytes = self.chunk_bytes;
        let job: Job = Box::new(move || {
            let mut buf = buf;
            let out: Result<Vec<u8>>;
            if shared.cancelled.load(Ordering::Relaxed) {
                buf.clear();
                out = Ok(buf);
            } else {
                let mut g = lock_ignore_poison(&shared.reader);
                match g.as_mut() {
                    None => {
                        // EOF (or error) already hit by an earlier fill.
                        buf.clear();
                        out = Ok(buf);
                    }
                    Some(r) => {
                        let cap0 = buf.capacity();
                        buf.resize(chunk_bytes, 0);
                        let grew = buf.capacity().saturating_sub(cap0);
                        if grew > 0 {
                            let tot = shared.alloc.fetch_add(grew, Ordering::Relaxed) + grew;
                            stats.note_stream_buf(tot as u64);
                        }
                        match r.read_fully(&mut buf) {
                            Ok(n) => {
                                buf.truncate(n);
                                if n < chunk_bytes {
                                    *g = None; // EOF: close the file early
                                }
                                if n > 0 {
                                    stats.add_read_ahead(n as u64);
                                }
                                out = Ok(buf);
                            }
                            Err(e) => {
                                *g = None;
                                out = Err(e);
                            }
                        }
                    }
                }
            }
            if let Err(lost) = tx.send(out) {
                // Consumer gone (stream dropped mid-flight): park the
                // buffer instead of leaking the allocation to the heap.
                if let Ok(buf) = lost.0 {
                    scratch::put_chunk_vec(buf);
                }
            }
        });
        self.disk
            .io_service()
            .ok_or_else(|| pipeline_err("pipelined stream on a disk without an io service"))?
            .submit_read(job)
    }

    /// Advance to the next chunk. `Ok(false)` = EOF.
    fn refill(&mut self) -> Result<bool> {
        if self.failed {
            return Err(pipeline_err("prefetch stream already failed"));
        }
        if self.eof {
            return Ok(false);
        }
        if self.last {
            self.eof = true;
            return Ok(false);
        }
        // Donate the consumed buffer as the next read-ahead slot, then
        // block for the oldest in-flight chunk. When an adopted hint is
        // the oldest chunk, skip one donation instead — the hint already
        // occupies the slot this donation would have created.
        let donated = std::mem::take(&mut self.cur);
        self.pos = 0;
        if self.skip_submit_once {
            self.skip_submit_once = false;
        } else {
            self.submit_fill(donated)?;
        }
        let t0 = Instant::now();
        let msg = self
            .data_rx
            .recv_timeout(DRAIN_TIMEOUT)
            .map_err(|_| pipeline_err("read-ahead lane stalled"))?;
        let waited = t0.elapsed();
        self.disk.pipe_stats().add_reader_wait(waited);
        hist::record(hist::Domain::ReaderStall, self.disk.node(), waited);
        if waited >= STALL_TRACE_MIN {
            trace::complete_since(
                trace::Kind::ReaderStall,
                "pipe.read_stall",
                Some(self.disk.node()),
                t0,
                0,
                0,
            );
        }
        match msg {
            Ok(chunk) => {
                if chunk.len() < self.chunk_bytes {
                    self.last = true;
                }
                self.cur = chunk;
                if self.cur.is_empty() {
                    self.eof = true;
                    return Ok(false);
                }
                Ok(true)
            }
            Err(e) => {
                self.failed = true;
                Err(e)
            }
        }
    }

    /// Fill `out` as far as possible from the chunk stream; returns bytes
    /// copied, which is < `out.len()` only at EOF.
    fn read_fully(&mut self, out: &mut [u8]) -> Result<usize> {
        let mut got = 0;
        while got < out.len() {
            if self.pos == self.cur.len() && !self.refill()? {
                break;
            }
            let n = (out.len() - got).min(self.cur.len() - self.pos);
            out[got..got + n].copy_from_slice(&self.cur[self.pos..self.pos + n]);
            self.pos += n;
            got += n;
        }
        Ok(got)
    }
}

impl Drop for ChunkFetcher {
    fn drop(&mut self) {
        // Still-queued fill jobs become no-ops; the file handle is
        // released by whichever job (or this drop) holds the state last.
        self.shared.cancelled.store(true, Ordering::Relaxed);
        // Park every buffer we still have custody of: the consumer's
        // chunk and everything already delivered. In-flight fills park
        // their own buffer when their send fails (receiver gone).
        scratch::put_chunk_vec(std::mem::take(&mut self.cur));
        while let Ok(msg) = self.data_rx.try_recv() {
            if let Ok(buf) = msg {
                scratch::put_chunk_vec(buf);
            }
        }
    }
}

/// Owned streaming byte reader: read-ahead when the disk has a pipeline,
/// a plain metered reader otherwise. No record framing — used for the
/// delayed-op spill segments ([`crate::storage::buffer::SpillDrain`]).
pub struct ByteReader {
    inner: ByteReaderInner,
}

enum ByteReaderInner {
    Direct(SharedMeteredReader),
    Ahead(ChunkFetcher),
}

impl ByteReader {
    /// Open `rel` on `disk` for owned streaming reads.
    pub fn open(disk: &Arc<NodeDisk>, rel: impl AsRef<Path>) -> Result<ByteReader> {
        let inner = if disk.io_service().is_some() {
            ByteReaderInner::Ahead(ChunkFetcher::open(disk, rel, PIPE_CHUNK)?)
        } else {
            ByteReaderInner::Direct(disk.open_file_shared(rel)?)
        };
        Ok(ByteReader { inner })
    }

    /// Fill `buf` as far as possible; returns bytes read, < `buf.len()`
    /// only at EOF.
    pub fn read_fully(&mut self, buf: &mut [u8]) -> Result<usize> {
        match &mut self.inner {
            ByteReaderInner::Direct(r) => r.read_fully(buf),
            ByteReaderInner::Ahead(f) => f.read_fully(buf),
        }
    }
}

/// Read the whole of `rel` into RAM — the whole-bucket load the array /
/// bit-array sync paths use (a bucket is the unit Roomy sizes to fit in
/// memory). On a pipelined disk the bytes stream through the read-ahead
/// lane, so the caller overlaps with the tail of the file; without a
/// service this is exactly [`NodeDisk::read_all`].
pub fn read_all_pipelined(disk: &Arc<NodeDisk>, rel: impl AsRef<Path>) -> Result<Vec<u8>> {
    if disk.io_service().is_none() {
        return disk.read_all(rel);
    }
    let mut r = ByteReader::open(disk, &rel)?;
    let mut out = Vec::with_capacity(disk.len(&rel) as usize);
    let mut buf = scratch::chunk_buf(PIPE_CHUNK);
    buf.resize(PIPE_CHUNK, 0);
    loop {
        let n = r.read_fully(&mut buf)?;
        out.extend_from_slice(&buf[..n]);
        if n < buf.len() {
            return Ok(out);
        }
    }
}

/// Write `data` to `rel` atomically (staging + rename) — the whole-bucket
/// store counterpart of [`read_all_pipelined`]. On a pipelined disk the
/// chunks flush through the write-behind lane while the caller returns to
/// compute; without a service this is exactly [`NodeDisk::write_all`].
pub fn write_all_pipelined(
    disk: &Arc<NodeDisk>,
    rel: impl AsRef<Path>,
    data: &[u8],
) -> Result<()> {
    if disk.io_service().is_none() {
        return disk.write_all(rel, data);
    }
    let mut f = ChunkFlusher::open(disk, rel, false)?;
    f.push(data)?;
    f.finish()
}

/// Streaming reader of fixed-size records with read-ahead.
///
/// Depth 0 (or a disk without a service) is exactly
/// [`RecordReader`](crate::storage::RecordReader); otherwise chunks are
/// prefetched through the node's read lane.
pub struct PrefetchReader<'d> {
    inner: PfInner<'d>,
    rec_size: usize,
}

enum PfInner<'d> {
    Sync(RecordReader<'d>),
    Ahead(ChunkFetcher),
}

impl<'d> PrefetchReader<'d> {
    /// Open `rel`; errors if the file length is not a record multiple.
    pub fn open(disk: &'d Arc<NodeDisk>, rel: impl AsRef<Path>, rec_size: usize) -> Result<Self> {
        Self::open_with_chunk(disk, rel, rec_size, PIPE_CHUNK)
    }

    /// Like [`PrefetchReader::open`] with an explicit chunk size — k-way
    /// merges divide the chunk by k so a merge's total pipeline RAM stays
    /// O(depth × [`PIPE_CHUNK`]) regardless of fan-in.
    pub fn open_with_chunk(
        disk: &'d Arc<NodeDisk>,
        rel: impl AsRef<Path>,
        rec_size: usize,
        chunk_bytes: usize,
    ) -> Result<Self> {
        assert!(rec_size > 0);
        if disk.io_service().is_none() {
            return Ok(PrefetchReader {
                inner: PfInner::Sync(RecordReader::open(disk, rel, rec_size)?),
                rec_size,
            });
        }
        let len = disk.len(&rel);
        if !len.is_multiple_of(rec_size as u64) {
            return Err(RoomyError::InvalidArg(format!(
                "file {:?} length {len} is not a multiple of record size {rec_size}",
                rel.as_ref()
            )));
        }
        let chunk = chunk_bytes.clamp(rec_size, PIPE_CHUNK.max(rec_size));
        Ok(PrefetchReader {
            inner: PfInner::Ahead(ChunkFetcher::open(disk, rel, chunk)?),
            rec_size,
        })
    }

    /// Record size in bytes.
    pub fn rec_size(&self) -> usize {
        self.rec_size
    }

    /// Read up to `max` records into `out` (cleared first). Returns the
    /// number of records read; 0 = EOF.
    pub fn read_batch(&mut self, out: &mut Vec<u8>, max: usize) -> Result<usize> {
        match &mut self.inner {
            PfInner::Sync(r) => r.read_batch(out, max),
            PfInner::Ahead(f) => {
                out.clear();
                out.resize(max * self.rec_size, 0);
                let n = f.read_fully(out)?;
                if n % self.rec_size != 0 {
                    return Err(RoomyError::InvalidArg(format!(
                        "truncated record ({n} bytes) in prefetch stream"
                    )));
                }
                out.truncate(n);
                Ok(n / self.rec_size)
            }
        }
    }

    /// Read one record into `rec`; Ok(false) = EOF.
    pub fn read_one(&mut self, rec: &mut [u8]) -> Result<bool> {
        debug_assert_eq!(rec.len(), self.rec_size);
        match &mut self.inner {
            PfInner::Sync(r) => r.read_one(rec),
            PfInner::Ahead(f) => {
                let n = f.read_fully(rec)?;
                match n {
                    0 => Ok(false),
                    n if n == self.rec_size => Ok(true),
                    n => Err(RoomyError::InvalidArg(format!(
                        "truncated record ({n} bytes) in prefetch stream"
                    ))),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Write side: chunk flusher + record wrapper
// ---------------------------------------------------------------------

/// State shared between a writing stream's producer and its queued write
/// jobs.
struct WriteShared {
    slot: Mutex<WriteSlot>,
    /// Fast-path error flag so the producer never touches `slot` (whose
    /// lock is held across throttled writes) on the hot path.
    has_err: AtomicBool,
    cancelled: AtomicBool,
    alloc: AtomicUsize,
}

struct WriteSlot {
    w: Option<SharedMeteredWriter>,
    err: Option<RoomyError>,
}

/// Owned, overlapped chunk sink: up to `depth` chunk buffers circulate
/// between this producer and the node's write lane.
struct ChunkFlusher {
    disk: Arc<NodeDisk>,
    shared: Arc<WriteShared>,
    pool_rx: Receiver<Vec<u8>>,
    pool_tx: Sender<Vec<u8>>,
    cur: Vec<u8>,
    /// Capacity of `cur` when it was taken (allocation accounting).
    cur_cap0: usize,
    chunk_bytes: usize,
    /// Buffers we may still allocate lazily (depth − 1; `cur` is one).
    spare_budget: usize,
    /// Write jobs submitted whose buffers have not come back yet.
    outstanding: usize,
    /// `Some` in create mode: bytes go here, renamed to `target` at
    /// finish, removed on abandoning drop.
    staging: Option<PathBuf>,
    target: PathBuf,
    finished: bool,
}

impl ChunkFlusher {
    fn open(disk: &Arc<NodeDisk>, rel: impl AsRef<Path>, append: bool) -> Result<Self> {
        let target = rel.as_ref().to_path_buf();
        let (writer, staging) = if append {
            (disk.append_file_shared(&target)?, None)
        } else {
            let staging = PathBuf::from(format!(
                "tmp/pipeline/n{}-{}.pstage",
                disk.node(),
                STAGING_ID.fetch_add(1, Ordering::Relaxed)
            ));
            (disk.create_file_shared(&staging)?, Some(staging))
        };
        let (pool_tx, pool_rx) = channel();
        disk.pipe_stats().add_stream();
        let shared = Arc::new(WriteShared {
            slot: Mutex::new(WriteSlot { w: Some(writer), err: None }),
            has_err: AtomicBool::new(false),
            cancelled: AtomicBool::new(false),
            alloc: AtomicUsize::new(0),
        });
        // The producer's first chunk buffer comes from the scratch
        // pool; charge its capacity up front (flush_cur's grow-metering
        // only sees capacity added past `cur_cap0`).
        let cur = scratch::take_chunk_vec(PIPE_CHUNK);
        let cur_cap0 = cur.capacity();
        if cur_cap0 > 0 {
            shared.alloc.store(cur_cap0, Ordering::Relaxed);
            disk.pipe_stats().note_stream_buf(cur_cap0 as u64);
        }
        Ok(ChunkFlusher {
            disk: Arc::clone(disk),
            shared,
            pool_rx,
            pool_tx,
            cur,
            cur_cap0,
            chunk_bytes: PIPE_CHUNK,
            spare_budget: disk.effective_depth().max(1) - 1,
            outstanding: 0,
            staging,
            target,
            finished: false,
        })
    }

    fn push(&mut self, data: &[u8]) -> Result<()> {
        if self.shared.has_err.load(Ordering::Relaxed) {
            return self.take_err();
        }
        let mut data = data;
        // Oversized batches are cut at chunk boundaries so one push never
        // grows a buffer past the chunk size.
        while !data.is_empty() {
            if self.cur.len() >= self.chunk_bytes {
                self.flush_cur()?;
            }
            let room = self.chunk_bytes - self.cur.len();
            let n = room.min(data.len());
            self.cur.extend_from_slice(&data[..n]);
            data = &data[n..];
        }
        if self.cur.len() >= self.chunk_bytes {
            self.flush_cur()?;
        }
        Ok(())
    }

    /// Surface (and consume) the deferred lane error.
    fn take_err(&mut self) -> Result<()> {
        let e = lock_ignore_poison(&self.shared.slot).err.take();
        Err(e.unwrap_or_else(|| pipeline_err("write-behind stream already failed")))
    }

    fn flush_cur(&mut self) -> Result<()> {
        if self.cur.is_empty() {
            return Ok(());
        }
        let grew = self.cur.capacity().saturating_sub(self.cur_cap0);
        if grew > 0 {
            let tot = self.shared.alloc.fetch_add(grew, Ordering::Relaxed) + grew;
            self.disk.pipe_stats().note_stream_buf(tot as u64);
        }
        // Submit first, then acquire the next buffer: at depth 1 the only
        // buffer comes back from the job just submitted.
        let full = std::mem::take(&mut self.cur);
        self.submit_write(full)?;
        self.cur = self.take_buffer()?;
        self.cur_cap0 = self.cur.capacity();
        Ok(())
    }

    /// A free buffer: reuse a returned one, allocate while under the depth
    /// budget, else block until the lane returns one (backpressure).
    fn take_buffer(&mut self) -> Result<Vec<u8>> {
        if let Ok(b) = self.pool_rx.try_recv() {
            self.outstanding -= 1;
            return Ok(b);
        }
        if self.spare_budget > 0 {
            self.spare_budget -= 1;
            let b = scratch::take_chunk_vec(self.chunk_bytes);
            let cap = b.capacity();
            if cap > 0 {
                let tot = self.shared.alloc.fetch_add(cap, Ordering::Relaxed) + cap;
                self.disk.pipe_stats().note_stream_buf(tot as u64);
            }
            return Ok(b);
        }
        if self.outstanding == 0 {
            // Defensive: nothing in flight could ever return a buffer.
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let b = self
            .pool_rx
            .recv_timeout(DRAIN_TIMEOUT)
            .map_err(|_| pipeline_err("write-behind lane stalled"))?;
        let waited = t0.elapsed();
        self.disk.pipe_stats().add_writer_wait(waited);
        hist::record(hist::Domain::WriterStall, self.disk.node(), waited);
        if waited >= STALL_TRACE_MIN {
            trace::complete_since(
                trace::Kind::WriterStall,
                "pipe.write_stall",
                Some(self.disk.node()),
                t0,
                0,
                0,
            );
        }
        self.outstanding -= 1;
        Ok(b)
    }

    fn submit_write(&mut self, buf: Vec<u8>) -> Result<()> {
        let shared = Arc::clone(&self.shared);
        let tx = self.pool_tx.clone();
        let stats = Arc::clone(self.disk.pipe_stats());
        let job: Job = Box::new(move || {
            let mut buf = buf;
            if !shared.cancelled.load(Ordering::Relaxed) {
                let mut slot = lock_ignore_poison(&shared.slot);
                if slot.err.is_none() {
                    if let Some(w) = slot.w.as_mut() {
                        match w.write_bytes(&buf) {
                            Ok(()) => stats.add_write_behind(buf.len() as u64),
                            Err(e) => {
                                slot.err = Some(e);
                                shared.has_err.store(true, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
            buf.clear();
            // The buffer returns to the producer; if the producer is
            // gone (abandoned stream past its drain), park it instead.
            if let Err(lost) = tx.send(buf) {
                scratch::put_chunk_vec(lost.0);
            }
        });
        self.disk
            .io_service()
            .ok_or_else(|| pipeline_err("pipelined stream on a disk without an io service"))?
            .submit_write(job)?;
        self.outstanding += 1;
        Ok(())
    }

    /// Wait until every submitted chunk has been written; the returned
    /// buffers go back to the scratch pool (this stream is done with
    /// them).
    fn drain(&mut self) -> Result<()> {
        while self.outstanding > 0 {
            let b = self
                .pool_rx
                .recv_timeout(DRAIN_TIMEOUT)
                .map_err(|_| pipeline_err("write-behind lane stalled in drain"))?;
            scratch::put_chunk_vec(b);
            self.outstanding -= 1;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        let result = (|| {
            self.flush_cur()?;
            self.drain()?;
            let w = {
                let mut slot = lock_ignore_poison(&self.shared.slot);
                if let Some(e) = slot.err.take() {
                    return Err(e);
                }
                slot.w.take()
            };
            if let Some(w) = w {
                w.finish()?;
            }
            if let Some(staging) = self.staging.take() {
                if let Err(e) = self.disk.rename(&staging, &self.target) {
                    // Put the path back so the error-path cleanup below
                    // still removes the staging file.
                    self.staging = Some(staging);
                    return Err(e);
                }
            }
            Ok(())
        })();
        // Success or failure, this stream is done: Drop must not try to
        // clean up again, but a failed create must not leak its staging.
        self.finished = true;
        scratch::put_chunk_vec(std::mem::take(&mut self.cur));
        if result.is_err() {
            if let Some(staging) = self.staging.take() {
                let _ = self.disk.remove(&staging);
            }
        }
        result
    }
}

impl Drop for ChunkFlusher {
    /// Abandoned stream (task error / worker panic): stop the lane from
    /// writing more, wait for in-flight chunks, close the file and remove
    /// the staging file — the destination is never touched in create
    /// mode, and `tmp/pipeline/` is left clean.
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        self.shared.cancelled.store(true, Ordering::Relaxed);
        while self.outstanding > 0 {
            match self.pool_rx.recv_timeout(DRAIN_TIMEOUT) {
                Ok(b) => {
                    scratch::put_chunk_vec(b);
                    self.outstanding -= 1;
                }
                Err(_) => break, // lane wedged; still try to clean up
            }
        }
        scratch::put_chunk_vec(std::mem::take(&mut self.cur));
        lock_ignore_poison(&self.shared.slot).w = None; // close the file
        if let Some(staging) = self.staging.take() {
            let _ = self.disk.remove(&staging);
        }
    }
}

/// Streaming writer of fixed-size records with write-behind.
///
/// Depth 0 (or a disk without a service) is exactly
/// [`RecordWriter`](crate::storage::RecordWriter); otherwise completed
/// chunks flush through the node's write lane while the producer keeps
/// going, and `finish()` drains the lane (create mode additionally
/// renames the staging file onto the destination).
pub struct WriteBehindWriter<'d> {
    inner: WbInner<'d>,
    rec_size: usize,
    written: u64,
}

enum WbInner<'d> {
    Sync(RecordWriter<'d>),
    Behind(ChunkFlusher),
}

impl<'d> WriteBehindWriter<'d> {
    /// Create/truncate `rel` on `disk` for records of `rec_size` bytes.
    pub fn create(disk: &'d Arc<NodeDisk>, rel: impl AsRef<Path>, rec_size: usize) -> Result<Self> {
        assert!(rec_size > 0);
        let inner = if disk.io_service().is_some() {
            WbInner::Behind(ChunkFlusher::open(disk, rel, false)?)
        } else {
            WbInner::Sync(RecordWriter::create(disk, rel, rec_size)?)
        };
        Ok(WriteBehindWriter { inner, rec_size, written: 0 })
    }

    /// Open `rel` for appending records of `rec_size` bytes. Append mode
    /// writes the destination in place (no staging): an abandoned stream
    /// has the same torn-tail semantics as the synchronous path.
    pub fn append(disk: &'d Arc<NodeDisk>, rel: impl AsRef<Path>, rec_size: usize) -> Result<Self> {
        assert!(rec_size > 0);
        let inner = if disk.io_service().is_some() {
            WbInner::Behind(ChunkFlusher::open(disk, rel, true)?)
        } else {
            WbInner::Sync(RecordWriter::append(disk, rel, rec_size)?)
        };
        Ok(WriteBehindWriter { inner, rec_size, written: 0 })
    }

    /// Write one record (must be exactly `rec_size` bytes).
    pub fn push(&mut self, rec: &[u8]) -> Result<()> {
        debug_assert_eq!(rec.len(), self.rec_size);
        match &mut self.inner {
            WbInner::Sync(w) => w.push(rec)?,
            WbInner::Behind(f) => {
                f.push(rec)?;
                self.written += 1;
            }
        }
        Ok(())
    }

    /// Write a batch of concatenated records.
    pub fn push_batch(&mut self, recs: &[u8]) -> Result<()> {
        debug_assert_eq!(recs.len() % self.rec_size, 0);
        match &mut self.inner {
            WbInner::Sync(w) => w.push_batch(recs)?,
            WbInner::Behind(f) => {
                f.push(recs)?;
                self.written += (recs.len() / self.rec_size) as u64;
            }
        }
        Ok(())
    }

    /// Records written through this writer.
    pub fn written(&self) -> u64 {
        match &self.inner {
            WbInner::Sync(w) => w.written(),
            WbInner::Behind(_) => self.written,
        }
    }

    /// Drain, flush and close; in overlapped create mode the destination
    /// appears (atomically, via rename) only now.
    pub fn finish(self) -> Result<()> {
        match self.inner {
            WbInner::Sync(w) => w.finish(),
            WbInner::Behind(mut f) => f.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DiskPolicy;
    use crate::testutil::{files_under, tmpdir};

    fn plain_disk(dir: &Path) -> Arc<NodeDisk> {
        Arc::new(NodeDisk::create(0, dir, DiskPolicy::unthrottled()).unwrap())
    }

    fn piped_disk(dir: &Path, depth: usize) -> Arc<NodeDisk> {
        Arc::new(
            NodeDisk::create_with_depth(0, dir, DiskPolicy::unthrottled(), depth).unwrap(),
        )
    }

    fn write_recs(d: &Arc<NodeDisk>, rel: &str, n: u32) {
        let mut w = RecordWriter::create(d, rel, 4).unwrap();
        for i in 0..n {
            w.push(&i.to_le_bytes()).unwrap();
        }
        w.finish().unwrap();
    }

    fn read_recs(d: &Arc<NodeDisk>, rel: &str) -> Vec<u32> {
        let mut r = PrefetchReader::open(d, rel, 4).unwrap();
        let mut out = Vec::new();
        let mut buf = Vec::new();
        loop {
            let n = r.read_batch(&mut buf, 1000).unwrap();
            if n == 0 {
                return out;
            }
            for rec in buf.chunks_exact(4) {
                out.push(u32::from_le_bytes(rec.try_into().unwrap()));
            }
        }
    }

    #[test]
    fn sync_mode_roundtrip_without_service() {
        let t = tmpdir("pipe_sync");
        let d = plain_disk(t.path());
        assert!(d.io_service().is_none());
        let mut w = WriteBehindWriter::create(&d, "f.dat", 4).unwrap();
        for i in 0u32..100 {
            w.push(&i.to_le_bytes()).unwrap();
        }
        assert_eq!(w.written(), 100);
        w.finish().unwrap();
        assert_eq!(read_recs(&d, "f.dat"), (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn overlapped_roundtrip_matches_sync_bytes() {
        let t0 = tmpdir("pipe_ref");
        let d0 = plain_disk(t0.path());
        write_recs(&d0, "f.dat", 50_000);
        let reference = d0.read_all("f.dat").unwrap();

        for depth in [1usize, 2, 4, 64] {
            let t = tmpdir(&format!("pipe_over_{depth}"));
            let d = piped_disk(t.path(), depth);
            assert!(d.io_service().is_some());
            let mut w = WriteBehindWriter::create(&d, "f.dat", 4).unwrap();
            for i in 0u32..50_000 {
                w.push(&i.to_le_bytes()).unwrap();
            }
            assert_eq!(w.written(), 50_000);
            w.finish().unwrap();
            assert_eq!(
                d.read_all("f.dat").unwrap(),
                reference,
                "depth {depth} bytes diverged"
            );
            assert_eq!(read_recs(&d, "f.dat"), (0..50_000).collect::<Vec<_>>());
            // staging is gone after finish
            assert_eq!(files_under(&t.path().join("tmp/pipeline")), 0);
        }
    }

    #[test]
    fn prefetch_read_one_and_batches_cross_chunks() {
        let t = tmpdir("pipe_read_one");
        let d = piped_disk(t.path(), 2);
        // 3-byte records with a chunk that is NOT a record multiple:
        // records must still come back whole across chunk boundaries.
        let mut w = WriteBehindWriter::create(&d, "r.dat", 3).unwrap();
        for i in 0u32..5_000 {
            w.push(&[i as u8, (i >> 8) as u8, (i >> 16) as u8]).unwrap();
        }
        w.finish().unwrap();
        let mut r = PrefetchReader::open_with_chunk(&d, "r.dat", 3, 1024).unwrap();
        let mut rec = [0u8; 3];
        for i in 0u32..5_000 {
            assert!(r.read_one(&mut rec).unwrap(), "record {i} missing");
            assert_eq!(rec, [i as u8, (i >> 8) as u8, (i >> 16) as u8]);
        }
        assert!(!r.read_one(&mut rec).unwrap());
    }

    #[test]
    fn append_mode_accumulates() {
        let t = tmpdir("pipe_append");
        let d = piped_disk(t.path(), 2);
        for round in 0u32..3 {
            let mut w = WriteBehindWriter::append(&d, "log.dat", 4).unwrap();
            w.push(&round.to_le_bytes()).unwrap();
            w.finish().unwrap();
        }
        assert_eq!(read_recs(&d, "log.dat"), vec![0, 1, 2]);
    }

    #[test]
    fn abandoned_create_leaves_no_staging_and_no_target() {
        let t = tmpdir("pipe_abandon");
        let d = piped_disk(t.path(), 2);
        {
            let mut w = WriteBehindWriter::create(&d, "out.dat", 4).unwrap();
            for i in 0u32..200_000 {
                w.push(&i.to_le_bytes()).unwrap();
            }
            // dropped without finish — simulates a panicking task
        }
        assert!(!d.exists("out.dat"), "abandoned create must not publish the target");
        assert_eq!(
            files_under(&t.path().join("tmp/pipeline")),
            0,
            "staging leak"
        );
    }

    #[test]
    fn empty_create_publishes_empty_file() {
        let t = tmpdir("pipe_empty");
        let d = piped_disk(t.path(), 4);
        WriteBehindWriter::create(&d, "e.dat", 8).unwrap().finish().unwrap();
        assert!(d.exists("e.dat"));
        assert_eq!(d.len("e.dat"), 0);
    }

    #[test]
    fn depth_larger_than_file_degrades_gracefully() {
        let t = tmpdir("pipe_tiny");
        let d = piped_disk(t.path(), 64);
        write_recs(&d, "tiny.dat", 3);
        assert_eq!(read_recs(&d, "tiny.dat"), vec![0, 1, 2]);
        // a sub-chunk file must have allocated at most one chunk buffer
        let snap = d.pipe_stats().snapshot();
        assert!(
            snap.peak_stream_buf <= PIPE_CHUNK as u64,
            "tiny stream allocated {} bytes",
            snap.peak_stream_buf
        );
    }

    #[test]
    fn stream_buffers_bounded_by_depth_times_chunk() {
        let t = tmpdir("pipe_bound");
        for depth in [1usize, 2, 4] {
            let d = piped_disk(&t.path().join(format!("d{depth}")), depth);
            write_recs(&d, "big.dat", 400_000); // ~1.5 MiB, many chunks
            let _ = read_recs(&d, "big.dat");
            let mut w = WriteBehindWriter::create(&d, "copy.dat", 4).unwrap();
            for i in 0u32..400_000 {
                w.push(&i.to_le_bytes()).unwrap();
            }
            w.finish().unwrap();
            let snap = d.pipe_stats().snapshot();
            assert!(snap.chunks_ahead > 0 && snap.chunks_behind > 0);
            assert!(
                snap.peak_stream_buf <= (depth * PIPE_CHUNK) as u64,
                "depth {depth}: peak stream buffers {} exceed {}",
                snap.peak_stream_buf,
                depth * PIPE_CHUNK
            );
        }
    }

    #[test]
    fn service_threads_exit_on_disk_drop() {
        let t = tmpdir("pipe_threads");
        let d = piped_disk(t.path(), 2);
        let flags = d.io_service().unwrap().alive_flags();
        assert_eq!(flags.len(), 2);
        assert!(flags.iter().all(|f| f.load(Ordering::SeqCst)));
        drop(d);
        assert!(
            flags.iter().all(|f| !f.load(Ordering::SeqCst)),
            "service lanes must be joined when the disk drops"
        );
    }

    #[test]
    fn byte_reader_streams_across_chunk_boundaries() {
        let t = tmpdir("pipe_bytes");
        let d = piped_disk(t.path(), 2);
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        d.write_all("b.dat", &payload).unwrap();
        let mut r = ByteReader::open(&d, "b.dat").unwrap();
        let mut got = Vec::new();
        let mut buf = [0u8; 7777]; // prime-ish size forces chunk straddling
        loop {
            let n = r.read_fully(&mut buf).unwrap();
            got.extend_from_slice(&buf[..n]);
            if n < buf.len() {
                break;
            }
        }
        assert_eq!(got, payload);
    }

    #[test]
    fn whole_file_helpers_roundtrip_at_every_depth() {
        let payload: Vec<u8> = (0..600_000u32).map(|i| (i % 249) as u8).collect();
        let mut references = Vec::new();
        for depth in [0usize, 1, 2, 4] {
            let t = tmpdir(&format!("pipe_whole_{depth}"));
            let d = if depth == 0 { plain_disk(t.path()) } else { piped_disk(t.path(), depth) };
            write_all_pipelined(&d, "w/bucket.dat", &payload).unwrap();
            assert_eq!(read_all_pipelined(&d, "w/bucket.dat").unwrap(), payload);
            // depth 0 and depth > 0 must agree byte-for-byte on disk
            references.push(d.read_all("w/bucket.dat").unwrap());
            // atomic: no staging or .tmp residue
            assert_eq!(files_under(&t.path().join("tmp")), 0);
            assert!(!d.exists("w/bucket.tmp"));
        }
        for r in &references[1..] {
            assert_eq!(r, &references[0]);
        }
    }

    #[test]
    fn whole_file_helpers_meter_and_use_lanes() {
        let t = tmpdir("pipe_whole_meter");
        let d = piped_disk(t.path(), 2);
        let payload = vec![7u8; 512 * 1024];
        write_all_pipelined(&d, "b.dat", &payload).unwrap();
        let _ = read_all_pipelined(&d, "b.dat").unwrap();
        let io = d.stats().snapshot();
        assert_eq!(io.bytes_written, payload.len() as u64);
        assert_eq!(io.bytes_read, payload.len() as u64);
        let pipe = d.pipe_stats().snapshot();
        assert!(pipe.chunks_behind > 0, "write must ride the write lane");
        assert!(pipe.chunks_ahead > 0, "read must ride the read lane");
    }

    #[test]
    fn read_error_surfaces_missing_file() {
        let t = tmpdir("pipe_missing");
        let d = piped_disk(t.path(), 2);
        assert!(PrefetchReader::open(&d, "nope.dat", 4).is_err());
        assert!(ByteReader::open(&d, "nope.dat").is_err());
    }

    /// Block until `rel`'s hint is warmed (the hint job is asynchronous on
    /// the read lane).
    fn wait_hint_ready(d: &Arc<NodeDisk>, rel: &str) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !d.hints().is_ready(Path::new(rel)) {
            assert!(Instant::now() < deadline, "hint for {rel} never warmed");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn hint_warms_first_chunk_and_scan_adopts_it() {
        // reference run without hints, for metering parity
        let t0 = tmpdir("hint_ref");
        let d0 = piped_disk(t0.path(), 2);
        write_recs(&d0, "f.dat", 200_000); // ~800 KiB, several chunks
        let r0 = d0.stats().snapshot().bytes_read;
        let s0 = d0.stats().snapshot().seeks;
        let data0 = read_recs(&d0, "f.dat");
        let read0 = d0.stats().snapshot().bytes_read - r0;
        let seeks0 = d0.stats().snapshot().seeks - s0;

        let t = tmpdir("hint_hit");
        let d = piped_disk(t.path(), 2);
        write_recs(&d, "f.dat", 200_000);
        let r1 = d.stats().snapshot().bytes_read;
        let s1 = d.stats().snapshot().seeks;
        d.hint_prefetch("f.dat");
        wait_hint_ready(&d, "f.dat");
        assert_eq!(read_recs(&d, "f.dat"), data0);
        let snap = d.pipe_stats().snapshot();
        assert_eq!(snap.hints_posted, 1);
        assert_eq!(snap.hint_hits, 1, "warmed hint must be adopted");
        assert_eq!(snap.hint_wastes, 0);
        // an adopted hint replaces the scan's own open + first-chunk
        // read, so byte and seek totals match the unhinted run exactly
        assert_eq!(d.stats().snapshot().bytes_read - r1, read0);
        assert_eq!(d.stats().snapshot().seeks - s1, seeks0);
        drop(d);
    }

    #[test]
    fn stale_hint_is_discarded_after_rewrite() {
        let t = tmpdir("hint_stale");
        let d = piped_disk(t.path(), 2);
        write_recs(&d, "f.dat", 100_000);
        d.hint_prefetch("f.dat");
        wait_hint_ready(&d, "f.dat");
        // replace-by-rename: new inode, same path
        let mut w = RecordWriter::create(&d, "f.tmp", 4).unwrap();
        for i in 0..50u32 {
            w.push(&(i + 7).to_le_bytes()).unwrap();
        }
        w.finish().unwrap();
        d.rename("f.tmp", "f.dat").unwrap();
        assert_eq!(
            read_recs(&d, "f.dat"),
            (7..57).collect::<Vec<_>>(),
            "a stale hint must never leak old bytes into a scan"
        );
        let snap = d.pipe_stats().snapshot();
        assert_eq!(snap.hint_hits, 0);
        assert!(snap.hint_wastes >= 1, "stale hint must be counted as waste");
    }

    #[test]
    fn short_file_hint_hits_and_append_invalidates() {
        let t = tmpdir("hint_short");
        let d = piped_disk(t.path(), 2);
        // a file smaller than one chunk: the warm captures all of it
        write_recs(&d, "tiny.dat", 5);
        d.hint_prefetch("tiny.dat");
        wait_hint_ready(&d, "tiny.dat");
        assert_eq!(read_recs(&d, "tiny.dat"), vec![0, 1, 2, 3, 4]);
        assert_eq!(d.pipe_stats().snapshot().hint_hits, 1);

        // same again, but the file grows before the scan arrives: the
        // short warmed chunk would truncate the scan — must be discarded
        d.hint_prefetch("tiny.dat");
        wait_hint_ready(&d, "tiny.dat");
        let mut w = RecordWriter::append(&d, "tiny.dat", 4).unwrap();
        w.push(&5u32.to_le_bytes()).unwrap();
        w.finish().unwrap();
        assert_eq!(read_recs(&d, "tiny.dat"), vec![0, 1, 2, 3, 4, 5]);
        let snap = d.pipe_stats().snapshot();
        assert_eq!(snap.hint_hits, 1, "grown file must not re-hit");
        assert!(snap.hint_wastes >= 1);
    }

    #[test]
    fn hint_cache_bounded_by_depth_and_counts_teardown_waste() {
        let t = tmpdir("hint_cap");
        let d = piped_disk(t.path(), 2); // cap = depth = 2
        for i in 0..4 {
            write_recs(&d, &format!("f{i}.dat"), 10);
        }
        // fill the cache with two warmed hints...
        d.hint_prefetch("f0.dat");
        wait_hint_ready(&d, "f0.dat");
        d.hint_prefetch("f1.dat");
        wait_hint_ready(&d, "f1.dat");
        // ...a duplicate is dropped (still 2 posted)...
        d.hint_prefetch("f0.dat");
        assert_eq!(d.pipe_stats().snapshot().hints_posted, 2);
        // ...and further hints evict the oldest *ready* slot (waste) so
        // stale leftovers never wedge the bounded cache
        d.hint_prefetch("f2.dat");
        wait_hint_ready(&d, "f2.dat");
        d.hint_prefetch("f3.dat");
        wait_hint_ready(&d, "f3.dat");
        let snap = d.pipe_stats().snapshot();
        assert_eq!(snap.hints_posted, 4);
        assert_eq!(snap.hint_wastes, 2, "evicted warms are waste");
        assert!(!d.hints().is_ready(Path::new("f0.dat")), "f0 evicted");
        assert!(!d.hints().is_ready(Path::new("f1.dat")), "f1 evicted");
        // missing files are ignored outright
        d.hint_prefetch("nope.dat");
        assert_eq!(d.pipe_stats().snapshot().hints_posted, 4);
        let stats = Arc::clone(d.pipe_stats());
        drop(d); // f2 + f3 still warmed, never consumed
        assert_eq!(stats.snapshot().hint_wastes, 4, "unconsumed hints are waste");
        assert_eq!(stats.snapshot().hint_hits, 0);
        // full lifecycle accounted: posted == hits + wastes
        assert_eq!(stats.snapshot().hints_posted, 4);
    }

    #[test]
    fn geometry_mismatched_hint_is_evicted_not_wedged() {
        let t = tmpdir("hint_geom");
        let d = piped_disk(t.path(), 2);
        write_recs(&d, "f.dat", 200_000);
        d.hint_prefetch("f.dat");
        wait_hint_ready(&d, "f.dat");
        // a consumer with a reduced chunk (the k-way-merge geometry)
        // cannot adopt the full-chunk warm — the slot must be evicted
        // (counted as waste), not left to occupy the bounded cache
        let mut r = PrefetchReader::open_with_chunk(&d, "f.dat", 4, 1024).unwrap();
        let mut rec = [0u8; 4];
        assert!(r.read_one(&mut rec).unwrap());
        assert_eq!(u32::from_le_bytes(rec), 0);
        let snap = d.pipe_stats().snapshot();
        assert_eq!(snap.hint_hits, 0);
        assert!(snap.hint_wastes >= 1, "mismatched warm must be evicted as waste");
        assert!(!d.hints().is_ready(Path::new("f.dat")), "slot must be gone");
    }

    #[test]
    fn unhinted_scans_are_unaffected() {
        // a plain read with hints never posted must not touch the hint
        // counters at all
        let t = tmpdir("hint_none");
        let d = piped_disk(t.path(), 2);
        write_recs(&d, "f.dat", 1_000);
        let _ = read_recs(&d, "f.dat");
        let snap = d.pipe_stats().snapshot();
        assert_eq!(snap.hints_posted, 0);
        assert_eq!(snap.hint_hits, 0);
        assert_eq!(snap.hint_wastes, 0);
    }

    #[test]
    fn metering_parity_between_depths() {
        // The pipeline must charge the same byte totals as the sync path.
        let t0 = tmpdir("pipe_meter0");
        let d0 = plain_disk(t0.path());
        write_recs(&d0, "f.dat", 10_000);
        let w0 = d0.stats().snapshot().bytes_written;
        let _ = read_recs(&d0, "f.dat");
        let r0 = d0.stats().snapshot().bytes_read;

        let t1 = tmpdir("pipe_meter1");
        let d1 = piped_disk(t1.path(), 4);
        write_recs(&d1, "f.dat", 10_000);
        let w1 = d1.stats().snapshot().bytes_written;
        let _ = read_recs(&d1, "f.dat");
        let r1 = d1.stats().snapshot().bytes_read;
        assert_eq!(w0, w1, "written bytes must meter identically");
        assert_eq!(r0, r1, "read bytes must meter identically");
    }
}
