//! Disk substrate: metered node-local disks, fixed-record chunk files,
//! spillable staging buffers, external sort, and the overlapped-I/O
//! pipeline.
//!
//! Everything Roomy writes goes through [`diskio::NodeDisk`], which meters
//! bytes/seeks into [`crate::metrics::IoStats`] and (optionally) enforces a
//! simulated [`crate::DiskPolicy`] so the paper's 2010 disk regime can be
//! reproduced on modern hardware.
//!
//! When [`RoomyConfig::io_pipeline_depth`](crate::RoomyConfig::io_pipeline_depth)
//! is > 0, each node additionally runs an I/O service (a read-ahead lane
//! and a write-behind lane, one OS thread each — [`pipeline`]): pool
//! tasks stream buckets through [`pipeline::PrefetchReader`] /
//! [`pipeline::WriteBehindWriter`], which double-buffer `depth` chunks of
//! [`pipeline::PIPE_CHUNK`] bytes through bounded queues so a task
//! computes on chunk *k* while the service reads chunk *k+1* ahead and
//! flushes chunk *k−1* behind. The pipeline never changes on-disk bytes
//! or ordering within a file (depth 0 is byte-for-byte the synchronous
//! path — `tests/determinism.rs` pins this across depths and worker
//! counts), transfers stay fully metered (bandwidth-model sleeps move to
//! the service lanes — that *is* the overlap), and per-stream buffer RAM
//! is capped at depth × chunk (observable via
//! [`crate::metrics::PipelineStats`]).
//!
//! Layout conventions (one directory per simulated node):
//!
//! ```text
//! <root>/node<K>/<structure>/bucket<B>.dat     bucket payload
//! <root>/node<K>/<structure>/ops<B>.log        shuffled delayed-op log
//! <root>/node<K>/tmp/capture/...               in-collective op-capture spill
//! <root>/node<K>/tmp/sort/...                  external-sort run files
//! <root>/node<K>/tmp/pipeline/...              write-behind staging files
//! ```
//!
//! Everything under `tmp/` is strictly ephemeral scratch; a crashed run
//! can leave it behind, so [`crate::cluster::Cluster::new`] purges it at
//! bring-up.

pub mod buffer;
pub mod chunkfile;
pub mod diskio;
pub mod extsort;
pub mod pipeline;

pub use buffer::{SpillBuffer, SpillDrain};
pub use chunkfile::{RecordReader, RecordWriter};
pub use diskio::NodeDisk;
pub use pipeline::{ByteReader, PrefetchReader, WriteBehindWriter, PIPE_CHUNK};
