//! Disk substrate: metered node-local disks, fixed-record chunk files,
//! spillable staging buffers, external sort, and the overlapped-I/O
//! pipeline.
//!
//! Everything Roomy writes goes through [`diskio::NodeDisk`], which meters
//! bytes/seeks into [`crate::metrics::IoStats`] and (optionally) enforces a
//! simulated [`crate::DiskPolicy`] so the paper's 2010 disk regime can be
//! reproduced on modern hardware.
//!
//! When [`RoomyConfig::io_pipeline_depth`](crate::RoomyConfig::io_pipeline_depth)
//! is > 0, each node additionally runs an I/O service (a read-ahead lane
//! and a write-behind lane, one OS thread each — [`pipeline`]): pool
//! tasks stream buckets through [`pipeline::PrefetchReader`] /
//! [`pipeline::WriteBehindWriter`], which double-buffer `depth` chunks of
//! [`pipeline::PIPE_CHUNK`] bytes through bounded queues so a task
//! computes on chunk *k* while the service reads chunk *k+1* ahead and
//! flushes chunk *k−1* behind. The pipeline never changes on-disk bytes
//! or ordering within a file (depth 0 is byte-for-byte the synchronous
//! path — `tests/determinism.rs` pins this across depths and worker
//! counts), transfers stay fully metered (bandwidth-model sleeps move to
//! the service lanes — that *is* the overlap), and per-stream buffer RAM
//! is capped at depth × chunk (observable via
//! [`crate::metrics::PipelineStats`]).
//!
//! Layout conventions (one directory per simulated node, checkpoints
//! beside them):
//!
//! ```text
//! <root>/node<K>/<structure>/bucket<B>.dat     bucket payload
//! <root>/node<K>/<structure>/ops<B>.log        shuffled delayed-op log
//! <root>/node<K>/tmp/capture/...               in-collective op-capture spill
//! <root>/node<K>/tmp/sort/...                  external-sort run files
//! <root>/node<K>/tmp/pipeline/...              write-behind staging files
//! <root>/node<K>/tmp/restore/...               checkpoint-restore staging
//! <root>/checkpoints/<name>/MANIFEST           durable checkpoint manifest
//! <root>/checkpoints/<name>/node<K>/...        snapshotted structure files
//! <root>/checkpoints/<name>.staging/           in-progress save (never read)
//! <root>/checkpoints/<name>.prev/              commit-window survivor
//! ```
//!
//! The `tmp/capture`, `tmp/sort`, `tmp/pipeline` and `tmp/restore`
//! subtrees are strictly ephemeral scratch; a crashed run can leave them
//! behind, so [`crate::cluster::Cluster::new`] purges exactly those at
//! bring-up — and nothing else, because everything outside them is
//! durable state.
//!
//! ## Checkpoint / manifest format ([`checkpoint`])
//!
//! A checkpoint directory holds one snapshotted copy (hardlink where the
//! filesystem allows and the file is replace-by-rename; streaming copy
//! otherwise, and always for append-in-place list shards) of every file
//! of every snapshotted structure, under `node<K>/<structure>/`, plus a
//! `MANIFEST`: a line-oriented text file
//!
//! ```text
//! roomy-checkpoint v1
//! cluster <workers> <nbuckets>
//! struct <kind> <name> <dir> <rec> <key> <len> <size> <bits> <sorted> <append> <counts>
//! file <node> <len> <fnv1a-64 hex> <relpath>
//! app <key> <value>
//! digest <fnv1a-64 hex of everything above>
//! ```
//!
//! `struct` rows carry the in-RAM half of a structure's state (size
//! counters, sorted flag, bit-array histogram) so a typed re-open
//! reconstitutes it; `file` rows pin every byte with a digest that
//! restore re-verifies; `app` rows hold driver state (the resumable BFS
//! level counter and profile); the final `digest` row makes any flipped
//! byte in the manifest itself detectable. Saves stage under
//! `<name>.staging/` and commit by rename (old checkpoint briefly
//! `<name>.prev`), so a crash anywhere leaves a restorable checkpoint.

pub mod bloom;
pub mod buffer;
pub mod checkpoint;
pub mod chunkfile;
pub mod diskio;
pub mod extsort;
pub mod pipeline;
pub mod scratch;

pub use bloom::{DedupFilter, ShardBloom};
pub use buffer::{SpillBuffer, SpillDrain};
pub use checkpoint::{CheckpointManager, Checkpointable, Manifest, Restored, StructKind, StructMeta};
pub use chunkfile::{RecordReader, RecordWriter};
pub use diskio::NodeDisk;
pub use pipeline::{
    read_all_pipelined, write_all_pipelined, ByteReader, PrefetchReader, WriteBehindWriter,
    PIPE_CHUNK,
};
pub use scratch::{Arena, ScratchBuf, ScratchPool};
