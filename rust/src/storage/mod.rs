//! Disk substrate: metered node-local disks, fixed-record chunk files,
//! spillable staging buffers, and external sort.
//!
//! Everything Roomy writes goes through [`diskio::NodeDisk`], which meters
//! bytes/seeks into [`crate::metrics::IoStats`] and (optionally) enforces a
//! simulated [`crate::DiskPolicy`] so the paper's 2010 disk regime can be
//! reproduced on modern hardware.
//!
//! Layout conventions (one directory per simulated node):
//!
//! ```text
//! <root>/node<K>/<structure>/bucket<B>.dat     bucket payload
//! <root>/node<K>/<structure>/ops<B>.log        shuffled delayed-op log
//! <root>/node<K>/tmp/...                       sort runs, scratch
//! ```

pub mod buffer;
pub mod chunkfile;
pub mod diskio;
pub mod extsort;

pub use buffer::{SpillBuffer, SpillDrain};
pub use chunkfile::{RecordReader, RecordWriter};
pub use diskio::NodeDisk;
