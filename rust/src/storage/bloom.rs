//! Approximate-membership dedup tier: per-bucket scalable bloom filters.
//!
//! Duplicate elimination is the dominant cost of every Roomy BFS level —
//! the exact path sorts and merges the full seen set against the
//! frontier every level. When
//! [`RoomyConfig::bloom_bits_per_key`](crate::RoomyConfig::bloom_bits_per_key)
//! is > 0, each list shard / set shard / hashtable bucket keeps a
//! [`ShardBloom`] in RAM that answers one question without touching
//! disk: *is this record **definitely new**?*
//!
//! - **Exact-backed mode** (the default once enabled): a "definitely
//!   new" answer lets the caller skip the exact sort-merge or
//!   full-bucket replay and append directly; a "maybe seen" answer falls
//!   through to the unchanged exact pass. Because a bloom filter has no
//!   false negatives over its fed set, the *result bytes are identical*
//!   to the filter-off run — only the amount of exact-pass work changes
//!   (`tests/determinism.rs` and `tests/integration_dedup.rs` pin this).
//! - **Approximate mode**
//!   ([`bloom_approximate`](crate::RoomyConfig::bloom_approximate)):
//!   "maybe seen" is treated as seen and the record is dropped without
//!   an exact check. The false-positive rate is bounded by the
//!   bits-per-key budget (~`0.6185^bits` per probe) and measured in
//!   [`crate::metrics::DedupStats`].
//!
//! ## Why per bucket, and why rebuilt instead of checkpointed
//!
//! Filters shard exactly like the data: one filter per bucket, touched
//! only by the pool task that owns that bucket during a collective — no
//! shared mutable state, so the tier composes with any
//! `Topology`/steal-policy schedule unchanged. Filters are **RAM-only**:
//! checkpoints never contain them, and a restored structure rebuilds its
//! filters by streaming the restored bucket files once
//! ([`DedupFilter::rebuild_shard`]). That keeps checkpoint manifests and
//! on-disk digests byte-identical with the filter on or off, which is
//! what lets kill-and-resume stay pinned against filter-less reference
//! runs.
//!
//! Soundness rule for callers: **every append path must feed the
//! filter** (over-approximation is safe, under-feeding is not — a
//! record on disk that the filter never saw would later be called
//! "definitely new" and duplicated in exact mode). Removals do *not*
//! clear bits; a removed-then-readded record simply takes the exact
//! path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::hashfn::fp_bytes;
use crate::metrics::DedupStats;

thread_local! {
    /// Scratch fingerprints for [`DedupFilter::insert_batch`]'s batched
    /// hash sweep (reused across calls; never observable to callers).
    static BATCH_FPS: std::cell::RefCell<Vec<u64>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// A scalable bloom filter over raw record bytes.
///
/// Grows as a sequence of sub-filters with doubling capacity (starting
/// at [`ShardBloom::FIRST_BITS`] bits): inserts go to the newest
/// sub-filter, probes OR across all of them. Growth is driven purely by
/// the deterministic insert count, so two filters fed the same multiset
/// in any order hold the same bits per sub-filter boundary only if fed
/// in the same counts — callers never rely on bit equality, only on the
/// no-false-negative guarantee, which holds regardless.
#[derive(Debug)]
pub struct ShardBloom {
    /// Probe hashes per record: `max(1, round(bits_per_key · ln 2))`.
    k: u32,
    /// Bits budgeted per inserted key; fixes each sub-filter's capacity.
    bits_per_key: usize,
    /// Sub-filter bit arrays, oldest first, capacities doubling.
    subs: Vec<Vec<u64>>,
    /// Inserts into the newest sub-filter.
    newest_count: usize,
    /// Insert capacity of the newest sub-filter before growing.
    newest_cap: usize,
    /// Total inserts ever (monotone; removals never decrement).
    inserts: usize,
}

impl ShardBloom {
    /// Bits in the first sub-filter (2^12; one 512-byte cache-friendly
    /// array before any growth).
    pub const FIRST_BITS: usize = 4096;

    /// An empty filter budgeting `bits_per_key` bits per inserted key.
    /// `bits_per_key` must be > 0 (0 means "tier disabled" and is the
    /// caller's responsibility to gate).
    pub fn new(bits_per_key: usize) -> ShardBloom {
        assert!(bits_per_key > 0, "bits_per_key must be > 0");
        // k = bits_per_key * ln 2, the FP-minimizing probe count.
        let k = ((bits_per_key as f64) * std::f64::consts::LN_2).round().max(1.0) as u32;
        ShardBloom {
            k,
            bits_per_key,
            subs: vec![vec![0u64; Self::FIRST_BITS / 64]],
            newest_count: 0,
            newest_cap: Self::FIRST_BITS / bits_per_key.max(1),
            inserts: 0,
        }
    }

    /// Derive the double-hashing pair (Kirsch–Mitzenmacher): all k probe
    /// positions are `h1 + i·h2`, with `h2` forced odd so it is
    /// invertible mod any power-of-two bit count.
    fn hash_pair(rec: &[u8]) -> (u64, u64) {
        Self::pair_from_fp(fp_bytes(rec))
    }

    /// The probe pair derived from an already-computed fingerprint.
    /// Split out so batched insert paths can fingerprint a whole chunk
    /// with [`crate::hashfn::fp_bytes_batch_into`] and still land on the
    /// exact bit positions the scalar path sets.
    fn pair_from_fp(h1: u64) -> (u64, u64) {
        // Independent-looking second hash from the same fingerprint:
        // one more splitmix-style avalanche round, forced odd.
        let mut h2 = h1 ^ 0x9E3779B97F4A7C15;
        h2 ^= h2 >> 30;
        h2 = h2.wrapping_mul(0xBF58476D1CE4E5B9);
        h2 ^= h2 >> 27;
        (h1, h2 | 1)
    }

    /// Record `rec` as seen.
    pub fn insert(&mut self, rec: &[u8]) {
        self.insert_fp(fp_bytes(rec));
    }

    /// Record a pre-fingerprinted record as seen — bit-identical to
    /// [`insert`](Self::insert) fed the record whose fingerprint is
    /// `h1` (the batch entry point's contract).
    pub(crate) fn insert_fp(&mut self, h1: u64) {
        if self.newest_count >= self.newest_cap {
            self.grow();
        }
        let (h1, h2) = Self::pair_from_fp(h1);
        let words = self.subs.last_mut().expect("at least one sub-filter");
        let nbits = (words.len() * 64) as u64;
        for i in 0..self.k as u64 {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) % nbits;
            words[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
        self.newest_count += 1;
        self.inserts += 1;
    }

    /// `false` means **definitely not** inserted; `true` means *maybe*.
    pub fn maybe_contains(&self, rec: &[u8]) -> bool {
        let (h1, h2) = Self::hash_pair(rec);
        'sub: for words in &self.subs {
            let nbits = (words.len() * 64) as u64;
            for i in 0..self.k as u64 {
                let bit = h1.wrapping_add(i.wrapping_mul(h2)) % nbits;
                if words[(bit / 64) as usize] & (1u64 << (bit % 64)) == 0 {
                    continue 'sub;
                }
            }
            return true;
        }
        false
    }

    /// Append a fresh sub-filter with double the previous capacity.
    fn grow(&mut self) {
        let next_words = self.subs.last().expect("non-empty").len() * 2;
        self.subs.push(vec![0u64; next_words]);
        self.newest_cap = (next_words * 64) / self.bits_per_key.max(1);
        self.newest_count = 0;
    }

    /// Total inserts ever fed to this filter.
    pub fn inserts(&self) -> usize {
        self.inserts
    }

    /// RAM held by the bit arrays, in bytes.
    pub fn ram_bytes(&self) -> usize {
        self.subs.iter().map(|w| w.len() * 8).sum()
    }
}

/// Per-structure sidecar: one [`ShardBloom`] per bucket, plus the shared
/// [`DedupStats`] the instance reports. Buckets are mutually exclusive
/// per collective task, so per-bucket mutexes never contend — they only
/// make the sidecar `Sync` for the pool.
pub struct DedupFilter {
    bits_per_key: usize,
    approximate: bool,
    shards: Vec<Mutex<ShardBloom>>,
    /// Current RAM across all shards, maintained by growth deltas so
    /// `DedupStats` can meter filter memory against the space bound
    /// without locking every shard on read.
    ram: AtomicUsize,
    stats: std::sync::Arc<DedupStats>,
}

impl std::fmt::Debug for DedupFilter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DedupFilter")
            .field("bits_per_key", &self.bits_per_key)
            .field("approximate", &self.approximate)
            .field("shards", &self.shards.len())
            .finish()
    }
}

impl DedupFilter {
    /// A filter bank of `nbuckets` empty shards. Callers gate on
    /// `bits_per_key > 0` and pass `None` otherwise.
    pub fn new(
        nbuckets: usize,
        bits_per_key: usize,
        approximate: bool,
        stats: std::sync::Arc<DedupStats>,
    ) -> DedupFilter {
        let shards: Vec<Mutex<ShardBloom>> =
            (0..nbuckets).map(|_| Mutex::new(ShardBloom::new(bits_per_key))).collect();
        let initial: usize = shards.iter().map(|s| s.lock().unwrap().ram_bytes()).sum();
        let f = DedupFilter {
            bits_per_key,
            approximate,
            shards,
            ram: AtomicUsize::new(initial),
            stats,
        };
        f.stats.note_ram(initial as u64);
        f
    }

    /// Whether "maybe seen" answers may be treated as seen (drop without
    /// the exact pass).
    pub fn approximate(&self) -> bool {
        self.approximate
    }

    /// The configured bits-per-key budget.
    pub fn bits_per_key(&self) -> usize {
        self.bits_per_key
    }

    /// Run `f` with exclusive access to bucket `b`'s filter, folding any
    /// RAM growth (or shrink, after a rebuild) into the metered total.
    pub fn with_shard<R>(&self, b: usize, f: impl FnOnce(&mut ShardBloom) -> R) -> R {
        let mut g = self.shards[b].lock().unwrap();
        let before = g.ram_bytes();
        let r = f(&mut g);
        let after = g.ram_bytes();
        drop(g);
        self.apply_ram_delta(before, after);
        r
    }

    fn apply_ram_delta(&self, before: usize, after: usize) {
        if after > before {
            let total = self.ram.fetch_add(after - before, Ordering::Relaxed) + (after - before);
            self.stats.note_ram(total as u64);
        } else if before > after {
            self.ram.fetch_sub(before - after, Ordering::Relaxed);
        }
    }

    /// Feed one record of bucket `b` (append-path hook).
    pub fn insert(&self, b: usize, rec: &[u8]) {
        self.with_shard(b, |s| s.insert(rec));
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
    }

    /// Feed a batch of `rec_size`-byte records of bucket `b` under one
    /// lock acquisition (streaming append paths). Fingerprints the whole
    /// chunk with the batched kernel before taking the lock, then sets
    /// the same bits a per-record [`insert`](Self::insert) loop would.
    pub fn insert_batch(&self, b: usize, batch: &[u8], rec_size: usize) {
        let n = (batch.len() / rec_size) as u64;
        if n == 0 {
            return;
        }
        BATCH_FPS.with(|f| {
            let mut fps = f.borrow_mut();
            fps.clear();
            let whole = &batch[..n as usize * rec_size];
            crate::hashfn::fp_bytes_batch_into(whole, rec_size, &mut fps);
            self.with_shard(b, |s| {
                for &h1 in fps.iter() {
                    s.insert_fp(h1);
                }
            });
        });
        self.stats.inserts.fetch_add(n, Ordering::Relaxed);
    }

    /// Probe bucket `b`: `false` = definitely new (metered as a
    /// shortcut candidate), `true` = maybe seen (metered as an
    /// exact-pass fallback candidate).
    pub fn probe(&self, b: usize, rec: &[u8]) -> bool {
        self.stats.probes.fetch_add(1, Ordering::Relaxed);
        let hit = self.shards[b].lock().unwrap().maybe_contains(rec);
        if hit {
            self.stats.maybe_seen.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.definite_new.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Rebuild bucket `b`'s filter from the authoritative record stream
    /// (used after a checkpoint restore: filters are RAM-only and never
    /// serialized, so a restored structure re-derives them from its
    /// restored bucket files).
    pub fn rebuild_shard(&self, b: usize, records: impl Iterator<Item = Vec<u8>>) {
        let mut sp =
            crate::obs::trace::span(crate::obs::trace::Kind::Mark, "bloom.rebuild", None);
        let mut fed = 0u64;
        self.with_shard(b, |s| {
            *s = ShardBloom::new(self.bits_per_key);
            for rec in records {
                s.insert(&rec);
                fed += 1;
            }
        });
        sp.set_args(b as u64, fed);
    }

    /// Current filter RAM in bytes (all shards).
    pub fn ram_bytes(&self) -> usize {
        self.ram.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn no_false_negatives_over_fed_set() {
        let mut f = ShardBloom::new(10);
        let mut rng = Rng::new(0xB100F1);
        let keys: Vec<[u8; 8]> = (0..5000).map(|_| rng.next_u64().to_le_bytes()).collect();
        for k in &keys {
            f.insert(k);
        }
        for k in &keys {
            assert!(f.maybe_contains(k), "fed key reported definitely-absent");
        }
    }

    #[test]
    fn fp_rate_within_budget_on_random_keys() {
        let mut f = ShardBloom::new(10);
        let mut rng = Rng::new(0xB100F2);
        for _ in 0..10_000 {
            f.insert(&rng.next_u64().to_le_bytes());
        }
        // Disjoint probe set (different generator stream).
        let mut rng2 = Rng::new(0xDEADBEEF);
        let probes = 20_000usize;
        let fps = (0..probes)
            .filter(|_| f.maybe_contains(&(rng2.next_u64() | 1 << 63).to_le_bytes()))
            .count();
        // 10 bits/key ⇒ theoretical ~0.8% per sub-filter; scalable
        // growth unions a few sub-filters, so allow a generous 5%.
        let rate = fps as f64 / probes as f64;
        assert!(rate < 0.05, "false-positive rate {rate} out of budget");
    }

    #[test]
    fn grows_and_meters_ram() {
        let mut f = ShardBloom::new(8);
        let base_ram = f.ram_bytes();
        let mut rng = Rng::new(0xB100F3);
        for _ in 0..100_000 {
            f.insert(&rng.next_u64().to_le_bytes());
        }
        assert!(f.subs.len() > 1, "filter should have grown");
        assert!(f.ram_bytes() > base_ram);
        assert_eq!(f.inserts(), 100_000);
    }

    #[test]
    fn empty_filter_rejects_everything() {
        let f = ShardBloom::new(10);
        for v in 0..1000u64 {
            assert!(!f.maybe_contains(&v.to_le_bytes()));
        }
    }

    #[test]
    fn insert_batch_sets_identical_bits_to_scalar_inserts() {
        let stats = std::sync::Arc::new(DedupStats::default());
        let batched = DedupFilter::new(2, 10, false, stats.clone());
        let scalar = DedupFilter::new(2, 10, false, stats);
        let mut rng = Rng::new(0xB100F4);
        let mut chunk = Vec::new();
        for _ in 0..3000 {
            chunk.extend_from_slice(&rng.next_u64().to_le_bytes());
        }
        batched.insert_batch(1, &chunk, 8);
        for rec in chunk.chunks_exact(8) {
            scalar.insert(1, rec);
        }
        let b = batched.shards[1].lock().unwrap();
        let s = scalar.shards[1].lock().unwrap();
        assert_eq!(b.subs, s.subs, "batched insert diverged from scalar bit positions");
        assert_eq!(b.inserts(), s.inserts());
    }

    #[test]
    fn dedup_filter_probe_and_rebuild() {
        let stats = std::sync::Arc::new(DedupStats::default());
        let f = DedupFilter::new(4, 10, false, stats.clone());
        f.insert(2, b"hello...");
        assert!(f.probe(2, b"hello..."), "fed record must probe maybe-seen");
        assert!(!f.probe(3, b"hello..."), "other shard untouched");
        // Rebuild shard 2 from a different authoritative stream.
        f.rebuild_shard(2, vec![b"world...".to_vec()].into_iter());
        assert!(!f.probe(2, b"hello..."), "rebuilt shard forgot old records");
        assert!(f.probe(2, b"world..."));
        assert!(f.ram_bytes() >= 4 * ShardBloom::FIRST_BITS / 8);
        let snap = stats.snapshot();
        assert_eq!(snap.probes, 4);
        assert!(snap.filter_ram_bytes > 0);
    }
}
