//! Test utilities: unique temp directories and a small property-testing
//! harness (the offline build has no `proptest`, so we roll a deterministic
//! SplitMix64-based shrinking-free checker of our own).

pub mod prop;

pub use prop::{prop_check, Rng};

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static NEXT: AtomicU64 = AtomicU64::new(0);

/// Self-cleaning unique temp directory.
pub struct TmpDir {
    path: PathBuf,
}

impl TmpDir {
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TmpDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Create a fresh directory under the system temp dir. Unique across
/// threads and processes (pid + counter).
pub fn tmpdir(tag: &str) -> TmpDir {
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!(
        "roomy-test-{}-{}-{}",
        tag,
        std::process::id(),
        n
    ));
    std::fs::create_dir_all(&path).expect("create tmpdir");
    TmpDir { path }
}

/// Recursive count of plain files under `dir` (0 if it does not exist).
/// Scratch-leak assertions in the unit and integration suites share this.
pub fn files_under(dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut n = 0;
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            n += files_under(&p);
        } else {
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tmpdir_unique_and_cleaned() {
        let p;
        {
            let a = tmpdir("x");
            let b = tmpdir("x");
            assert_ne!(a.path(), b.path());
            assert!(a.path().exists());
            p = a.path().to_path_buf();
        }
        assert!(!p.exists(), "tmpdir should be removed on drop");
    }
}
