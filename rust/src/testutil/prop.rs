//! Minimal deterministic property-testing harness.
//!
//! The offline environment has no `proptest`/`quickcheck`; this module
//! provides the subset Roomy's invariant tests need: a fast deterministic
//! PRNG (SplitMix64), generators for the shapes we use, and a driver that
//! runs a property across many seeded cases and reports the failing seed
//! (re-runnable with `ROOMY_PROP_SEED`).

/// SplitMix64 PRNG — tiny, fast, and good enough for test-case generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Rejection-free fast-range; fine for tests.
        ((self.next_u64() >> 32).wrapping_mul(bound) >> 32)
            .min(bound - 1)
            % bound
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo.wrapping_add(self.below((hi - lo) as u64) as i64)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Random byte vector of length `len`.
    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.next_u64() as u8).collect()
    }

    /// Vector of u64 with values below `bound`.
    pub fn u64s_below(&mut self, n: usize, bound: u64) -> Vec<u64> {
        (0..n).map(|_| self.below(bound)).collect()
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u8> {
        let mut v: Vec<u8> = (0..n as u8).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

/// Run `prop` for `cases` seeded cases. Panics with the failing seed on
/// the first failure. Override the base seed with env `ROOMY_PROP_SEED`
/// to reproduce a specific run.
pub fn prop_check(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    let base: u64 = std::env::var("ROOMY_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (seed {seed:#x}); \
                 rerun with ROOMY_PROP_SEED={base}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
        // bound=1 is always 0
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn range_i64_spans_negative() {
        let mut r = Rng::new(9);
        let mut saw_neg = false;
        for _ in 0..1000 {
            let v = r.range_i64(-10, 10);
            assert!((-10..10).contains(&v));
            saw_neg |= v < 0;
        }
        assert!(saw_neg);
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 5, 16] {
            let mut p = r.permutation(n);
            p.sort();
            let expect: Vec<u8> = (0..n as u8).collect();
            assert_eq!(p, expect);
        }
    }

    #[test]
    fn prop_check_runs_all_cases() {
        let mut count = 0;
        prop_check("counter", 25, |_rng| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn prop_check_propagates_failure() {
        prop_check("fails", 5, |rng| {
            assert!(rng.below(10) < 5, "will fail eventually");
        });
    }
}
