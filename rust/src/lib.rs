//! # Roomy: a system for space-limited computations
//!
//! A Rust reproduction of *Roomy* (Daniel Kunkle, CS.DC 2010): a programming
//! model and library for **parallel disk-based computation**, using the
//! aggregate disks of many nodes as a transparent extension of RAM.
//!
//! The two pillars of the paper, as implemented here:
//!
//! 1. **Bandwidth** — data structures are partitioned into buckets spread
//!    over the (simulated) node-local disks of a cluster and all collective
//!    operations stream every disk in parallel ([`cluster`], [`storage`]).
//! 2. **Latency** — random-access operations are *delayed*: staged into
//!    per-bucket operation logs and applied in batch, streaming, at an
//!    explicit `sync()` ([`roomy`]).
//!
//! The public API mirrors the paper's Table 1:
//!
//! - [`roomy::RoomyArray`] — fixed-size indexed array (delayed
//!   `access`/`update`, immediate `map`/`reduce`/`predicate_count`)
//! - [`roomy::RoomyBitArray`] — arrays of 1/2/4-bit elements ("elements can
//!   be as small as one bit")
//! - [`roomy::RoomyHashTable`] — delayed `insert`/`remove`/`access`/`update`
//! - [`roomy::RoomyList`] — delayed `add`/`remove`, immediate
//!   `add_all`/`remove_all`/`remove_dupes`
//!
//! The programming constructs of paper §3 live in [`constructs`]: map,
//! reduce, set operations, chain reduction, parallel prefix, pair reduction
//! and breadth-first search; the flagship pancake-sorting application is in
//! [`apps::pancake`].
//!
//! ## Three-layer architecture
//!
//! This crate is Layer 3 of a Rust + JAX + Pallas stack: the numeric batch
//! hot paths (fingerprint routing, prefix scan, BFS frontier expansion,
//! numeric reduce) can execute as AOT-compiled XLA programs authored in
//! JAX/Pallas at build time (`python/compile`), loaded from `artifacts/`
//! via PJRT by [`runtime`], and dispatched through [`accel`] (which also
//! provides bit-exact pure-Rust fallbacks). Python never runs at request
//! time.
//!
//! ## Example
//!
//! ```
//! use roomy::{Roomy, RoomyConfig};
//!
//! # fn main() -> roomy::Result<()> {
//! let root = std::env::temp_dir().join(format!("roomy-doc-{}", std::process::id()));
//! let r = Roomy::open(RoomyConfig::for_testing(&root))?;
//!
//! // A disk-resident array over the simulated cluster.
//! let ra = r.array::<u64>("counts", 1_000, 0)?;
//! let inc = ra.register_update(|_i, v: &mut u64, amount: &u64| *v += amount);
//!
//! // Delayed random-access updates: staged per bucket...
//! for i in 0..10_000u64 {
//!     ra.update(i % 1_000, &1u64, inc)?;
//! }
//! // ...and applied in one streaming batch.
//! ra.sync()?;
//!
//! let total = ra.reduce(|| 0u64, |acc, _i, v| acc + v, |a, b| a + b)?;
//! assert_eq!(total, 10_000);
//! # std::fs::remove_dir_all(&root).ok();
//! # Ok(())
//! # }
//! ```

pub mod accel;
pub mod apps;
pub mod cluster;
pub mod config;
pub mod constructs;
pub mod error;
pub mod hashfn;
pub mod metrics;
pub mod obs;
pub mod roomy;
pub mod runtime;
pub mod storage;
pub mod testutil;

pub use cluster::Topology;
pub use config::{AccelMode, AutotuneMode, DiskPolicy, KernelMode, RoomyConfig, StealPolicy};
pub use error::{Result, RoomyError};
pub use roomy::{
    Element, Roomy, RoomyArray, RoomyBitArray, RoomyHashTable, RoomyList, RoomySet,
};
