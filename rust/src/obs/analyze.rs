//! Offline run analysis: turn a flushed Chrome trace and/or a
//! `report_json` document back into performance *answers*.
//!
//! The flight recorder ([`super::trace`]) captures raw spans; this module
//! is the read side. [`Analysis::from_value`] loads a document parsed by
//! [`super::json::parse`] — either a Chrome-trace file (`traceEvents`) or
//! a [`crate::Roomy::report_json`] snapshot (`schema`) — and computes,
//! per collective:
//!
//! - **critical path**: the busiest single worker's task time inside the
//!   collective's window (the lower bound on wall time any schedule of
//!   the same tasks could reach);
//! - **per-node skew**: exact per-node task-duration p95s and their
//!   max/median ratio (1.0 = perfectly balanced);
//! - **stall attribution**: read-ahead / write-behind stall time whose
//!   interval falls inside the collective;
//! - **steal/locality attribution**: how many of its tasks ran stolen.
//!
//! Rows group by collective name (a `rl.sync [frontier]` label stays its
//! own row), and [`render_table`] prints the top-N by total wall time.
//! [`Analysis::to_json`] emits the same data machine-readably (the
//! `"analysis": 1` marker distinguishes it from the inputs).
//!
//! [`diff`] compares two runs: any two of {trace, report_json, analysis
//! JSON, `BENCH_*.json` bench baseline} flatten into one metric
//! namespace, and time-like metrics (`secs`, `*_ms`, `*_us`) that grew
//! past a configurable threshold are flagged as regressions — the CLI
//! (`roomy analyze-diff a b`) exits nonzero when any fire, which is what
//! makes "faster" a gated claim in CI.
//!
//! Truncation is never silent: a trace whose rings overwrote events
//! carries `droppedEvents` > 0, and both the table and the analysis JSON
//! say so (attribution is then a lower bound over the surviving window).

use std::collections::BTreeMap;

use super::json::{array, num, Obj, Value};

/// Per-node task statistics inside one collective group.
#[derive(Clone, Debug, Default)]
pub struct NodeStat {
    pub node: u32,
    pub tasks: u64,
    pub task_us: f64,
    /// Exact p95 task duration (µs) — offline we have every surviving
    /// span, so no bucketing error.
    pub p95_us: f64,
    pub max_us: f64,
}

/// One collective name's aggregate across all its instances in the run.
#[derive(Clone, Debug, Default)]
pub struct Group {
    pub name: String,
    /// Collective instances (spans) under this name.
    pub calls: u64,
    /// Total wall time across instances (µs).
    pub wall_us: f64,
    /// Sum over instances of the busiest worker's task time (µs).
    pub critical_us: f64,
    pub tasks: u64,
    pub task_us: f64,
    pub stolen: u64,
    pub reader_stall_us: f64,
    pub writer_stall_us: f64,
    pub per_node: Vec<NodeStat>,
}

impl Group {
    /// max / median of the per-node p95s: 1.0 = balanced, large = one
    /// node dominates. 0.0 when no node ran tasks.
    pub fn p95_skew(&self) -> f64 {
        let mut p95s: Vec<f64> =
            self.per_node.iter().filter(|n| n.tasks > 0).map(|n| n.p95_us).collect();
        if p95s.is_empty() {
            return 0.0;
        }
        p95s.sort_by(|a, b| a.total_cmp(b));
        let med = p95s[p95s.len() / 2].max(f64::MIN_POSITIVE);
        p95s[p95s.len() - 1] / med
    }

    /// Wall / critical-path: how much headroom a better schedule has
    /// (1.0 = the schedule already matched the busiest worker).
    pub fn stretch(&self) -> f64 {
        if self.critical_us <= 0.0 { 0.0 } else { self.wall_us / self.critical_us }
    }
}

/// Run-wide sums.
#[derive(Clone, Debug, Default)]
pub struct Totals {
    pub collectives: u64,
    pub wall_us: f64,
    pub tasks: u64,
    pub task_us: f64,
    pub stolen: u64,
    pub reader_stalls: u64,
    pub reader_stall_us: f64,
    pub writer_stalls: u64,
    pub writer_stall_us: f64,
}

/// The analyzed run.
#[derive(Clone, Debug, Default)]
pub struct Analysis {
    /// `"trace"` or `"report"` — which document kind produced this.
    pub source: String,
    /// Events the recorder overwrote before the flush (0 = complete).
    pub dropped_events: u64,
    pub totals: Totals,
    /// Groups sorted by total wall time, descending.
    pub groups: Vec<Group>,
}

impl Analysis {
    /// True when the source trace lost events to ring overwrites; every
    /// attribution is then a lower bound over the surviving window.
    pub fn truncated(&self) -> bool {
        self.dropped_events > 0
    }

    /// Analyze a parsed document: a Chrome trace (`traceEvents`), a
    /// `report_json` snapshot (`schema`), or an already-analyzed document
    /// (`analysis`, reloaded as-is for diffing).
    pub fn from_value(v: &Value) -> Result<Analysis, String> {
        if v.get("traceEvents").is_some() {
            Ok(Self::from_trace(v))
        } else if v.get("schema").is_some() {
            Ok(Self::from_report(v))
        } else {
            Err("document is neither a Chrome trace (traceEvents) nor a metrics report (schema)"
                .into())
        }
    }

    fn from_trace(v: &Value) -> Analysis {
        let dropped =
            v.get("droppedEvents").and_then(Value::as_f64).unwrap_or(0.0).max(0.0) as u64;
        let events = v.get("traceEvents").and_then(Value::as_arr).unwrap_or(&[]);

        // One pass over the event soup, split by category.
        struct Inst {
            name: String,
            t0: f64,
            t1: f64,
            // accumulated attribution
            per_worker_us: BTreeMap<u32, f64>,
            per_node: BTreeMap<u32, Vec<f64>>,
            tasks: u64,
            stolen: u64,
            reader_stall_us: f64,
            writer_stall_us: f64,
        }
        let mut insts: Vec<Inst> = Vec::new();
        struct TaskEv {
            name: String,
            ts: f64,
            dur: f64,
            node: u32,
            tid: u32,
            stolen: bool,
        }
        struct StallEv {
            reader: bool,
            ts: f64,
            dur: f64,
        }
        let mut task_evs: Vec<TaskEv> = Vec::new();
        let mut stall_evs: Vec<StallEv> = Vec::new();

        let fnum = |e: &Value, k: &str| e.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        for e in events {
            if e.get("ph").and_then(Value::as_str) != Some("X") {
                continue;
            }
            let cat = e.get("cat").and_then(Value::as_str).unwrap_or("");
            let name = e.get("name").and_then(Value::as_str).unwrap_or("").to_string();
            let (ts, dur) = (fnum(e, "ts"), fnum(e, "dur"));
            match cat {
                "collective" => insts.push(Inst {
                    name,
                    t0: ts,
                    t1: ts + dur,
                    per_worker_us: BTreeMap::new(),
                    per_node: BTreeMap::new(),
                    tasks: 0,
                    stolen: 0,
                    reader_stall_us: 0.0,
                    writer_stall_us: 0.0,
                }),
                "task" => {
                    let pid = fnum(e, "pid") as u32;
                    let stolen = e
                        .get("args")
                        .map(|a| fnum(a, "stolen") != 0.0)
                        .unwrap_or(false);
                    task_evs.push(TaskEv {
                        name,
                        ts,
                        dur,
                        node: pid.saturating_sub(2),
                        tid: fnum(e, "tid") as u32,
                        stolen,
                    });
                }
                "pipeline" => {
                    let reader = match name.as_str() {
                        "pipe.read_stall" => true,
                        "pipe.write_stall" => false,
                        _ => continue,
                    };
                    stall_evs.push(StallEv { reader, ts, dur });
                }
                _ => {}
            }
        }

        // Attribute each task to the narrowest enclosing collective whose
        // base name matches (collective spans carry an optional
        // " [label]" suffix the task spans don't). Dropped events can
        // orphan tasks; those simply stay unattributed.
        let base = |n: &str| n.split(" [").next().unwrap_or(n).to_string();
        let inst_base: Vec<String> = insts.iter().map(|i| base(&i.name)).collect();
        for t in &task_evs {
            let mut best: Option<usize> = None;
            for (i, inst) in insts.iter().enumerate() {
                if inst_base[i] == t.name && inst.t0 <= t.ts && t.ts < inst.t1 {
                    let narrower = match best {
                        Some(b) => (inst.t1 - inst.t0) < (insts[b].t1 - insts[b].t0),
                        None => true,
                    };
                    if narrower {
                        best = Some(i);
                    }
                }
            }
            if let Some(i) = best {
                let inst = &mut insts[i];
                inst.tasks += 1;
                inst.stolen += u64::from(t.stolen);
                *inst.per_worker_us.entry(t.tid).or_insert(0.0) += t.dur;
                inst.per_node.entry(t.node).or_default().push(t.dur);
            }
        }
        // Stalls carry no collective name — attribute by time window.
        for s in &stall_evs {
            let mid = s.ts + s.dur / 2.0;
            let mut best: Option<usize> = None;
            for (i, inst) in insts.iter().enumerate() {
                if inst.t0 <= mid && mid < inst.t1 {
                    let narrower = match best {
                        Some(b) => (inst.t1 - inst.t0) < (insts[b].t1 - insts[b].t0),
                        None => true,
                    };
                    if narrower {
                        best = Some(i);
                    }
                }
            }
            if let Some(i) = best {
                if s.reader {
                    insts[i].reader_stall_us += s.dur;
                } else {
                    insts[i].writer_stall_us += s.dur;
                }
            }
        }

        // Fold instances into name groups.
        let mut groups: BTreeMap<String, (Group, BTreeMap<u32, Vec<f64>>)> = BTreeMap::new();
        let mut totals = Totals::default();
        for inst in &insts {
            totals.collectives += 1;
            totals.wall_us += inst.t1 - inst.t0;
            totals.tasks += inst.tasks;
            totals.stolen += inst.stolen;
            let entry = groups
                .entry(inst.name.clone())
                .or_insert_with(|| {
                    (Group { name: inst.name.clone(), ..Group::default() }, BTreeMap::new())
                });
            let (g, node_durs) = entry;
            g.calls += 1;
            g.wall_us += inst.t1 - inst.t0;
            g.critical_us +=
                inst.per_worker_us.values().fold(0.0f64, |m, &v| m.max(v));
            g.tasks += inst.tasks;
            g.stolen += inst.stolen;
            g.reader_stall_us += inst.reader_stall_us;
            g.writer_stall_us += inst.writer_stall_us;
            for (&node, durs) in &inst.per_node {
                let acc = node_durs.entry(node).or_default();
                acc.extend_from_slice(durs);
                g.task_us += durs.iter().sum::<f64>();
            }
        }
        totals.task_us = groups.values().map(|(g, _)| g.task_us).sum();
        // Totals cover *every* stall, attributed or not (a stall between
        // collectives is still time the run lost); per-group rows only
        // carry what fell inside their windows.
        totals.reader_stalls = stall_evs.iter().filter(|s| s.reader).count() as u64;
        totals.writer_stalls = stall_evs.iter().filter(|s| !s.reader).count() as u64;
        totals.reader_stall_us = stall_evs.iter().filter(|s| s.reader).map(|s| s.dur).sum();
        totals.writer_stall_us = stall_evs.iter().filter(|s| !s.reader).map(|s| s.dur).sum();

        let mut out: Vec<Group> = groups
            .into_values()
            .map(|(mut g, node_durs)| {
                g.per_node = node_durs
                    .into_iter()
                    .map(|(node, mut durs)| {
                        durs.sort_by(|a, b| a.total_cmp(b));
                        let rank =
                            ((0.95 * durs.len() as f64).ceil() as usize).clamp(1, durs.len());
                        NodeStat {
                            node,
                            tasks: durs.len() as u64,
                            task_us: durs.iter().sum(),
                            p95_us: durs[rank - 1],
                            max_us: *durs.last().unwrap(),
                        }
                    })
                    .collect();
                g
            })
            .collect();
        out.sort_by(|a, b| b.wall_us.total_cmp(&a.wall_us));
        Analysis { source: "trace".into(), dropped_events: dropped, totals, groups: out }
    }

    /// Reduced analysis from a `report_json` document: phase rows become
    /// groups (wall only — the counters carry no per-task spans), stall
    /// totals come from the pipeline section, steals from the pool.
    fn from_report(v: &Value) -> Analysis {
        let mut a = Analysis { source: "report".into(), ..Analysis::default() };
        a.dropped_events = v
            .get("trace")
            .and_then(|t| t.get("dropped_events"))
            .and_then(Value::as_f64)
            .unwrap_or(0.0) as u64;
        if let Some(rows) = v.get("phases").and_then(Value::as_arr) {
            for r in rows {
                let name = r.get("name").and_then(Value::as_str).unwrap_or("?").to_string();
                let wall_us =
                    r.get("total_ms").and_then(Value::as_f64).unwrap_or(0.0) * 1e3;
                let calls = r.get("calls").and_then(Value::as_f64).unwrap_or(0.0) as u64;
                a.totals.collectives += calls;
                a.totals.wall_us += wall_us;
                a.groups.push(Group { name, calls, wall_us, ..Group::default() });
            }
        }
        if let Some(p) = v.get("pipeline") {
            a.totals.reader_stall_us =
                p.get("reader_wait_ms").and_then(Value::as_f64).unwrap_or(0.0) * 1e3;
            a.totals.writer_stall_us =
                p.get("writer_wait_ms").and_then(Value::as_f64).unwrap_or(0.0) * 1e3;
        }
        if let Some(p) = v.get("pool") {
            a.totals.stolen = p.get("steals").and_then(Value::as_f64).unwrap_or(0.0) as u64;
            a.totals.tasks = a.totals.stolen
                + p.get("locality_hits").and_then(Value::as_f64).unwrap_or(0.0) as u64;
        }
        a.groups.sort_by(|x, y| y.wall_us.total_cmp(&x.wall_us));
        a
    }

    /// Machine-readable form (marker `"analysis": 1`).
    pub fn to_json(&self) -> String {
        let mut root = Obj::new();
        root.u64("analysis", 1);
        root.str("source", &self.source);
        root.bool("truncated", self.truncated());
        root.u64("dropped_events", self.dropped_events);

        let t = &self.totals;
        let mut o = Obj::new();
        o.u64("collectives", t.collectives);
        o.f64("wall_ms", t.wall_us / 1e3);
        o.u64("tasks", t.tasks);
        o.f64("task_ms", t.task_us / 1e3);
        o.u64("stolen", t.stolen);
        o.f64("steal_rate", if t.tasks == 0 { 0.0 } else { t.stolen as f64 / t.tasks as f64 });
        o.u64("reader_stalls", t.reader_stalls);
        o.f64("reader_stall_ms", t.reader_stall_us / 1e3);
        o.u64("writer_stalls", t.writer_stalls);
        o.f64("writer_stall_ms", t.writer_stall_us / 1e3);
        root.raw("totals", &o.build());

        let rows: Vec<String> = self
            .groups
            .iter()
            .map(|g| {
                let mut r = Obj::new();
                r.str("name", &g.name);
                r.u64("calls", g.calls);
                r.f64("wall_ms", g.wall_us / 1e3);
                r.f64("critical_path_ms", g.critical_us / 1e3);
                r.f64("stretch", g.stretch());
                r.u64("tasks", g.tasks);
                r.f64("task_ms", g.task_us / 1e3);
                r.u64("stolen", g.stolen);
                r.f64("reader_stall_ms", g.reader_stall_us / 1e3);
                r.f64("writer_stall_ms", g.writer_stall_us / 1e3);
                r.f64("p95_skew", g.p95_skew());
                let nodes: Vec<String> = g
                    .per_node
                    .iter()
                    .map(|n| {
                        let mut o = Obj::new();
                        o.u64("node", n.node as u64);
                        o.u64("tasks", n.tasks);
                        o.f64("task_ms", n.task_us / 1e3);
                        o.f64("p95_us", n.p95_us);
                        o.f64("max_us", n.max_us);
                        o.build()
                    })
                    .collect();
                r.raw("per_node", &array(&nodes));
                r.build()
            })
            .collect();
        root.raw("collectives", &array(&rows));
        root.build()
    }
}

/// The human attribution table: top-`top_n` collective groups by wall
/// time, plus run totals and a truncation warning when events were lost.
pub fn render_table(a: &Analysis, top_n: usize) -> String {
    let mut s = String::new();
    if a.truncated() {
        s.push_str(&format!(
            "WARNING: trace is truncated ({} events overwritten in the rings before the \
             flush); every attribution below is a lower bound over the surviving window\n\n",
            a.dropped_events
        ));
    }
    let t = &a.totals;
    s.push_str(&format!(
        "source: {} | {} collectives, {:.1} ms wall | {} tasks ({} stolen, {:.0}% local) | \
         stalls: read {:.1} ms, write {:.1} ms\n\n",
        a.source,
        t.collectives,
        t.wall_us / 1e3,
        t.tasks,
        t.stolen,
        if t.tasks == 0 { 100.0 } else { 100.0 * (t.tasks - t.stolen) as f64 / t.tasks as f64 },
        t.reader_stall_us / 1e3,
        t.writer_stall_us / 1e3,
    ));
    s.push_str(&format!(
        "{:<34} {:>5} {:>9} {:>9} {:>6} {:>6} {:>6} {:>8} {:>8} {:>7}\n",
        "collective", "calls", "wall_ms", "crit_ms", "strch", "tasks", "stolen", "rstl_ms",
        "wstl_ms", "p95skew"
    ));
    for g in a.groups.iter().take(top_n) {
        let name: String = if g.name.len() > 34 {
            format!("{}…", &g.name[..33.min(g.name.len())])
        } else {
            g.name.clone()
        };
        s.push_str(&format!(
            "{:<34} {:>5} {:>9.2} {:>9.2} {:>6.2} {:>6} {:>6} {:>8.2} {:>8.2} {:>7.2}\n",
            name,
            g.calls,
            g.wall_us / 1e3,
            g.critical_us / 1e3,
            g.stretch(),
            g.tasks,
            g.stolen,
            g.reader_stall_us / 1e3,
            g.writer_stall_us / 1e3,
            g.p95_skew(),
        ));
    }
    if a.groups.len() > top_n {
        s.push_str(&format!(
            "… {} more groups (raise --top to see them)\n",
            a.groups.len() - top_n
        ));
    }
    // Per-node skew detail for the heaviest group that actually ran
    // tasks — the "which node is the problem" answer.
    if let Some(g) = a.groups.iter().find(|g| !g.per_node.is_empty()) {
        s.push_str(&format!("\nper-node task p95 for {:?}:\n", g.name));
        for n in &g.per_node {
            s.push_str(&format!(
                "  node{:<3} {:>6} tasks  {:>10.2} ms total  p95 {:>9.1} us  max {:>9.1} us\n",
                n.node, n.tasks, n.task_us / 1e3, n.p95_us, n.max_us
            ));
        }
    }
    s
}

// ----------------------------------------------------------------------
// Run diffing
// ----------------------------------------------------------------------

/// One compared metric.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub key: String,
    pub a: f64,
    pub b: f64,
    /// (b - a) / a × 100; 0 when a == 0.
    pub delta_pct: f64,
    /// Time-like metric that grew past the threshold.
    pub regressed: bool,
}

/// Is a grown value of this metric bad? Only time-like metrics gate the
/// diff; throughputs, rates and byte counts are reported but never fail
/// a run (their direction is workload-dependent).
fn time_like(key: &str) -> bool {
    key.ends_with("secs")
        || key.ends_with("_ms")
        || key.ends_with("_us")
        || key.ends_with("_ns")
}

/// Flatten any supported document into one `name → value` metric map.
///
/// - bench baseline (`samples`): `bench/<group>/<metric>`
/// - analysis (`analysis`): `collective/<name>/{wall_ms,critical_path_ms,
///   reader_stall_ms,writer_stall_ms}` + `total/...`
/// - trace (`traceEvents`): analyzed first, then as above
/// - report (`schema`): phases, pipeline waits, io bytes
pub fn flatten_metrics(v: &Value) -> Result<BTreeMap<String, f64>, String> {
    let mut m = BTreeMap::new();
    if let Some(samples) = v.get("samples").and_then(Value::as_arr) {
        for s in samples {
            let g = s.get("group").and_then(Value::as_str).unwrap_or("?");
            let k = s.get("metric").and_then(Value::as_str).unwrap_or("?");
            if let Some(val) = s.get("value").and_then(Value::as_f64) {
                m.insert(format!("bench/{g}/{k}"), val);
            }
        }
        return Ok(m);
    }
    let analysis_doc;
    let a = if v.get("analysis").is_some() {
        v
    } else if v.get("traceEvents").is_some() || v.get("schema").is_some() {
        analysis_doc = super::json::parse(&Analysis::from_value(v)?.to_json())
            .map_err(|e| format!("internal: analysis JSON does not reparse: {e}"))?;
        // Also surface raw report counters alongside the phase analysis.
        if let Some(io) = v.get("io") {
            for k in ["bytes_read", "bytes_written"] {
                if let Some(val) = io.get(k).and_then(Value::as_f64) {
                    m.insert(format!("io/{k}"), val);
                }
            }
        }
        &analysis_doc
    } else {
        return Err(
            "unsupported document: expected traceEvents, schema, analysis, or samples".into()
        );
    };
    if let Some(t) = a.get("totals") {
        for k in ["wall_ms", "task_ms", "reader_stall_ms", "writer_stall_ms"] {
            if let Some(val) = t.get(k).and_then(Value::as_f64) {
                m.insert(format!("total/{k}"), val);
            }
        }
        for k in ["collectives", "tasks", "stolen"] {
            if let Some(val) = t.get(k).and_then(Value::as_f64) {
                m.insert(format!("total/{k}"), val);
            }
        }
    }
    if let Some(rows) = a.get("collectives").and_then(Value::as_arr) {
        for r in rows {
            let name = r.get("name").and_then(Value::as_str).unwrap_or("?");
            for k in ["wall_ms", "critical_path_ms", "reader_stall_ms", "writer_stall_ms"] {
                if let Some(val) = r.get(k).and_then(Value::as_f64) {
                    m.insert(format!("collective/{name}/{k}"), val);
                }
            }
        }
    }
    Ok(m)
}

/// Compare two flattened runs. A row regresses when it is time-like and
/// `b > a × (1 + threshold_pct/100)` (with a tiny absolute floor so
/// zero-vs-epsilon noise never fires). Returns all common rows sorted by
/// |delta|, plus the regression verdict.
pub fn diff(
    a: &Value,
    b: &Value,
    threshold_pct: f64,
) -> Result<(Vec<DiffRow>, bool), String> {
    let ma = flatten_metrics(a)?;
    let mb = flatten_metrics(b)?;
    let mut rows = Vec::new();
    let mut regressed = false;
    for (k, &va) in &ma {
        let Some(&vb) = mb.get(k) else { continue };
        let delta_pct = if va == 0.0 {
            if vb == 0.0 { 0.0 } else { 100.0 }
        } else {
            (vb - va) / va * 100.0
        };
        let bad = time_like(k)
            && vb > va * (1.0 + threshold_pct / 100.0)
            && (vb - va) > 1e-6;
        regressed |= bad;
        rows.push(DiffRow { key: k.clone(), a: va, b: vb, delta_pct, regressed: bad });
    }
    rows.sort_by(|x, y| y.delta_pct.abs().total_cmp(&x.delta_pct.abs()));
    Ok((rows, regressed))
}

/// Human side-by-side diff table.
pub fn render_diff(rows: &[DiffRow], threshold_pct: f64, regressed: bool) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<52} {:>12} {:>12} {:>9}\n",
        "metric", "a", "b", "delta"
    ));
    for r in rows {
        let key: String = if r.key.len() > 52 {
            format!("{}…", &r.key[..51.min(r.key.len())])
        } else {
            r.key.clone()
        };
        s.push_str(&format!(
            "{:<52} {:>12.4} {:>12.4} {:>+8.1}%{}\n",
            key,
            r.a,
            r.b,
            r.delta_pct,
            if r.regressed { "  << REGRESSION" } else { "" }
        ));
    }
    s.push_str(&format!(
        "\n{} metrics compared, threshold +{threshold_pct:.0}% on time-like metrics: {}\n",
        rows.len(),
        if regressed { "REGRESSED" } else { "ok" }
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json::parse;

    /// A hand-built two-collective trace: `rl.sync [s]` with tasks on two
    /// nodes (one slow, stolen work, a reader stall inside), and a fast
    /// `ra.map`. Matches the flusher's event shape exactly.
    fn synthetic_trace() -> String {
        let ev = |name: &str, cat: &str, ts: f64, dur: f64, pid: u32, tid: u32, args: &str| {
            format!(
                r#"{{"name":"{name}","cat":"{cat}","ph":"X","dur":{dur},"ts":{ts},"pid":{pid},"tid":{tid},"args":{args}}}"#
            )
        };
        let events = [
            // collective 1: wall 1000us, window [0, 1000)
            ev("rl.sync [s]", "collective", 0.0, 1000.0, 1, 1000, "{}"),
            // node0 (pid 2) tasks on worker0 (tid 2): 100 + 200 us
            ev("rl.sync", "task", 10.0, 100.0, 2, 2, r#"{"bucket":0,"stolen":0}"#),
            ev("rl.sync", "task", 120.0, 200.0, 2, 2, r#"{"bucket":1,"stolen":0}"#),
            // node1 (pid 3) tasks: 600us on worker1, 150us stolen on worker0
            ev("rl.sync", "task", 10.0, 600.0, 3, 3, r#"{"bucket":2,"stolen":0}"#),
            ev("rl.sync", "task", 330.0, 150.0, 3, 2, r#"{"bucket":3,"stolen":1}"#),
            // a reader stall inside the window
            ev("pipe.read_stall", "pipeline", 400.0, 80.0, 3, 3, "{}"),
            // collective 2: wall 300us, window [2000, 2300), no tasks recorded
            ev("ra.map", "collective", 2000.0, 300.0, 1, 1000, "{}"),
            // a writer stall outside both windows: stays unattributed but
            // still counts in totals
            ev("pipe.write_stall", "pipeline", 5000.0, 40.0, 2, 2, "{}"),
        ];
        format!(
            r#"{{"displayTimeUnit":"ms","droppedEvents":0,"traceEvents":[{}]}}"#,
            events.join(",")
        )
    }

    #[test]
    fn attributes_critical_path_skew_and_stalls() {
        let v = parse(&synthetic_trace()).unwrap();
        let a = Analysis::from_value(&v).unwrap();
        assert_eq!(a.source, "trace");
        assert!(!a.truncated());
        assert_eq!(a.totals.collectives, 2);
        assert_eq!(a.totals.tasks, 4);
        assert_eq!(a.totals.stolen, 1);
        assert_eq!(a.totals.reader_stalls, 1);
        assert!((a.totals.writer_stall_us - 40.0).abs() < 1e-9, "totals count all stalls");

        // Heaviest group first.
        let g = &a.groups[0];
        assert_eq!(g.name, "rl.sync [s]");
        assert_eq!(g.calls, 1);
        assert!((g.wall_us - 1000.0).abs() < 1e-9);
        // worker0 (tid 2): 100+200+150 = 450; worker1 (tid 3): 600 → crit 600
        assert!((g.critical_us - 600.0).abs() < 1e-9, "critical path is the busiest worker");
        assert_eq!(g.tasks, 4);
        assert_eq!(g.stolen, 1);
        assert!((g.reader_stall_us - 80.0).abs() < 1e-9, "stall inside the window attributes");
        assert!((g.writer_stall_us - 0.0).abs() < 1e-9, "stall outside stays out");

        // Per-node: node0 p95 = 200 (durs 100,200), node1 p95 = 600
        // (durs 150,600) → skew 600/median. medians: [200,600] → med 600?
        // sorted p95s = [200, 600], len 2, med = p95s[1] = 600, max = 600
        // → skew 1.0? No: p95s[len/2] = p95s[1] = 600 → 600/600 = 1.0.
        let n0 = g.per_node.iter().find(|n| n.node == 0).unwrap();
        let n1 = g.per_node.iter().find(|n| n.node == 1).unwrap();
        assert_eq!(n0.tasks, 2);
        assert!((n0.p95_us - 200.0).abs() < 1e-9);
        assert_eq!(n1.tasks, 2);
        assert!((n1.p95_us - 600.0).abs() < 1e-9);
        assert!(g.p95_skew() >= 1.0);
        assert!(g.stretch() > 1.0, "wall 1000 vs crit 600");

        // The analysis JSON round-trips and carries the marker.
        let j = parse(&a.to_json()).expect("analysis JSON must parse");
        assert_eq!(j.get("analysis").and_then(Value::as_f64), Some(1.0));
        let rows = j.get("collectives").and_then(Value::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").and_then(Value::as_str), Some("rl.sync [s]"));
        assert!(rows[0].get("per_node").and_then(Value::as_arr).unwrap().len() == 2);

        // Human table mentions the headline numbers.
        let table = render_table(&a, 10);
        assert!(table.contains("rl.sync [s]"), "{table}");
        assert!(table.contains("per-node task p95"), "{table}");
        assert!(!table.contains("WARNING"), "{table}");
    }

    #[test]
    fn truncated_traces_warn() {
        let t = synthetic_trace().replace("\"droppedEvents\":0", "\"droppedEvents\":123");
        let a = Analysis::from_value(&parse(&t).unwrap()).unwrap();
        assert!(a.truncated());
        assert_eq!(a.dropped_events, 123);
        assert!(render_table(&a, 10).contains("WARNING"));
        let j = parse(&a.to_json()).unwrap();
        assert_eq!(j.get("truncated"), Some(&Value::Bool(true)));
    }

    #[test]
    fn report_documents_analyze_too() {
        let doc = r#"{"schema":1,
            "pipeline":{"reader_wait_ms":12.5,"writer_wait_ms":2.5},
            "pool":{"steals":3,"locality_hits":17},
            "phases":[{"name":"rl.sync","total_ms":40.0,"calls":4},
                      {"name":"ra.map","total_ms":10.0,"calls":1}],
            "trace":{"enabled":false,"dropped_events":0}}"#;
        let a = Analysis::from_value(&parse(doc).unwrap()).unwrap();
        assert_eq!(a.source, "report");
        assert_eq!(a.totals.collectives, 5);
        assert_eq!(a.totals.tasks, 20);
        assert_eq!(a.totals.stolen, 3);
        assert!((a.totals.reader_stall_us - 12_500.0).abs() < 1e-6);
        assert_eq!(a.groups[0].name, "rl.sync");
    }

    #[test]
    fn diff_is_zero_on_identical_and_fires_on_regression() {
        let v = parse(&synthetic_trace()).unwrap();
        let (rows, regressed) = diff(&v, &v, 50.0).unwrap();
        assert!(!rows.is_empty());
        assert!(!regressed, "identical runs must never regress");
        assert!(rows.iter().all(|r| r.delta_pct == 0.0));

        // Inject a 10x slowdown into the heavy collective.
        let slow = synthetic_trace().replace("\"dur\":1000,", "\"dur\":10000,");
        assert_ne!(slow, synthetic_trace());
        let vb = parse(&slow).unwrap();
        let (rows, regressed) = diff(&v, &vb, 50.0).unwrap();
        assert!(regressed, "10x wall growth past a 50% threshold must regress");
        let hit = rows
            .iter()
            .find(|r| r.key == "collective/rl.sync [s]/wall_ms")
            .expect("per-collective wall metric");
        assert!(hit.regressed);
        assert!(hit.delta_pct > 800.0);
        assert!(render_diff(&rows, 50.0, regressed).contains("REGRESSION"));

        // The same regression under a generous-enough threshold passes.
        let (_, regressed) = diff(&v, &vb, 100_000.0).unwrap();
        assert!(!regressed);
    }

    #[test]
    fn bench_baselines_flatten_and_diff() {
        let a = parse(
            r#"{"bench":"structures","scale":1,"samples":[
                {"group":"map n=10","metric":"secs","value":0.5},
                {"group":"map n=10","metric":"mb_moved","value":100.0}]}"#,
        )
        .unwrap();
        let b = parse(
            r#"{"bench":"structures","scale":1,"samples":[
                {"group":"map n=10","metric":"secs","value":2.0},
                {"group":"map n=10","metric":"mb_moved","value":100.0}]}"#,
        )
        .unwrap();
        let m = flatten_metrics(&a).unwrap();
        assert_eq!(m.get("bench/map n=10/secs"), Some(&0.5));
        let (rows, regressed) = diff(&a, &b, 50.0).unwrap();
        assert!(regressed, "4x secs past 50% must regress");
        assert!(rows.iter().any(|r| r.key.ends_with("/secs") && r.regressed));
        // mb_moved is not time-like: identical here, but even growth
        // would only be reported, never gated.
        let (_, regressed) = diff(&a, &b, 500.0).unwrap();
        assert!(!regressed, "4x is under a 500% threshold");
    }

    #[test]
    fn unsupported_documents_error() {
        let v = parse(r#"{"hello":1}"#).unwrap();
        assert!(Analysis::from_value(&v).is_err());
        assert!(flatten_metrics(&v).is_err());
    }
}
