//! Flight recorder: per-worker bounded ring-buffer span tracing with a
//! Chrome-trace-event JSON flusher.
//!
//! Off by default: every instrumentation site guards on one relaxed
//! atomic load ([`enabled`]) and does nothing else, so the counters-only
//! configuration pays ~zero cost and — critically — the recorder can
//! never influence what the collectives compute. Tracing records *when*
//! things happened, never what bytes land on disk; `tests/determinism.rs`
//! pins byte-identical instance roots with tracing on and off.
//!
//! Arming (`ROOMY_TRACE=<path>` / `--trace <path>` /
//! [`crate::Roomy::open`] with `trace_path` set) is process-global and
//! sticky: rings are shared by every instance in the process and flushed
//! as one timeline. Each *track* is a fixed-capacity ring of fixed-size
//! [`Event`] records — recording copies one struct under a short mutex,
//! allocates nothing on the hot path, and overwrites the oldest event
//! when full (a flight recorder keeps the most recent window, not the
//! whole flight).
//!
//! Track assignment mirrors the thread structure: pool worker slot `w`
//! records onto worker track `w % 32`, any other thread (the leader, a
//! per-node checkpoint thread) gets a lazily assigned leader track. The
//! flusher maps events to Chrome trace form: one `pid` per simulated node
//! (`pid 1` = cluster-scoped events such as collectives), one `tid` per
//! worker, collectives and tasks as nesting `X` complete events, autotune
//! decisions and bloom outcomes as `i` instant events. The output loads
//! directly into `chrome://tracing` or <https://ui.perfetto.dev>.

use std::cell::{Cell, RefCell};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::json;

/// Span/instant taxonomy. Each kind maps to a Chrome `cat` and fixed arg
/// names at flush time, so the recorded [`Event`] stays fixed-size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// A structure collective on the leader (`ra.sync`, `rl.remove_dupes`,
    /// `checkpoint.save`, ...). Args: bytes read / bytes written.
    Collective,
    /// One pool bucket task, nested under its collective on the worker's
    /// track. Args: bucket index / stolen flag (0 = home node).
    Task,
    /// Pipeline consumer waited on the read-ahead lane. No args.
    ReaderStall,
    /// Pipeline producer waited for a write-behind buffer. No args.
    WriterStall,
    /// A cross-task prefetch hint was adopted by a scan. No args.
    HintHit,
    /// External sort run generation. Args: runs produced.
    SortRuns,
    /// External sort merge. Args: records written / fan-in.
    SortMerge,
    /// Checkpoint save. Args: files written or linked / bytes.
    CkptSave,
    /// Checkpoint restore. Args: files restored / bytes.
    CkptRestore,
    /// Bloom "definitely new" shortcut skipped exact work. Args: bytes of
    /// exact merge work avoided.
    BloomShortcut,
    /// Bloom "maybe seen" fell through to the exact path. No args.
    BloomFallback,
    /// One autotune adaptation round. Args: depth raises+decays this
    /// round / chosen hint distance.
    Autotune,
    /// Autotune changed one node's effective pipeline depth. Args: new
    /// depth.
    AutotuneDepth,
    /// One BFS level. Args: level index / frontier size entering it.
    Level,
    /// Free-form marker (tests, apps). Args: generic a / b.
    Mark,
}

impl Kind {
    fn cat(self) -> &'static str {
        match self {
            Kind::Collective => "collective",
            Kind::Task => "task",
            Kind::ReaderStall | Kind::WriterStall | Kind::HintHit => "pipeline",
            Kind::SortRuns | Kind::SortMerge => "extsort",
            Kind::CkptSave | Kind::CkptRestore => "checkpoint",
            Kind::BloomShortcut | Kind::BloomFallback => "bloom",
            Kind::Autotune | Kind::AutotuneDepth => "autotune",
            Kind::Level => "bfs",
            Kind::Mark => "mark",
        }
    }

    /// Arg names for (a, b); empty string = omit the arg.
    fn arg_names(self) -> (&'static str, &'static str) {
        match self {
            Kind::Collective => ("bytes_read", "bytes_written"),
            Kind::Task => ("bucket", "stolen"),
            Kind::ReaderStall | Kind::WriterStall | Kind::HintHit => ("", ""),
            Kind::SortRuns => ("runs", ""),
            Kind::SortMerge => ("records", "fanin"),
            Kind::CkptSave | Kind::CkptRestore => ("files", "bytes"),
            Kind::BloomShortcut => ("bytes_avoided", ""),
            Kind::BloomFallback => ("", ""),
            Kind::Autotune => ("moves", "hint_ahead"),
            Kind::AutotuneDepth => ("depth", ""),
            Kind::Level => ("level", "frontier"),
            Kind::Mark => ("a", "b"),
        }
    }
}

/// Longest recorded span name; longer names are truncated at record time.
pub const MAX_NAME: usize = 48;

/// Sentinel duration marking an instant event.
const INSTANT: u64 = u64::MAX;

/// Node id for cluster-scoped events (the leader's collectives).
const CLUSTER: u32 = u32::MAX;

/// Worker id for non-pool threads.
const LEADER: u32 = u32::MAX;

/// One fixed-size trace record (~90 bytes, `Copy`, no heap).
#[derive(Clone, Copy)]
struct Event {
    /// Start, ns since the recorder epoch (monotonic clock).
    t0_ns: u64,
    /// Duration in ns; [`INSTANT`] for instant events.
    dur_ns: u64,
    kind: Kind,
    name: [u8; MAX_NAME],
    name_len: u8,
    /// Owning node, or [`CLUSTER`].
    node: u32,
    /// Pool worker slot, or [`LEADER`].
    worker: u32,
    a: u64,
    b: u64,
}

/// Events kept per track. 4096 × ~90 B ≈ 360 KiB per active track; only
/// tracks that record anything allocate at all.
const RING_CAP: usize = 4096;

const WORKER_TRACKS: usize = 32;
const LEADER_TRACKS: usize = 64;
const NUM_TRACKS: usize = WORKER_TRACKS + LEADER_TRACKS;

struct Ring {
    buf: Vec<Event>,
    /// Overwrite cursor once `buf` reaches capacity (oldest event).
    next: usize,
    dropped: u64,
}

impl Ring {
    fn new() -> Ring {
        Ring { buf: Vec::new(), next: 0, dropped: 0 }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < RING_CAP {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static PATH: Mutex<Option<PathBuf>> = Mutex::new(None);
static TRACKS: OnceLock<Vec<Mutex<Option<Ring>>>> = OnceLock::new();
static FLUSH_LOCK: Mutex<()> = Mutex::new(());
static NEXT_LEADER: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Lazily assigned leader-track slot for non-pool threads.
    static LEADER_SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
    /// Structure-instance label prepended to collective span names.
    static LABEL: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Is recording armed? One relaxed load — the entire cost of every
/// instrumentation site when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the process-global recorder and set the flush destination.
/// Idempotent; a later arm re-points the destination. The epoch is pinned
/// on first arm so all timestamps share one monotonic origin.
pub fn arm(path: &Path) {
    EPOCH.get_or_init(Instant::now);
    *PATH.lock().unwrap() = Some(path.to_path_buf());
    ENABLED.store(true, Ordering::Release);
}

/// The armed flush destination, if any.
pub fn armed_path() -> Option<PathBuf> {
    PATH.lock().unwrap().clone()
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn tracks() -> &'static [Mutex<Option<Ring>>] {
    TRACKS.get_or_init(|| (0..NUM_TRACKS).map(|_| Mutex::new(None)).collect())
}

fn leader_track() -> usize {
    LEADER_SLOT.with(|s| {
        let mut slot = s.get();
        if slot == usize::MAX {
            slot = NEXT_LEADER.fetch_add(1, Ordering::Relaxed) % LEADER_TRACKS;
            s.set(slot);
        }
        WORKER_TRACKS + slot
    })
}

/// (worker id, track index) for the current thread.
fn here() -> (u32, usize) {
    match crate::runtime::pool::current_worker() {
        Some(w) => (w as u32, w % WORKER_TRACKS),
        None => (LEADER, leader_track()),
    }
}

fn copy_name(name: &str) -> ([u8; MAX_NAME], u8) {
    let mut buf = [0u8; MAX_NAME];
    let n = name.len().min(MAX_NAME);
    buf[..n].copy_from_slice(&name.as_bytes()[..n]);
    (buf, n as u8)
}

fn record(track: usize, ev: Event) {
    let mut g = tracks()[track].lock().unwrap();
    g.get_or_insert_with(Ring::new).push(ev);
}

fn ns_since_epoch(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).unwrap_or_default().as_nanos() as u64
}

// ----------------------------------------------------------------------
// Recording API
// ----------------------------------------------------------------------

/// An in-flight span; records one complete event on drop. Disarmed (free)
/// when tracing is off.
pub struct Span(Option<SpanInner>);

struct SpanInner {
    kind: Kind,
    name: [u8; MAX_NAME],
    name_len: u8,
    node: u32,
    worker: u32,
    track: usize,
    t0: Instant,
    a: u64,
    b: u64,
}

impl Span {
    /// Attach args before the span closes (e.g. bytes moved, once known).
    pub fn set_args(&mut self, a: u64, b: u64) {
        if let Some(s) = &mut self.0 {
            s.a = a;
            s.b = b;
        }
    }

    /// Whether this span will record (i.e. tracing was on at open).
    pub fn armed(&self) -> bool {
        self.0.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            record(
                s.track,
                Event {
                    t0_ns: ns_since_epoch(s.t0),
                    dur_ns: s.t0.elapsed().as_nanos() as u64,
                    kind: s.kind,
                    name: s.name,
                    name_len: s.name_len,
                    node: s.node,
                    worker: s.worker,
                    a: s.a,
                    b: s.b,
                },
            );
        }
    }
}

fn open_span(kind: Kind, name: &str, node: Option<usize>, worker: u32, track: usize) -> Span {
    let (name, name_len) = copy_name(name);
    Span(Some(SpanInner {
        kind,
        name,
        name_len,
        node: node.map_or(CLUSTER, |n| n as u32),
        worker,
        track,
        t0: Instant::now(),
        a: 0,
        b: 0,
    }))
}

/// Open a span on the current thread's track (`node: None` = cluster
/// scope). Returns a disarmed no-op span when tracing is off.
pub fn span(kind: Kind, name: &str, node: Option<usize>) -> Span {
    if !enabled() {
        return Span(None);
    }
    let (worker, track) = here();
    open_span(kind, name, node, worker, track)
}

/// Open a span attributed to an explicit pool worker slot (used by the
/// pool itself, where the slot is known without a thread-local lookup).
pub fn span_at(kind: Kind, name: &str, node: Option<usize>, worker: usize) -> Span {
    if !enabled() {
        return Span(None);
    }
    open_span(kind, name, node, worker as u32, worker % WORKER_TRACKS)
}

/// Record an instant event on the current thread's track.
pub fn instant(kind: Kind, name: &str, node: Option<usize>, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let (worker, track) = here();
    let (name, name_len) = copy_name(name);
    record(
        track,
        Event {
            t0_ns: ns_since_epoch(Instant::now()),
            dur_ns: INSTANT,
            kind,
            name,
            name_len,
            node: node.map_or(CLUSTER, |n| n as u32),
            worker,
            a,
            b,
        },
    );
}

/// Record a complete event for an interval that started at `t0` and ends
/// now — used where the caller already took a timestamp for its counters
/// (pipeline stall metering), so tracing adds no extra clock reads.
pub fn complete_since(kind: Kind, name: &str, node: Option<usize>, t0: Instant, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let (worker, track) = here();
    let (name, name_len) = copy_name(name);
    record(
        track,
        Event {
            t0_ns: ns_since_epoch(t0),
            dur_ns: t0.elapsed().as_nanos() as u64,
            kind,
            name,
            name_len,
            node: node.map_or(CLUSTER, |n| n as u32),
            worker,
            a,
            b,
        },
    );
}

// ----------------------------------------------------------------------
// Structure-instance labels
// ----------------------------------------------------------------------

/// Restores the previous label on drop.
pub struct LabelGuard(Option<Option<String>>);

impl Drop for LabelGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.0.take() {
            LABEL.with(|l| *l.borrow_mut() = prev);
        }
    }
}

/// Tag collective spans opened on this thread (until the guard drops)
/// with a structure-instance label, so `rl.sync` becomes
/// `rl.sync [frontier]` in the trace. No-op when tracing is off.
pub fn struct_label(name: &str) -> LabelGuard {
    if !enabled() {
        return LabelGuard(None);
    }
    let prev = LABEL.with(|l| l.replace(Some(name.to_string())));
    LabelGuard(Some(prev))
}

/// The current thread's structure label, if tracing is on and one is set.
pub fn current_label() -> Option<String> {
    if !enabled() {
        return None;
    }
    LABEL.with(|l| l.borrow().clone())
}

// ----------------------------------------------------------------------
// Chrome trace flusher
// ----------------------------------------------------------------------

/// Chrome pid for an event: the cluster timeline or one pid per node.
fn pid_of(ev: &Event) -> u32 {
    if ev.node == CLUSTER {
        1
    } else {
        ev.node + 2
    }
}

/// Chrome tid for an event on `track`: one tid per worker slot; leader
/// threads get stable 1000+ tids so concurrent non-pool threads (per-node
/// checkpoint jobs, parallel test harness threads) never interleave spans
/// on one timeline row.
fn tid_of(ev: &Event, track: usize) -> u32 {
    if ev.worker == LEADER {
        1000 + (track.saturating_sub(WORKER_TRACKS)) as u32
    } else {
        ev.worker + 2
    }
}

fn render() -> String {
    // Snapshot every ring under its own lock; events are Copy.
    let mut evs: Vec<(usize, Event)> = Vec::new();
    let mut dropped: u64 = 0;
    // Per-track overwrite counts (track index → count), so a truncated
    // trace says *which* timeline lost its head, not just that one did.
    let mut dropped_by_track: Vec<(usize, u64)> = Vec::new();
    for (track, slot) in tracks().iter().enumerate() {
        let g = slot.lock().unwrap();
        if let Some(ring) = g.as_ref() {
            dropped += ring.dropped;
            if ring.dropped > 0 {
                dropped_by_track.push((track, ring.dropped));
            }
            // Oldest-first: the ring is in push order until it wraps.
            for i in 0..ring.buf.len() {
                evs.push((track, ring.buf[(ring.next + i) % ring.buf.len()]));
            }
        }
    }
    evs.sort_by_key(|(_, e)| (e.t0_ns, u64::MAX - e.dur_ns.min(INSTANT - 1)));

    let mut pids: Vec<u32> = Vec::new();
    let mut tids: Vec<(u32, u32)> = Vec::new();
    let mut rows: Vec<String> = Vec::new();
    for (track, ev) in &evs {
        let pid = pid_of(ev);
        let tid = tid_of(ev, *track);
        if !pids.contains(&pid) {
            pids.push(pid);
        }
        if !tids.contains(&(pid, tid)) {
            tids.push((pid, tid));
        }
        let name = String::from_utf8_lossy(&ev.name[..ev.name_len as usize]).into_owned();
        let ts = ev.t0_ns as f64 / 1000.0;
        let (an, bn) = ev.kind.arg_names();
        let mut args = json::Obj::new();
        if !an.is_empty() {
            args.u64(an, ev.a);
        }
        if !bn.is_empty() {
            args.u64(bn, ev.b);
        }
        let mut o = json::Obj::new();
        o.str("name", &name).str("cat", ev.kind.cat());
        if ev.dur_ns == INSTANT {
            o.str("ph", "i").str("s", "t");
        } else {
            o.str("ph", "X").raw("dur", &json::num(ev.dur_ns as f64 / 1000.0));
        }
        o.raw("ts", &json::num(ts)).u64("pid", pid as u64).u64("tid", tid as u64);
        o.raw("args", &args.build());
        rows.push(o.build());
    }

    // Process/thread naming metadata so Perfetto labels the timeline.
    let mut meta: Vec<String> = Vec::new();
    for pid in &pids {
        let pname = if *pid == 1 { "cluster".to_string() } else { format!("node{}", pid - 2) };
        let mut args = json::Obj::new();
        args.str("name", &pname);
        let mut o = json::Obj::new();
        o.str("ph", "M").str("name", "process_name").u64("pid", *pid as u64).u64("tid", 0);
        o.raw("args", &args.build());
        meta.push(o.build());
    }
    for (pid, tid) in &tids {
        let tname = if *tid >= 1000 {
            format!("leader-{}", tid - 1000)
        } else {
            format!("worker{}", tid - 2)
        };
        let mut args = json::Obj::new();
        args.str("name", &tname);
        let mut o = json::Obj::new();
        o.str("ph", "M").str("name", "thread_name").u64("pid", *pid as u64).u64("tid", *tid as u64);
        o.raw("args", &args.build());
        meta.push(o.build());
    }

    let mut doc = json::Obj::new();
    doc.str("displayTimeUnit", "ms");
    doc.u64("droppedEvents", dropped);
    if !dropped_by_track.is_empty() {
        // Track i < WORKER_TRACKS is worker i's ring; the rest are
        // leader slots — same naming as the thread_name metadata.
        let mut by = json::Obj::new();
        for (track, n) in &dropped_by_track {
            let name = if *track < WORKER_TRACKS {
                format!("worker{track}")
            } else {
                format!("leader-{}", track - WORKER_TRACKS)
            };
            by.u64(&name, *n);
        }
        doc.raw("droppedEventsByTrack", &by.build());
    }
    meta.extend(rows);
    doc.raw("traceEvents", &json::array(&meta));
    doc.build()
}

/// Total events overwritten in the rings so far (all tracks). Zero means
/// every recorded span is still in the buffers; nonzero means a flushed
/// trace is a truncated window and `report_json` says so.
pub fn dropped_events() -> u64 {
    if !enabled() {
        return 0;
    }
    tracks()
        .iter()
        .map(|slot| slot.lock().unwrap().as_ref().map_or(0, |r| r.dropped))
        .sum()
}

/// Serialize every ring to the armed path as Chrome trace JSON. Returns
/// the path written, or `None` when tracing was never armed. The file is
/// written whole via a temp + rename so a concurrently flushed path is
/// always complete; each flush rewrites the full timeline, so calling it
/// repeatedly (every `Roomy` teardown) is safe.
pub fn flush() -> std::io::Result<Option<PathBuf>> {
    if !enabled() {
        return Ok(None);
    }
    let Some(path) = armed_path() else { return Ok(None) };
    let _g = FLUSH_LOCK.lock().unwrap();
    let text = render();
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    std::fs::write(&tmp, &text)?;
    std::fs::rename(&tmp, &path)?;
    Ok(Some(path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json::Value;

    fn f(e: &Value, k: &str) -> f64 {
        e.get(k).and_then(Value::as_f64).unwrap_or(f64::NAN)
    }

    /// Serializes tests that arm the (process-global) recorder.
    static ARM: Mutex<()> = Mutex::new(());

    /// The tentpole contract in miniature: an emitted trace parses as
    /// JSON, and a nested span pair comes back as X events where the
    /// inner begin/end sit strictly inside the outer's, with monotonic
    /// timestamps (begin grows along the recording order, every end ≥ its
    /// begin).
    #[test]
    fn emitted_trace_parses_and_nests() {
        let _g = ARM.lock().unwrap();
        let dir = crate::testutil::tmpdir("obs-trace-unit");
        let path = dir.path().join("trace.json");
        arm(&path);

        {
            let mut outer = span(Kind::Mark, "ut.outer", Some(3));
            assert!(outer.armed());
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let mut inner = span(Kind::Mark, "ut.inner", Some(3));
                inner.set_args(7, 9);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            instant(Kind::Mark, "ut.tick", Some(3), 1, 2);
            std::thread::sleep(std::time::Duration::from_millis(2));
            outer.set_args(1, 0);
        }

        // Read the path flush() actually wrote: a concurrent Roomy
        // instance (suite-wide ROOMY_TRACE) may have re-pointed the
        // global destination between our arm() and here; the rings are
        // shared either way, so the flushed file contains our events.
        let written = flush().expect("flush trace").expect("recorder is armed");
        let text = std::fs::read_to_string(&written).expect("read flushed trace");
        let doc = crate::obs::json::parse(&text).expect("trace must be valid JSON");
        let evs = doc.get("traceEvents").and_then(Value::as_arr).expect("traceEvents array");
        assert!(!evs.is_empty());

        // Every complete event in the file is well-formed on its own.
        for e in evs {
            if e.get("ph").and_then(Value::as_str) == Some("X") {
                assert!(f(e, "ts") >= 0.0 && f(e, "dur") >= 0.0, "bad X event: {e:?}");
            }
        }

        let find = |name: &str| {
            evs.iter()
                .find(|e| e.get("name").and_then(Value::as_str) == Some(name))
                .unwrap_or_else(|| panic!("event {name:?} missing from trace"))
        };
        let outer = find("ut.outer");
        let inner = find("ut.inner");
        let tick = find("ut.tick");

        // Same node → same pid; same thread → same tid.
        for k in ["pid", "tid"] {
            assert_eq!(f(outer, k), f(inner, k));
            assert_eq!(f(outer, k), f(tick, k));
        }
        assert_eq!(outer.get("ph").and_then(Value::as_str), Some("X"));
        assert_eq!(inner.get("ph").and_then(Value::as_str), Some("X"));
        assert_eq!(tick.get("ph").and_then(Value::as_str), Some("i"));

        // Monotonic + properly nested: outer begin < inner begin,
        // inner end < outer end, instant inside the outer interval.
        let (ob, oe) = (f(outer, "ts"), f(outer, "ts") + f(outer, "dur"));
        let (ib, ie) = (f(inner, "ts"), f(inner, "ts") + f(inner, "dur"));
        assert!(ob < ib, "outer must begin before inner ({ob} vs {ib})");
        assert!(ie < oe, "inner must end before outer ({ie} vs {oe})");
        assert!(ib < ie && ob < oe, "ends must follow begins");
        let tt = f(tick, "ts");
        assert!(ob < tt && tt < oe, "instant must fall inside the outer span");

        // Args flow through with kind-mapped names.
        assert_eq!(f(inner.get("args").unwrap(), "a"), 7.0);
        assert_eq!(f(inner.get("args").unwrap(), "b"), 9.0);
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let mut r = Ring::new();
        let (name, name_len) = copy_name("x");
        for i in 0..(RING_CAP as u64 + 10) {
            r.push(Event {
                t0_ns: i,
                dur_ns: INSTANT,
                kind: Kind::Mark,
                name,
                name_len,
                node: CLUSTER,
                worker: LEADER,
                a: i,
                b: 0,
            });
        }
        assert_eq!(r.buf.len(), RING_CAP);
        assert_eq!(r.dropped, 10);
        // Oldest surviving event is #10; ring order starts at `next`.
        assert_eq!(r.buf[r.next].t0_ns, 10);
    }

    #[test]
    fn labels_nest_and_restore() {
        let _g = ARM.lock().unwrap();
        let dir = crate::testutil::tmpdir("obs-label-unit");
        arm(&dir.path().join("t.json"));
        assert_eq!(current_label(), None);
        {
            let _a = struct_label("outer");
            assert_eq!(current_label().as_deref(), Some("outer"));
            {
                let _b = struct_label("inner");
                assert_eq!(current_label().as_deref(), Some("inner"));
            }
            assert_eq!(current_label().as_deref(), Some("outer"));
        }
        assert_eq!(current_label(), None);
    }
}
