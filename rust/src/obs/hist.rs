//! Latency histograms: fixed-size log2-bucket duration distributions.
//!
//! The flight recorder ([`super::trace`]) keeps the most recent *window*
//! of spans; histograms keep the whole run's *distribution* in constant
//! space. Each histogram is a fixed array of [`BUCKETS`] atomic counters
//! — bucket 0 counts zero-length durations, bucket `i ≥ 1` counts
//! durations in `[2^(i-1), 2^i)` nanoseconds — plus one total-ns counter
//! for means. Recording is lock-free (two relaxed `fetch_add`s) and
//! allocation-free; like tracing, every site is off-by-default behind one
//! relaxed atomic load ([`enabled`]), so the counters-only configuration
//! pays ~zero cost and the recorder can never influence what collectives
//! compute. `tests/determinism.rs` pins byte-identical instance roots
//! with histograms on and off.
//!
//! Three duration domains are recorded per node, one per cluster:
//!
//! - [`Domain::Task`] — pool bucket-task wall time, keyed by the owning
//!   node (the per-node p95 here is the tuner's task-skew signal).
//! - [`Domain::ReaderStall`] / [`Domain::WriterStall`] — time a
//!   collective spent blocked on the per-node I/O lanes.
//! - [`Domain::Collective`] — whole-collective wall time (cluster scope).
//!
//! Snapshots are plain arrays: they merge by element-wise addition (so
//! per-node snapshots fold into cluster totals and round deltas are
//! subtraction), and percentiles come from the bucket boundaries — a
//! reported pNN is the *upper bound* of the bucket the NNth percentile
//! falls in, i.e. exact-to-within-2× by construction. Arming
//! (`ROOMY_HIST=on` / `--hist` / `RoomyConfig::hist`, and implicitly
//! `--autotune spans`) is process-global and sticky, mirroring the trace
//! recorder.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Log2 buckets per histogram. Bucket 0 holds zero-length durations;
/// bucket `i ≥ 1` holds `[2^(i-1), 2^i)` ns. 43 doubling buckets reach
/// `2^42` ns ≈ 73 minutes — beyond any single task/stall/collective —
/// and everything longer clamps into the last bucket.
pub const BUCKETS: usize = 44;

/// Per-node histogram slots. Nodes beyond this clamp into the last slot
/// (the report stays correct in aggregate; per-node attribution saturates
/// like the trace recorder's 32 worker tracks).
pub const MAX_NODES: usize = 64;

/// What a recorded duration measures. Each domain keeps [`MAX_NODES`]
/// per-node histograms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// One pool bucket task, keyed by the owning node.
    Task,
    /// Pipeline consumer blocked on the read-ahead lane.
    ReaderStall,
    /// Pipeline producer blocked on a write-behind buffer.
    WriterStall,
    /// One whole collective (cluster scope; recorded as node 0).
    Collective,
}

/// All domains, in storage order.
pub const DOMAINS: [Domain; 4] =
    [Domain::Task, Domain::ReaderStall, Domain::WriterStall, Domain::Collective];

impl Domain {
    fn index(self) -> usize {
        match self {
            Domain::Task => 0,
            Domain::ReaderStall => 1,
            Domain::WriterStall => 2,
            Domain::Collective => 3,
        }
    }

    /// Stable key used in `report_json` / analysis documents.
    pub fn key(self) -> &'static str {
        match self {
            Domain::Task => "task",
            Domain::ReaderStall => "reader_stall",
            Domain::WriterStall => "writer_stall",
            Domain::Collective => "collective",
        }
    }
}

/// Bucket index for a duration of `ns` nanoseconds: 0 for 0, else
/// `floor(log2(ns)) + 1`, clamped to the last bucket.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Smallest duration (ns) counted by bucket `i`.
pub fn bucket_lower(i: usize) -> u64 {
    match i {
        0 => 0,
        _ => 1u64 << (i - 1),
    }
}

/// Largest duration (ns) counted by bucket `i` (inclusive). The last
/// bucket clamps, so its upper bound is `u64::MAX`.
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i == BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// A bank of lock-free histograms: one per (domain, node). All storage is
/// allocated up front; recording is two relaxed `fetch_add`s.
#[derive(Debug)]
pub struct Hist {
    /// `DOMAINS.len() × MAX_NODES × BUCKETS` counters, row-major.
    counts: Vec<AtomicU64>,
    /// `DOMAINS.len() × MAX_NODES` total-ns accumulators (for means).
    sums: Vec<AtomicU64>,
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            counts: (0..DOMAINS.len() * MAX_NODES * BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sums: (0..DOMAINS.len() * MAX_NODES).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn slot(domain: Domain, node: usize) -> usize {
        domain.index() * MAX_NODES + node.min(MAX_NODES - 1)
    }

    /// Record one duration. Lock-free, allocation-free.
    pub fn record(&self, domain: Domain, node: usize, dur: Duration) {
        let ns = dur.as_nanos().min(u128::from(u64::MAX)) as u64;
        let slot = Self::slot(domain, node);
        self.counts[slot * BUCKETS + bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.sums[slot].fetch_add(ns, Ordering::Relaxed);
    }

    /// Point-in-time copy of one (domain, node) histogram.
    pub fn snapshot(&self, domain: Domain, node: usize) -> HistSnapshot {
        let slot = Self::slot(domain, node);
        let mut s = HistSnapshot::default();
        for (i, b) in s.buckets.iter_mut().enumerate() {
            *b = self.counts[slot * BUCKETS + i].load(Ordering::Relaxed);
        }
        s.total_ns = self.sums[slot].load(Ordering::Relaxed);
        s
    }

    /// One snapshot per node in `0..nodes` for a domain.
    pub fn per_node(&self, domain: Domain, nodes: usize) -> Vec<HistSnapshot> {
        (0..nodes.min(MAX_NODES)).map(|n| self.snapshot(domain, n)).collect()
    }

    /// All nodes of a domain merged into one distribution.
    pub fn merged(&self, domain: Domain) -> HistSnapshot {
        let mut acc = HistSnapshot::default();
        for n in 0..MAX_NODES {
            acc.merge(&self.snapshot(domain, n));
        }
        acc
    }

    /// Zero every counter (bench harness support, tests).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        for s in &self.sums {
            s.store(0, Ordering::Relaxed);
        }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

/// Plain copy of one histogram. Merging is element-wise addition (and is
/// therefore associative and commutative — pinned by tests); round deltas
/// are element-wise saturating subtraction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub total_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; BUCKETS], total_ns: 0 }
    }
}

impl HistSnapshot {
    /// Recorded durations in this snapshot.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Mean duration in ns (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        let n = self.count();
        if n == 0 { 0 } else { self.total_ns / n }
    }

    /// Fold `other` into `self` (element-wise addition).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.total_ns += other.total_ns;
    }

    /// What grew since `earlier` (element-wise saturating subtraction —
    /// safe across a counter reset, which just reads as an empty delta).
    pub fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut d = HistSnapshot::default();
        for (i, (a, b)) in self.buckets.iter().zip(earlier.buckets.iter()).enumerate() {
            d.buckets[i] = a.saturating_sub(*b);
        }
        d.total_ns = self.total_ns.saturating_sub(earlier.total_ns);
        d
    }

    /// The `q`-quantile (`0.0 < q <= 1.0`) in ns: the upper bound of the
    /// bucket the quantile rank falls in (so the true value is within 2×
    /// below the reported one). Returns 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        // ceil(q * n) with q clamped into (0, 1]: the rank of the
        // percentile observation, 1-based.
        let q = q.clamp(f64::MIN_POSITIVE, 1.0);
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

// ----------------------------------------------------------------------
// Process-global instance + one-relaxed-load gate
// ----------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<Arc<Hist>> = OnceLock::new();

/// Is recording armed? One relaxed load — the entire cost of every
/// instrumentation site when histograms are off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the process-global histograms. Idempotent and sticky, mirroring
/// the trace recorder: rings are shared by every instance in the process.
pub fn arm() {
    let _ = global();
    ENABLED.store(true, Ordering::Release);
}

/// The process-global histogram bank (allocated on first use).
pub fn global() -> Arc<Hist> {
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(Hist::new())))
}

/// Record one duration into the global bank. The disarmed cost is the
/// single relaxed load in [`enabled`].
#[inline]
pub fn record(domain: Domain, node: usize, dur: Duration) {
    if !enabled() {
        return;
    }
    global().record(domain, node, dur);
}

/// Record one collective wall time (cluster scope).
#[inline]
pub fn record_collective(dur: Duration) {
    if !enabled() {
        return;
    }
    global().record(Domain::Collective, 0, dur);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(n: u64) -> Duration {
        Duration::from_nanos(n)
    }

    /// The log2 bucket boundaries, pinned exactly: bucket 0 = {0},
    /// bucket i = [2^(i-1), 2^i) for i ≥ 1, last bucket clamps.
    #[test]
    fn bucket_boundaries_are_pinned() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        for i in 1..BUCKETS - 1 {
            // Every bucket's own bounds map back into it, and the bound
            // arithmetic tiles the u64 range with no gaps or overlaps.
            assert_eq!(bucket_of(bucket_lower(i)), i, "lower bound of bucket {i}");
            assert_eq!(bucket_of(bucket_upper(i)), i, "upper bound of bucket {i}");
            assert_eq!(bucket_lower(i + 1), bucket_upper(i).wrapping_add(1));
        }
        assert_eq!(bucket_upper(BUCKETS - 1), u64::MAX);
    }

    /// Merging snapshots is associative and commutative — the property
    /// that makes per-node → cluster folds and cross-run sums exact.
    #[test]
    fn merge_is_associative_and_commutative() {
        let h = Hist::new();
        for (node, base) in [(0usize, 10u64), (1, 5_000), (2, 9_999_999)] {
            for k in 0..50u64 {
                h.record(Domain::Task, node, ns(base + k * base / 10));
            }
        }
        let a = h.snapshot(Domain::Task, 0);
        let b = h.snapshot(Domain::Task, 1);
        let c = h.snapshot(Domain::Task, 2);

        let mut ab_c = a;
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut a_bc = b;
        a_bc.merge(&c);
        let mut left = a;
        left.merge(&a_bc);
        assert_eq!(ab_c, left, "(a+b)+c must equal a+(b+c)");

        let mut ba = b;
        ba.merge(&a);
        let mut ab = a;
        ab.merge(&b);
        assert_eq!(ab, ba, "a+b must equal b+a");

        assert_eq!(ab_c.count(), 150);
        assert_eq!(h.merged(Domain::Task), ab_c, "merged() must equal the manual fold");
    }

    /// Percentiles agree with an exact reference computation, to within
    /// the log2-bucket guarantee: reference ≤ reported ≤ 2 × reference
    /// (the reported value is the bucket upper bound).
    #[test]
    fn percentiles_match_reference_within_bucket_bounds() {
        let h = Hist::new();
        // A deliberately skewed distribution: many fast, few slow.
        let mut vals: Vec<u64> = Vec::new();
        let mut x = 0x2545F4914F6CDD1Du64;
        for i in 0..1000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = if i % 50 == 0 { 40_000_000 + x % 10_000_000 } else { 1_000 + x % 30_000 };
            vals.push(v);
            h.record(Domain::ReaderStall, 2, ns(v));
        }
        vals.sort_unstable();
        let s = h.snapshot(Domain::ReaderStall, 2);
        assert_eq!(s.count(), 1000);
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * 1000.0).ceil() as usize).clamp(1, 1000);
            let exact = vals[rank - 1];
            let got = s.percentile(q);
            assert!(
                got >= exact && got <= exact.saturating_mul(2),
                "p{q}: exact {exact} vs bucketed {got} out of the 2x envelope"
            );
        }
        assert_eq!(s.percentile(1.0), bucket_upper(bucket_of(*vals.last().unwrap())));
        let mean: u64 = vals.iter().sum::<u64>() / 1000;
        assert_eq!(s.mean_ns(), mean);
    }

    #[test]
    fn empty_and_zero_histograms() {
        let h = Hist::new();
        let s = h.snapshot(Domain::WriterStall, 0);
        assert_eq!(s.count(), 0);
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.mean_ns(), 0);
        h.record(Domain::WriterStall, 0, ns(0));
        let s = h.snapshot(Domain::WriterStall, 0);
        assert_eq!(s.count(), 1);
        assert_eq!(s.p50(), 0, "a zero-length duration lands in bucket 0");
    }

    /// Deltas subtract element-wise and survive a reset (saturating).
    #[test]
    fn deltas_subtract_and_survive_reset() {
        let h = Hist::new();
        h.record(Domain::Collective, 0, ns(500));
        let early = h.snapshot(Domain::Collective, 0);
        h.record(Domain::Collective, 0, ns(700_000));
        let late = h.snapshot(Domain::Collective, 0);
        let d = late.delta(&early);
        assert_eq!(d.count(), 1);
        assert_eq!(d.buckets[bucket_of(700_000)], 1);
        assert_eq!(d.total_ns, 700_000);
        h.reset();
        let after = h.snapshot(Domain::Collective, 0);
        assert_eq!(after.count(), 0);
        assert_eq!(after.delta(&late).count(), 0, "reset must read as an empty delta");
    }

    /// Out-of-range node ids clamp into the last slot instead of
    /// panicking (mirrors the trace recorder's track saturation).
    #[test]
    fn node_ids_clamp() {
        let h = Hist::new();
        h.record(Domain::Task, MAX_NODES + 7, ns(100));
        assert_eq!(h.snapshot(Domain::Task, MAX_NODES - 1).count(), 1);
        assert_eq!(h.merged(Domain::Task).count(), 1);
    }
}
