//! Hand-rolled JSON: the one escaper/writer the whole tree shares, plus a
//! minimal parser for validating what we emit.
//!
//! The offline build has no serde, so every machine-readable surface —
//! [`crate::Roomy::report_json`], the flight-recorder trace flusher
//! ([`super::trace`]), and the bench harness's `BENCH_baseline.json` — goes
//! through this module. The parser exists so tests and CI can round-trip
//! those documents without an external tool: it is a strict
//! recursive-descent reader of the JSON we produce (objects, arrays,
//! strings with escapes, f64 numbers, booleans, null), not a general
//! spec-lawyer.

/// Escape `s` for inclusion inside a JSON string literal (no surrounding
/// quotes). Escapes `"`, `\`, and all control characters as `\u00XX`.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A quoted, escaped JSON string literal.
pub fn quote(s: &str) -> String {
    format!("\"{}\"", escape(s))
}

/// Render an `f64` as a JSON value: non-finite (empty timing, div-by-zero
/// rates) becomes `null` so the document always parses.
pub fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

// ----------------------------------------------------------------------
// Writer: a small push-style object/array builder. Callers compose nested
// documents by building inner fragments first (everything is seconds-scale
// end-of-run reporting, not a hot path).
// ----------------------------------------------------------------------

/// Builds one JSON object `{...}` field by field.
#[derive(Default)]
pub struct Obj {
    body: String,
}

impl Obj {
    pub fn new() -> Self {
        Obj::default()
    }

    /// Add a field whose value is already-rendered JSON.
    pub fn raw(&mut self, key: &str, value: &str) -> &mut Self {
        if !self.body.is_empty() {
            self.body.push(',');
        }
        self.body.push_str(&quote(key));
        self.body.push(':');
        self.body.push_str(value);
        self
    }

    pub fn str(&mut self, key: &str, value: &str) -> &mut Self {
        let v = quote(value);
        self.raw(key, &v)
    }

    pub fn u64(&mut self, key: &str, value: u64) -> &mut Self {
        self.raw(key, &value.to_string())
    }

    pub fn f64(&mut self, key: &str, value: f64) -> &mut Self {
        let v = num(value);
        self.raw(key, &v)
    }

    pub fn bool(&mut self, key: &str, value: bool) -> &mut Self {
        self.raw(key, if value { "true" } else { "false" })
    }

    /// Render the finished object.
    pub fn build(&self) -> String {
        format!("{{{}}}", self.body)
    }
}

/// Render a JSON array from already-rendered element fragments.
pub fn array(items: &[String]) -> String {
    format!("[{}]", items.join(","))
}

// ----------------------------------------------------------------------
// Parser
// ----------------------------------------------------------------------

/// A parsed JSON value. Object fields keep document order.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Field lookup on an object (first match); `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Number as u64 when it is a non-negative integer (counters).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing bytes at offset {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Value::Str),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at offset {}", other.map(|b| b as char), self.pos)),
        }
    }

    fn literal(&mut self, text: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("truncated \\u escape")?;
                            let hex = std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            // Our writer only emits \u00XX for control chars;
                            // accept any BMP scalar, map surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so this is
                    // always a valid boundary walk).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).unwrap();
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_matches_writer_contract() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape("tab\there"), "tab\\u0009here");
        assert_eq!(escape("nl\n"), "nl\\u000a");
    }

    #[test]
    fn writer_roundtrips_through_parser() {
        let mut o = Obj::new();
        o.str("name", "we \"quote\" and \\escape\\ and \x01 control")
            .u64("count", 42)
            .f64("rate", 0.5)
            .f64("bad", f64::NAN)
            .bool("on", true)
            .raw("rows", &array(&[num(1.0), num(2.5), "null".into()]));
        let text = o.build();
        let v = parse(&text).expect("writer output must parse");
        assert_eq!(
            v.get("name").and_then(Value::as_str),
            Some("we \"quote\" and \\escape\\ and \u{1} control")
        );
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(42.0));
        assert_eq!(v.get("bad"), Some(&Value::Null));
        assert_eq!(v.get("on"), Some(&Value::Bool(true)));
        assert_eq!(v.get("rows").and_then(Value::as_arr).map(<[Value]>::len), Some(3));
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} trailing").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn parser_handles_nested_documents() {
        let v = parse(r#"{"a":[{"b":[1,2,{"c":null}]},true],"d":-1.5e3}"#).unwrap();
        assert_eq!(v.get("d").and_then(Value::as_f64), Some(-1500.0));
        let a = v.get("a").and_then(Value::as_arr).unwrap();
        assert_eq!(a.len(), 2);
        assert!(a[0].get("b").is_some());
    }
}
