//! Observability: the flight recorder ([`trace`]), latency histograms
//! ([`hist`]), the offline run analyzer ([`analyze`]), and the shared
//! hand-rolled JSON surface ([`json`]) behind `Roomy::report_json()`, the
//! Chrome-trace flusher, and the bench harness's `BENCH_baseline.json`.
//!
//! Everything here is read-only with respect to the computation: tracing
//! and histograms record timestamps and counter deltas, never data, so
//! arming them cannot change a single on-disk byte (pinned by
//! `tests/determinism.rs`).

pub mod analyze;
pub mod hist;
pub mod json;
pub mod trace;
