//! Observability: the flight recorder ([`trace`]) and the shared
//! hand-rolled JSON surface ([`json`]) behind `Roomy::report_json()`, the
//! Chrome-trace flusher, and the bench harness's `BENCH_baseline.json`.
//!
//! Everything here is read-only with respect to the computation: tracing
//! records timestamps and counter deltas, never data, so arming it cannot
//! change a single on-disk byte (pinned by `tests/determinism.rs`).

pub mod json;
pub mod trace;
