//! Worker-pool execution engine for Roomy collectives — locality-aware:
//! per-node work queues, bounded stealing, cross-task prefetch hints.
//!
//! A [`WorkerPool`] fans a set of **independent bucket tasks** out to
//! `num_workers` scoped worker threads. Tasks are tagged with their
//! owning node by the shared [`Topology`] and land on **one FIFO queue
//! per node**; worker slots are bound to home nodes (node `n` is homed
//! by slot `n % nthreads`, so every node has exactly one home worker).
//! A worker drains its home queues first — computation follows the data
//! on its own node's disk, the premise of the paper — and what an *idle*
//! worker does next is the [`StealPolicy`]:
//!
//! - `Off` — strict locality: the worker stops; a skewed node serializes
//!   behind its home worker but no worker ever touches another node's
//!   data (the multi-node sharding contract).
//! - `Bounded` (default) — steal **one task at a time** from the LIFO
//!   end of the most-loaded node queue, leaving the victim's FIFO front
//!   to its home worker.
//! - `Greedy` — the pre-locality flat cursor: any worker takes the
//!   globally lowest-index remaining task (bench baseline).
//!
//! When a worker dequeues a task, the pool posts a **cross-task prefetch
//! hint** for up to [`WorkerPool::hint_ahead`] tasks still queued on the
//! same node (default 1 — the next task only; each task hinted at most
//! once): the caller-supplied hint closure typically
//! warms that bucket's file through the node's read-ahead lane
//! ([`crate::storage::pipeline`]), so the next scan starts with its
//! first chunk already staged.
//!
//! Scheduling only moves *where and when* a task runs — three mechanisms
//! keep the result *observably identical* to a serial run regardless of
//! worker count, steal policy or schedule:
//!
//! 1. results are returned **indexed by task** (ascending bucket order),
//!    never in completion order;
//! 2. delayed operations issued by user functions *during* a task are
//!    **captured** into per-task, per-destination logs and replayed into
//!    the destination [`StagedOps`] only after the barrier, ordered by
//!    (task index, destination, issue order) — each destination's staging
//!    receives exactly the byte sequence a serial run produces (only the
//!    interleaving *across* destinations differs, which no buffer
//!    observes);
//! 3. errors and panics are reported for the **lowest-index** failing
//!    task, not whichever thread lost the race.
//!
//! The pool uses `std::thread::scope`, so task closures may borrow from
//! the caller; worker threads live for one collective. Thread-locals
//! (e.g. the op-encode scratch in [`crate::roomy::ops`]) are therefore
//! genuinely *per-worker* scratch — every worker thread owns a private
//! instance for the duration of the collective.
//!
//! Nested collectives are not supported from inside task closures: a task
//! may *stage* delayed ops on any structure, but must not invoke another
//! structure's `sync`/`map`/`reduce` (the inner barrier would replay its
//! captured ops out of order with respect to the outer collective).
//!
//! Space note: op capture is **spill-backed**, so the strict space bound
//! holds inside collectives too. Each task's [`OpCapture`] keeps one
//! [`SpillBuffer`] per destination structure, and all of a task's logs
//! share one **flat**
//! [`RoomyConfig::capture_spill_threshold`](crate::RoomyConfig::capture_spill_threshold)
//! budget: when a push takes the task's total capture RAM over the
//! budget, the largest log flushes to its private scratch file
//! (`tmp/capture/r<run>t<task>/d<K>.capture` on a node disk, created
//! lazily) until the task is back under. Per-task capture RAM is
//! O(threshold), not O(ops issued) and not O(destination structures).
//! Budget-forced flushes are counted in
//! [`PoolStats::capture_budget_spills`](crate::metrics::PoolStats::capture_budget_spills).
//! Post-barrier replay streams each log back in (task, destination,
//! issue) order — per-destination byte order identical to serial — and
//! deletes the scratch files; failed or panicking tasks delete theirs on
//! drop, so `tmp/capture/` never leaks. Direct (outside-collective)
//! staging keeps the seed's spill-at-threshold bound as before. Capture
//! volume is observable via the capture counters in
//! [`crate::metrics::PoolStats`].

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cluster::Topology;
use crate::config::StealPolicy;
use crate::error::{Result, RoomyError};
use crate::metrics::PoolStats;
use crate::obs::hist;
use crate::roomy::ops::StagedOps;
use crate::storage::{NodeDisk, SpillBuffer};

/// Capture log record header: `[bucket u32 LE, payload len u32 LE]`.
const CAPTURE_HDR: usize = 8;

/// Ceiling on the cross-task prefetch hint distance: the most queued
/// successors one dequeue may hint. Fixed so [`Take`] stays a flat,
/// allocation-free struct on the dequeue path. Hinting further ahead than
/// the deepest read-ahead lane (`io_pipeline_depth` caps at small values
/// in practice) only evicts its own warm chunks.
pub const MAX_HINT_AHEAD: usize = 4;

/// Where one task's capture logs overflow to: a private scratch directory
/// on one node disk, created lazily on first spill and removed when the
/// capture is replayed or discarded. `threshold` is the task's **flat**
/// capture-RAM budget, shared across all destination logs.
pub(crate) struct CaptureBacking {
    disk: Arc<NodeDisk>,
    dir_rel: String,
    threshold: usize,
}

/// One destination structure's capture log within a task.
struct DestLog {
    sink: Arc<StagedOps>,
    buf: SpillBuffer,
}

/// Per-task log of delayed ops issued while the task ran. One
/// [`SpillBuffer`] per destination structure holds `[bucket, len,
/// payload]` records in issue order; all of a task's logs share one flat
/// `capture_spill_threshold` budget — when a push takes the task's total
/// capture RAM over it, the largest log flushes to scratch (ties go to
/// the oldest log), so capture RAM per task stays O(threshold) however
/// many ops a collective issues and however many structures it stages
/// into. Without backing (a bare pool outside any cluster) logs are
/// RAM-only, preserving the old unbounded behavior.
pub(crate) struct OpCapture {
    backing: Option<CaptureBacking>,
    logs: Vec<DestLog>,
    /// Record bytes captured (headers included).
    bytes: u64,
    /// High-water mark of capture RAM across this task's logs, including
    /// the transient peak just before a push triggers a spill.
    peak_ram: usize,
    /// Sum of `ram_bytes()` across logs, maintained incrementally so the
    /// per-op path never scans the log list.
    ram_total: usize,
    /// Spills forced by the shared budget (reported to `PoolStats`).
    budget_spills: u64,
    /// Log index the previous op hit — consecutive ops overwhelmingly
    /// target the same destination, so this usually skips the lookup.
    last_idx: usize,
}

impl OpCapture {
    fn new(backing: Option<CaptureBacking>) -> Self {
        OpCapture {
            backing,
            logs: Vec::new(),
            bytes: 0,
            peak_ram: 0,
            ram_total: 0,
            budget_spills: 0,
            last_idx: 0,
        }
    }

    fn push(&mut self, sink: Arc<StagedOps>, bucket: u32, rec: &[u8]) -> Result<()> {
        // The transient maximum inside this push: current RAM across all
        // logs plus the record about to be appended (the budget check
        // runs after the append).
        self.peak_ram = self.peak_ram.max(self.ram_total + CAPTURE_HDR + rec.len());

        let idx = if self
            .logs
            .get(self.last_idx)
            .is_some_and(|l| Arc::ptr_eq(&l.sink, &sink))
        {
            self.last_idx
        } else {
            match self.logs.iter().position(|l| Arc::ptr_eq(&l.sink, &sink)) {
                Some(i) => i,
                None => {
                    // Spill timing is driven by the shared budget below,
                    // so the buffer's own threshold is disarmed (it only
                    // spills when this capture tells it to).
                    let buf = match &self.backing {
                        Some(b) => SpillBuffer::new(
                            Arc::clone(&b.disk),
                            format!("{}/d{}.capture", b.dir_rel, self.logs.len()),
                            usize::MAX,
                        ),
                        None => SpillBuffer::ram_only(),
                    };
                    self.logs.push(DestLog { sink, buf });
                    self.logs.len() - 1
                }
            }
        };
        self.last_idx = idx;
        let buf = &mut self.logs[idx].buf;
        let mut hdr = [0u8; CAPTURE_HDR];
        hdr[..4].copy_from_slice(&bucket.to_le_bytes());
        hdr[4..].copy_from_slice(&(rec.len() as u32).to_le_bytes());
        buf.push(&hdr)?;
        buf.push(rec)?;
        self.ram_total += CAPTURE_HDR + rec.len();
        self.bytes += (CAPTURE_HDR + rec.len()) as u64;

        // Flat per-task budget: flush the largest log until back under.
        if let Some(b) = &self.backing {
            while self.ram_total > b.threshold {
                let victim = self
                    .logs
                    .iter()
                    .enumerate()
                    .max_by_key(|&(i, l)| (l.buf.ram_bytes(), std::cmp::Reverse(i)))
                    .map(|(i, l)| (i, l.buf.ram_bytes()))
                    .expect("over-budget capture has at least one log");
                let (vi, vram) = victim;
                if vram == 0 {
                    break; // nothing left to flush (tiny budget, all spilled)
                }
                self.logs[vi].buf.spill()?;
                self.ram_total -= vram;
                self.budget_spills += 1;
            }
        }
        Ok(())
    }

    /// Stream every captured op back to its destination, per destination
    /// in issue order (destinations in first-op order). Consumes the logs;
    /// each scratch file is deleted as its drain is dropped, even if a
    /// downstream stage fails mid-replay.
    fn replay(&mut self) -> Result<()> {
        let logs = std::mem::take(&mut self.logs);
        let mut payload = Vec::new();
        for log in logs {
            let mut drain = log.buf.into_drain()?;
            let mut hdr = [0u8; CAPTURE_HDR];
            while drain.read_exact_or_eof(&mut hdr)? {
                let bucket = u32::from_le_bytes(hdr[..4].try_into().unwrap());
                let len = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
                payload.resize(len, 0);
                if !drain.read_exact_or_eof(&mut payload)? {
                    return Err(RoomyError::InvalidArg(
                        "truncated record in capture log".into(),
                    ));
                }
                log.sink.stage_direct(bucket, &payload)?;
            }
        }
        Ok(())
    }

    /// Bytes spilled to scratch files across this task's logs.
    fn spilled_bytes(&self) -> u64 {
        self.logs.iter().map(|l| l.buf.spilled_bytes()).sum()
    }

    /// Scratch files created (logs that overflowed to disk).
    fn scratch_files(&self) -> u64 {
        self.logs.iter().filter(|l| l.buf.spilled_bytes() > 0).count() as u64
    }
}

impl Drop for OpCapture {
    /// Leak-free teardown on every path: un-replayed logs (task error,
    /// worker panic, a failure elsewhere in the collective) drop their
    /// spill files, and the task's scratch directory goes with them.
    fn drop(&mut self) {
        for log in &mut self.logs {
            let _ = log.buf.clear();
        }
        if let Some(b) = &self.backing {
            let _ = b.disk.remove_dir(&b.dir_rel);
        }
    }
}

/// Per-thread task context, present only while a pool worker is inside a
/// task closure.
struct TaskCtx {
    worker: usize,
    capture: OpCapture,
}

thread_local! {
    static TASK: RefCell<Option<TaskCtx>> = const { RefCell::new(None) };
}

/// Cheap probe: is the calling thread inside a pool task (capture armed)?
pub(crate) fn capture_active() -> bool {
    TASK.with(|t| t.borrow().is_some())
}

/// Capture `rec` into the current task's op log, if the calling thread is
/// inside a pool task. Returns `Ok(false)` when no task is active (the
/// caller should stage directly); errors are spill-file I/O failures.
pub(crate) fn try_capture(sink: &Arc<StagedOps>, bucket: u32, rec: &[u8]) -> Result<bool> {
    TASK.with(|t| match t.borrow_mut().as_mut() {
        Some(ctx) => {
            ctx.capture.push(Arc::clone(sink), bucket, rec)?;
            Ok(true)
        }
        None => Ok(false),
    })
}

/// Pool worker slot of the calling thread, if it is currently executing a
/// pool task (per-worker scratch, diagnostics).
pub fn current_worker() -> Option<usize> {
    TASK.with(|t| t.borrow().as_ref().map(|c| c.worker))
}

/// One finished task, tagged with its index for deterministic merging.
struct Done<R> {
    task: usize,
    result: Result<R>,
    capture: OpCapture,
}

/// One dequeued task: its index, whether it came off the worker's own
/// home queue, and up to `hint_ahead` tasks still queued on the same node
/// (the prefetch-hint candidates, nearest first). Fixed-width so the
/// dequeue path never allocates.
struct Take {
    task: usize,
    local: bool,
    hints: [usize; MAX_HINT_AHEAD],
    nhints: usize,
}

/// First `k` tasks still queued, nearest first, into a flat array.
fn peek_hints(q: &VecDeque<usize>, k: usize) -> ([usize; MAX_HINT_AHEAD], usize) {
    let mut hints = [0usize; MAX_HINT_AHEAD];
    let mut n = 0;
    for &t in q.iter().take(k.min(MAX_HINT_AHEAD)) {
        hints[n] = t;
        n += 1;
    }
    (hints, n)
}

/// Where one collective's tasks are drawn from.
enum SourceKind {
    /// `Greedy`: the flat global cursor of the pre-locality engine —
    /// every worker takes the lowest-index remaining task.
    Cursor { cursor: AtomicUsize, ntasks: usize },
    /// `Off` / `Bounded`: one FIFO queue per node, tasks ascending.
    /// `lens` mirrors the queue sizes so victim selection does not lock
    /// every queue; each is decremented under its queue's lock, so a
    /// zero read without the lock is authoritative once all pops drain.
    Queues {
        queues: Vec<Mutex<VecDeque<usize>>>,
        lens: Vec<AtomicUsize>,
        steal: bool,
    },
}

/// Per-collective task source: the schedule lives here, the determinism
/// lives in the merge (results by task index, capture replay in (task,
/// issue) order) — so this type may hand tasks out in any order it
/// likes.
struct TaskSource {
    kind: SourceKind,
    /// Tasks initially queued per node — each queue's peak depth, since
    /// queues only drain (reported to [`PoolStats`]).
    depths: Vec<u64>,
}

impl TaskSource {
    fn build(ntasks: usize, topo: &Topology, policy: StealPolicy) -> TaskSource {
        let nodes = topo.nodes();
        let mut depths = vec![0u64; nodes];
        for t in 0..ntasks {
            depths[topo.owner(t as u32)] += 1;
        }
        let kind = match policy {
            StealPolicy::Greedy => {
                SourceKind::Cursor { cursor: AtomicUsize::new(0), ntasks }
            }
            _ => {
                let mut qs: Vec<VecDeque<usize>> =
                    (0..nodes).map(|n| VecDeque::with_capacity(depths[n] as usize)).collect();
                for t in 0..ntasks {
                    qs[topo.owner(t as u32)].push_back(t);
                }
                SourceKind::Queues {
                    queues: qs.into_iter().map(Mutex::new).collect(),
                    lens: depths.iter().map(|&d| AtomicUsize::new(d as usize)).collect(),
                    steal: policy == StealPolicy::Bounded,
                }
            }
        };
        TaskSource { kind, depths }
    }

    /// Next task for worker `wid`, or `None` when this worker is done:
    /// all queues empty, or (under `Off`) its home queues empty.
    fn next(
        &self,
        wid: usize,
        nthreads: usize,
        homes: &[usize],
        home_cursor: &mut usize,
        topo: &Topology,
        hint_k: usize,
    ) -> Option<Take> {
        match &self.kind {
            SourceKind::Cursor { cursor, ntasks } => {
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t >= *ntasks {
                    return None;
                }
                Some(Take {
                    task: t,
                    local: topo.home_worker(topo.owner(t as u32), nthreads) == wid,
                    // no hints: greedy is the faithful pre-locality
                    // baseline, and the global next task is usually
                    // dequeued by another worker before a warm could
                    // land — it would only race its own consumer
                    hints: [0; MAX_HINT_AHEAD],
                    nhints: 0,
                })
            }
            SourceKind::Queues { queues, lens, steal } => {
                // Home drain: finish the current home node before moving
                // to the next (one streaming disk at a time), FIFO within
                // a node so hints always name the next bucket to run.
                for k in 0..homes.len() {
                    let n = homes[(*home_cursor + k) % homes.len()];
                    if lens[n].load(Ordering::Relaxed) == 0 {
                        continue;
                    }
                    let mut q = queues[n].lock().expect("node queue poisoned");
                    if let Some(t) = q.pop_front() {
                        lens[n].fetch_sub(1, Ordering::Relaxed);
                        let (hints, nhints) = peek_hints(&q, hint_k);
                        drop(q);
                        *home_cursor = (*home_cursor + k) % homes.len();
                        return Some(Take { task: t, local: true, hints, nhints });
                    }
                }
                if !*steal {
                    return None; // strict locality: idle when home is dry
                }
                // Bounded steal: one task from the LIFO end of the most
                // loaded queue (ties → lowest node); rescan on a race.
                loop {
                    let victim = lens
                        .iter()
                        .enumerate()
                        .map(|(n, l)| (l.load(Ordering::Relaxed), n))
                        .filter(|&(len, _)| len > 0)
                        .max_by_key(|&(len, n)| (len, std::cmp::Reverse(n)))
                        .map(|(_, n)| n)?;
                    let mut q = queues[victim].lock().expect("node queue poisoned");
                    if let Some(t) = q.pop_back() {
                        lens[victim].fetch_sub(1, Ordering::Relaxed);
                        let (hints, nhints) = peek_hints(&q, hint_k);
                        drop(q);
                        return Some(Take { task: t, local: false, hints, nhints });
                    }
                }
            }
        }
    }
}

/// Spill backing shared by every capture the pool arms: the cluster's
/// node disks, the capture threshold, and a run counter that keeps the
/// scratch directories of concurrent collectives on one pool disjoint.
#[derive(Debug)]
struct CaptureSpillCfg {
    disks: Vec<Arc<NodeDisk>>,
    threshold: usize,
    runs: AtomicU64,
}

/// Fixed-width worker pool executing per-bucket collective tasks. One
/// pool lives in each [`crate::cluster::Cluster`]; worker threads are
/// scoped per collective (no idle threads between collectives).
#[derive(Debug)]
pub struct WorkerPool {
    workers: usize,
    stats: PoolStats,
    capture: Option<CaptureSpillCfg>,
    steal: StealPolicy,
    /// Cross-task prefetch hint distance: queued successors hinted per
    /// dequeue (1 = the seed's next-task-only behavior). Atomic so the
    /// autotune controller can adjust it through a shared reference
    /// between collectives; hints never change what a task reads, only
    /// when bytes move, so any value is byte-identical.
    hint_ahead: AtomicUsize,
    /// Effective worker width: threads actually spawned per collective
    /// (`1..=workers`). The autotune width policy narrows this when few
    /// nodes have work and task skew makes extra slots pure steal
    /// contention. Like `hint_ahead`, it only moves *when* tasks run,
    /// never what they compute — results and replay stay in task order,
    /// so every width trajectory is byte-identical.
    effective_width: AtomicUsize,
    /// When set, a `Bounded` steal policy escalates to `Greedy` for the
    /// next collectives (extreme-skew response: stragglers dominate, so
    /// locality is worth trading for drain speed). `Off` is never
    /// escalated — multi-node sharding relies on strict homing.
    steal_boost: AtomicBool,
}

impl WorkerPool {
    /// Pool of `workers` threads (clamped to ≥ 1). Until
    /// [`WorkerPool::set_capture_spill`] is called, op capture is RAM-only
    /// (no disks to spill to). Stealing defaults to
    /// [`StealPolicy::Bounded`]; [`crate::cluster::Cluster::new`] installs
    /// the configured policy.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        WorkerPool {
            workers,
            stats: PoolStats::new(workers),
            capture: None,
            steal: StealPolicy::default(),
            hint_ahead: AtomicUsize::new(1),
            effective_width: AtomicUsize::new(workers),
            steal_boost: AtomicBool::new(false),
        }
    }

    /// Install the idle-worker scheduling policy (see [`StealPolicy`]).
    pub fn set_steal_policy(&mut self, policy: StealPolicy) {
        self.steal = policy;
    }

    /// The idle-worker scheduling policy in force.
    pub fn steal_policy(&self) -> StealPolicy {
        self.steal
    }

    /// Set the cross-task prefetch hint distance, clamped to
    /// `1..=`[`MAX_HINT_AHEAD`]. Takes effect at the next collective.
    pub fn set_hint_ahead(&self, k: usize) {
        self.hint_ahead.store(k.clamp(1, MAX_HINT_AHEAD), Ordering::Relaxed);
    }

    /// The cross-task prefetch hint distance in force (default 1).
    pub fn hint_ahead(&self) -> usize {
        self.hint_ahead.load(Ordering::Relaxed)
    }

    /// Set the effective worker width, clamped to `1..=num_workers`.
    /// Sampled once at the top of each collective, so a running
    /// collective keeps the width it started with.
    pub fn set_effective_width(&self, w: usize) {
        self.effective_width.store(w.clamp(1, self.workers), Ordering::Relaxed);
    }

    /// The effective worker width in force (default: the full pool).
    pub fn effective_width(&self) -> usize {
        self.effective_width.load(Ordering::Relaxed)
    }

    /// Toggle the extreme-skew steal escalation (`Bounded` → `Greedy`
    /// for subsequent collectives). A no-op under `Off` or `Greedy`.
    pub fn set_steal_boost(&self, on: bool) {
        self.steal_boost.store(on, Ordering::Relaxed);
    }

    /// Whether the steal escalation is currently requested.
    pub fn steal_boost(&self) -> bool {
        self.steal_boost.load(Ordering::Relaxed)
    }

    /// The steal policy a collective starting now would run under:
    /// the configured policy, escalated `Bounded` → `Greedy` while the
    /// boost is set. `Off` is never escalated.
    pub fn effective_steal_policy(&self) -> StealPolicy {
        if self.steal == StealPolicy::Bounded && self.steal_boost() {
            StealPolicy::Greedy
        } else {
            self.steal
        }
    }

    /// Back op capture with scratch files on `disks` (task `t` scratches
    /// on `disks[t % disks.len()]` — the owner of bucket `t` under the
    /// cluster's round-robin layout). `threshold` is each task's **flat**
    /// capture-RAM budget across all of its destination logs. Called by
    /// [`crate::cluster::Cluster::new`] with
    /// [`RoomyConfig::capture_spill_threshold`](crate::RoomyConfig::capture_spill_threshold).
    pub(crate) fn set_capture_spill(&mut self, disks: Vec<Arc<NodeDisk>>, threshold: usize) {
        debug_assert!(!disks.is_empty() && threshold > 0);
        self.capture = Some(CaptureSpillCfg { disks, threshold, runs: AtomicU64::new(0) });
    }

    /// Configured worker count.
    pub fn num_workers(&self) -> usize {
        self.workers
    }

    /// Per-worker execution counters.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Spill backing for task `t` of run `run`, if the pool has disks.
    fn capture_backing(&self, run: u64, t: usize) -> Option<CaptureBacking> {
        self.capture.as_ref().map(|c| CaptureBacking {
            disk: Arc::clone(&c.disks[t % c.disks.len()]),
            dir_rel: format!("tmp/capture/r{run}t{t}"),
            threshold: c.threshold,
        })
    }

    /// Run `job(task)` for every `task` in `0..ntasks` across the pool and
    /// return the results **in task order**. Tasks are spread over the
    /// degenerate one-task-per-slot [`Topology`] (task `t` homes on slot
    /// `t % workers`); no prefetch hints. See [`WorkerPool::run_tagged`].
    pub fn run_tasks<R, F>(&self, phase: &str, ntasks: usize, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> Result<R> + Sync,
    {
        self.run_tagged(phase, ntasks, Topology::flat(self.workers), |_t| {}, job)
    }

    /// Run `job(task)` for every `task` in `0..ntasks` across the pool's
    /// per-node work queues and return the results **in task order**.
    /// `topo` tags each task with its owning node; worker slots are bound
    /// to home nodes and idle slots follow the configured
    /// [`StealPolicy`]. When a task is dequeued, `hint(next)` is invoked
    /// for the next task still queued on the same node (at most once per
    /// task) — the cross-task prefetch entry point.
    ///
    /// Delayed ops issued inside `job` are captured per task and replayed
    /// in (task, destination, issue) order after all tasks complete — per
    /// destination buffer that is the serial byte order; see the module
    /// docs for why this makes the schedule invisible.
    ///
    /// On failure the error of the lowest-index failing task is returned
    /// (a panic in task `t` beats an `Err` from any task after `t`);
    /// captured ops are *not* replayed, matching the undefined partial
    /// state any failed collective leaves on disk — but every task's
    /// capture scratch files are removed, so failure never leaks disk
    /// space under `tmp/capture/`.
    pub fn run_tagged<R, F, H>(
        &self,
        phase: &str,
        ntasks: usize,
        topo: Topology,
        hint: H,
        job: F,
    ) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> Result<R> + Sync,
        H: Fn(usize) + Sync,
    {
        if ntasks == 0 {
            return Ok(Vec::new());
        }
        // Width and steal policy are sampled once per collective (like
        // the hint distance) so every worker sees one consistent value.
        let nthreads = self.effective_width().min(ntasks);
        let nodes = topo.nodes();
        let source = TaskSource::build(ntasks, &topo, self.effective_steal_policy());
        self.stats.note_queue_depths(&source.depths);
        // Each task's hint fires at most once, whichever worker peeks it.
        let hinted: Vec<AtomicBool> = (0..ntasks).map(|_| AtomicBool::new(false)).collect();
        // Hint distance is sampled once per collective so every worker
        // sees one consistent value for the whole run.
        let hint_k = self.hint_ahead();
        let abort = AtomicBool::new(false);
        let run = self
            .capture
            .as_ref()
            .map(|c| c.runs.fetch_add(1, Ordering::Relaxed))
            .unwrap_or(0);

        let outs: Vec<(Vec<Done<R>>, Option<(usize, usize)>)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..nthreads)
                    .map(|wid| {
                        let (abort, job, stats) = (&abort, &job, &self.stats);
                        let (source, hinted, hint, topo) = (&source, &hinted, &hint, &topo);
                        scope.spawn(move || {
                            // Home nodes of this slot: {n : n % nthreads == wid}.
                            let homes: Vec<usize> =
                                (wid..nodes).step_by(nthreads).collect();
                            let mut home_cursor = 0usize;
                            let mut done: Vec<Done<R>> = Vec::new();
                            let mut panicked: Option<(usize, usize)> = None;
                            while !abort.load(Ordering::Relaxed) {
                                let Some(take) = source.next(
                                    wid,
                                    nthreads,
                                    &homes,
                                    &mut home_cursor,
                                    topo,
                                    hint_k,
                                ) else {
                                    break;
                                };
                                for &nx in &take.hints[..take.nhints] {
                                    if !hinted[nx].swap(true, Ordering::Relaxed) {
                                        hint(nx);
                                    }
                                }
                                stats.add_locality(take.local);
                                let t = take.task;
                                let t0 = Instant::now();
                                TASK.with(|c| {
                                    *c.borrow_mut() = Some(TaskCtx {
                                        worker: wid,
                                        capture: OpCapture::new(
                                            self.capture_backing(run, t),
                                        ),
                                    })
                                });
                                // Flight recorder: one span per bucket
                                // task, on this worker's track, tagged
                                // with the owning node and whether the
                                // task was stolen. Disarmed = no-op.
                                let mut tsp = crate::obs::trace::span_at(
                                    crate::obs::trace::Kind::Task,
                                    phase,
                                    Some(topo.owner(t as u32)),
                                    wid,
                                );
                                tsp.set_args(t as u64, u64::from(!take.local));
                                let r = catch_unwind(AssertUnwindSafe(|| job(t)));
                                drop(tsp);
                                let ctx = TASK
                                    .with(|c| c.borrow_mut().take())
                                    .expect("pool task context vanished");
                                let dt = t0.elapsed();
                                stats.charge(wid, dt);
                                hist::record(hist::Domain::Task, topo.owner(t as u32), dt);
                                stats.charge_capture(
                                    ctx.capture.bytes,
                                    ctx.capture.spilled_bytes(),
                                    ctx.capture.scratch_files(),
                                    ctx.capture.peak_ram as u64,
                                    ctx.capture.budget_spills,
                                );
                                match r {
                                    Ok(result) => {
                                        if result.is_err() {
                                            abort.store(true, Ordering::Relaxed);
                                        }
                                        done.push(Done {
                                            task: t,
                                            result,
                                            capture: ctx.capture,
                                        });
                                    }
                                    Err(_) => {
                                        panicked = Some((t, wid));
                                        abort.store(true, Ordering::Relaxed);
                                        break;
                                    }
                                }
                            }
                            (done, panicked)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("pool worker thread died outside a task"))
                    .collect()
            });

        // Deterministic merge: order everything by task index, then report
        // the lowest-index failure (panic wins ties with itself only).
        let mut all: Vec<Done<R>> = Vec::with_capacity(ntasks);
        let mut panic_at: Option<(usize, usize)> = None;
        for (done, p) in outs {
            all.extend(done);
            if let Some((t, w)) = p {
                panic_at = Some(match panic_at {
                    Some((pt, pw)) if pt <= t => (pt, pw),
                    _ => (t, w),
                });
            }
        }
        all.sort_by_key(|d| d.task);

        let first_err_task = all.iter().find(|d| d.result.is_err()).map(|d| d.task);
        if let Some((pt, pw)) = panic_at {
            if first_err_task.is_none_or(|et| pt < et) {
                return Err(RoomyError::WorkerPanic {
                    worker: pw,
                    phase: phase.to_string(),
                });
            }
        }

        let mut results = Vec::with_capacity(ntasks);
        let mut captures = Vec::with_capacity(ntasks);
        for d in all {
            match d.result {
                Ok(r) => {
                    results.push(r);
                    captures.push(d.capture);
                }
                Err(e) => return Err(e),
            }
        }
        debug_assert_eq!(results.len(), ntasks, "abort never set ⇒ all tasks ran");

        // Post-barrier replay: (task index, issue order) == serial order.
        // Each capture is dropped as soon as it has replayed, deleting its
        // scratch directory; on error the remaining captures drop too, so
        // no scratch state survives a failed collective.
        for mut cap in captures {
            cap.replay()?;
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::RoomyConfig;
    use crate::testutil::tmpdir;

    fn pool(n: usize) -> WorkerPool {
        WorkerPool::new(n)
    }

    #[test]
    fn results_come_back_in_task_order() {
        for workers in [1, 2, 4, 7] {
            let p = pool(workers);
            let out = p
                .run_tasks("t", 33, |t| {
                    // stagger completion to scramble the schedule
                    if t % 3 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    Ok(t * 10)
                })
                .unwrap();
            assert_eq!(out, (0..33).map(|t| t * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_tasks_is_empty() {
        let p = pool(4);
        let out: Vec<u32> = p.run_tasks("t", 0, |_| Ok(1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn workers_clamped_to_one() {
        let p = pool(0);
        assert_eq!(p.num_workers(), 1);
        let out = p.run_tasks("t", 3, Ok).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn parallelism_is_real() {
        // With 4 workers and 4 tasks, all tasks must be in flight at once.
        let p = pool(4);
        let barrier = std::sync::Barrier::new(4);
        p.run_tasks("t", 4, |_t| {
            barrier.wait();
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn lowest_index_error_wins() {
        let p = pool(4);
        let r: Result<Vec<()>> = p.run_tasks("t", 16, |t| {
            if t >= 3 {
                Err(RoomyError::InvalidArg(format!("task {t}")))
            } else {
                Ok(())
            }
        });
        match r {
            Err(RoomyError::InvalidArg(msg)) => assert_eq!(msg, "task 3"),
            other => panic!("expected InvalidArg, got {other:?}"),
        }
    }

    #[test]
    fn panic_becomes_worker_panic() {
        let p = pool(2);
        let r: Result<Vec<()>> = p.run_tasks("boom-phase", 8, |t| {
            if t == 1 {
                panic!("task exploded");
            }
            Ok(())
        });
        match r {
            Err(RoomyError::WorkerPanic { phase, .. }) => assert_eq!(phase, "boom-phase"),
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
    }

    #[test]
    fn current_worker_visible_inside_tasks_only() {
        assert_eq!(current_worker(), None);
        let p = pool(3);
        p.run_tasks("t", 9, |_t| {
            let w = current_worker().expect("inside a task");
            assert!(w < 3);
            Ok(())
        })
        .unwrap();
        assert_eq!(current_worker(), None);
    }

    #[test]
    fn stats_count_every_task() {
        let p = pool(2);
        p.run_tasks("t", 10, |_| Ok(())).unwrap();
        assert_eq!(p.stats().total_tasks(), 10);
        p.stats().reset();
        assert_eq!(p.stats().total_tasks(), 0);
    }

    /// Strict locality: every task must run on its owning node's home
    /// worker — no worker ever touches another node's tasks.
    #[test]
    fn off_policy_is_strictly_local() {
        let mut p = pool(4);
        p.set_steal_policy(StealPolicy::Off);
        let ran = std::sync::Mutex::new(Vec::new());
        let topo = Topology::new(4, 4); // 16 tasks over 4 nodes
        p.run_tagged("t", 16, topo, |_| {}, |t| {
            // jitter so a non-local scheduler would interleave
            if t % 3 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            ran.lock().unwrap().push((t, current_worker().unwrap()));
            Ok(())
        })
        .unwrap();
        for (t, w) in ran.into_inner().unwrap() {
            assert_eq!(w, topo.owner(t as u32) % 4, "task {t} ran off its home worker");
        }
        assert_eq!(p.stats().steals(), 0);
        assert_eq!(p.stats().locality_hits(), 16);
        assert_eq!(p.stats().locality_rate(), 1.0);
        assert_eq!(p.stats().per_node_queue_depth(), vec![4, 4, 4, 4]);
    }

    /// Off policy still completes when one worker homes several nodes
    /// (num_workers < nodes) — every node has exactly one home worker.
    #[test]
    fn off_policy_covers_unhomed_nodes() {
        let mut p = pool(2);
        p.set_steal_policy(StealPolicy::Off);
        let out = p
            .run_tagged("t", 12, Topology::new(5, 3), |_| {}, |t| Ok(t))
            .unwrap();
        assert_eq!(out, (0..12).collect::<Vec<_>>());
        assert_eq!(p.stats().steals(), 0);
    }

    /// Bounded stealing: when one node's tasks are slow, the other
    /// workers must drain it instead of idling — and the result is still
    /// ordered by task index.
    #[test]
    fn bounded_steal_drains_a_slow_node() {
        let mut p = pool(2);
        p.set_steal_policy(StealPolicy::Bounded);
        let topo = Topology::new(2, 4); // node 0: even tasks, node 1: odd
        let out = p
            .run_tagged("t", 8, topo, |_| {}, |t| {
                if t % 2 == 0 {
                    // node 0's tasks are 20ms each; worker 1 finishes its
                    // four instant tasks and must steal
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Ok(t)
            })
            .unwrap();
        assert_eq!(out, (0..8).collect::<Vec<_>>());
        assert!(p.stats().steals() > 0, "idle worker must have stolen");
        assert_eq!(p.stats().steals() + p.stats().locality_hits(), 8);
    }

    /// Greedy ignores homes (the flat-cursor baseline): a single worker
    /// runs tasks in exactly ascending order, and with several workers
    /// the locality accounting still partitions every task.
    #[test]
    fn greedy_is_flat_cursor() {
        let mut p = pool(1);
        p.set_steal_policy(StealPolicy::Greedy);
        let order = std::sync::Mutex::new(Vec::new());
        p.run_tagged("t", 6, Topology::new(3, 2), |_| {}, |t| {
            order.lock().unwrap().push(t);
            Ok(())
        })
        .unwrap();
        assert_eq!(*order.lock().unwrap(), (0..6).collect::<Vec<_>>());
        // one worker homes every node, so everything is trivially local
        assert_eq!(p.stats().locality_hits(), 6);
        assert_eq!(p.stats().steals(), 0);

        let mut p = pool(3);
        p.set_steal_policy(StealPolicy::Greedy);
        let out = p
            .run_tagged("t", 30, Topology::new(3, 10), |_| {}, |t| {
                if t % 4 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Ok(t)
            })
            .unwrap();
        assert_eq!(out, (0..30).collect::<Vec<_>>());
        assert_eq!(p.stats().steals() + p.stats().locality_hits(), 30);
    }

    /// Every dequeue posts a hint for the next task still queued on the
    /// same node, exactly once per task; the first task of a queue is
    /// never hinted (it is dequeued immediately).
    #[test]
    fn hints_fire_once_for_every_queued_successor() {
        let p = pool(1); // serial: deterministic queue fronts
        let hints = std::sync::Mutex::new(Vec::new());
        p.run_tagged(
            "t",
            6,
            Topology::new(2, 3), // node 0: {0,2,4}, node 1: {1,3,5}
            |t| hints.lock().unwrap().push(t),
            |_t| Ok(()),
        )
        .unwrap();
        let mut got = hints.into_inner().unwrap();
        got.sort();
        // worker 0 homes both nodes: drains node 0 (hints 2, 4) then
        // node 1 (hints 3, 5); queue fronts 0 and 1 are never hinted
        assert_eq!(got, vec![2, 3, 4, 5]);
    }

    /// Raising the hint distance fans each dequeue's hints over several
    /// queued successors, still at most once per task, and clamps to
    /// `MAX_HINT_AHEAD`; queue fronts are dequeued before any peek can
    /// see them, so they are still never hinted.
    #[test]
    fn hint_ahead_widens_the_hint_window() {
        let p = pool(1); // serial: deterministic queue fronts
        assert_eq!(p.hint_ahead(), 1);
        p.set_hint_ahead(3);
        assert_eq!(p.hint_ahead(), 3);
        p.set_hint_ahead(0); // clamps low
        assert_eq!(p.hint_ahead(), 1);
        p.set_hint_ahead(64); // clamps high
        assert_eq!(p.hint_ahead(), MAX_HINT_AHEAD);
        p.set_hint_ahead(3);

        let hints = std::sync::Mutex::new(Vec::new());
        p.run_tagged(
            "t",
            8,
            Topology::new(2, 4), // node 0: {0,2,4,6}, node 1: {1,3,5,7}
            |t| hints.lock().unwrap().push(t),
            |_t| Ok(()),
        )
        .unwrap();
        let mut got = hints.into_inner().unwrap();
        got.sort();
        // every task except the two queue fronts is hinted exactly once
        assert_eq!(got, vec![2, 3, 4, 5, 6, 7]);
    }

    /// The effective width clamps to `1..=workers` and bounds the
    /// threads a collective actually spawns.
    #[test]
    fn effective_width_narrows_the_pool() {
        let p = pool(4);
        assert_eq!(p.effective_width(), 4);
        p.set_effective_width(0); // clamps low
        assert_eq!(p.effective_width(), 1);
        p.set_effective_width(99); // clamps high
        assert_eq!(p.effective_width(), 4);

        // Width 1: tasks can never overlap, whatever the topology says.
        p.set_effective_width(1);
        let in_flight = AtomicUsize::new(0);
        let results = p
            .run_tagged("t", 8, Topology::new(4, 2), |_| {}, |t| {
                assert_eq!(
                    in_flight.fetch_add(1, Ordering::SeqCst),
                    0,
                    "width 1 must serialize tasks"
                );
                std::thread::sleep(std::time::Duration::from_micros(100));
                in_flight.fetch_sub(1, Ordering::SeqCst);
                Ok(t * 2)
            })
            .unwrap();
        assert_eq!(results, (0..8).map(|t| t * 2).collect::<Vec<_>>());

        // Restored width runs the full pool again.
        p.set_effective_width(4);
        let r = p.run_tasks("t", 6, |t| Ok(t)).unwrap();
        assert_eq!(r, (0..6).collect::<Vec<_>>());
    }

    /// The steal boost escalates `Bounded` to `Greedy` and nothing else:
    /// `Off` keeps the multi-node sharding contract, `Greedy` is already
    /// maximal.
    #[test]
    fn steal_boost_escalates_bounded_only() {
        let mut p = pool(2);
        assert_eq!(p.effective_steal_policy(), StealPolicy::Bounded);
        p.set_steal_boost(true);
        assert_eq!(p.effective_steal_policy(), StealPolicy::Greedy);
        p.set_steal_boost(false);
        assert_eq!(p.effective_steal_policy(), StealPolicy::Bounded);

        p.set_steal_policy(StealPolicy::Off);
        p.set_steal_boost(true);
        assert_eq!(p.effective_steal_policy(), StealPolicy::Off, "Off is never escalated");

        p.set_steal_policy(StealPolicy::Greedy);
        assert_eq!(p.effective_steal_policy(), StealPolicy::Greedy);

        // A boosted collective still returns results in task order.
        p.set_steal_policy(StealPolicy::Bounded);
        p.set_steal_boost(true);
        let r = p.run_tagged("t", 10, Topology::new(2, 5), |_| {}, |t| Ok(t)).unwrap();
        assert_eq!(r, (0..10).collect::<Vec<_>>());
    }

    /// Captured ops must replay in (task, issue) order — the serial byte
    /// order — no matter how many workers race.
    #[test]
    fn capture_replays_in_serial_order() {
        let t = tmpdir("pool_capture");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let mut reference: Option<Vec<u8>> = None;
        for workers in [1usize, 2, 4] {
            let p = pool(workers);
            p.run_tasks("t", 8, |task| {
                // jitter the schedule
                if task % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(150));
                }
                for k in 0..3u8 {
                    staged.stage(0, &[task as u8, k])?;
                }
                Ok(())
            })
            .unwrap();

            let buf = staged.take(0, &cluster, "cap", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            match &reference {
                None => {
                    // serial (1 worker) defines the canonical order:
                    // task-major, issue-minor
                    let expect: Vec<u8> = (0..8u8)
                        .flat_map(|t| (0..3u8).map(move |k| [t, k]))
                        .flatten()
                        .collect();
                    assert_eq!(got, expect);
                    reference = Some(got);
                }
                Some(r0) => assert_eq!(&got, r0, "workers={workers} diverged"),
            }
        }
    }

    use crate::testutil::files_under;

    /// With spill backing and a tiny threshold, capture overflows to
    /// scratch files, replays in serial order, keeps per-task RAM bounded,
    /// and removes every scratch file afterwards.
    #[test]
    fn spill_backed_capture_replays_and_cleans_up() {
        let t = tmpdir("pool_capture_spill");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let threshold = 16usize;
        let rec_len = 2usize;
        let mut reference: Option<Vec<u8>> = None;
        for workers in [1usize, 2, 4] {
            let mut p = pool(workers);
            p.set_capture_spill(cluster.disks().to_vec(), threshold);
            p.run_tasks("t", 6, |task| {
                if task % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(150));
                }
                // ~10x threshold bytes of ops per task
                for k in 0..16u8 {
                    staged.stage(0, &[task as u8, k])?;
                }
                Ok(())
            })
            .unwrap();

            assert!(p.stats().capture_spilled_bytes() > 0, "must have spilled");
            assert!(p.stats().capture_scratch_files() > 0);
            assert!(
                p.stats().capture_peak_task_ram() as usize
                    <= threshold + super::CAPTURE_HDR + rec_len,
                "peak capture RAM {} exceeds threshold {} + record",
                p.stats().capture_peak_task_ram(),
                threshold,
            );
            // scratch fully cleaned after the barrier
            for w in 0..cluster.nworkers() {
                let scratch = cluster.disk(w).root().join("tmp/capture");
                assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
            }

            let buf = staged.take(0, &cluster, "cap", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            match &reference {
                None => {
                    let expect: Vec<u8> = (0..6u8)
                        .flat_map(|t| (0..16u8).map(move |k| [t, k]))
                        .flatten()
                        .collect();
                    assert_eq!(got, expect);
                    reference = Some(got);
                }
                Some(r0) => assert_eq!(&got, r0, "workers={workers} diverged"),
            }
        }
    }

    /// The capture budget is **flat per task**: staging into several
    /// destination structures shares one threshold, so peak capture RAM
    /// stays ≤ threshold + one record however many destinations a task
    /// touches — and the forced flushes are counted.
    #[test]
    fn flat_budget_shared_across_destinations() {
        let t = tmpdir("pool_capture_flat");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let dst_a = StagedOps::new(&cluster, "fa", 1 << 20);
        let dst_b = StagedOps::new(&cluster, "fb", 1 << 20);

        let threshold = 64usize;
        let rec_len = 2usize;
        let mut p = pool(2);
        p.set_capture_spill(cluster.disks().to_vec(), threshold);
        p.run_tasks("t", 4, |task| {
            // alternate destinations; per-destination volume stays under
            // the threshold, but the task total (~320 bytes) exceeds it —
            // only the shared budget can force spills here
            for k in 0..16u8 {
                let rec = [task as u8, k];
                if k % 2 == 0 {
                    dst_a.stage(0, &rec)?;
                } else {
                    dst_b.stage(0, &rec)?;
                }
            }
            Ok(())
        })
        .unwrap();

        assert!(p.stats().capture_budget_spills() > 0, "budget never forced a spill");
        assert!(
            p.stats().capture_peak_task_ram() as usize
                <= threshold + super::CAPTURE_HDR + rec_len,
            "flat budget violated: peak {} > {} + record",
            p.stats().capture_peak_task_ram(),
            threshold,
        );
        // both destinations replayed in serial order
        for (staged, parity) in [(&dst_a, 0u8), (&dst_b, 1u8)] {
            let buf = staged.take(0, &cluster, "f", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            let expect: Vec<u8> = (0..4u8)
                .flat_map(|t| (0..16u8).filter(move |k| k % 2 == parity).map(move |k| [t, k]))
                .flatten()
                .collect();
            assert_eq!(got, expect, "destination parity {parity} diverged");
        }
        // scratch fully cleaned after the barrier
        for w in 0..cluster.nworkers() {
            let scratch = cluster.disk(w).root().join("tmp/capture");
            assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
        }
    }

    /// A panicking task must not leave capture scratch files behind, and
    /// neither must the already-completed tasks whose captures are thrown
    /// away with the failed collective.
    #[test]
    fn failed_collective_leaves_no_capture_scratch() {
        let t = tmpdir("pool_capture_panic");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let mut p = pool(4);
        p.set_capture_spill(cluster.disks().to_vec(), 8);
        let r: Result<Vec<()>> = p.run_tasks("boom", 8, |task| {
            for k in 0..32u8 {
                staged.stage(0, &[task as u8, k])?; // forces spills
            }
            if task == 5 {
                panic!("mid-collective failure");
            }
            Ok(())
        });
        assert!(matches!(r, Err(RoomyError::WorkerPanic { .. })));
        for w in 0..cluster.nworkers() {
            let scratch = cluster.disk(w).root().join("tmp/capture");
            assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
        }
        // nothing was replayed either
        assert_eq!(staged.staged_bytes(), 0);
    }

    /// Ops staged outside any pool task go straight to the buffer.
    #[test]
    fn direct_staging_outside_pool() {
        let t = tmpdir("pool_direct");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 1;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "d", 64);
        staged.stage(0, &[1, 2, 3]).unwrap();
        assert_eq!(staged.staged_bytes(), 3);
    }
}
