//! Worker-pool execution engine for Roomy collectives.
//!
//! A [`WorkerPool`] fans a set of **independent bucket tasks** out to
//! `num_workers` scoped worker threads. Workers claim tasks dynamically
//! (an atomic cursor — cheap work stealing, so a skewed bucket does not
//! stall the others), and three mechanisms keep the result *observably
//! identical* to a serial run regardless of worker count or schedule:
//!
//! 1. results are returned **indexed by task** (ascending bucket order),
//!    never in completion order;
//! 2. delayed operations issued by user functions *during* a task are
//!    **captured** into a per-task write buffer and replayed into the
//!    destination [`StagedOps`] only after the barrier, in (task index,
//!    issue order) — exactly the byte order a serial run produces;
//! 3. errors and panics are reported for the **lowest-index** failing
//!    task, not whichever thread lost the race.
//!
//! The pool uses `std::thread::scope`, so task closures may borrow from
//! the caller; worker threads live for one collective. Thread-locals
//! (e.g. the op-encode scratch in [`crate::roomy::ops`]) are therefore
//! genuinely *per-worker* scratch — every worker thread owns a private
//! instance for the duration of the collective.
//!
//! Nested collectives are not supported from inside task closures: a task
//! may *stage* delayed ops on any structure, but must not invoke another
//! structure's `sync`/`map`/`reduce` (the inner barrier would replay its
//! captured ops out of order with respect to the outer collective).
//!
//! Space note: captured ops live in RAM until the barrier (the
//! destination `SpillBuffer`s only see them at replay), so a collective
//! that issues O(per-task ops) holds that many encoded records in memory
//! per in-flight task. Direct (outside-collective) staging keeps the
//! seed's spill-at-threshold bound. Spilling capture arenas per task is
//! recorded as an open item in ROADMAP.md.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Result, RoomyError};
use crate::metrics::PoolStats;
use crate::roomy::ops::StagedOps;

/// Per-task log of delayed ops issued while the task ran. Records are
/// appended to one arena (`bytes`) in issue order; `entries` names the
/// destination of each record.
#[derive(Default)]
pub(crate) struct OpCapture {
    /// `(destination staging, destination bucket, record length)` per op.
    entries: Vec<(Arc<StagedOps>, u32, u32)>,
    /// Concatenated record bytes, aligned with `entries`.
    bytes: Vec<u8>,
}

impl OpCapture {
    fn push(&mut self, sink: Arc<StagedOps>, bucket: u32, rec: &[u8]) {
        self.entries.push((sink, bucket, rec.len() as u32));
        self.bytes.extend_from_slice(rec);
    }

    /// Apply every captured op to its destination, in issue order.
    fn replay(&self) -> Result<()> {
        let mut off = 0usize;
        for (sink, bucket, len) in &self.entries {
            let end = off + *len as usize;
            sink.stage_direct(*bucket, &self.bytes[off..end])?;
            off = end;
        }
        Ok(())
    }
}

/// Per-thread task context, present only while a pool worker is inside a
/// task closure.
struct TaskCtx {
    worker: usize,
    capture: OpCapture,
}

thread_local! {
    static TASK: RefCell<Option<TaskCtx>> = const { RefCell::new(None) };
}

/// Cheap probe: is the calling thread inside a pool task (capture armed)?
pub(crate) fn capture_active() -> bool {
    TASK.with(|t| t.borrow().is_some())
}

/// Capture `rec` into the current task's op log, if the calling thread is
/// inside a pool task. Returns `false` when no task is active (the caller
/// should stage directly).
pub(crate) fn try_capture(sink: &Arc<StagedOps>, bucket: u32, rec: &[u8]) -> bool {
    TASK.with(|t| match t.borrow_mut().as_mut() {
        Some(ctx) => {
            ctx.capture.push(Arc::clone(sink), bucket, rec);
            true
        }
        None => false,
    })
}

/// Pool worker slot of the calling thread, if it is currently executing a
/// pool task (per-worker scratch, diagnostics).
pub fn current_worker() -> Option<usize> {
    TASK.with(|t| t.borrow().as_ref().map(|c| c.worker))
}

/// One finished task, tagged with its index for deterministic merging.
struct Done<R> {
    task: usize,
    result: Result<R>,
    capture: OpCapture,
}

/// Fixed-width worker pool executing per-bucket collective tasks. One
/// pool lives in each [`crate::cluster::Cluster`]; worker threads are
/// scoped per collective (no idle threads between collectives).
#[derive(Debug)]
pub struct WorkerPool {
    workers: usize,
    stats: PoolStats,
}

impl WorkerPool {
    /// Pool of `workers` threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        WorkerPool { workers, stats: PoolStats::new(workers) }
    }

    /// Configured worker count.
    pub fn num_workers(&self) -> usize {
        self.workers
    }

    /// Per-worker execution counters.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Run `job(task)` for every `task` in `0..ntasks` across the pool and
    /// return the results **in task order**. Delayed ops issued inside
    /// `job` are captured per task and replayed in (task, issue) order
    /// after all tasks complete — see the module docs for why this makes
    /// the schedule invisible.
    ///
    /// On failure the error of the lowest-index failing task is returned
    /// (a panic in task `t` beats an `Err` from any task after `t`);
    /// captured ops are *not* replayed, matching the undefined partial
    /// state any failed collective leaves on disk.
    pub fn run_tasks<R, F>(&self, phase: &str, ntasks: usize, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> Result<R> + Sync,
    {
        if ntasks == 0 {
            return Ok(Vec::new());
        }
        let nthreads = self.workers.min(ntasks);
        let cursor = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);

        let outs: Vec<(Vec<Done<R>>, Option<(usize, usize)>)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..nthreads)
                    .map(|wid| {
                        let (cursor, abort, job, stats) =
                            (&cursor, &abort, &job, &self.stats);
                        scope.spawn(move || {
                            let mut done: Vec<Done<R>> = Vec::new();
                            let mut panicked: Option<(usize, usize)> = None;
                            while !abort.load(Ordering::Relaxed) {
                                let t = cursor.fetch_add(1, Ordering::Relaxed);
                                if t >= ntasks {
                                    break;
                                }
                                let t0 = Instant::now();
                                TASK.with(|c| {
                                    *c.borrow_mut() = Some(TaskCtx {
                                        worker: wid,
                                        capture: OpCapture::default(),
                                    })
                                });
                                let r = catch_unwind(AssertUnwindSafe(|| job(t)));
                                let ctx = TASK
                                    .with(|c| c.borrow_mut().take())
                                    .expect("pool task context vanished");
                                stats.charge(wid, t0.elapsed());
                                match r {
                                    Ok(result) => {
                                        if result.is_err() {
                                            abort.store(true, Ordering::Relaxed);
                                        }
                                        done.push(Done {
                                            task: t,
                                            result,
                                            capture: ctx.capture,
                                        });
                                    }
                                    Err(_) => {
                                        panicked = Some((t, wid));
                                        abort.store(true, Ordering::Relaxed);
                                        break;
                                    }
                                }
                            }
                            (done, panicked)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("pool worker thread died outside a task"))
                    .collect()
            });

        // Deterministic merge: order everything by task index, then report
        // the lowest-index failure (panic wins ties with itself only).
        let mut all: Vec<Done<R>> = Vec::with_capacity(ntasks);
        let mut panic_at: Option<(usize, usize)> = None;
        for (done, p) in outs {
            all.extend(done);
            if let Some((t, w)) = p {
                panic_at = Some(match panic_at {
                    Some((pt, pw)) if pt <= t => (pt, pw),
                    _ => (t, w),
                });
            }
        }
        all.sort_by_key(|d| d.task);

        let first_err_task = all.iter().find(|d| d.result.is_err()).map(|d| d.task);
        if let Some((pt, pw)) = panic_at {
            if first_err_task.is_none_or(|et| pt < et) {
                return Err(RoomyError::WorkerPanic {
                    worker: pw,
                    phase: phase.to_string(),
                });
            }
        }

        let mut results = Vec::with_capacity(ntasks);
        let mut captures = Vec::with_capacity(ntasks);
        for d in all {
            match d.result {
                Ok(r) => {
                    results.push(r);
                    captures.push(d.capture);
                }
                Err(e) => return Err(e),
            }
        }
        debug_assert_eq!(results.len(), ntasks, "abort never set ⇒ all tasks ran");

        // Post-barrier replay: (task index, issue order) == serial order.
        for cap in &captures {
            cap.replay()?;
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::RoomyConfig;
    use crate::testutil::tmpdir;

    fn pool(n: usize) -> WorkerPool {
        WorkerPool::new(n)
    }

    #[test]
    fn results_come_back_in_task_order() {
        for workers in [1, 2, 4, 7] {
            let p = pool(workers);
            let out = p
                .run_tasks("t", 33, |t| {
                    // stagger completion to scramble the schedule
                    if t % 3 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    Ok(t * 10)
                })
                .unwrap();
            assert_eq!(out, (0..33).map(|t| t * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_tasks_is_empty() {
        let p = pool(4);
        let out: Vec<u32> = p.run_tasks("t", 0, |_| Ok(1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn workers_clamped_to_one() {
        let p = pool(0);
        assert_eq!(p.num_workers(), 1);
        let out = p.run_tasks("t", 3, Ok).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn parallelism_is_real() {
        // With 4 workers and 4 tasks, all tasks must be in flight at once.
        let p = pool(4);
        let barrier = std::sync::Barrier::new(4);
        p.run_tasks("t", 4, |_t| {
            barrier.wait();
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn lowest_index_error_wins() {
        let p = pool(4);
        let r: Result<Vec<()>> = p.run_tasks("t", 16, |t| {
            if t >= 3 {
                Err(RoomyError::InvalidArg(format!("task {t}")))
            } else {
                Ok(())
            }
        });
        match r {
            Err(RoomyError::InvalidArg(msg)) => assert_eq!(msg, "task 3"),
            other => panic!("expected InvalidArg, got {other:?}"),
        }
    }

    #[test]
    fn panic_becomes_worker_panic() {
        let p = pool(2);
        let r: Result<Vec<()>> = p.run_tasks("boom-phase", 8, |t| {
            if t == 1 {
                panic!("task exploded");
            }
            Ok(())
        });
        match r {
            Err(RoomyError::WorkerPanic { phase, .. }) => assert_eq!(phase, "boom-phase"),
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
    }

    #[test]
    fn current_worker_visible_inside_tasks_only() {
        assert_eq!(current_worker(), None);
        let p = pool(3);
        p.run_tasks("t", 9, |_t| {
            let w = current_worker().expect("inside a task");
            assert!(w < 3);
            Ok(())
        })
        .unwrap();
        assert_eq!(current_worker(), None);
    }

    #[test]
    fn stats_count_every_task() {
        let p = pool(2);
        p.run_tasks("t", 10, |_| Ok(())).unwrap();
        assert_eq!(p.stats().total_tasks(), 10);
        p.stats().reset();
        assert_eq!(p.stats().total_tasks(), 0);
    }

    /// Captured ops must replay in (task, issue) order — the serial byte
    /// order — no matter how many workers race.
    #[test]
    fn capture_replays_in_serial_order() {
        let t = tmpdir("pool_capture");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let mut reference: Option<Vec<u8>> = None;
        for workers in [1usize, 2, 4] {
            let p = pool(workers);
            p.run_tasks("t", 8, |task| {
                // jitter the schedule
                if task % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(150));
                }
                for k in 0..3u8 {
                    staged.stage(0, &[task as u8, k])?;
                }
                Ok(())
            })
            .unwrap();

            let buf = staged.take(0, &cluster, "cap", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            match &reference {
                None => {
                    // serial (1 worker) defines the canonical order:
                    // task-major, issue-minor
                    let expect: Vec<u8> = (0..8u8)
                        .flat_map(|t| (0..3u8).map(move |k| [t, k]))
                        .flatten()
                        .collect();
                    assert_eq!(got, expect);
                    reference = Some(got);
                }
                Some(r0) => assert_eq!(&got, r0, "workers={workers} diverged"),
            }
        }
    }

    /// Ops staged outside any pool task go straight to the buffer.
    #[test]
    fn direct_staging_outside_pool() {
        let t = tmpdir("pool_direct");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 1;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "d", 64);
        staged.stage(0, &[1, 2, 3]).unwrap();
        assert_eq!(staged.staged_bytes(), 3);
    }
}
