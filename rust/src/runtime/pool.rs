//! Worker-pool execution engine for Roomy collectives.
//!
//! A [`WorkerPool`] fans a set of **independent bucket tasks** out to
//! `num_workers` scoped worker threads. Workers claim tasks dynamically
//! (an atomic cursor — cheap work stealing, so a skewed bucket does not
//! stall the others), and three mechanisms keep the result *observably
//! identical* to a serial run regardless of worker count or schedule:
//!
//! 1. results are returned **indexed by task** (ascending bucket order),
//!    never in completion order;
//! 2. delayed operations issued by user functions *during* a task are
//!    **captured** into per-task, per-destination logs and replayed into
//!    the destination [`StagedOps`] only after the barrier, ordered by
//!    (task index, destination, issue order) — each destination's staging
//!    receives exactly the byte sequence a serial run produces (only the
//!    interleaving *across* destinations differs, which no buffer
//!    observes);
//! 3. errors and panics are reported for the **lowest-index** failing
//!    task, not whichever thread lost the race.
//!
//! The pool uses `std::thread::scope`, so task closures may borrow from
//! the caller; worker threads live for one collective. Thread-locals
//! (e.g. the op-encode scratch in [`crate::roomy::ops`]) are therefore
//! genuinely *per-worker* scratch — every worker thread owns a private
//! instance for the duration of the collective.
//!
//! Nested collectives are not supported from inside task closures: a task
//! may *stage* delayed ops on any structure, but must not invoke another
//! structure's `sync`/`map`/`reduce` (the inner barrier would replay its
//! captured ops out of order with respect to the outer collective).
//!
//! Space note: op capture is **spill-backed**, so the strict space bound
//! holds inside collectives too. Each task's [`OpCapture`] keeps one
//! [`SpillBuffer`] per destination structure, and all of a task's logs
//! share one **flat**
//! [`RoomyConfig::capture_spill_threshold`](crate::RoomyConfig::capture_spill_threshold)
//! budget: when a push takes the task's total capture RAM over the
//! budget, the largest log flushes to its private scratch file
//! (`tmp/capture/r<run>t<task>/d<K>.capture` on a node disk, created
//! lazily) until the task is back under. Per-task capture RAM is
//! O(threshold), not O(ops issued) and not O(destination structures).
//! Budget-forced flushes are counted in
//! [`PoolStats::capture_budget_spills`](crate::metrics::PoolStats::capture_budget_spills).
//! Post-barrier replay streams each log back in (task, destination,
//! issue) order — per-destination byte order identical to serial — and
//! deletes the scratch files; failed or panicking tasks delete theirs on
//! drop, so `tmp/capture/` never leaks. Direct (outside-collective)
//! staging keeps the seed's spill-at-threshold bound as before. Capture
//! volume is observable via the capture counters in
//! [`crate::metrics::PoolStats`].

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Result, RoomyError};
use crate::metrics::PoolStats;
use crate::roomy::ops::StagedOps;
use crate::storage::{NodeDisk, SpillBuffer};

/// Capture log record header: `[bucket u32 LE, payload len u32 LE]`.
const CAPTURE_HDR: usize = 8;

/// Where one task's capture logs overflow to: a private scratch directory
/// on one node disk, created lazily on first spill and removed when the
/// capture is replayed or discarded. `threshold` is the task's **flat**
/// capture-RAM budget, shared across all destination logs.
pub(crate) struct CaptureBacking {
    disk: Arc<NodeDisk>,
    dir_rel: String,
    threshold: usize,
}

/// One destination structure's capture log within a task.
struct DestLog {
    sink: Arc<StagedOps>,
    buf: SpillBuffer,
}

/// Per-task log of delayed ops issued while the task ran. One
/// [`SpillBuffer`] per destination structure holds `[bucket, len,
/// payload]` records in issue order; all of a task's logs share one flat
/// `capture_spill_threshold` budget — when a push takes the task's total
/// capture RAM over it, the largest log flushes to scratch (ties go to
/// the oldest log), so capture RAM per task stays O(threshold) however
/// many ops a collective issues and however many structures it stages
/// into. Without backing (a bare pool outside any cluster) logs are
/// RAM-only, preserving the old unbounded behavior.
pub(crate) struct OpCapture {
    backing: Option<CaptureBacking>,
    logs: Vec<DestLog>,
    /// Record bytes captured (headers included).
    bytes: u64,
    /// High-water mark of capture RAM across this task's logs, including
    /// the transient peak just before a push triggers a spill.
    peak_ram: usize,
    /// Sum of `ram_bytes()` across logs, maintained incrementally so the
    /// per-op path never scans the log list.
    ram_total: usize,
    /// Spills forced by the shared budget (reported to `PoolStats`).
    budget_spills: u64,
    /// Log index the previous op hit — consecutive ops overwhelmingly
    /// target the same destination, so this usually skips the lookup.
    last_idx: usize,
}

impl OpCapture {
    fn new(backing: Option<CaptureBacking>) -> Self {
        OpCapture {
            backing,
            logs: Vec::new(),
            bytes: 0,
            peak_ram: 0,
            ram_total: 0,
            budget_spills: 0,
            last_idx: 0,
        }
    }

    fn push(&mut self, sink: Arc<StagedOps>, bucket: u32, rec: &[u8]) -> Result<()> {
        // The transient maximum inside this push: current RAM across all
        // logs plus the record about to be appended (the budget check
        // runs after the append).
        self.peak_ram = self.peak_ram.max(self.ram_total + CAPTURE_HDR + rec.len());

        let idx = if self
            .logs
            .get(self.last_idx)
            .is_some_and(|l| Arc::ptr_eq(&l.sink, &sink))
        {
            self.last_idx
        } else {
            match self.logs.iter().position(|l| Arc::ptr_eq(&l.sink, &sink)) {
                Some(i) => i,
                None => {
                    // Spill timing is driven by the shared budget below,
                    // so the buffer's own threshold is disarmed (it only
                    // spills when this capture tells it to).
                    let buf = match &self.backing {
                        Some(b) => SpillBuffer::new(
                            Arc::clone(&b.disk),
                            format!("{}/d{}.capture", b.dir_rel, self.logs.len()),
                            usize::MAX,
                        ),
                        None => SpillBuffer::ram_only(),
                    };
                    self.logs.push(DestLog { sink, buf });
                    self.logs.len() - 1
                }
            }
        };
        self.last_idx = idx;
        let buf = &mut self.logs[idx].buf;
        let mut hdr = [0u8; CAPTURE_HDR];
        hdr[..4].copy_from_slice(&bucket.to_le_bytes());
        hdr[4..].copy_from_slice(&(rec.len() as u32).to_le_bytes());
        buf.push(&hdr)?;
        buf.push(rec)?;
        self.ram_total += CAPTURE_HDR + rec.len();
        self.bytes += (CAPTURE_HDR + rec.len()) as u64;

        // Flat per-task budget: flush the largest log until back under.
        if let Some(b) = &self.backing {
            while self.ram_total > b.threshold {
                let victim = self
                    .logs
                    .iter()
                    .enumerate()
                    .max_by_key(|&(i, l)| (l.buf.ram_bytes(), std::cmp::Reverse(i)))
                    .map(|(i, l)| (i, l.buf.ram_bytes()))
                    .expect("over-budget capture has at least one log");
                let (vi, vram) = victim;
                if vram == 0 {
                    break; // nothing left to flush (tiny budget, all spilled)
                }
                self.logs[vi].buf.spill()?;
                self.ram_total -= vram;
                self.budget_spills += 1;
            }
        }
        Ok(())
    }

    /// Stream every captured op back to its destination, per destination
    /// in issue order (destinations in first-op order). Consumes the logs;
    /// each scratch file is deleted as its drain is dropped, even if a
    /// downstream stage fails mid-replay.
    fn replay(&mut self) -> Result<()> {
        let logs = std::mem::take(&mut self.logs);
        let mut payload = Vec::new();
        for log in logs {
            let mut drain = log.buf.into_drain()?;
            let mut hdr = [0u8; CAPTURE_HDR];
            while drain.read_exact_or_eof(&mut hdr)? {
                let bucket = u32::from_le_bytes(hdr[..4].try_into().unwrap());
                let len = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
                payload.resize(len, 0);
                if !drain.read_exact_or_eof(&mut payload)? {
                    return Err(RoomyError::InvalidArg(
                        "truncated record in capture log".into(),
                    ));
                }
                log.sink.stage_direct(bucket, &payload)?;
            }
        }
        Ok(())
    }

    /// Bytes spilled to scratch files across this task's logs.
    fn spilled_bytes(&self) -> u64 {
        self.logs.iter().map(|l| l.buf.spilled_bytes()).sum()
    }

    /// Scratch files created (logs that overflowed to disk).
    fn scratch_files(&self) -> u64 {
        self.logs.iter().filter(|l| l.buf.spilled_bytes() > 0).count() as u64
    }
}

impl Drop for OpCapture {
    /// Leak-free teardown on every path: un-replayed logs (task error,
    /// worker panic, a failure elsewhere in the collective) drop their
    /// spill files, and the task's scratch directory goes with them.
    fn drop(&mut self) {
        for log in &mut self.logs {
            let _ = log.buf.clear();
        }
        if let Some(b) = &self.backing {
            let _ = b.disk.remove_dir(&b.dir_rel);
        }
    }
}

/// Per-thread task context, present only while a pool worker is inside a
/// task closure.
struct TaskCtx {
    worker: usize,
    capture: OpCapture,
}

thread_local! {
    static TASK: RefCell<Option<TaskCtx>> = const { RefCell::new(None) };
}

/// Cheap probe: is the calling thread inside a pool task (capture armed)?
pub(crate) fn capture_active() -> bool {
    TASK.with(|t| t.borrow().is_some())
}

/// Capture `rec` into the current task's op log, if the calling thread is
/// inside a pool task. Returns `Ok(false)` when no task is active (the
/// caller should stage directly); errors are spill-file I/O failures.
pub(crate) fn try_capture(sink: &Arc<StagedOps>, bucket: u32, rec: &[u8]) -> Result<bool> {
    TASK.with(|t| match t.borrow_mut().as_mut() {
        Some(ctx) => {
            ctx.capture.push(Arc::clone(sink), bucket, rec)?;
            Ok(true)
        }
        None => Ok(false),
    })
}

/// Pool worker slot of the calling thread, if it is currently executing a
/// pool task (per-worker scratch, diagnostics).
pub fn current_worker() -> Option<usize> {
    TASK.with(|t| t.borrow().as_ref().map(|c| c.worker))
}

/// One finished task, tagged with its index for deterministic merging.
struct Done<R> {
    task: usize,
    result: Result<R>,
    capture: OpCapture,
}

/// Spill backing shared by every capture the pool arms: the cluster's
/// node disks, the capture threshold, and a run counter that keeps the
/// scratch directories of concurrent collectives on one pool disjoint.
#[derive(Debug)]
struct CaptureSpillCfg {
    disks: Vec<Arc<NodeDisk>>,
    threshold: usize,
    runs: AtomicU64,
}

/// Fixed-width worker pool executing per-bucket collective tasks. One
/// pool lives in each [`crate::cluster::Cluster`]; worker threads are
/// scoped per collective (no idle threads between collectives).
#[derive(Debug)]
pub struct WorkerPool {
    workers: usize,
    stats: PoolStats,
    capture: Option<CaptureSpillCfg>,
}

impl WorkerPool {
    /// Pool of `workers` threads (clamped to ≥ 1). Until
    /// [`WorkerPool::set_capture_spill`] is called, op capture is RAM-only
    /// (no disks to spill to).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        WorkerPool { workers, stats: PoolStats::new(workers), capture: None }
    }

    /// Back op capture with scratch files on `disks` (task `t` scratches
    /// on `disks[t % disks.len()]` — the owner of bucket `t` under the
    /// cluster's round-robin layout). `threshold` is each task's **flat**
    /// capture-RAM budget across all of its destination logs. Called by
    /// [`crate::cluster::Cluster::new`] with
    /// [`RoomyConfig::capture_spill_threshold`](crate::RoomyConfig::capture_spill_threshold).
    pub(crate) fn set_capture_spill(&mut self, disks: Vec<Arc<NodeDisk>>, threshold: usize) {
        debug_assert!(!disks.is_empty() && threshold > 0);
        self.capture = Some(CaptureSpillCfg { disks, threshold, runs: AtomicU64::new(0) });
    }

    /// Configured worker count.
    pub fn num_workers(&self) -> usize {
        self.workers
    }

    /// Per-worker execution counters.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Spill backing for task `t` of run `run`, if the pool has disks.
    fn capture_backing(&self, run: u64, t: usize) -> Option<CaptureBacking> {
        self.capture.as_ref().map(|c| CaptureBacking {
            disk: Arc::clone(&c.disks[t % c.disks.len()]),
            dir_rel: format!("tmp/capture/r{run}t{t}"),
            threshold: c.threshold,
        })
    }

    /// Run `job(task)` for every `task` in `0..ntasks` across the pool and
    /// return the results **in task order**. Delayed ops issued inside
    /// `job` are captured per task and replayed in (task, destination,
    /// issue) order after all tasks complete — per destination buffer
    /// that is the serial byte order; see the module docs for why this
    /// makes the schedule invisible.
    ///
    /// On failure the error of the lowest-index failing task is returned
    /// (a panic in task `t` beats an `Err` from any task after `t`);
    /// captured ops are *not* replayed, matching the undefined partial
    /// state any failed collective leaves on disk — but every task's
    /// capture scratch files are removed, so failure never leaks disk
    /// space under `tmp/capture/`.
    pub fn run_tasks<R, F>(&self, phase: &str, ntasks: usize, job: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> Result<R> + Sync,
    {
        if ntasks == 0 {
            return Ok(Vec::new());
        }
        let nthreads = self.workers.min(ntasks);
        let cursor = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let run = self
            .capture
            .as_ref()
            .map(|c| c.runs.fetch_add(1, Ordering::Relaxed))
            .unwrap_or(0);

        let outs: Vec<(Vec<Done<R>>, Option<(usize, usize)>)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..nthreads)
                    .map(|wid| {
                        let (cursor, abort, job, stats) =
                            (&cursor, &abort, &job, &self.stats);
                        scope.spawn(move || {
                            let mut done: Vec<Done<R>> = Vec::new();
                            let mut panicked: Option<(usize, usize)> = None;
                            while !abort.load(Ordering::Relaxed) {
                                let t = cursor.fetch_add(1, Ordering::Relaxed);
                                if t >= ntasks {
                                    break;
                                }
                                let t0 = Instant::now();
                                TASK.with(|c| {
                                    *c.borrow_mut() = Some(TaskCtx {
                                        worker: wid,
                                        capture: OpCapture::new(
                                            self.capture_backing(run, t),
                                        ),
                                    })
                                });
                                let r = catch_unwind(AssertUnwindSafe(|| job(t)));
                                let ctx = TASK
                                    .with(|c| c.borrow_mut().take())
                                    .expect("pool task context vanished");
                                stats.charge(wid, t0.elapsed());
                                stats.charge_capture(
                                    ctx.capture.bytes,
                                    ctx.capture.spilled_bytes(),
                                    ctx.capture.scratch_files(),
                                    ctx.capture.peak_ram as u64,
                                    ctx.capture.budget_spills,
                                );
                                match r {
                                    Ok(result) => {
                                        if result.is_err() {
                                            abort.store(true, Ordering::Relaxed);
                                        }
                                        done.push(Done {
                                            task: t,
                                            result,
                                            capture: ctx.capture,
                                        });
                                    }
                                    Err(_) => {
                                        panicked = Some((t, wid));
                                        abort.store(true, Ordering::Relaxed);
                                        break;
                                    }
                                }
                            }
                            (done, panicked)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("pool worker thread died outside a task"))
                    .collect()
            });

        // Deterministic merge: order everything by task index, then report
        // the lowest-index failure (panic wins ties with itself only).
        let mut all: Vec<Done<R>> = Vec::with_capacity(ntasks);
        let mut panic_at: Option<(usize, usize)> = None;
        for (done, p) in outs {
            all.extend(done);
            if let Some((t, w)) = p {
                panic_at = Some(match panic_at {
                    Some((pt, pw)) if pt <= t => (pt, pw),
                    _ => (t, w),
                });
            }
        }
        all.sort_by_key(|d| d.task);

        let first_err_task = all.iter().find(|d| d.result.is_err()).map(|d| d.task);
        if let Some((pt, pw)) = panic_at {
            if first_err_task.is_none_or(|et| pt < et) {
                return Err(RoomyError::WorkerPanic {
                    worker: pw,
                    phase: phase.to_string(),
                });
            }
        }

        let mut results = Vec::with_capacity(ntasks);
        let mut captures = Vec::with_capacity(ntasks);
        for d in all {
            match d.result {
                Ok(r) => {
                    results.push(r);
                    captures.push(d.capture);
                }
                Err(e) => return Err(e),
            }
        }
        debug_assert_eq!(results.len(), ntasks, "abort never set ⇒ all tasks ran");

        // Post-barrier replay: (task index, issue order) == serial order.
        // Each capture is dropped as soon as it has replayed, deleting its
        // scratch directory; on error the remaining captures drop too, so
        // no scratch state survives a failed collective.
        for mut cap in captures {
            cap.replay()?;
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::RoomyConfig;
    use crate::testutil::tmpdir;

    fn pool(n: usize) -> WorkerPool {
        WorkerPool::new(n)
    }

    #[test]
    fn results_come_back_in_task_order() {
        for workers in [1, 2, 4, 7] {
            let p = pool(workers);
            let out = p
                .run_tasks("t", 33, |t| {
                    // stagger completion to scramble the schedule
                    if t % 3 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    Ok(t * 10)
                })
                .unwrap();
            assert_eq!(out, (0..33).map(|t| t * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_tasks_is_empty() {
        let p = pool(4);
        let out: Vec<u32> = p.run_tasks("t", 0, |_| Ok(1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn workers_clamped_to_one() {
        let p = pool(0);
        assert_eq!(p.num_workers(), 1);
        let out = p.run_tasks("t", 3, Ok).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn parallelism_is_real() {
        // With 4 workers and 4 tasks, all tasks must be in flight at once.
        let p = pool(4);
        let barrier = std::sync::Barrier::new(4);
        p.run_tasks("t", 4, |_t| {
            barrier.wait();
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn lowest_index_error_wins() {
        let p = pool(4);
        let r: Result<Vec<()>> = p.run_tasks("t", 16, |t| {
            if t >= 3 {
                Err(RoomyError::InvalidArg(format!("task {t}")))
            } else {
                Ok(())
            }
        });
        match r {
            Err(RoomyError::InvalidArg(msg)) => assert_eq!(msg, "task 3"),
            other => panic!("expected InvalidArg, got {other:?}"),
        }
    }

    #[test]
    fn panic_becomes_worker_panic() {
        let p = pool(2);
        let r: Result<Vec<()>> = p.run_tasks("boom-phase", 8, |t| {
            if t == 1 {
                panic!("task exploded");
            }
            Ok(())
        });
        match r {
            Err(RoomyError::WorkerPanic { phase, .. }) => assert_eq!(phase, "boom-phase"),
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
    }

    #[test]
    fn current_worker_visible_inside_tasks_only() {
        assert_eq!(current_worker(), None);
        let p = pool(3);
        p.run_tasks("t", 9, |_t| {
            let w = current_worker().expect("inside a task");
            assert!(w < 3);
            Ok(())
        })
        .unwrap();
        assert_eq!(current_worker(), None);
    }

    #[test]
    fn stats_count_every_task() {
        let p = pool(2);
        p.run_tasks("t", 10, |_| Ok(())).unwrap();
        assert_eq!(p.stats().total_tasks(), 10);
        p.stats().reset();
        assert_eq!(p.stats().total_tasks(), 0);
    }

    /// Captured ops must replay in (task, issue) order — the serial byte
    /// order — no matter how many workers race.
    #[test]
    fn capture_replays_in_serial_order() {
        let t = tmpdir("pool_capture");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let mut reference: Option<Vec<u8>> = None;
        for workers in [1usize, 2, 4] {
            let p = pool(workers);
            p.run_tasks("t", 8, |task| {
                // jitter the schedule
                if task % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(150));
                }
                for k in 0..3u8 {
                    staged.stage(0, &[task as u8, k])?;
                }
                Ok(())
            })
            .unwrap();

            let buf = staged.take(0, &cluster, "cap", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            match &reference {
                None => {
                    // serial (1 worker) defines the canonical order:
                    // task-major, issue-minor
                    let expect: Vec<u8> = (0..8u8)
                        .flat_map(|t| (0..3u8).map(move |k| [t, k]))
                        .flatten()
                        .collect();
                    assert_eq!(got, expect);
                    reference = Some(got);
                }
                Some(r0) => assert_eq!(&got, r0, "workers={workers} diverged"),
            }
        }
    }

    use crate::testutil::files_under;

    /// With spill backing and a tiny threshold, capture overflows to
    /// scratch files, replays in serial order, keeps per-task RAM bounded,
    /// and removes every scratch file afterwards.
    #[test]
    fn spill_backed_capture_replays_and_cleans_up() {
        let t = tmpdir("pool_capture_spill");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let threshold = 16usize;
        let rec_len = 2usize;
        let mut reference: Option<Vec<u8>> = None;
        for workers in [1usize, 2, 4] {
            let mut p = pool(workers);
            p.set_capture_spill(cluster.disks().to_vec(), threshold);
            p.run_tasks("t", 6, |task| {
                if task % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(150));
                }
                // ~10x threshold bytes of ops per task
                for k in 0..16u8 {
                    staged.stage(0, &[task as u8, k])?;
                }
                Ok(())
            })
            .unwrap();

            assert!(p.stats().capture_spilled_bytes() > 0, "must have spilled");
            assert!(p.stats().capture_scratch_files() > 0);
            assert!(
                p.stats().capture_peak_task_ram() as usize
                    <= threshold + super::CAPTURE_HDR + rec_len,
                "peak capture RAM {} exceeds threshold {} + record",
                p.stats().capture_peak_task_ram(),
                threshold,
            );
            // scratch fully cleaned after the barrier
            for w in 0..cluster.nworkers() {
                let scratch = cluster.disk(w).root().join("tmp/capture");
                assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
            }

            let buf = staged.take(0, &cluster, "cap", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            match &reference {
                None => {
                    let expect: Vec<u8> = (0..6u8)
                        .flat_map(|t| (0..16u8).map(move |k| [t, k]))
                        .flatten()
                        .collect();
                    assert_eq!(got, expect);
                    reference = Some(got);
                }
                Some(r0) => assert_eq!(&got, r0, "workers={workers} diverged"),
            }
        }
    }

    /// The capture budget is **flat per task**: staging into several
    /// destination structures shares one threshold, so peak capture RAM
    /// stays ≤ threshold + one record however many destinations a task
    /// touches — and the forced flushes are counted.
    #[test]
    fn flat_budget_shared_across_destinations() {
        let t = tmpdir("pool_capture_flat");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let dst_a = StagedOps::new(&cluster, "fa", 1 << 20);
        let dst_b = StagedOps::new(&cluster, "fb", 1 << 20);

        let threshold = 64usize;
        let rec_len = 2usize;
        let mut p = pool(2);
        p.set_capture_spill(cluster.disks().to_vec(), threshold);
        p.run_tasks("t", 4, |task| {
            // alternate destinations; per-destination volume stays under
            // the threshold, but the task total (~320 bytes) exceeds it —
            // only the shared budget can force spills here
            for k in 0..16u8 {
                let rec = [task as u8, k];
                if k % 2 == 0 {
                    dst_a.stage(0, &rec)?;
                } else {
                    dst_b.stage(0, &rec)?;
                }
            }
            Ok(())
        })
        .unwrap();

        assert!(p.stats().capture_budget_spills() > 0, "budget never forced a spill");
        assert!(
            p.stats().capture_peak_task_ram() as usize
                <= threshold + super::CAPTURE_HDR + rec_len,
            "flat budget violated: peak {} > {} + record",
            p.stats().capture_peak_task_ram(),
            threshold,
        );
        // both destinations replayed in serial order
        for (staged, parity) in [(&dst_a, 0u8), (&dst_b, 1u8)] {
            let buf = staged.take(0, &cluster, "f", 1 << 20);
            let mut r = buf.reader().unwrap();
            let mut got = Vec::new();
            let mut rec = [0u8; 2];
            while r.read_exact_or_eof(&mut rec).unwrap() {
                got.extend_from_slice(&rec);
            }
            let expect: Vec<u8> = (0..4u8)
                .flat_map(|t| (0..16u8).filter(move |k| k % 2 == parity).map(move |k| [t, k]))
                .flatten()
                .collect();
            assert_eq!(got, expect, "destination parity {parity} diverged");
        }
        // scratch fully cleaned after the barrier
        for w in 0..cluster.nworkers() {
            let scratch = cluster.disk(w).root().join("tmp/capture");
            assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
        }
    }

    /// A panicking task must not leave capture scratch files behind, and
    /// neither must the already-completed tasks whose captures are thrown
    /// away with the failed collective.
    #[test]
    fn failed_collective_leaves_no_capture_scratch() {
        let t = tmpdir("pool_capture_panic");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 2;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "cap", 1 << 20);

        let mut p = pool(4);
        p.set_capture_spill(cluster.disks().to_vec(), 8);
        let r: Result<Vec<()>> = p.run_tasks("boom", 8, |task| {
            for k in 0..32u8 {
                staged.stage(0, &[task as u8, k])?; // forces spills
            }
            if task == 5 {
                panic!("mid-collective failure");
            }
            Ok(())
        });
        assert!(matches!(r, Err(RoomyError::WorkerPanic { .. })));
        for w in 0..cluster.nworkers() {
            let scratch = cluster.disk(w).root().join("tmp/capture");
            assert_eq!(files_under(&scratch), 0, "scratch leak on node {w}");
        }
        // nothing was replayed either
        assert_eq!(staged.staged_bytes(), 0);
    }

    /// Ops staged outside any pool task go straight to the buffer.
    #[test]
    fn direct_staging_outside_pool() {
        let t = tmpdir("pool_direct");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.workers = 1;
        cfg.buckets_per_worker = 1;
        let cluster = Cluster::new(&cfg).unwrap();
        let staged = StagedOps::new(&cluster, "d", 64);
        staged.stage(0, &[1, 2, 3]).unwrap();
        assert_eq!(staged.staged_bytes(), 3);
    }
}
