//! Offline [`Engine`] stub: the build has no PJRT client (`xla` feature
//! disabled), so artifact execution is unavailable and every accel caller
//! falls back to the bit-exact Rust kernels in [`crate::accel`].
//!
//! [`Engine::load`] still validates `manifest.tsv` so configuration errors
//! (missing directory, malformed manifest) surface identically to the real
//! engine — but it never returns an instance, so the methods below exist
//! only to satisfy the [`crate::accel`] call sites at compile time.

use std::path::Path;
use std::sync::Arc;

use super::TensorBuf;
use crate::config::{AccelMode, RoomyConfig};
use crate::error::{Result, RoomyError};

/// PJRT engine handle (stub: can never be constructed).
#[derive(Debug)]
pub struct Engine {
    _unconstructible: (),
}

impl Engine {
    /// Validate the manifest, then fail: executing artifacts requires the
    /// `xla` feature.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| RoomyError::io(&manifest, e))?;
        let mut entries = 0usize;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let mut cols = line.split('\t');
            let (name, file) =
                (cols.next().unwrap_or_default(), cols.next().unwrap_or_default());
            if name.is_empty() || file.is_empty() {
                return Err(RoomyError::InvalidArg(format!(
                    "malformed manifest line: {line:?}"
                )));
            }
            entries += 1;
        }
        Err(RoomyError::Xla(format!(
            "{entries} artifacts found in {dir:?}, but this build has no PJRT client \
             (enable the `xla` cargo feature); using Rust kernels"
        )))
    }

    /// Resolve the engine implied by `cfg.accel`. Without the `xla`
    /// feature this is always `None`; `AccelMode::Xla` warns.
    pub fn from_config(cfg: &RoomyConfig) -> Option<Arc<Engine>> {
        if cfg.accel == AccelMode::Xla {
            eprintln!(
                "roomy: warning: AccelMode::Xla requested but this build has no PJRT \
                 client (enable the `xla` cargo feature); using Rust kernels"
            );
        }
        None
    }

    /// Names of all known entry points (stub: unreachable).
    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    /// Whether entry point `name` is available (stub: unreachable).
    pub fn has(&self, _name: &str) -> bool {
        false
    }

    /// Execute entry point `name` (stub: unreachable).
    pub fn run(&self, name: &str, _inputs: Vec<TensorBuf>) -> Result<Vec<TensorBuf>> {
        Err(RoomyError::Xla(format!(
            "cannot execute {name:?}: built without the `xla` feature"
        )))
    }
}
